//! Matrix fingerprinting for the autotuner's persistent plan cache.
//!
//! A [`Fingerprint`] is (1) a **structural hash** — FNV-1a over the
//! dimensions and the full CSR structure (row lengths + column
//! indices), so two matrices share a cache entry only when their
//! sparsity patterns are identical — and (2) a small **feature vector**
//! (row-length histogram moments, diagonal dominance, mean band)
//! drawing on [`crate::sparse::stats`]. The hash keys the plan store;
//! the features steer the tuner's candidate generation (e.g. where to
//! place the ELL/ER width cutoff) without a second pass over the
//! matrix.

use crate::sparse::csr::Csr;
use crate::sparse::scalar::Scalar;
use crate::util::stats::Summary;

const FNV_OFFSET: u64 = 0xcbf29ce484222325;
const FNV_PRIME: u64 = 0x100000001b3;

#[inline]
fn fnv1a(mut h: u64, bytes: &[u8]) -> u64 {
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(FNV_PRIME);
    }
    h
}

/// Structural identity + shape features of one matrix — the cache key
/// and candidate-generation input of the autotuner.
#[derive(Clone, Debug, PartialEq)]
pub struct Fingerprint {
    pub nrows: usize,
    pub ncols: usize,
    pub nnz: usize,
    /// FNV-1a over dimensions, per-row lengths, and column indices.
    /// Values are deliberately excluded: the EHYB layout depends only
    /// on structure, so numerically-updated matrices (same pattern)
    /// reuse the cached plan — OSKI's "same structure, new values"
    /// amortization.
    pub structure_hash: u64,
    /// Row-length histogram moments.
    pub row_mean: f64,
    pub row_max: f64,
    pub row_stddev: f64,
    pub row_median: f64,
    /// Fraction of rows that are (weakly) diagonally dominant:
    /// `|a_ii| >= Σ_{j≠i} |a_ij|`. The one value-dependent feature —
    /// a proxy for FEM/SPD-like systems vs circuit-style matrices.
    pub diag_dominant_fraction: f64,
    /// Mean `|col - row|` over all entries (locality proxy).
    pub mean_band: f64,
}

impl Fingerprint {
    pub fn of<S: Scalar>(m: &Csr<S>) -> Self {
        let n = m.nrows();
        let mut h = FNV_OFFSET;
        h = fnv1a(h, &(n as u64).to_le_bytes());
        h = fnv1a(h, &(m.ncols() as u64).to_le_bytes());

        let mut lens = Vec::with_capacity(n);
        let mut band_sum = 0f64;
        let mut dominant = 0usize;
        for i in 0..n {
            let (cols, vals) = m.row(i);
            lens.push(cols.len() as f64);
            h = fnv1a(h, &(cols.len() as u32).to_le_bytes());
            let mut diag = 0f64;
            let mut off = 0f64;
            for (&c, &v) in cols.iter().zip(vals) {
                h = fnv1a(h, &c.to_le_bytes());
                band_sum += (c as i64 - i as i64).unsigned_abs() as f64;
                let a = v.to_f64().abs();
                if c as usize == i {
                    diag += a;
                } else {
                    off += a;
                }
            }
            if diag >= off {
                dominant += 1;
            }
        }
        let row = Summary::of(&lens);
        Fingerprint {
            nrows: n,
            ncols: m.ncols(),
            nnz: m.nnz(),
            structure_hash: h,
            row_mean: row.as_ref().map_or(0.0, |s| s.mean),
            row_max: row.as_ref().map_or(0.0, |s| s.max),
            row_stddev: row.as_ref().map_or(0.0, |s| s.stddev),
            row_median: row.as_ref().map_or(0.0, |s| s.median),
            diag_dominant_fraction: if n == 0 { 0.0 } else { dominant as f64 / n as f64 },
            mean_band: if m.nnz() == 0 { 0.0 } else { band_sum / m.nnz() as f64 },
        }
    }

    /// Filename-safe cache key: hash plus the human-auditable dims.
    pub fn key(&self) -> String {
        format!("{:016x}-n{}-nnz{}", self.structure_hash, self.nrows, self.nnz)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::coo::Coo;
    use crate::sparse::gen::{circuit, poisson2d};

    #[test]
    fn identical_structure_same_key_regardless_of_values() {
        let a = poisson2d::<f64>(12, 12);
        // Same structure, scaled values.
        let mut coo = Coo::<f64>::new(a.nrows(), a.ncols());
        for i in 0..a.nrows() {
            let (cols, vals) = a.row(i);
            for (&c, &v) in cols.iter().zip(vals) {
                coo.push(i, c as usize, 3.0 * v);
            }
        }
        let b = coo.to_csr();
        assert_eq!(Fingerprint::of(&a).key(), Fingerprint::of(&b).key());
    }

    #[test]
    fn different_structure_different_hash() {
        let a = Fingerprint::of(&poisson2d::<f64>(12, 12));
        let b = Fingerprint::of(&poisson2d::<f64>(12, 13));
        let c = Fingerprint::of(&circuit::<f64>(144, 3, 0.05, 1));
        assert_ne!(a.structure_hash, b.structure_hash);
        assert_ne!(a.structure_hash, c.structure_hash);
    }

    #[test]
    fn dtype_does_not_change_structure_hash() {
        // The store key separates dtypes explicitly; the structural hash
        // itself is value- and precision-independent.
        let m64 = poisson2d::<f64>(10, 10);
        let m32: Csr<f32> = m64.cast();
        assert_eq!(
            Fingerprint::of(&m64).structure_hash,
            Fingerprint::of(&m32).structure_hash
        );
    }

    #[test]
    fn features_match_known_matrix() {
        let fp = Fingerprint::of(&poisson2d::<f64>(10, 10));
        assert_eq!(fp.nrows, 100);
        assert_eq!(fp.row_max, 5.0);
        // The 5-point Laplacian (4 on the diagonal, -1 off) is weakly
        // diagonally dominant everywhere.
        assert_eq!(fp.diag_dominant_fraction, 1.0);
        assert!(fp.row_mean > 3.0 && fp.row_mean < 5.0);
        assert!(fp.mean_band > 0.0);
    }

    #[test]
    fn key_is_filename_safe() {
        let key = Fingerprint::of(&poisson2d::<f64>(4, 4)).key();
        assert!(key.chars().all(|c| c.is_ascii_alphanumeric() || c == '-'));
    }
}
