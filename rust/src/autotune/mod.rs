//! OSKI-style autotuning for the EHYB pipeline (ISSUE 3 tentpole): a
//! layer between "format" and "engine" that picks the plan knobs
//! *per matrix* instead of one-size-fits-all, and remembers the answer
//! across process restarts.
//!
//! * [`fingerprint`] — structural hash + feature vector identifying a
//!   matrix (the plan-cache key and the candidate generator's input).
//! * [`tuner`] — searches the EHYB plan space (`slice_height`,
//!   partition count vs. the shared-memory budget from
//!   [`crate::preprocess::cache_size::cache_plan`], the ELL/ER width
//!   cutoff, and the engine kind) at two [`TuneLevel`]s: `Heuristic`
//!   scored by a [`ScoreOracle`] — replayed [`crate::traffic`]
//!   simulation by default, [`crate::perfmodel`] roofline bounds on
//!   request — and `Measured` timing budget-capped probes of the real
//!   candidate engines across `spmv`/`spmv_batch` widths.
//! * [`store`] — the persistent plan cache: JSON via
//!   [`crate::runtime::json`], atomic writes, keyed by
//!   fingerprint × device × scalar type.
//!
//! Callers normally reach all of this through the facade:
//! `SpmvContext::builder(m).tune(level).plan_cache(dir).build()?` —
//! see [`crate::api::SpmvContextBuilder::tune`].
//!
//! **Shard-aware tuning** (the ISSUE 3 follow-up, landed with the
//! [`crate::shard`] layer): a sharded EHYB build
//! (`.shards(..).tune(..)`) runs one search per shard over that
//! shard's square diagonal block, and each winner persists under the
//! *block's own* [`Fingerprint`] — so shard-count or boundary changes
//! re-key naturally, identical shards (e.g. repeating stencil bands)
//! share entries, and a restarted sharded server warm-starts all K
//! searches from the store.

pub mod fingerprint;
pub mod store;
pub mod tuner;

pub use fingerprint::Fingerprint;
pub use store::PlanStore;
pub use tuner::{
    choose_engine, tune, tune_calibrated, tune_scored, tune_with_fingerprint, ScoreOracle,
    TuneLevel, TuneOutcome, TunedPlan,
};

use crate::preprocess::cache_size::DeviceParams;
use crate::preprocess::PreprocessConfig;

/// Filename-safe identity of a device model for plan-store keying.
/// Derived from the sizing-relevant parameters (processor count and
/// scratchpad bytes) — two devices that size partitions identically
/// share cached plans.
pub fn device_key(dev: &DeviceParams) -> String {
    format!("p{}-shm{}", dev.processors, dev.shm_bytes)
}

/// Canonical identity of the full base preprocessing config a tune ran
/// under — the seed knobs the search derives its default plan and
/// candidates from (`slice_height`, `vec_size_override`,
/// `ell_width_cutoff`) **and** every other field that shapes the built
/// `EhybMatrix` (sort, partitioner); the device has its own key
/// component. Recorded in persisted plans and checked on cache hits,
/// so a plan tuned from a different starting config — whose "default
/// plan" (the ≤-guarantee's reference point) was a different plan —
/// never silently serves this build.
pub fn config_key(cfg: &PreprocessConfig) -> String {
    let opt = |v: Option<usize>| v.map_or_else(|| "x".to_string(), |v| v.to_string());
    format!(
        "h{}-v{}-w{}-sd{}-{:?}-r{}-c{}-s{:x}",
        cfg.slice_height,
        opt(cfg.vec_size_override),
        opt(cfg.ell_width_cutoff.map(|c| c as usize)),
        cfg.sort_descending as u8,
        cfg.partition.method,
        cfg.partition.refine_passes,
        cfg.partition.coarsen_factor,
        cfg.partition.seed
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn device_key_distinguishes_models() {
        assert_ne!(device_key(&DeviceParams::v100()), device_key(&DeviceParams::tpu_core()));
        assert_eq!(device_key(&DeviceParams::v100()), "p80-shm98304");
    }

    #[test]
    fn config_key_tracks_every_search_relevant_field() {
        use crate::partition::{PartitionConfig, PartitionMethod};
        let base = PreprocessConfig::default();
        // The seed knobs change the key: a search started from a
        // different default must not share cache entries (its
        // ≤-default guarantee referenced a different plan).
        for other in [
            PreprocessConfig { slice_height: 16, ..base.clone() },
            PreprocessConfig { vec_size_override: Some(96), ..base.clone() },
            PreprocessConfig { ell_width_cutoff: Some(3), ..base.clone() },
            PreprocessConfig { sort_descending: false, ..base.clone() },
            PreprocessConfig {
                partition: PartitionConfig {
                    method: PartitionMethod::Random,
                    ..base.partition.clone()
                },
                ..base.clone()
            },
        ] {
            assert_ne!(config_key(&base), config_key(&other), "{other:?}");
        }
        // Deterministic and device-independent (device has its own key).
        let other_dev = PreprocessConfig { device: DeviceParams::tpu_core(), ..base.clone() };
        assert_eq!(config_key(&base), config_key(&other_dev));
    }
}
