//! The OSKI-style plan search: enumerate EHYB knob settings (and, for
//! [`EngineKind::Auto`], the baseline engines), score each candidate,
//! and return the winner as a serializable [`TunedPlan`].
//!
//! Two search modes:
//!
//! * [`TuneLevel::Heuristic`] — score = model-predicted seconds per
//!   SpMV from the configured [`ScoreOracle`]: the replayed
//!   storage-traffic simulation ([`crate::traffic`], the default — it
//!   sees x reuse, L2 capacity, and the explicit cache) or the
//!   [`crate::perfmodel`] roofline bounds (`ScoreOracle::Roofline`,
//!   the pre-0.7 behaviour). Free of wall-clock noise; no kernel runs.
//! * [`TuneLevel::Measured`] — score = measured seconds per SpMV of a
//!   real microbench probe of each candidate engine — the best
//!   per-vector time across `spmv_batch` widths B ∈ {1, 4, 8}, since
//!   service workloads are batched; the winning width is recorded in
//!   [`TunedPlan::probe_width`] — capped by a time **budget**: the
//!   default plan is always measured, further candidates are probed
//!   only while the budget has room.
//!
//! Selection guarantee (ISSUE 3 acceptance): the default plan is the
//! first scored candidate (under the same oracle and the same probe
//! widths) and is replaced only by a *strictly lower* score, so the
//! tuned plan's score is never worse than the default's.

use super::fingerprint::Fingerprint;
use crate::api::EngineKind;
use crate::gpu::device::GpuDevice;
use crate::perfmodel;
use crate::preprocess::cache_size::cache_plan;
use crate::preprocess::{EhybPlan, PreprocessConfig};
use crate::runtime::json::{self, Json};
use crate::sparse::csr::Csr;
use crate::sparse::scalar::Scalar;
use crate::spmv::SpmvEngine;
use crate::telemetry::Telemetry;
use crate::util::timer::bench_secs;
use crate::util::Timer;
use std::time::Duration;

/// How hard to search (and how to score candidates).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TuneLevel {
    /// Rank candidates by the roofline-predicted time; no kernel runs.
    Heuristic,
    /// Time real microbench probes of each candidate, spending at most
    /// `budget` wall-clock on the whole search (the default plan is
    /// always probed; further candidates only while budget remains).
    Measured { budget: Duration },
}

impl TuneLevel {
    /// `Measured` with the default 250 ms search budget.
    pub fn measured() -> Self {
        TuneLevel::Measured { budget: Duration::from_millis(250) }
    }

    /// Tag stored in persisted plans ("heuristic" / "measured").
    pub fn tag(&self) -> &'static str {
        match self {
            TuneLevel::Heuristic => "heuristic",
            TuneLevel::Measured { .. } => "measured",
        }
    }
}

/// What [`TuneLevel::Heuristic`] scores candidates with. (`Measured`
/// probes wall clock and ignores this.)
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum ScoreOracle {
    /// Replay the candidate plan through the storage-traffic simulator
    /// ([`crate::traffic`]): per-level byte counters with hits
    /// credited. Sees the explicit x-cache, u16 columns, L2 capacity.
    #[default]
    Traffic,
    /// The 0.6 static roofline bounds ([`crate::perfmodel`]):
    /// compulsory bytes / HBM bandwidth. Cheaper (O(1) per candidate
    /// once the plan is built) but blind to reuse.
    Roofline,
}

impl ScoreOracle {
    /// Tag stored in persisted plans ("traffic" / "roofline").
    pub fn tag(&self) -> &'static str {
        match self {
            ScoreOracle::Traffic => "traffic",
            ScoreOracle::Roofline => "roofline",
        }
    }

    /// Inverse of [`ScoreOracle::tag`] (CLI `--oracle` parsing).
    pub fn from_name(s: &str) -> Option<ScoreOracle> {
        match s {
            "traffic" => Some(ScoreOracle::Traffic),
            "roofline" => Some(ScoreOracle::Roofline),
            _ => None,
        }
    }
}

/// The winning plan — everything needed to rebuild the exact pipeline
/// (engine kind + EHYB knobs) plus provenance for auditing. This is
/// the unit the [`super::PlanStore`] persists.
#[derive(Clone, Debug, PartialEq)]
pub struct TunedPlan {
    /// Concrete engine to run (never [`EngineKind::Auto`]).
    pub engine: EngineKind,
    pub slice_height: usize,
    /// `Some(v)` pins `vec_size_override`; `None` keeps equations
    /// (1)–(2) sizing (so the plan stays portable across device models
    /// within one store key).
    pub vec_size: Option<usize>,
    pub ell_width_cutoff: Option<u32>,
    /// Winner's score: seconds per SpMV (roofline-predicted or
    /// measured, per `level`). Lower is better.
    pub score_secs: f64,
    /// The default plan's score under the same metric — always
    /// `>= score_secs` (selection guarantee).
    pub default_score_secs: f64,
    /// "heuristic" | "measured".
    pub level: String,
    /// [`Fingerprint::key`] of the matrix this plan was tuned for.
    pub fingerprint: String,
    /// [`super::device_key`] of the device model used for sizing.
    pub device: String,
    /// Scalar tag ("f32"/"f64").
    pub dtype: String,
    /// [`super::config_key`] of the full base config the plan was
    /// tuned under (seed knobs included — they define the default plan
    /// the ≤-guarantee references). A cache hit is honored only when
    /// it matches, so the recorded scores always describe the search
    /// this build would have run.
    pub base_config: String,
    /// The search scope that produced this plan: the requested
    /// [`EngineKind::name`] ("auto" searched every engine, "ehyb" only
    /// the EHYB knobs, ...). Part of the store *filename*, so an
    /// EHYB-only tune can never clobber the entry an `Auto` search
    /// established (and vice versa).
    pub scope: String,
    /// Resolved [`crate::reorder::ReorderSpec`] tag of the global
    /// ordering the tuned matrix was permuted with ("none" when
    /// untouched). The fingerprint is computed on the *reordered*
    /// structure, so differently-ordered builds already key separate
    /// store entries; this records which ordering produced the entry
    /// and lets the facade refuse a hit whose ordering provenance
    /// disagrees with the current build. The tuner itself always emits
    /// "none" — the facade (which owns the reordering) stamps the tag
    /// before persisting. Entries written before 0.5 load as "none".
    pub reorder: String,
    /// [`ScoreOracle::tag`] the search was configured with ("traffic" |
    /// "roofline") — meaningful provenance for heuristic plans (their
    /// `score_secs` is that model's prediction); measured plans record
    /// the knob too but their scores are wall clock. A heuristic cache
    /// hit is honored only when the oracles match, so switching oracle
    /// re-scores instead of silently reusing the other model's ranking.
    /// Entries written before 0.7 load as "roofline" — that is what
    /// scored them.
    pub oracle: String,
    /// `Measured` probes `spmv_batch` widths {1, 4, 8}; this is the
    /// width whose per-vector time won (1 = single-vector spmv).
    /// 0 for heuristic plans (nothing was probed). Pre-0.7 measured
    /// entries load as 1 — they only ever probed B = 1.
    pub probe_width: u32,
    /// Worst observed-vs-predicted relative component drift
    /// (`DriftReport::max_rel_drift`) stamped back onto the plan by
    /// `ctx.observe_drift()` after real kernel runs — `None` until a
    /// drift check ran (the tuner itself always emits `None`; pre-0.10
    /// entries load as `None`). A warm start honors the cached plan
    /// only while [`Self::drift_ok`] holds, so a plan whose cost-model
    /// provenance went stale is re-searched instead of trusted.
    ///
    /// [`Self::drift_ok`]: TunedPlan::drift_ok
    pub drift: Option<f64>,
}

/// Overlay the three tuned knobs onto a base config — THE single code
/// path for turning (slice_height, vec_size, cutoff) into a
/// `PreprocessConfig`: candidate scoring ([`Candidate::config`]) and
/// plan-cache rebuilds ([`TunedPlan::apply`]) both come through here,
/// so a warm start rebuilds exactly the configuration that was scored.
fn knob_overlay(
    base: &PreprocessConfig,
    slice_height: usize,
    vec_size: Option<usize>,
    cutoff: Option<u32>,
) -> PreprocessConfig {
    PreprocessConfig {
        slice_height,
        vec_size_override: vec_size,
        ell_width_cutoff: cutoff,
        ..base.clone()
    }
}

impl TunedPlan {
    /// Overlay this plan's knobs onto a base preprocessing config
    /// (see [`knob_overlay`] — shared with the tuner's own candidate
    /// builds, so a cache round-trip rebuilds a byte-identical
    /// `EhybMatrix`).
    pub fn apply(&self, base: &PreprocessConfig) -> PreprocessConfig {
        knob_overlay(base, self.slice_height, self.vec_size, self.ell_width_cutoff)
    }

    pub fn to_json(&self) -> Json {
        let opt_num = |v: Option<usize>| match v {
            Some(v) => Json::Num(v as f64),
            None => Json::Null,
        };
        json::obj([
            ("version", Json::Num(1.0)),
            ("engine", Json::Str(self.engine.name().into())),
            ("slice_height", Json::Num(self.slice_height as f64)),
            ("vec_size", opt_num(self.vec_size)),
            ("ell_width_cutoff", opt_num(self.ell_width_cutoff.map(|c| c as usize))),
            ("score_secs", Json::Num(self.score_secs)),
            ("default_score_secs", Json::Num(self.default_score_secs)),
            ("level", Json::Str(self.level.clone())),
            ("fingerprint", Json::Str(self.fingerprint.clone())),
            ("device", Json::Str(self.device.clone())),
            ("dtype", Json::Str(self.dtype.clone())),
            ("base_config", Json::Str(self.base_config.clone())),
            ("scope", Json::Str(self.scope.clone())),
            ("reorder", Json::Str(self.reorder.clone())),
            ("oracle", Json::Str(self.oracle.clone())),
            ("probe_width", Json::Num(self.probe_width as f64)),
            (
                "drift",
                match self.drift {
                    Some(d) => Json::Num(d),
                    None => Json::Null,
                },
            ),
        ])
    }

    /// Whether the plan's observed drift (if any was ever recorded) is
    /// within `threshold`. Plans with no recorded drift pass — absence
    /// of evidence is not staleness.
    pub fn drift_ok(&self, threshold: f64) -> bool {
        self.drift.map_or(true, |d| d <= threshold)
    }

    /// Whether a cached plan may serve a build that requested
    /// `requested` at `level` under a base config with `config_key`:
    ///
    /// * an explicit engine request is never overridden (a plan whose
    ///   winner is another engine is a miss);
    /// * a measured plan serves both levels (it supersedes the
    ///   heuristic model), a heuristic plan never serves a measured
    ///   request — so `Measured` always gets real probes;
    /// * a heuristic plan serves a heuristic request only when it was
    ///   scored by the same [`ScoreOracle`] — a roofline-era entry
    ///   must not masquerade as a traffic-simulated ranking (measured
    ///   plans supersede either oracle);
    /// * the base config (seed knobs included) must match exactly —
    ///   otherwise the cached search started from a different default
    ///   plan and its scores do not describe this build.
    pub fn usable_for(
        &self,
        requested: EngineKind,
        level: TuneLevel,
        oracle: ScoreOracle,
        config_key: &str,
    ) -> bool {
        let kind_ok = requested == EngineKind::Auto || self.engine == requested;
        let level_ok = self.level == level.tag() || self.level == "measured";
        let oracle_ok = self.level == "measured"
            || level.tag() != "heuristic"
            || self.oracle == oracle.tag();
        kind_ok && level_ok && oracle_ok && self.base_config == config_key
    }

    pub fn from_json(j: &Json) -> crate::Result<TunedPlan> {
        fn field<'a>(j: &'a Json, k: &str) -> crate::Result<&'a Json> {
            j.get(k).ok_or_else(|| crate::EhybError::Parse(format!("tuned plan missing {k:?}")))
        }
        fn str_field(j: &Json, k: &str) -> crate::Result<String> {
            Ok(field(j, k)?
                .as_str()
                .ok_or_else(|| {
                    crate::EhybError::Parse(format!("tuned plan field {k:?} not a string"))
                })?
                .to_string())
        }
        fn num_field(j: &Json, k: &str) -> crate::Result<f64> {
            field(j, k)?.as_f64().ok_or_else(|| {
                crate::EhybError::Parse(format!("tuned plan field {k:?} not a number"))
            })
        }
        fn opt_usize(j: &Json, k: &str) -> crate::Result<Option<usize>> {
            match field(j, k)? {
                Json::Null => Ok(None),
                v => Ok(Some(v.as_usize().ok_or_else(|| {
                    crate::EhybError::Parse(format!("tuned plan field {k:?} not a number"))
                })?)),
            }
        }
        let engine_name = str_field(j, "engine")?;
        let engine = EngineKind::from_name(&engine_name).ok_or_else(|| {
            crate::EhybError::Parse(format!("tuned plan has unknown engine {engine_name:?}"))
        })?;
        crate::ensure!(engine != EngineKind::Auto, "tuned plan engine must be concrete");
        let plan = TunedPlan {
            engine,
            slice_height: num_field(j, "slice_height")? as usize,
            vec_size: opt_usize(j, "vec_size")?,
            ell_width_cutoff: opt_usize(j, "ell_width_cutoff")?.map(|c| c as u32),
            score_secs: num_field(j, "score_secs")?,
            default_score_secs: num_field(j, "default_score_secs")?,
            level: str_field(j, "level")?,
            fingerprint: str_field(j, "fingerprint")?,
            device: str_field(j, "device")?,
            dtype: str_field(j, "dtype")?,
            base_config: str_field(j, "base_config")?,
            scope: str_field(j, "scope")?,
            // Absent in pre-0.5 entries: they were tuned without any
            // reordering, which is exactly what "none" records.
            reorder: match j.get("reorder") {
                None => "none".to_string(),
                Some(v) => v
                    .as_str()
                    .ok_or_else(|| {
                        crate::EhybError::Parse("tuned plan field \"reorder\" not a string".into())
                    })?
                    .to_string(),
            },
            // Absent in pre-0.7 entries: the roofline model scored
            // every heuristic plan back then.
            oracle: match j.get("oracle") {
                None => "roofline".to_string(),
                Some(v) => v
                    .as_str()
                    .ok_or_else(|| {
                        crate::EhybError::Parse("tuned plan field \"oracle\" not a string".into())
                    })?
                    .to_string(),
            },
            // Absent in pre-0.7 entries: measured searches only probed
            // the single-vector path (B = 1); heuristic plans probe
            // nothing (0).
            probe_width: match j.get("probe_width") {
                None => u32::from(
                    j.get("level").and_then(|v| v.as_str()).unwrap_or_default() == "measured",
                ),
                Some(v) => v.as_usize().ok_or_else(|| {
                    crate::EhybError::Parse("tuned plan field \"probe_width\" not a number".into())
                })? as u32,
            },
            // Absent in pre-0.10 entries: no drift check ever ran.
            drift: match j.get("drift") {
                None | Some(Json::Null) => None,
                Some(v) => Some(v.as_f64().ok_or_else(|| {
                    crate::EhybError::Parse("tuned plan field \"drift\" not a number".into())
                })?),
            },
        };
        // Range-validate before anything downstream trusts the knobs: a
        // corrupted / hand-edited cache entry must surface as an error
        // (treated as a miss by the facade), never as a panic inside
        // `EhybPlan::build` on every warm start. The EHYB knob checks
        // only apply to EHYB winners — baseline plans carry the base
        // config's values verbatim, which may legitimately be
        // EHYB-infeasible (that can be exactly why a baseline won).
        if plan.engine == EngineKind::Ehyb {
            crate::ensure!(
                plan.slice_height >= 1 && plan.slice_height <= (1 << 16),
                "tuned plan slice_height {} out of range",
                plan.slice_height
            );
            if let Some(v) = plan.vec_size {
                crate::ensure!(
                    v >= plan.slice_height && v % plan.slice_height == 0 && v <= (1 << 16),
                    "tuned plan vec_size {v} invalid for slice_height {}",
                    plan.slice_height
                );
            }
            if let Some(c) = plan.ell_width_cutoff {
                crate::ensure!(c >= 1, "tuned plan ell_width_cutoff must be >= 1");
            }
        }
        crate::ensure!(
            plan.level == "heuristic" || plan.level == "measured",
            "tuned plan has unknown level {:?}",
            plan.level
        );
        crate::ensure!(
            ScoreOracle::from_name(&plan.oracle).is_some(),
            "tuned plan has unknown oracle {:?}",
            plan.oracle
        );
        if let Some(d) = plan.drift {
            crate::ensure!(d.is_finite() && d >= 0.0, "tuned plan drift {d} out of range");
        }
        Ok(plan)
    }
}

/// One point in the search space.
#[derive(Clone, Debug, PartialEq)]
struct Candidate {
    engine: EngineKind,
    slice_height: usize,
    vec_size: Option<usize>,
    cutoff: Option<u32>,
}

impl Candidate {
    fn baseline(kind: EngineKind, base: &PreprocessConfig) -> Candidate {
        Candidate {
            engine: kind,
            slice_height: base.slice_height,
            vec_size: base.vec_size_override,
            cutoff: base.ell_width_cutoff,
        }
    }

    fn ehyb_base(base: &PreprocessConfig) -> Candidate {
        Candidate {
            engine: EngineKind::Ehyb,
            slice_height: base.slice_height,
            vec_size: base.vec_size_override,
            cutoff: base.ell_width_cutoff,
        }
    }

    fn config(&self, base: &PreprocessConfig) -> PreprocessConfig {
        knob_overlay(base, self.slice_height, self.vec_size, self.cutoff)
    }
}

/// Result of one `tune` run: the winning plan, the already-built EHYB
/// preprocessing output for it (when the winner is EHYB — so the facade
/// never rebuilds what the search already paid for), and search stats.
pub struct TuneOutcome<S: Scalar> {
    pub plan: TunedPlan,
    pub ehyb: Option<EhybPlan<S>>,
    /// Candidates actually scored (the default plan is always one).
    pub candidates_tried: usize,
    /// Candidates skipped for any reason (budget exhausted or
    /// infeasible config).
    pub candidates_skipped: usize,
    /// The subset of `candidates_skipped` shed purely because the
    /// `Measured` budget ran out (always 0 for `Heuristic`).
    pub budget_skipped: usize,
    pub search_secs: f64,
}

impl<S: Scalar> TuneOutcome<S> {
    /// Whether the search covered everything the budget allowed. A
    /// budget-starved `Measured` run that probed only the default is
    /// NOT a search result worth caching: persisting it would
    /// permanently pin the unsearched default as the "measured
    /// winner" for every later, better-budgeted request. Infeasible
    /// candidates (e.g. partition failures) do not count against the
    /// search — they can never score, so skipping them loses nothing.
    pub fn searched(&self) -> bool {
        self.candidates_tried > 1 || self.budget_skipped == 0
    }
}

struct Scored<S: Scalar> {
    cand: Candidate,
    score: f64,
    ehyb: Option<EhybPlan<S>>,
    /// Winning `spmv_batch` probe width (0 when nothing was probed,
    /// i.e. heuristic scoring).
    width: u32,
}

/// Search the plan space for `m` under `base`, honoring `requested`:
///
/// * [`EngineKind::Auto`] — search EHYB knob settings **and** every
///   baseline engine;
/// * [`EngineKind::Ehyb`] — tune the EHYB knobs (`slice_height`,
///   `vec_size` against the shared-memory budget, ELL/ER width cutoff)
///   with the base config as the default plan;
/// * any other concrete kind — nothing to vary, the default plan is
///   returned unchanged (tuning a fixed baseline is the identity).
pub fn tune<S: Scalar>(
    m: &Csr<S>,
    base: &PreprocessConfig,
    requested: EngineKind,
    level: TuneLevel,
) -> crate::Result<TuneOutcome<S>> {
    tune_with_fingerprint(m, base, requested, level, None)
}

/// [`tune`] with an optionally precomputed [`Fingerprint`]: the facade
/// already hashes the matrix for its plan-cache lookup, and the
/// structural hash is a full O(nnz) pass — recomputing it here would
/// double that cost on every cached-capable build. Scores heuristic
/// candidates with the default oracle ([`ScoreOracle::Traffic`]); use
/// [`tune_scored`] to pick explicitly.
pub fn tune_with_fingerprint<S: Scalar>(
    m: &Csr<S>,
    base: &PreprocessConfig,
    requested: EngineKind,
    level: TuneLevel,
    fingerprint: Option<Fingerprint>,
) -> crate::Result<TuneOutcome<S>> {
    search(m, base, requested, level, ScoreOracle::default(), fingerprint, None, true, None)
}

/// [`tune_with_fingerprint`] with an explicit heuristic
/// [`ScoreOracle`] — what the facade's
/// [`crate::api::SpmvContextBuilder::score_oracle`] knob routes to.
pub fn tune_scored<S: Scalar>(
    m: &Csr<S>,
    base: &PreprocessConfig,
    requested: EngineKind,
    level: TuneLevel,
    oracle: ScoreOracle,
    fingerprint: Option<Fingerprint>,
) -> crate::Result<TuneOutcome<S>> {
    search(m, base, requested, level, oracle, fingerprint, None, true, None)
}

/// [`tune_scored`] recording one `tune.candidate(…)` span per scored
/// candidate into `tel` (what `SpmvContext::build` runs under its
/// `tune` span, so the search's per-candidate cost shows up in the
/// build-side span tree).
pub fn tune_scored_traced<S: Scalar>(
    m: &Csr<S>,
    base: &PreprocessConfig,
    requested: EngineKind,
    level: TuneLevel,
    oracle: ScoreOracle,
    fingerprint: Option<Fingerprint>,
    tel: &Telemetry,
) -> crate::Result<TuneOutcome<S>> {
    search(m, base, requested, level, oracle, fingerprint, None, true, Some(tel))
}

/// The full-option search entry point: [`tune_scored_traced`] /
/// [`choose_engine_traced`] plus an optional [`Calibration`] that
/// rescales the traffic oracle's `predicted_secs` with observed
/// per-level costs (fitted from real kernel runs), and an explicit
/// `knob_variants` switch (`false` reproduces [`choose_engine`]'s
/// engine-choice-only search). Roofline scoring and `Measured` probes
/// ignore the calibration — it maps simulated per-level traffic to
/// seconds, which only the traffic oracle produces.
///
/// [`Calibration`]: crate::profile::Calibration
#[allow(clippy::too_many_arguments)]
pub fn tune_calibrated<S: Scalar>(
    m: &Csr<S>,
    base: &PreprocessConfig,
    requested: EngineKind,
    level: TuneLevel,
    oracle: ScoreOracle,
    fingerprint: Option<Fingerprint>,
    calibration: Option<&crate::profile::Calibration>,
    knob_variants: bool,
    tel: Option<&Telemetry>,
) -> crate::Result<TuneOutcome<S>> {
    search(m, base, requested, level, oracle, fingerprint, calibration, knob_variants, tel)
}

/// Engine choice only — what implicit [`EngineKind::Auto`] (no
/// `.tune(..)`) uses: score the base EHYB plan against the baseline
/// bounds without the knob search, so an untouched `Auto` build pays
/// one preprocessing pass exactly like the pre-tuner roofline
/// comparison did. The full knob search stays opt-in via `.tune(..)`.
///
/// When `fingerprint` is `None` the O(nnz) hash is skipped too and the
/// returned plan's `fingerprint` is an `unhashed-…` placeholder — do
/// not persist such a plan (the facade never does).
pub fn choose_engine<S: Scalar>(
    m: &Csr<S>,
    base: &PreprocessConfig,
    level: TuneLevel,
    oracle: ScoreOracle,
    fingerprint: Option<Fingerprint>,
) -> crate::Result<TuneOutcome<S>> {
    search(m, base, EngineKind::Auto, level, oracle, fingerprint, None, false, None)
}

/// [`choose_engine`] with per-candidate `tune.candidate(…)` spans
/// recorded into `tel` (the implicit-`Auto` path of an instrumented
/// build).
pub fn choose_engine_traced<S: Scalar>(
    m: &Csr<S>,
    base: &PreprocessConfig,
    level: TuneLevel,
    oracle: ScoreOracle,
    fingerprint: Option<Fingerprint>,
    tel: &Telemetry,
) -> crate::Result<TuneOutcome<S>> {
    search(m, base, EngineKind::Auto, level, oracle, fingerprint, None, false, Some(tel))
}

#[allow(clippy::too_many_arguments)]
fn search<S: Scalar>(
    m: &Csr<S>,
    base: &PreprocessConfig,
    requested: EngineKind,
    level: TuneLevel,
    oracle: ScoreOracle,
    fingerprint: Option<Fingerprint>,
    calibration: Option<&crate::profile::Calibration>,
    knob_variants: bool,
    tel: Option<&Telemetry>,
) -> crate::Result<TuneOutcome<S>> {
    let t0 = Timer::start();
    let square = m.nrows() == m.ncols() && m.nrows() > 0;
    // The fingerprint's O(nnz) hash is only needed to generate knob
    // variants (row moments) or to key a persisted plan. Without a
    // caller-supplied fingerprint (the facade passes one whenever a
    // store exists), the light engine-choice path AND identity tunes
    // of fixed baseline kinds — which generate no variants — skip the
    // pass and record a placeholder; such plans are never persisted by
    // the facade.
    let generates_variants = knob_variants
        && (requested == EngineKind::Ehyb || (requested == EngineKind::Auto && square));
    let fp = match (fingerprint, generates_variants) {
        (Some(fp), _) => Some(fp),
        (None, true) => Some(Fingerprint::of(m)),
        (None, false) => None,
    };
    let fp_key = fp
        .as_ref()
        .map(|f| f.key())
        .unwrap_or_else(|| format!("unhashed-n{}-nnz{}", m.nrows(), m.nnz()));
    // Target device for heuristic scoring: the traffic oracle replays
    // against this part's L2/shm/sector geometry; under the roofline
    // oracle the bounds are byte ratios and any bandwidth-bound device
    // ranks candidates identically. V100 is the paper's reference part
    // (same convention the pre-tuner `EngineKind::Auto` used).
    let dev = GpuDevice::v100();

    let default_cand = match requested {
        EngineKind::Auto if square => Candidate::ehyb_base(base),
        EngineKind::Auto => Candidate::baseline(EngineKind::CsrScalar, base),
        EngineKind::Ehyb => Candidate::ehyb_base(base),
        concrete => Candidate::baseline(concrete, base),
    };

    let mut cands: Vec<Candidate> = Vec::new();
    match requested {
        EngineKind::Auto => {
            if square && knob_variants {
                // knob_variants implies fp is Some (see above).
                cands.extend(ehyb_variants::<S>(base, fp.as_ref().expect("fingerprint")));
            }
            for k in EngineKind::ALL {
                // Plain dense-width ELL can dwarf the matrix on
                // power-law rows; never build (or even model) it as a
                // candidate there — a measured probe would OOM.
                if k == EngineKind::Ell && crate::api::ell_padding_excessive(m) {
                    continue;
                }
                if k != EngineKind::Ehyb && k != default_cand.engine {
                    cands.push(Candidate::baseline(k, base));
                }
            }
        }
        EngineKind::Ehyb => {
            if knob_variants {
                cands.extend(ehyb_variants::<S>(base, fp.as_ref().expect("fingerprint")));
            }
        }
        _ => {}
    }
    cands.retain(|c| *c != default_cand);

    // The default plan is always scored — even under a zero budget —
    // so the tuner can never return something it didn't compare
    // against. An error here (e.g. explicit EHYB on a non-square
    // matrix) propagates, matching the untuned builder — except under
    // `Auto`, where an infeasible EHYB default (partition failure, bad
    // override) falls back to the CSR-scalar baseline, matching the
    // pre-tuner `Auto` behaviour.
    let mut best = {
        let _span =
            tel.map(|t| t.span(format!("tune.candidate(i=0,{:?})", default_cand.engine)));
        match score_candidate::<S>(m, base, &default_cand, level, oracle, &dev, calibration) {
            Ok(s) => s,
            Err(_) if requested == EngineKind::Auto && default_cand.engine == EngineKind::Ehyb => {
                cands.retain(|c| c.engine != EngineKind::Ehyb);
                let fallback = Candidate::baseline(EngineKind::CsrScalar, base);
                cands.retain(|c| *c != fallback);
                score_candidate::<S>(m, base, &fallback, level, oracle, &dev, calibration)?
            }
            Err(e) => return Err(e),
        }
    };
    let default_score = best.score;
    let mut tried = 1usize;
    let mut skipped = 0usize;
    let mut budget_skipped = 0usize;
    let budget = match level {
        TuneLevel::Measured { budget } => Some(budget),
        TuneLevel::Heuristic => None,
    };
    for (i, c) in cands.iter().enumerate() {
        if let Some(b) = budget {
            if t0.elapsed() >= b {
                skipped += 1;
                budget_skipped += 1;
                continue;
            }
        }
        let _span = tel.map(|t| t.span(format!("tune.candidate(i={},{:?})", i + 1, c.engine)));
        match score_candidate::<S>(m, base, c, level, oracle, &dev, calibration) {
            Ok(s) => {
                tried += 1;
                if s.score < best.score {
                    best = s;
                }
            }
            // Infeasible candidate (partition failure, bad override):
            // not an error for the search, just not a contender.
            Err(_) => skipped += 1,
        }
    }
    debug_assert!(best.score <= default_score, "tuned {} > default {}", best.score, default_score);

    Ok(TuneOutcome {
        plan: TunedPlan {
            engine: best.cand.engine,
            slice_height: best.cand.slice_height,
            vec_size: best.cand.vec_size,
            ell_width_cutoff: best.cand.cutoff,
            score_secs: best.score,
            default_score_secs: default_score,
            level: level.tag().to_string(),
            fingerprint: fp_key,
            device: super::device_key(&base.device),
            dtype: S::NAME.to_string(),
            base_config: super::config_key(base),
            scope: requested.name().to_string(),
            reorder: "none".to_string(),
            oracle: oracle.tag().to_string(),
            probe_width: best.width,
            drift: None,
        },
        ehyb: best.ehyb,
        candidates_tried: tried,
        candidates_skipped: skipped,
        budget_skipped,
        search_secs: t0.elapsed_secs(),
    })
}

/// EHYB knob variants around the base config: `vec_size` halvings and
/// doubling against the shared-memory budget, a halved slice height,
/// and ELL/ER width cutoffs placed from the row-length moments.
fn ehyb_variants<S: Scalar>(base: &PreprocessConfig, fp: &Fingerprint) -> Vec<Candidate> {
    let h = base.slice_height;
    let v0 = base
        .vec_size_override
        .unwrap_or_else(|| cache_plan::<S>(fp.nrows, h, &base.device).vec_size);
    let shm_rows = (base.device.shm_bytes / S::BYTES).max(h);
    let clamp = |v: usize, h: usize| -> Option<usize> {
        let mut v = (v / h).max(1) * h;
        v = v.min(1 << 16);
        while v > shm_rows && v > h {
            v -= h;
        }
        Some(v)
    };

    let mut out: Vec<Candidate> = Vec::new();
    let mut push = |c: Candidate| {
        if !out.contains(&c) {
            out.push(c);
        }
    };

    // Cache-size sweep: fewer/more partitions against the scratchpad
    // budget (the Akbudak et al. motivation: measured/ modeled cache
    // behaviour, not a constant, picks the partition size).
    for v in [v0 / 2, v0 * 2, v0 / 4] {
        if let Some(v) = clamp(v, h) {
            if v != v0 {
                push(Candidate {
                    engine: EngineKind::Ehyb,
                    slice_height: h,
                    vec_size: Some(v),
                    cutoff: base.ell_width_cutoff,
                });
            }
        }
    }
    // Halved slice height: shorter slices pad less on skewed rows.
    // v0 is a multiple of h, hence of h/2.
    if h >= 16 && h % 2 == 0 {
        push(Candidate {
            engine: EngineKind::Ehyb,
            slice_height: h / 2,
            vec_size: Some(v0),
            cutoff: base.ell_width_cutoff,
        });
    }
    // ELL/ER width cutoffs from the row histogram: clamp heavy rows a
    // little above the mean, and above the mean + 2σ tail.
    for c in [
        fp.row_mean.ceil() as u32 + 1,
        (fp.row_mean + 2.0 * fp.row_stddev).ceil() as u32 + 1,
    ] {
        if c >= 1 && (c as f64) < fp.row_max {
            push(Candidate {
                engine: EngineKind::Ehyb,
                slice_height: h,
                vec_size: base.vec_size_override,
                cutoff: Some(c),
            });
        }
    }
    out
}

#[allow(clippy::too_many_arguments)]
fn score_candidate<S: Scalar>(
    m: &Csr<S>,
    base: &PreprocessConfig,
    cand: &Candidate,
    level: TuneLevel,
    oracle: ScoreOracle,
    dev: &GpuDevice,
    cal: Option<&crate::profile::Calibration>,
) -> crate::Result<Scored<S>> {
    // With a calibration in hand, the traffic oracle's per-level byte
    // counts are priced at the *observed* secs-per-byte instead of the
    // device model's nominal bandwidths; rankings follow what the host
    // actually measured. Roofline and Measured scoring are unaffected.
    let priced = |r: crate::traffic::TrafficReport| match cal {
        Some(c) => c.apply(&r),
        None => r.predicted_secs,
    };
    if cand.engine == EngineKind::Ehyb {
        let cfg = cand.config(base);
        let plan = EhybPlan::build(m, &cfg)?;
        let (score, width) = match level {
            TuneLevel::Heuristic => match oracle {
                ScoreOracle::Traffic => {
                    (priced(crate::traffic::ehyb_traffic(&plan.matrix, dev)), 0)
                }
                ScoreOracle::Roofline => {
                    (perfmodel::ehyb_bound(&plan.matrix).predicted_secs(dev), 0)
                }
            },
            TuneLevel::Measured { .. } => {
                let engine = crate::api::build_engine(EngineKind::Ehyb, m, Some(&plan));
                measure_spmv(engine.as_ref(), m)
            }
        };
        Ok(Scored { cand: cand.clone(), score, ehyb: Some(plan), width })
    } else {
        let (score, width) = match level {
            TuneLevel::Heuristic => match oracle {
                ScoreOracle::Traffic => {
                    (priced(crate::traffic::baseline_traffic(cand.engine, m, dev)), 0)
                }
                ScoreOracle::Roofline => (baseline_predicted_secs(cand.engine, m, dev), 0),
            },
            TuneLevel::Measured { .. } => {
                let engine = crate::api::build_engine(cand.engine, m, None);
                measure_spmv(engine.as_ref(), m)
            }
        };
        Ok(Scored { cand: cand.clone(), score, ehyb: None, width })
    }
}

/// Roofline-predicted seconds per SpMV for a baseline kind: ELL-family
/// formats pay their fill ratio — dense-width for plain ELL, per-slice
/// for SELL-P (one heavy row inflates its own 32-row slice, not the
/// whole matrix) — everything else gets the CSR-family bound (HYB
/// splits precisely to avoid ELL padding).
fn baseline_predicted_secs<S: Scalar>(kind: EngineKind, m: &Csr<S>, dev: &GpuDevice) -> f64 {
    let nnz = m.nnz();
    match kind {
        EngineKind::Ell => {
            let fill =
                if nnz == 0 { 1.0 } else { (m.max_row_nnz() * m.nrows()) as f64 / nnz as f64 };
            perfmodel::ell_bound(m, fill.max(1.0)).predicted_secs(dev)
        }
        EngineKind::SellP => perfmodel::ell_bound(m, sellp_fill(m, 32)).predicted_secs(dev),
        _ => perfmodel::csr_bound(m).predicted_secs(dev),
    }
}

/// SELL-P fill ratio at slice height `h`: stored slots (each slice of
/// `h` rows padded to its own max width) over logical nnz.
fn sellp_fill<S: Scalar>(m: &Csr<S>, h: usize) -> f64 {
    let nnz = m.nnz();
    if nnz == 0 {
        return 1.0;
    }
    let n = m.nrows();
    let mut slots = 0usize;
    let mut s = 0;
    while s < n {
        let end = (s + h).min(n);
        let maxw = (s..end).map(|i| m.row_nnz(i)).max().unwrap_or(0);
        slots += (end - s) * maxw;
        s = end;
    }
    (slots as f64 / nnz as f64).max(1.0)
}

/// Batch widths a `Measured` probe sweeps: the single-vector path plus
/// the blocked-SpMM widths service workloads actually run at.
const PROBE_WIDTHS: [usize; 3] = [1, 4, 8];

/// Deterministic-input microbench probe: best per-vector seconds across
/// the [`PROBE_WIDTHS`] `spmv_batch` sweep (`t_batch / B` — SpMV is
/// memory-bound, so a wider block amortizes the matrix stream). Returns
/// `(secs_per_vector, winning_width)`.
fn measure_spmv<S: Scalar>(engine: &dyn SpmvEngine<S>, m: &Csr<S>) -> (f64, u32) {
    let xval = |i: usize, b: usize| S::from_f64(((i * 13 + b * 7 + 7) % 17) as f64 * 0.25 - 2.0);
    let x: Vec<S> = (0..m.ncols()).map(|i| xval(i, 0)).collect();
    let mut y = vec![S::ZERO; m.nrows()];
    let mut best = (bench_secs(|| engine.spmv(&x, &mut y), 3, Duration::from_millis(2)), 1u32);
    for &bw in PROBE_WIDTHS.iter().filter(|&&bw| bw > 1) {
        let mut xs = crate::api::BatchBuf::<S>::zeros(m.ncols(), bw);
        for b in 0..bw {
            for i in 0..m.ncols() {
                xs.col_mut(b)[i] = xval(i, b);
            }
        }
        let mut ys = crate::api::BatchBuf::<S>::zeros(m.nrows(), bw);
        let secs = bench_secs(
            || {
                let mut ysv = ys.view_mut();
                engine.spmv_batch(xs.view(), &mut ysv)
            },
            3,
            Duration::from_millis(2),
        );
        let per_vec = secs / bw as f64;
        if per_vec < best.0 {
            best = (per_vec, bw as u32);
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::gen::{circuit, poisson2d, unstructured_mesh};

    fn cfg(v: usize) -> PreprocessConfig {
        PreprocessConfig { vec_size_override: Some(v), ..Default::default() }
    }

    #[test]
    fn heuristic_never_worse_than_default() {
        for (name, m) in [
            ("poisson", poisson2d::<f64>(24, 24)),
            ("mesh", unstructured_mesh::<f64>(32, 32, 0.4, 5)),
            ("circuit", circuit::<f64>(700, 4, 0.03, 9)),
        ] {
            for requested in [EngineKind::Ehyb, EngineKind::Auto] {
                let out = tune(&m, &cfg(128), requested, TuneLevel::Heuristic).unwrap();
                assert!(
                    out.plan.score_secs <= out.plan.default_score_secs,
                    "{name}/{requested:?}: {} > {}",
                    out.plan.score_secs,
                    out.plan.default_score_secs
                );
                assert!(out.candidates_tried >= 1);
                assert_ne!(out.plan.engine, EngineKind::Auto);
            }
        }
    }

    #[test]
    fn measured_never_worse_than_default() {
        let m = unstructured_mesh::<f64>(24, 24, 0.4, 7);
        let out = tune(&m, &cfg(64), EngineKind::Ehyb, TuneLevel::measured()).unwrap();
        assert!(out.plan.score_secs <= out.plan.default_score_secs);
        assert_eq!(out.plan.level, "measured");
        assert!(out.ehyb.is_some());
        // Satellite (ISSUE 7): the batch-width sweep ran and recorded
        // which width won.
        assert!(
            PROBE_WIDTHS.contains(&(out.plan.probe_width as usize)),
            "probe_width {} not in {PROBE_WIDTHS:?}",
            out.plan.probe_width
        );
    }

    #[test]
    fn heuristic_oracles_both_never_worse_and_stamp_provenance() {
        let m = unstructured_mesh::<f64>(32, 32, 0.4, 5);
        for oracle in [ScoreOracle::Traffic, ScoreOracle::Roofline] {
            let out = tune_scored(
                &m,
                &cfg(128),
                EngineKind::Auto,
                TuneLevel::Heuristic,
                oracle,
                None,
            )
            .unwrap();
            assert!(out.plan.score_secs <= out.plan.default_score_secs, "{oracle:?}");
            assert_eq!(out.plan.oracle, oracle.tag());
            assert_eq!(out.plan.probe_width, 0, "heuristic probes nothing");
        }
    }

    #[test]
    fn traffic_oracle_is_the_default_and_deterministic() {
        let m = poisson2d::<f64>(24, 24);
        let a = tune(&m, &cfg(128), EngineKind::Ehyb, TuneLevel::Heuristic).unwrap();
        assert_eq!(a.plan.oracle, "traffic");
        let b = tune(&m, &cfg(128), EngineKind::Ehyb, TuneLevel::Heuristic).unwrap();
        // The replayed simulation is deterministic: identical scores,
        // identical winner, bit for bit.
        assert_eq!(a.plan, b.plan);
    }

    #[test]
    fn zero_budget_probes_only_the_default() {
        let m = unstructured_mesh::<f64>(24, 24, 0.4, 7);
        let out = tune(
            &m,
            &cfg(64),
            EngineKind::Ehyb,
            TuneLevel::Measured { budget: Duration::ZERO },
        )
        .unwrap();
        // Budget respected: the default is the only scored candidate,
        // everything else was shed on budget.
        assert_eq!(out.candidates_tried, 1);
        assert!(out.candidates_skipped > 0, "no candidates existed to skip");
        assert_eq!(out.budget_skipped, out.candidates_skipped);
        assert!(!out.searched(), "a budget-starved run must not present as a search");
        assert_eq!(out.plan.score_secs, out.plan.default_score_secs);
        // The winner under a zero budget IS the default plan.
        assert_eq!(out.plan.engine, EngineKind::Ehyb);
        assert_eq!(out.plan.vec_size, Some(64));
    }

    #[test]
    fn generous_budget_probes_more_candidates() {
        let m = poisson2d::<f64>(16, 16);
        let out = tune(
            &m,
            &cfg(64),
            EngineKind::Ehyb,
            TuneLevel::Measured { budget: Duration::from_secs(30) },
        )
        .unwrap();
        assert!(out.candidates_tried > 1, "tried {}", out.candidates_tried);
    }

    #[test]
    fn concrete_baseline_kind_is_identity() {
        let m = poisson2d::<f64>(16, 16);
        let out = tune(&m, &cfg(64), EngineKind::Merge, TuneLevel::Heuristic).unwrap();
        assert_eq!(out.plan.engine, EngineKind::Merge);
        assert_eq!(out.candidates_tried, 1);
        assert_eq!(out.plan.score_secs, out.plan.default_score_secs);
    }

    #[test]
    fn auto_on_non_square_never_picks_ehyb() {
        use crate::sparse::coo::Coo;
        let mut coo = Coo::<f64>::new(4, 6);
        for i in 0..4 {
            coo.push(i, i, 1.0);
        }
        let cfg = PreprocessConfig::default();
        let out = tune(&coo.to_csr(), &cfg, EngineKind::Auto, TuneLevel::Heuristic).unwrap();
        assert_ne!(out.plan.engine, EngineKind::Ehyb);
        assert!(out.ehyb.is_none());
    }

    #[test]
    fn sellp_fill_not_punished_by_one_hub_row() {
        use crate::sparse::coo::Coo;
        let n = 320;
        let mut coo = Coo::<f64>::new(n, n);
        for i in 0..n {
            coo.push(i, i, 1.0);
        }
        for j in 1..200 {
            coo.push(0, j, 0.5);
        }
        let m = coo.to_csr();
        let dense_fill = (m.max_row_nnz() * m.nrows()) as f64 / m.nnz() as f64;
        let sliced = sellp_fill(&m, 32);
        // The hub row inflates only its own slice, not the whole format.
        assert!(sliced >= 1.0);
        assert!(sliced < dense_fill / 5.0, "sliced {sliced} vs dense {dense_fill}");
        // And the heuristic ranks SELL-P strictly ahead of plain ELL here.
        let dev = GpuDevice::v100();
        assert!(
            baseline_predicted_secs(EngineKind::SellP, &m, &dev)
                < baseline_predicted_secs(EngineKind::Ell, &m, &dev)
        );
    }

    #[test]
    fn traced_tune_records_one_span_per_scored_candidate() {
        let m = poisson2d::<f64>(16, 16);
        let tel = Telemetry::with_fake_clock();
        let out = tune_scored_traced(
            &m,
            &cfg(64),
            EngineKind::Ehyb,
            TuneLevel::Heuristic,
            ScoreOracle::default(),
            None,
            &tel,
        )
        .unwrap();
        let snap = tel.snapshot();
        let cand_spans: Vec<_> =
            snap.spans.iter().filter(|s| s.name.starts_with("tune.candidate(")).collect();
        // Every scored candidate left a span (skipped ones may appear
        // too — a span opens before scoring can fail), starting with
        // the always-scored default at i=0.
        assert!(cand_spans.len() >= out.candidates_tried);
        assert!(cand_spans.iter().any(|s| s.name.starts_with("tune.candidate(i=0,")));
        for s in &cand_spans {
            assert!(s.end_nanos > s.start_nanos);
        }
        // The untraced entry point records nothing.
        let tel2 = Telemetry::with_fake_clock();
        tune(&m, &cfg(64), EngineKind::Ehyb, TuneLevel::Heuristic).unwrap();
        assert!(tel2.snapshot().spans.is_empty());
    }

    #[test]
    fn choose_engine_scores_only_the_base_ehyb_plan() {
        let m = poisson2d::<f64>(16, 16);
        let out =
            choose_engine(&m, &cfg(64), TuneLevel::Heuristic, ScoreOracle::default(), None)
                .unwrap();
        assert_ne!(out.plan.engine, EngineKind::Auto);
        // No knob variants: an EHYB winner is the base plan itself.
        if out.plan.engine == EngineKind::Ehyb {
            assert_eq!(out.plan.vec_size, Some(64));
            assert_eq!(out.plan.slice_height, 32);
            assert_eq!(out.plan.ell_width_cutoff, None);
        }
        // Only the default and the baselines can have been scored.
        assert!(out.candidates_tried <= EngineKind::ALL.len());
    }

    #[test]
    fn auto_with_infeasible_ehyb_falls_back_to_baseline() {
        // vec_size 48 is not a multiple of slice_height 32, so every
        // EHYB build fails; Auto must still tune (pre-tuner `Auto`
        // silently fell back too), explicit Ehyb must error.
        let m = poisson2d::<f64>(16, 16);
        let bad = cfg(48);
        let out = tune(&m, &bad, EngineKind::Auto, TuneLevel::Heuristic).unwrap();
        assert_ne!(out.plan.engine, EngineKind::Ehyb);
        assert!(out.ehyb.is_none());
        assert!(tune(&m, &bad, EngineKind::Ehyb, TuneLevel::Heuristic).is_err());
    }

    fn sample_plan() -> TunedPlan {
        TunedPlan {
            engine: EngineKind::Ehyb,
            slice_height: 32,
            vec_size: Some(96),
            ell_width_cutoff: Some(5),
            score_secs: 1.25e-4,
            default_score_secs: 2.5e-4,
            level: "heuristic".into(),
            fingerprint: "abc-n100-nnz500".into(),
            device: "p80-shm98304".into(),
            dtype: "f64".into(),
            base_config: "sd1-Multilevel-r4-c8-s9e3779b9".into(),
            scope: "ehyb".into(),
            reorder: "none".into(),
            oracle: "roofline".into(),
            probe_width: 0,
            drift: None,
        }
    }

    #[test]
    fn tuned_plan_json_roundtrip() {
        let plan = sample_plan();
        let back = TunedPlan::from_json(&Json::parse(&plan.to_json().dump()).unwrap()).unwrap();
        assert_eq!(back, plan);
        // None fields round-trip through JSON null.
        let plan2 = TunedPlan { vec_size: None, ell_width_cutoff: None, ..plan };
        let back2 = TunedPlan::from_json(&Json::parse(&plan2.to_json().dump()).unwrap()).unwrap();
        assert_eq!(back2, plan2);
        // A stamped reorder tag survives the round trip.
        let plan3 = TunedPlan { reorder: "rcm".into(), ..sample_plan() };
        let back3 = TunedPlan::from_json(&Json::parse(&plan3.to_json().dump()).unwrap()).unwrap();
        assert_eq!(back3.reorder, "rcm");
    }

    #[test]
    fn pre_reorder_entries_load_as_none() {
        // 0.4-era cache entries have no "reorder" field; they must load
        // (as "none"), not rot into parse errors.
        let mut j = sample_plan().to_json();
        if let Json::Obj(m) = &mut j {
            m.remove("reorder");
        }
        let back = TunedPlan::from_json(&j).unwrap();
        assert_eq!(back.reorder, "none");
        // But a present non-string value is a parse error.
        if let Json::Obj(m) = &mut j {
            m.insert("reorder".into(), Json::Num(3.0));
        }
        assert!(TunedPlan::from_json(&j).is_err());
    }

    #[test]
    fn pre_traffic_entries_load_as_roofline() {
        // 0.6-era cache entries carry neither "oracle" nor
        // "probe_width": a heuristic entry was roofline-scored, a
        // measured one only ever probed B = 1.
        let mut j = sample_plan().to_json();
        if let Json::Obj(m) = &mut j {
            m.remove("oracle");
            m.remove("probe_width");
        }
        let back = TunedPlan::from_json(&j).unwrap();
        assert_eq!(back.oracle, "roofline");
        assert_eq!(back.probe_width, 0, "heuristic entries probed nothing");
        let mut jm = TunedPlan { level: "measured".into(), ..sample_plan() }.to_json();
        if let Json::Obj(m) = &mut jm {
            m.remove("oracle");
            m.remove("probe_width");
        }
        let backm = TunedPlan::from_json(&jm).unwrap();
        assert_eq!(backm.probe_width, 1, "pre-0.7 measured entries probed only B=1");
        // Unknown oracle values are rejected like unknown levels.
        let mut jb = sample_plan().to_json();
        if let Json::Obj(m) = &mut jb {
            m.insert("oracle".into(), Json::Str("crystal-ball".into()));
        }
        assert!(TunedPlan::from_json(&jb).is_err());
    }

    #[test]
    fn pre_drift_entries_load_as_none_and_drift_round_trips() {
        // 0.9-era cache entries have no "drift" field: no drift check
        // ever ran against them, which is exactly what None records.
        let mut j = sample_plan().to_json();
        if let Json::Obj(m) = &mut j {
            m.remove("drift");
        }
        let back = TunedPlan::from_json(&j).unwrap();
        assert_eq!(back.drift, None);
        assert!(back.drift_ok(0.0), "no recorded drift can never be stale");
        // A stamped drift survives the round trip and gates drift_ok.
        let stamped = TunedPlan { drift: Some(0.21), ..sample_plan() };
        let back =
            TunedPlan::from_json(&Json::parse(&stamped.to_json().dump()).unwrap()).unwrap();
        assert_eq!(back.drift, Some(0.21));
        assert!(back.drift_ok(0.25) && !back.drift_ok(0.15));
        // Out-of-range drifts are rejected like any corrupted field.
        for bad in ["-0.5", "\"lots\""] {
            let mut j = sample_plan().to_json();
            if let Json::Obj(m) = &mut j {
                m.insert("drift".into(), Json::parse(bad).unwrap());
            }
            assert!(TunedPlan::from_json(&j).is_err(), "drift {bad} accepted");
        }
    }

    #[test]
    fn calibrated_search_stays_deterministic_and_never_worse() {
        use crate::profile::Calibration;
        let m = unstructured_mesh::<f64>(32, 32, 0.4, 5);
        // An uncalibrated-equivalent calibration (the device model's
        // own secs-per-byte) must not change the traffic oracle's
        // ranking; a skewed one still upholds the ≤-default guarantee.
        let dev = GpuDevice::v100();
        let neutral = Calibration::uncalibrated(&dev);
        let skewed =
            Calibration { dram_secs_per_byte: neutral.dram_secs_per_byte * 3.0, ..neutral.clone() };
        for cal in [None, Some(&neutral), Some(&skewed)] {
            let a = tune_calibrated(
                &m,
                &cfg(128),
                EngineKind::Auto,
                TuneLevel::Heuristic,
                ScoreOracle::Traffic,
                None,
                cal,
                true,
                None,
            )
            .unwrap();
            let b = tune_calibrated(
                &m,
                &cfg(128),
                EngineKind::Auto,
                TuneLevel::Heuristic,
                ScoreOracle::Traffic,
                None,
                cal,
                true,
                None,
            )
            .unwrap();
            assert_eq!(a.plan, b.plan, "calibrated scoring must stay deterministic");
            assert!(a.plan.score_secs <= a.plan.default_score_secs);
            assert_eq!(a.plan.drift, None, "a fresh search carries no observed drift");
        }
    }

    #[test]
    fn malformed_plan_json_is_a_parse_error() {
        let j = Json::parse(r#"{"engine": "warp-drive"}"#).unwrap();
        assert!(matches!(
            TunedPlan::from_json(&j),
            Err(crate::EhybError::Parse(_))
        ));
    }

    #[test]
    fn out_of_range_plan_json_is_an_error_not_a_panic() {
        // slice_height 0 (or an incompatible vec_size) in a corrupted
        // cache entry must be rejected at parse time — adopting it
        // would divide by zero inside EhybPlan::build on every warm
        // start.
        for (k, v) in [("slice_height", "0"), ("vec_size", "48"), ("ell_width_cutoff", "0")] {
            let mut j = sample_plan().to_json();
            if let Json::Obj(m) = &mut j {
                m.insert(k.to_string(), Json::parse(v).unwrap());
            }
            assert!(TunedPlan::from_json(&j).is_err(), "field {k}={v} accepted");
        }
        let mut j = sample_plan().to_json();
        if let Json::Obj(m) = &mut j {
            m.insert("level".into(), Json::Str("vibes".into()));
        }
        assert!(TunedPlan::from_json(&j).is_err());
        // Baseline winners carry base-config values verbatim, which may
        // be EHYB-infeasible (e.g. the Auto fallback after an
        // infeasible override) — they must still load.
        let baseline = TunedPlan {
            engine: EngineKind::CsrScalar,
            vec_size: Some(48), // not a multiple of slice_height 32
            scope: "auto".into(),
            ..sample_plan()
        };
        let back = TunedPlan::from_json(&Json::parse(&baseline.to_json().dump()).unwrap()).unwrap();
        assert_eq!(back, baseline);
    }

    #[test]
    fn usable_for_honors_kind_level_oracle_and_config() {
        let rl = ScoreOracle::Roofline;
        let tr = ScoreOracle::Traffic;
        let heuristic = sample_plan(); // oracle: "roofline"
        let key = heuristic.base_config.clone();
        // Kind: explicit requests are never overridden; Auto takes any.
        assert!(heuristic.usable_for(EngineKind::Ehyb, TuneLevel::Heuristic, rl, &key));
        assert!(heuristic.usable_for(EngineKind::Auto, TuneLevel::Heuristic, rl, &key));
        let baseline = TunedPlan { engine: EngineKind::CsrScalar, ..sample_plan() };
        assert!(!baseline.usable_for(EngineKind::Ehyb, TuneLevel::Heuristic, rl, &key));
        assert!(baseline.usable_for(EngineKind::Auto, TuneLevel::Heuristic, rl, &key));
        // Level: measured supersedes heuristic, never the reverse.
        assert!(!heuristic.usable_for(EngineKind::Ehyb, TuneLevel::measured(), rl, &key));
        let measured = TunedPlan { level: "measured".into(), ..sample_plan() };
        assert!(measured.usable_for(EngineKind::Ehyb, TuneLevel::Heuristic, rl, &key));
        assert!(measured.usable_for(EngineKind::Ehyb, TuneLevel::measured(), rl, &key));
        // Oracle: a roofline-scored heuristic entry must not serve a
        // traffic-oracle heuristic request (and vice versa) — the
        // scores are different models' predictions. Measured entries
        // supersede either oracle.
        assert!(!heuristic.usable_for(EngineKind::Ehyb, TuneLevel::Heuristic, tr, &key));
        let traffic_plan = TunedPlan { oracle: "traffic".into(), ..sample_plan() };
        assert!(traffic_plan.usable_for(EngineKind::Ehyb, TuneLevel::Heuristic, tr, &key));
        assert!(!traffic_plan.usable_for(EngineKind::Ehyb, TuneLevel::Heuristic, rl, &key));
        assert!(measured.usable_for(EngineKind::Ehyb, TuneLevel::Heuristic, tr, &key));
        // Base config must match exactly.
        assert!(!heuristic.usable_for(EngineKind::Ehyb, TuneLevel::Heuristic, rl, "sd0-other"));
    }

    #[test]
    fn ehyb_variants_are_feasible_and_distinct() {
        let m = unstructured_mesh::<f64>(32, 32, 0.4, 5);
        let fp = Fingerprint::of(&m);
        let base = cfg(128);
        let variants = ehyb_variants::<f64>(&base, &fp);
        assert!(!variants.is_empty());
        for (i, c) in variants.iter().enumerate() {
            assert_eq!(c.engine, EngineKind::Ehyb);
            // Every variant must build.
            EhybPlan::build(&m, &c.config(&base))
                .unwrap_or_else(|e| panic!("variant {c:?} infeasible: {e}"));
            assert!(!variants[..i].contains(c), "duplicate candidate {c:?}");
        }
    }
}
