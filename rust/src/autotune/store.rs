//! The persistent plan store: one JSON file per (fingerprint × device
//! × scalar type × search scope) under a cache directory, written
//! atomically
//! (temp-file + rename) so concurrent tuners and readers never observe
//! a torn plan. A restarted server pointing at the same directory
//! warm-starts with zero search — the OSKI "offline tuning, online
//! reuse" amortization.
//!
//! Directory resolution convention (what the facade uses):
//! `SpmvContextBuilder::plan_cache(dir)` explicitly, else the
//! `EHYB_TUNE_DIR` environment variable, else no persistence.
//!
//! The store is deliberately dumb: it persists and retrieves
//! [`TunedPlan`]s by key and verifies the entry's self-described
//! identity. Whether a retrieved plan actually *fits* a given build
//! (engine kind, tune level, base config) is the facade's decision via
//! [`TunedPlan::usable_for`].
//!
//! A *damaged* entry — torn JSON, out-of-range knobs, a mislabeled key
//! — is **quarantined** on load: atomically renamed to `<name>.bad`
//! (preserved for postmortem) and counted in
//! [`PlanStore::quarantines`], so the key reads as a cold miss from
//! then on and the next successful tune re-occupies it. Plain I/O read
//! errors are *not* quarantined: an unreadable disk says nothing about
//! the entry itself.

use super::tuner::TunedPlan;
use crate::runtime::json::Json;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// Per-process sequence for temp-file names: two threads saving the
/// same key concurrently must not share a temp file, or one could
/// rename the other's half-written JSON into place.
static TMP_SEQ: AtomicU64 = AtomicU64::new(0);

/// Environment variable naming the default plan-cache directory.
pub const ENV_DIR: &str = "EHYB_TUNE_DIR";

/// A plan-cache directory handle. Clones share the quarantine counter.
#[derive(Clone, Debug)]
pub struct PlanStore {
    dir: PathBuf,
    quarantined: Arc<AtomicU64>,
}

impl PlanStore {
    pub fn new(dir: impl Into<PathBuf>) -> Self {
        Self { dir: dir.into(), quarantined: Arc::new(AtomicU64::new(0)) }
    }

    /// Damaged entries this handle (and its clones) moved aside.
    pub fn quarantines(&self) -> u64 {
        self.quarantined.load(Ordering::Relaxed)
    }

    /// Move a damaged entry to `<name>.bad` — atomic within the
    /// directory, best-effort (a failed quarantine must not escalate a
    /// cache miss into anything worse). Counted only when the rename
    /// actually happened.
    fn quarantine(&self, path: &Path) {
        let mut bad = path.as_os_str().to_owned();
        bad.push(".bad");
        if std::fs::rename(path, &bad).is_ok() {
            self.quarantined.fetch_add(1, Ordering::Relaxed);
        }
    }

    /// Store at the `EHYB_TUNE_DIR` directory, if the variable is set
    /// and non-empty.
    pub fn from_env() -> Option<Self> {
        std::env::var(ENV_DIR).ok().filter(|v| !v.is_empty()).map(Self::new)
    }

    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Cache file for one (fingerprint, device, dtype, scope) key.
    /// `scope` is the search scope that owns the entry
    /// ([`crate::api::EngineKind::name`] of the requested kind), so an
    /// `Auto` winner and an EHYB-only winner coexist instead of
    /// clobbering each other.
    pub fn path_for(&self, fingerprint: &str, device: &str, dtype: &str, scope: &str) -> PathBuf {
        self.dir.join(format!("{fingerprint}-{device}-{dtype}-{scope}.json"))
    }

    /// Load the cached plan for a key. `Ok(None)` = no entry (cold
    /// cache); `Err` = an entry exists but cannot be used — callers
    /// that prefer to re-tune on a damaged cache can treat `Err` as a
    /// miss. A malformed or mislabeled entry is additionally
    /// [quarantined](Self::quarantines) to `<name>.bad`, so only the
    /// first reader pays for the damage; an I/O read error is returned
    /// as-is (the entry may be fine).
    pub fn load(
        &self,
        fingerprint: &str,
        device: &str,
        dtype: &str,
        scope: &str,
    ) -> crate::Result<Option<TunedPlan>> {
        let path = self.path_for(fingerprint, device, dtype, scope);
        let text = match std::fs::read_to_string(&path) {
            Ok(t) => t,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(None),
            Err(e) => return Err(crate::EhybError::Io(format!("{}: {e}", path.display()))),
        };
        let plan = match Json::parse(&text).and_then(|j| TunedPlan::from_json(&j)) {
            Ok(plan) => plan,
            Err(e) => {
                self.quarantine(&path);
                return Err(e);
            }
        };
        if !(plan.fingerprint == fingerprint
            && plan.device == device
            && plan.dtype == dtype
            && plan.scope == scope)
        {
            self.quarantine(&path);
            return Err(crate::EhybError::Parse(format!(
                "plan cache entry {} is keyed for ({}, {}, {}, {})",
                path.display(),
                plan.fingerprint,
                plan.device,
                plan.dtype,
                plan.scope
            )));
        }
        Ok(Some(plan))
    }

    /// Persist `plan` under its own key. Atomic: the JSON is written to
    /// a temp file unique per process *and* per save (so concurrent
    /// in-process tuners never share one) in the same directory and
    /// renamed into place — readers see either the old entry or the
    /// new one, never a partial write.
    pub fn save(&self, plan: &TunedPlan) -> crate::Result<PathBuf> {
        let path = self.path_for(&plan.fingerprint, &plan.device, &plan.dtype, &plan.scope);
        self.write_atomic(&path, &plan.to_json().dump())?;
        Ok(path)
    }

    /// Cache file for the host calibration of one (device, dtype) key.
    /// Calibrations are host-wide — per-level secs/byte of the machine
    /// running the kernels — not per matrix, so the fingerprint and
    /// scope play no part in the key.
    pub fn calibration_path(&self, device: &str, dtype: &str) -> PathBuf {
        self.dir.join(format!("calibration-{device}-{dtype}.json"))
    }

    /// Persist a fitted [`Calibration`] with the same atomic protocol
    /// as [`Self::save`].
    ///
    /// [`Calibration`]: crate::profile::Calibration
    pub fn save_calibration(
        &self,
        cal: &crate::profile::Calibration,
        device: &str,
        dtype: &str,
    ) -> crate::Result<PathBuf> {
        let path = self.calibration_path(device, dtype);
        self.write_atomic(&path, &cal.to_json().dump())?;
        Ok(path)
    }

    /// Load the persisted calibration for a key, with the same
    /// miss/damage discipline as [`Self::load`]: `Ok(None)` = no entry,
    /// a malformed entry is quarantined to `<name>.bad` and returned as
    /// `Err`, an I/O read error is returned as-is.
    pub fn load_calibration(
        &self,
        device: &str,
        dtype: &str,
    ) -> crate::Result<Option<crate::profile::Calibration>> {
        let path = self.calibration_path(device, dtype);
        let text = match std::fs::read_to_string(&path) {
            Ok(t) => t,
            Err(e) if e.kind() == std::io::ErrorKind::NotFound => return Ok(None),
            Err(e) => return Err(crate::EhybError::Io(format!("{}: {e}", path.display()))),
        };
        match Json::parse(&text).and_then(|j| crate::profile::Calibration::from_json(&j)) {
            Ok(cal) => Ok(Some(cal)),
            Err(e) => {
                self.quarantine(&path);
                Err(e)
            }
        }
    }

    /// The shared temp-file + rename write both entry kinds use.
    fn write_atomic(&self, path: &Path, text: &str) -> crate::Result<()> {
        std::fs::create_dir_all(&self.dir)?;
        let tmp = self.dir.join(format!(
            ".{}-{}-{}.tmp",
            path.file_name().and_then(|n| n.to_str()).unwrap_or("plan"),
            std::process::id(),
            TMP_SEQ.fetch_add(1, Ordering::Relaxed)
        ));
        std::fs::write(&tmp, text)
            .map_err(|e| crate::EhybError::Io(format!("{}: {e}", tmp.display())))?;
        std::fs::rename(&tmp, path)
            .map_err(|e| crate::EhybError::Io(format!("{}: {e}", path.display())))?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::EngineKind;

    fn plan() -> TunedPlan {
        TunedPlan {
            engine: EngineKind::Ehyb,
            slice_height: 32,
            vec_size: Some(128),
            ell_width_cutoff: None,
            score_secs: 1e-4,
            default_score_secs: 2e-4,
            level: "heuristic".into(),
            fingerprint: "deadbeef-n64-nnz256".into(),
            device: "p80-shm98304".into(),
            dtype: "f64".into(),
            base_config: "sd1-Multilevel-r4-c8-s9e3779b9".into(),
            scope: "ehyb".into(),
            reorder: "none".into(),
            oracle: "roofline".into(),
            probe_width: 0,
            drift: None,
        }
    }

    fn temp_store(tag: &str) -> PlanStore {
        let dir = std::env::temp_dir().join(format!("ehyb-store-{tag}-{}", std::process::id()));
        PlanStore::new(dir)
    }

    #[test]
    fn save_load_roundtrip() {
        let store = temp_store("rt");
        let p = plan();
        let path = store.save(&p).unwrap();
        assert!(path.exists());
        let back = store.load(&p.fingerprint, &p.device, &p.dtype, &p.scope).unwrap().unwrap();
        assert_eq!(back, p);
        // No temp droppings left behind.
        let leftovers: Vec<_> = std::fs::read_dir(store.dir())
            .unwrap()
            .filter_map(|e| e.ok())
            .filter(|e| e.path().extension().is_some_and(|x| x == "tmp"))
            .collect();
        assert!(leftovers.is_empty(), "{leftovers:?}");
        std::fs::remove_dir_all(store.dir()).ok();
    }

    #[test]
    fn missing_entry_is_none() {
        let store = temp_store("miss");
        assert!(store.load("nope", "dev", "f64", "auto").unwrap().is_none());
    }

    #[test]
    fn malformed_entry_is_err_not_panic() {
        let store = temp_store("bad");
        std::fs::create_dir_all(store.dir()).unwrap();
        std::fs::write(store.path_for("k", "d", "f64", "auto"), "{not json").unwrap();
        assert!(store.load("k", "d", "f64", "auto").is_err());
        // ...and the damage is quarantined: the key is a cold miss now.
        assert_eq!(store.quarantines(), 1);
        assert!(store.load("k", "d", "f64", "auto").unwrap().is_none());
        std::fs::remove_dir_all(store.dir()).ok();
    }

    #[test]
    fn torn_entry_is_quarantined_and_next_save_recovers() {
        let store = temp_store("torn");
        let p = plan();
        let path = store.save(&p).unwrap();
        // Tear the entry mid-JSON — what a crashed writer without the
        // temp-file + rename protocol would have left behind.
        let text = std::fs::read_to_string(&path).unwrap();
        std::fs::write(&path, &text[..text.len() / 2]).unwrap();
        assert!(store.load(&p.fingerprint, &p.device, &p.dtype, &p.scope).is_err());
        assert_eq!(store.quarantines(), 1);
        // The torn file moved aside: same key is a plain miss, the .bad
        // artifact is preserved for postmortem, nothing re-quarantines.
        assert!(store.load(&p.fingerprint, &p.device, &p.dtype, &p.scope).unwrap().is_none());
        assert_eq!(store.quarantines(), 1);
        let bads = std::fs::read_dir(store.dir())
            .unwrap()
            .filter_map(|e| e.ok())
            .filter(|e| e.path().to_string_lossy().ends_with(".bad"))
            .count();
        assert_eq!(bads, 1);
        // A fresh save re-occupies the key and round-trips.
        store.save(&p).unwrap();
        let back = store.load(&p.fingerprint, &p.device, &p.dtype, &p.scope).unwrap().unwrap();
        assert_eq!(back, p);
        std::fs::remove_dir_all(store.dir()).ok();
    }

    #[test]
    fn mislabeled_entry_is_err() {
        let store = temp_store("mislabel");
        let p = plan();
        store.save(&p).unwrap();
        // Copy the file under a different key: load must reject it.
        std::fs::copy(
            store.path_for(&p.fingerprint, &p.device, &p.dtype, &p.scope),
            store.path_for("other-key", &p.device, &p.dtype, &p.scope),
        )
        .unwrap();
        assert!(store.load("other-key", &p.device, &p.dtype, &p.scope).is_err());
        std::fs::remove_dir_all(store.dir()).ok();
    }

    #[test]
    fn scopes_have_separate_entries() {
        // An EHYB-only tune must not clobber what an Auto search
        // established for the same matrix.
        let store = temp_store("scopes");
        let auto_plan =
            TunedPlan { engine: EngineKind::CsrScalar, scope: "auto".into(), ..plan() };
        let ehyb_plan = plan(); // scope "ehyb"
        store.save(&auto_plan).unwrap();
        store.save(&ehyb_plan).unwrap();
        let a = store.load(&plan().fingerprint, &plan().device, "f64", "auto").unwrap().unwrap();
        let e = store.load(&plan().fingerprint, &plan().device, "f64", "ehyb").unwrap().unwrap();
        assert_eq!(a, auto_plan);
        assert_eq!(e, ehyb_plan);
        std::fs::remove_dir_all(store.dir()).ok();
    }

    #[test]
    fn calibration_roundtrips_and_quarantines_like_plans() {
        use crate::profile::Calibration;
        let store = temp_store("cal");
        assert!(store.load_calibration("p80-shm98304", "f64").unwrap().is_none());
        let cal = Calibration {
            dram_secs_per_byte: 1.2e-12,
            l2_secs_per_byte: 4.0e-13,
            shm_secs_per_byte: 8.0e-14,
            base_secs: 3.0e-6,
            samples: 9,
            residual: 0.04,
        };
        let path = store.save_calibration(&cal, "p80-shm98304", "f64").unwrap();
        assert!(path.exists());
        let back = store.load_calibration("p80-shm98304", "f64").unwrap().unwrap();
        assert_eq!(back, cal);
        // A calibration never shadows a plan entry for the same device.
        let p = plan();
        store.save(&p).unwrap();
        assert!(store.load(&p.fingerprint, &p.device, &p.dtype, &p.scope).unwrap().is_some());
        // Damage quarantines like plan entries: err once, then a miss.
        std::fs::write(store.calibration_path("p80-shm98304", "f64"), "{torn").unwrap();
        assert!(store.load_calibration("p80-shm98304", "f64").is_err());
        assert_eq!(store.quarantines(), 1);
        assert!(store.load_calibration("p80-shm98304", "f64").unwrap().is_none());
        std::fs::remove_dir_all(store.dir()).ok();
    }

    #[test]
    fn from_env_requires_nonempty() {
        // Does not mutate the environment (unsafe under parallel
        // tests): just exercise both constructor paths directly.
        assert!(PlanStore::new("/tmp/x").dir().ends_with("x"));
        if std::env::var(ENV_DIR).is_err() {
            assert!(PlanStore::from_env().is_none());
        }
    }
}
