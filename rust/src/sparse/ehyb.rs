//! The EHYB storage format (paper §3, Figure 1): the result of the
//! "partitioning, reordering, and caching" preprocessing.
//!
//! After graph partitioning and the per-partition descending-nnz
//! reordering, the matrix (in the *new* row/column order) splits into:
//!
//! * **Sliced-ELL part** — entries whose row and column fall in the same
//!   partition. Stored as SELL-P slices (slice height = warp size = 32),
//!   contiguous per partition, with **partition-local u16 column
//!   indices** (valid because the partition's x-slice is capped by
//!   shared-memory/VMEM capacity < 2¹⁶ elements — paper §3.4).
//! * **ER (extra rows) part** — entries whose column leaves the row's
//!   partition, re-arranged into descending-length rows with global u32
//!   columns, plus the `yIdxER` map from ER slot to output row.
//!
//! Padding slots store `col = 0, val = 0` — numerically inert and
//! gather-safe (index 0 always in bounds), matching what the L1 Pallas
//! kernel needs. Logical nnz is tracked in explicit fields.
//!
//! This module owns storage, invariant validation, and a serial
//! reference SpMV with exactly the kernel's semantics. Construction
//! lives in [`crate::preprocess`]; the optimized engine in
//! [`crate::spmv::ehyb_cpu`]; the simulated CUDA kernel in
//! [`crate::gpu::kernels`].

use super::scalar::Scalar;

/// EHYB matrix in new (post-reorder) index space plus the permutation
/// back to the original ordering.
///
/// `PartialEq` compares every stored array (values element-wise) — what
/// the autotune plan-store round-trip test means by "byte-identical"
/// modulo the usual `-0.0 == 0.0` float-equality caveat; pair it with a
/// bit-level value check when that distinction matters.
#[derive(Clone, Debug, PartialEq)]
pub struct EhybMatrix<S: Scalar> {
    /// Original dimension (square matrices only — FEM systems).
    pub n: usize,
    /// Number of partitions (paper: K × P).
    pub num_parts: usize,
    /// Rows (and x-entries) per partition = the paper's `VecSize`;
    /// multiple of `slice_height`. The last partition may be logically
    /// short; it is padded to `vec_size`.
    pub vec_size: usize,
    /// Slice height (warp size; 32).
    pub slice_height: usize,

    // ---- sliced-ELL (in-partition) part ----
    /// Element offset of each slice, `len = num_slices + 1`
    /// (paper `PositionELL`). Slices are contiguous per partition:
    /// partition p owns slices `[p*slices_per_part, (p+1)*slices_per_part)`.
    pub slice_ptr: Vec<u32>,
    /// Max nnz of rows in each slice (paper `WidthELL`).
    pub slice_width: Vec<u32>,
    /// Partition-local column indices (paper §3.4 compact format).
    pub ell_cols: Vec<u16>,
    pub ell_vals: Vec<S>,
    /// Logical (unpadded) nonzeros in the ELL part.
    pub ell_nnz: usize,

    // ---- ER (out-of-partition) part ----
    /// ER slice offsets (paper `PositionER`).
    pub er_slice_ptr: Vec<u32>,
    pub er_slice_width: Vec<u32>,
    /// Number of logical ER rows.
    pub er_rows: usize,
    /// Global (new-order) column indices.
    pub er_cols: Vec<u32>,
    pub er_vals: Vec<S>,
    /// `y_idx_er[j]` = new-order output row of ER row `j` (paper `yIdxER`).
    pub y_idx_er: Vec<u32>,
    /// Logical nonzeros in the ER part.
    pub er_nnz: usize,

    // ---- permutation ----
    /// `perm[old] = new` (paper `ReorderTable`).
    pub perm: Vec<u32>,
    /// `iperm[new] = old`.
    pub iperm: Vec<u32>,
}

impl<S: Scalar> EhybMatrix<S> {
    pub fn nnz(&self) -> usize {
        self.ell_nnz + self.er_nnz
    }

    pub fn num_slices(&self) -> usize {
        self.slice_width.len()
    }

    pub fn slices_per_part(&self) -> usize {
        self.vec_size / self.slice_height
    }

    /// Padded row count = num_parts * vec_size.
    pub fn padded_rows(&self) -> usize {
        self.num_parts * self.vec_size
    }

    /// Fraction of nonzeros that fell out of their partition — the
    /// edge-cut quality metric of the partitioner (lower is better).
    pub fn er_fraction(&self) -> f64 {
        if self.nnz() == 0 {
            return 0.0;
        }
        self.er_nnz as f64 / self.nnz() as f64
    }

    /// Stored ELL slots / logical ELL nnz (padding overhead the
    /// descending-nnz reorder minimizes).
    pub fn ell_fill_ratio(&self) -> f64 {
        if self.ell_nnz == 0 {
            return 1.0;
        }
        self.ell_vals.len() as f64 / self.ell_nnz as f64
    }

    /// Device-memory footprint in bytes — the quantity §3.4's u16 trick
    /// reduces by 25 % (f32) / 13.3 % (f64) on the ELL part.
    pub fn bytes(&self) -> usize {
        self.slice_ptr.len() * 4
            + self.slice_width.len() * 4
            + self.ell_cols.len() * 2
            + self.ell_vals.len() * S::BYTES
            + self.er_slice_ptr.len() * 4
            + self.er_slice_width.len() * 4
            + self.er_cols.len() * 4
            + self.er_vals.len() * S::BYTES
            + self.y_idx_er.len() * 4
            + self.perm.len() * 4
    }

    /// Bytes if the ELL columns were stored as u32 (ablation §7.2).
    pub fn bytes_u32_cols(&self) -> usize {
        self.bytes() + self.ell_cols.len() * 2
    }

    /// Validate all structural invariants. Called by tests and after
    /// preprocessing in debug builds.
    pub fn validate(&self) -> crate::Result<()> {
        use crate::ensure;
        ensure!(self.vec_size % self.slice_height == 0, "vec_size not multiple of slice height");
        ensure!(self.vec_size <= (1 << 16), "vec_size {} exceeds u16 index space", self.vec_size);
        ensure!(self.padded_rows() >= self.n, "partitions do not cover matrix");
        ensure!(self.num_slices() == self.num_parts * self.slices_per_part(), "slice count");
        ensure!(self.slice_ptr.len() == self.num_slices() + 1, "slice_ptr length");
        ensure!(self.slice_ptr[0] == 0, "slice_ptr[0]");
        for s in 0..self.num_slices() {
            ensure!(
                self.slice_ptr[s + 1] - self.slice_ptr[s]
                    == self.slice_width[s] * self.slice_height as u32,
                "slice {s} extent != width*height"
            );
        }
        ensure!(*self.slice_ptr.last().unwrap() as usize == self.ell_vals.len(), "ELL size");
        ensure!(self.ell_cols.len() == self.ell_vals.len(), "ELL col/val len");
        ensure!(
            self.ell_cols.iter().all(|&c| (c as usize) < self.vec_size),
            "ELL local col out of partition"
        );
        // ER invariants.
        ensure!(self.er_slice_ptr.len() == self.er_slice_width.len() + 1, "ER slice_ptr len");
        ensure!(*self.er_slice_ptr.last().unwrap_or(&0) as usize == self.er_vals.len(), "ER size");
        ensure!(self.er_cols.len() == self.er_vals.len(), "ER col/val len");
        ensure!(self.er_cols.iter().all(|&c| (c as usize) < self.padded_rows()), "ER col bound");
        ensure!(self.y_idx_er.len() >= self.er_rows, "yIdxER length");
        ensure!(
            self.y_idx_er[..self.er_rows]
                .iter()
                .all(|&r| (r as usize) < self.n + (self.padded_rows() - self.n)),
            "yIdxER bound"
        );
        // Injectivity: one ER slot per distinct output row. The parallel
        // ER scatter in `spmv::ehyb_cpu` relies on this to write
        // disjoint yp entries from different slice ranges.
        let mut er_seen = vec![false; self.padded_rows()];
        for &r in &self.y_idx_er[..self.er_rows] {
            ensure!(!er_seen[r as usize], "yIdxER not injective at row {r}");
            er_seen[r as usize] = true;
        }
        // Permutation is a bijection old<->new over n rows.
        ensure!(self.perm.len() == self.n && self.iperm.len() >= self.n, "perm length");
        for old in 0..self.n {
            let new = self.perm[old] as usize;
            ensure!(new < self.padded_rows(), "perm out of range");
            ensure!(self.iperm[new] as usize == old, "perm/iperm mismatch at {old}");
        }
        Ok(())
    }

    /// Reference SpMV with the kernel's exact semantics, in the original
    /// index space: `y = A x`. Serial; used as the correctness oracle for
    /// the optimized engines and the GPU-simulated kernel.
    pub fn spmv(&self, x: &[S], y: &mut [S]) {
        assert_eq!(x.len(), self.n);
        assert_eq!(y.len(), self.n);
        // Permute x into new order (the GPU kernel stores x pre-permuted;
        // the runtime does this once per solve, not per SpMV).
        let xp = self.permute_x(x);
        let yp = self.spmv_new_order(&xp);
        for new in 0..self.padded_rows() {
            let old = self.iperm[new] as usize;
            if old < self.n {
                y[old] = yp[new];
            }
        }
    }

    /// Permute x (old order) to the new order, padded to `padded_rows`.
    pub fn permute_x(&self, x: &[S]) -> Vec<S> {
        let mut xp = vec![S::ZERO; self.padded_rows()];
        for old in 0..self.n {
            xp[self.perm[old] as usize] = x[old];
        }
        xp
    }

    /// Scatter y (new order, padded) back to old order.
    pub fn unpermute_y(&self, yp: &[S]) -> Vec<S> {
        let mut y = vec![S::ZERO; self.n];
        for new in 0..self.padded_rows() {
            let old = self.iperm[new] as usize;
            if old < self.n {
                y[old] = yp[new];
            }
        }
        y
    }

    /// SpMV entirely in the new (reordered, padded) index space —
    /// mirrors Algorithm 3: per partition, gather from the partition's
    /// x-slice (the "explicitly cached" segment), then the ER pass.
    pub fn spmv_new_order(&self, xp: &[S]) -> Vec<S> {
        assert_eq!(xp.len(), self.padded_rows());
        let mut yp = vec![S::ZERO; self.padded_rows()];
        let h = self.slice_height;
        let spp = self.slices_per_part();
        for p in 0..self.num_parts {
            // Algorithm 3 line 4: the explicit cache — a view of the
            // partition's x slice (on GPU: copied to shared memory).
            let cached = &xp[p * self.vec_size..(p + 1) * self.vec_size];
            for ls in 0..spp {
                let s = p * spp + ls;
                let base = self.slice_ptr[s] as usize;
                let w = self.slice_width[s] as usize;
                let row0 = p * self.vec_size + ls * h;
                for lane in 0..h {
                    let mut acc = S::ZERO;
                    for k in 0..w {
                        let idx = base + k * h + lane;
                        // Padding is col=0,val=0: contributes nothing.
                        acc = self.ell_vals[idx].mul_add(cached[self.ell_cols[idx] as usize], acc);
                    }
                    yp[row0 + lane] = acc;
                }
            }
        }
        // ER pass: uncached gathers over the full vector, scatter-add.
        let h = self.slice_height;
        for s in 0..self.er_slice_width.len() {
            let base = self.er_slice_ptr[s] as usize;
            let w = self.er_slice_width[s] as usize;
            for lane in 0..h {
                let j = s * h + lane;
                if j >= self.er_rows {
                    break;
                }
                let mut acc = S::ZERO;
                for k in 0..w {
                    let idx = base + k * h + lane;
                    acc = self.er_vals[idx].mul_add(xp[self.er_cols[idx] as usize], acc);
                }
                let out = self.y_idx_er[j] as usize;
                yp[out] += acc;
            }
        }
        yp
    }
}

// NOTE: constructed by `crate::preprocess::EhybPlan::build`; tests that
// need a real instance live there and in `rust/tests/`.
#[cfg(test)]
mod tests {
    use crate::preprocess::{EhybPlan, PreprocessConfig};
    use crate::sparse::gen::poisson2d;

    #[test]
    fn bytes_u16_smaller_than_u32() {
        let m = poisson2d::<f32>(24, 24);
        let plan = EhybPlan::build(&m, &PreprocessConfig::default()).unwrap();
        let e = &plan.matrix;
        assert!(e.bytes() < e.bytes_u32_cols());
        // §3.4: saving is exactly 2 bytes per stored ELL slot.
        assert_eq!(e.bytes_u32_cols() - e.bytes(), e.ell_cols.len() * 2);
    }

    #[test]
    fn validate_passes_on_built_matrix() {
        let m = poisson2d::<f64>(17, 13); // deliberately non-multiple dims
        let plan = EhybPlan::build(&m, &PreprocessConfig::default()).unwrap();
        plan.matrix.validate().unwrap();
    }

    #[test]
    fn er_fraction_bounded() {
        let m = poisson2d::<f64>(32, 32);
        let plan = EhybPlan::build(&m, &PreprocessConfig::default()).unwrap();
        let f = plan.matrix.er_fraction();
        assert!((0.0..=1.0).contains(&f));
        // A good partitioner keeps most stencil entries in-partition.
        assert!(f < 0.5, "er_fraction={f}");
    }
}
