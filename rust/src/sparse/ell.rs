//! ELLPACK format: dense `nrows × width` value/column arrays in
//! column-major order so that consecutive rows (GPU threads) access
//! consecutive memory — the coalescing-friendly layout from
//! Bell & Garland 2009. Building block of [`super::hyb`].

use super::csr::Csr;
use super::scalar::Scalar;
use crate::util::lanes::{lane_width, Pack};

/// ELL matrix. `cols[k * nrows + i]` / `vals[k * nrows + i]` hold the
/// k-th entry of row i; padding slots have `col = PAD` and `val = 0`.
#[derive(Clone, Debug)]
pub struct Ell<S: Scalar> {
    nrows: usize,
    ncols: usize,
    width: usize,
    pub cols: Vec<u32>,
    pub vals: Vec<S>,
}

/// Padding marker. Using a valid column (0) with value 0 would also be
/// correct numerically; a sentinel keeps traffic accounting honest.
pub const PAD: u32 = u32::MAX;

impl<S: Scalar> Ell<S> {
    /// Build from CSR with the natural width = max row nnz.
    pub fn from_csr(csr: &Csr<S>) -> Self {
        Self::from_csr_with_width(csr, csr.max_row_nnz())
    }

    /// Build with an explicit width; rows longer than `width` are an error
    /// (HYB handles the overflow instead).
    pub fn from_csr_with_width(csr: &Csr<S>, width: usize) -> Self {
        let nrows = csr.nrows();
        let mut cols = vec![PAD; nrows * width];
        let mut vals = vec![S::ZERO; nrows * width];
        for i in 0..nrows {
            let (rc, rv) = csr.row(i);
            assert!(rc.len() <= width, "row {i} nnz {} exceeds ELL width {width}", rc.len());
            for (k, (&c, &v)) in rc.iter().zip(rv).enumerate() {
                cols[k * nrows + i] = c;
                vals[k * nrows + i] = v;
            }
        }
        Self { nrows, ncols: csr.ncols(), width, cols, vals }
    }

    pub fn nrows(&self) -> usize {
        self.nrows
    }
    pub fn ncols(&self) -> usize {
        self.ncols
    }
    pub fn width(&self) -> usize {
        self.width
    }

    /// Stored nonzeros (excludes padding).
    pub fn nnz(&self) -> usize {
        self.cols.iter().filter(|&&c| c != PAD).count()
    }

    /// Padding overhead ratio: stored slots / nnz.
    pub fn fill_ratio(&self) -> f64 {
        let nnz = self.nnz();
        if nnz == 0 {
            return 1.0;
        }
        (self.nrows * self.width) as f64 / nnz as f64
    }

    /// `y = A x` traversing column-major (the GPU access order).
    /// Dispatches on the crate's `simd` feature; both legs are always
    /// compiled ([`Self::spmv_scalar`] / [`Self::spmv_simd`]).
    pub fn spmv(&self, x: &[S], y: &mut [S]) {
        if cfg!(feature = "simd") {
            self.spmv_simd(x, y)
        } else {
            self.spmv_scalar(x, y)
        }
    }

    /// Reference column-major walk, pad slots skipped by branch.
    pub fn spmv_scalar(&self, x: &[S], y: &mut [S]) {
        assert_eq!(x.len(), self.ncols);
        assert_eq!(y.len(), self.nrows);
        y.fill(S::ZERO);
        for k in 0..self.width {
            let base = k * self.nrows;
            for i in 0..self.nrows {
                let c = self.cols[base + i];
                if c != PAD {
                    y[i] = self.vals[base + i].mul_add(x[c as usize], y[i]);
                }
            }
        }
    }

    /// Row-packed walk: `W` adjacent rows advance together down the k
    /// columns with pad slots handled branch-free by the `+0.0`-fma
    /// identity. Each row's k-ordered fused chain is untouched, so the
    /// result is bitwise equal to [`Self::spmv_scalar`] for finite `x`.
    pub fn spmv_simd(&self, x: &[S], y: &mut [S]) {
        match lane_width(S::BYTES) {
            16 => self.spmv_packed::<16>(x, y),
            8 => self.spmv_packed::<8>(x, y),
            4 => self.spmv_packed::<4>(x, y),
            _ => self.spmv_packed::<2>(x, y),
        }
    }

    fn spmv_packed<const W: usize>(&self, x: &[S], y: &mut [S]) {
        assert_eq!(x.len(), self.ncols);
        assert_eq!(y.len(), self.nrows);
        let n = self.nrows;
        let mut i = 0;
        while i + W <= n {
            let mut acc = Pack::<S, W>::ZERO;
            for k in 0..self.width {
                let off = k * n + i;
                let vals = Pack::load(&self.vals[off..off + W]);
                let xg = Pack::gather_u32_pad0(x, &self.cols[off..off + W], PAD);
                acc = vals.mul_add(xg, acc);
            }
            acc.store(&mut y[i..i + W]);
            i += W;
        }
        for r in i..n {
            let mut acc = S::ZERO;
            for k in 0..self.width {
                let c = self.cols[k * n + r];
                if c != PAD {
                    acc = self.vals[k * n + r].mul_add(x[c as usize], acc);
                }
            }
            y[r] = acc;
        }
    }

    pub fn bytes(&self) -> usize {
        self.cols.len() * 4 + self.vals.len() * S::BYTES
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::coo::Coo;

    fn sample() -> Csr<f64> {
        Coo::from_triplets(
            3,
            4,
            vec![(0, 0, 1.0), (0, 3, 2.0), (1, 1, 3.0), (2, 0, 4.0), (2, 2, 5.0), (2, 3, 6.0)],
        )
        .unwrap()
        .to_csr()
    }

    #[test]
    fn from_csr_width() {
        let e = Ell::from_csr(&sample());
        assert_eq!(e.width(), 3);
        assert_eq!(e.nnz(), 6);
    }

    #[test]
    fn column_major_layout() {
        let e = Ell::from_csr(&sample());
        // First entries of each row live contiguously: rows 0,1,2 -> cols 0,1,0.
        assert_eq!(&e.cols[0..3], &[0, 1, 0]);
    }

    #[test]
    fn spmv_matches_csr() {
        let csr = sample();
        let e = Ell::from_csr(&csr);
        let x = [1.0, 2.0, 3.0, 4.0];
        let mut y1 = [0.0; 3];
        let mut y2 = [0.0; 3];
        csr.spmv(&x, &mut y1);
        e.spmv(&x, &mut y2);
        assert_eq!(y1, y2);
    }

    #[test]
    fn simd_walk_bit_identical_to_scalar() {
        use crate::util::Xoshiro256;
        for &(n, seed) in &[(3usize, 1u64), (61, 4), (128, 9)] {
            let mut rng = Xoshiro256::new(seed);
            let mut coo = Coo::<f64>::new(n, n);
            for i in 0..n {
                for _ in 0..1 + rng.next_below(7) {
                    coo.push(i, rng.next_below(n), rng.range_f64(-1.0, 1.0));
                }
            }
            let e = Ell::from_csr(&coo.to_csr());
            let x: Vec<f64> = (0..n).map(|i| ((i * 17 + 3) % 31) as f64 * 0.0625 - 1.0).collect();
            let mut y_s = vec![0.0; n];
            let mut y_v = vec![0.0; n];
            e.spmv_scalar(&x, &mut y_s);
            e.spmv_simd(&x, &mut y_v);
            assert_eq!(y_s, y_v, "n={n}");
        }
    }

    #[test]
    fn fill_ratio() {
        let e = Ell::from_csr(&sample());
        assert!((e.fill_ratio() - 9.0 / 6.0).abs() < 1e-12);
    }

    #[test]
    #[should_panic(expected = "exceeds ELL width")]
    fn overflow_width_panics() {
        Ell::from_csr_with_width(&sample(), 2);
    }

    #[test]
    fn empty() {
        let csr = Coo::<f64>::new(2, 2).to_csr();
        let e = Ell::from_csr(&csr);
        assert_eq!(e.width(), 0);
        let mut y = [1.0; 2];
        e.spmv(&[1.0, 1.0], &mut y);
        assert_eq!(y, [0.0, 0.0]);
    }
}
