//! Synthetic matrix generators standing in for the paper's 94 SuiteSparse
//! FEM matrices (no network access in this environment — see DESIGN.md §4).
//!
//! Each generator reproduces the *structural* properties that determine
//! SpMV behaviour for its category: nnz/row distribution, bandwidth /
//! locality (how partitionable the graph is), and value magnitudes.
//! Categories map 1:1 to the paper's Table 3 corpus: structural (3D
//! elasticity, 27-pt stencils), CFD (7-pt/anisotropic), electromagnetics
//! (edge elements ≈ mixed-degree local graphs), circuit/power (power-law
//! degree with long-range couplings), optimization (KKT-style block
//! systems), model reduction / semiconductor (unstructured + bands).

use super::coo::Coo;
use super::csr::Csr;
use super::scalar::Scalar;
use crate::util::Xoshiro256;

/// 1D Laplacian (tridiagonal [-1, 2, -1]); mostly for unit tests.
pub fn poisson1d<S: Scalar>(n: usize) -> Csr<S> {
    let mut coo = Coo::with_capacity(n, n, 3 * n);
    for i in 0..n {
        coo.push(i, i, S::from_f64(2.0));
        if i > 0 {
            coo.push(i, i - 1, S::from_f64(-1.0));
        }
        if i + 1 < n {
            coo.push(i, i + 1, S::from_f64(-1.0));
        }
    }
    coo.to_csr()
}

/// 2D 5-point Laplacian on an `nx × ny` grid.
pub fn poisson2d<S: Scalar>(nx: usize, ny: usize) -> Csr<S> {
    let n = nx * ny;
    let mut coo = Coo::with_capacity(n, n, 5 * n);
    for y in 0..ny {
        for x in 0..nx {
            let i = y * nx + x;
            coo.push(i, i, S::from_f64(4.0));
            if x > 0 {
                coo.push(i, i - 1, S::from_f64(-1.0));
            }
            if x + 1 < nx {
                coo.push(i, i + 1, S::from_f64(-1.0));
            }
            if y > 0 {
                coo.push(i, i - nx, S::from_f64(-1.0));
            }
            if y + 1 < ny {
                coo.push(i, i + nx, S::from_f64(-1.0));
            }
        }
    }
    coo.to_csr()
}

/// 3D 7-point Laplacian on an `nx × ny × nz` grid — the canonical CFD /
/// thermal matrix (paper's atmosmodX, FEM_3D_thermal2 class).
pub fn poisson3d<S: Scalar>(nx: usize, ny: usize, nz: usize) -> Csr<S> {
    let n = nx * ny * nz;
    let mut coo = Coo::with_capacity(n, n, 7 * n);
    let idx = |x: usize, y: usize, z: usize| (z * ny + y) * nx + x;
    for z in 0..nz {
        for y in 0..ny {
            for x in 0..nx {
                let i = idx(x, y, z);
                coo.push(i, i, S::from_f64(6.0));
                if x > 0 {
                    coo.push(i, idx(x - 1, y, z), S::from_f64(-1.0));
                }
                if x + 1 < nx {
                    coo.push(i, idx(x + 1, y, z), S::from_f64(-1.0));
                }
                if y > 0 {
                    coo.push(i, idx(x, y - 1, z), S::from_f64(-1.0));
                }
                if y + 1 < ny {
                    coo.push(i, idx(x, y + 1, z), S::from_f64(-1.0));
                }
                if z > 0 {
                    coo.push(i, idx(x, y, z - 1), S::from_f64(-1.0));
                }
                if z + 1 < nz {
                    coo.push(i, idx(x, y, z + 1), S::from_f64(-1.0));
                }
            }
        }
    }
    coo.to_csr()
}

/// 3D 27-point stencil — trilinear (Q1) hexahedral FEM assembly pattern
/// (the paper's 3D-problem class: cant, consph, BenElechi1).
pub fn stencil27<S: Scalar>(nx: usize, ny: usize, nz: usize, seed: u64) -> Csr<S> {
    let n = nx * ny * nz;
    let mut rng = Xoshiro256::new(seed);
    let mut coo = Coo::with_capacity(n, n, 27 * n);
    let idx = |x: usize, y: usize, z: usize| (z * ny + y) * nx + x;
    for z in 0..nz {
        for y in 0..ny {
            for x in 0..nx {
                let i = idx(x, y, z);
                for dz in -1i64..=1 {
                    for dy in -1i64..=1 {
                        for dx in -1i64..=1 {
                            let (xx, yy, zz) = (x as i64 + dx, y as i64 + dy, z as i64 + dz);
                            if xx < 0 || yy < 0 || zz < 0 {
                                continue;
                            }
                            let (xx, yy, zz) = (xx as usize, yy as usize, zz as usize);
                            if xx >= nx || yy >= ny || zz >= nz {
                                continue;
                            }
                            let j = idx(xx, yy, zz);
                            let v = if i == j {
                                26.0 + rng.next_f64()
                            } else {
                                -1.0 + 0.1 * rng.next_gaussian()
                            };
                            coo.push(i, j, S::from_f64(v));
                        }
                    }
                }
            }
        }
    }
    coo.to_csr()
}

/// 3D linear elasticity pattern: `ndof` unknowns per grid node coupled
/// within the 27-point neighbourhood — dense `ndof × ndof` blocks give
/// the high nnz/row (~60–81) of the paper's structural matrices
/// (audikw_1, Emilia_923, bone010 …).
pub fn elasticity3d<S: Scalar>(nx: usize, ny: usize, nz: usize, ndof: usize, seed: u64) -> Csr<S> {
    let nodes = nx * ny * nz;
    let n = nodes * ndof;
    let mut rng = Xoshiro256::new(seed);
    let mut coo = Coo::with_capacity(n, n, 27 * ndof * ndof * nodes / 2);
    let idx = |x: usize, y: usize, z: usize| (z * ny + y) * nx + x;
    for z in 0..nz {
        for y in 0..ny {
            for x in 0..nx {
                let node_i = idx(x, y, z);
                for dz in -1i64..=1 {
                    for dy in -1i64..=1 {
                        for dx in -1i64..=1 {
                            let (xx, yy, zz) = (x as i64 + dx, y as i64 + dy, z as i64 + dz);
                            if xx < 0 || yy < 0 || zz < 0 {
                                continue;
                            }
                            let (xx, yy, zz) = (xx as usize, yy as usize, zz as usize);
                            if xx >= nx || yy >= ny || zz >= nz {
                                continue;
                            }
                            let node_j = idx(xx, yy, zz);
                            for a in 0..ndof {
                                for b in 0..ndof {
                                    let i = node_i * ndof + a;
                                    let j = node_j * ndof + b;
                                    let v = if i == j {
                                        80.0 + rng.next_f64()
                                    } else {
                                        -1.0 + 0.05 * rng.next_gaussian()
                                    };
                                    coo.push(i, j, S::from_f64(v));
                                }
                            }
                        }
                    }
                }
            }
        }
    }
    coo.to_csr()
}

/// Unstructured-mesh graph: points on a jittered grid connected to their
/// spatial neighbours within a radius, giving the irregular-but-local
/// sparsity of unstructured FEM meshes (offshore, F1, Fault_639 …).
/// Node numbering is randomized, so locality is *hidden* from naive
/// partition-by-index — exactly the case where graph partitioning earns
/// its keep.
pub fn unstructured_mesh<S: Scalar>(nx: usize, ny: usize, avg_extra: f64, seed: u64) -> Csr<S> {
    let n = nx * ny;
    let mut rng = Xoshiro256::new(seed);
    // Random relabeling.
    let mut label: Vec<usize> = (0..n).collect();
    rng.shuffle(&mut label);
    let idx = |x: usize, y: usize| label[y * nx + x];
    let mut coo = Coo::with_capacity(n, n, 8 * n);
    for y in 0..ny {
        for x in 0..nx {
            let i = idx(x, y);
            coo.push(i, i, S::from_f64(8.0 + rng.next_f64()));
            // 8-neighbourhood with random dropout => degree jitter.
            for (dx, dy) in
                [(-1i64, 0i64), (1, 0), (0, -1), (0, 1), (-1, -1), (1, 1), (-1, 1), (1, -1)]
            {
                let (xx, yy) = (x as i64 + dx, y as i64 + dy);
                if xx < 0 || yy < 0 || xx >= nx as i64 || yy >= ny as i64 {
                    continue;
                }
                if rng.next_f64() < 0.8 {
                    let j = idx(xx as usize, yy as usize);
                    coo.push(i, j, S::from_f64(-1.0 + 0.1 * rng.next_gaussian()));
                }
            }
            // A few longer-range couplings (mesh grading / contact).
            let extra = (avg_extra * 2.0 * rng.next_f64()) as usize;
            for _ in 0..extra {
                let dx = rng.next_below(7) as i64 - 3;
                let dy = rng.next_below(7) as i64 - 3;
                let (xx, yy) = (x as i64 + dx, y as i64 + dy);
                if xx >= 0 && yy >= 0 && xx < nx as i64 && yy < ny as i64 {
                    let noise = S::from_f64(0.05 * rng.next_gaussian());
                    coo.push(i, idx(xx as usize, yy as usize), noise);
                }
            }
        }
    }
    let mut m = coo;
    m.sum_duplicates();
    m.to_csr()
}

/// Circuit-simulation pattern (Freescale1, memchip, rajat31): mostly very
/// short rows plus a power-law tail of high-degree "net" rows with
/// long-range connections — the format-stress case.
pub fn circuit<S: Scalar>(n: usize, avg_deg: usize, hub_fraction: f64, seed: u64) -> Csr<S> {
    let mut rng = Xoshiro256::new(seed);
    let mut coo = Coo::with_capacity(n, n, n * (avg_deg + 1));
    for i in 0..n {
        coo.push(i, i, S::from_f64(2.0 + rng.next_f64()));
        let deg = if rng.next_f64() < hub_fraction {
            // Hub row: power-law length, capped.
            let u = rng.next_f64().max(1e-9);
            ((avg_deg as f64 * 20.0 * u.powf(-0.5)) as usize).min(n / 4).max(avg_deg)
        } else {
            1 + rng.next_below(avg_deg.max(1))
        };
        for _ in 0..deg {
            // Mostly local, some global couplings.
            let j = if rng.next_f64() < 0.7 {
                let span = 200.min(n);
                let lo = i.saturating_sub(span / 2);
                (lo + rng.next_below(span)).min(n - 1)
            } else {
                rng.next_below(n)
            };
            coo.push(i, j, S::from_f64(-0.1 + 0.05 * rng.next_gaussian()));
        }
    }
    let mut m = coo;
    m.sum_duplicates();
    m.to_csr()
}

/// KKT-style optimization matrix (nlpkkt80/120/160): a 2×2 block system
/// [[H, Aᵀ], [A, 0]] with stencil H and a sparse coupling A.
pub fn kkt<S: Scalar>(nh: usize, seed: u64) -> Csr<S> {
    let h = poisson3d::<S>(nh, nh, nh);
    let m = h.nrows();
    let nc = m / 2; // constraint count
    let n = m + nc;
    let mut rng = Xoshiro256::new(seed);
    let mut coo = Coo::with_capacity(n, n, h.nnz() + 6 * nc);
    for i in 0..m {
        let (cols, vals) = h.row(i);
        for (&c, &v) in cols.iter().zip(vals) {
            coo.push(i, c as usize, v);
        }
    }
    for k in 0..nc {
        // Each constraint couples ~3 primal variables.
        for _ in 0..3 {
            let j = rng.next_below(m);
            let v = S::from_f64(1.0 + rng.next_f64());
            coo.push(m + k, j, v);
            coo.push(j, m + k, v);
        }
    }
    let mut c = coo;
    c.sum_duplicates();
    c.to_csr()
}

/// Banded matrix with uniform random fill inside the band — model
/// reduction / semiconductor device class (t3dh, nv2-like bandedness).
pub fn banded<S: Scalar>(n: usize, bandwidth: usize, fill: f64, seed: u64) -> Csr<S> {
    let mut rng = Xoshiro256::new(seed);
    let mut coo = Coo::with_capacity(n, n, (n as f64 * bandwidth as f64 * fill) as usize);
    for i in 0..n {
        coo.push(i, i, S::from_f64(4.0 + rng.next_f64()));
        let lo = i.saturating_sub(bandwidth);
        let hi = (i + bandwidth + 1).min(n);
        for j in lo..hi {
            if j != i && rng.next_f64() < fill {
                coo.push(i, j, S::from_f64(-0.5 + 0.2 * rng.next_gaussian()));
            }
        }
    }
    coo.to_csr()
}

/// Make a matrix strictly diagonally dominant (in place on a clone):
/// guarantees SPD-like behaviour for solver tests when symmetrized.
pub fn diag_dominant<S: Scalar>(csr: &Csr<S>) -> Csr<S> {
    let n = csr.nrows();
    let mut coo = Coo::with_capacity(n, n, csr.nnz());
    for i in 0..n {
        let (cols, vals) = csr.row(i);
        let offsum: f64 = cols
            .iter()
            .zip(vals)
            .filter(|(&c, _)| c as usize != i)
            .map(|(_, &v)| v.to_f64().abs())
            .sum();
        for (&c, &v) in cols.iter().zip(vals) {
            if c as usize == i {
                coo.push(i, i, S::from_f64(offsum + 1.0));
            } else {
                coo.push(i, c as usize, v);
            }
        }
        if !cols.iter().any(|&c| c as usize == i) {
            coo.push(i, i, S::from_f64(offsum + 1.0));
        }
    }
    coo.to_csr()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson1d_structure() {
        let m = poisson1d::<f64>(5);
        assert_eq!(m.nnz(), 13);
        assert_eq!(m.row_nnz(0), 2);
        assert_eq!(m.row_nnz(2), 3);
        assert_eq!(m.diagonal(), vec![2.0; 5]);
    }

    #[test]
    fn poisson2d_row_sums() {
        // Interior rows of the Laplacian sum to zero.
        let m = poisson2d::<f64>(5, 5);
        let x = vec![1.0; 25];
        let mut y = vec![0.0; 25];
        m.spmv(&x, &mut y);
        assert_eq!(y[12], 0.0); // center
        assert!(y[0] > 0.0); // corner has fewer neighbours
    }

    #[test]
    fn poisson3d_dims() {
        let m = poisson3d::<f32>(4, 5, 6);
        assert_eq!(m.nrows(), 120);
        assert_eq!(m.max_row_nnz(), 7);
    }

    #[test]
    fn stencil27_max_degree() {
        let m = stencil27::<f64>(4, 4, 4, 1);
        assert_eq!(m.max_row_nnz(), 27);
        assert_eq!(m.nrows(), 64);
    }

    #[test]
    fn elasticity_block_degree() {
        let m = elasticity3d::<f64>(3, 3, 3, 3, 2);
        assert_eq!(m.nrows(), 81);
        // Interior node: 27 neighbours × 3 dof = 81 nnz/row.
        assert_eq!(m.max_row_nnz(), 81);
    }

    #[test]
    fn unstructured_is_symmetric_structure_after_symmetrize() {
        let m = unstructured_mesh::<f64>(16, 16, 0.5, 3);
        assert_eq!(m.nrows(), 256);
        assert!(m.nnz() > 256 * 4);
        let s = m.symmetrize_structure();
        let t = s.transpose();
        assert_eq!(s.col_idx, t.col_idx);
    }

    #[test]
    fn circuit_has_hubs() {
        let m = circuit::<f64>(2000, 3, 0.02, 7);
        let max = m.max_row_nnz();
        let avg = m.nnz() as f64 / 2000.0;
        assert!(max as f64 > avg * 5.0, "max={max} avg={avg}");
    }

    #[test]
    fn kkt_is_square_and_indefinite_structured() {
        let m = kkt::<f64>(6, 5);
        assert_eq!(m.nrows(), 216 + 108);
        assert_eq!(m.nrows(), m.ncols());
    }

    #[test]
    fn banded_within_band() {
        let m = banded::<f64>(100, 5, 0.5, 11);
        for i in 0..100 {
            let (cols, _) = m.row(i);
            for &c in cols {
                assert!((c as i64 - i as i64).unsigned_abs() <= 5);
            }
        }
    }

    #[test]
    fn diag_dominant_property() {
        let m = diag_dominant(&unstructured_mesh::<f64>(8, 8, 0.5, 9));
        for i in 0..m.nrows() {
            let (cols, vals) = m.row(i);
            let mut diag = 0.0;
            let mut off = 0.0;
            for (&c, &v) in cols.iter().zip(vals) {
                if c as usize == i {
                    diag = v;
                } else {
                    off += v.abs();
                }
            }
            assert!(diag > off, "row {i}: {diag} <= {off}");
        }
    }

    #[test]
    fn generators_deterministic() {
        let a = circuit::<f64>(500, 3, 0.05, 42);
        let b = circuit::<f64>(500, 3, 0.05, 42);
        assert_eq!(a.col_idx, b.col_idx);
        assert_eq!(a.vals, b.vals);
    }
}
