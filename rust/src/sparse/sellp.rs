//! SELL-P — sliced ELLPACK with padding (Anzt, Tomov & Dongarra 2014,
//! paper ref [2]). Rows are grouped into slices of `slice_height` (the
//! warp size, 32); each slice stores its own width = max row nnz in the
//! slice, column-major within the slice. EHYB's in-partition part is a
//! SELL-P layout whose slices are additionally sorted by descending row
//! nnz *within each partition* and whose column indices are partition-
//! local u16.

use super::csr::Csr;
use super::ell::PAD;
use super::scalar::Scalar;
use crate::util::lanes::{lane_width, Pack};

#[derive(Clone, Debug)]
pub struct SellP<S: Scalar> {
    nrows: usize,
    ncols: usize,
    pub slice_height: usize,
    /// Start offset (in elements) of each slice in `cols`/`vals`;
    /// `len = num_slices + 1`. Matches the paper's `PositionELL`.
    pub slice_ptr: Vec<u32>,
    /// Width (max nnz) of each slice — the paper's `WidthELL`.
    pub slice_width: Vec<u32>,
    pub cols: Vec<u32>,
    pub vals: Vec<S>,
}

impl<S: Scalar> SellP<S> {
    pub fn from_csr(csr: &Csr<S>, slice_height: usize) -> Self {
        let nrows = csr.nrows();
        let num_slices = nrows.div_ceil(slice_height);
        let mut slice_width = vec![0u32; num_slices];
        for s in 0..num_slices {
            let lo = s * slice_height;
            let hi = (lo + slice_height).min(nrows);
            slice_width[s] = (lo..hi).map(|i| csr.row_nnz(i)).max().unwrap_or(0) as u32;
        }
        let mut slice_ptr = vec![0u32; num_slices + 1];
        for s in 0..num_slices {
            slice_ptr[s + 1] = slice_ptr[s] + slice_width[s] * slice_height as u32;
        }
        let total = slice_ptr[num_slices] as usize;
        let mut cols = vec![PAD; total];
        let mut vals = vec![S::ZERO; total];
        for s in 0..num_slices {
            let lo = s * slice_height;
            let hi = (lo + slice_height).min(nrows);
            let base = slice_ptr[s] as usize;
            for i in lo..hi {
                let (rc, rv) = csr.row(i);
                let lane = i - lo;
                for (k, (&c, &v)) in rc.iter().zip(rv).enumerate() {
                    cols[base + k * slice_height + lane] = c;
                    vals[base + k * slice_height + lane] = v;
                }
            }
        }
        Self { nrows, ncols: csr.ncols(), slice_height, slice_ptr, slice_width, cols, vals }
    }

    pub fn nrows(&self) -> usize {
        self.nrows
    }
    pub fn ncols(&self) -> usize {
        self.ncols
    }
    pub fn num_slices(&self) -> usize {
        self.slice_width.len()
    }

    pub fn nnz(&self) -> usize {
        self.cols.iter().filter(|&&c| c != PAD).count()
    }

    /// Stored slots / nnz — the padding overhead the descending-nnz
    /// reorder in EHYB minimizes.
    pub fn fill_ratio(&self) -> f64 {
        let nnz = self.nnz();
        if nnz == 0 {
            return 1.0;
        }
        self.cols.len() as f64 / nnz as f64
    }

    /// SpMV dispatching on the crate's `simd` feature. Both legs are
    /// always compiled; see [`Self::spmv_scalar`] / [`Self::spmv_simd`].
    pub fn spmv(&self, x: &[S], y: &mut [S]) {
        if cfg!(feature = "simd") {
            self.spmv_simd(x, y)
        } else {
            self.spmv_scalar(x, y)
        }
    }

    /// Reference walk: one lane at a time, pad slots skipped by branch.
    pub fn spmv_scalar(&self, x: &[S], y: &mut [S]) {
        assert_eq!(x.len(), self.ncols);
        assert_eq!(y.len(), self.nrows);
        let h = self.slice_height;
        for s in 0..self.num_slices() {
            let base = self.slice_ptr[s] as usize;
            let w = self.slice_width[s] as usize;
            let lo = s * h;
            let hi = (lo + h).min(self.nrows);
            for i in lo..hi {
                let lane = i - lo;
                let mut acc = S::ZERO;
                for k in 0..w {
                    let c = self.cols[base + k * h + lane];
                    if c != PAD {
                        acc = self.vals[base + k * h + lane].mul_add(x[c as usize], acc);
                    }
                }
                y[i] = acc;
            }
        }
    }

    /// Lane-packed walk: `W` adjacent slice lanes advance together down
    /// the slice's k columns, pad slots handled branch-free by the
    /// `+0.0`-fma identity (bitwise equal to [`Self::spmv_scalar`] for
    /// finite `x` — each row keeps its own k-ordered fused chain).
    pub fn spmv_simd(&self, x: &[S], y: &mut [S]) {
        match lane_width(S::BYTES) {
            16 => self.spmv_packed::<16>(x, y),
            8 => self.spmv_packed::<8>(x, y),
            4 => self.spmv_packed::<4>(x, y),
            _ => self.spmv_packed::<2>(x, y),
        }
    }

    fn spmv_packed<const W: usize>(&self, x: &[S], y: &mut [S]) {
        assert_eq!(x.len(), self.ncols);
        assert_eq!(y.len(), self.nrows);
        let h = self.slice_height;
        for s in 0..self.num_slices() {
            let base = self.slice_ptr[s] as usize;
            let w = self.slice_width[s] as usize;
            let lo = s * h;
            let nlanes = (lo + h).min(self.nrows) - lo;
            let mut lane = 0;
            while lane + W <= nlanes {
                let mut acc = Pack::<S, W>::ZERO;
                for k in 0..w {
                    let off = base + k * h + lane;
                    let vals = Pack::load(&self.vals[off..off + W]);
                    let xg = Pack::gather_u32_pad0(x, &self.cols[off..off + W], PAD);
                    acc = vals.mul_add(xg, acc);
                }
                acc.store(&mut y[lo + lane..lo + lane + W]);
                lane += W;
            }
            for l in lane..nlanes {
                let mut acc = S::ZERO;
                for k in 0..w {
                    let c = self.cols[base + k * h + l];
                    if c != PAD {
                        acc = self.vals[base + k * h + l].mul_add(x[c as usize], acc);
                    }
                }
                y[lo + l] = acc;
            }
        }
    }

    pub fn bytes(&self) -> usize {
        self.slice_ptr.len() * 4
            + self.slice_width.len() * 4
            + self.cols.len() * 4
            + self.vals.len() * S::BYTES
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::coo::Coo;
    use crate::util::Xoshiro256;

    fn random_csr(n: usize, seed: u64) -> Csr<f64> {
        let mut rng = Xoshiro256::new(seed);
        let mut coo = Coo::new(n, n);
        for i in 0..n {
            let deg = 1 + rng.next_below(9);
            for _ in 0..deg {
                coo.push(i, rng.next_below(n), rng.range_f64(-1.0, 1.0));
            }
        }
        coo.to_csr()
    }

    #[test]
    fn spmv_matches_csr_various_heights() {
        let csr = random_csr(100, 42);
        let x: Vec<f64> = (0..100).map(|i| (i as f64).sin()).collect();
        let mut y_ref = vec![0.0; 100];
        csr.spmv(&x, &mut y_ref);
        for &h in &[1usize, 4, 32, 64, 128] {
            let s = SellP::from_csr(&csr, h);
            let mut y = vec![0.0; 100];
            s.spmv(&x, &mut y);
            for i in 0..100 {
                assert!((y[i] - y_ref[i]).abs() < 1e-12, "h={h} i={i}");
            }
        }
    }

    #[test]
    fn simd_walk_bit_identical_to_scalar() {
        // Heights that are multiples of W, below W, and non-multiples
        // all exercise the packed main loop + scalar tail split.
        for &(n, h, seed) in &[(100usize, 32usize, 42u64), (97, 8, 5), (33, 3, 11), (64, 64, 2)] {
            let csr = random_csr(n, seed);
            let s = SellP::from_csr(&csr, h);
            let x: Vec<f64> = (0..n).map(|i| ((i * 13 + 1) % 29) as f64 * 0.125 - 1.5).collect();
            let mut y_s = vec![0.0; n];
            let mut y_v = vec![0.0; n];
            s.spmv_scalar(&x, &mut y_s);
            s.spmv_simd(&x, &mut y_v);
            assert_eq!(y_s, y_v, "n={n} h={h}");
        }
    }

    #[test]
    fn slice_count() {
        let csr = random_csr(100, 1);
        let s = SellP::from_csr(&csr, 32);
        assert_eq!(s.num_slices(), 4); // ceil(100/32)
    }

    #[test]
    fn nnz_preserved() {
        let csr = random_csr(64, 7);
        let s = SellP::from_csr(&csr, 32);
        assert_eq!(s.nnz(), csr.nnz());
    }

    #[test]
    fn fill_ratio_at_least_one() {
        let csr = random_csr(64, 9);
        let s = SellP::from_csr(&csr, 32);
        assert!(s.fill_ratio() >= 1.0);
    }

    #[test]
    fn per_slice_width_less_than_global() {
        // A matrix with one long row: SELL-P should only pad one slice.
        let mut coo = Coo::<f64>::new(64, 64);
        for j in 0..32 {
            coo.push(0, j, 1.0);
        }
        for i in 1..64 {
            coo.push(i, i, 1.0);
        }
        let s = SellP::from_csr(&coo.to_csr(), 32);
        assert_eq!(s.slice_width[0], 32);
        assert_eq!(s.slice_width[1], 1);
    }
}
