//! Matrix Market (`.mtx`) I/O — the SuiteSparse interchange format the
//! paper's 94-matrix corpus ships in. Supports `coordinate` matrices with
//! `real` / `integer` / `pattern` fields and `general` / `symmetric` /
//! `skew-symmetric` symmetries (the FEM corpus uses all of these).

use super::coo::Coo;
use super::scalar::Scalar;
use std::io::{BufRead, BufReader, BufWriter, Write};
use std::path::Path;

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Field {
    Real,
    Integer,
    Pattern,
}

#[derive(Clone, Copy, Debug, PartialEq, Eq)]
enum Symmetry {
    General,
    Symmetric,
    SkewSymmetric,
}

/// Read a Matrix Market coordinate file into COO (symmetric storage is
/// expanded). Malformed input — unparseable tokens, non-finite values,
/// out-of-range indices, duplicate coordinates (including symmetric
/// mirrors) — is a typed [`crate::EhybError::Parse`] carrying the
/// 1-based line number, so a corrupt corpus file names its own bad line
/// instead of poisoning the matrix.
pub fn read_matrix_market<S: Scalar, P: AsRef<Path>>(path: P) -> crate::Result<Coo<S>> {
    let file = std::fs::File::open(path.as_ref())
        .map_err(|e| crate::EhybError::Io(format!("open {:?}: {e}", path.as_ref())))?;
    read_matrix_market_from(BufReader::new(file))
}

/// Typed, line-numbered entry rejection.
fn entry_err(lineno: usize, what: impl std::fmt::Display) -> crate::EhybError {
    crate::EhybError::Parse(format!("line {lineno}: {what}"))
}

/// Read from any buffered reader (unit-testable without files).
pub fn read_matrix_market_from<S: Scalar, R: BufRead>(mut r: R) -> crate::Result<Coo<S>> {
    let mut lineno = 1usize;
    let mut header = String::new();
    r.read_line(&mut header)?;
    let h: Vec<&str> = header.trim().split_whitespace().collect();
    crate::ensure!(
        h.len() >= 5 && h[0] == "%%MatrixMarket" && h[1] == "matrix",
        "bad MatrixMarket header: {header:?}"
    );
    if h[2] != "coordinate" {
        return Err(crate::EhybError::UnsupportedFormat(format!(
            "only coordinate format supported, got {}",
            h[2]
        )));
    }
    let field = match h[3] {
        "real" => Field::Real,
        "integer" => Field::Integer,
        "pattern" => Field::Pattern,
        other => {
            return Err(crate::EhybError::UnsupportedFormat(format!("field type {other}")))
        }
    };
    let symmetry = match h[4] {
        "general" => Symmetry::General,
        "symmetric" => Symmetry::Symmetric,
        "skew-symmetric" => Symmetry::SkewSymmetric,
        other => {
            return Err(crate::EhybError::UnsupportedFormat(format!("symmetry {other}")))
        }
    };

    // Skip comments, read the size line.
    let mut line = String::new();
    loop {
        line.clear();
        crate::ensure!(r.read_line(&mut line)? > 0, "EOF before size line");
        lineno += 1;
        let t = line.trim();
        if !t.is_empty() && !t.starts_with('%') {
            break;
        }
    }
    let dims: Vec<usize> = line
        .trim()
        .split_whitespace()
        .map(|t| t.parse::<usize>())
        .collect::<Result<_, _>>()
        .map_err(|e| entry_err(lineno, format!("bad size line {:?}: {e}", line.trim())))?;
    if dims.len() != 3 {
        return Err(entry_err(lineno, "size line must have 3 fields"));
    }
    let (nrows, ncols, nnz) = (dims[0], dims[1], dims[2]);

    let mut coo = Coo::with_capacity(nrows, ncols, nnz * 2);
    // Every coordinate this file may occupy, symmetric mirrors
    // included: a duplicate would silently double a value under the old
    // sum-duplicates policy, so it is rejected with its line number.
    let mut occupied = std::collections::HashSet::with_capacity(nnz * 2);
    let mut seen = 0usize;
    loop {
        line.clear();
        if r.read_line(&mut line)? == 0 {
            break;
        }
        lineno += 1;
        let t = line.trim();
        if t.is_empty() || t.starts_with('%') {
            continue;
        }
        let mut it = t.split_whitespace();
        let i: usize = it
            .next()
            .ok_or_else(|| entry_err(lineno, "missing row index"))?
            .parse()
            .map_err(|e| entry_err(lineno, format!("bad row index: {e}")))?;
        let j: usize = it
            .next()
            .ok_or_else(|| entry_err(lineno, "missing column index"))?
            .parse()
            .map_err(|e| entry_err(lineno, format!("bad column index: {e}")))?;
        let v = match field {
            Field::Pattern => S::ONE,
            _ => {
                let tok =
                    it.next().ok_or_else(|| entry_err(lineno, "missing value"))?;
                let f: f64 = tok
                    .parse()
                    .map_err(|e| entry_err(lineno, format!("bad value {tok:?}: {e}")))?;
                if !f.is_finite() {
                    return Err(entry_err(
                        lineno,
                        format!("non-finite value {f} at ({i},{j})"),
                    ));
                }
                S::from_f64(f)
            }
        };
        if !(i >= 1 && i <= nrows && j >= 1 && j <= ncols) {
            return Err(entry_err(
                lineno,
                format!("entry ({i},{j}) outside {nrows}x{ncols}"),
            ));
        }
        let (r0, c0) = (i - 1, j - 1);
        if !occupied.insert((r0, c0)) {
            return Err(entry_err(lineno, format!("duplicate entry ({i},{j})")));
        }
        coo.push(r0, c0, v);
        if symmetry != Symmetry::General && r0 != c0 {
            if !occupied.insert((c0, r0)) {
                return Err(entry_err(
                    lineno,
                    format!("duplicate entry ({i},{j}): mirror ({j},{i}) already present"),
                ));
            }
            let mv = if symmetry == Symmetry::Symmetric { v } else { -v };
            coo.push(c0, r0, mv);
        }
        seen += 1;
    }
    crate::ensure!(seen == nnz, "expected {nnz} entries, read {seen}");
    Ok(coo)
}

/// Write COO as a `general real` coordinate Matrix Market file.
pub fn write_matrix_market<S: Scalar, P: AsRef<Path>>(m: &Coo<S>, path: P) -> crate::Result<()> {
    let file = std::fs::File::create(path)?;
    let mut w = BufWriter::new(file);
    writeln!(w, "%%MatrixMarket matrix coordinate real general")?;
    writeln!(w, "% generated by ehyb (EHYB SpMV reproduction)")?;
    writeln!(w, "{} {} {}", m.nrows(), m.ncols(), m.nnz())?;
    for k in 0..m.nnz() {
        writeln!(w, "{} {} {:e}", m.rows[k] + 1, m.cols[k] + 1, m.vals[k].to_f64())?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::Cursor;

    #[test]
    fn parse_general_real() {
        let txt = "%%MatrixMarket matrix coordinate real general\n% comment\n3 3 2\n1 1 1.5\n3 2 -2.0\n";
        let m: Coo<f64> = read_matrix_market_from(Cursor::new(txt)).unwrap();
        assert_eq!((m.nrows(), m.ncols(), m.nnz()), (3, 3, 2));
        let x = [1.0, 1.0, 1.0];
        let mut y = [0.0; 3];
        m.spmv(&x, &mut y);
        assert_eq!(y, [1.5, 0.0, -2.0]);
    }

    #[test]
    fn parse_symmetric_expands() {
        let txt = "%%MatrixMarket matrix coordinate real symmetric\n2 2 2\n1 1 4.0\n2 1 1.0\n";
        let m: Coo<f64> = read_matrix_market_from(Cursor::new(txt)).unwrap();
        assert_eq!(m.nnz(), 3); // diag + both off-diag
        let csr = m.to_csr();
        let (c0, v0) = csr.row(0);
        assert_eq!(c0, &[0, 1]);
        assert_eq!(v0, &[4.0, 1.0]);
    }

    #[test]
    fn parse_skew_symmetric() {
        let txt = "%%MatrixMarket matrix coordinate real skew-symmetric\n2 2 1\n2 1 3.0\n";
        let m: Coo<f64> = read_matrix_market_from(Cursor::new(txt)).unwrap();
        let csr = m.to_csr();
        let (_, v0) = csr.row(0);
        assert_eq!(v0, &[-3.0]);
    }

    #[test]
    fn parse_pattern() {
        let txt = "%%MatrixMarket matrix coordinate pattern general\n2 2 2\n1 2\n2 1\n";
        let m: Coo<f32> = read_matrix_market_from(Cursor::new(txt)).unwrap();
        assert_eq!(m.vals, vec![1.0f32, 1.0]);
    }

    #[test]
    fn rejects_bad_header() {
        let txt = "%%NotMatrixMarket\n1 1 0\n";
        assert!(read_matrix_market_from::<f64, _>(Cursor::new(txt)).is_err());
    }

    #[test]
    fn rejects_array_format() {
        let txt = "%%MatrixMarket matrix array real general\n2 2\n1.0\n";
        assert!(read_matrix_market_from::<f64, _>(Cursor::new(txt)).is_err());
    }

    #[test]
    fn rejects_truncated() {
        let txt = "%%MatrixMarket matrix coordinate real general\n2 2 3\n1 1 1.0\n";
        assert!(read_matrix_market_from::<f64, _>(Cursor::new(txt)).is_err());
    }

    #[test]
    fn rejects_out_of_range() {
        let txt = "%%MatrixMarket matrix coordinate real general\n2 2 1\n3 1 1.0\n";
        assert!(read_matrix_market_from::<f64, _>(Cursor::new(txt)).is_err());
    }

    fn parse_error_of(txt: &str) -> String {
        match read_matrix_market_from::<f64, _>(Cursor::new(txt)) {
            Err(crate::EhybError::Parse(msg)) => msg,
            other => panic!("expected EhybError::Parse, got {other:?}"),
        }
    }

    #[test]
    fn rejects_nonfinite_value_with_line_number() {
        // "inf" and "NaN" both parse as f64 — the finiteness check has
        // to catch them explicitly, naming the offending line.
        let txt = "%%MatrixMarket matrix coordinate real general\n3 3 2\n1 1 1.0\n2 2 inf\n";
        let msg = parse_error_of(txt);
        assert!(msg.contains("line 4") && msg.contains("non-finite"), "{msg}");
        let txt = "%%MatrixMarket matrix coordinate real general\n3 3 1\n1 1 NaN\n";
        let msg = parse_error_of(txt);
        assert!(msg.contains("line 3") && msg.contains("non-finite"), "{msg}");
    }

    #[test]
    fn rejects_unparseable_tokens_with_line_number() {
        let txt = "%%MatrixMarket matrix coordinate real general\n2 2 1\n1 x 1.0\n";
        let msg = parse_error_of(txt);
        assert!(msg.contains("line 3") && msg.contains("column index"), "{msg}");
        let txt = "%%MatrixMarket matrix coordinate real general\n2 2 1\n1 1 abc\n";
        let msg = parse_error_of(txt);
        assert!(msg.contains("line 3") && msg.contains("\"abc\""), "{msg}");
        let txt = "%%MatrixMarket matrix coordinate real general\n2 2 1\n1 1\n";
        let msg = parse_error_of(txt);
        assert!(msg.contains("line 3") && msg.contains("missing value"), "{msg}");
    }

    #[test]
    fn out_of_range_error_names_its_line() {
        let txt = "%%MatrixMarket matrix coordinate real general\n2 2 2\n1 1 1.0\n1 5 2.0\n";
        let msg = parse_error_of(txt);
        assert!(msg.contains("line 4") && msg.contains("(1,5)"), "{msg}");
    }

    #[test]
    fn rejects_duplicate_entries_with_line_number() {
        let txt = "%%MatrixMarket matrix coordinate real general\n2 2 2\n1 1 1.0\n1 1 2.0\n";
        let msg = parse_error_of(txt);
        assert!(msg.contains("line 4") && msg.contains("duplicate"), "{msg}");
    }

    #[test]
    fn rejects_symmetric_mirror_collision() {
        // A symmetric file carrying both triangles: the second entry
        // collides with the first one's expanded mirror.
        let txt = "%%MatrixMarket matrix coordinate real symmetric\n2 2 2\n2 1 1.0\n1 2 2.0\n";
        let msg = parse_error_of(txt);
        assert!(msg.contains("line 4") && msg.contains("duplicate"), "{msg}");
    }

    #[test]
    fn roundtrip_through_file() {
        let m = Coo::from_triplets(3, 3, vec![(0, 0, 1.25), (1, 2, -0.5), (2, 1, 3.0)]).unwrap();
        let dir = std::env::temp_dir().join("ehyb_mmio_test");
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("rt.mtx");
        write_matrix_market(&m, &path).unwrap();
        let m2: Coo<f64> = read_matrix_market(&path).unwrap();
        assert_eq!(m2.nnz(), 3);
        let x = [1.0, 2.0, 3.0];
        let mut y1 = [0.0; 3];
        let mut y2 = [0.0; 3];
        m.spmv(&x, &mut y1);
        m2.spmv(&x, &mut y2);
        assert_eq!(y1, y2);
        std::fs::remove_file(path).ok();
    }
}
