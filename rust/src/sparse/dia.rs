//! DIA (diagonal) format — stores dense diagonals. Only efficient for
//! structured-stencil matrices; included as the structured-case contrast
//! baseline from Bell & Garland 2009 and for validating the Poisson
//! generators (whose stencils are exactly banded).

use super::csr::Csr;
use super::scalar::Scalar;

#[derive(Clone, Debug)]
pub struct Dia<S: Scalar> {
    nrows: usize,
    ncols: usize,
    /// Diagonal offsets, ascending (0 = main, negative = sub).
    pub offsets: Vec<i64>,
    /// `data[d * nrows + i]` = A[i, i + offsets[d]].
    pub data: Vec<S>,
}

impl<S: Scalar> Dia<S> {
    /// Build from CSR. Returns `None` when the number of occupied
    /// diagonals exceeds `max_diags` (format unsuitable).
    pub fn from_csr(csr: &Csr<S>, max_diags: usize) -> Option<Self> {
        let mut offsets: Vec<i64> = Vec::new();
        for i in 0..csr.nrows() {
            let (cols, _) = csr.row(i);
            for &c in cols {
                let off = c as i64 - i as i64;
                if let Err(pos) = offsets.binary_search(&off) {
                    offsets.insert(pos, off);
                    if offsets.len() > max_diags {
                        return None;
                    }
                }
            }
        }
        let nrows = csr.nrows();
        let mut data = vec![S::ZERO; offsets.len() * nrows];
        for i in 0..nrows {
            let (cols, vals) = csr.row(i);
            for (&c, &v) in cols.iter().zip(vals) {
                let off = c as i64 - i as i64;
                let d = offsets.binary_search(&off).unwrap();
                data[d * nrows + i] = v;
            }
        }
        Some(Self { nrows, ncols: csr.ncols(), offsets, data })
    }

    pub fn nrows(&self) -> usize {
        self.nrows
    }
    pub fn num_diags(&self) -> usize {
        self.offsets.len()
    }

    pub fn spmv(&self, x: &[S], y: &mut [S]) {
        assert_eq!(x.len(), self.ncols);
        assert_eq!(y.len(), self.nrows);
        y.fill(S::ZERO);
        for (d, &off) in self.offsets.iter().enumerate() {
            let base = d * self.nrows;
            let lo = (-off).max(0) as usize;
            let hi = self.nrows.min((self.ncols as i64 - off).max(0) as usize);
            for i in lo..hi {
                let j = (i as i64 + off) as usize;
                y[i] = self.data[base + i].mul_add(x[j], y[i]);
            }
        }
    }

    pub fn bytes(&self) -> usize {
        self.offsets.len() * 8 + self.data.len() * S::BYTES
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::gen::poisson1d;

    #[test]
    fn tridiagonal_has_three_diags() {
        let csr = poisson1d::<f64>(16);
        let dia = Dia::from_csr(&csr, 8).unwrap();
        assert_eq!(dia.num_diags(), 3);
        assert_eq!(dia.offsets, vec![-1, 0, 1]);
    }

    #[test]
    fn spmv_matches_csr() {
        let csr = poisson1d::<f64>(50);
        let dia = Dia::from_csr(&csr, 8).unwrap();
        let x: Vec<f64> = (0..50).map(|i| (i as f64 * 0.1).cos()).collect();
        let mut y1 = vec![0.0; 50];
        let mut y2 = vec![0.0; 50];
        csr.spmv(&x, &mut y1);
        dia.spmv(&x, &mut y2);
        for i in 0..50 {
            assert!((y1[i] - y2[i]).abs() < 1e-12);
        }
    }

    #[test]
    fn unsuitable_matrix_rejected() {
        use crate::sparse::coo::Coo;
        use crate::util::Xoshiro256;
        let mut rng = Xoshiro256::new(3);
        let mut coo = Coo::<f64>::new(64, 64);
        for i in 0..64 {
            for _ in 0..4 {
                coo.push(i, rng.next_below(64), 1.0);
            }
        }
        assert!(Dia::from_csr(&coo.to_csr(), 8).is_none());
    }
}
