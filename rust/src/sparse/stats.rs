//! Matrix structure statistics — used by the harness reports and to
//! verify that the synthetic corpus matches the paper's categories
//! (nnz/row distributions, bandwidth, symmetry).

use super::csr::Csr;
use super::scalar::Scalar;
use crate::util::stats::Summary;

#[derive(Clone, Debug)]
pub struct MatrixStats {
    pub nrows: usize,
    pub ncols: usize,
    pub nnz: usize,
    pub row_nnz: Summary,
    pub empty_rows: usize,
    /// Max |col - row| over all entries.
    pub bandwidth: usize,
    /// Average |col - row| — a locality proxy.
    pub mean_band: f64,
    /// Fraction of entries with a structural mirror (1.0 = structurally
    /// symmetric).
    pub structural_symmetry: f64,
}

impl MatrixStats {
    pub fn of<S: Scalar>(m: &Csr<S>) -> Self {
        let n = m.nrows();
        let lens: Vec<f64> = (0..n).map(|i| m.row_nnz(i) as f64).collect();
        let mut bandwidth = 0usize;
        let mut band_sum = 0f64;
        for i in 0..n {
            let (cols, _) = m.row(i);
            for &c in cols {
                let d = (c as i64 - i as i64).unsigned_abs() as usize;
                bandwidth = bandwidth.max(d);
                band_sum += d as f64;
            }
        }
        // Structural symmetry via transpose comparison.
        let t = m.transpose();
        let mut mirrored = 0usize;
        for i in 0..n.min(m.ncols()) {
            let (a, _) = m.row(i);
            let (b, _) = t.row(i);
            // Count intersection of two sorted lists.
            let (mut p, mut q) = (0, 0);
            while p < a.len() && q < b.len() {
                match a[p].cmp(&b[q]) {
                    std::cmp::Ordering::Less => p += 1,
                    std::cmp::Ordering::Greater => q += 1,
                    std::cmp::Ordering::Equal => {
                        mirrored += 1;
                        p += 1;
                        q += 1;
                    }
                }
            }
        }
        MatrixStats {
            nrows: n,
            ncols: m.ncols(),
            nnz: m.nnz(),
            row_nnz: Summary::of(&lens).unwrap_or(Summary {
                n: 0,
                min: 0.0,
                max: 0.0,
                mean: 0.0,
                geomean: 0.0,
                median: 0.0,
                stddev: 0.0,
            }),
            empty_rows: lens.iter().filter(|&&l| l == 0.0).count(),
            bandwidth,
            mean_band: if m.nnz() == 0 { 0.0 } else { band_sum / m.nnz() as f64 },
            structural_symmetry: if m.nnz() == 0 { 1.0 } else { mirrored as f64 / m.nnz() as f64 },
        }
    }

    /// One-line report used by `ehyb info`.
    pub fn oneline(&self) -> String {
        format!(
            "n={} nnz={} nnz/row(avg={:.1},max={:.0},sd={:.1}) bw={} sym={:.2}",
            self.nrows,
            self.nnz,
            self.row_nnz.mean,
            self.row_nnz.max,
            self.row_nnz.stddev,
            self.bandwidth,
            self.structural_symmetry
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::gen::{poisson2d, circuit};

    #[test]
    fn poisson_stats() {
        let s = MatrixStats::of(&poisson2d::<f64>(10, 10));
        assert_eq!(s.nrows, 100);
        assert_eq!(s.bandwidth, 10);
        assert!((s.structural_symmetry - 1.0).abs() < 1e-12);
        assert_eq!(s.empty_rows, 0);
        assert_eq!(s.row_nnz.max, 5.0);
    }

    #[test]
    fn circuit_not_symmetric() {
        let s = MatrixStats::of(&circuit::<f64>(500, 3, 0.05, 1));
        assert!(s.structural_symmetry < 1.0);
    }

    #[test]
    fn oneline_contains_fields() {
        let s = MatrixStats::of(&poisson2d::<f64>(4, 4));
        let line = s.oneline();
        assert!(line.contains("n=16"));
        assert!(line.contains("bw=4"));
    }
}
