//! Classic HYB = ELL + COO tail (Bell & Garland 2009; the cuSPARSE HYB
//! format). The width is chosen so that the ELL part covers most entries
//! and pathological long rows spill to COO. EHYB replaces the "ELL +
//! spill" split with "in-partition + out-of-partition".

use super::coo::Coo;
use super::csr::Csr;
use super::ell::Ell;
use super::scalar::Scalar;

#[derive(Clone, Debug)]
pub struct Hyb<S: Scalar> {
    pub ell: Ell<S>,
    pub coo: Coo<S>,
}

impl<S: Scalar> Hyb<S> {
    /// Split at `width`: first `width` entries of each row go to ELL, the
    /// rest to COO.
    pub fn from_csr_with_width(csr: &Csr<S>, width: usize) -> Self {
        let nrows = csr.nrows();
        // Truncate each row to `width` for the ELL part.
        let mut ell_rowptr = vec![0u32; nrows + 1];
        let mut ell_cols = Vec::new();
        let mut ell_vals = Vec::new();
        let mut coo = Coo::new(nrows, csr.ncols());
        for i in 0..nrows {
            let (cols, vals) = csr.row(i);
            let cut = cols.len().min(width);
            ell_cols.extend_from_slice(&cols[..cut]);
            ell_vals.extend_from_slice(&vals[..cut]);
            ell_rowptr[i + 1] = ell_rowptr[i] + cut as u32;
            for (&c, &v) in cols[cut..].iter().zip(&vals[cut..]) {
                coo.push(i, c as usize, v);
            }
        }
        let ell_csr = Csr::from_raw(nrows, csr.ncols(), ell_rowptr, ell_cols, ell_vals);
        Hyb { ell: Ell::from_csr_with_width(&ell_csr, width), coo }
    }

    /// cuSPARSE-style automatic width: the largest k such that at least
    /// `threshold` (e.g. 2/3) of rows have ≥ k entries — equivalently a
    /// quantile of the nnz/row distribution.
    pub fn from_csr_auto(csr: &Csr<S>, threshold: f64) -> Self {
        let mut lens: Vec<usize> = (0..csr.nrows()).map(|i| csr.row_nnz(i)).collect();
        lens.sort_unstable();
        let idx = ((csr.nrows() as f64) * (1.0 - threshold)) as usize;
        let width = if lens.is_empty() { 0 } else { lens[idx.min(lens.len() - 1)] };
        Self::from_csr_with_width(csr, width.max(1))
    }

    pub fn nnz(&self) -> usize {
        self.ell.nnz() + self.coo.nnz()
    }

    /// Inherits the ELL part's `simd`-feature dispatch; the irregular
    /// COO tail stays scalar on every leg (sorted row-major, so its
    /// accumulation order is fixed either way).
    pub fn spmv(&self, x: &[S], y: &mut [S]) {
        self.ell.spmv(x, y);
        self.coo_tail(x, y);
    }

    /// Explicit scalar twin: ELL scalar leg + scalar COO tail.
    pub fn spmv_scalar(&self, x: &[S], y: &mut [S]) {
        self.ell.spmv_scalar(x, y);
        self.coo_tail(x, y);
    }

    /// Explicit SIMD twin: ELL packed leg + the same scalar COO tail.
    /// Bitwise equal to [`Self::spmv_scalar`] for finite `x` (the ELL
    /// legs are; the tail is shared).
    pub fn spmv_simd(&self, x: &[S], y: &mut [S]) {
        self.ell.spmv_simd(x, y);
        self.coo_tail(x, y);
    }

    /// COO part accumulates on top of the ELL result.
    fn coo_tail(&self, x: &[S], y: &mut [S]) {
        for i in 0..self.coo.nnz() {
            let r = self.coo.rows[i] as usize;
            let c = self.coo.cols[i] as usize;
            y[r] = self.coo.vals[i].mul_add(x[c], y[r]);
        }
    }

    pub fn bytes(&self) -> usize {
        self.ell.bytes() + self.coo.nnz() * (8 + S::BYTES)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::coo::Coo;

    fn skewed() -> Csr<f64> {
        // Row 0 has 5 entries, rows 1-3 have 1 each.
        let mut t = vec![(0usize, 0usize, 1.0), (0, 1, 2.0), (0, 2, 3.0), (0, 3, 4.0), (0, 4, 5.0)];
        t.push((1, 1, 6.0));
        t.push((2, 2, 7.0));
        t.push((3, 3, 8.0));
        Coo::from_triplets(4, 5, t).unwrap().to_csr()
    }

    #[test]
    fn split_counts() {
        let h = Hyb::from_csr_with_width(&skewed(), 1);
        assert_eq!(h.ell.nnz(), 4);
        assert_eq!(h.coo.nnz(), 4);
        assert_eq!(h.nnz(), 8);
    }

    #[test]
    fn spmv_matches_csr() {
        let csr = skewed();
        for width in 1..=5 {
            let h = Hyb::from_csr_with_width(&csr, width);
            let x = [1.0, 2.0, 3.0, 4.0, 5.0];
            let mut y1 = [0.0; 4];
            let mut y2 = [0.0; 4];
            csr.spmv(&x, &mut y1);
            h.spmv(&x, &mut y2);
            assert_eq!(y1, y2, "width={width}");
        }
    }

    #[test]
    fn simd_twin_bit_identical() {
        let csr = skewed();
        for width in 1..=5 {
            let h = Hyb::from_csr_with_width(&csr, width);
            let x = [1.5, -2.0, 3.0, 0.25, -0.5];
            let mut y_s = [0.0; 4];
            let mut y_v = [0.0; 4];
            h.spmv_scalar(&x, &mut y_s);
            h.spmv_simd(&x, &mut y_v);
            assert_eq!(y_s, y_v, "width={width}");
        }
    }

    #[test]
    fn auto_width_reasonable() {
        let h = Hyb::from_csr_auto(&skewed(), 2.0 / 3.0);
        // 3 of 4 rows have exactly 1 entry => width 1.
        assert_eq!(h.ell.width(), 1);
    }

    #[test]
    fn uniform_matrix_no_coo() {
        let m = Coo::from_triplets(3, 3, vec![(0, 0, 1.0), (1, 1, 1.0), (2, 2, 1.0)])
            .unwrap()
            .to_csr();
        let h = Hyb::from_csr_auto(&m, 2.0 / 3.0);
        assert_eq!(h.coo.nnz(), 0);
    }
}
