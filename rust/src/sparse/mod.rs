//! Sparse-matrix substrate: element trait, storage formats, conversions,
//! Matrix-Market I/O, synthetic FEM-style generators, and structure
//! statistics.
//!
//! Formats implemented (all from the SpMV-on-GPU literature the paper
//! builds on — Bell & Garland 2009, SELL-P, EHYB itself):
//!
//! | module   | format | role in the paper |
//! |----------|--------|-------------------|
//! | [`coo`]  | coordinate | interchange / input format (Algorithm 1 input) |
//! | [`csr`]  | compressed sparse row | baseline engines, cuSPARSE analogues |
//! | [`ell`]  | ELLPACK | HYB building block |
//! | [`hyb`]  | ELL + COO hybrid | classic HYB the paper's name riffs on |
//! | [`sellp`]| sliced ELL, padded | the layout EHYB's in-partition part extends |
//! | [`dia`]  | diagonal | structured-stencil contrast baseline |
//! | [`ehyb`] | EHYB storage proper | the paper's format (built by [`crate::preprocess`]) |

pub mod scalar;
pub mod coo;
pub mod csr;
pub mod ell;
pub mod hyb;
pub mod sellp;
pub mod dia;
pub mod ehyb;
pub mod mmio;
pub mod gen;
pub mod stats;

pub use coo::Coo;
pub use csr::Csr;
pub use dia::Dia;
pub use ehyb::EhybMatrix;
pub use ell::Ell;
pub use hyb::Hyb;
pub use scalar::Scalar;
pub use sellp::SellP;
