//! Element trait abstracting f32/f64 — the paper evaluates both
//! precisions (its Tables 1 and 2), and the u16-column optimization saves
//! a different fraction of traffic for each (25 % vs 13.3 %), so every
//! engine and model in the crate is generic over [`Scalar`].

use std::fmt::{Debug, Display};
use std::ops::{Add, AddAssign, Div, Mul, MulAssign, Neg, Sub, SubAssign};

/// Floating-point element type for matrices and vectors.
pub trait Scalar:
    Copy
    + Send
    + Sync
    + PartialOrd
    + PartialEq
    + Debug
    + Display
    + Default
    + Add<Output = Self>
    + Sub<Output = Self>
    + Mul<Output = Self>
    + Div<Output = Self>
    + Neg<Output = Self>
    + AddAssign
    + SubAssign
    + MulAssign
    + 'static
{
    const ZERO: Self;
    const ONE: Self;
    /// Bytes per element (the paper's τ in equation (1)).
    const BYTES: usize;
    /// Name used for artifact filenames and reports: `"f32"` / `"f64"`.
    const NAME: &'static str;

    fn from_f64(v: f64) -> Self;
    fn to_f64(self) -> f64;
    fn abs(self) -> Self;
    fn sqrt(self) -> Self;
    /// Fused multiply-add (`self * a + b`); the SpMV inner loop.
    fn mul_add(self, a: Self, b: Self) -> Self;
}

impl Scalar for f32 {
    const ZERO: Self = 0.0;
    const ONE: Self = 1.0;
    const BYTES: usize = 4;
    const NAME: &'static str = "f32";

    #[inline]
    fn from_f64(v: f64) -> Self {
        v as f32
    }
    #[inline]
    fn to_f64(self) -> f64 {
        self as f64
    }
    #[inline]
    fn abs(self) -> Self {
        f32::abs(self)
    }
    #[inline]
    fn sqrt(self) -> Self {
        f32::sqrt(self)
    }
    #[inline]
    fn mul_add(self, a: Self, b: Self) -> Self {
        f32::mul_add(self, a, b)
    }
}

impl Scalar for f64 {
    const ZERO: Self = 0.0;
    const ONE: Self = 1.0;
    const BYTES: usize = 8;
    const NAME: &'static str = "f64";

    #[inline]
    fn from_f64(v: f64) -> Self {
        v
    }
    #[inline]
    fn to_f64(self) -> f64 {
        self
    }
    #[inline]
    fn abs(self) -> Self {
        f64::abs(self)
    }
    #[inline]
    fn sqrt(self) -> Self {
        f64::sqrt(self)
    }
    #[inline]
    fn mul_add(self, a: Self, b: Self) -> Self {
        f64::mul_add(self, a, b)
    }
}

/// Dense dot product — used by the iterative solvers.
pub fn dot<S: Scalar>(a: &[S], b: &[S]) -> S {
    debug_assert_eq!(a.len(), b.len());
    let mut acc = S::ZERO;
    for (&x, &y) in a.iter().zip(b) {
        acc = x.mul_add(y, acc);
    }
    acc
}

/// Euclidean norm.
pub fn norm2<S: Scalar>(a: &[S]) -> S {
    dot(a, a).sqrt()
}

/// `y += alpha * x`.
pub fn axpy<S: Scalar>(alpha: S, x: &[S], y: &mut [S]) {
    debug_assert_eq!(x.len(), y.len());
    for (yi, &xi) in y.iter_mut().zip(x) {
        *yi = alpha.mul_add(xi, *yi);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn constants() {
        assert_eq!(f32::BYTES, 4);
        assert_eq!(f64::BYTES, 8);
        assert_eq!(f32::NAME, "f32");
        assert_eq!(f64::NAME, "f64");
    }

    #[test]
    fn roundtrip_f64() {
        assert_eq!(f64::from_f64(1.5).to_f64(), 1.5);
        assert_eq!(f32::from_f64(1.5).to_f64(), 1.5);
    }

    #[test]
    fn dot_and_norm() {
        let a = [3.0f64, 4.0];
        assert_eq!(dot(&a, &a), 25.0);
        assert_eq!(norm2(&a), 5.0);
    }

    #[test]
    fn axpy_works() {
        let x = [1.0f32, 2.0];
        let mut y = [10.0f32, 20.0];
        axpy(2.0, &x, &mut y);
        assert_eq!(y, [12.0, 24.0]);
    }

    #[test]
    fn mul_add_fused() {
        assert_eq!(2.0f64.mul_add(3.0, 4.0), 10.0);
    }
}
