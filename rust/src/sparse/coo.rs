//! Coordinate (COO) format — the interchange format. Paper Algorithm 1
//! takes a COO matrix as input; Matrix-Market files are COO by nature.

use super::csr::Csr;
use super::scalar::Scalar;

/// Coordinate-format sparse matrix. Triplets need not be sorted;
/// duplicates are allowed until [`Coo::sum_duplicates`] is called.
#[derive(Clone, Debug)]
pub struct Coo<S: Scalar> {
    nrows: usize,
    ncols: usize,
    pub rows: Vec<u32>,
    pub cols: Vec<u32>,
    pub vals: Vec<S>,
}

impl<S: Scalar> Coo<S> {
    pub fn new(nrows: usize, ncols: usize) -> Self {
        Self { nrows, ncols, rows: Vec::new(), cols: Vec::new(), vals: Vec::new() }
    }

    pub fn with_capacity(nrows: usize, ncols: usize, nnz: usize) -> Self {
        Self {
            nrows,
            ncols,
            rows: Vec::with_capacity(nnz),
            cols: Vec::with_capacity(nnz),
            vals: Vec::with_capacity(nnz),
        }
    }

    /// Build from triplets, validating bounds.
    pub fn from_triplets(
        nrows: usize,
        ncols: usize,
        triplets: impl IntoIterator<Item = (usize, usize, S)>,
    ) -> crate::Result<Self> {
        let mut m = Coo::new(nrows, ncols);
        for (r, c, v) in triplets {
            crate::ensure!(r < nrows && c < ncols, "entry ({r},{c}) out of bounds {nrows}x{ncols}");
            m.push(r, c, v);
        }
        Ok(m)
    }

    #[inline]
    pub fn push(&mut self, row: usize, col: usize, val: S) {
        debug_assert!(row < self.nrows && col < self.ncols);
        self.rows.push(row as u32);
        self.cols.push(col as u32);
        self.vals.push(val);
    }

    pub fn nrows(&self) -> usize {
        self.nrows
    }
    pub fn ncols(&self) -> usize {
        self.ncols
    }
    pub fn nnz(&self) -> usize {
        self.vals.len()
    }

    /// Sort triplets by (row, col). Stable with respect to duplicate
    /// coordinates (insertion order preserved).
    pub fn sort(&mut self) {
        let mut idx: Vec<u32> = (0..self.nnz() as u32).collect();
        idx.sort_by_key(|&i| (self.rows[i as usize], self.cols[i as usize], i));
        self.permute(&idx);
    }

    fn permute(&mut self, idx: &[u32]) {
        self.rows = idx.iter().map(|&i| self.rows[i as usize]).collect();
        self.cols = idx.iter().map(|&i| self.cols[i as usize]).collect();
        self.vals = idx.iter().map(|&i| self.vals[i as usize]).collect();
    }

    /// Sort and merge duplicate coordinates by summation (Matrix-Market
    /// symmetric expansion can produce duplicates on the diagonal).
    pub fn sum_duplicates(&mut self) {
        if self.nnz() == 0 {
            return;
        }
        self.sort();
        let mut w = 0usize;
        for r in 1..self.nnz() {
            if self.rows[r] == self.rows[w] && self.cols[r] == self.cols[w] {
                let v = self.vals[r];
                self.vals[w] += v;
            } else {
                w += 1;
                self.rows[w] = self.rows[r];
                self.cols[w] = self.cols[r];
                self.vals[w] = self.vals[r];
            }
        }
        self.rows.truncate(w + 1);
        self.cols.truncate(w + 1);
        self.vals.truncate(w + 1);
    }

    /// Convert to CSR (sorts + merges duplicates first).
    pub fn to_csr(&self) -> Csr<S> {
        let mut m = self.clone();
        m.sum_duplicates();
        let mut row_ptr = vec![0u32; self.nrows + 1];
        for &r in &m.rows {
            row_ptr[r as usize + 1] += 1;
        }
        for i in 0..self.nrows {
            row_ptr[i + 1] += row_ptr[i];
        }
        Csr::from_raw(self.nrows, self.ncols, row_ptr, m.cols, m.vals)
    }

    /// Reference SpMV: `y = A * x`. O(nnz); order-of-accumulation follows
    /// triplet order.
    pub fn spmv(&self, x: &[S], y: &mut [S]) {
        assert_eq!(x.len(), self.ncols);
        assert_eq!(y.len(), self.nrows);
        y.fill(S::ZERO);
        for i in 0..self.nnz() {
            let r = self.rows[i] as usize;
            let c = self.cols[i] as usize;
            y[r] = self.vals[i].mul_add(x[c], y[r]);
        }
    }

    /// Transpose (swaps row/col indices).
    pub fn transpose(&self) -> Coo<S> {
        Coo {
            nrows: self.ncols,
            ncols: self.nrows,
            rows: self.cols.clone(),
            cols: self.rows.clone(),
            vals: self.vals.clone(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Coo<f64> {
        // [[1, 0, 2],
        //  [0, 3, 0],
        //  [4, 0, 5]]
        let t = vec![(0, 0, 1.0), (2, 2, 5.0), (0, 2, 2.0), (1, 1, 3.0), (2, 0, 4.0)];
        Coo::from_triplets(3, 3, t).unwrap()
    }

    #[test]
    fn dims_and_nnz() {
        let m = sample();
        assert_eq!((m.nrows(), m.ncols(), m.nnz()), (3, 3, 5));
    }

    #[test]
    fn bounds_checked() {
        assert!(Coo::<f64>::from_triplets(2, 2, vec![(2, 0, 1.0)]).is_err());
        assert!(Coo::<f64>::from_triplets(2, 2, vec![(0, 2, 1.0)]).is_err());
    }

    #[test]
    fn spmv_reference() {
        let m = sample();
        let x = [1.0, 2.0, 3.0];
        let mut y = [0.0; 3];
        m.spmv(&x, &mut y);
        assert_eq!(y, [7.0, 6.0, 19.0]);
    }

    #[test]
    fn sort_orders_triplets() {
        let mut m = sample();
        m.sort();
        let coords: Vec<(u32, u32)> = m.rows.iter().zip(&m.cols).map(|(&r, &c)| (r, c)).collect();
        let mut sorted = coords.clone();
        sorted.sort();
        assert_eq!(coords, sorted);
    }

    #[test]
    fn sum_duplicates_merges() {
        let mut m = Coo::from_triplets(2, 2, vec![(0, 0, 1.0), (0, 0, 2.0), (1, 1, 3.0)]).unwrap();
        m.sum_duplicates();
        assert_eq!(m.nnz(), 2);
        assert_eq!(m.vals[0], 3.0);
    }

    #[test]
    fn to_csr_matches_spmv() {
        let m = sample();
        let csr = m.to_csr();
        let x = [0.5, -1.0, 2.0];
        let mut y1 = [0.0; 3];
        let mut y2 = [0.0; 3];
        m.spmv(&x, &mut y1);
        csr.spmv(&x, &mut y2);
        assert_eq!(y1, y2);
    }

    #[test]
    fn transpose_roundtrip() {
        let m = sample();
        let t = m.transpose().transpose();
        let x = [1.0, 1.0, 1.0];
        let mut y1 = [0.0; 3];
        let mut y2 = [0.0; 3];
        m.spmv(&x, &mut y1);
        t.spmv(&x, &mut y2);
        assert_eq!(y1, y2);
    }

    #[test]
    fn empty_matrix() {
        let m = Coo::<f32>::new(4, 4);
        let x = [1.0f32; 4];
        let mut y = [9.0f32; 4];
        m.spmv(&x, &mut y);
        assert_eq!(y, [0.0; 4]);
        assert_eq!(m.to_csr().nnz(), 0);
    }
}
