//! Compressed Sparse Row — the workhorse format. All baseline GPU-kernel
//! models (cuSPARSE ALG1/ALG2 analogues, merge-based, CSR5-like) and the
//! EHYB preprocessing pipeline consume CSR.

use super::coo::Coo;
use super::scalar::Scalar;

/// CSR matrix with u32 indices (the paper's matrices all fit; ≤ 4.29 G
/// rows/nnz per array — `stokes`, the largest, has 349 M nnz).
#[derive(Clone, Debug)]
pub struct Csr<S: Scalar> {
    nrows: usize,
    ncols: usize,
    pub row_ptr: Vec<u32>,
    pub col_idx: Vec<u32>,
    pub vals: Vec<S>,
}

impl<S: Scalar> Csr<S> {
    /// Assemble from raw parts. `row_ptr` must be monotone with
    /// `row_ptr[0] == 0` and `row_ptr[nrows] == nnz`; `col_idx[k]` are
    /// filled into their row slots in input order (counting sort).
    pub(crate) fn from_raw(
        nrows: usize,
        ncols: usize,
        row_ptr: Vec<u32>,
        sorted_cols: Vec<u32>,
        sorted_vals: Vec<S>,
    ) -> Self {
        debug_assert_eq!(row_ptr.len(), nrows + 1);
        debug_assert_eq!(*row_ptr.last().unwrap() as usize, sorted_cols.len());
        Self { nrows, ncols, row_ptr, col_idx: sorted_cols, vals: sorted_vals }
    }

    /// Validated constructor from components.
    pub fn new(
        nrows: usize,
        ncols: usize,
        row_ptr: Vec<u32>,
        col_idx: Vec<u32>,
        vals: Vec<S>,
    ) -> crate::Result<Self> {
        crate::ensure!(row_ptr.len() == nrows + 1, "row_ptr length");
        crate::ensure!(row_ptr[0] == 0, "row_ptr[0] != 0");
        crate::ensure!(
            row_ptr.windows(2).all(|w| w[0] <= w[1]),
            "row_ptr not monotone"
        );
        crate::ensure!(*row_ptr.last().unwrap() as usize == col_idx.len(), "nnz mismatch");
        crate::ensure!(col_idx.len() == vals.len(), "col/val length mismatch");
        crate::ensure!(col_idx.iter().all(|&c| (c as usize) < ncols), "col out of bounds");
        Ok(Self { nrows, ncols, row_ptr, col_idx, vals })
    }

    pub fn nrows(&self) -> usize {
        self.nrows
    }
    pub fn ncols(&self) -> usize {
        self.ncols
    }
    pub fn nnz(&self) -> usize {
        self.vals.len()
    }

    /// Row `i`'s (cols, vals) slices.
    #[inline]
    pub fn row(&self, i: usize) -> (&[u32], &[S]) {
        let lo = self.row_ptr[i] as usize;
        let hi = self.row_ptr[i + 1] as usize;
        (&self.col_idx[lo..hi], &self.vals[lo..hi])
    }

    #[inline]
    pub fn row_nnz(&self, i: usize) -> usize {
        (self.row_ptr[i + 1] - self.row_ptr[i]) as usize
    }

    pub fn max_row_nnz(&self) -> usize {
        (0..self.nrows).map(|i| self.row_nnz(i)).max().unwrap_or(0)
    }

    /// Reference row-major SpMV: `y = A x`.
    pub fn spmv(&self, x: &[S], y: &mut [S]) {
        assert_eq!(x.len(), self.ncols);
        assert_eq!(y.len(), self.nrows);
        for i in 0..self.nrows {
            let (cols, vals) = self.row(i);
            let mut acc = S::ZERO;
            for (&c, &v) in cols.iter().zip(vals) {
                acc = v.mul_add(x[c as usize], acc);
            }
            y[i] = acc;
        }
    }

    /// Dense `y = A x` in f64 regardless of S — the high-precision oracle
    /// the test-suite compares every engine against.
    pub fn spmv_f64_oracle(&self, x: &[S]) -> Vec<f64> {
        let mut y = vec![0.0f64; self.nrows];
        for i in 0..self.nrows {
            let (cols, vals) = self.row(i);
            let mut acc = 0.0f64;
            for (&c, &v) in cols.iter().zip(vals) {
                acc += v.to_f64() * x[c as usize].to_f64();
            }
            y[i] = acc;
        }
        y
    }

    pub fn to_coo(&self) -> Coo<S> {
        let mut m = Coo::with_capacity(self.nrows, self.ncols, self.nnz());
        for i in 0..self.nrows {
            let (cols, vals) = self.row(i);
            for (&c, &v) in cols.iter().zip(vals) {
                m.push(i, c as usize, v);
            }
        }
        m
    }

    /// Transpose via counting sort: O(nnz + n).
    pub fn transpose(&self) -> Csr<S> {
        let mut cnt = vec![0u32; self.ncols + 1];
        for &c in &self.col_idx {
            cnt[c as usize + 1] += 1;
        }
        for i in 0..self.ncols {
            cnt[i + 1] += cnt[i];
        }
        let row_ptr = cnt.clone();
        let mut col_idx = vec![0u32; self.nnz()];
        let mut vals = vec![S::ZERO; self.nnz()];
        let mut next = cnt;
        for i in 0..self.nrows {
            let (cols, vs) = self.row(i);
            for (&c, &v) in cols.iter().zip(vs) {
                let slot = next[c as usize] as usize;
                next[c as usize] += 1;
                col_idx[slot] = i as u32;
                vals[slot] = v;
            }
        }
        Csr { nrows: self.ncols, ncols: self.nrows, row_ptr, col_idx, vals }
    }

    /// Structural symmetrization `A ∪ Aᵀ` with values from A where present
    /// (values of the transpose only fill structural holes). Used to build
    /// the undirected partitioning graph of Algorithm 1 for non-symmetric
    /// matrices.
    pub fn symmetrize_structure(&self) -> Csr<S> {
        assert_eq!(self.nrows, self.ncols, "symmetrize requires square");
        let t = self.transpose();
        let mut coo = Coo::with_capacity(self.nrows, self.ncols, self.nnz() * 2);
        for i in 0..self.nrows {
            let (cols, vals) = self.row(i);
            for (&c, &v) in cols.iter().zip(vals) {
                coo.push(i, c as usize, v);
            }
            let (tcols, _) = t.row(i);
            for &c in tcols {
                // Push a structural zero; sum_duplicates keeps the value
                // from A when both exist (0 + v = v).
                coo.push(i, c as usize, S::ZERO);
            }
        }
        coo.to_csr()
    }

    /// Extract the diagonal (missing entries are zero).
    pub fn diagonal(&self) -> Vec<S> {
        let mut d = vec![S::ZERO; self.nrows.min(self.ncols)];
        for i in 0..d.len() {
            let (cols, vals) = self.row(i);
            for (&c, &v) in cols.iter().zip(vals) {
                if c as usize == i {
                    d[i] = v;
                }
            }
        }
        d
    }

    /// Permute rows and columns symmetrically: `B = P A Pᵀ` where
    /// `perm[old] = new`. Used by reordering ablations.
    pub fn permute_symmetric(&self, perm: &[u32]) -> Csr<S> {
        assert_eq!(perm.len(), self.nrows);
        assert_eq!(self.nrows, self.ncols);
        let mut coo = Coo::with_capacity(self.nrows, self.ncols, self.nnz());
        for i in 0..self.nrows {
            let (cols, vals) = self.row(i);
            let ni = perm[i] as usize;
            for (&c, &v) in cols.iter().zip(vals) {
                coo.push(ni, perm[c as usize] as usize, v);
            }
        }
        coo.to_csr()
    }

    /// Symmetric permutation `B = P A Pᵀ` (`perm[old] = new`, a
    /// bijection) that preserves the **within-row entry order** of `A`:
    /// row `perm[i]` of `B` holds row `i`'s entries in their original
    /// relative order with columns mapped through `perm` — so `B`'s
    /// columns are generally *unsorted* within a row. Every row-local
    /// SpMV engine accumulates a row in stored-entry order, so an
    /// engine built on `B` runs bit-identical per-row FMA chains to one
    /// built on `A` (with `x`/`y` permuted accordingly) — the contract
    /// the [`crate::reorder`] round-trip tests pin.
    /// [`Csr::permute_symmetric`] (COO round-trip) re-sorts columns and
    /// stays for callers that need canonical order.
    pub fn permute_symmetric_stable(&self, perm: &[u32]) -> Csr<S> {
        assert_eq!(perm.len(), self.nrows);
        assert_eq!(self.nrows, self.ncols, "symmetric permutation requires square");
        let n = self.nrows;
        let mut iperm = vec![u32::MAX; n];
        for (old, &new) in perm.iter().enumerate() {
            debug_assert!(
                iperm[new as usize] == u32::MAX,
                "perm is not a bijection: new index {new} assigned twice"
            );
            iperm[new as usize] = old as u32;
        }
        let mut row_ptr = vec![0u32; n + 1];
        for new in 0..n {
            row_ptr[new + 1] = row_ptr[new] + self.row_nnz(iperm[new] as usize) as u32;
        }
        let mut col_idx = Vec::with_capacity(self.nnz());
        let mut vals = Vec::with_capacity(self.nnz());
        for &old in &iperm {
            let (cols, vs) = self.row(old as usize);
            col_idx.extend(cols.iter().map(|&c| perm[c as usize]));
            vals.extend_from_slice(vs);
        }
        Csr { nrows: n, ncols: n, row_ptr, col_idx, vals }
    }

    /// Extract rows `lo..hi` as a standalone (generally rectangular)
    /// CSR over the **same column space**: row `i` of the slice is row
    /// `lo + i` of `self`, entries in identical order. The building
    /// block of the row-sharding layer ([`crate::shard`]) — because the
    /// entry order within every row is preserved, any engine whose
    /// per-row accumulation depends only on that row's entries computes
    /// bit-identical results on the slice.
    pub fn row_slice(&self, lo: usize, hi: usize) -> Csr<S> {
        assert!(lo <= hi && hi <= self.nrows, "bad row slice {lo}..{hi} of {}", self.nrows);
        let base = self.row_ptr[lo];
        let end = self.row_ptr[hi] as usize;
        let row_ptr: Vec<u32> = self.row_ptr[lo..=hi].iter().map(|&p| p - base).collect();
        Csr {
            nrows: hi - lo,
            ncols: self.ncols,
            row_ptr,
            col_idx: self.col_idx[base as usize..end].to_vec(),
            vals: self.vals[base as usize..end].to_vec(),
        }
    }

    /// Split rows `lo..hi` into the **square diagonal block** (entries
    /// whose column also falls in `lo..hi`, columns rebased to the
    /// block) and the **halo remainder** (entries whose column lies
    /// outside, kept in the full column space). Within every row the
    /// relative entry order of each part is preserved. This is the
    /// shard-level analogue of EHYB's in-partition / out-of-partition
    /// split: the block's x-slice is the shard's hot working set, the
    /// halo is its uncached remainder.
    pub fn diag_block_split(&self, lo: usize, hi: usize) -> (Csr<S>, Csr<S>) {
        assert!(lo <= hi && hi <= self.nrows, "bad row range {lo}..{hi} of {}", self.nrows);
        let rows = hi - lo;
        let mut block_ptr = vec![0u32; rows + 1];
        let mut block_cols = Vec::new();
        let mut block_vals = Vec::new();
        let mut halo_ptr = vec![0u32; rows + 1];
        let mut halo_cols = Vec::new();
        let mut halo_vals = Vec::new();
        for r in 0..rows {
            let (cols, vals) = self.row(lo + r);
            for (&c, &v) in cols.iter().zip(vals) {
                if (lo..hi).contains(&(c as usize)) {
                    block_cols.push(c - lo as u32);
                    block_vals.push(v);
                } else {
                    halo_cols.push(c);
                    halo_vals.push(v);
                }
            }
            block_ptr[r + 1] = block_cols.len() as u32;
            halo_ptr[r + 1] = halo_cols.len() as u32;
        }
        let block = Csr {
            nrows: rows,
            ncols: rows,
            row_ptr: block_ptr,
            col_idx: block_cols,
            vals: block_vals,
        };
        let halo = Csr {
            nrows: rows,
            ncols: self.ncols,
            row_ptr: halo_ptr,
            col_idx: halo_cols,
            vals: halo_vals,
        };
        (block, halo)
    }

    /// Memory footprint in bytes (index + value arrays) — input to the
    /// traffic models.
    pub fn bytes(&self) -> usize {
        self.row_ptr.len() * 4 + self.col_idx.len() * 4 + self.vals.len() * S::BYTES
    }

    /// Cast values to another scalar type (f64 suite → f32 runs).
    pub fn cast<T: Scalar>(&self) -> Csr<T> {
        Csr {
            nrows: self.nrows,
            ncols: self.ncols,
            row_ptr: self.row_ptr.clone(),
            col_idx: self.col_idx.clone(),
            vals: self.vals.iter().map(|v| T::from_f64(v.to_f64())).collect(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Csr<f64> {
        // [[1, 0, 2],
        //  [0, 3, 0],
        //  [4, 0, 5]]
        let t = vec![(0, 0, 1.0), (0, 2, 2.0), (1, 1, 3.0), (2, 0, 4.0), (2, 2, 5.0)];
        Coo::from_triplets(3, 3, t).unwrap().to_csr()
    }

    #[test]
    fn construction_validates() {
        assert!(Csr::<f64>::new(2, 2, vec![0, 1, 2], vec![0, 1], vec![1.0, 2.0]).is_ok());
        // Non-monotone row_ptr.
        assert!(Csr::<f64>::new(2, 2, vec![0, 3, 2], vec![0, 1], vec![1.0, 2.0]).is_err());
        // Column out of bounds.
        assert!(Csr::<f64>::new(2, 2, vec![0, 1, 2], vec![0, 5], vec![1.0, 2.0]).is_err());
        assert!(Csr::<f64>::new(2, 2, vec![0, 1], vec![0], vec![1.0]).is_err()); // row_ptr len
    }

    #[test]
    fn row_access() {
        let m = sample();
        let (cols, vals) = m.row(0);
        assert_eq!(cols, &[0, 2]);
        assert_eq!(vals, &[1.0, 2.0]);
        assert_eq!(m.row_nnz(1), 1);
        assert_eq!(m.max_row_nnz(), 2);
    }

    #[test]
    fn spmv_matches_dense() {
        let m = sample();
        let x = [1.0, 2.0, 3.0];
        let mut y = [0.0; 3];
        m.spmv(&x, &mut y);
        assert_eq!(y, [7.0, 6.0, 19.0]);
    }

    #[test]
    fn transpose_correct() {
        let m = sample();
        let t = m.transpose();
        // Column 0 of A = [1, 0, 4] => row 0 of T.
        let (cols, vals) = t.row(0);
        assert_eq!(cols, &[0, 2]);
        assert_eq!(vals, &[1.0, 4.0]);
        // (Ax, y) == (x, A^T y)
        let x = [1.0, 2.0, 3.0];
        let y = [4.0, 5.0, 6.0];
        let mut ax = [0.0; 3];
        m.spmv(&x, &mut ax);
        let mut aty = [0.0; 3];
        t.spmv(&y, &mut aty);
        let lhs: f64 = ax.iter().zip(&y).map(|(a, b)| a * b).sum();
        let rhs: f64 = x.iter().zip(&aty).map(|(a, b)| a * b).sum();
        assert!((lhs - rhs).abs() < 1e-12);
    }

    #[test]
    fn symmetrize_makes_symmetric() {
        let m = sample();
        let s = m.symmetrize_structure();
        let t = s.transpose();
        // Structure of s must equal structure of its transpose.
        assert_eq!(s.row_ptr, t.row_ptr);
        assert_eq!(s.col_idx, t.col_idx);
        // Values from A preserved.
        let (cols, vals) = s.row(0);
        let pos = cols.iter().position(|&c| c == 2).unwrap();
        assert_eq!(vals[pos], 2.0);
    }

    #[test]
    fn diagonal_extraction() {
        let m = sample();
        assert_eq!(m.diagonal(), vec![1.0, 3.0, 5.0]);
    }

    #[test]
    fn permute_symmetric_preserves_spmv() {
        let m = sample();
        let perm = [2u32, 0, 1]; // old->new
        let p = m.permute_symmetric(&perm);
        // y_new[perm[i]] should equal y_old[i] when x permuted likewise.
        let x = [1.0, 2.0, 3.0];
        let mut xp = [0.0; 3];
        for i in 0..3 {
            xp[perm[i] as usize] = x[i];
        }
        let mut y = [0.0; 3];
        m.spmv(&x, &mut y);
        let mut yp = [0.0; 3];
        p.spmv(&xp, &mut yp);
        for i in 0..3 {
            assert!((yp[perm[i] as usize] - y[i]).abs() < 1e-12);
        }
    }

    #[test]
    fn permute_symmetric_stable_preserves_row_entry_order() {
        let m = sample();
        let perm = [2u32, 0, 1]; // old->new
        let p = m.permute_symmetric_stable(&perm);
        // Old row 2 ([4 at col 0, 5 at col 2]) lands at new row 1 with
        // its entries in the ORIGINAL order, columns mapped: col 0 -> 2,
        // col 2 -> 1 (unsorted — that is the point).
        let (cols, vals) = p.row(1);
        assert_eq!(cols, &[2, 1]);
        assert_eq!(vals, &[4.0, 5.0]);
        // Same linear operator as the sorted permute.
        let x = [1.0, 2.0, 3.0];
        let mut xp = [0.0; 3];
        for i in 0..3 {
            xp[perm[i] as usize] = x[i];
        }
        let mut y = [0.0; 3];
        m.spmv(&x, &mut y);
        let mut yp = [0.0; 3];
        p.spmv(&xp, &mut yp);
        for i in 0..3 {
            assert_eq!(yp[perm[i] as usize], y[i], "stable permute must be exact");
        }
        // Identity permutation reproduces the matrix verbatim.
        let id = m.permute_symmetric_stable(&[0, 1, 2]);
        assert_eq!(id.row_ptr, m.row_ptr);
        assert_eq!(id.col_idx, m.col_idx);
        assert_eq!(id.vals, m.vals);
    }

    #[test]
    fn oracle_matches_spmv_for_f64() {
        let m = sample();
        let x = [0.1, 0.2, 0.3];
        let mut y = [0.0; 3];
        m.spmv(&x, &mut y);
        let o = m.spmv_f64_oracle(&x);
        for i in 0..3 {
            assert!((y[i] - o[i]).abs() < 1e-12);
        }
    }

    #[test]
    fn cast_f32() {
        let m = sample().cast::<f32>();
        assert_eq!(m.vals[0], 1.0f32);
        assert_eq!(m.nnz(), 5);
    }

    #[test]
    fn bytes_accounting() {
        let m = sample();
        assert_eq!(m.bytes(), 4 * 4 + 5 * 4 + 5 * 8);
    }

    #[test]
    fn row_slice_preserves_rows_and_order() {
        let m = sample();
        let s = m.row_slice(1, 3);
        assert_eq!(s.nrows(), 2);
        assert_eq!(s.ncols(), 3);
        assert_eq!(s.nnz(), 3);
        let (cols, vals) = s.row(1); // row 2 of the original
        assert_eq!(cols, &[0, 2]);
        assert_eq!(vals, &[4.0, 5.0]);
        // Degenerate slices.
        assert_eq!(m.row_slice(0, 0).nnz(), 0);
        assert_eq!(m.row_slice(0, 3).nnz(), m.nnz());
    }

    #[test]
    fn row_slices_reassemble_spmv() {
        let m = sample();
        let x = [1.0, 2.0, 3.0];
        let mut y_full = [0.0; 3];
        m.spmv(&x, &mut y_full);
        let mut y = Vec::new();
        for (lo, hi) in [(0usize, 2usize), (2, 3)] {
            let s = m.row_slice(lo, hi);
            let mut part = vec![0.0; hi - lo];
            s.spmv(&x, &mut part);
            y.extend(part);
        }
        assert_eq!(y, y_full);
    }

    #[test]
    fn diag_block_split_partitions_entries() {
        let m = sample();
        let (block, halo) = m.diag_block_split(0, 2);
        // Rows 0..2: entries (0,0) (0,2) (1,1); cols < 2 stay in block.
        assert_eq!(block.nrows(), 2);
        assert_eq!(block.ncols(), 2);
        assert_eq!(block.nnz(), 2); // (0,0) and (1,1)
        assert_eq!(halo.nnz(), 1); // (0,2)
        assert_eq!(halo.ncols(), 3);
        // block + halo reassemble the slice's SpMV.
        let x = [1.0, 2.0, 3.0];
        let mut yb = [0.0; 2];
        block.spmv(&x[0..2], &mut yb);
        let mut yh = [0.0; 2];
        halo.spmv(&x, &mut yh);
        let mut y_full = [0.0; 3];
        m.spmv(&x, &mut y_full);
        for i in 0..2 {
            assert!((yb[i] + yh[i] - y_full[i]).abs() < 1e-15);
        }
        // Full-range split has an empty halo.
        let (b2, h2) = m.diag_block_split(0, 3);
        assert_eq!(b2.nnz(), m.nnz());
        assert_eq!(h2.nnz(), 0);
    }
}
