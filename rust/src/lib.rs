//! # EHYB — Explicit-Caching Hybrid SpMV framework
//!
//! Reproduction of *"Explicit caching HYB: a new high-performance SpMV
//! framework on GPGPU"* (Chong Chen, 2022) as a three-layer
//! Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — all host-side systems: sparse formats, the
//!   multilevel graph partitioner (METIS substitute), EHYB preprocessing
//!   (paper Algorithms 1–2), CPU baseline SpMV engines (single-vector,
//!   partition-parallel, and blocked multi-vector `spmv_batch`), a
//!   warp-level GPU simulator (V100 substitute), an analytic roofline
//!   model, the PJRT runtime that executes AOT-compiled kernels
//!   (feature `pjrt`), and the coordinator (request-fusing SpMV
//!   service + single- and multi-RHS iterative solvers).
//! * **L2 (python/compile/model.py)** — the JAX SpMV graph (sliced-ELL
//!   kernel + ER part + inverse permutation), lowered once to HLO text.
//! * **L1 (python/compile/kernels/ehyb.py)** — the Pallas kernel with the
//!   input-vector partition explicitly staged into VMEM (the TPU analogue
//!   of the paper's shared-memory cache).
//!
//! See `DESIGN.md` for the full system inventory and the per-experiment
//! index, and `EXPERIMENTS.md` for paper-vs-measured results.
//!
//! ## Quickstart
//!
//! ```no_run
//! // (no_run: doctest binaries don't inherit the rpath to the PJRT
//! // runtime libs in this offline image; the same flow is executed by
//! // rust/tests/integration.rs.)
//! use ehyb::sparse::gen::poisson2d;
//! use ehyb::preprocess::{EhybPlan, PreprocessConfig};
//! use ehyb::spmv::{SpmvEngine, ehyb_cpu::EhybCpu};
//!
//! let m = poisson2d::<f64>(32, 32); // 1024x1024 5-point stencil, CSR
//! let plan = EhybPlan::build(&m, &PreprocessConfig::default()).unwrap();
//! let x: Vec<f64> = (0..m.nrows()).map(|i| (i % 7) as f64).collect();
//! let engine = EhybCpu::new(&plan);
//! let mut y = vec![0.0; m.nrows()];
//! engine.spmv(&x, &mut y);
//! assert!(y.iter().all(|v| v.is_finite()));
//!
//! // Batched multi-vector SpMV: the blocked SpMM kernel streams the
//! // matrix once per register block instead of once per vector.
//! let xs: Vec<Vec<f64>> = (0..4)
//!     .map(|t| (0..m.nrows()).map(|i| ((i + t) % 5) as f64).collect())
//!     .collect();
//! let xrefs: Vec<&[f64]> = xs.iter().map(|v| v.as_slice()).collect();
//! let mut ys: Vec<Vec<f64>> = vec![Vec::new(); xrefs.len()];
//! engine.spmv_batch(&xrefs, &mut ys); // ys[i] = A * xs[i]
//! ```
//!
//! ## Tuning
//!
//! * **`EHYB_THREADS`** — worker-thread count for the partition-
//!   parallel SpMV/SpMM hot paths (and the preprocessing partitioner).
//!   Defaults to `min(cores, 16)`; resolved once and cached, override
//!   at runtime with [`util::par::set_num_threads`]. The parallel walk
//!   is bit-identical to the serial kernel at any thread count.
//! * **Batching** — prefer [`spmv::SpmvEngine::spmv_batch`] (or the
//!   service's request fusion / [`coordinator::cg_many`]) whenever
//!   several vectors share one matrix: SpMV is memory-bound, so batch
//!   width multiplies arithmetic intensity.

pub mod util;
pub mod sparse;
pub mod partition;
pub mod preprocess;
pub mod spmv;
pub mod gpu;
pub mod perfmodel;
pub mod runtime;
pub mod coordinator;
pub mod harness;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
