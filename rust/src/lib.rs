//! # EHYB — Explicit-Caching Hybrid SpMV framework
//!
//! Reproduction of *"Explicit caching HYB: a new high-performance SpMV
//! framework on GPGPU"* (Chong Chen, 2022) as a three-layer
//! Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — all host-side systems: sparse formats, the
//!   multilevel graph partitioner (METIS substitute), EHYB preprocessing
//!   (paper Algorithms 1–2), CPU baseline SpMV engines, a warp-level GPU
//!   simulator (V100 substitute), an analytic roofline model, the PJRT
//!   runtime that executes AOT-compiled kernels, and the coordinator
//!   (batched SpMV service + iterative solvers).
//! * **L2 (python/compile/model.py)** — the JAX SpMV graph (sliced-ELL
//!   kernel + ER part + inverse permutation), lowered once to HLO text.
//! * **L1 (python/compile/kernels/ehyb.py)** — the Pallas kernel with the
//!   input-vector partition explicitly staged into VMEM (the TPU analogue
//!   of the paper's shared-memory cache).
//!
//! See `DESIGN.md` for the full system inventory and the per-experiment
//! index, and `EXPERIMENTS.md` for paper-vs-measured results.
//!
//! ## Quickstart
//!
//! ```no_run
//! // (no_run: doctest binaries don't inherit the rpath to the PJRT
//! // runtime libs in this offline image; the same flow is executed by
//! // rust/tests/integration.rs.)
//! use ehyb::sparse::gen::poisson2d;
//! use ehyb::preprocess::{EhybPlan, PreprocessConfig};
//! use ehyb::spmv::{SpmvEngine, ehyb_cpu::EhybCpu};
//!
//! let m = poisson2d::<f64>(32, 32); // 1024x1024 5-point stencil, CSR
//! let plan = EhybPlan::build(&m, &PreprocessConfig::default()).unwrap();
//! let x: Vec<f64> = (0..m.nrows()).map(|i| (i % 7) as f64).collect();
//! let engine = EhybCpu::new(&plan);
//! let mut y = vec![0.0; m.nrows()];
//! engine.spmv(&x, &mut y);
//! assert!(y.iter().all(|v| v.is_finite()));
//! ```

pub mod util;
pub mod sparse;
pub mod partition;
pub mod preprocess;
pub mod spmv;
pub mod gpu;
pub mod perfmodel;
pub mod runtime;
pub mod coordinator;
pub mod harness;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
