//! # EHYB — Explicit-Caching Hybrid SpMV framework
//!
//! Reproduction of *"Explicit caching HYB: a new high-performance SpMV
//! framework on GPGPU"* (Chong Chen, 2022) as a three-layer
//! Rust + JAX + Pallas stack:
//!
//! * **L3 (this crate)** — all host-side systems: sparse formats, the
//!   multilevel graph partitioner (METIS substitute), EHYB preprocessing
//!   (paper Algorithms 1–2), CPU baseline SpMV engines (single-vector,
//!   partition-parallel, and blocked multi-vector `spmv_batch`), a
//!   warp-level GPU simulator (V100 substitute), an analytic roofline
//!   model, the PJRT runtime that executes AOT-compiled kernels
//!   (feature `pjrt`), and the coordinator (request-fusing SpMV
//!   service + single- and multi-RHS iterative solvers).
//! * **L2 (python/compile/model.py)** — the JAX SpMV graph (sliced-ELL
//!   kernel + ER part + inverse permutation), lowered once to HLO text.
//! * **L1 (python/compile/kernels/ehyb.py)** — the Pallas kernel with the
//!   input-vector partition explicitly staged into VMEM (the TPU analogue
//!   of the paper's shared-memory cache).
//!
//! See `DESIGN.md` for the full system inventory and the per-experiment
//! index, `EXPERIMENTS.md` for paper-vs-measured results, and
//! `MIGRATION.md` for the pre-facade → [`SpmvContext`] call mapping.
//!
//! ## Quickstart
//!
//! The whole pipeline — preprocess once (partition → reorder →
//! explicitly-cached format), execute many — lives behind one prepared
//! handle, [`SpmvContext`]:
//!
//! ```no_run
//! // (no_run: doctest binaries don't inherit the rpath to the PJRT
//! // runtime libs in this offline image; the same flow is executed by
//! // rust/tests/integration.rs and rust/tests/api.rs.)
//! use ehyb::sparse::gen::poisson2d;
//! use ehyb::{BatchBuf, EngineKind, SpmvContext, TuneLevel};
//!
//! let m = poisson2d::<f64>(32, 32); // 1024x1024 5-point stencil, CSR
//! let n = m.nrows();
//!
//! // Build once: runs Algorithms 1-2 and prepares the EHYB engine.
//! // `EngineKind::Auto` would instead pick the engine whose roofline
//! // bound wins on this matrix.
//! let ctx = SpmvContext::builder(m).engine(EngineKind::Ehyb).build()?;
//!
//! // Execute many: dimension-checked SpMV (typed EhybError instead of
//! // a panic on bad input lengths).
//! let x: Vec<f64> = (0..n).map(|i| (i % 7) as f64).collect();
//! let y = ctx.spmv_alloc(&x)?;
//! assert!(y.iter().all(|v| v.is_finite()));
//!
//! // Batched SpMV over ONE contiguous allocation: the blocked SpMM
//! // kernel streams the matrix once per register block instead of once
//! // per vector.
//! let mut xs = BatchBuf::<f64>::zeros(n, 4);
//! for b in 0..4 {
//!     for i in 0..n {
//!         xs.col_mut(b)[i] = ((i + b) % 5) as f64;
//!     }
//! }
//! let mut ys = BatchBuf::<f64>::zeros(n, 4);
//! {
//!     let mut ysv = ys.view_mut();
//!     ctx.spmv_batch(xs.view(), &mut ysv)?; // ys.col(b) = A * xs.col(b)
//! }
//!
//! // The same handle spawns the request-fusing service and drives the
//! // iterative solvers. The service queue is bounded: submissions past
//! // the bound shed with `EhybError::Overloaded` instead of growing an
//! // unbounded backlog (`serve_bounded` picks the bound explicitly).
//! let svc = ctx.serve(16)?; // SpmvService; svc.client().spmv(x) round-trips
//! let pre = ehyb::coordinator::Jacobi::new(ctx.matrix());
//! let cfg = ehyb::coordinator::SolverConfig::default();
//! let (sol, report) = ctx.solver().cg(&x, None, &pre, &cfg)?;
//! assert_eq!(sol.len(), n);
//! drop((svc, report));
//!
//! // OSKI-style autotuning: search the EHYB plan knobs (slice height,
//! // partition size vs. the scratchpad budget, ELL/ER width cutoff).
//! // Add `.plan_cache(dir)` (or set EHYB_TUNE_DIR) to persist the
//! // winner — keyed by matrix fingerprint x device x dtype x search
//! // scope — so a restarted process warm-starts with zero search.
//! let m2 = poisson2d::<f64>(32, 32);
//! let tuned = SpmvContext::builder(m2)
//!     .engine(EngineKind::Auto)              // also searches engine kind
//!     .tune(TuneLevel::measured())           // or TuneLevel::Heuristic
//!     .build()?;
//! let plan = tuned.tuned().expect("tuner-routed build");
//! assert!(plan.score_secs <= plan.default_score_secs); // never worse
//!
//! // Row-sharded execution: split the matrix into contiguous row
//! // shards (Auto = one per worker thread), one prepared engine per
//! // shard, every kernel fanning out shard-parallel with disjoint `y`
//! // ranges. See `examples/sharded.rs` for the full tour (per-shard
//! // tuning, per-shard metrics, sharded serving).
//! let m3 = poisson2d::<f64>(32, 32);
//! let ctx3 = SpmvContext::builder(m3).shards(ehyb::ShardSpec::Auto).build()?;
//! assert!(ctx3.shards() >= 1);
//! let y3 = ctx3.spmv_alloc(&x)?;
//! assert_eq!(y3.len(), n);
//!
//! // Global reordering: apply a locality-aware symmetric row/column
//! // ordering (RCM, partition-rank, or Auto = scored footprint
//! // reduction) AHEAD of the pipeline, so shard boundaries, the EHYB
//! // partitioner, and tuning fingerprints all see the improved
//! // locality. User-facing vectors stay in original index space.
//! let m4 = poisson2d::<f64>(32, 32);
//! let ctx4 = SpmvContext::builder(m4)
//!     .reorder(ehyb::ReorderSpec::Auto)
//!     .shards(ehyb::ShardSpec::Auto)
//!     .build()?;
//! let y4 = ctx4.spmv_alloc(&x)?; // same index space as x
//! assert_eq!(y4.len(), n);
//! # Ok::<(), ehyb::EhybError>(())
//! ```
//!
//! ## Tuning
//!
//! * **SIMD (`simd` feature, on by default)** — the hot kernels (EHYB
//!   ELL walk + ER tail, register-blocked SpMM, SELL-P, ELL, HYB's
//!   ELL part, the csr-vector warp model, CSR5) run lane-packed legs
//!   built on [`util::lanes::Pack`], a stable-Rust fixed-width pack
//!   LLVM auto-vectorizes; compile with `-C target-cpu=native` so fma
//!   lowers to hardware and the packed legs pay off. Lane-parallel
//!   kernels stay **bitwise identical** to the scalar reference walks
//!   for finite inputs (per-row fused chains are preserved); CSR5's
//!   two-phase leg matches to ~1e-9. `--no-default-features` restores
//!   scalar dispatch; both legs always compile and are callable
//!   explicitly (`*_scalar` / `*_simd`). Reordered EHYB contexts also
//!   **fuse** the adapter's x/y permutes with EHYB's internal
//!   permutation into one gather per side ([`spmv::PermutedSpmv`]) —
//!   bitwise identical to the two-pass route, minus two full vector
//!   passes per SpMV.
//! * **Autotuner** — `SpmvContext::builder(m).tune(level)` searches the
//!   EHYB plan space per matrix ([`autotune`]):
//!   [`TuneLevel::Heuristic`] ranks candidates by the [`perfmodel`]
//!   roofline bounds; [`TuneLevel::Measured`] microbenches the real
//!   candidate engines under a wall-clock budget. A tuned plan is
//!   adopted only if its score is no worse than the default plan's.
//!   **`EHYB_TUNE_DIR`** (or `.plan_cache(dir)`) names the persistent
//!   plan store — JSON, atomically written, keyed by structural
//!   fingerprint × device × scalar type — so restarts skip the search.
//! * **`EHYB_THREADS`** — worker-thread count for the partition-
//!   parallel SpMV/SpMM hot paths (and the preprocessing partitioner).
//!   Defaults to `min(cores, 16)`; resolved once and cached, override
//!   at runtime with [`util::par::set_num_threads`]. Both the parallel
//!   ELL walk and the parallel ER scatter are bit-identical to the
//!   serial kernel at any thread count.
//! * **Batching** — prefer [`SpmvContext::spmv_batch`] (or the
//!   service's request fusion / [`SpmvContext::solver`]'s `cg_many`)
//!   whenever several vectors share one matrix: SpMV is memory-bound,
//!   so batch width multiplies arithmetic intensity.
//! * **Sharding** — `builder(m).shards(ShardSpec::Auto)` splits the
//!   matrix into per-core row shards ([`shard`]): every kernel fans
//!   out shard-parallel, each shard's format + x working set sized for
//!   a private cache, and sharded EHYB builds tune + cache plans **per
//!   shard**. Row-local engines stay bit-identical to the unsharded
//!   kernel; see [`shard`] for the full contract.
//! * **Reordering** — `builder(m).reorder(ReorderSpec::Rcm)` (or
//!   `PartitionRank`/`Auto`) permutes the matrix symmetrically before
//!   anything else runs ([`reorder`]), shrinking bandwidth, the
//!   windowed cache footprint, and the cache-aware cross-shard cut.
//!   Row-local engines stay bit-identical (the permute preserves
//!   per-row entry order); tuned plans key on the reordered
//!   fingerprint, so cached winners survive restarts per ordering.
//! * **Traffic model** — [`traffic`] replays a prepared plan (EHYB
//!   partitions with their explicit x-slice cache, the baseline walks,
//!   shard halos) through a modeled shm/L2/DRAM hierarchy, producing
//!   per-level byte counters, x-reuse statistics, and a hit-aware
//!   `predicted_secs`. It is the **default `TuneLevel::Heuristic`
//!   oracle** (`.score_oracle(ScoreOracle::Roofline)` restores the
//!   0.6 static bounds) and the score behind [`ReorderSpec::Auto`];
//!   `cargo run --example traffic` prints the per-level tables and
//!   `ablation --which traffic` the per-engine comparison.
//!
//! ## Robustness
//!
//! The [`resilience`] layer hardens the serving path end to end; every
//! piece is opt-in and the defaults are bit-identical to 0.5:
//!
//! ```no_run
//! # // (no_run: same PJRT rpath caveat as the quickstart; the flow is
//! # // executed by rust/tests/resilience.rs.)
//! use ehyb::sparse::gen::poisson2d;
//! use ehyb::{EngineKind, GuardLevel, RetryPolicy, SpmvContext};
//!
//! let m = poisson2d::<f64>(32, 32);
//! let n = m.nrows();
//!
//! // Degraded mode + ingress guards: a failed EHYB build downgrades to
//! // the csr-vector baseline instead of failing (recorded, never
//! // silent), and non-finite inputs are rejected with a typed error
//! // before they can poison an iterate.
//! let ctx = SpmvContext::builder(m)
//!     .engine(EngineKind::Ehyb)
//!     .fallback(true)
//!     .guard(GuardLevel::Reject)
//!     .build()?;
//! assert!(ctx.health().healthy()); // no downgrade was needed here
//!
//! // Panic-isolated serving with deadlines and retry: an engine panic
//! // poisons exactly one fused batch (every request in it gets
//! // `EhybError::EngineFault`), the engine is respawned, and the
//! // service keeps serving — `svc.metrics.faults` / `respawns` /
//! // `deadline_misses` count it all.
//! let svc = ctx.serve(16)?;
//! let client = svc.client();
//! let x: Vec<f64> = (0..n).map(|i| (i % 7) as f64).collect();
//! let policy = RetryPolicy::default(); // bounded backoff, seeded jitter
//! let y = client.spmv_with_retry(x, &policy)?;
//! assert_eq!(y.len(), n);
//!
//! // A solve that breaks down or diverges restarts once with
//! // Jacobi-preconditioned BiCGSTAB (counted in ctx.health()).
//! let pre = ehyb::coordinator::Jacobi::new(ctx.matrix());
//! let cfg = ehyb::coordinator::SolverConfig {
//!     divergence_window: 3, // declare divergence after 3 growing iters
//!     ..Default::default()
//! };
//! let b = vec![1.0; n];
//! let (sol, report) = ctx.solver().cg(&b, None, &pre, &cfg)?;
//! assert!(report.converged()); // report.status is the typed outcome
//! drop((svc, sol));
//! # Ok::<(), ehyb::EhybError>(())
//! ```
//!
//! The deterministic chaos harness ([`resilience::FaultPlan`] +
//! `cargo run -- chaos --seed 7`, `rust/tests/resilience.rs`) injects
//! engine panics, queue saturation, torn plan-cache entries, and NaN
//! inputs, and asserts every fault maps to a typed error or a recorded
//! recovery — never a hang or a wrong answer.
//!
//! ## Observability
//!
//! One [`Telemetry`] handle per context records the whole pipeline
//! ([`telemetry`]): build-side spans (`reorder` → `ehyb.partition` →
//! `ehyb.assemble` → per-candidate `tune` → `shard.build` →
//! `engine.build`), serve-side spans (`serve.batch` → `queue.wait` →
//! `kernel` → per-shard `shard.kernel`), a per-request [`TraceId`]
//! minted at submit and carried through retries, sheds, deadlines,
//! faults, and solver iterations to exactly one terminal event, and a
//! metric registry folding in service counters, per-shard gauges, and
//! log-spaced latency histograms. Snapshot it all at once with
//! [`SpmvContext::telemetry_snapshot`]; export deterministically as
//! JSON or Prometheus text ([`TelemetrySnapshot::to_json`] /
//! [`TelemetrySnapshot::to_prometheus`]), or render
//! `harness::report::telemetry_markdown`. `cargo run -- stats --seed 7`
//! prints a seeded snapshot; `cargo run -- trace --seed 7` replays one
//! request's full story from its trace ID. Tests pass
//! [`Telemetry::with_fake_clock`] for bit-for-bit reproducible span
//! trees.
//!
//! **Kernel profiling + model drift** ([`profile`], `profile` feature,
//! on by default): the hot paths themselves count the data movement
//! they observably perform — ELL-walk and ER-tail stream bytes,
//! explicit x-cache fills, uncached gather footprint (distinct cache
//! lines), SpMM register-tile reuse, pad-slot waste, per-shard halo
//! bytes — as a handful of relaxed atomic adds per call
//! ([`KernelProfile`], [`SpmvContext::profile`], also folded into the
//! telemetry snapshot as `profile.*` gauges). [`SpmvContext::drift`]
//! diffs the observation against the [`traffic`] replay of the same
//! prepared plan, per component, and
//! [`SpmvContext::observe_drift`] closes the loop: drift past the
//! bound ([`SpmvContextBuilder::drift_threshold`]) records a
//! model-drift health event, stamps the tuned plan's `drift`
//! provenance, and re-persists it so a warm start re-searches instead
//! of trusting a stale score. [`Calibration`] least-squares-fits
//! per-level secs/byte from measured samples and rescales the traffic
//! oracle's `predicted_secs` to the executing host (persisted beside
//! plans via [`PlanStore::save_calibration`], applied via
//! [`SpmvContextBuilder::calibration`]). With `--no-default-features`
//! every recording call compiles to a no-op and the kernels are
//! bitwise identical (`rust/tests/profile.rs`). `cargo run -- profile
//! --seed 7` prints the observed-vs-predicted tables; `ablation
//! --which drift` compares calibrated vs uncalibrated tuner picks.
//!
//! [`SpmvContextBuilder::drift_threshold`]: api::SpmvContextBuilder::drift_threshold
//! [`SpmvContextBuilder::calibration`]: api::SpmvContextBuilder::calibration

pub mod util;
pub mod sparse;
pub mod partition;
pub mod reorder;
pub mod preprocess;
pub mod spmv;
pub mod shard;
pub mod gpu;
pub mod perfmodel;
pub mod profile;
pub mod traffic;
pub mod runtime;
pub mod coordinator;
pub mod harness;
pub mod api;
pub mod autotune;
pub mod resilience;
pub mod telemetry;

pub use api::{BatchBuf, EhybError, EngineKind, SpmvContext, VecBatch, VecBatchMut};
pub use autotune::{Fingerprint, PlanStore, ScoreOracle, TuneLevel, TunedPlan};
pub use profile::{Calibration, DriftReport, KernelProfile};
pub use reorder::{ReorderQuality, ReorderSpec, Reordering};
pub use resilience::{FaultInjector, FaultPlan, GuardLevel, HealthReport, RetryPolicy};
pub use shard::{ShardSpec, ShardStrategy, ShardedEngine};
pub use telemetry::{MetricRegistry, Telemetry, TelemetrySnapshot, TraceId};
pub use traffic::{LevelTraffic, ShardTraffic, TrafficReport, XReuse};

/// Crate-wide result type over the typed [`EhybError`].
pub type Result<T> = std::result::Result<T, EhybError>;
