//! Typed error surface for the crate — replaces the `anyhow` string
//! errors (and the panicking dimension `assert!`s on the facade entry
//! points) with a thiserror-style enum callers can match on.
//!
//! `crate::Result<T>` is an alias for `Result<T, EhybError>`; every
//! fallible public API in the crate returns it. The `crate::ensure!` /
//! `crate::bail!` macros below mirror `anyhow::ensure!` / `anyhow::bail!`
//! for invariant checks whose only payload is a message.

use std::fmt;

/// Everything that can go wrong in the EHYB pipeline, by category.
#[derive(Debug)]
pub enum EhybError {
    /// An input/output vector (or batch) length disagrees with the
    /// matrix dimensions. Returned by the [`crate::api::SpmvContext`]
    /// entry points instead of panicking.
    DimensionMismatch {
        /// Which argument was wrong ("x", "y", "batch width", ...).
        what: &'static str,
        expected: usize,
        got: usize,
    },
    /// The graph partitioner produced an unusable assignment (capacity
    /// overflow or wrong cardinality).
    PartitionFailed(String),
    /// The matrix shape/storage is not supported by the requested
    /// pipeline (non-square for EHYB, non-coordinate Matrix Market, ...).
    UnsupportedFormat(String),
    /// The SpMV service thread has shut down (or dropped the reply);
    /// the request was not served.
    ServiceStopped,
    /// The SpMV service's bounded request queue is full: the request was
    /// shed instead of queued (backpressure). `queue_depth` is the
    /// configured bound the queue had reached; retry after draining.
    Overloaded {
        queue_depth: usize,
    },
    /// The engine panicked while executing a fused batch. The service
    /// quarantines the engine (every request in the poisoned batch gets
    /// this error) and respawns a fresh one via its factory — the
    /// service itself keeps serving. The payload is the panic message.
    EngineFault(String),
    /// The request's deadline expired before the service drained it;
    /// the request was dropped without occupying kernel width.
    DeadlineExceeded,
    /// A non-finite (NaN/Inf) value was rejected by an input guard
    /// (`GuardLevel::Reject` on the facade).
    NonFinite {
        /// Which argument held the value ("x", "batch column 3", ...).
        what: &'static str,
        /// Index of the first offending element.
        index: usize,
    },
    /// Backend/runtime failure (PJRT client, missing artifacts).
    Runtime(String),
    /// Filesystem / OS error, with context.
    Io(String),
    /// Malformed input text (Matrix Market, JSON manifest).
    Parse(String),
    /// A structural invariant was violated (validation failures,
    /// bad configuration values).
    Invalid(String),
}

impl fmt::Display for EhybError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            EhybError::DimensionMismatch { what, expected, got } => {
                write!(f, "dimension mismatch for {what}: expected {expected}, got {got}")
            }
            EhybError::PartitionFailed(msg) => write!(f, "partitioning failed: {msg}"),
            EhybError::UnsupportedFormat(msg) => write!(f, "unsupported format: {msg}"),
            EhybError::ServiceStopped => write!(f, "SpMV service stopped"),
            EhybError::Overloaded { queue_depth } => {
                write!(f, "SpMV service overloaded: request queue full at depth {queue_depth}")
            }
            EhybError::EngineFault(msg) => {
                write!(f, "engine fault: batch quarantined after panic: {msg}")
            }
            EhybError::DeadlineExceeded => {
                write!(f, "deadline exceeded before the request was served")
            }
            EhybError::NonFinite { what, index } => {
                write!(f, "non-finite value in {what} at index {index}")
            }
            EhybError::Runtime(msg) => write!(f, "runtime error: {msg}"),
            EhybError::Io(msg) => write!(f, "I/O error: {msg}"),
            EhybError::Parse(msg) => write!(f, "parse error: {msg}"),
            EhybError::Invalid(msg) => write!(f, "{msg}"),
        }
    }
}

impl std::error::Error for EhybError {}

impl From<std::io::Error> for EhybError {
    fn from(e: std::io::Error) -> Self {
        EhybError::Io(e.to_string())
    }
}

impl From<std::num::ParseIntError> for EhybError {
    fn from(e: std::num::ParseIntError) -> Self {
        EhybError::Parse(e.to_string())
    }
}

impl From<std::num::ParseFloatError> for EhybError {
    fn from(e: std::num::ParseFloatError) -> Self {
        EhybError::Parse(e.to_string())
    }
}

#[cfg(feature = "pjrt")]
impl From<xla::Error> for EhybError {
    fn from(e: xla::Error) -> Self {
        EhybError::Runtime(format!("xla: {e}"))
    }
}

/// Return `Err(EhybError::Invalid(format!(...)))` — the crate-local
/// analogue of `anyhow::bail!` for message-only failures.
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return Err($crate::EhybError::Invalid(format!($($arg)*)))
    };
}

/// Check an invariant, returning `EhybError::Invalid` on violation —
/// the crate-local analogue of `anyhow::ensure!`.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_covers_variants() {
        let e = EhybError::DimensionMismatch { what: "x", expected: 4, got: 3 };
        assert_eq!(e.to_string(), "dimension mismatch for x: expected 4, got 3");
        assert!(EhybError::ServiceStopped.to_string().contains("stopped"));
        let e = EhybError::Overloaded { queue_depth: 64 };
        assert!(e.to_string().contains("overloaded") && e.to_string().contains("64"));
        assert!(EhybError::PartitionFailed("cap".into()).to_string().contains("cap"));
        assert!(EhybError::UnsupportedFormat("array".into()).to_string().contains("array"));
        let e = EhybError::EngineFault("index 4 out of bounds".into());
        assert!(e.to_string().contains("engine fault") && e.to_string().contains("index 4"));
        assert!(EhybError::DeadlineExceeded.to_string().contains("deadline"));
        let e = EhybError::NonFinite { what: "x", index: 7 };
        assert!(e.to_string().contains("non-finite") && e.to_string().contains('7'));
    }

    #[test]
    fn macros_produce_invalid() {
        fn f(ok: bool) -> crate::Result<()> {
            crate::ensure!(ok, "flag was {}", ok);
            Ok(())
        }
        assert!(f(true).is_ok());
        match f(false) {
            Err(EhybError::Invalid(msg)) => assert!(msg.contains("false")),
            other => panic!("expected Invalid, got {other:?}"),
        }
    }

    #[test]
    fn converts_into_anyhow() {
        // Callers with `anyhow::Result` keep working via `?`.
        fn f() -> anyhow::Result<()> {
            Err(EhybError::ServiceStopped)?;
            Ok(())
        }
        assert!(f().unwrap_err().to_string().contains("stopped"));
    }

    #[test]
    fn io_and_parse_conversions() {
        let e: EhybError = std::io::Error::new(std::io::ErrorKind::NotFound, "gone").into();
        assert!(matches!(e, EhybError::Io(_)));
        let e: EhybError = "x".parse::<usize>().unwrap_err().into();
        assert!(matches!(e, EhybError::Parse(_)));
    }
}
