//! The public facade: a *preprocess-once, execute-many* handle over the
//! whole pipeline (OSKI's tuning-handle design applied to EHYB).
//!
//! [`SpmvContext`] owns the matrix, the EHYB plan (when applicable), and
//! a prepared engine; it is built once through [`SpmvContext::builder`]
//! and then drives everything downstream:
//!
//! * [`SpmvContext::spmv`] / [`SpmvContext::spmv_batch`] — dimension-
//!   checked execution (typed [`EhybError::DimensionMismatch`] instead
//!   of a panic);
//! * [`SpmvContext::serve`] — spawn the request-fusing
//!   [`SpmvService`](crate::coordinator::service::SpmvService) on this
//!   context's engine;
//! * [`SpmvContext::solver`] — preconditioned CG / BiCGSTAB / multi-RHS
//!   CG over this context's engine.
//!
//! [`EngineKind::Auto`] and [`SpmvContextBuilder::tune`] route through
//! the [`crate::autotune`] tuner: the plan knobs (and for `Auto` the
//! engine kind itself) are searched per matrix — scored at
//! [`TuneLevel::Heuristic`] by the configured [`ScoreOracle`] (the
//! replayed [`crate::traffic`] simulation by default, roofline bounds
//! via [`SpmvContextBuilder::score_oracle`]), microbenched at
//! [`TuneLevel::Measured`] — and the winner can persist in a
//! [`PlanStore`] so a restarted process warm-starts with zero search.

pub mod batch;
pub mod error;

pub use batch::{BatchBuf, VecBatch, VecBatchMut};
pub use error::EhybError;

use crate::autotune::{self, Fingerprint, PlanStore, ScoreOracle, TuneLevel, TunedPlan};
use crate::coordinator::precond::{Jacobi, Preconditioner};
use crate::coordinator::service::{self, BatchKernel, SpmvService};
use crate::coordinator::solver::{self, SolveReport, SolveStatus, SolverConfig};
use crate::preprocess::{EhybPlan, PreprocessConfig};
use crate::profile::{Calibration, DriftReport, KernelProfile};
use crate::resilience::{GuardLevel, Health, HealthReport};
use crate::reorder::{ReorderSpec, ReorderedEngine, Reordering};
use crate::telemetry::{metrics::labeled, Telemetry, TelemetrySnapshot, TraceHealthEvent, TraceId};
use crate::shard::{ShardPlan, ShardSpec, ShardStrategy, ShardedEngine};
use crate::sparse::csr::Csr;
use crate::sparse::scalar::Scalar;
use crate::spmv::csr5::Csr5Like;
use crate::spmv::csr_scalar::CsrScalar;
use crate::spmv::csr_vector::CsrVector;
use crate::spmv::ehyb_cpu::EhybCpu;
use crate::spmv::ell::EllEngine;
use crate::spmv::hyb::HybEngine;
use crate::spmv::merge::MergeSpmv;
use crate::spmv::sellp::SellPEngine;
use crate::spmv::SpmvEngine;
use std::path::PathBuf;
use std::sync::{Arc, OnceLock};

/// Which prepared engine a [`SpmvContext`] should carry.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EngineKind {
    /// Choose via the [`crate::autotune`] tuner (heuristic scoring
    /// through the builder's [`ScoreOracle`] unless
    /// [`SpmvContextBuilder::tune`] asked for measured probes): EHYB
    /// when its plan wins, else the best baseline.
    Auto,
    /// The paper's explicitly-cached hybrid engine (requires a square
    /// matrix; runs Algorithms 1–2 at build time).
    Ehyb,
    CsrScalar,
    CsrVector,
    Ell,
    Hyb,
    SellP,
    Merge,
    Csr5,
}

impl EngineKind {
    /// Every concrete (non-`Auto`) engine kind — the paper's EHYB plus
    /// all seven baselines.
    pub const ALL: [EngineKind; 8] = [
        EngineKind::Ehyb,
        EngineKind::CsrScalar,
        EngineKind::CsrVector,
        EngineKind::Ell,
        EngineKind::Hyb,
        EngineKind::SellP,
        EngineKind::Merge,
        EngineKind::Csr5,
    ];

    /// Stable lowercase tag ("ehyb", "csr-scalar", ...) — used by the
    /// persisted plan store and CLI flags. Inverse of
    /// [`EngineKind::from_name`].
    pub fn name(self) -> &'static str {
        match self {
            EngineKind::Auto => "auto",
            EngineKind::Ehyb => "ehyb",
            EngineKind::CsrScalar => "csr-scalar",
            EngineKind::CsrVector => "csr-vector",
            EngineKind::Ell => "ell",
            EngineKind::Hyb => "hyb",
            EngineKind::SellP => "sellp",
            EngineKind::Merge => "merge",
            EngineKind::Csr5 => "csr5",
        }
    }

    pub fn from_name(name: &str) -> Option<EngineKind> {
        Some(match name {
            "auto" => EngineKind::Auto,
            "ehyb" => EngineKind::Ehyb,
            "csr-scalar" => EngineKind::CsrScalar,
            "csr-vector" => EngineKind::CsrVector,
            "ell" => EngineKind::Ell,
            "hyb" => EngineKind::Hyb,
            "sellp" => EngineKind::SellP,
            "merge" => EngineKind::Merge,
            "csr5" => EngineKind::Csr5,
            _ => return None,
        })
    }
}

/// Construct the engine for a concrete kind — THE single code path for
/// engine construction in the crate: the context's lazy cell, the
/// harness's [`all_contexts`] sweep, and the tuner's measured probes
/// all come through here (the old `spmv::registry` duplicate is
/// retired).
pub(crate) fn build_engine<S: Scalar>(
    kind: EngineKind,
    matrix: &Csr<S>,
    plan: Option<&EhybPlan<S>>,
) -> Arc<dyn SpmvEngine<S>> {
    match kind {
        EngineKind::Ehyb => Arc::new(EhybCpu::new(plan.expect("Ehyb kind carries a plan"))),
        EngineKind::CsrScalar => Arc::new(CsrScalar::new(matrix)),
        EngineKind::CsrVector => Arc::new(CsrVector::new(matrix)),
        EngineKind::Ell => Arc::new(EllEngine::new(matrix)),
        EngineKind::Hyb => Arc::new(HybEngine::new(matrix)),
        EngineKind::SellP => Arc::new(SellPEngine::new(matrix)),
        EngineKind::Merge => Arc::new(MergeSpmv::new(matrix)),
        EngineKind::Csr5 => Arc::new(Csr5Like::new(matrix)),
        EngineKind::Auto => unreachable!("Auto resolves to a concrete kind at build time"),
    }
}

/// Whether the plain dense-width ELL format would blow up on this
/// matrix: it stores `nrows × max_row_nnz` slots, which on power-law
/// rows is arbitrarily larger than the matrix itself (the retired
/// registry omitted plain ELL from its sweeps for exactly this
/// reason). The engine sweeps and the tuner's measured probes skip
/// plain ELL when padding exceeds 16× the nnz on a nontrivially-sized
/// matrix; the sliced formats (SELL-P, HYB's split) bound padding and
/// stay in.
pub(crate) fn ell_padding_excessive<S: Scalar>(m: &Csr<S>) -> bool {
    let slots = m.max_row_nnz().saturating_mul(m.nrows());
    slots > (1 << 20) && slots > m.nnz().saturating_mul(16)
}

/// One prepared context per concrete engine kind (paper's EHYB + all
/// seven baselines) — what the harness's engine sweep iterates now that
/// `spmv::registry` is retired. Each context owns its own clone of the
/// matrix; engines build lazily on first use. For large matrices where
/// holding `ALL.len()` matrix copies at once matters, loop
/// `EngineKind::ALL` and build/drop one context at a time instead (see
/// `harness::runner::bench_cpu_engines`, which also skips plain ELL on
/// padding-hostile matrices — [`EngineKind::Ell`] here only allocates
/// its dense-width format if you actually call `.engine()`).
pub fn all_contexts<S: Scalar>(
    m: &Csr<S>,
    cfg: &PreprocessConfig,
) -> crate::Result<Vec<SpmvContext<S>>> {
    EngineKind::ALL
        .iter()
        .map(|&kind| SpmvContext::builder(m.clone()).engine(kind).config(cfg.clone()).build())
        .collect()
}

/// Builder for [`SpmvContext`]: `SpmvContext::builder(m).engine(..)
/// .config(..).tune(..).build()?`.
pub struct SpmvContextBuilder<S: Scalar> {
    matrix: Csr<S>,
    kind: EngineKind,
    config: PreprocessConfig,
    tune: Option<TuneLevel>,
    cache_dir: Option<PathBuf>,
    cache_disabled: bool,
    shards: Option<ShardSpec>,
    shard_strategy: ShardStrategy,
    reorder: Option<ReorderSpec>,
    fallback: bool,
    guard: GuardLevel,
    oracle: ScoreOracle,
    drift_threshold: f64,
    calibration: Option<Calibration>,
    telemetry: Option<Telemetry>,
}

impl<S: Scalar> SpmvContextBuilder<S> {
    /// Select the engine (default: [`EngineKind::Ehyb`]).
    pub fn engine(mut self, kind: EngineKind) -> Self {
        self.kind = kind;
        self
    }

    /// Preprocessing tunables for the EHYB plan (ignored by baselines).
    pub fn config(mut self, config: PreprocessConfig) -> Self {
        self.config = config;
        self
    }

    /// Autotune the plan at build time (OSKI-style): search the EHYB
    /// knobs — and, with [`EngineKind::Auto`], the engine kind — and
    /// adopt the winner only if its score is no worse than the default
    /// plan's. Combine with [`Self::plan_cache`] (or the
    /// `EHYB_TUNE_DIR` environment variable) to persist winners and
    /// warm-start later builds with zero search.
    pub fn tune(mut self, level: TuneLevel) -> Self {
        self.tune = Some(level);
        self
    }

    /// How [`TuneLevel::Heuristic`] searches score candidates (default
    /// [`ScoreOracle::Traffic`] — the replayed [`crate::traffic`]
    /// storage simulation). [`ScoreOracle::Roofline`] restores the
    /// pre-0.7 closed-form [`crate::perfmodel`] bounds. Ignored by
    /// measured-level tuning, which times real engines; cached
    /// heuristic plans only hit when their recorded oracle matches.
    pub fn score_oracle(mut self, oracle: ScoreOracle) -> Self {
        self.oracle = oracle;
        self
    }

    /// Relative observed-vs-predicted drift bound (default
    /// [`crate::profile::DEFAULT_DRIFT_THRESHOLD`], 15%). Two
    /// consumers: a cached plan whose recorded observed drift
    /// ([`TunedPlan::drift`]) exceeds the bound is re-searched instead
    /// of adopted on warm start, and [`SpmvContext::observe_drift`]
    /// records a model-drift health event when a fresh
    /// [`DriftReport`] exceeds it.
    pub fn drift_threshold(mut self, threshold: f64) -> Self {
        self.drift_threshold = threshold;
        self
    }

    /// Explicit oracle [`Calibration`] for heuristic scoring: rescales
    /// the traffic oracle's predicted seconds with measured per-level
    /// byte costs, so the search ranks candidates by the host's
    /// observed speed rather than the reference device model. When not
    /// set, a tuner-routed build loads the persisted calibration for
    /// this device/dtype key from the plan cache directory, if one was
    /// ever saved there ([`PlanStore::save_calibration`]). Roofline
    /// scoring and measured probes ignore it.
    pub fn calibration(mut self, cal: Calibration) -> Self {
        self.calibration = Some(cal);
        self
    }

    /// Persist/load tuned plans in `dir` (overrides the `EHYB_TUNE_DIR`
    /// environment convention). Only consulted on tuner-routed builds
    /// ([`Self::tune`] or [`EngineKind::Auto`]).
    pub fn plan_cache(mut self, dir: impl Into<PathBuf>) -> Self {
        self.cache_dir = Some(dir.into());
        self
    }

    /// Opt this build out of the plan cache entirely — including the
    /// `EHYB_TUNE_DIR` environment fallback. For measurement tools
    /// (the tuning ablation, benches, tests) that must search fresh
    /// and must not read from or write into the user's cache.
    pub fn no_plan_cache(mut self) -> Self {
        self.cache_disabled = true;
        self.cache_dir = None;
        self
    }

    /// Execute through a row-sharded engine: the matrix is split into
    /// contiguous row shards ([`ShardSpec::Auto`] = one per worker
    /// thread), one engine is prepared per shard, and every
    /// `spmv`/`spmv_batch` fans out shard-parallel with each shard
    /// writing its own disjoint row range of `y`. See [`crate::shard`]
    /// for the per-kind bit-identity contract. Combined with
    /// [`Self::tune`] on an EHYB build, **each shard tunes its diagonal
    /// block independently** and the winners persist per shard
    /// fingerprint in the plan cache ([`SpmvContext::tuned_shards`]).
    pub fn shards(mut self, spec: ShardSpec) -> Self {
        self.shards = Some(spec);
        self
    }

    /// Where shard boundaries go (default
    /// [`ShardStrategy::CacheAware`]). Only meaningful with
    /// [`Self::shards`].
    pub fn shard_strategy(mut self, strategy: ShardStrategy) -> Self {
        self.shard_strategy = strategy;
        self
    }

    /// Apply a global locality-aware row/column reordering
    /// ([`crate::reorder`]) **ahead of** the whole pipeline: tuning
    /// fingerprints, shard boundaries, and the EHYB partitioner all see
    /// the permuted matrix (so [`ShardStrategy::CacheAware`] has real
    /// locality to find), while user-facing vectors stay in original
    /// index space — the built engine is wrapped in a
    /// [`ReorderedEngine`] adapter that permutes `x` in and `y` out
    /// through pooled scratch, and `cg`/`cg_many`/`serve` run unchanged
    /// on top. [`ReorderSpec::Auto`] picks the ordering by scored
    /// footprint reduction; a resolution to the identity executes with
    /// zero overhead. Requires a square matrix (except
    /// [`ReorderSpec::None`], which is a no-op).
    pub fn reorder(mut self, spec: ReorderSpec) -> Self {
        self.reorder = Some(spec);
        self
    }

    /// Degraded-mode operation (default off): an EHYB (or tuner-routed)
    /// build that fails downgrades to the [`EngineKind::CsrVector`]
    /// baseline instead of failing the build, and a solve that ends in
    /// [`SolveStatus::Breakdown`] / [`SolveStatus::Diverged`] is retried
    /// once from scratch with Jacobi-preconditioned BiCGSTAB. Every
    /// downgrade is counted and logged in [`SpmvContext::health`] — the
    /// context never degrades silently. Sharded (K ≥ 2) EHYB builds stay
    /// strict: their validation errors are configuration mistakes, not
    /// runtime conditions to absorb.
    pub fn fallback(mut self, enabled: bool) -> Self {
        self.fallback = enabled;
        self
    }

    /// Non-finite input/output policy (default [`GuardLevel::Off`] —
    /// zero hot-path cost). [`GuardLevel::Reject`] turns a NaN/Inf in
    /// `x` into a typed [`EhybError::NonFinite`] before the engine
    /// runs; [`GuardLevel::Monitor`] records non-finite engine outputs
    /// in [`SpmvContext::health`] without changing any return value.
    pub fn guard(mut self, level: GuardLevel) -> Self {
        self.guard = level;
        self
    }

    /// Record build-time spans, request traces, and metrics into this
    /// [`Telemetry`] handle (default: a fresh wall-clock handle). Pass
    /// [`Telemetry::with_fake_clock`] for deterministic, tick-counted
    /// timelines in tests and goldens; pass one shared handle to
    /// several builds to land them in a single snapshot. Everything the
    /// context and its service/solvers record is retrievable via
    /// [`SpmvContext::telemetry_snapshot`].
    pub fn telemetry(mut self, tel: Telemetry) -> Self {
        self.telemetry = Some(tel);
        self
    }

    /// Run preprocessing / tuning (as requested) and prepare the engine.
    pub fn build(self) -> crate::Result<SpmvContext<S>> {
        let SpmvContextBuilder {
            matrix,
            kind,
            mut config,
            tune,
            cache_dir,
            cache_disabled,
            shards,
            shard_strategy,
            reorder,
            fallback,
            guard,
            oracle,
            drift_threshold,
            mut calibration,
            telemetry,
        } = self;
        // Degradation ledger — shared with the solver handle so a
        // fallback build and a restarted solve report through one
        // `ctx.health()` snapshot.
        let health = Arc::new(Health::default());
        // One telemetry handle for the whole pipeline: build spans
        // here, request traces in the service, kernel spans inside the
        // sharded engine. RAII guards close the spans on the error
        // paths too.
        let tel = telemetry.unwrap_or_else(Telemetry::new);
        let build_span = tel.span("build");
        // --- Global reordering (ISSUE 5 tentpole): resolved FIRST so
        // everything downstream — tuning fingerprints, shard
        // boundaries, the EHYB partitioner — sees the permuted
        // structure. `exec` is the matrix the engines run on; `matrix`
        // stays the user-facing original.
        let mut reordering: Option<Arc<Reordering>> = None;
        let mut exec_matrix: Option<Csr<S>> = None;
        if let Some(spec) = reorder {
            if spec != ReorderSpec::None {
                if matrix.nrows() != matrix.ncols() || matrix.nrows() == 0 {
                    return Err(EhybError::UnsupportedFormat(format!(
                        "reordering requires a non-empty square matrix, got {}x{}",
                        matrix.nrows(),
                        matrix.ncols()
                    )));
                }
                let _g = tel.span("reorder");
                let r = Reordering::compute(&matrix, spec)?;
                if !r.is_identity() {
                    exec_matrix = Some(r.apply(&matrix));
                }
                reordering = Some(Arc::new(r));
            }
        }
        let exec: &Csr<S> = exec_matrix.as_ref().unwrap_or(&matrix);
        // Stamped into tuned plans (and checked on cache hits): the
        // fingerprint already keys on the reordered structure, the tag
        // records which ordering produced it.
        let reorder_tag =
            reordering.as_ref().map_or_else(|| "none".to_string(), |r| r.resolved.clone());
        let shard_k = shards.map(|s| s.resolve(exec.nrows()));
        // Per-shard tuning below resolves its own store from the same
        // setting the whole-matrix store uses.
        let shard_cache_dir = cache_dir.clone();
        // The cache only participates for tuner-routed requests with a
        // real search (`Auto` / `Ehyb`): tuning a fixed baseline is the
        // identity, and persisting it would clobber the shared
        // fingerprint entry with a no-op plan. The handle outlives the
        // build — `SpmvContext::observe_drift` re-persists the plan
        // with its observed-drift stamp through it.
        let store: Option<PlanStore> = if !cache_disabled
            && (tune.is_some() || kind == EngineKind::Auto)
            && matches!(kind, EngineKind::Auto | EngineKind::Ehyb)
        {
            cache_dir.map(PlanStore::new).or_else(PlanStore::from_env)
        } else {
            None
        };
        let mut tuned: Option<TunedPlan> = None;
        let (resolved, plan): (EngineKind, Option<EhybPlan<S>>) = match (kind, tune) {
            (EngineKind::Ehyb, None) if shard_k.is_some_and(|k| k >= 2) => {
                // ISSUE 5 satellite: a sharded EHYB build never
                // executes the whole-matrix plan — every shard runs its
                // own diagonal-block pipeline below, so a K ≥ 2 build
                // runs exactly K block pipelines, not K + 1. Keep the
                // validation the skipped plan build would have done.
                if exec.nrows() != exec.ncols() || exec.nrows() == 0 {
                    return Err(EhybError::UnsupportedFormat(format!(
                        "EHYB requires a square matrix, got {}x{}",
                        exec.nrows(),
                        exec.ncols()
                    )));
                }
                (EngineKind::Ehyb, None)
            }
            (EngineKind::Ehyb, None) => match EhybPlan::build(exec, &config) {
                Ok(p) => (EngineKind::Ehyb, Some(p)),
                Err(e) if fallback => {
                    // Degraded mode: the requested pipeline could not be
                    // built; serve the always-buildable csr-vector
                    // baseline and record the downgrade instead of
                    // failing the build.
                    health.record_engine_fallback(format!(
                        "ehyb plan build failed ({e}); csr-vector serving"
                    ));
                    (EngineKind::CsrVector, None)
                }
                Err(e) => return Err(e),
            },
            (concrete, None) if concrete != EngineKind::Auto => (concrete, None),
            // Tuner-routed: explicit `.tune(..)` and/or `Auto`.
            (requested, tune_level) => {
                let explicit = tune_level.is_some();
                let level = tune_level.unwrap_or(TuneLevel::Heuristic);
                // The fingerprint is a full O(nnz) hash pass — compute
                // it once, only when a store can use it, and hand it on
                // to the tuner so the search does not re-hash. It is
                // computed on the REORDERED structure, so differently-
                // ordered builds of one matrix key separate entries and
                // cached winners survive restarts per ordering.
                let fp = store.as_ref().map(|_| Fingerprint::of(exec));
                let device = autotune::device_key(&config.device);
                let cfg_key = autotune::config_key(&config);
                // Host calibration for the traffic oracle: an explicit
                // builder calibration wins; otherwise the persisted fit
                // for this device/dtype key (saved from profiled runs)
                // warm-starts from the same directory as the plans.
                if calibration.is_none() {
                    if let Some(s) = &store {
                        calibration = s.load_calibration(&device, S::NAME).ok().flatten();
                    }
                }
                // A damaged cache entry (Err) is treated as a miss, and
                // a hit is honored only when it fits this build: the
                // entry for this search scope (so Auto and EHYB-only
                // winners never clobber each other), same (or Auto)
                // engine request, compatible tune level, an exactly
                // matching base config (`TunedPlan::usable_for`), the
                // same resolved reordering provenance, and no recorded
                // observed drift past this build's bound (a drifted
                // plan's score provenance is stale — re-search it).
                let hit = store
                    .as_ref()
                    .zip(fp.as_ref())
                    .and_then(|(s, fp)| {
                        s.load(&fp.key(), &device, S::NAME, requested.name()).ok().flatten()
                    })
                    .filter(|tp| tp.usable_for(requested, level, oracle, &cfg_key))
                    .filter(|tp| tp.reorder == reorder_tag)
                    .filter(|tp| tp.drift_ok(drift_threshold));
                // Adopt the cached plan — unless rebuilding it fails
                // (stale entry for a matrix/config drift the keys did
                // not capture), in which case fall through to a fresh
                // search rather than failing the build.
                let adopted = hit.and_then(|tp| {
                    let cfg2 = tp.apply(&config);
                    if tp.engine == EngineKind::Ehyb {
                        EhybPlan::build(exec, &cfg2).ok().map(|p| (tp, cfg2, Some(p)))
                    } else {
                        Some((tp, cfg2, None))
                    }
                });
                match adopted {
                    Some((tp, cfg2, plan)) => {
                        config = cfg2;
                        let engine = tp.engine;
                        tuned = Some(tp);
                        (engine, plan)
                    }
                    None => {
                        let tune_span = tel.span("tune");
                        // Implicit `Auto` (no `.tune(..)`, the only way
                        // `explicit` is false here) keeps its engine-
                        // choice-only search — one preprocessing pass,
                        // like the pre-tuner engine comparison; the
                        // knob search stays opt-in. Either way the
                        // search scores through the host calibration
                        // when one is in effect.
                        let searched = autotune::tuner::tune_calibrated(
                            exec,
                            &config,
                            requested,
                            level,
                            oracle,
                            fp,
                            calibration.as_ref(),
                            explicit,
                            Some(&tel),
                        );
                        drop(tune_span);
                        match searched {
                            Err(e) if fallback => {
                                // Degraded mode for tuner-routed builds:
                                // a failed search/preprocess downgrades
                                // to the untuned csr-vector baseline.
                                health.record_engine_fallback(format!(
                                    "tuned build failed ({e}); csr-vector serving"
                                ));
                                (EngineKind::CsrVector, None)
                            }
                            Err(e) => return Err(e),
                            Ok(mut out) => {
                                // Stamp the ordering that produced this
                                // search before anything persists or
                                // reports it.
                                out.plan.reorder = reorder_tag.clone();
                                // Persist only real search results:
                                // implicit Auto's light engine choice
                                // and budget-starved measured runs
                                // (`!searched()`) must not occupy the
                                // entry a full `.tune(..)` search would
                                // fill. Best-effort: an unwritable cache
                                // dir must not fail the build.
                                if explicit && out.searched() {
                                    if let Some(store) = &store {
                                        let _ = store.save(&out.plan);
                                    }
                                }
                                config = out.plan.apply(&config);
                                let engine = out.plan.engine;
                                let plan = out.ehyb;
                                tuned = Some(out.plan);
                                (engine, plan)
                            }
                        }
                    }
                }
            }
        };
        // Partition/assemble phase timings from the whole-matrix plan,
        // surfaced as derived spans (`derived_span` backdates the wall
        // interval; under a fake clock each is one tick) and as build
        // gauges in the registry.
        if let Some(p) = &plan {
            tel.derived_span("ehyb.partition", TraceId::NONE, p.timings.partition_secs);
            tel.derived_span("ehyb.assemble", TraceId::NONE, p.timings.reorder_secs);
            tel.registry().set_gauge("build.partition_secs", p.timings.partition_secs);
            tel.registry().set_gauge("build.assemble_secs", p.timings.reorder_secs);
        }
        // --- Row sharding (ISSUE 4 tentpole): split into contiguous
        // row shards, prepare one engine per shard from the resolved
        // kind and the final (possibly tuned) config, and preset the
        // engine cell with the sharded fan-out engine. EHYB shards
        // additionally tune their diagonal blocks independently when
        // `.tune(..)` was requested, each keyed by its own block
        // fingerprint in the plan cache.
        let mut shard_plan: Option<ShardPlan> = None;
        let mut shard_tuned: Vec<Option<TunedPlan>> = Vec::new();
        let mut sharded: Option<Arc<ShardedEngine<S>>> = None;
        let mut reorder_cut: Option<(usize, usize)> = None;
        if let Some(spec) = shards {
            let _shard_span = tel.span("shard.build");
            let k = spec.resolve(exec.nrows());
            let splan = ShardPlan::new(exec, k, shard_strategy);
            if exec_matrix.is_some() {
                // Report the boundary traffic the reordering removed:
                // the same strategy planned on the natural order vs the
                // permuted order this build actually executes.
                let natural = ShardPlan::new(&matrix, k, shard_strategy);
                reorder_cut = Some((natural.cut_nnz(&matrix), splan.cut_nnz(exec)));
            }
            let shard_overrides = match (resolved, tune) {
                (EngineKind::Ehyb, Some(level)) if k > 1 => {
                    let store = if cache_disabled {
                        None
                    } else {
                        shard_cache_dir.map(PlanStore::new).or_else(PlanStore::from_env)
                    };
                    let mut overrides = Vec::with_capacity(splan.num_shards());
                    for rg in splan.ranges() {
                        let (block, _halo) = exec.diag_block_split(rg.start, rg.end);
                        if block.nnz() == 0 {
                            // Pure-halo shard: nothing to tune.
                            shard_tuned.push(None);
                            overrides.push((config.clone(), None));
                            continue;
                        }
                        let (tp, cfg2, bplan) = tune_shard_block(
                            &block,
                            &config,
                            level,
                            oracle,
                            store.as_ref(),
                            &reorder_tag,
                            calibration.as_ref(),
                            drift_threshold,
                        )?;
                        shard_tuned.push(Some(tp));
                        overrides.push((cfg2, bplan));
                    }
                    Some(overrides)
                }
                (EngineKind::Ehyb, Some(_)) => {
                    // K = 1: the single shard IS the whole matrix — its
                    // block fingerprint equals the whole-matrix
                    // fingerprint, so a second per-shard search would
                    // fight the whole-matrix entry over the same cache
                    // file (their base-config keys differ, each lookup
                    // would miss and clobber the other's write). Reuse
                    // the whole-matrix winner and its already-built
                    // plan instead of searching or preprocessing again.
                    shard_tuned.push(tuned.clone());
                    Some(vec![(config.clone(), plan.clone())])
                }
                (EngineKind::Ehyb, None) if plan.is_some() => {
                    // K = 1 untuned: the whole-matrix plan exists (the
                    // K ≥ 2 arm above skipped it) — hand it to the
                    // single shard instead of preprocessing twice.
                    Some(vec![(config.clone(), plan.clone())])
                }
                _ => None,
            };
            let engine = ShardedEngine::build(exec, resolved, &config, &splan, shard_overrides)?;
            let arc = Arc::new(engine);
            // Per-shard `shard.kernel(i=K)` spans from inside the
            // fan-out land on the same handle as everything else.
            arc.set_telemetry(tel.clone());
            sharded = Some(arc.clone());
            shard_plan = Some(splan);
        }
        let engine = OnceLock::new();
        if let Some(arc) = &sharded {
            let inner = arc.clone() as Arc<dyn SpmvEngine<S>>;
            let _ = engine.set(wrap_reordered(inner, &reordering, exec_matrix.is_some()));
        }
        drop(build_span);
        Ok(SpmvContext {
            matrix,
            config,
            kind: resolved,
            requested: kind,
            plan,
            tuned,
            reordering,
            exec_matrix,
            reorder_cut,
            shard_plan,
            shard_tuned,
            sharded,
            engine,
            fallback,
            guard,
            health,
            store,
            drift_threshold,
            calibration,
            tel,
        })
    }
}

/// Per-shard OSKI tune of one EHYB diagonal block — the whole-matrix
/// cache policy of [`SpmvContextBuilder::build`] applied per shard:
/// honor a usable cached entry (verifying it still rebuilds), otherwise
/// search fresh and persist only real search results. Every shard keys
/// its own plan-cache entry by its block's structural fingerprint, so a
/// restarted sharded server warm-starts all K searches. Returns the
/// winning plan, the overlaid config, and the **already-built**
/// [`EhybPlan`] (from the hit verification or the search itself), so
/// the engine construction downstream never preprocesses the block a
/// second time.
#[allow(clippy::type_complexity)]
#[allow(clippy::too_many_arguments)]
fn tune_shard_block<S: Scalar>(
    block: &Csr<S>,
    base: &PreprocessConfig,
    level: TuneLevel,
    oracle: ScoreOracle,
    store: Option<&PlanStore>,
    reorder_tag: &str,
    calibration: Option<&Calibration>,
    drift_threshold: f64,
) -> crate::Result<(TunedPlan, PreprocessConfig, Option<EhybPlan<S>>)> {
    let fp = Fingerprint::of(block);
    let device = autotune::device_key(&base.device);
    let cfg_key = autotune::config_key(base);
    let hit = store
        .and_then(|s| s.load(&fp.key(), &device, S::NAME, EngineKind::Ehyb.name()).ok().flatten())
        .filter(|tp| tp.usable_for(EngineKind::Ehyb, level, oracle, &cfg_key))
        .filter(|tp| tp.reorder == reorder_tag)
        .filter(|tp| tp.drift_ok(drift_threshold));
    if let Some(tp) = hit {
        let cfg = tp.apply(base);
        // A stale entry that no longer rebuilds is a miss, not a build
        // failure (same fallback the whole-matrix path takes); a good
        // one hands its verification build straight to the engine.
        if let Ok(bplan) = EhybPlan::build(block, &cfg) {
            return Ok((tp, cfg, Some(bplan)));
        }
    }
    let mut out = autotune::tuner::tune_calibrated(
        block,
        base,
        EngineKind::Ehyb,
        level,
        oracle,
        Some(fp),
        calibration,
        true,
        None,
    )?;
    // The block is a block of the already-reordered matrix; record the
    // ordering provenance just like the whole-matrix entry does.
    out.plan.reorder = reorder_tag.to_string();
    if out.searched() {
        if let Some(s) = store {
            let _ = s.save(&out.plan);
        }
    }
    let cfg = out.plan.apply(base);
    Ok((out.plan, cfg, out.ehyb))
}

/// Wrap `inner` in the reorder boundary adapter when this build runs on
/// a (non-identity) permuted matrix.
fn wrap_reordered<S: Scalar>(
    inner: Arc<dyn SpmvEngine<S>>,
    reordering: &Option<Arc<Reordering>>,
    permuted: bool,
) -> Arc<dyn SpmvEngine<S>> {
    match reordering {
        Some(r) if permuted => Arc::new(ReorderedEngine::new(inner, r.clone())),
        _ => inner,
    }
}

/// A prepared SpMV pipeline: matrix + (optional) EHYB plan + engine.
/// Build once, execute many times — the handle every layer of the crate
/// (service, solvers, harness, examples) now goes through.
pub struct SpmvContext<S: Scalar> {
    matrix: Csr<S>,
    config: PreprocessConfig,
    kind: EngineKind,
    requested: EngineKind,
    plan: Option<EhybPlan<S>>,
    /// Present iff the build was tuner-routed (`.tune(..)` or `Auto`):
    /// the winning plan with its score provenance.
    tuned: Option<TunedPlan>,
    /// Present iff `.reorder(..)` requested anything but `None`: the
    /// computed ordering with before/after quality metrics.
    reordering: Option<Arc<Reordering>>,
    /// The permuted matrix the engines execute on — present iff the
    /// resolved reordering is non-identity (`matrix` stays in the
    /// user-facing original order).
    exec_matrix: Option<Csr<S>>,
    /// `(before, after)` cross-shard `cut_nnz` under the shard
    /// strategy, when reordering and sharding combined.
    reorder_cut: Option<(usize, usize)>,
    /// Present iff the build was sharded (`.shards(..)`): the row
    /// ranges the engine fans out over.
    shard_plan: Option<ShardPlan>,
    /// Per-shard tuned plans (sharded EHYB builds with `.tune(..)`;
    /// `None` entries are pure-halo shards with nothing to tune).
    shard_tuned: Vec<Option<TunedPlan>>,
    /// The concrete sharded engine (same object the engine cell holds)
    /// — kept typed so per-shard stats stay reachable.
    sharded: Option<Arc<ShardedEngine<S>>>,
    /// Constructed lazily on first execution: plan-only consumers (the
    /// harness reads partition/timing provenance off `plan()`) never
    /// pay for the engine's own copy of the format. Sharded builds
    /// preset this cell at build time.
    engine: OnceLock<Arc<dyn SpmvEngine<S>>>,
    /// Degraded-mode operation requested at build time
    /// ([`SpmvContextBuilder::fallback`]): build failures downgrade to
    /// a baseline engine, broken solves restart once.
    fallback: bool,
    /// Non-finite input/output policy
    /// ([`SpmvContextBuilder::guard`]).
    guard: GuardLevel,
    /// Degradation ledger: every fallback, restart, and guarded
    /// non-finite value lands here (snapshot via
    /// [`SpmvContext::health`]).
    health: Arc<Health>,
    /// The plan cache handle the build resolved (tuner-routed builds
    /// only) — retained so [`Self::observe_drift`] can re-persist the
    /// plan with its observed-drift stamp.
    store: Option<PlanStore>,
    /// Relative drift bound ([`SpmvContextBuilder::drift_threshold`]).
    drift_threshold: f64,
    /// Oracle calibration in effect: the builder's explicit one, or
    /// the persisted fit the build loaded from the plan cache.
    calibration: Option<Calibration>,
    /// Telemetry handle shared by every layer this context drives:
    /// build spans were recorded into it at build time; the service
    /// ([`SpmvContext::serve`]), the sharded engine, and the solver
    /// handle all record into the same registry/rings. Snapshot via
    /// [`SpmvContext::telemetry_snapshot`].
    tel: Telemetry,
}

/// Index of the first non-finite (NaN/Inf) element, if any.
fn first_nonfinite<S: Scalar>(v: &[S]) -> Option<usize> {
    v.iter().position(|s| !s.to_f64().is_finite())
}

impl<S: Scalar> SpmvContext<S> {
    /// Start building a context over `matrix` (takes ownership — the
    /// context is the long-lived handle).
    pub fn builder(matrix: Csr<S>) -> SpmvContextBuilder<S> {
        SpmvContextBuilder {
            matrix,
            kind: EngineKind::Ehyb,
            config: PreprocessConfig::default(),
            tune: None,
            cache_dir: None,
            cache_disabled: false,
            shards: None,
            shard_strategy: ShardStrategy::default(),
            reorder: None,
            fallback: false,
            guard: GuardLevel::Off,
            oracle: ScoreOracle::default(),
            drift_threshold: crate::profile::DEFAULT_DRIFT_THRESHOLD,
            calibration: None,
            telemetry: None,
        }
    }

    /// Shorthand for the default EHYB pipeline with default config.
    pub fn new(matrix: Csr<S>) -> crate::Result<Self> {
        Self::builder(matrix).build()
    }

    /// The concrete engine kind this context runs (never `Auto`).
    pub fn kind(&self) -> EngineKind {
        self.kind
    }

    /// The kind that was requested at build time (may be `Auto`).
    pub fn requested_kind(&self) -> EngineKind {
        self.requested
    }

    pub fn matrix(&self) -> &Csr<S> {
        &self.matrix
    }

    pub fn config(&self) -> &PreprocessConfig {
        &self.config
    }

    /// The EHYB preprocessing output — partition provenance, cache
    /// plan, and the Figure 6 timings. Present iff the resolved engine
    /// is [`EngineKind::Ehyb`] **and** the build actually ran the
    /// whole-matrix pipeline: an untuned build sharded into K ≥ 2 skips
    /// it (each shard runs its own diagonal-block pipeline — see
    /// [`crate::shard::ShardStat::block_prep`]), so this is `None`
    /// there. Built from the reordered matrix when `.reorder(..)` is
    /// active.
    pub fn plan(&self) -> Option<&EhybPlan<S>> {
        self.plan.as_ref()
    }

    /// The tuner's winning plan + score provenance — present iff this
    /// context was built through the tuner (`.tune(..)` or
    /// [`EngineKind::Auto`]), whether searched fresh or loaded from the
    /// plan cache. On sharded EHYB builds this is the **whole-matrix**
    /// plan; the per-shard winners are [`Self::tuned_shards`].
    pub fn tuned(&self) -> Option<&TunedPlan> {
        self.tuned.as_ref()
    }

    /// Per-shard tuned plans, in shard order — non-empty iff this build
    /// combined [`SpmvContextBuilder::shards`] with
    /// [`SpmvContextBuilder::tune`] on an EHYB pipeline. A `None` entry
    /// is a pure-halo shard (empty diagonal block, nothing to tune).
    pub fn tuned_shards(&self) -> &[Option<TunedPlan>] {
        &self.shard_tuned
    }

    /// Number of row shards this context executes with (1 = unsharded).
    pub fn shards(&self) -> usize {
        self.shard_plan.as_ref().map_or(1, ShardPlan::num_shards)
    }

    /// The sharded engine's row ranges, when this build was sharded.
    pub fn shard_ranges(&self) -> Option<&[std::ops::Range<usize>]> {
        self.shard_plan.as_ref().map(ShardPlan::ranges)
    }

    /// The concrete sharded engine (per-shard execution stats live
    /// here), when this build was sharded.
    pub fn sharded(&self) -> Option<&ShardedEngine<S>> {
        self.sharded.as_deref()
    }

    /// The global reordering this context was built with — present iff
    /// [`SpmvContextBuilder::reorder`] requested anything but
    /// [`ReorderSpec::None`]. `resolved` records what actually ran; an
    /// identity resolution executes with zero overhead (no adapter).
    pub fn reordering(&self) -> Option<&Reordering> {
        self.reordering.as_deref()
    }

    /// The permuted matrix the engines execute on, when the resolved
    /// reordering is non-identity. [`Self::matrix`] stays in original
    /// index space, as do all `spmv`/solver/service vectors.
    pub fn reordered_matrix(&self) -> Option<&Csr<S>> {
        self.exec_matrix.as_ref()
    }

    /// Cross-shard entries (`cut_nnz`) before → after reordering, when
    /// this build combined `.reorder(..)` with `.shards(..)`: the same
    /// shard strategy planned on the natural vs the permuted order.
    pub fn reorder_cut_nnz(&self) -> Option<(usize, usize)> {
        self.reorder_cut
    }

    /// Degradation snapshot: engine fallbacks, solver restarts, and
    /// guarded non-finite values, with a capped event log. A freshly
    /// built context that got exactly what it asked for reports
    /// [`HealthReport::healthy`]; a build that downgraded under
    /// [`SpmvContextBuilder::fallback`] reports
    /// [`HealthReport::degraded`] — compare [`Self::kind`] against
    /// [`Self::requested_kind`] for what is actually serving.
    pub fn health(&self) -> HealthReport {
        self.health.report()
    }

    /// The non-finite guard policy this context executes with.
    pub fn guard(&self) -> GuardLevel {
        self.guard
    }

    /// Whether degraded-mode fallback was requested at build time.
    pub fn fallback_enabled(&self) -> bool {
        self.fallback
    }

    /// Observed kernel-level data movement since the engine was built:
    /// the aggregate of every `spmv`/`spmv_batch` this context ran,
    /// counted inside the hot paths themselves (sharded builds merge
    /// all shards, with cross-shard halo gathers attributed
    /// separately). `None` when nothing was recorded — the engine
    /// never ran, or the crate was built without the `profile`
    /// feature.
    pub fn profile(&self) -> Option<KernelProfile> {
        self.engine.get().and_then(|e| e.kernel_profile())
    }

    /// The relative drift bound this context applies
    /// ([`SpmvContextBuilder::drift_threshold`]).
    pub fn drift_threshold(&self) -> f64 {
        self.drift_threshold
    }

    /// The oracle calibration in effect — the builder's explicit one,
    /// or the persisted fit loaded from the plan cache on a
    /// tuner-routed build.
    pub fn calibration(&self) -> Option<&Calibration> {
        self.calibration.as_ref()
    }

    /// The [`crate::traffic`] replay of this context's prepared plan —
    /// the prediction [`Self::drift`] diffs the observed profile
    /// against, priced for the same reference device the tuner scored
    /// on. `None` for sharded builds (their per-shard replay is the
    /// separate [`crate::traffic::shard_traffic`] breakdown) and for
    /// an EHYB context without a whole-matrix plan.
    pub fn predicted_traffic(&self) -> Option<crate::traffic::TrafficReport> {
        if self.sharded.is_some() {
            return None;
        }
        let dev = crate::gpu::device::GpuDevice::v100();
        match self.kind {
            EngineKind::Ehyb => {
                self.plan.as_ref().map(|p| crate::traffic::ehyb_traffic(&p.matrix, &dev))
            }
            kind => {
                let exec = self.exec_matrix.as_ref().unwrap_or(&self.matrix);
                Some(crate::traffic::baseline_traffic(kind, exec, &dev))
            }
        }
    }

    /// The sim-vs-observed cross-check: diff what the engine
    /// observably moved ([`Self::profile`]) against what the traffic
    /// simulator predicted for the same prepared plan, per component,
    /// normalized per right-hand side. Pure read — records nothing;
    /// use [`Self::observe_drift`] to feed the result back into the
    /// health ledger and the plan cache. `None` when there is no
    /// observation or no replayable plan (see
    /// [`Self::predicted_traffic`]).
    pub fn drift(&self) -> Option<DriftReport> {
        let observed = self.profile()?;
        let predicted = self.predicted_traffic()?;
        Some(DriftReport::new(
            &observed,
            &predicted,
            self.calibration.as_ref(),
            self.drift_threshold,
        ))
    }

    /// [`Self::drift`] with the loop closed: when the report exceeds
    /// the drift bound, a model-drift event lands in [`Self::health`]
    /// naming the worst component; and on tuner-routed builds the
    /// winning plan's `drift` provenance is stamped with the observed
    /// figure and re-persisted — so the next warm start re-searches
    /// instead of adopting a plan whose score provenance no longer
    /// matches reality.
    pub fn observe_drift(&mut self) -> Option<DriftReport> {
        let d = self.drift()?;
        if d.exceeded() {
            // Name the byte component when one tripped the bound;
            // otherwise the calibrated-seconds leg did.
            let worst = match d.worst_component() {
                Some(c) if c.rel() >= d.stamp() => c.component,
                _ => "calibrated-secs",
            };
            self.health.record_model_drift(format!(
                "{}: {} off by {:.0}% (bound {:.0}%)",
                d.engine,
                worst,
                d.stamp() * 100.0,
                d.threshold * 100.0
            ));
        }
        if let Some(tp) = self.tuned.as_mut() {
            tp.drift = Some(d.stamp());
            if let Some(store) = &self.store {
                // Best-effort, like the build-time persist: an
                // unwritable cache dir must not fail the observation.
                let _ = store.save(tp);
            }
        }
        Some(d)
    }

    /// The telemetry handle every layer of this context records into —
    /// hand it to dashboards, or to other builds that should share one
    /// timeline.
    pub fn telemetry(&self) -> &Telemetry {
        &self.tel
    }

    /// Freeze everything recorded so far into one
    /// [`TelemetrySnapshot`]: build/serve/tune spans, request events,
    /// metric registry, attached service blocks — plus, refreshed at
    /// snapshot time, the sharded engine's per-shard block-prep gauges
    /// (`shard.block_prep_secs{shard="K"}`), its scratch-pool miss
    /// gauge, and the [`Health`] event log folded in as trace-tagged
    /// `health_events`.
    pub fn telemetry_snapshot(&self) -> TelemetrySnapshot {
        if let Some(sh) = &self.sharded {
            for (i, st) in sh.stats().iter().enumerate() {
                if let Some(t) = &st.block_prep {
                    let name = labeled("shard.block_prep_secs", &[("shard", &i.to_string())]);
                    self.tel.registry().set_gauge(&name, t.total_secs());
                }
            }
            self.tel.registry().set_gauge("shard.scratch_misses", sh.scratch_misses() as f64);
        }
        // Observed kernel counters, refreshed at snapshot time like the
        // shard gauges (present only once something was profiled).
        if let Some(p) = self.profile() {
            let reg = self.tel.registry();
            reg.set_gauge("profile.calls", p.calls as f64);
            reg.set_gauge("profile.lanes", p.lanes as f64);
            reg.set_gauge("profile.total_bytes", p.total_bytes() as f64);
            reg.set_gauge("profile.bytes_per_lane", p.bytes_per_lane());
            reg.set_gauge("profile.tile_reuse", p.tile_reuse());
            reg.set_gauge("profile.secs", p.secs);
            for (component, bytes) in [
                ("ell", p.ell_bytes),
                ("er", p.er_bytes),
                ("meta", p.meta_bytes),
                ("x-fill", p.x_fill_bytes),
                ("x-gather", p.x_gather_bytes),
                ("halo", p.halo_bytes),
                ("write", p.write_bytes),
            ] {
                let name = labeled("profile.bytes", &[("component", component)]);
                reg.set_gauge(&name, bytes as f64);
            }
        }
        let mut snap = self.tel.snapshot();
        snap.health_events = self
            .health
            .events_traced()
            .into_iter()
            .map(|(detail, trace)| TraceHealthEvent { trace, detail })
            .collect();
        snap
    }

    fn engine_cell(&self) -> &Arc<dyn SpmvEngine<S>> {
        self.engine.get_or_init(|| {
            let _g = self.tel.span("engine.build");
            let exec = self.exec_matrix.as_ref().unwrap_or(&self.matrix);
            let inner = build_engine(self.kind, exec, self.plan.as_ref());
            wrap_reordered(inner, &self.reordering, self.exec_matrix.is_some())
        })
    }

    /// The prepared engine (built on first use, then cached).
    pub fn engine(&self) -> &dyn SpmvEngine<S> {
        self.engine_cell().as_ref()
    }

    /// Shared handle to the prepared engine (what [`Self::serve`] moves
    /// into the service thread).
    pub fn engine_arc(&self) -> Arc<dyn SpmvEngine<S>> {
        self.engine_cell().clone()
    }

    pub fn nrows(&self) -> usize {
        self.matrix.nrows()
    }

    pub fn ncols(&self) -> usize {
        self.matrix.ncols()
    }

    pub fn nnz(&self) -> usize {
        self.matrix.nnz()
    }

    fn check_dim(what: &'static str, expected: usize, got: usize) -> crate::Result<()> {
        if expected != got {
            return Err(EhybError::DimensionMismatch { what, expected, got });
        }
        Ok(())
    }

    /// One dimension-checked SpMV: `y = A x`. Under
    /// [`GuardLevel::Reject`] a non-finite `x` is a typed
    /// [`EhybError::NonFinite`] before the engine runs; under
    /// [`GuardLevel::Monitor`] (or `Reject`) a non-finite result is
    /// recorded in [`Self::health`].
    pub fn spmv(&self, x: &[S], y: &mut [S]) -> crate::Result<()> {
        Self::check_dim("x", self.ncols(), x.len())?;
        Self::check_dim("y", self.nrows(), y.len())?;
        if self.guard.rejects() {
            if let Some(index) = first_nonfinite(x) {
                self.health.record_rejected_input(format!("spmv x[{index}]"));
                return Err(EhybError::NonFinite { what: "x", index });
            }
        }
        self.engine().spmv(x, y);
        if self.guard.monitors() {
            if let Some(index) = first_nonfinite(y) {
                self.health.record_nonfinite_output(format!("spmv y[{index}]"));
            }
        }
        Ok(())
    }

    /// Allocating convenience: `A x`.
    pub fn spmv_alloc(&self, x: &[S]) -> crate::Result<Vec<S>> {
        let mut y = vec![S::ZERO; self.nrows()];
        self.spmv(x, &mut y)?;
        Ok(y)
    }

    /// Dimension-checked batched SpMV over borrowed contiguous views:
    /// `ys[b] = A xs[b]` for every column of the batch, through the
    /// engine's fused SpMM path when it has one.
    pub fn spmv_batch(
        &self,
        xs: VecBatch<'_, S>,
        ys: &mut VecBatchMut<'_, S>,
    ) -> crate::Result<()> {
        Self::check_dim("x batch rows", self.ncols(), xs.n())?;
        Self::check_dim("y batch rows", self.nrows(), ys.n())?;
        Self::check_dim("batch width", xs.width(), ys.width())?;
        if self.guard.rejects() {
            if let Some(index) = first_nonfinite(xs.as_slice()) {
                self.health.record_rejected_input(format!(
                    "spmv_batch column {} row {}",
                    index / xs.n().max(1),
                    index % xs.n().max(1)
                ));
                return Err(EhybError::NonFinite { what: "batch x", index });
            }
        }
        self.engine().spmv_batch(xs, ys);
        if self.guard.monitors() {
            if let Some(index) = first_nonfinite(ys.as_batch().as_slice()) {
                self.health.record_nonfinite_output(format!("spmv_batch y[{index}]"));
            }
        }
        Ok(())
    }

    /// Spawn the request-fusing SpMV service on this context's engine.
    /// `max_batch` bounds how many queued requests one drain fuses into
    /// a single batched kernel call; the request queue is bounded at
    /// [`service::DEFAULT_QUEUE_BOUND`] (submissions beyond it shed
    /// with [`EhybError::Overloaded`]) — use [`Self::serve_bounded`] to
    /// pick the bound.
    pub fn serve(&self, max_batch: usize) -> crate::Result<SpmvService<S>> {
        self.serve_bounded(max_batch, service::DEFAULT_QUEUE_BOUND)
    }

    /// [`Self::serve`] with an explicit request-queue bound: at most
    /// `queue_bound` requests wait in the service queue; further
    /// submissions return [`EhybError::Overloaded`] immediately instead
    /// of growing an unbounded backlog (load shedding / backpressure).
    pub fn serve_bounded(
        &self,
        max_batch: usize,
        queue_bound: usize,
    ) -> crate::Result<SpmvService<S>> {
        self.serve_inner(max_batch, queue_bound, false)
    }

    /// [`Self::serve_bounded`] with a **shed-rate-adaptive** fused-batch
    /// limit: `max_batch` is the cap; the live limit halves when
    /// submissions shed with [`EhybError::Overloaded`] and doubles back
    /// while the queue drains idle. See
    /// [`SpmvService::spawn_adaptive`]; the live limit is observable in
    /// `ServiceMetrics::adaptive_max_batch`.
    pub fn serve_adaptive(
        &self,
        max_batch: usize,
        queue_bound: usize,
    ) -> crate::Result<SpmvService<S>> {
        self.serve_inner(max_batch, queue_bound, true)
    }

    fn serve_inner(
        &self,
        max_batch: usize,
        queue_bound: usize,
        adaptive: bool,
    ) -> crate::Result<SpmvService<S>> {
        if self.nrows() != self.ncols() {
            return Err(EhybError::UnsupportedFormat(format!(
                "SpMV service requires a square matrix, got {}x{}",
                self.nrows(),
                self.ncols()
            )));
        }
        let engine = self.engine_arc();
        let nrows = self.nrows();
        // The factory is `FnMut`: the service re-invokes it to respawn
        // after a panicked batch, so each call hands out its own clone
        // of the shared engine handle.
        let make = move || {
            let engine = engine.clone();
            let fb = engine.format_bytes();
            let kernel: BatchKernel<S> = Box::new(move |xs, ys| engine.spmv_batch(xs, ys));
            Ok((kernel, fb))
        };
        // The service records into this context's handle: submit/shed/
        // reply events, queue-wait and batch spans, and its metrics
        // block, all in the same snapshot as the build spans.
        SpmvService::spawn_with_telemetry(
            make,
            nrows,
            max_batch,
            queue_bound,
            adaptive,
            self.tel.clone(),
        )
    }

    /// Iterative solvers running over this context's engine.
    pub fn solver(&self) -> SolverHandle<'_, S> {
        SolverHandle { ctx: self }
    }
}

/// Solver entry points bound to one [`SpmvContext`] — dimension-checked,
/// with the SpMV (and the multi-RHS fused batch) wired to the context's
/// prepared engine.
pub struct SolverHandle<'c, S: Scalar> {
    ctx: &'c SpmvContext<S>,
}

impl<S: Scalar> SolverHandle<'_, S> {
    fn check_square(&self) -> crate::Result<usize> {
        let (n, m) = (self.ctx.nrows(), self.ctx.ncols());
        if n != m {
            return Err(EhybError::UnsupportedFormat(format!(
                "iterative solvers require a square matrix, got {n}x{m}"
            )));
        }
        Ok(n)
    }

    /// Preconditioned conjugate gradients; `x0 = None` starts from zero.
    pub fn cg(
        &self,
        b: &[S],
        x0: Option<&[S]>,
        precond: &dyn Preconditioner<S>,
        cfg: &SolverConfig,
    ) -> crate::Result<(Vec<S>, SolveReport)> {
        let n = self.check_square()?;
        SpmvContext::<S>::check_dim("b", n, b.len())?;
        if let Some(x0) = x0 {
            SpmvContext::<S>::check_dim("x0", n, x0.len())?;
        }
        let zeros;
        let x0 = match x0 {
            Some(x0) => x0,
            None => {
                zeros = vec![S::ZERO; n];
                &zeros
            }
        };
        let engine = self.ctx.engine();
        let trace = self.ctx.tel.mint_trace();
        let span = self.ctx.tel.span_traced("solve.cg", trace);
        let out = solver::cg(|x, y| engine.spmv(x, y), b, x0, precond, cfg);
        let out = self.restart_if_broken(out, b, cfg, trace);
        drop(span);
        self.emit_solve_events(trace, &out.1);
        Ok(out)
    }

    /// Preconditioned BiCGSTAB; `x0 = None` starts from zero.
    pub fn bicgstab(
        &self,
        b: &[S],
        x0: Option<&[S]>,
        precond: &dyn Preconditioner<S>,
        cfg: &SolverConfig,
    ) -> crate::Result<(Vec<S>, SolveReport)> {
        let n = self.check_square()?;
        SpmvContext::<S>::check_dim("b", n, b.len())?;
        if let Some(x0) = x0 {
            SpmvContext::<S>::check_dim("x0", n, x0.len())?;
        }
        let zeros;
        let x0 = match x0 {
            Some(x0) => x0,
            None => {
                zeros = vec![S::ZERO; n];
                &zeros
            }
        };
        let engine = self.ctx.engine();
        let trace = self.ctx.tel.mint_trace();
        let span = self.ctx.tel.span_traced("solve.bicgstab", trace);
        let out = solver::bicgstab(|x, y| engine.spmv(x, y), b, x0, precond, cfg);
        let out = self.restart_if_broken(out, b, cfg, trace);
        drop(span);
        self.emit_solve_events(trace, &out.1);
        Ok(out)
    }

    /// Degraded-mode solve recovery: when the context was built with
    /// [`SpmvContextBuilder::fallback`] and a solve ended in
    /// [`SolveStatus::Breakdown`] or [`SolveStatus::Diverged`], retry
    /// **once** from scratch with Jacobi-preconditioned BiCGSTAB (the
    /// most breakdown-tolerant solver/preconditioner pair in the crate
    /// — it also handles the nonsymmetric systems CG diverges on). The
    /// restart starts from zero rather than the broken iterate, and
    /// runs with the divergence monitor off: it is the last resort, and
    /// BiCGSTAB's non-monotone residual would trip a tight window
    /// immediately. Whatever status the restart ends with is final; the
    /// attempt is recorded in [`SpmvContext::health`] either way.
    fn restart_if_broken(
        &self,
        out: (Vec<S>, SolveReport),
        b: &[S],
        cfg: &SolverConfig,
        trace: TraceId,
    ) -> (Vec<S>, SolveReport) {
        let (x, rep) = out;
        if !self.ctx.fallback
            || !matches!(rep.status, SolveStatus::Breakdown | SolveStatus::Diverged)
        {
            return (x, rep);
        }
        let detail = format!(
            "{} {} at iter {}; jacobi-bicgstab restart",
            rep.solver,
            rep.status.name(),
            rep.iters
        );
        // Tag the health ledger AND the trace's event stream, so the
        // restart shows up both in `ctx.health()` and in
        // `describe_trace` for this solve.
        self.ctx.health.record_solver_restart_traced(&detail, trace);
        self.ctx.tel.event("solver-restart", trace, detail);
        let pre = Jacobi::new(self.ctx.matrix());
        let mut rcfg = cfg.clone();
        rcfg.divergence_window = 0;
        let x0 = vec![S::ZERO; b.len()];
        let engine = self.ctx.engine();
        solver::bicgstab(|v, y| engine.spmv(v, y), b, &x0, &pre, &rcfg)
    }

    /// Replay the final report's residual history as per-iteration
    /// trace events (plus one closing summary event), so
    /// `describe_trace` on a solve's trace shows its convergence
    /// story. Post-hoc: the solver core stays telemetry-free.
    fn emit_solve_events(&self, trace: TraceId, rep: &SolveReport) {
        for (i, r) in rep.history.iter().enumerate() {
            self.ctx
                .tel
                .event("solver-iter", trace, format!("iter={} rel_residual={r:.3e}", i + 1));
        }
        self.ctx.tel.event(
            "solver-done",
            trace,
            format!("{} {} after {} iters", rep.solver, rep.status.name(), rep.iters),
        );
    }

    /// Multi-RHS preconditioned CG: every iteration's SpMVs fuse into
    /// one batched call on the context's engine (zero starts).
    pub fn cg_many(
        &self,
        bs: &[Vec<S>],
        precond: &dyn Preconditioner<S>,
        cfg: &SolverConfig,
    ) -> crate::Result<Vec<(Vec<S>, SolveReport)>> {
        let n = self.check_square()?;
        for b in bs {
            SpmvContext::<S>::check_dim("b", n, b.len())?;
        }
        let x0s = vec![vec![S::ZERO; n]; bs.len()];
        let engine = self.ctx.engine();
        let _span = self.ctx.tel.span(format!("solve.cg_many(w={})", bs.len()));
        Ok(solver::cg_many(|xs, ys| engine.spmv_batch(xs, ys), bs, &x0s, precond, cfg))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::precond::Jacobi;
    use crate::sparse::gen::{poisson2d, unstructured_mesh};
    use crate::util::check::assert_allclose;

    fn ctx_for(kind: EngineKind) -> SpmvContext<f64> {
        let m = poisson2d::<f64>(16, 16);
        SpmvContext::builder(m)
            .engine(kind)
            .config(PreprocessConfig { vec_size_override: Some(64), ..Default::default() })
            .build()
            .unwrap()
    }

    #[test]
    fn every_kind_matches_oracle() {
        let m = poisson2d::<f64>(16, 16);
        let x: Vec<f64> = (0..256).map(|i| ((i * 7 + 3) % 13) as f64 * 0.5 - 3.0).collect();
        let oracle = m.spmv_f64_oracle(&x);
        for kind in EngineKind::ALL {
            let ctx = ctx_for(kind);
            assert_eq!(ctx.kind(), kind);
            let y = ctx.spmv_alloc(&x).unwrap();
            assert_allclose(&y, &oracle, 1e-10, 1e-10)
                .unwrap_or_else(|e| panic!("{kind:?}: {e}"));
        }
    }

    #[test]
    fn dimension_mismatch_is_typed_not_a_panic() {
        for kind in EngineKind::ALL {
            let ctx = ctx_for(kind);
            let short = vec![0.0; ctx.ncols() - 1];
            let mut y = vec![0.0; ctx.nrows()];
            match ctx.spmv(&short, &mut y) {
                Err(EhybError::DimensionMismatch { what: "x", .. }) => {}
                other => panic!("{kind:?}: expected DimensionMismatch, got {other:?}"),
            }
            let x = vec![0.0; ctx.ncols()];
            let mut long = vec![0.0; ctx.nrows() + 3];
            match ctx.spmv(&x, &mut long) {
                Err(EhybError::DimensionMismatch { what: "y", .. }) => {}
                other => panic!("{kind:?}: expected DimensionMismatch, got {other:?}"),
            }
        }
    }

    #[test]
    fn batch_entry_checks_dims() {
        let ctx = ctx_for(EngineKind::Ehyb);
        let n = ctx.nrows();
        let xs = BatchBuf::<f64>::zeros(n - 1, 2);
        let mut ys = BatchBuf::<f64>::zeros(n, 2);
        let mut ysv = ys.view_mut();
        assert!(matches!(
            ctx.spmv_batch(xs.view(), &mut ysv),
            Err(EhybError::DimensionMismatch { .. })
        ));
    }

    #[test]
    fn batch_matches_repeated_spmv() {
        let ctx = ctx_for(EngineKind::Ehyb);
        let n = ctx.nrows();
        let mut xs = BatchBuf::<f64>::zeros(n, 3);
        for b in 0..3 {
            for i in 0..n {
                xs.col_mut(b)[i] = ((i * 5 + b * 11) % 17) as f64 * 0.25 - 2.0;
            }
        }
        let mut ys = BatchBuf::<f64>::zeros(n, 3);
        {
            let mut ysv = ys.view_mut();
            ctx.spmv_batch(xs.view(), &mut ysv).unwrap();
        }
        for b in 0..3 {
            let y1 = ctx.spmv_alloc(xs.col(b)).unwrap();
            assert_eq!(ys.col(b), &y1[..], "lane {b}");
        }
    }

    #[test]
    fn ehyb_context_carries_plan() {
        let ctx = ctx_for(EngineKind::Ehyb);
        let plan = ctx.plan().expect("plan");
        assert_eq!(plan.matrix.n, ctx.nrows());
        assert!(ctx_for(EngineKind::Merge).plan().is_none());
    }

    #[test]
    fn auto_picks_ehyb_on_partition_friendly_mesh() {
        // A mesh with strong locality: EHYB's u16-column bound beats the
        // CSR-family bound, so Auto must resolve to Ehyb.
        let m = unstructured_mesh::<f64>(48, 48, 0.3, 1);
        let ctx = SpmvContext::builder(m)
            .engine(EngineKind::Auto)
            .config(PreprocessConfig { vec_size_override: Some(512), ..Default::default() })
            .build()
            .unwrap();
        assert_eq!(ctx.requested_kind(), EngineKind::Auto);
        assert_eq!(ctx.kind(), EngineKind::Ehyb);
        assert!(ctx.plan().is_some());
    }

    #[test]
    fn auto_on_non_square_falls_back_to_baseline() {
        use crate::sparse::coo::Coo;
        let mut coo = Coo::<f64>::new(4, 6);
        for i in 0..4 {
            coo.push(i, i, 1.0);
            coo.push(i, i + 2, 0.5);
        }
        let ctx = SpmvContext::builder(coo.to_csr()).engine(EngineKind::Auto).build().unwrap();
        assert_ne!(ctx.kind(), EngineKind::Ehyb);
        let y = ctx.spmv_alloc(&[1.0; 6]).unwrap();
        assert_eq!(y.len(), 4);
    }

    #[test]
    fn ehyb_rejects_non_square_with_typed_error() {
        use crate::sparse::coo::Coo;
        let m = Coo::<f64>::new(3, 4).to_csr();
        match SpmvContext::builder(m).engine(EngineKind::Ehyb).build() {
            Err(EhybError::UnsupportedFormat(_)) => {}
            other => panic!("expected UnsupportedFormat, got {:?}", other.err()),
        }
    }

    #[test]
    fn kind_names_roundtrip_and_are_unique() {
        let mut seen = std::collections::BTreeSet::new();
        for kind in EngineKind::ALL.into_iter().chain([EngineKind::Auto]) {
            let name = kind.name();
            assert!(seen.insert(name), "duplicate kind tag {name}");
            assert_eq!(EngineKind::from_name(name), Some(kind));
        }
        assert_eq!(EngineKind::from_name("warp-drive"), None);
    }

    #[test]
    fn all_contexts_covers_every_kind_and_validates() {
        // The registry replacement: one context per concrete kind, each
        // engine validated against the oracle + both batch entry points.
        let m = crate::sparse::gen::unstructured_mesh::<f64>(20, 20, 0.5, 12);
        let cfg = PreprocessConfig { vec_size_override: Some(64), ..Default::default() };
        let ctxs = all_contexts(&m, &cfg).unwrap();
        assert_eq!(ctxs.len(), EngineKind::ALL.len());
        let mut names: Vec<&str> = Vec::new();
        for (ctx, &kind) in ctxs.iter().zip(EngineKind::ALL.iter()) {
            assert_eq!(ctx.kind(), kind);
            crate::spmv::testutil::validate_engine(ctx.engine(), &m);
            names.push(ctx.engine().name());
        }
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), ctxs.len(), "engine report names must be unique");
    }

    #[test]
    fn ell_padding_guard_detects_power_law() {
        use crate::sparse::coo::Coo;
        // One near-dense row in a big sparse matrix: plain ELL would
        // allocate nrows × max_row_nnz ≈ 4.5M slots for 4.5k nonzeros.
        let n = 3000;
        let mut coo = Coo::<f64>::new(n, n);
        for i in 0..n {
            coo.push(i, i, 1.0);
        }
        for j in 1..1500 {
            coo.push(0, j, 0.5);
        }
        assert!(ell_padding_excessive(&coo.to_csr()));
        // Regular stencils are fine.
        assert!(!ell_padding_excessive(&poisson2d::<f64>(16, 16)));
    }

    #[test]
    fn tuned_build_exposes_plan_and_respects_score_guarantee() {
        let m = unstructured_mesh::<f64>(32, 32, 0.4, 5);
        let ctx = SpmvContext::builder(m)
            .engine(EngineKind::Ehyb)
            .config(PreprocessConfig { vec_size_override: Some(128), ..Default::default() })
            .tune(crate::autotune::TuneLevel::Heuristic)
            .no_plan_cache()
            .build()
            .unwrap();
        let tp = ctx.tuned().expect("tuner-routed build carries TunedPlan");
        assert!(tp.score_secs <= tp.default_score_secs);
        assert_eq!(ctx.kind(), tp.engine);
        // The context's config reflects the tuned knobs, so plan() was
        // built from exactly what the TunedPlan records.
        assert_eq!(ctx.config().vec_size_override, tp.vec_size);
        assert_eq!(ctx.config().ell_width_cutoff, tp.ell_width_cutoff);
        assert!(ctx.plan().is_some());
        // Untuned builds carry no TunedPlan.
        assert!(ctx_for(EngineKind::Ehyb).tuned().is_none());
    }

    #[test]
    fn sharded_context_matches_unsharded_bitwise_on_row_local_engine() {
        let m = poisson2d::<f64>(16, 16);
        let x: Vec<f64> = (0..256).map(|i| ((i * 11 + 5) % 19) as f64 * 0.25 - 2.0).collect();
        let base = ctx_for(EngineKind::CsrScalar);
        let y_ref = base.spmv_alloc(&x).unwrap();
        for k in [1usize, 2, 7] {
            let ctx = SpmvContext::builder(m.clone())
                .engine(EngineKind::CsrScalar)
                .shards(ShardSpec::Count(k))
                .build()
                .unwrap();
            assert_eq!(ctx.shards(), k);
            assert_eq!(ctx.shard_ranges().unwrap().len(), k);
            assert!(ctx.sharded().is_some());
            let y = ctx.spmv_alloc(&x).unwrap();
            assert_eq!(y, y_ref, "k={k}");
        }
        // Unsharded contexts report one shard and no sharded engine.
        assert_eq!(base.shards(), 1);
        assert!(base.sharded().is_none());
        assert!(base.shard_ranges().is_none());
    }

    #[test]
    fn sharded_ehyb_skips_the_never_executed_whole_matrix_plan() {
        // ISSUE 5 satellite: at K >= 2 the whole-matrix plan would
        // never execute (every shard runs its own diagonal-block
        // pipeline), so the build must run exactly K block pipelines —
        // not K + 1 — which the per-shard preprocessing timings prove.
        let m = poisson2d::<f64>(16, 16);
        let ctx = SpmvContext::builder(m)
            .engine(EngineKind::Ehyb)
            .config(PreprocessConfig { vec_size_override: Some(64), ..Default::default() })
            .shards(ShardSpec::Count(3))
            .build()
            .unwrap();
        assert!(ctx.plan().is_none(), "K=3 must not pay for a whole-matrix plan");
        let stats = ctx.sharded().unwrap().stats();
        assert_eq!(stats.iter().filter(|s| s.block_prep.is_some()).count(), 3);
        assert_eq!(ctx.engine().name(), "sharded");
        assert_eq!(ctx.sharded().unwrap().num_shards(), 3);
        let x = vec![1.0; 256];
        let y = ctx.spmv_alloc(&x).unwrap();
        let oracle = ctx.matrix().spmv_f64_oracle(&x);
        assert_allclose(&y, &oracle, 1e-10, 1e-10).unwrap();
        // K = 1 is the whole matrix: the plan exists and is handed to
        // the single shard (one pipeline run, not two).
        let ctx1 = SpmvContext::builder(poisson2d::<f64>(16, 16))
            .engine(EngineKind::Ehyb)
            .config(PreprocessConfig { vec_size_override: Some(64), ..Default::default() })
            .shards(ShardSpec::Count(1))
            .build()
            .unwrap();
        assert!(ctx1.plan().is_some());
        assert!(ctx1.sharded().unwrap().stats()[0].block_prep.is_some());
    }

    #[test]
    fn sharded_tuned_build_reports_per_shard_plans() {
        let m = unstructured_mesh::<f64>(32, 32, 0.4, 5);
        let ctx = SpmvContext::builder(m)
            .engine(EngineKind::Ehyb)
            .config(PreprocessConfig { vec_size_override: Some(64), ..Default::default() })
            .tune(crate::autotune::TuneLevel::Heuristic)
            .no_plan_cache()
            .shards(ShardSpec::Count(4))
            .build()
            .unwrap();
        assert_eq!(ctx.tuned_shards().len(), 4);
        for (i, tp) in ctx.tuned_shards().iter().enumerate() {
            let tp = tp.as_ref().unwrap_or_else(|| panic!("shard {i} has a diagonal block"));
            assert_eq!(tp.engine, EngineKind::Ehyb);
            assert!(tp.score_secs <= tp.default_score_secs, "shard {i}");
            assert_eq!(tp.scope, "ehyb");
        }
        // Untuned sharded builds carry no per-shard plans.
        let m2 = poisson2d::<f64>(8, 8);
        let ctx2 = SpmvContext::builder(m2).shards(ShardSpec::Count(2)).build().unwrap();
        assert!(ctx2.tuned_shards().is_empty());
    }

    #[test]
    fn single_shard_tuned_build_reuses_whole_matrix_plan() {
        // K = 1: the shard block IS the matrix, so its fingerprint
        // equals the whole-matrix fingerprint. The build must reuse the
        // whole-matrix winner instead of running a second search that
        // would fight over the same cache file (their base-config keys
        // differ after the first tune applies its knobs).
        let m = unstructured_mesh::<f64>(24, 24, 0.4, 3);
        let dir = std::env::temp_dir()
            .join(format!("ehyb-api-shard1-{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        let ctx = SpmvContext::builder(m)
            .engine(EngineKind::Ehyb)
            .config(PreprocessConfig { vec_size_override: Some(64), ..Default::default() })
            .tune(crate::autotune::TuneLevel::Heuristic)
            .plan_cache(&dir)
            .shards(ShardSpec::Count(1))
            .build()
            .unwrap();
        assert_eq!(ctx.shards(), 1);
        assert_eq!(ctx.tuned_shards().len(), 1);
        assert_eq!(ctx.tuned_shards()[0].as_ref(), ctx.tuned());
        // Exactly one persisted entry: the whole-matrix plan.
        let entries = std::fs::read_dir(&dir)
            .unwrap()
            .filter_map(|e| e.ok())
            .filter(|e| e.path().extension().is_some_and(|x| x == "json"))
            .count();
        assert_eq!(entries, 1, "K=1 must not write a second, competing cache entry");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn solver_handle_solves() {
        let ctx = ctx_for(EngineKind::Ehyb);
        let n = ctx.nrows();
        let b: Vec<f64> = (0..n).map(|i| ((i * 37 + 11) % 23) as f64 / 23.0 - 0.5).collect();
        let pre = Jacobi::new(ctx.matrix());
        let (x, rep) = ctx.solver().cg(&b, None, &pre, &SolverConfig::default()).unwrap();
        assert!(rep.converged(), "{rep:?}");
        let mut ax = vec![0.0; n];
        ctx.matrix().spmv(&x, &mut ax);
        assert_allclose(&ax, &b, 1e-6, 1e-6).unwrap();
        // Wrong-length rhs is a typed error.
        assert!(matches!(
            ctx.solver().cg(&b[..n - 1], None, &pre, &SolverConfig::default()),
            Err(EhybError::DimensionMismatch { .. })
        ));
    }

    #[test]
    fn fallback_downgrades_failed_ehyb_build_and_records_it() {
        use crate::sparse::coo::Coo;
        // A non-square matrix fails the EHYB plan build; with
        // `.fallback(true)` the context serves csr-vector instead and
        // the downgrade is on the health record.
        let mut coo = Coo::<f64>::new(3, 4);
        for i in 0..3 {
            coo.push(i, i, 2.0);
        }
        coo.push(0, 3, 1.0);
        let ctx = SpmvContext::builder(coo.to_csr())
            .engine(EngineKind::Ehyb)
            .fallback(true)
            .build()
            .unwrap();
        assert_eq!(ctx.kind(), EngineKind::CsrVector);
        assert_eq!(ctx.requested_kind(), EngineKind::Ehyb);
        assert!(ctx.fallback_enabled());
        let h = ctx.health();
        assert!(h.degraded() && !h.healthy());
        assert_eq!(h.engine_fallbacks, 1);
        assert!(h.events[0].contains("csr-vector"), "{:?}", h.events);
        // The degraded context actually serves.
        let y = ctx.spmv_alloc(&[1.0; 4]).unwrap();
        assert_eq!(y, vec![3.0, 2.0, 2.0]);
        // Tuner-routed builds take the same downgrade: explicit EHYB
        // tuning on a non-square matrix is a search error, absorbed
        // into the baseline under fallback.
        let mut coo2 = Coo::<f64>::new(3, 4);
        for i in 0..3 {
            coo2.push(i, i, 2.0);
        }
        let tuned = SpmvContext::builder(coo2.to_csr())
            .engine(EngineKind::Ehyb)
            .tune(TuneLevel::Heuristic)
            .no_plan_cache()
            .fallback(true)
            .build()
            .unwrap();
        assert_eq!(tuned.kind(), EngineKind::CsrVector);
        assert!(tuned.tuned().is_none());
        assert_eq!(tuned.health().engine_fallbacks, 1);
    }

    #[test]
    fn default_context_is_healthy_and_unguarded() {
        let ctx = ctx_for(EngineKind::Ehyb);
        assert!(ctx.health().healthy());
        assert!(!ctx.fallback_enabled());
        assert_eq!(ctx.guard(), crate::resilience::GuardLevel::Off);
        // Off-guard contexts pass NaN straight through (pre-0.6
        // behavior): no error, nothing recorded.
        let mut x = vec![1.0; ctx.ncols()];
        x[5] = f64::NAN;
        let y = ctx.spmv_alloc(&x).unwrap();
        assert!(y.iter().any(|v| v.is_nan()));
        assert!(ctx.health().healthy());
    }

    #[test]
    fn reject_guard_returns_typed_nonfinite() {
        let m = poisson2d::<f64>(16, 16);
        let ctx = SpmvContext::builder(m)
            .engine(EngineKind::Ehyb)
            .config(PreprocessConfig { vec_size_override: Some(64), ..Default::default() })
            .guard(crate::resilience::GuardLevel::Reject)
            .build()
            .unwrap();
        let mut x = vec![1.0; ctx.ncols()];
        x[3] = f64::INFINITY;
        match ctx.spmv_alloc(&x) {
            Err(EhybError::NonFinite { what: "x", index: 3 }) => {}
            other => panic!("expected NonFinite at 3, got {other:?}"),
        }
        assert_eq!(ctx.health().rejected_inputs, 1);
        // Finite inputs serve normally under the same guard.
        x[3] = 1.0;
        let y = ctx.spmv_alloc(&x).unwrap();
        assert!(y.iter().all(|v| v.is_finite()));
        assert_eq!(ctx.health().rejected_inputs, 1);
        // Batched entry point rejects too, with the flat index.
        let n = ctx.nrows();
        let mut xs = vec![0.5; 2 * n];
        xs[n + 7] = f64::NAN;
        let mut ys = vec![0.0; 2 * n];
        let xb = VecBatch::new(&xs, n).unwrap();
        let mut yb = VecBatchMut::new(&mut ys, n).unwrap();
        match ctx.spmv_batch(xb, &mut yb) {
            Err(EhybError::NonFinite { what: "batch x", index }) => assert_eq!(index, n + 7),
            other => panic!("expected batch NonFinite, got {other:?}"),
        }
        assert_eq!(ctx.health().rejected_inputs, 2);
    }

    #[test]
    fn monitor_guard_records_nonfinite_output_without_failing() {
        let m = poisson2d::<f64>(16, 16);
        let ctx = SpmvContext::builder(m)
            .engine(EngineKind::CsrVector)
            .guard(crate::resilience::GuardLevel::Monitor)
            .build()
            .unwrap();
        // Monitor never rejects inputs: the NaN flows through the
        // engine, the poisoned output is recorded, the call succeeds.
        let mut x = vec![1.0; ctx.ncols()];
        x[0] = f64::NAN;
        let y = ctx.spmv_alloc(&x).unwrap();
        assert!(y.iter().any(|v| v.is_nan()));
        let h = ctx.health();
        assert_eq!(h.rejected_inputs, 0);
        assert!(h.nonfinite_outputs >= 1);
        assert!(!h.healthy() && !h.degraded());
    }

    #[test]
    fn solver_restart_on_breakdown_is_recorded() {
        use crate::coordinator::precond::Identity;
        use crate::sparse::coo::Coo;
        // The zero matrix breaks CG down at iteration 1 (p·Ap = 0).
        // With fallback the handle records one Jacobi-BiCGSTAB restart;
        // the restart breaks down too (same singular operator), and
        // that status is final — one restart, never a loop.
        let a = Coo::<f64>::new(4, 4).to_csr();
        let b = vec![1.0, 0.0, 0.0, 0.0];
        let ctx = SpmvContext::builder(a.clone())
            .engine(EngineKind::CsrVector)
            .fallback(true)
            .build()
            .unwrap();
        let (_, rep) =
            ctx.solver().cg(&b, None, &Identity, &SolverConfig::default()).unwrap();
        assert_eq!(rep.solver, "bicgstab", "restart ran");
        assert!(!rep.converged());
        assert_eq!(ctx.health().solver_restarts, 1);
        assert!(ctx.health().events[0].contains("breakdown"), "{:?}", ctx.health().events);
        // Strict contexts (default) return the broken report untouched.
        let strict =
            SpmvContext::builder(a).engine(EngineKind::CsrVector).build().unwrap();
        let (_, rep) =
            strict.solver().cg(&b, None, &Identity, &SolverConfig::default()).unwrap();
        assert_eq!(rep.solver, "cg");
        assert_eq!(rep.status, SolveStatus::Breakdown);
        assert_eq!(strict.health().solver_restarts, 0);
    }

    #[test]
    fn solver_restart_recovers_diverging_nonsymmetric_system() {
        use crate::coordinator::precond::Identity;
        use crate::sparse::coo::Coo;
        // The Jordan block A = [[1, 2], [0, 1]] is nonsingular but
        // nonsymmetric: with b = (0, 1), CG's residual grows 2 → √80,
        // so a one-iteration divergence window fires at iteration 2.
        // The BiCGSTAB restart solves the same system exactly (its
        // first stabilization step lands on x = (-2, 1)).
        let mut coo = Coo::<f64>::new(2, 2);
        coo.push(0, 0, 1.0);
        coo.push(0, 1, 2.0);
        coo.push(1, 1, 1.0);
        let a = coo.to_csr();
        let b = vec![0.0, 1.0];
        let ctx = SpmvContext::builder(a.clone())
            .engine(EngineKind::CsrVector)
            .fallback(true)
            .build()
            .unwrap();
        let cfg = SolverConfig { divergence_window: 1, ..Default::default() };
        let (x, rep) = ctx.solver().cg(&b, None, &Identity, &cfg).unwrap();
        assert_eq!(ctx.health().solver_restarts, 1);
        assert!(ctx.health().events[0].contains("diverged"), "{:?}", ctx.health().events);
        assert_eq!(rep.solver, "bicgstab");
        assert!(rep.converged(), "{rep:?}");
        assert_allclose(&x, &[-2.0, 1.0], 1e-10, 1e-10).unwrap();
        // Without the window the same config never restarts: CG just
        // burns its budget (default behavior is untouched).
        let strict = SpmvContext::builder(a).engine(EngineKind::CsrVector).build().unwrap();
        let (_, rep) = strict.solver().cg(&b, None, &Identity, &cfg).unwrap();
        assert_eq!(rep.status, SolveStatus::Diverged);
        assert_eq!(strict.health().solver_restarts, 0);
    }

    #[test]
    fn telemetry_snapshot_covers_build_shards_and_solves() {
        let m = poisson2d::<f64>(16, 16);
        let ctx = SpmvContext::builder(m)
            .engine(EngineKind::Ehyb)
            .config(PreprocessConfig { vec_size_override: Some(64), ..Default::default() })
            .shards(ShardSpec::Count(2))
            .telemetry(crate::telemetry::Telemetry::with_fake_clock())
            .build()
            .unwrap();
        let n = ctx.nrows();
        let xs = BatchBuf::<f64>::zeros(n, 2);
        let mut ys = BatchBuf::<f64>::zeros(n, 2);
        let mut ysv = ys.view_mut();
        ctx.spmv_batch(xs.view(), &mut ysv).unwrap();
        let b: Vec<f64> = (0..n).map(|i| ((i * 13 + 5) % 17) as f64 / 17.0 + 0.1).collect();
        let pre = Jacobi::new(ctx.matrix());
        let (_, rep) = ctx.solver().cg(&b, None, &pre, &SolverConfig::default()).unwrap();
        assert!(rep.converged(), "{rep:?}");
        let snap = ctx.telemetry_snapshot();
        // Build-side spans: `shard.build` nests under the `build` root,
        // and the fused batch recorded one kernel span per shard.
        let build = snap.spans.iter().find(|s| s.name == "build").expect("build span");
        let sb = snap.spans.iter().find(|s| s.name == "shard.build").expect("shard.build");
        assert_eq!(sb.parent, build.id);
        let kernels =
            snap.spans.iter().filter(|s| s.name.starts_with("shard.kernel(i=")).count();
        assert_eq!(kernels, 2);
        // Satellite: per-shard block-prep and scratch-miss gauges are
        // refreshed into the registry at snapshot time.
        assert!(snap.gauges.contains_key("shard.block_prep_secs{shard=\"0\"}"));
        assert!(snap.gauges.contains_key("shard.block_prep_secs{shard=\"1\"}"));
        assert!(snap.gauges.contains_key("shard.scratch_misses"));
        // The solve minted its own trace: a traced span, one
        // `solver-iter` event per residual-history entry, one summary.
        let solve = snap.spans.iter().find(|s| s.name == "solve.cg").expect("solve span");
        assert_ne!(solve.trace, 0);
        let iters = snap
            .events
            .iter()
            .filter(|e| e.trace == solve.trace && e.kind == "solver-iter")
            .count();
        assert_eq!(iters, rep.history.len());
        assert!(snap.events.iter().any(|e| e.trace == solve.trace && e.kind == "solver-done"));
    }

    #[cfg(feature = "profile")]
    #[test]
    fn profile_and_drift_close_the_loop_on_ehyb() {
        let ctx = ctx_for(EngineKind::Ehyb);
        assert!(ctx.profile().is_none(), "nothing recorded before the first call");
        let x = vec![1.0; ctx.ncols()];
        let mut y = vec![0.0; ctx.nrows()];
        for _ in 0..3 {
            ctx.spmv(&x, &mut y).unwrap();
        }
        let p = ctx.profile().expect("profiled engine");
        assert_eq!(p.engine, "ehyb");
        assert_eq!((p.calls, p.lanes), (3, 3));
        assert!(p.total_bytes() > 0 && p.secs > 0.0);
        // B=1 observation vs the B=1 replay of the same plan: every
        // compulsory byte component ties out exactly, so uncalibrated
        // drift is zero.
        let d = ctx.drift().expect("drift report");
        assert_eq!(d.max_rel_drift(), 0.0, "{d:?}");
        assert!(!d.exceeded() && !d.calibrated);
        // The snapshot folds the observed counters in as gauges.
        let snap = ctx.telemetry_snapshot();
        assert!(snap.gauges.contains_key("profile.total_bytes"));
        assert!(snap.gauges.contains_key("profile.bytes{component=\"ell\"}"));
        assert_eq!(snap.gauges["profile.lanes"], 3.0);
        // Baselines profile too, against their own replay.
        let csr = ctx_for(EngineKind::CsrVector);
        csr.spmv(&x, &mut y).unwrap();
        let dc = csr.drift().expect("csr drift report");
        assert_eq!(dc.max_rel_drift(), 0.0, "{dc:?}");
    }

    #[cfg(feature = "profile")]
    #[test]
    fn observed_drift_invalidates_cached_plan_and_records_health() {
        let m = unstructured_mesh::<f64>(32, 32, 0.4, 5);
        let dir =
            std::env::temp_dir().join(format!("ehyb-api-drift-{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        let cfg = PreprocessConfig { vec_size_override: Some(128), ..Default::default() };
        // A nonsense calibration (zero seconds for any traffic) makes
        // the calibrated-seconds leg drift ~100% while the byte
        // components still tie out.
        let bogus = crate::profile::Calibration {
            dram_secs_per_byte: 0.0,
            l2_secs_per_byte: 0.0,
            shm_secs_per_byte: 0.0,
            base_secs: 0.0,
            samples: 2,
            residual: 0.0,
        };
        let mut ctx = SpmvContext::builder(m.clone())
            .engine(EngineKind::Ehyb)
            .config(cfg.clone())
            .tune(TuneLevel::Heuristic)
            .plan_cache(&dir)
            .calibration(bogus)
            .build()
            .unwrap();
        let x = vec![1.0; ctx.ncols()];
        let mut y = vec![0.0; ctx.nrows()];
        ctx.spmv(&x, &mut y).unwrap();
        let d = ctx.observe_drift().expect("observation");
        assert!(d.calibrated && d.exceeded(), "{d:?}");
        let h = ctx.health();
        assert_eq!(h.model_drifts, 1);
        assert!(!h.healthy() && !h.degraded());
        assert!(h.events[0].contains("calibrated-secs"), "{:?}", h.events);
        let stamp = d.stamp();
        assert_eq!(ctx.tuned().unwrap().drift, Some(stamp));
        // A permissive bound adopts the stamped entry as-is, drift
        // provenance included.
        let adopted = SpmvContext::builder(m.clone())
            .engine(EngineKind::Ehyb)
            .config(cfg.clone())
            .tune(TuneLevel::Heuristic)
            .plan_cache(&dir)
            .drift_threshold(2.0)
            .build()
            .unwrap();
        assert_eq!(adopted.tuned().unwrap().drift, Some(stamp));
        // Under the default bound the stamped entry is filtered out:
        // the build re-searches and the fresh winner carries no drift.
        let fresh = SpmvContext::builder(m)
            .engine(EngineKind::Ehyb)
            .config(cfg)
            .tune(TuneLevel::Heuristic)
            .plan_cache(&dir)
            .build()
            .unwrap();
        assert_eq!(fresh.tuned().unwrap().drift, None, "drifted plan re-searched");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn sharded_context_profiles_but_does_not_replay() {
        let ctx = SpmvContext::builder(poisson2d::<f64>(16, 16))
            .engine(EngineKind::Ehyb)
            .config(PreprocessConfig { vec_size_override: Some(64), ..Default::default() })
            .shards(ShardSpec::Count(2))
            .build()
            .unwrap();
        let x = vec![1.0; ctx.ncols()];
        let mut y = vec![0.0; ctx.nrows()];
        ctx.spmv(&x, &mut y).unwrap();
        // Per-shard replay lives in `traffic::shard_traffic`, not the
        // whole-matrix drift path.
        assert!(ctx.predicted_traffic().is_none());
        assert!(ctx.drift().is_none());
        if crate::profile::enabled() {
            let p = ctx.profile().expect("sharded profile merges shards");
            assert_eq!(p.engine, "sharded");
            assert_eq!(p.lanes, 2, "one lane per shard kernel");
        } else {
            assert!(ctx.profile().is_none());
        }
    }

    #[test]
    fn solver_restart_emits_traced_health_and_trace_events() {
        use crate::coordinator::precond::Identity;
        use crate::sparse::coo::Coo;
        let a = Coo::<f64>::new(4, 4).to_csr();
        let b = vec![1.0, 0.0, 0.0, 0.0];
        let ctx = SpmvContext::builder(a)
            .engine(EngineKind::CsrVector)
            .fallback(true)
            .telemetry(crate::telemetry::Telemetry::with_fake_clock())
            .build()
            .unwrap();
        let (_, rep) = ctx.solver().cg(&b, None, &Identity, &SolverConfig::default()).unwrap();
        assert_eq!(rep.solver, "bicgstab");
        let snap = ctx.telemetry_snapshot();
        let solve = snap.spans.iter().find(|s| s.name == "solve.cg").unwrap();
        let restart = snap
            .events
            .iter()
            .find(|e| e.kind == "solver-restart")
            .expect("restart event recorded");
        assert_eq!(restart.trace, solve.trace);
        // The health ledger's event carries the same trace tag, and the
        // snapshot folds it into `health_events`.
        assert_eq!(snap.health_events.len(), 1);
        assert_eq!(snap.health_events[0].trace, solve.trace);
        assert!(snap.health_events[0].detail.contains("solver restart"));
    }
}
