//! The public facade: a *preprocess-once, execute-many* handle over the
//! whole pipeline (OSKI's tuning-handle design applied to EHYB).
//!
//! [`SpmvContext`] owns the matrix, the EHYB plan (when applicable), and
//! a prepared engine; it is built once through [`SpmvContext::builder`]
//! and then drives everything downstream:
//!
//! * [`SpmvContext::spmv`] / [`SpmvContext::spmv_batch`] — dimension-
//!   checked execution (typed [`EhybError::DimensionMismatch`] instead
//!   of a panic);
//! * [`SpmvContext::serve`] — spawn the request-fusing
//!   [`SpmvService`](crate::coordinator::service::SpmvService) on this
//!   context's engine;
//! * [`SpmvContext::solver`] — preconditioned CG / BiCGSTAB / multi-RHS
//!   CG over this context's engine.
//!
//! [`EngineKind::Auto`] picks the engine from the
//! [`crate::perfmodel`] roofline predictions (EHYB vs the CSR-family and
//! ELL-family bounds) instead of hard-coding EHYB.

pub mod batch;
pub mod error;

pub use batch::{BatchBuf, VecBatch, VecBatchMut};
pub use error::EhybError;

use crate::coordinator::precond::Preconditioner;
use crate::coordinator::service::{BatchKernel, SpmvService};
use crate::coordinator::solver::{self, SolveReport, SolverConfig};
use crate::gpu::device::GpuDevice;
use crate::perfmodel;
use crate::preprocess::{EhybPlan, PreprocessConfig};
use crate::sparse::csr::Csr;
use crate::sparse::scalar::Scalar;
use crate::spmv::csr5::Csr5Like;
use crate::spmv::csr_scalar::CsrScalar;
use crate::spmv::csr_vector::CsrVector;
use crate::spmv::ehyb_cpu::EhybCpu;
use crate::spmv::ell::EllEngine;
use crate::spmv::hyb::HybEngine;
use crate::spmv::merge::MergeSpmv;
use crate::spmv::sellp::SellPEngine;
use crate::spmv::SpmvEngine;
use std::sync::{Arc, OnceLock};

/// Which prepared engine a [`SpmvContext`] should carry.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EngineKind {
    /// Choose via the [`crate::perfmodel`] roofline bounds (EHYB when
    /// its predicted up-boundary wins, else the best baseline).
    Auto,
    /// The paper's explicitly-cached hybrid engine (requires a square
    /// matrix; runs Algorithms 1–2 at build time).
    Ehyb,
    CsrScalar,
    CsrVector,
    Ell,
    Hyb,
    SellP,
    Merge,
    Csr5,
}

impl EngineKind {
    /// Every concrete (non-`Auto`) engine kind — the paper's EHYB plus
    /// all seven baselines.
    pub const ALL: [EngineKind; 8] = [
        EngineKind::Ehyb,
        EngineKind::CsrScalar,
        EngineKind::CsrVector,
        EngineKind::Ell,
        EngineKind::Hyb,
        EngineKind::SellP,
        EngineKind::Merge,
        EngineKind::Csr5,
    ];
}

/// Builder for [`SpmvContext`]: `SpmvContext::builder(m).engine(..)
/// .config(..).build()?`.
pub struct SpmvContextBuilder<S: Scalar> {
    matrix: Csr<S>,
    kind: EngineKind,
    config: PreprocessConfig,
}

impl<S: Scalar> SpmvContextBuilder<S> {
    /// Select the engine (default: [`EngineKind::Ehyb`]).
    pub fn engine(mut self, kind: EngineKind) -> Self {
        self.kind = kind;
        self
    }

    /// Preprocessing tunables for the EHYB plan (ignored by baselines).
    pub fn config(mut self, config: PreprocessConfig) -> Self {
        self.config = config;
        self
    }

    /// Run preprocessing (when needed) and prepare the engine.
    pub fn build(self) -> crate::Result<SpmvContext<S>> {
        let SpmvContextBuilder { matrix, kind, config } = self;
        let (resolved, plan): (EngineKind, Option<EhybPlan<S>>) = match kind {
            EngineKind::Ehyb => (EngineKind::Ehyb, Some(EhybPlan::build(&matrix, &config)?)),
            EngineKind::Auto => choose_auto(&matrix, &config),
            concrete => (concrete, None),
        };
        Ok(SpmvContext {
            matrix,
            config,
            kind: resolved,
            requested: kind,
            plan,
            engine: OnceLock::new(),
        })
    }
}

/// Roofline-model engine choice for [`EngineKind::Auto`]: build the EHYB
/// plan (when the matrix is square) and compare its predicted memory-
/// bound up-boundary against the CSR-family and ELL-family bounds.
fn choose_auto<S: Scalar>(
    m: &Csr<S>,
    config: &PreprocessConfig,
) -> (EngineKind, Option<EhybPlan<S>>) {
    // Roofline device: the bounds are ratios of bytes moved, so any
    // bandwidth-bound device ranks the formats identically; V100 is the
    // paper's reference part. (`PreprocessConfig::device` shapes the
    // cache plan but carries no bandwidth numbers, so it cannot drive
    // the roofline itself.)
    let dev = GpuDevice::v100();
    let nnz = m.nnz();
    let csr_gf = perfmodel::csr_bound(m).roofline_gflops(nnz, &dev);
    let ell_fill =
        if nnz == 0 { 1.0 } else { (m.max_row_nnz() * m.nrows()) as f64 / nnz as f64 };
    let ell_gf = perfmodel::ell_bound(m, ell_fill.max(1.0)).roofline_gflops(nnz, &dev);
    let baseline =
        if ell_gf > csr_gf { (EngineKind::Ell, ell_gf) } else { (EngineKind::CsrScalar, csr_gf) };
    if m.nrows() != m.ncols() {
        return (baseline.0, None);
    }
    match EhybPlan::build(m, config) {
        Ok(plan) => {
            let ehyb_gf =
                perfmodel::ehyb_bound(&plan.matrix).roofline_gflops(plan.matrix.nnz(), &dev);
            if ehyb_gf >= baseline.1 {
                (EngineKind::Ehyb, Some(plan))
            } else {
                (baseline.0, None)
            }
        }
        Err(_) => (baseline.0, None),
    }
}

/// A prepared SpMV pipeline: matrix + (optional) EHYB plan + engine.
/// Build once, execute many times — the handle every layer of the crate
/// (service, solvers, harness, examples) now goes through.
pub struct SpmvContext<S: Scalar> {
    matrix: Csr<S>,
    config: PreprocessConfig,
    kind: EngineKind,
    requested: EngineKind,
    plan: Option<EhybPlan<S>>,
    /// Constructed lazily on first execution: plan-only consumers (the
    /// harness reads partition/timing provenance off `plan()`) never
    /// pay for the engine's own copy of the format.
    engine: OnceLock<Arc<dyn SpmvEngine<S>>>,
}

impl<S: Scalar> SpmvContext<S> {
    /// Start building a context over `matrix` (takes ownership — the
    /// context is the long-lived handle).
    pub fn builder(matrix: Csr<S>) -> SpmvContextBuilder<S> {
        SpmvContextBuilder { matrix, kind: EngineKind::Ehyb, config: PreprocessConfig::default() }
    }

    /// Shorthand for the default EHYB pipeline with default config.
    pub fn new(matrix: Csr<S>) -> crate::Result<Self> {
        Self::builder(matrix).build()
    }

    /// The concrete engine kind this context runs (never `Auto`).
    pub fn kind(&self) -> EngineKind {
        self.kind
    }

    /// The kind that was requested at build time (may be `Auto`).
    pub fn requested_kind(&self) -> EngineKind {
        self.requested
    }

    pub fn matrix(&self) -> &Csr<S> {
        &self.matrix
    }

    pub fn config(&self) -> &PreprocessConfig {
        &self.config
    }

    /// The EHYB preprocessing output (present iff the resolved engine is
    /// [`EngineKind::Ehyb`]) — partition provenance, cache plan, and the
    /// Figure 6 timings live here.
    pub fn plan(&self) -> Option<&EhybPlan<S>> {
        self.plan.as_ref()
    }

    fn engine_cell(&self) -> &Arc<dyn SpmvEngine<S>> {
        self.engine.get_or_init(|| match self.kind {
            EngineKind::Ehyb => {
                Arc::new(EhybCpu::new(self.plan.as_ref().expect("Ehyb kind carries a plan")))
            }
            EngineKind::CsrScalar => Arc::new(CsrScalar::new(&self.matrix)),
            EngineKind::CsrVector => Arc::new(CsrVector::new(&self.matrix)),
            EngineKind::Ell => Arc::new(EllEngine::new(&self.matrix)),
            EngineKind::Hyb => Arc::new(HybEngine::new(&self.matrix)),
            EngineKind::SellP => Arc::new(SellPEngine::new(&self.matrix)),
            EngineKind::Merge => Arc::new(MergeSpmv::new(&self.matrix)),
            EngineKind::Csr5 => Arc::new(Csr5Like::new(&self.matrix)),
            EngineKind::Auto => unreachable!("Auto resolves to a concrete kind at build time"),
        })
    }

    /// The prepared engine (built on first use, then cached).
    pub fn engine(&self) -> &dyn SpmvEngine<S> {
        self.engine_cell().as_ref()
    }

    /// Shared handle to the prepared engine (what [`Self::serve`] moves
    /// into the service thread).
    pub fn engine_arc(&self) -> Arc<dyn SpmvEngine<S>> {
        self.engine_cell().clone()
    }

    pub fn nrows(&self) -> usize {
        self.matrix.nrows()
    }

    pub fn ncols(&self) -> usize {
        self.matrix.ncols()
    }

    pub fn nnz(&self) -> usize {
        self.matrix.nnz()
    }

    fn check_dim(what: &'static str, expected: usize, got: usize) -> crate::Result<()> {
        if expected != got {
            return Err(EhybError::DimensionMismatch { what, expected, got });
        }
        Ok(())
    }

    /// One dimension-checked SpMV: `y = A x`.
    pub fn spmv(&self, x: &[S], y: &mut [S]) -> crate::Result<()> {
        Self::check_dim("x", self.ncols(), x.len())?;
        Self::check_dim("y", self.nrows(), y.len())?;
        self.engine().spmv(x, y);
        Ok(())
    }

    /// Allocating convenience: `A x`.
    pub fn spmv_alloc(&self, x: &[S]) -> crate::Result<Vec<S>> {
        let mut y = vec![S::ZERO; self.nrows()];
        self.spmv(x, &mut y)?;
        Ok(y)
    }

    /// Dimension-checked batched SpMV over borrowed contiguous views:
    /// `ys[b] = A xs[b]` for every column of the batch, through the
    /// engine's fused SpMM path when it has one.
    pub fn spmv_batch(&self, xs: VecBatch<'_, S>, ys: &mut VecBatchMut<'_, S>) -> crate::Result<()> {
        Self::check_dim("x batch rows", self.ncols(), xs.n())?;
        Self::check_dim("y batch rows", self.nrows(), ys.n())?;
        Self::check_dim("batch width", xs.width(), ys.width())?;
        self.engine().spmv_batch(xs, ys);
        Ok(())
    }

    /// Spawn the request-fusing SpMV service on this context's engine.
    /// `max_batch` bounds how many queued requests one drain fuses into
    /// a single batched kernel call.
    pub fn serve(&self, max_batch: usize) -> crate::Result<SpmvService<S>> {
        if self.nrows() != self.ncols() {
            return Err(EhybError::UnsupportedFormat(format!(
                "SpMV service requires a square matrix, got {}x{}",
                self.nrows(),
                self.ncols()
            )));
        }
        let engine = self.engine_arc();
        let nrows = self.nrows();
        SpmvService::spawn(
            move || {
                let fb = engine.format_bytes();
                let kernel: BatchKernel<S> = Box::new(move |xs, ys| engine.spmv_batch(xs, ys));
                Ok((kernel, fb))
            },
            nrows,
            max_batch,
        )
    }

    /// Iterative solvers running over this context's engine.
    pub fn solver(&self) -> SolverHandle<'_, S> {
        SolverHandle { ctx: self }
    }
}

/// Solver entry points bound to one [`SpmvContext`] — dimension-checked,
/// with the SpMV (and the multi-RHS fused batch) wired to the context's
/// prepared engine.
pub struct SolverHandle<'c, S: Scalar> {
    ctx: &'c SpmvContext<S>,
}

impl<S: Scalar> SolverHandle<'_, S> {
    fn check_square(&self) -> crate::Result<usize> {
        let (n, m) = (self.ctx.nrows(), self.ctx.ncols());
        if n != m {
            return Err(EhybError::UnsupportedFormat(format!(
                "iterative solvers require a square matrix, got {n}x{m}"
            )));
        }
        Ok(n)
    }

    /// Preconditioned conjugate gradients; `x0 = None` starts from zero.
    pub fn cg(
        &self,
        b: &[S],
        x0: Option<&[S]>,
        precond: &dyn Preconditioner<S>,
        cfg: &SolverConfig,
    ) -> crate::Result<(Vec<S>, SolveReport)> {
        let n = self.check_square()?;
        SpmvContext::<S>::check_dim("b", n, b.len())?;
        if let Some(x0) = x0 {
            SpmvContext::<S>::check_dim("x0", n, x0.len())?;
        }
        let zeros;
        let x0 = match x0 {
            Some(x0) => x0,
            None => {
                zeros = vec![S::ZERO; n];
                &zeros
            }
        };
        let engine = self.ctx.engine();
        Ok(solver::cg(|x, y| engine.spmv(x, y), b, x0, precond, cfg))
    }

    /// Preconditioned BiCGSTAB; `x0 = None` starts from zero.
    pub fn bicgstab(
        &self,
        b: &[S],
        x0: Option<&[S]>,
        precond: &dyn Preconditioner<S>,
        cfg: &SolverConfig,
    ) -> crate::Result<(Vec<S>, SolveReport)> {
        let n = self.check_square()?;
        SpmvContext::<S>::check_dim("b", n, b.len())?;
        if let Some(x0) = x0 {
            SpmvContext::<S>::check_dim("x0", n, x0.len())?;
        }
        let zeros;
        let x0 = match x0 {
            Some(x0) => x0,
            None => {
                zeros = vec![S::ZERO; n];
                &zeros
            }
        };
        let engine = self.ctx.engine();
        Ok(solver::bicgstab(|x, y| engine.spmv(x, y), b, x0, precond, cfg))
    }

    /// Multi-RHS preconditioned CG: every iteration's SpMVs fuse into
    /// one batched call on the context's engine (zero starts).
    pub fn cg_many(
        &self,
        bs: &[Vec<S>],
        precond: &dyn Preconditioner<S>,
        cfg: &SolverConfig,
    ) -> crate::Result<Vec<(Vec<S>, SolveReport)>> {
        let n = self.check_square()?;
        for b in bs {
            SpmvContext::<S>::check_dim("b", n, b.len())?;
        }
        let x0s = vec![vec![S::ZERO; n]; bs.len()];
        let engine = self.ctx.engine();
        Ok(solver::cg_many(|xs, ys| engine.spmv_batch(xs, ys), bs, &x0s, precond, cfg))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::precond::Jacobi;
    use crate::sparse::gen::{poisson2d, unstructured_mesh};
    use crate::util::check::assert_allclose;

    fn ctx_for(kind: EngineKind) -> SpmvContext<f64> {
        let m = poisson2d::<f64>(16, 16);
        SpmvContext::builder(m)
            .engine(kind)
            .config(PreprocessConfig { vec_size_override: Some(64), ..Default::default() })
            .build()
            .unwrap()
    }

    #[test]
    fn every_kind_matches_oracle() {
        let m = poisson2d::<f64>(16, 16);
        let x: Vec<f64> = (0..256).map(|i| ((i * 7 + 3) % 13) as f64 * 0.5 - 3.0).collect();
        let oracle = m.spmv_f64_oracle(&x);
        for kind in EngineKind::ALL {
            let ctx = ctx_for(kind);
            assert_eq!(ctx.kind(), kind);
            let y = ctx.spmv_alloc(&x).unwrap();
            assert_allclose(&y, &oracle, 1e-10, 1e-10)
                .unwrap_or_else(|e| panic!("{kind:?}: {e}"));
        }
    }

    #[test]
    fn dimension_mismatch_is_typed_not_a_panic() {
        for kind in EngineKind::ALL {
            let ctx = ctx_for(kind);
            let short = vec![0.0; ctx.ncols() - 1];
            let mut y = vec![0.0; ctx.nrows()];
            match ctx.spmv(&short, &mut y) {
                Err(EhybError::DimensionMismatch { what: "x", .. }) => {}
                other => panic!("{kind:?}: expected DimensionMismatch, got {other:?}"),
            }
            let x = vec![0.0; ctx.ncols()];
            let mut long = vec![0.0; ctx.nrows() + 3];
            match ctx.spmv(&x, &mut long) {
                Err(EhybError::DimensionMismatch { what: "y", .. }) => {}
                other => panic!("{kind:?}: expected DimensionMismatch, got {other:?}"),
            }
        }
    }

    #[test]
    fn batch_entry_checks_dims() {
        let ctx = ctx_for(EngineKind::Ehyb);
        let n = ctx.nrows();
        let xs = BatchBuf::<f64>::zeros(n - 1, 2);
        let mut ys = BatchBuf::<f64>::zeros(n, 2);
        let mut ysv = ys.view_mut();
        assert!(matches!(
            ctx.spmv_batch(xs.view(), &mut ysv),
            Err(EhybError::DimensionMismatch { .. })
        ));
    }

    #[test]
    fn batch_matches_repeated_spmv() {
        let ctx = ctx_for(EngineKind::Ehyb);
        let n = ctx.nrows();
        let mut xs = BatchBuf::<f64>::zeros(n, 3);
        for b in 0..3 {
            for i in 0..n {
                xs.col_mut(b)[i] = ((i * 5 + b * 11) % 17) as f64 * 0.25 - 2.0;
            }
        }
        let mut ys = BatchBuf::<f64>::zeros(n, 3);
        {
            let mut ysv = ys.view_mut();
            ctx.spmv_batch(xs.view(), &mut ysv).unwrap();
        }
        for b in 0..3 {
            let y1 = ctx.spmv_alloc(xs.col(b)).unwrap();
            assert_eq!(ys.col(b), &y1[..], "lane {b}");
        }
    }

    #[test]
    fn ehyb_context_carries_plan() {
        let ctx = ctx_for(EngineKind::Ehyb);
        let plan = ctx.plan().expect("plan");
        assert_eq!(plan.matrix.n, ctx.nrows());
        assert!(ctx_for(EngineKind::Merge).plan().is_none());
    }

    #[test]
    fn auto_picks_ehyb_on_partition_friendly_mesh() {
        // A mesh with strong locality: EHYB's u16-column bound beats the
        // CSR-family bound, so Auto must resolve to Ehyb.
        let m = unstructured_mesh::<f64>(48, 48, 0.3, 1);
        let ctx = SpmvContext::builder(m)
            .engine(EngineKind::Auto)
            .config(PreprocessConfig { vec_size_override: Some(512), ..Default::default() })
            .build()
            .unwrap();
        assert_eq!(ctx.requested_kind(), EngineKind::Auto);
        assert_eq!(ctx.kind(), EngineKind::Ehyb);
        assert!(ctx.plan().is_some());
    }

    #[test]
    fn auto_on_non_square_falls_back_to_baseline() {
        use crate::sparse::coo::Coo;
        let mut coo = Coo::<f64>::new(4, 6);
        for i in 0..4 {
            coo.push(i, i, 1.0);
            coo.push(i, i + 2, 0.5);
        }
        let ctx = SpmvContext::builder(coo.to_csr()).engine(EngineKind::Auto).build().unwrap();
        assert_ne!(ctx.kind(), EngineKind::Ehyb);
        let y = ctx.spmv_alloc(&[1.0; 6]).unwrap();
        assert_eq!(y.len(), 4);
    }

    #[test]
    fn ehyb_rejects_non_square_with_typed_error() {
        use crate::sparse::coo::Coo;
        let m = Coo::<f64>::new(3, 4).to_csr();
        match SpmvContext::builder(m).engine(EngineKind::Ehyb).build() {
            Err(EhybError::UnsupportedFormat(_)) => {}
            other => panic!("expected UnsupportedFormat, got {:?}", other.err()),
        }
    }

    #[test]
    fn solver_handle_solves() {
        let ctx = ctx_for(EngineKind::Ehyb);
        let n = ctx.nrows();
        let b: Vec<f64> = (0..n).map(|i| ((i * 37 + 11) % 23) as f64 / 23.0 - 0.5).collect();
        let pre = Jacobi::new(ctx.matrix());
        let (x, rep) = ctx.solver().cg(&b, None, &pre, &SolverConfig::default()).unwrap();
        assert!(rep.converged, "{rep:?}");
        let mut ax = vec![0.0; n];
        ctx.matrix().spmv(&x, &mut ax);
        assert_allclose(&ax, &b, 1e-6, 1e-6).unwrap();
        // Wrong-length rhs is a typed error.
        assert!(matches!(
            ctx.solver().cg(&b[..n - 1], None, &pre, &SolverConfig::default()),
            Err(EhybError::DimensionMismatch { .. })
        ));
    }
}
