//! Borrowed multi-vector views over one contiguous allocation.
//!
//! The seed's batched SpMV signature (`&[&[S]]` in, `&mut [Vec<S>]` out)
//! forced every caller to own `Vec<Vec<S>>` and re-slice per call, and
//! let the batch scatter across N heap allocations. [`VecBatch`] /
//! [`VecBatchMut`] replace it with views over **column-major contiguous
//! storage**: vector `b` of a width-`W` batch over length-`n` vectors is
//! the slice `data[b*n .. (b+1)*n]`. One allocation per batch, cheap
//! column access, and a layout the blocked SpMM kernel, the service's
//! fused drain, and `cg_many` can all share. [`BatchBuf`] is the owned
//! companion that hands out the views.

use crate::api::error::EhybError;
use crate::sparse::scalar::Scalar;

/// Immutable view of a batch of equal-length vectors in one contiguous
/// column-major slice.
#[derive(Clone, Copy, Debug)]
pub struct VecBatch<'a, S> {
    data: &'a [S],
    n: usize,
}

impl<'a, S: Scalar> VecBatch<'a, S> {
    /// View `data` as a batch of vectors of length `n`. Errors unless
    /// `data.len()` is a whole number of vectors.
    pub fn new(data: &'a [S], n: usize) -> crate::Result<Self> {
        if n == 0 {
            if !data.is_empty() {
                return Err(EhybError::DimensionMismatch {
                    what: "batch storage (n = 0 requires empty data)",
                    expected: 0,
                    got: data.len(),
                });
            }
            return Ok(Self { data, n });
        }
        if data.len() % n != 0 {
            return Err(EhybError::DimensionMismatch {
                what: "batch storage (must be width * n elements)",
                expected: n * (data.len() / n + 1),
                got: data.len(),
            });
        }
        Ok(Self { data, n })
    }

    /// Vector length (rows per column).
    pub fn n(&self) -> usize {
        self.n
    }

    /// Number of vectors in the batch.
    pub fn width(&self) -> usize {
        if self.n == 0 {
            0
        } else {
            self.data.len() / self.n
        }
    }

    /// Vector `b` of the batch.
    #[inline]
    pub fn col(&self, b: usize) -> &'a [S] {
        &self.data[b * self.n..(b + 1) * self.n]
    }

    /// Iterate over the vectors in batch order.
    pub fn cols(&self) -> impl Iterator<Item = &'a [S]> + '_ {
        self.data.chunks(self.n.max(1))
    }

    /// The whole contiguous storage.
    pub fn as_slice(&self) -> &'a [S] {
        self.data
    }
}

/// Mutable view of a batch of equal-length vectors in one contiguous
/// column-major slice.
#[derive(Debug)]
pub struct VecBatchMut<'a, S> {
    data: &'a mut [S],
    n: usize,
}

impl<'a, S: Scalar> VecBatchMut<'a, S> {
    /// View `data` as a mutable batch of vectors of length `n`.
    pub fn new(data: &'a mut [S], n: usize) -> crate::Result<Self> {
        VecBatch::new(&*data, n)?; // same shape validation
        Ok(Self { data, n })
    }

    pub fn n(&self) -> usize {
        self.n
    }

    pub fn width(&self) -> usize {
        if self.n == 0 {
            0
        } else {
            self.data.len() / self.n
        }
    }

    /// Vector `b` of the batch, mutably.
    #[inline]
    pub fn col_mut(&mut self, b: usize) -> &mut [S] {
        &mut self.data[b * self.n..(b + 1) * self.n]
    }

    /// Vector `b` of the batch, immutably.
    #[inline]
    pub fn col(&self, b: usize) -> &[S] {
        &self.data[b * self.n..(b + 1) * self.n]
    }

    /// Iterate over the vectors mutably, in batch order.
    pub fn cols_mut(&mut self) -> impl Iterator<Item = &mut [S]> + '_ {
        self.data.chunks_mut(self.n.max(1))
    }

    /// Reborrow as an immutable batch view.
    pub fn as_batch(&self) -> VecBatch<'_, S> {
        VecBatch { data: self.data, n: self.n }
    }
}

/// Owned column-major batch storage: one `Vec<S>` holding `width`
/// vectors of length `n`, handing out [`VecBatch`]/[`VecBatchMut`]
/// views. The allocation persists across calls, so repeated batched
/// SpMVs are allocation-free.
#[derive(Clone, Debug)]
pub struct BatchBuf<S> {
    data: Vec<S>,
    n: usize,
}

impl<S: Scalar> BatchBuf<S> {
    /// `width` zero vectors of length `n`.
    pub fn zeros(n: usize, width: usize) -> Self {
        Self { data: vec![S::ZERO; n * width], n }
    }

    /// Copy a set of equal-length columns into contiguous storage.
    pub fn from_cols(cols: &[&[S]]) -> crate::Result<Self> {
        let n = cols.first().map_or(0, |c| c.len());
        let mut data = Vec::with_capacity(n * cols.len());
        for col in cols {
            if col.len() != n {
                return Err(EhybError::DimensionMismatch {
                    what: "batch column",
                    expected: n,
                    got: col.len(),
                });
            }
            data.extend_from_slice(col);
        }
        Ok(Self { data, n })
    }

    pub fn n(&self) -> usize {
        self.n
    }

    pub fn width(&self) -> usize {
        if self.n == 0 {
            0
        } else {
            self.data.len() / self.n
        }
    }

    /// Resize in place to `width` vectors (new columns are zeroed).
    pub fn set_width(&mut self, width: usize) {
        self.data.resize(self.n * width, S::ZERO);
    }

    #[inline]
    pub fn col(&self, b: usize) -> &[S] {
        &self.data[b * self.n..(b + 1) * self.n]
    }

    #[inline]
    pub fn col_mut(&mut self, b: usize) -> &mut [S] {
        &mut self.data[b * self.n..(b + 1) * self.n]
    }

    /// Immutable view of the whole batch.
    pub fn view(&self) -> VecBatch<'_, S> {
        VecBatch { data: &self.data, n: self.n }
    }

    /// Mutable view of the whole batch.
    pub fn view_mut(&mut self) -> VecBatchMut<'_, S> {
        VecBatchMut { data: &mut self.data, n: self.n }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn view_shape_validated() {
        let data = [1.0f64, 2.0, 3.0, 4.0, 5.0, 6.0];
        let b = VecBatch::new(&data, 3).unwrap();
        assert_eq!(b.width(), 2);
        assert_eq!(b.col(0), &[1.0, 2.0, 3.0]);
        assert_eq!(b.col(1), &[4.0, 5.0, 6.0]);
        assert!(matches!(
            VecBatch::new(&data, 4),
            Err(EhybError::DimensionMismatch { .. })
        ));
    }

    #[test]
    fn empty_batch() {
        let data: [f64; 0] = [];
        let b = VecBatch::new(&data, 5).unwrap();
        assert_eq!(b.width(), 0);
        assert_eq!(b.cols().count(), 0);
    }

    #[test]
    fn mut_view_writes_through() {
        let mut data = vec![0.0f64; 6];
        {
            let mut b = VecBatchMut::new(&mut data, 2).unwrap();
            assert_eq!(b.width(), 3);
            b.col_mut(1).copy_from_slice(&[7.0, 8.0]);
        }
        assert_eq!(data, vec![0.0, 0.0, 7.0, 8.0, 0.0, 0.0]);
    }

    #[test]
    fn buf_round_trip() {
        let xs: Vec<Vec<f64>> = vec![vec![1.0, 2.0], vec![3.0, 4.0]];
        let refs: Vec<&[f64]> = xs.iter().map(|v| v.as_slice()).collect();
        let buf = BatchBuf::from_cols(&refs).unwrap();
        assert_eq!(buf.width(), 2);
        assert_eq!(buf.view().col(1), &[3.0, 4.0]);
        let mut out = BatchBuf::<f64>::zeros(2, 2);
        out.col_mut(0).copy_from_slice(buf.col(0));
        assert_eq!(out.view().col(0), &[1.0, 2.0]);
    }

    #[test]
    fn from_cols_rejects_ragged() {
        let a = [1.0f64, 2.0];
        let b = [3.0f64];
        assert!(BatchBuf::from_cols(&[&a[..], &b[..]]).is_err());
    }

    #[test]
    fn set_width_preserves_prefix() {
        let mut buf = BatchBuf::<f64>::zeros(3, 1);
        buf.col_mut(0).copy_from_slice(&[1.0, 2.0, 3.0]);
        buf.set_width(3);
        assert_eq!(buf.width(), 3);
        assert_eq!(buf.col(0), &[1.0, 2.0, 3.0]);
        assert_eq!(buf.col(2), &[0.0, 0.0, 0.0]);
    }
}
