//! Analytic roofline model: closed-form bytes-per-nonzero for each
//! format, giving the *theory performance up-boundary* the paper's
//! abstract refers to ("leads to higher FLOPs than the theory
//! performance up-boundary of the existing GPU-based SpMV
//! implementations"). The simulator measures; this model explains.
//!
//! For a memory-bound kernel, `GFLOPS ≤ 2 · BW / bytes_per_nnz`. The
//! boundary for conventional formats assumes every x element is fetched
//! from HBM exactly once (perfect implicit caching — unattainable);
//! EHYB's boundary is *higher* because the u16 columns shrink the
//! mandatory per-nnz stream below CSR's 4-byte floor.

use crate::sparse::csr::Csr;
use crate::sparse::ehyb::EhybMatrix;
use crate::sparse::scalar::Scalar;
use crate::gpu::device::GpuDevice;

/// Per-SpMV traffic decomposition (bytes), with everything optional
/// idealized: x fetched once, no cache misses beyond compulsory.
#[derive(Clone, Copy, Debug)]
pub struct TrafficModel {
    pub matrix_bytes: f64,
    pub x_bytes: f64,
    pub y_bytes: f64,
}

impl TrafficModel {
    pub fn total(&self) -> f64 {
        self.matrix_bytes + self.x_bytes + self.y_bytes
    }

    /// The compulsory-traffic floor in whole bytes: what a kernel must
    /// move even with perfect caching. The replayed simulator
    /// ([`crate::traffic`]) can only sit at or above this (sector
    /// rounding, conflict and capacity misses) — the conservation
    /// proptests in `tests/traffic.rs` gate exactly that inequality.
    pub fn compulsory_bytes(&self) -> u64 {
        self.total() as u64
    }

    /// Roofline GFLOPS on `dev` for `nnz` nonzeros.
    pub fn roofline_gflops(&self, nnz: usize, dev: &GpuDevice) -> f64 {
        2.0 * nnz as f64 / (self.total() / dev.hbm_bw) / 1e9
    }

    /// Idealized seconds per SpMV on `dev` (total bytes / HBM
    /// bandwidth) — the scalar the autotuner ranks candidate plans by
    /// (lower is better; same ordering as `roofline_gflops` at fixed
    /// nnz).
    pub fn predicted_secs(&self, dev: &GpuDevice) -> f64 {
        self.total() / dev.hbm_bw
    }
}

/// The paper's "theory up-boundary" for CSR-family formats: per nnz a
/// 4-byte column and a τ-byte value; x and y each touched once.
pub fn csr_bound<S: Scalar>(m: &Csr<S>) -> TrafficModel {
    let tau = S::BYTES as f64;
    TrafficModel {
        matrix_bytes: m.nnz() as f64 * (4.0 + tau) + (m.nrows() as f64 + 1.0) * 4.0,
        x_bytes: m.ncols() as f64 * tau,
        y_bytes: m.nrows() as f64 * tau,
    }
}

/// ELL-family bound: padding inflates both streams by the fill ratio.
pub fn ell_bound<S: Scalar>(m: &Csr<S>, fill_ratio: f64) -> TrafficModel {
    let tau = S::BYTES as f64;
    TrafficModel {
        matrix_bytes: m.nnz() as f64 * fill_ratio * (4.0 + tau),
        x_bytes: m.ncols() as f64 * tau,
        y_bytes: m.nrows() as f64 * tau,
    }
}

/// EHYB bound: ELL part streams 2-byte columns (×fill), ER part 4-byte;
/// x is read once into the caches (vec_size per partition) plus once per
/// ER entry in the worst case — idealized to once total, matching the
/// other bounds' optimism.
pub fn ehyb_bound<S: Scalar>(e: &EhybMatrix<S>) -> TrafficModel {
    let tau = S::BYTES as f64;
    let ell_slots = e.ell_vals.len() as f64;
    let er_slots = e.er_vals.len() as f64;
    TrafficModel {
        matrix_bytes: ell_slots * (2.0 + tau)
            + er_slots * (4.0 + tau)
            + e.y_idx_er.len() as f64 * 4.0
            + (e.num_slices() as f64 + e.er_slice_width.len() as f64) * 8.0,
        x_bytes: (e.num_parts * e.vec_size) as f64 * tau,
        y_bytes: e.padded_rows() as f64 * tau,
    }
}

/// Measured-vs-roofline efficiency: the L1 perf-pass metric
/// (DESIGN.md §9 — "match the paper's achieved/roofline efficiency
/// ratio, not absolute TFLOPs").
pub fn efficiency(measured_gflops: f64, bound: &TrafficModel, nnz: usize, dev: &GpuDevice) -> f64 {
    measured_gflops / bound.roofline_gflops(nnz, dev).max(1e-12)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::preprocess::{EhybPlan, PreprocessConfig};
    use crate::sparse::gen::{poisson2d, unstructured_mesh};

    #[test]
    fn csr_bound_scales_with_tau() {
        let m32 = poisson2d::<f32>(32, 32);
        let m64 = poisson2d::<f64>(32, 32);
        let b32 = csr_bound(&m32);
        let b64 = csr_bound(&m64);
        assert!(b64.total() > b32.total());
        let dev = GpuDevice::v100();
        assert!(b32.roofline_gflops(m32.nnz(), &dev) > b64.roofline_gflops(m64.nnz(), &dev));
    }

    #[test]
    fn ehyb_bound_beats_csr_bound_when_er_small() {
        // The abstract's claim: EHYB's boundary exceeds the conventional
        // one because of the u16 columns — provided ER stays small.
        let m = unstructured_mesh::<f64>(48, 48, 0.3, 1);
        let plan = EhybPlan::build(
            &m,
            &PreprocessConfig { vec_size_override: Some(512), ..Default::default() },
        )
        .unwrap();
        let dev = GpuDevice::v100();
        let csr = csr_bound(&m).roofline_gflops(m.nnz(), &dev);
        let eh = ehyb_bound(&plan.matrix).roofline_gflops(plan.matrix.nnz(), &dev);
        assert!(
            eh > csr,
            "ehyb bound {eh} <= csr bound {csr} (er_frac {}, fill {})",
            plan.matrix.er_fraction(),
            plan.matrix.ell_fill_ratio()
        );
    }

    #[test]
    fn predicted_secs_orders_like_gflops() {
        let m = poisson2d::<f64>(32, 32);
        let dev = GpuDevice::v100();
        let csr = csr_bound(&m);
        let ell = ell_bound(&m, 2.0);
        // More bytes => more predicted seconds => fewer roofline GFLOPS.
        assert!(ell.predicted_secs(&dev) > csr.predicted_secs(&dev));
        assert!(ell.roofline_gflops(m.nnz(), &dev) < csr.roofline_gflops(m.nnz(), &dev));
        assert!((csr.predicted_secs(&dev) - csr.total() / dev.hbm_bw).abs() < 1e-18);
    }

    #[test]
    fn efficiency_bounded_by_one_for_sim() {
        use crate::gpu::{kernels, simulate};
        let m = poisson2d::<f64>(64, 64);
        let dev = GpuDevice::v100();
        let r = simulate(&kernels::csr_vector_alg1(&m, &dev), &dev);
        let eff = efficiency(r.gflops, &csr_bound(&m), m.nnz(), &dev);
        assert!(eff > 0.0 && eff <= 1.05, "eff={eff}"); // small slack: model idealizes row_ptr
    }
}
