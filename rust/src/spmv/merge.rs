//! Merge-based SpMV (Merrill & Garland 2016, paper ref [17]): the
//! (row_ptr, nnz) merge path is split into equal-length segments, one per
//! "team" (CTA on the GPU). Each team binary-searches its starting
//! diagonal and processes its segment, carrying partial row sums across
//! team boundaries. Perfectly load-balanced in (rows + nnz) regardless of
//! the row-length distribution — the property that makes it the robust
//! baseline the paper compares against.

use super::SpmvEngine;
use crate::sparse::csr::Csr;
use crate::sparse::scalar::Scalar;

pub struct MergeSpmv<S: Scalar> {
    m: Csr<S>,
    /// Work items per team (tunable; GPU uses items ≈ CTA tile).
    items_per_team: usize,
}

impl<S: Scalar> MergeSpmv<S> {
    pub fn new(m: &Csr<S>) -> Self {
        Self { m: m.clone(), items_per_team: 256 }
    }

    pub fn with_items_per_team(m: &Csr<S>, items: usize) -> Self {
        Self { m: m.clone(), items_per_team: items.max(1) }
    }

    /// Split diagonal `d` of the merge path into (rows consumed, nnz
    /// consumed): the largest `r` with `row_ptr[r] + r ≤ d` (the function
    /// is strictly increasing in `r`, so this is a plain binary search).
    fn merge_path_search(&self, d: usize) -> (usize, usize) {
        let nnz = self.m.nnz();
        let mut lo = d.saturating_sub(nnz);
        let mut hi = d.min(self.m.nrows());
        while lo < hi {
            let mid = (lo + hi + 1) / 2;
            if self.m.row_ptr[mid] as usize + mid <= d {
                lo = mid;
            } else {
                hi = mid - 1;
            }
        }
        (lo, d - lo)
    }
}

impl<S: Scalar> SpmvEngine<S> for MergeSpmv<S> {
    fn name(&self) -> &'static str {
        "merge"
    }

    fn spmv(&self, x: &[S], y: &mut [S]) {
        let m = &self.m;
        assert_eq!(x.len(), m.ncols());
        assert_eq!(y.len(), m.nrows());
        let nrows = m.nrows();
        let nnz = m.nnz();
        let total = nrows + nnz;
        let teams = total.div_ceil(self.items_per_team).max(1);

        y.fill(S::ZERO);
        // (row, partial) carry-outs per team, fixed up serially after —
        // the GPU version does this with a second fix-up kernel.
        let mut carries: Vec<(usize, S)> = Vec::with_capacity(teams);
        for t in 0..teams {
            let d0 = (t * total) / teams;
            let d1 = ((t + 1) * total) / teams;
            let (row0, nz0) = self.merge_path_search(d0);
            let (row_end, nz_end) = self.merge_path_search(d1);
            let mut nz = nz0;
            let mut acc = S::ZERO;
            // Rows fully ending inside this segment: the split at d1
            // guarantees row_ptr[row_end] ≤ nz_end, so each such row's
            // entries all lie before nz_end.
            for row in row0..row_end {
                let rend = m.row_ptr[row + 1] as usize;
                while nz < rend {
                    acc = m.vals[nz].mul_add(x[m.col_idx[nz] as usize], acc);
                    nz += 1;
                }
                y[row] += acc;
                acc = S::ZERO;
            }
            // Tail: partial prefix of row_end.
            while nz < nz_end {
                acc = m.vals[nz].mul_add(x[m.col_idx[nz] as usize], acc);
                nz += 1;
            }
            carries.push((row_end, acc));
        }
        for (row, acc) in carries {
            if row < nrows {
                y[row] += acc;
            }
        }
    }

    fn nrows(&self) -> usize {
        self.m.nrows()
    }
    fn ncols(&self) -> usize {
        self.m.ncols()
    }
    fn nnz(&self) -> usize {
        self.m.nnz()
    }
    fn format_bytes(&self) -> usize {
        self.m.bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spmv::testutil::validate_engine;
    use crate::sparse::gen::{circuit, poisson2d, unstructured_mesh};
    use crate::sparse::coo::Coo;

    #[test]
    fn merge_path_search_endpoints() {
        let m = poisson2d::<f64>(4, 4);
        let e = MergeSpmv::new(&m);
        assert_eq!(e.merge_path_search(0), (0, 0));
        let (r, z) = e.merge_path_search(m.nrows() + m.nnz());
        assert_eq!((r, z), (m.nrows(), m.nnz()));
    }

    #[test]
    fn validates_regular() {
        let m = poisson2d::<f64>(13, 11);
        validate_engine(&MergeSpmv::new(&m), &m);
    }

    #[test]
    fn validates_irregular() {
        let m = circuit::<f64>(800, 4, 0.05, 17);
        validate_engine(&MergeSpmv::new(&m), &m);
    }

    #[test]
    fn validates_many_team_sizes() {
        let m = unstructured_mesh::<f64>(16, 16, 0.5, 4);
        for items in [1usize, 7, 32, 257, 100_000] {
            validate_engine(&MergeSpmv::with_items_per_team(&m, items), &m);
        }
    }

    #[test]
    fn empty_rows_handled() {
        // Rows 1 and 3 empty; merge path must cross them without stalls.
        let m = Coo::<f64>::from_triplets(5, 5, vec![(0, 0, 1.0), (2, 2, 2.0), (4, 4, 3.0)])
            .unwrap()
            .to_csr();
        for items in [1usize, 2, 4, 64] {
            validate_engine(&MergeSpmv::with_items_per_team(&m, items), &m);
        }
    }

    #[test]
    fn single_long_row_split_across_teams() {
        let mut coo = Coo::<f64>::new(1, 1000);
        for j in 0..1000 {
            coo.push(0, j, 1.0);
        }
        let m = coo.to_csr();
        let e = MergeSpmv::with_items_per_team(&m, 64);
        let x = vec![1.0; 1000];
        let mut y = vec![0.0; 1];
        e.spmv(&x, &mut y);
        assert_eq!(y[0], 1000.0);
    }
}
