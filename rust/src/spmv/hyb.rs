//! HYB engine (cuSPARSE-HYB analogue): auto-width ELL + COO tail.

use super::SpmvEngine;
use crate::sparse::csr::Csr;
use crate::sparse::hyb::Hyb;
use crate::sparse::scalar::Scalar;

pub struct HybEngine<S: Scalar> {
    h: Hyb<S>,
    nrows: usize,
}

impl<S: Scalar> HybEngine<S> {
    pub fn new(m: &Csr<S>) -> Self {
        Self { h: Hyb::from_csr_auto(m, 2.0 / 3.0), nrows: m.nrows() }
    }
    /// Explicit scalar leg (the trait `spmv` dispatches on the `simd`
    /// feature; this twin is always available for tests/benches).
    pub fn spmv_scalar(&self, x: &[S], y: &mut [S]) {
        self.h.spmv_scalar(x, y);
    }
    /// Explicit SIMD leg — ELL part packed, COO tail shared; bitwise
    /// equal to the scalar twin for finite `x` (see [`Hyb::spmv_simd`]).
    pub fn spmv_simd(&self, x: &[S], y: &mut [S]) {
        self.h.spmv_simd(x, y);
    }
}

impl<S: Scalar> SpmvEngine<S> for HybEngine<S> {
    fn name(&self) -> &'static str {
        "hyb"
    }
    fn spmv(&self, x: &[S], y: &mut [S]) {
        self.h.spmv(x, y);
    }
    fn nrows(&self) -> usize {
        self.nrows
    }
    fn ncols(&self) -> usize {
        self.h.ell.ncols()
    }
    fn nnz(&self) -> usize {
        self.h.nnz()
    }
    fn format_bytes(&self) -> usize {
        self.h.bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spmv::testutil::validate_engine;
    use crate::sparse::gen::circuit;

    #[test]
    fn validates_on_skewed() {
        let m = circuit::<f64>(600, 3, 0.05, 21);
        validate_engine(&HybEngine::new(&m), &m);
    }
}
