//! CSR "scalar" engine: one pass per row, the textbook kernel
//! (one CUDA thread per row in Bell & Garland's csr-scalar). On CPU this
//! is also the strongest serial layout, so it doubles as the wall-clock
//! reference for the perf pass.

use super::SpmvEngine;
use crate::sparse::csr::Csr;
use crate::sparse::scalar::Scalar;

pub struct CsrScalar<S: Scalar> {
    m: Csr<S>,
    profile: crate::profile::ProfileState,
}

impl<S: Scalar> CsrScalar<S> {
    pub fn new(m: &Csr<S>) -> Self {
        Self { m: m.clone(), profile: crate::profile::ProfileState::new() }
    }
}

impl<S: Scalar> SpmvEngine<S> for CsrScalar<S> {
    fn name(&self) -> &'static str {
        "csr-scalar"
    }

    fn spmv(&self, x: &[S], y: &mut [S]) {
        let t = crate::profile::timer();
        let m = &self.m;
        assert_eq!(x.len(), m.ncols());
        assert_eq!(y.len(), m.nrows());
        let row_ptr = &m.row_ptr;
        let cols = &m.col_idx;
        let vals = &m.vals;
        for i in 0..m.nrows() {
            let lo = row_ptr[i] as usize;
            let hi = row_ptr[i + 1] as usize;
            let mut acc = S::ZERO;
            for k in lo..hi {
                // Safety note: indices validated at construction.
                acc = vals[k].mul_add(x[cols[k] as usize], acc);
            }
            y[i] = acc;
        }
        self.profile.record(1, crate::profile::elapsed(t), || {
            crate::profile::CallCost::of_csr(&self.m)
        });
    }

    fn nrows(&self) -> usize {
        self.m.nrows()
    }
    fn ncols(&self) -> usize {
        self.m.ncols()
    }
    fn nnz(&self) -> usize {
        self.m.nnz()
    }
    fn format_bytes(&self) -> usize {
        self.m.bytes()
    }
    fn kernel_profile(&self) -> Option<crate::profile::KernelProfile> {
        self.profile.snapshot("csr-scalar")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spmv::testutil::validate_engine;
    use crate::sparse::gen::{circuit, poisson2d};

    #[test]
    fn validates_f64() {
        let m = poisson2d::<f64>(15, 17);
        validate_engine(&CsrScalar::new(&m), &m);
    }

    #[test]
    fn validates_f32() {
        let m = circuit::<f32>(400, 4, 0.05, 3);
        validate_engine(&CsrScalar::new(&m), &m);
    }
}
