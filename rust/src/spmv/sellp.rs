//! SELL-P engine (paper ref [2], the format EHYB's ELL part extends).

use super::SpmvEngine;
use crate::sparse::csr::Csr;
use crate::sparse::scalar::Scalar;
use crate::sparse::sellp::SellP;

pub struct SellPEngine<S: Scalar> {
    s: SellP<S>,
    nnz: usize,
}

impl<S: Scalar> SellPEngine<S> {
    pub fn new(m: &Csr<S>) -> Self {
        Self { s: SellP::from_csr(m, 32), nnz: m.nnz() }
    }
    pub fn with_slice_height(m: &Csr<S>, h: usize) -> Self {
        Self { s: SellP::from_csr(m, h), nnz: m.nnz() }
    }
    /// Explicit scalar leg (the trait `spmv` dispatches on the `simd`
    /// feature; this twin is always available for tests/benches).
    pub fn spmv_scalar(&self, x: &[S], y: &mut [S]) {
        self.s.spmv_scalar(x, y);
    }
    /// Explicit SIMD leg — bitwise equal to the scalar twin for finite
    /// `x` (see [`SellP::spmv_simd`]).
    pub fn spmv_simd(&self, x: &[S], y: &mut [S]) {
        self.s.spmv_simd(x, y);
    }
}

impl<S: Scalar> SpmvEngine<S> for SellPEngine<S> {
    fn name(&self) -> &'static str {
        "sellp"
    }
    fn spmv(&self, x: &[S], y: &mut [S]) {
        self.s.spmv(x, y);
    }
    fn nrows(&self) -> usize {
        self.s.nrows()
    }
    fn ncols(&self) -> usize {
        self.s.ncols()
    }
    fn nnz(&self) -> usize {
        self.nnz
    }
    fn format_bytes(&self) -> usize {
        self.s.bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spmv::testutil::validate_engine;
    use crate::sparse::gen::unstructured_mesh;

    #[test]
    fn validates() {
        let m = unstructured_mesh::<f64>(18, 18, 0.5, 2);
        validate_engine(&SellPEngine::new(&m), &m);
    }
}
