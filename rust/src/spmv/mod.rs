//! CPU SpMV engines: the optimized EHYB hot path plus every baseline the
//! paper compares against (§5): CSR scalar/vector (cuSPARSE ALG1/ALG2
//! analogues), ELL, HYB, SELL-P, merge-based (Merrill & Garland), and a
//! CSR5-like tiled engine. All engines implement [`SpmvEngine`] and are
//! validated against the f64 CSR oracle.
//!
//! These serve two roles:
//! 1. wall-clock baselines for the L3 perf pass (SpMV is memory-bound on
//!    CPU too, so relative format behaviour is meaningful), and
//! 2. executable semantics for the GPU-simulated kernels in
//!    [`crate::gpu::kernels`] (same traversal order, so the simulator's
//!    traffic counts describe exactly this arithmetic).

pub mod csr_scalar;
pub mod csr_vector;
pub mod ell;
pub mod hyb;
pub mod sellp;
pub mod merge;
pub mod csr5;
pub mod ehyb_cpu;
pub mod registry;

use crate::sparse::scalar::Scalar;

/// A prepared SpMV engine: `y = A x` for the matrix it was built from.
pub trait SpmvEngine<S: Scalar>: Send + Sync {
    /// Engine name as it appears in reports (matches the paper's labels).
    fn name(&self) -> &'static str;
    /// Execute one SpMV.
    fn spmv(&self, x: &[S], y: &mut [S]);
    /// Execute SpMV for a batch of input vectors sharing this matrix:
    /// `ys[i] = A xs[i]`, with each `ys[i]` resized to [`Self::nrows`].
    ///
    /// SpMV is memory-bound, so engines with a real SpMM path override
    /// this to stream the matrix **once** per batch (arithmetic
    /// intensity × batch width). The default keeps every baseline
    /// correct by looping [`Self::spmv`]; overrides must stay
    /// element-wise identical to that loop.
    fn spmv_batch(&self, xs: &[&[S]], ys: &mut [Vec<S>]) {
        assert_eq!(xs.len(), ys.len(), "batch inputs/outputs disagree");
        for (x, y) in xs.iter().zip(ys.iter_mut()) {
            // Size without zero-filling recycled buffers: `spmv`
            // overwrites every row.
            if y.len() != self.nrows() {
                y.clear();
                y.resize(self.nrows(), S::ZERO);
            }
            self.spmv(x, y);
        }
    }
    /// Rows of the underlying matrix.
    fn nrows(&self) -> usize;
    /// Logical nonzeros (for GFLOPS accounting: 2·nnz flops per SpMV).
    fn nnz(&self) -> usize;
    /// Device-memory bytes the format occupies (traffic-model input).
    fn format_bytes(&self) -> usize;
}

/// GFLOPS for `secs` per SpMV at this engine's nnz (2 flops per entry —
/// the convention the paper's figures use).
pub fn gflops(nnz: usize, secs: f64) -> f64 {
    if secs <= 0.0 {
        return 0.0;
    }
    2.0 * nnz as f64 / secs / 1e9
}

#[cfg(test)]
pub(crate) mod testutil {
    use super::*;
    use crate::sparse::csr::Csr;
    use crate::util::check::assert_allclose;

    /// Validate `engine` against the f64 oracle on a deterministic x.
    pub fn validate_engine<S: Scalar>(engine: &dyn SpmvEngine<S>, csr: &Csr<S>) {
        let n = csr.ncols();
        let x: Vec<S> =
            (0..n).map(|i| S::from_f64(((i * 13 + 5) % 23) as f64 * 0.125 - 1.0)).collect();
        let oracle = csr.spmv_f64_oracle(&x);
        let mut y = vec![S::ZERO; csr.nrows()];
        engine.spmv(&x, &mut y);
        let y64: Vec<f64> = y.iter().map(|v| v.to_f64()).collect();
        let (rtol, atol) = if S::BYTES == 4 { (1e-4, 1e-4) } else { (1e-10, 1e-10) };
        assert_allclose(&y64, &oracle, rtol, atol)
            .unwrap_or_else(|e| panic!("{} mismatch: {e}", engine.name()));
        assert_eq!(engine.nrows(), csr.nrows());
        assert_eq!(engine.nnz(), csr.nnz(), "{} nnz", engine.name());
        assert!(engine.format_bytes() > 0);
        // The batched entry must agree with the single-vector path
        // bit-for-bit: blocked kernels keep per-row accumulation order.
        let xs: Vec<Vec<S>> = (0..3)
            .map(|t| {
                (0..n)
                    .map(|i| S::from_f64((((i * 7 + t * 11 + 3) % 19) as f64) * 0.25 - 2.0))
                    .collect()
            })
            .collect();
        let xrefs: Vec<&[S]> = xs.iter().map(|v| v.as_slice()).collect();
        let mut ys: Vec<Vec<S>> = vec![Vec::new(); xs.len()];
        engine.spmv_batch(&xrefs, &mut ys);
        for (xb, yb) in xs.iter().zip(&ys) {
            let mut y1 = vec![S::ZERO; engine.nrows()];
            engine.spmv(xb, &mut y1);
            assert_eq!(&y1, yb, "{}: spmv_batch != repeated spmv", engine.name());
        }
    }
}
