//! CPU SpMV engines: the optimized EHYB hot path plus every baseline the
//! paper compares against (§5): CSR scalar/vector (cuSPARSE ALG1/ALG2
//! analogues), ELL, HYB, SELL-P, merge-based (Merrill & Garland), and a
//! CSR5-like tiled engine. All engines implement [`SpmvEngine`] and are
//! validated against the f64 CSR oracle.
//!
//! These serve two roles:
//! 1. wall-clock baselines for the L3 perf pass (SpMV is memory-bound on
//!    CPU too, so relative format behaviour is meaningful), and
//! 2. executable semantics for the GPU-simulated kernels in
//!    [`crate::gpu::kernels`] (same traversal order, so the simulator's
//!    traffic counts describe exactly this arithmetic).
//!
//! Callers normally reach engines through the
//! [`SpmvContext`](crate::api::SpmvContext) facade, which adds
//! dimension checking with typed errors on top of the raw trait.

pub mod csr_scalar;
pub mod csr_vector;
pub mod ell;
pub mod hyb;
pub mod sellp;
pub mod merge;
pub mod csr5;
pub mod ehyb_cpu;
// NOTE: the old `registry` module (duplicate engine-construction paths
// for the harness sweep) is retired — build one `SpmvContext` per
// `EngineKind` via `crate::api::all_contexts` instead.

use crate::sparse::scalar::Scalar;
pub use crate::api::batch::{VecBatch, VecBatchMut};

/// A prepared SpMV engine: `y = A x` for the matrix it was built from.
pub trait SpmvEngine<S: Scalar>: Send + Sync {
    /// Engine name as it appears in reports (matches the paper's labels).
    fn name(&self) -> &'static str;
    /// Execute one SpMV.
    fn spmv(&self, x: &[S], y: &mut [S]);
    /// Execute SpMV for a batch of vectors sharing this matrix:
    /// `ys.col(b) = A xs.col(b)` for every column of the borrowed
    /// contiguous views (one allocation per side, not N).
    ///
    /// SpMV is memory-bound, so engines with a real SpMM path override
    /// this to stream the matrix **once** per batch (arithmetic
    /// intensity × batch width). The default keeps every baseline
    /// correct by looping [`Self::spmv`]; overrides must stay
    /// element-wise identical to that loop.
    fn spmv_batch(&self, xs: VecBatch<'_, S>, ys: &mut VecBatchMut<'_, S>) {
        assert_eq!(xs.width(), ys.width(), "batch inputs/outputs disagree");
        for b in 0..xs.width() {
            self.spmv(xs.col(b), ys.col_mut(b));
        }
    }
    /// Deprecated shim with the seed's scattered-allocation batch shape
    /// (`&[&[S]]` in, `&mut [Vec<S>]` out, each `ys[i]` resized to
    /// [`Self::nrows`]). Packs into contiguous storage and runs
    /// [`Self::spmv_batch`], so results are bit-identical to the view
    /// path.
    #[deprecated(since = "0.2.0", note = "use spmv_batch with VecBatch/VecBatchMut views")]
    fn spmv_batch_vecs(&self, xs: &[&[S]], ys: &mut [Vec<S>]) {
        assert_eq!(xs.len(), ys.len(), "batch inputs/outputs disagree");
        if xs.is_empty() {
            return;
        }
        let n = xs[0].len();
        let mut xbuf = Vec::with_capacity(n * xs.len());
        for x in xs {
            assert_eq!(x.len(), n, "batch inputs have unequal lengths");
            xbuf.extend_from_slice(x);
        }
        let nrows = self.nrows();
        let mut ybuf = vec![S::ZERO; nrows * xs.len()];
        {
            let xv = VecBatch::new(&xbuf, n).expect("contiguous batch");
            let mut yv = VecBatchMut::new(&mut ybuf, nrows).expect("contiguous batch");
            self.spmv_batch(xv, &mut yv);
        }
        for (b, y) in ys.iter_mut().enumerate() {
            // Size without zero-filling recycled buffers: the batch path
            // overwrites every row.
            if y.len() != nrows {
                y.clear();
                y.resize(nrows, S::ZERO);
            }
            y.copy_from_slice(&ybuf[b * nrows..(b + 1) * nrows]);
        }
    }
    /// Rows of the underlying matrix.
    fn nrows(&self) -> usize;
    /// Columns of the underlying matrix (defaults to square).
    fn ncols(&self) -> usize {
        self.nrows()
    }
    /// Logical nonzeros (for GFLOPS accounting: 2·nnz flops per SpMV).
    fn nnz(&self) -> usize;
    /// Device-memory bytes the format occupies (traffic-model input).
    fn format_bytes(&self) -> usize;
    /// The engine's internally-permuted kernel, when it has one.
    /// Engines that permute vectors internally (EHYB permutes into its
    /// partitioned new order on every call) expose it here so outer
    /// permutation adapters ([`crate::reorder::ReorderedEngine`]) can
    /// **fuse** both permutations into one gather per side instead of
    /// two full passes over x and y. Default: no internal permutation.
    fn permuted_kernel(&self) -> Option<&dyn PermutedSpmv<S>> {
        None
    }
    /// Observed data-movement counters since the engine was built, when
    /// this engine is instrumented (EHYB, the CSR walks, shard
    /// fan-outs) and the `profile` feature recorded at least one call.
    /// Default: not instrumented. Recording must never change results —
    /// `tests/profile.rs` pins bitwise identity for every engine kind.
    fn kernel_profile(&self) -> Option<crate::profile::KernelProfile> {
        None
    }
}

/// Capability trait for engines whose `spmv` is really
/// `permute_in → kernel → permute_out`: exposes the internal
/// permutation pair and the raw kernel so a wrapping adapter can
/// compose its own permutation with the engine's at build time
/// (gather fusion). The kernel runs in the engine's padded internal
/// index space of [`Self::padded_len`] elements.
pub trait PermutedSpmv<S: Scalar>: Send + Sync {
    /// Length of kernel-order vectors (≥ `nrows`; padding included).
    fn padded_len(&self) -> usize;
    /// `perm[old] = kernel index`; `len == nrows`.
    fn inner_perm(&self) -> &[u32];
    /// `iperm[kernel index] = old` (values `≥ nrows` mark padding
    /// slots); `len == padded_len`.
    fn inner_iperm(&self) -> &[u32];
    /// Run the kernel directly in internal index space:
    /// `yq = A_kernel xq`, both of [`Self::padded_len`] elements.
    fn spmv_permuted(&self, xq: &[S], yq: &mut [S]);
    /// Batched kernel in internal index space; every slice must be
    /// [`Self::padded_len`] long.
    fn spmv_batch_permuted(&self, xqs: &[&[S]], yqs: &mut [&mut [S]]);
}

/// GFLOPS for `secs` per SpMV at this engine's nnz (2 flops per entry —
/// the convention the paper's figures use).
pub fn gflops(nnz: usize, secs: f64) -> f64 {
    if secs <= 0.0 {
        return 0.0;
    }
    2.0 * nnz as f64 / secs / 1e9
}

#[cfg(test)]
pub(crate) mod testutil {
    use super::*;
    use crate::api::batch::BatchBuf;
    use crate::sparse::csr::Csr;
    use crate::util::check::assert_allclose;

    /// Validate `engine` against the f64 oracle on a deterministic x,
    /// then check that both batch entry points — the borrowed-view
    /// [`SpmvEngine::spmv_batch`] and the deprecated
    /// [`SpmvEngine::spmv_batch_vecs`] shim — are bit-identical to
    /// repeated single-vector calls.
    pub fn validate_engine<S: Scalar>(engine: &dyn SpmvEngine<S>, csr: &Csr<S>) {
        let n = csr.ncols();
        let x: Vec<S> =
            (0..n).map(|i| S::from_f64(((i * 13 + 5) % 23) as f64 * 0.125 - 1.0)).collect();
        let oracle = csr.spmv_f64_oracle(&x);
        let mut y = vec![S::ZERO; csr.nrows()];
        engine.spmv(&x, &mut y);
        let y64: Vec<f64> = y.iter().map(|v| v.to_f64()).collect();
        let (rtol, atol) = if S::BYTES == 4 { (1e-4, 1e-4) } else { (1e-10, 1e-10) };
        assert_allclose(&y64, &oracle, rtol, atol)
            .unwrap_or_else(|e| panic!("{} mismatch: {e}", engine.name()));
        assert_eq!(engine.nrows(), csr.nrows());
        assert_eq!(engine.ncols(), csr.ncols(), "{} ncols", engine.name());
        assert_eq!(engine.nnz(), csr.nnz(), "{} nnz", engine.name());
        assert!(engine.format_bytes() > 0);
        // Batched entries must agree with the single-vector path
        // bit-for-bit: blocked kernels keep per-row accumulation order.
        let xs: Vec<Vec<S>> = (0..3)
            .map(|t| {
                (0..n)
                    .map(|i| S::from_f64((((i * 7 + t * 11 + 3) % 19) as f64) * 0.25 - 2.0))
                    .collect()
            })
            .collect();
        let xrefs: Vec<&[S]> = xs.iter().map(|v| v.as_slice()).collect();
        // 1. Borrowed contiguous views.
        let xbatch = BatchBuf::from_cols(&xrefs).expect("equal-length columns");
        let mut ybatch = BatchBuf::<S>::zeros(engine.nrows(), xs.len());
        {
            let mut yv = ybatch.view_mut();
            engine.spmv_batch(xbatch.view(), &mut yv);
        }
        for (b, xb) in xs.iter().enumerate() {
            let mut y1 = vec![S::ZERO; engine.nrows()];
            engine.spmv(xb, &mut y1);
            assert_eq!(
                ybatch.col(b),
                &y1[..],
                "{}: spmv_batch (view) != repeated spmv",
                engine.name()
            );
        }
        // 2. Deprecated shim with the seed call shape.
        let mut ys: Vec<Vec<S>> = vec![Vec::new(); xs.len()];
        #[allow(deprecated)]
        engine.spmv_batch_vecs(&xrefs, &mut ys);
        for (b, yb) in ys.iter().enumerate() {
            assert_eq!(
                &yb[..],
                ybatch.col(b),
                "{}: deprecated shim != view batch path",
                engine.name()
            );
        }
    }
}
