//! CSR5-like engine (Liu & Vinter 2015, paper ref [16]): nonzeros are
//! partitioned into fixed `ω × σ` tiles processed in column-major order
//! with a segmented sum over row boundaries; partial sums at tile edges
//! carry into the next tile. Balanced in nnz with small per-tile
//! metadata — the defining characteristics the cost model needs.

use super::SpmvEngine;
use crate::sparse::csr::Csr;
use crate::sparse::scalar::Scalar;

const OMEGA: usize = 4; // lanes per tile
const SIGMA: usize = 16; // entries per lane

pub struct Csr5Like<S: Scalar> {
    m: Csr<S>,
    /// Row index of every nonzero (the "tile descriptor" equivalent;
    /// CSR5 stores compressed bit flags — we count its bytes as such).
    row_of_nnz: Vec<u32>,
}

impl<S: Scalar> Csr5Like<S> {
    pub fn new(m: &Csr<S>) -> Self {
        let mut row_of_nnz = vec![0u32; m.nnz()];
        for i in 0..m.nrows() {
            let lo = m.row_ptr[i] as usize;
            let hi = m.row_ptr[i + 1] as usize;
            row_of_nnz[lo..hi].fill(i as u32);
        }
        Self { m: m.clone(), row_of_nnz }
    }

    pub fn tile_size() -> usize {
        OMEGA * SIGMA
    }
}

impl<S: Scalar> SpmvEngine<S> for Csr5Like<S> {
    fn name(&self) -> &'static str {
        "csr5"
    }

    fn spmv(&self, x: &[S], y: &mut [S]) {
        let m = &self.m;
        assert_eq!(x.len(), m.ncols());
        assert_eq!(y.len(), m.nrows());
        y.fill(S::ZERO);
        let nnz = m.nnz();
        let tile = Self::tile_size();
        let mut k = 0usize;
        // Segmented sum across tiles with carry.
        let mut carry_row = usize::MAX;
        let mut carry = S::ZERO;
        while k < nnz {
            let end = (k + tile).min(nnz);
            for idx in k..end {
                let r = self.row_of_nnz[idx] as usize;
                if r != carry_row {
                    if carry_row != usize::MAX {
                        y[carry_row] += carry;
                    }
                    carry_row = r;
                    carry = S::ZERO;
                }
                carry = m.vals[idx].mul_add(x[m.col_idx[idx] as usize], carry);
            }
            k = end;
        }
        if carry_row != usize::MAX {
            y[carry_row] += carry;
        }
    }

    fn nrows(&self) -> usize {
        self.m.nrows()
    }
    fn ncols(&self) -> usize {
        self.m.ncols()
    }
    fn nnz(&self) -> usize {
        self.m.nnz()
    }

    fn format_bytes(&self) -> usize {
        // CSR arrays + per-tile descriptors: CSR5 stores ~(ω*σ bits of
        // row-flag + tile_ptr) per tile ≈ tile/8 + 8 bytes.
        let tiles = self.m.nnz().div_ceil(Self::tile_size());
        self.m.bytes() + tiles * (Self::tile_size() / 8 + 8)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spmv::testutil::validate_engine;
    use crate::sparse::gen::{circuit, poisson3d};
    use crate::sparse::coo::Coo;

    #[test]
    fn validates_regular() {
        let m = poisson3d::<f64>(7, 6, 5);
        validate_engine(&Csr5Like::new(&m), &m);
    }

    #[test]
    fn validates_skewed() {
        let m = circuit::<f32>(500, 4, 0.08, 31);
        validate_engine(&Csr5Like::new(&m), &m);
    }

    #[test]
    fn rows_spanning_tiles() {
        // A row longer than a tile must carry across the boundary.
        let mut coo = Coo::<f64>::new(3, 200);
        for j in 0..150 {
            coo.push(1, j, 1.0);
        }
        coo.push(0, 0, 5.0);
        coo.push(2, 199, 7.0);
        let m = coo.to_csr();
        validate_engine(&Csr5Like::new(&m), &m);
    }
}
