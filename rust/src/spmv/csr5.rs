//! CSR5-like engine (Liu & Vinter 2015, paper ref [16]): nonzeros are
//! partitioned into fixed `ω × σ` tiles processed in column-major order
//! with a segmented sum over row boundaries; partial sums at tile edges
//! carry into the next tile. Balanced in nnz with small per-tile
//! metadata — the defining characteristics the cost model needs.

use super::SpmvEngine;
use crate::sparse::csr::Csr;
use crate::sparse::scalar::Scalar;
use crate::util::lanes::{lane_width, Pack};

const OMEGA: usize = 4; // lanes per tile
const SIGMA: usize = 16; // entries per lane

pub struct Csr5Like<S: Scalar> {
    m: Csr<S>,
    /// Row index of every nonzero (the "tile descriptor" equivalent;
    /// CSR5 stores compressed bit flags — we count its bytes as such).
    row_of_nnz: Vec<u32>,
}

impl<S: Scalar> Csr5Like<S> {
    pub fn new(m: &Csr<S>) -> Self {
        let mut row_of_nnz = vec![0u32; m.nnz()];
        for i in 0..m.nrows() {
            let lo = m.row_ptr[i] as usize;
            let hi = m.row_ptr[i + 1] as usize;
            row_of_nnz[lo..hi].fill(i as u32);
        }
        Self { m: m.clone(), row_of_nnz }
    }

    pub fn tile_size() -> usize {
        OMEGA * SIGMA
    }

    /// Reference walk: fused multiply-add straight into the carry.
    pub fn spmv_scalar(&self, x: &[S], y: &mut [S]) {
        let m = &self.m;
        assert_eq!(x.len(), m.ncols());
        assert_eq!(y.len(), m.nrows());
        y.fill(S::ZERO);
        let nnz = m.nnz();
        let tile = Self::tile_size();
        let mut k = 0usize;
        // Segmented sum across tiles with carry.
        let mut carry_row = usize::MAX;
        let mut carry = S::ZERO;
        while k < nnz {
            let end = (k + tile).min(nnz);
            for idx in k..end {
                let r = self.row_of_nnz[idx] as usize;
                if r != carry_row {
                    if carry_row != usize::MAX {
                        y[carry_row] += carry;
                    }
                    carry_row = r;
                    carry = S::ZERO;
                }
                carry = m.vals[idx].mul_add(x[m.col_idx[idx] as usize], carry);
            }
            k = end;
        }
        if carry_row != usize::MAX {
            y[carry_row] += carry;
        }
    }

    /// Two-phase SIMD walk mirroring real CSR5: each tile's products
    /// `vals[idx] * x[col[idx]]` are computed in `W`-wide packs into a
    /// tile-local buffer, then the (inherently serial) segmented sum
    /// adds them into the carry. Splitting fma into mul-then-add
    /// re-associates each row's rounding chain, so this leg matches
    /// [`Self::spmv_scalar`] to 1e-9-relative, **not** bitwise — the
    /// one engine where the simd contract is allclose.
    pub fn spmv_simd(&self, x: &[S], y: &mut [S]) {
        match lane_width(S::BYTES) {
            16 => self.spmv_packed::<16>(x, y),
            8 => self.spmv_packed::<8>(x, y),
            4 => self.spmv_packed::<4>(x, y),
            _ => self.spmv_packed::<2>(x, y),
        }
    }

    fn spmv_packed<const W: usize>(&self, x: &[S], y: &mut [S]) {
        let m = &self.m;
        assert_eq!(x.len(), m.ncols());
        assert_eq!(y.len(), m.nrows());
        y.fill(S::ZERO);
        let nnz = m.nnz();
        let tile = Self::tile_size();
        let mut products = [S::ZERO; OMEGA * SIGMA];
        let mut k = 0usize;
        let mut carry_row = usize::MAX;
        let mut carry = S::ZERO;
        while k < nnz {
            let end = (k + tile).min(nnz);
            let len = end - k;
            // Phase 1: vectorized product pass over the tile.
            let mut j = 0;
            while j + W <= len {
                let v = Pack::<S, W>::load(&m.vals[k + j..k + j + W]);
                let mut xg = [S::ZERO; W];
                let mut l = 0;
                while l < W {
                    xg[l] = x[m.col_idx[k + j + l] as usize];
                    l += 1;
                }
                v.mul(Pack(xg)).store(&mut products[j..j + W]);
                j += W;
            }
            while j < len {
                products[j] = m.vals[k + j] * x[m.col_idx[k + j] as usize];
                j += 1;
            }
            // Phase 2: serial segmented sum over the buffered products.
            for (off, &p) in products[..len].iter().enumerate() {
                let r = self.row_of_nnz[k + off] as usize;
                if r != carry_row {
                    if carry_row != usize::MAX {
                        y[carry_row] += carry;
                    }
                    carry_row = r;
                    carry = S::ZERO;
                }
                carry += p;
            }
            k = end;
        }
        if carry_row != usize::MAX {
            y[carry_row] += carry;
        }
    }
}

impl<S: Scalar> SpmvEngine<S> for Csr5Like<S> {
    fn name(&self) -> &'static str {
        "csr5"
    }

    fn spmv(&self, x: &[S], y: &mut [S]) {
        if cfg!(feature = "simd") {
            self.spmv_simd(x, y)
        } else {
            self.spmv_scalar(x, y)
        }
    }

    fn nrows(&self) -> usize {
        self.m.nrows()
    }
    fn ncols(&self) -> usize {
        self.m.ncols()
    }
    fn nnz(&self) -> usize {
        self.m.nnz()
    }

    fn format_bytes(&self) -> usize {
        // CSR arrays + per-tile descriptors: CSR5 stores ~(ω*σ bits of
        // row-flag + tile_ptr) per tile ≈ tile/8 + 8 bytes.
        let tiles = self.m.nnz().div_ceil(Self::tile_size());
        self.m.bytes() + tiles * (Self::tile_size() / 8 + 8)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spmv::testutil::validate_engine;
    use crate::sparse::gen::{circuit, poisson3d};
    use crate::sparse::coo::Coo;

    #[test]
    fn validates_regular() {
        let m = poisson3d::<f64>(7, 6, 5);
        validate_engine(&Csr5Like::new(&m), &m);
    }

    #[test]
    fn validates_skewed() {
        let m = circuit::<f32>(500, 4, 0.08, 31);
        validate_engine(&Csr5Like::new(&m), &m);
    }

    #[test]
    fn simd_leg_allclose_to_scalar() {
        use crate::util::check::assert_allclose;
        let m = circuit::<f64>(800, 5, 0.06, 17);
        let e = Csr5Like::new(&m);
        let n = m.ncols();
        let x: Vec<f64> = (0..n).map(|i| ((i * 11 + 4) % 41) as f64 * 0.0625 - 1.25).collect();
        let mut y_s = vec![0.0; m.nrows()];
        let mut y_v = vec![0.0; m.nrows()];
        e.spmv_scalar(&x, &mut y_s);
        e.spmv_simd(&x, &mut y_v);
        // mul-then-add vs fma re-associates per-row rounding: allclose,
        // not assert_eq, by design.
        assert_allclose(&y_v, &y_s, 1e-9, 1e-12).unwrap();
    }

    #[test]
    fn rows_spanning_tiles() {
        // A row longer than a tile must carry across the boundary.
        let mut coo = Coo::<f64>::new(3, 200);
        for j in 0..150 {
            coo.push(1, j, 1.0);
        }
        coo.push(0, 0, 5.0);
        coo.push(2, 199, 7.0);
        let m = coo.to_csr();
        validate_engine(&Csr5Like::new(&m), &m);
    }
}
