//! ELL engine — wraps [`crate::sparse::ell::Ell`] behind the engine
//! trait. Column-major traversal, the coalesced GPU order.

use super::SpmvEngine;
use crate::sparse::csr::Csr;
use crate::sparse::ell::Ell;
use crate::sparse::scalar::Scalar;

pub struct EllEngine<S: Scalar> {
    e: Ell<S>,
    nnz: usize,
}

impl<S: Scalar> EllEngine<S> {
    pub fn new(m: &Csr<S>) -> Self {
        let e = Ell::from_csr(m);
        let nnz = m.nnz();
        Self { e, nnz }
    }
    /// Explicit scalar leg (the trait `spmv` dispatches on the `simd`
    /// feature; this twin is always available for tests/benches).
    pub fn spmv_scalar(&self, x: &[S], y: &mut [S]) {
        self.e.spmv_scalar(x, y);
    }
    /// Explicit SIMD leg — bitwise equal to the scalar twin for finite
    /// `x` (see [`Ell::spmv_simd`]).
    pub fn spmv_simd(&self, x: &[S], y: &mut [S]) {
        self.e.spmv_simd(x, y);
    }
}

impl<S: Scalar> SpmvEngine<S> for EllEngine<S> {
    fn name(&self) -> &'static str {
        "ell"
    }
    fn spmv(&self, x: &[S], y: &mut [S]) {
        self.e.spmv(x, y);
    }
    fn nrows(&self) -> usize {
        self.e.nrows()
    }
    fn ncols(&self) -> usize {
        self.e.ncols()
    }
    fn nnz(&self) -> usize {
        self.nnz
    }
    fn format_bytes(&self) -> usize {
        self.e.bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spmv::testutil::validate_engine;
    use crate::sparse::gen::poisson2d;

    #[test]
    fn validates() {
        let m = poisson2d::<f64>(12, 9);
        validate_engine(&EllEngine::new(&m), &m);
    }
}
