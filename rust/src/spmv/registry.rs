//! Engine registry: build every paper baseline (and EHYB) for a matrix.
//! The harness iterates this list to produce the Figure 2–5 series and
//! Table 1–2 speedups.

use super::csr5::Csr5Like;
use super::csr_scalar::CsrScalar;
use super::csr_vector::CsrVector;
use super::ehyb_cpu::EhybCpu;
use super::hyb::HybEngine;
use super::merge::MergeSpmv;
use super::sellp::SellPEngine;
use super::SpmvEngine;
use crate::preprocess::{EhybPlan, PreprocessConfig};
use crate::sparse::csr::Csr;
use crate::sparse::scalar::Scalar;

/// Baseline engines (everything except EHYB, which needs preprocessing).
pub fn baselines<S: Scalar>(m: &Csr<S>) -> Vec<Box<dyn SpmvEngine<S>>> {
    vec![
        Box::new(CsrScalar::new(m)),
        Box::new(CsrVector::new(m)),
        Box::new(HybEngine::new(m)),
        Box::new(SellPEngine::new(m)),
        Box::new(MergeSpmv::new(m)),
        Box::new(Csr5Like::new(m)),
    ]
}

/// All engines including EHYB (returns the plan too, for Fig. 6 data).
pub fn all_engines<S: Scalar>(
    m: &Csr<S>,
    cfg: &PreprocessConfig,
) -> crate::Result<(Vec<Box<dyn SpmvEngine<S>>>, EhybPlan<S>)> {
    let plan = EhybPlan::build(m, cfg)?;
    let mut engines = baselines(m);
    engines.push(Box::new(EhybCpu::new(&plan)));
    Ok((engines, plan))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spmv::testutil::validate_engine;
    use crate::sparse::gen::unstructured_mesh;

    #[test]
    fn every_engine_validates() {
        let m = unstructured_mesh::<f64>(20, 20, 0.5, 12);
        let cfg = PreprocessConfig { vec_size_override: Some(64), ..Default::default() };
        let (engines, _plan) = all_engines(&m, &cfg).unwrap();
        assert_eq!(engines.len(), 7);
        for e in &engines {
            validate_engine(e.as_ref(), &m);
        }
    }

    #[test]
    fn names_unique() {
        let m = unstructured_mesh::<f64>(12, 12, 0.5, 1);
        let engines = baselines(&m);
        let mut names: Vec<&str> = engines.iter().map(|e| e.name()).collect();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), engines.len());
    }
}
