//! CSR "vector" engine — the cuSPARSE generic-API **ALG1** analogue: one
//! warp per row, lanes striding the row, warp reduction at the end. On
//! CPU the warp is modelled as a `WARP`-wide strided accumulation; the
//! semantics (accumulation order) match what the GPU simulator counts.

use super::SpmvEngine;
use crate::sparse::csr::Csr;
use crate::sparse::scalar::Scalar;
use crate::util::lanes::{lane_width, Pack};

pub const WARP: usize = 32;

pub struct CsrVector<S: Scalar> {
    m: Csr<S>,
    profile: crate::profile::ProfileState,
}

impl<S: Scalar> CsrVector<S> {
    pub fn new(m: &Csr<S>) -> Self {
        Self { m: m.clone(), profile: crate::profile::ProfileState::new() }
    }

    /// Reference warp model: strided lane accumulation entry by entry.
    pub fn spmv_scalar(&self, x: &[S], y: &mut [S]) {
        let m = &self.m;
        assert_eq!(x.len(), m.ncols());
        assert_eq!(y.len(), m.nrows());
        let mut lanes = [S::ZERO; WARP];
        for i in 0..m.nrows() {
            let (cols, vals) = m.row(i);
            // Warp-strided partial sums.
            lanes.fill(S::ZERO);
            for (k, (&c, &v)) in cols.iter().zip(vals).enumerate() {
                let lane = k % WARP;
                lanes[lane] = v.mul_add(x[c as usize], lanes[lane]);
            }
            Self::reduce_warp(&mut lanes, &mut y[i]);
        }
    }

    /// SIMD warp model: each 32-entry stride group updates the lane
    /// registers in `W`-wide packs (contiguous val loads + x gathers).
    /// Entry `k` still lands on lane `k % WARP` with groups processed
    /// in ascending `k`, so every lane's fused chain — and the final
    /// tree reduction — is bit-identical to [`Self::spmv_scalar`],
    /// unconditionally (no padding trick involved).
    pub fn spmv_simd(&self, x: &[S], y: &mut [S]) {
        match lane_width(S::BYTES) {
            16 => self.spmv_packed::<16>(x, y),
            8 => self.spmv_packed::<8>(x, y),
            4 => self.spmv_packed::<4>(x, y),
            _ => self.spmv_packed::<2>(x, y),
        }
    }

    fn spmv_packed<const W: usize>(&self, x: &[S], y: &mut [S]) {
        let m = &self.m;
        assert_eq!(x.len(), m.ncols());
        assert_eq!(y.len(), m.nrows());
        let mut lanes = [S::ZERO; WARP];
        for i in 0..m.nrows() {
            let (cols, vals) = m.row(i);
            lanes.fill(S::ZERO);
            let mut k = 0;
            while k < cols.len() {
                // `k` is a multiple of WARP, so entry k+j maps to lane j.
                let g = (cols.len() - k).min(WARP);
                let mut j = 0;
                while j + W <= g {
                    let mut acc = Pack::<S, W>::load(&lanes[j..j + W]);
                    let v = Pack::load(&vals[k + j..k + j + W]);
                    let mut xg = [S::ZERO; W];
                    let mut l = 0;
                    while l < W {
                        xg[l] = x[cols[k + j + l] as usize];
                        l += 1;
                    }
                    acc = v.mul_add(Pack(xg), acc);
                    acc.store(&mut lanes[j..j + W]);
                    j += W;
                }
                while j < g {
                    lanes[j] = vals[k + j].mul_add(x[cols[k + j] as usize], lanes[j]);
                    j += 1;
                }
                k += g;
            }
            Self::reduce_warp(&mut lanes, &mut y[i]);
        }
    }

    /// Tree reduction (shfl_down order) shared by both legs.
    #[inline(always)]
    fn reduce_warp(lanes: &mut [S; WARP], out: &mut S) {
        let mut width = WARP / 2;
        while width > 0 {
            for l in 0..width {
                let other = lanes[l + width];
                lanes[l] += other;
            }
            width /= 2;
        }
        *out = lanes[0];
    }
}

impl<S: Scalar> SpmvEngine<S> for CsrVector<S> {
    fn name(&self) -> &'static str {
        "cusparse-alg1"
    }

    fn spmv(&self, x: &[S], y: &mut [S]) {
        let t = crate::profile::timer();
        if cfg!(feature = "simd") {
            self.spmv_simd(x, y)
        } else {
            self.spmv_scalar(x, y)
        }
        self.profile.record(1, crate::profile::elapsed(t), || {
            crate::profile::CallCost::of_csr(&self.m)
        });
    }

    fn nrows(&self) -> usize {
        self.m.nrows()
    }
    fn ncols(&self) -> usize {
        self.m.ncols()
    }
    fn nnz(&self) -> usize {
        self.m.nnz()
    }
    fn format_bytes(&self) -> usize {
        self.m.bytes()
    }
    fn kernel_profile(&self) -> Option<crate::profile::KernelProfile> {
        self.profile.snapshot("cusparse-alg1")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spmv::testutil::validate_engine;
    use crate::sparse::gen::{poisson3d, unstructured_mesh};

    #[test]
    fn validates_f64() {
        let m = poisson3d::<f64>(6, 7, 8);
        validate_engine(&CsrVector::new(&m), &m);
    }

    #[test]
    fn validates_on_irregular() {
        let m = unstructured_mesh::<f64>(20, 20, 0.5, 5);
        validate_engine(&CsrVector::new(&m), &m);
    }

    #[test]
    fn simd_warp_model_bit_identical_to_scalar() {
        for &(nx, ny, seed) in &[(20usize, 20usize, 5u64), (13, 17, 9)] {
            let m = unstructured_mesh::<f64>(nx, ny, 0.5, seed);
            let e = CsrVector::new(&m);
            let n = m.ncols();
            let x: Vec<f64> = (0..n).map(|i| ((i * 7 + 2) % 37) as f64 * 0.125 - 2.0).collect();
            let mut y_s = vec![0.0; m.nrows()];
            let mut y_v = vec![0.0; m.nrows()];
            e.spmv_scalar(&x, &mut y_s);
            e.spmv_simd(&x, &mut y_v);
            assert_eq!(y_s, y_v);
        }
    }

    #[test]
    fn long_rows_reduce_correctly() {
        use crate::sparse::coo::Coo;
        // One row with 100 entries crosses many warp strides.
        let mut coo = Coo::<f64>::new(2, 128);
        for j in 0..100 {
            coo.push(0, j, 1.0);
        }
        coo.push(1, 0, 2.0);
        let m = coo.to_csr();
        let e = CsrVector::new(&m);
        let x = vec![1.0; 128];
        let mut y = vec![0.0; 2];
        e.spmv(&x, &mut y);
        assert_eq!(y, vec![100.0, 2.0]);
    }
}
