//! CSR "vector" engine — the cuSPARSE generic-API **ALG1** analogue: one
//! warp per row, lanes striding the row, warp reduction at the end. On
//! CPU the warp is modelled as a `WARP`-wide strided accumulation; the
//! semantics (accumulation order) match what the GPU simulator counts.

use super::SpmvEngine;
use crate::sparse::csr::Csr;
use crate::sparse::scalar::Scalar;

pub const WARP: usize = 32;

pub struct CsrVector<S: Scalar> {
    m: Csr<S>,
}

impl<S: Scalar> CsrVector<S> {
    pub fn new(m: &Csr<S>) -> Self {
        Self { m: m.clone() }
    }
}

impl<S: Scalar> SpmvEngine<S> for CsrVector<S> {
    fn name(&self) -> &'static str {
        "cusparse-alg1"
    }

    fn spmv(&self, x: &[S], y: &mut [S]) {
        let m = &self.m;
        assert_eq!(x.len(), m.ncols());
        assert_eq!(y.len(), m.nrows());
        let mut lanes = [S::ZERO; WARP];
        for i in 0..m.nrows() {
            let (cols, vals) = m.row(i);
            // Warp-strided partial sums.
            lanes.fill(S::ZERO);
            for (k, (&c, &v)) in cols.iter().zip(vals).enumerate() {
                let lane = k % WARP;
                lanes[lane] = v.mul_add(x[c as usize], lanes[lane]);
            }
            // Tree reduction (shfl_down order).
            let mut width = WARP / 2;
            while width > 0 {
                for l in 0..width {
                    let other = lanes[l + width];
                    lanes[l] += other;
                }
                width /= 2;
            }
            y[i] = lanes[0];
        }
    }

    fn nrows(&self) -> usize {
        self.m.nrows()
    }
    fn ncols(&self) -> usize {
        self.m.ncols()
    }
    fn nnz(&self) -> usize {
        self.m.nnz()
    }
    fn format_bytes(&self) -> usize {
        self.m.bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::spmv::testutil::validate_engine;
    use crate::sparse::gen::{poisson3d, unstructured_mesh};

    #[test]
    fn validates_f64() {
        let m = poisson3d::<f64>(6, 7, 8);
        validate_engine(&CsrVector::new(&m), &m);
    }

    #[test]
    fn validates_on_irregular() {
        let m = unstructured_mesh::<f64>(20, 20, 0.5, 5);
        validate_engine(&CsrVector::new(&m), &m);
    }

    #[test]
    fn long_rows_reduce_correctly() {
        use crate::sparse::coo::Coo;
        // One row with 100 entries crosses many warp strides.
        let mut coo = Coo::<f64>::new(2, 128);
        for j in 0..100 {
            coo.push(0, j, 1.0);
        }
        coo.push(1, 0, 2.0);
        let m = coo.to_csr();
        let e = CsrVector::new(&m);
        let x = vec![1.0; 128];
        let mut y = vec![0.0; 2];
        e.spmv(&x, &mut y);
        assert_eq!(y, vec![100.0, 2.0]);
    }
}
