//! The optimized CPU engine for EHYB — Algorithm 3's semantics with the
//! L3 hot path tuned for cache behaviour: per-partition processing keeps
//! the x-slice resident in L1/L2 (the CPU analogue of the explicit
//! shared-memory cache), the u16 column stream halves index bandwidth,
//! and slices are walked lane-major so `y` accumulates in registers.

use super::SpmvEngine;
use crate::sparse::ehyb::EhybMatrix;
use crate::sparse::scalar::Scalar;
use std::sync::Mutex;

pub struct EhybCpu<S: Scalar> {
    m: EhybMatrix<S>,
    /// Scratch for the permuted x / y (reused across calls; allocation in
    /// the hot loop costs ~10 % on paper-scale matrices).
    scratch: Mutex<Scratch<S>>,
}

struct Scratch<S> {
    xp: Vec<S>,
    yp: Vec<S>,
}

impl<S: Scalar> EhybCpu<S> {
    pub fn new(plan: &crate::preprocess::EhybPlan<S>) -> Self {
        Self::from_matrix(plan.matrix.clone())
    }

    pub fn from_matrix(m: EhybMatrix<S>) -> Self {
        let padded = m.padded_rows();
        Self { m, scratch: Mutex::new(Scratch { xp: vec![S::ZERO; padded], yp: vec![S::ZERO; padded] }) }
    }

    pub fn matrix(&self) -> &EhybMatrix<S> {
        &self.m
    }

    /// Core kernel in the new index space (no permutations) — this is
    /// what the GPU kernel does per launch, and what the solver calls
    /// when it keeps its vectors permanently in the new order.
    ///
    /// Loop order (§Perf iteration 1): **k-outer / lane-inner**. The
    /// slice data is column-major (lane contiguous within each k
    /// column), so the inner loop streams `vals`/`cols` sequentially and
    /// gathers from the L1-resident cached x-slice; the h accumulators
    /// live in a stack array. The GPU-order walk (lane-outer, stride-h
    /// through the arrays) is kept as [`Self::spmv_new_order_lane_major`]
    /// for the before/after log in EXPERIMENTS.md §Perf.
    pub fn spmv_new_order(&self, xp: &[S], yp: &mut [S]) {
        let m = &self.m;
        debug_assert_eq!(xp.len(), m.padded_rows());
        debug_assert_eq!(yp.len(), m.padded_rows());
        let h = m.slice_height;
        let spp = m.slices_per_part();
        debug_assert!(h <= 64);
        let mut acc = [S::ZERO; 64];
        for p in 0..m.num_parts {
            // Explicit cache: this slice of xp stays hot in L1/L2 for the
            // whole partition (GPU: copied into shared memory once).
            let cached = &xp[p * m.vec_size..(p + 1) * m.vec_size];
            let mut row = p * m.vec_size;
            for ls in 0..spp {
                let s = p * spp + ls;
                let base = m.slice_ptr[s] as usize;
                let w = m.slice_width[s] as usize;
                acc[..h].fill(S::ZERO);
                for k in 0..w {
                    let off = base + k * h;
                    let vals = &m.ell_vals[off..off + h];
                    let cols = &m.ell_cols[off..off + h];
                    for lane in 0..h {
                        // Padding is col=0/val=0: branch-free. Bounds
                        // are guaranteed by EhybMatrix::validate.
                        acc[lane] = unsafe {
                            vals.get_unchecked(lane)
                                .mul_add(*cached.get_unchecked(*cols.get_unchecked(lane) as usize), acc[lane])
                        };
                    }
                }
                yp[row..row + h].copy_from_slice(&acc[..h]);
                row += h;
            }
        }
        // ER pass: uncached gathers over the full xp, same loop order.
        for s in 0..m.er_slice_width.len() {
            let base = m.er_slice_ptr[s] as usize;
            let w = m.er_slice_width[s] as usize;
            let jmax = (m.er_rows - s * h).min(h);
            acc[..jmax].fill(S::ZERO);
            for k in 0..w {
                let off = base + k * h;
                for lane in 0..jmax {
                    let idx = off + lane;
                    acc[lane] = unsafe {
                        m.er_vals
                            .get_unchecked(idx)
                            .mul_add(*xp.get_unchecked(*m.er_cols.get_unchecked(idx) as usize), acc[lane])
                    };
                }
            }
            for lane in 0..jmax {
                let out = m.y_idx_er[s * h + lane] as usize;
                yp[out] += acc[lane];
            }
        }
    }

    /// The GPU-order walk (lane-outer, stride-h array access) — kept as
    /// the §Perf baseline. Identical arithmetic per row, so results are
    /// bit-equal to [`Self::spmv_new_order`].
    pub fn spmv_new_order_lane_major(&self, xp: &[S], yp: &mut [S]) {
        let m = &self.m;
        let h = m.slice_height;
        let spp = m.slices_per_part();
        for p in 0..m.num_parts {
            let cached = &xp[p * m.vec_size..(p + 1) * m.vec_size];
            let mut row = p * m.vec_size;
            for ls in 0..spp {
                let s = p * spp + ls;
                let base = m.slice_ptr[s] as usize;
                let w = m.slice_width[s] as usize;
                for lane in 0..h {
                    let mut acc = S::ZERO;
                    let mut idx = base + lane;
                    for _ in 0..w {
                        acc = unsafe {
                            m.ell_vals
                                .get_unchecked(idx)
                                .mul_add(*cached.get_unchecked(*m.ell_cols.get_unchecked(idx) as usize), acc)
                        };
                        idx += h;
                    }
                    yp[row + lane] = acc;
                }
                row += h;
            }
        }
        for s in 0..m.er_slice_width.len() {
            let base = m.er_slice_ptr[s] as usize;
            let w = m.er_slice_width[s] as usize;
            let jmax = (m.er_rows - s * h).min(h);
            for lane in 0..jmax {
                let mut acc = S::ZERO;
                let mut idx = base + lane;
                for _ in 0..w {
                    acc = unsafe {
                        m.er_vals
                            .get_unchecked(idx)
                            .mul_add(*xp.get_unchecked(*m.er_cols.get_unchecked(idx) as usize), acc)
                    };
                    idx += h;
                }
                let out = m.y_idx_er[s * h + lane] as usize;
                yp[out] += acc;
            }
        }
    }
}

impl<S: Scalar> SpmvEngine<S> for EhybCpu<S> {
    fn name(&self) -> &'static str {
        "ehyb"
    }

    fn spmv(&self, x: &[S], y: &mut [S]) {
        let m = &self.m;
        assert_eq!(x.len(), m.n);
        assert_eq!(y.len(), m.n);
        let mut guard = self.scratch.lock().unwrap();
        let Scratch { xp, yp } = &mut *guard;
        // Permute in (gather by iperm is sequential-write).
        for new in 0..m.padded_rows() {
            let old = m.iperm[new] as usize;
            xp[new] = if old < m.n { x[old] } else { S::ZERO };
        }
        self.spmv_new_order(xp, yp);
        for new in 0..m.padded_rows() {
            let old = m.iperm[new] as usize;
            if old < m.n {
                y[old] = yp[new];
            }
        }
    }

    fn nrows(&self) -> usize {
        self.m.n
    }
    fn nnz(&self) -> usize {
        self.m.nnz()
    }
    fn format_bytes(&self) -> usize {
        self.m.bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::preprocess::{EhybPlan, PreprocessConfig};
    use crate::spmv::testutil::validate_engine;
    use crate::sparse::gen::{circuit, poisson2d, poisson3d, unstructured_mesh};

    fn cfg(v: usize) -> PreprocessConfig {
        PreprocessConfig { vec_size_override: Some(v), ..Default::default() }
    }

    #[test]
    fn validates_poisson2d() {
        let m = poisson2d::<f64>(20, 20);
        let plan = EhybPlan::build(&m, &cfg(64)).unwrap();
        validate_engine(&EhybCpu::new(&plan), &m);
    }

    #[test]
    fn validates_poisson3d_f32() {
        let m = poisson3d::<f32>(9, 8, 7);
        let plan = EhybPlan::build(&m, &cfg(96)).unwrap();
        validate_engine(&EhybCpu::new(&plan), &m);
    }

    #[test]
    fn validates_unstructured() {
        let m = unstructured_mesh::<f64>(24, 24, 0.7, 8);
        let plan = EhybPlan::build(&m, &cfg(128)).unwrap();
        validate_engine(&EhybCpu::new(&plan), &m);
    }

    #[test]
    fn validates_circuit() {
        let m = circuit::<f64>(900, 4, 0.04, 15);
        let plan = EhybPlan::build(&m, &cfg(64)).unwrap();
        validate_engine(&EhybCpu::new(&plan), &m);
    }

    #[test]
    fn matches_reference_semantics() {
        // Engine must agree with the EhybMatrix reference spmv exactly
        // (same arithmetic order).
        let m = unstructured_mesh::<f64>(16, 16, 0.5, 6);
        let plan = EhybPlan::build(&m, &cfg(64)).unwrap();
        let engine = EhybCpu::new(&plan);
        let x: Vec<f64> = (0..m.nrows()).map(|i| (i as f64 * 0.37).sin()).collect();
        let mut y1 = vec![0.0; m.nrows()];
        let mut y2 = vec![0.0; m.nrows()];
        engine.spmv(&x, &mut y1);
        plan.matrix.spmv(&x, &mut y2);
        assert_eq!(y1, y2);
    }

    #[test]
    fn loop_orders_agree_exactly() {
        // k-outer (CPU-optimized) and lane-outer (GPU-order baseline)
        // accumulate per-row in the same k order => bit-identical.
        let m = unstructured_mesh::<f64>(20, 20, 0.6, 9);
        let plan = EhybPlan::build(&m, &cfg(64)).unwrap();
        let engine = EhybCpu::new(&plan);
        let xp = plan.matrix.permute_x(
            &(0..m.nrows()).map(|i| (i as f64 * 0.11).cos()).collect::<Vec<_>>(),
        );
        let mut y1 = vec![0.0; plan.matrix.padded_rows()];
        let mut y2 = vec![0.0; plan.matrix.padded_rows()];
        engine.spmv_new_order(&xp, &mut y1);
        engine.spmv_new_order_lane_major(&xp, &mut y2);
        assert_eq!(y1, y2);
    }

    #[test]
    fn new_order_path_consistent() {
        let m = poisson2d::<f64>(16, 16);
        let plan = EhybPlan::build(&m, &cfg(64)).unwrap();
        let engine = EhybCpu::new(&plan);
        let x: Vec<f64> = (0..256).map(|i| i as f64 * 0.01).collect();
        let xp = plan.matrix.permute_x(&x);
        let mut yp = vec![0.0; plan.matrix.padded_rows()];
        engine.spmv_new_order(&xp, &mut yp);
        let y = plan.matrix.unpermute_y(&yp);
        let mut y_ref = vec![0.0; 256];
        m.spmv(&x, &mut y_ref);
        for i in 0..256 {
            assert!((y[i] - y_ref[i]).abs() < 1e-12);
        }
    }
}
