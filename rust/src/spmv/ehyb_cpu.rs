//! The optimized CPU engine for EHYB — Algorithm 3's semantics with the
//! L3 hot path tuned for cache behaviour: per-partition processing keeps
//! the x-slice resident in L1/L2 (the CPU analogue of the explicit
//! shared-memory cache), the u16 column stream halves index bandwidth,
//! and slices are walked lane-major so `y` accumulates in registers.
//!
//! On top of the single-vector kernel this module provides the two
//! levers that multiply throughput on a memory-bound kernel:
//!
//! * **Partition parallelism** — every partition owns a disjoint
//!   `vec_size` row range of `yp`, so the ELL pass splits race-free
//!   across [`crate::util::par`] worker threads (`EHYB_THREADS`). The
//!   ER scatter parallelizes too: each ER slot maps to a *distinct*
//!   output row (`y_idx_er` is injective, checked by
//!   `EhybMatrix::validate`), so ER slice ranges scatter into disjoint
//!   `yp` entries. Per-row accumulation order is unchanged in both
//!   passes, so results are **bit-identical** to the serial kernel at
//!   any thread count.
//! * **Blocked SpMM** — [`EhybCpu::spmm_new_order`] streams each
//!   partition's slice data once for a register block of input
//!   vectors, multiplying arithmetic intensity by the block width
//!   (the paper's data-movement economics applied across a request
//!   batch instead of within one SpMV). The engine-level batch entry
//!   ([`SpmvEngine::spmv_batch`]) runs over borrowed
//!   [`VecBatch`]/[`VecBatchMut`] views and stages the whole batch in
//!   **one** contiguous scratch allocation per side.
//! * **SIMD lanes** — the ELL walk, the ER tail, and the blocked SpMM
//!   all have lane-packed twins ([`crate::util::lanes`]) that process
//!   [`lane_width`] output rows per step, selected by the on-by-default
//!   `simd` cargo feature. Every output row keeps its own k-ordered
//!   fused chain, so the simd walks are **bit-identical** to the scalar
//!   ones (proptested in `rust/tests/simd.rs`); both variants are
//!   always compiled and publicly callable
//!   ([`EhybCpu::spmv_new_order_scalar`] /
//!   [`EhybCpu::spmv_new_order_simd`]) so one binary benches the pair.
//!
//! The engine also implements [`PermutedSpmv`], exposing its internal
//! permutation and the raw new-order kernels so the reorder adapter
//! ([`crate::reorder::ReorderedEngine`]) can *fuse* its own permutation
//! with EHYB's into one gather per side instead of two passes over x.

use super::{PermutedSpmv, SpmvEngine, VecBatch, VecBatchMut};
use crate::sparse::ehyb::EhybMatrix;
use crate::sparse::scalar::Scalar;
use crate::util::lanes::{lane_width, Pack};
use crate::util::par;
use std::sync::Mutex;

/// Feature-selected default for the kernel dispatchers: the `simd`
/// cargo feature only flips this bool — both kernel variants are
/// always compiled.
#[inline(always)]
fn simd_default() -> bool {
    cfg!(feature = "simd")
}

/// Stack-accumulator bound: slice heights are warp-sized (≤ 64).
const MAX_H: usize = 64;
/// Below this much work per call (stored entries × batch lanes) the
/// scoped-thread spawn/join costs more than the kernel saves; the auto
/// paths stay serial. ~256k entries ≈ a few hundred µs of serial work,
/// comfortably above per-call thread fan-out overhead.
const PAR_MIN_NNZ: usize = 256 * 1024;

pub struct EhybCpu<S: Scalar> {
    m: EhybMatrix<S>,
    /// True iff `y_idx_er` is injective over the logical ER slots and
    /// every target is in bounds — checked **once at construction**
    /// (not just in `validate()`/debug builds), because the parallel
    /// ER scatter's safety argument depends on it and `EhybMatrix` has
    /// public fields, so a hand-assembled matrix can reach
    /// [`Self::from_matrix`] without ever passing validation. When
    /// false, the ER tail stays serial (correct for any targets).
    er_scatter_disjoint: bool,
    /// Reusable permuted-vector buffers (allocation in the hot loop
    /// costs ~10 % on paper-scale matrices). A pool, not a single
    /// locked slot: concurrent callers pop distinct scratches and only
    /// touch the lock at call boundaries, so engine use never
    /// serializes on the compute itself.
    pool: ScratchPool<S>,
    /// Observed data-movement counters (relaxed atomics; structural
    /// per-call cost computed once). No-op when the `profile` feature
    /// is off — the kernels themselves are never touched either way.
    profile: crate::profile::ProfileState,
}

/// Permuted x/y storage for one in-flight call: one contiguous
/// allocation per side holding `width` padded vectors column-major
/// (lane `b` = `xp[b*padded..(b+1)*padded]`).
struct Scratch<S> {
    xp: Vec<S>,
    yp: Vec<S>,
}

impl<S> Default for Scratch<S> {
    fn default() -> Self {
        Self { xp: Vec::new(), yp: Vec::new() }
    }
}

struct ScratchPool<S> {
    free: Mutex<Vec<Scratch<S>>>,
}

impl<S: Scalar> ScratchPool<S> {
    fn new() -> Self {
        Self { free: Mutex::new(Vec::new()) }
    }

    /// Pop (or create) a scratch sized for `width` lanes of `padded`
    /// elements per side. Contents are unspecified — both passes fully
    /// overwrite their buffers before reading.
    fn take(&self, width: usize, padded: usize) -> Scratch<S> {
        let mut s = self.free.lock().unwrap().pop().unwrap_or_default();
        let want = width * padded;
        for v in [&mut s.xp, &mut s.yp] {
            if v.len() != want {
                v.clear();
                v.resize(want, S::ZERO);
            }
        }
        s
    }

    fn put(&self, s: Scratch<S>) {
        let mut free = self.free.lock().unwrap();
        // Bound pooled memory under bursty concurrency.
        if free.len() < 8 {
            free.push(s);
        }
    }
}

/// Raw-pointer capsule for the parallel ER scatter; the unsafe Send/Sync
/// is justified at the single use site (disjoint scatter targets).
struct SendPtr<S>(*mut S);
unsafe impl<S: Send> Send for SendPtr<S> {}
unsafe impl<S: Send> Sync for SendPtr<S> {}

impl<S: Scalar> EhybCpu<S> {
    pub fn new(plan: &crate::preprocess::EhybPlan<S>) -> Self {
        Self::from_matrix(plan.matrix.clone())
    }

    pub fn from_matrix(m: EhybMatrix<S>) -> Self {
        // O(er_rows) one-time check backing the parallel ER scatter's
        // disjointness argument; see the field doc.
        let mut seen = vec![false; m.padded_rows()];
        let er_scatter_disjoint = match m.y_idx_er.get(..m.er_rows) {
            Some(slots) => slots.iter().all(|&r| {
                let r = r as usize;
                r < seen.len() && !std::mem::replace(&mut seen[r], true)
            }),
            None => false, // malformed lengths: never fan the scatter out
        };
        Self {
            m,
            er_scatter_disjoint,
            pool: ScratchPool::new(),
            profile: crate::profile::ProfileState::new(),
        }
    }

    pub fn matrix(&self) -> &EhybMatrix<S> {
        &self.m
    }

    /// Core kernel in the new index space (no permutations) — this is
    /// what the GPU kernel does per launch, and what the solver calls
    /// when it keeps its vectors permanently in the new order. Serial;
    /// see [`Self::spmv_new_order_parallel`] for the threaded walk.
    ///
    /// Loop order (§Perf iteration 1): **k-outer / lane-inner**. The
    /// slice data is column-major (lane contiguous within each k
    /// column), so the inner loop streams `vals`/`cols` sequentially and
    /// gathers from the L1-resident cached x-slice; the h accumulators
    /// live in a stack array. The GPU-order walk (lane-outer, stride-h
    /// through the arrays) is kept as [`Self::spmv_new_order_lane_major`]
    /// for the before/after log in EXPERIMENTS.md §Perf.
    pub fn spmv_new_order(&self, xp: &[S], yp: &mut [S]) {
        self.spmv_new_order_with(xp, yp, simd_default());
    }

    /// Scalar reference walk, regardless of the `simd` feature.
    pub fn spmv_new_order_scalar(&self, xp: &[S], yp: &mut [S]) {
        self.spmv_new_order_with(xp, yp, false);
    }

    /// Lane-packed walk, regardless of the `simd` feature. Bit-identical
    /// to [`Self::spmv_new_order_scalar`] (per-row fused chains are
    /// preserved; see the module docs).
    pub fn spmv_new_order_simd(&self, xp: &[S], yp: &mut [S]) {
        self.spmv_new_order_with(xp, yp, true);
    }

    fn spmv_new_order_with(&self, xp: &[S], yp: &mut [S], simd: bool) {
        debug_assert_eq!(xp.len(), self.m.padded_rows());
        debug_assert_eq!(yp.len(), self.m.padded_rows());
        self.ell_pass(xp, yp, 0, simd);
        self.er_pass(xp, yp, simd);
    }

    /// Partition-parallel SpMV in the new index space. Each worker owns
    /// a contiguous run of partitions and therefore a disjoint row
    /// range of `yp` for the ELL pass; the ER scatter parallelizes over
    /// slice ranges (disjoint targets — see [`Self::er_pass_parallel`]).
    /// Per-row arithmetic order is unchanged, so the result is
    /// bit-identical to [`Self::spmv_new_order`] at any thread count.
    pub fn spmv_new_order_parallel(&self, xp: &[S], yp: &mut [S]) {
        self.spmv_new_order_parallel_with(xp, yp, simd_default());
    }

    fn spmv_new_order_parallel_with(&self, xp: &[S], yp: &mut [S], simd: bool) {
        let m = &self.m;
        debug_assert_eq!(xp.len(), m.padded_rows());
        debug_assert_eq!(yp.len(), m.padded_rows());
        let threads = par::num_threads().min(m.num_parts).max(1);
        if threads <= 1 {
            self.ell_pass(xp, yp, 0, simd);
        } else {
            let vec_size = m.vec_size;
            let rows_per = m.num_parts.div_ceil(threads) * vec_size;
            par::par_chunks_mut(yp, rows_per, |base, chunk| {
                self.ell_pass(xp, chunk, base / vec_size, simd);
            });
        }
        self.er_pass_parallel(xp, yp, simd);
    }

    /// Blocked multi-vector SpMM in the new index space:
    /// `yps[i] = A xps[i]` for all padded vectors at once (each `yps[i]`
    /// must already be `padded_rows` long). The batch is processed in
    /// register blocks of up to 4 vectors; within a block each
    /// partition's `ell_vals`/`ell_cols` stream is read **once**, its
    /// cached x-slices for all block lanes stay hot, and block×h
    /// outputs accumulate in stack registers. Per-row accumulation
    /// order matches the single-vector kernel, so each output is
    /// bit-identical to a [`Self::spmv_new_order`] call.
    pub fn spmm_new_order(&self, xps: &[&[S]], yps: &mut [&mut [S]]) {
        self.spmm_new_order_with(xps, yps, simd_default());
    }

    /// [`Self::spmm_new_order`] with an explicit scalar/simd selector —
    /// the bench sweep and the simd-vs-scalar proptests call this to
    /// compare the pair inside one binary.
    pub fn spmm_new_order_with(&self, xps: &[&[S]], yps: &mut [&mut [S]], simd: bool) {
        assert_eq!(xps.len(), yps.len(), "batch inputs/outputs disagree");
        let m = &self.m;
        let padded = m.padded_rows();
        for xp in xps {
            assert_eq!(xp.len(), padded, "xp not in padded new order");
        }
        for yp in yps.iter() {
            assert_eq!(yp.len(), padded, "yp not in padded new order");
        }
        // Fan out over partitions ONCE for the whole batch (each worker
        // walks every register block over its partition range), so the
        // thread spawn/join cost is paid per call, not per block.
        let threads = if m.nnz().saturating_mul(xps.len()) < PAR_MIN_NNZ {
            1
        } else {
            par::num_threads().min(m.num_parts).max(1)
        };
        if threads <= 1 {
            self.spmm_ell_blocks(xps, yps, 0, simd);
        } else {
            let parts_per = m.num_parts.div_ceil(threads);
            let rows_per = parts_per * m.vec_size;
            // Transpose the split: work unit t = (first partition,
            // the t-th row-chunk of every output vector).
            let mut its: Vec<_> = yps.iter_mut().map(|y| y.chunks_mut(rows_per)).collect();
            let nchunks = m.num_parts.div_ceil(parts_per);
            let work: Vec<(usize, Vec<&mut [S]>)> = (0..nchunks)
                .map(|c| (c * parts_per, its.iter_mut().map(|it| it.next().unwrap()).collect()))
                .collect();
            par::par_for_each(work, |_, (p0, mut chunks)| {
                self.spmm_ell_blocks(xps, &mut chunks, p0, simd);
            });
        }
        // ER tail: uncached gathers + scatter-add. Lanes are disjoint
        // output vectors, so the batch case parallelizes across lanes
        // without any aliasing.
        if threads > 1 && xps.len() > 1 && self.m.er_nnz > 0 {
            let work: Vec<(&[S], &mut [S])> =
                xps.iter().zip(yps.iter_mut()).map(|(x, y)| (*x, &mut **y)).collect();
            par::par_for_each(work, |_, (xp, yp)| self.er_pass(xp, yp, simd));
        } else {
            for (xp, yp) in xps.iter().zip(yps.iter_mut()) {
                self.er_pass(xp, yp, simd);
            }
        }
    }

    /// Walk the batch in register blocks of 4/2/1 over one partition
    /// chunk (`youts` are the chunk's row ranges, one per vector).
    fn spmm_ell_blocks(&self, xps: &[&[S]], youts: &mut [&mut [S]], p0: usize, simd: bool) {
        debug_assert_eq!(xps.len(), youts.len());
        let mut b0 = 0;
        while b0 < xps.len() {
            // Widest block that fits the remaining lanes.
            let nb = match xps.len() - b0 {
                n if n >= 4 => {
                    self.spmm_parts::<4>(&xps[b0..b0 + 4], &mut youts[b0..b0 + 4], p0, simd);
                    4
                }
                n if n >= 2 => {
                    self.spmm_parts::<2>(&xps[b0..b0 + 2], &mut youts[b0..b0 + 2], p0, simd);
                    2
                }
                _ => {
                    self.spmm_parts::<1>(&xps[b0..b0 + 1], &mut youts[b0..b0 + 1], p0, simd);
                    1
                }
            };
            b0 += nb;
        }
    }

    /// Per-block scalar/simd dispatch: the lane width is a compile-time
    /// constant inside each instantiation.
    fn spmm_parts<const NB: usize>(
        &self,
        xps: &[&[S]],
        yout: &mut [&mut [S]],
        p0: usize,
        simd: bool,
    ) {
        if simd {
            match lane_width(S::BYTES) {
                16 => self.spmm_parts_simd::<NB, 16>(xps, yout, p0),
                8 => self.spmm_parts_simd::<NB, 8>(xps, yout, p0),
                4 => self.spmm_parts_simd::<NB, 4>(xps, yout, p0),
                _ => self.spmm_parts_simd::<NB, 2>(xps, yout, p0),
            }
        } else {
            self.spmm_parts_scalar::<NB>(xps, yout, p0);
        }
    }

    /// ELL pass over the partition range starting at `p0`, writing into
    /// `yp_chunk` whose row 0 is partition `p0`'s first row. Extracted
    /// so the serial and parallel walks share one kernel body;
    /// dispatches to the scalar or lane-packed twin.
    fn ell_pass(&self, xp: &[S], yp_chunk: &mut [S], p0: usize, simd: bool) {
        if simd {
            match lane_width(S::BYTES) {
                16 => self.ell_pass_simd::<16>(xp, yp_chunk, p0),
                8 => self.ell_pass_simd::<8>(xp, yp_chunk, p0),
                4 => self.ell_pass_simd::<4>(xp, yp_chunk, p0),
                _ => self.ell_pass_simd::<2>(xp, yp_chunk, p0),
            }
        } else {
            self.ell_pass_scalar(xp, yp_chunk, p0);
        }
    }

    /// Scalar reference ELL walk (k-outer / lane-inner).
    fn ell_pass_scalar(&self, xp: &[S], yp_chunk: &mut [S], p0: usize) {
        let m = &self.m;
        let h = m.slice_height;
        let spp = m.slices_per_part();
        debug_assert!(h <= MAX_H);
        debug_assert_eq!(yp_chunk.len() % m.vec_size, 0);
        let nparts = yp_chunk.len() / m.vec_size;
        let mut acc = [S::ZERO; MAX_H];
        let mut row = 0usize;
        for p in p0..p0 + nparts {
            // Explicit cache: this slice of xp stays hot in L1/L2 for the
            // whole partition (GPU: copied into shared memory once).
            let cached = &xp[p * m.vec_size..(p + 1) * m.vec_size];
            for ls in 0..spp {
                let s = p * spp + ls;
                let base = m.slice_ptr[s] as usize;
                let w = m.slice_width[s] as usize;
                acc[..h].fill(S::ZERO);
                for k in 0..w {
                    let off = base + k * h;
                    let vals = &m.ell_vals[off..off + h];
                    let cols = &m.ell_cols[off..off + h];
                    for lane in 0..h {
                        // Padding is col=0/val=0: branch-free. Bounds
                        // are guaranteed by EhybMatrix::validate.
                        acc[lane] = unsafe {
                            vals.get_unchecked(lane).mul_add(
                                *cached.get_unchecked(*cols.get_unchecked(lane) as usize),
                                acc[lane],
                            )
                        };
                    }
                }
                yp_chunk[row..row + h].copy_from_slice(&acc[..h]);
                row += h;
            }
        }
    }

    /// Lane-packed ELL walk: `W` output rows per pack, k-inner so the
    /// pack accumulators stay in registers for a whole slice column
    /// stream. Each output row's fused chain is still accumulated in k
    /// order, so the result is bit-identical to
    /// [`Self::ell_pass_scalar`]. Lanes past the last full pack (when
    /// `W` does not divide the slice height) run the scalar chain.
    fn ell_pass_simd<const W: usize>(&self, xp: &[S], yp_chunk: &mut [S], p0: usize) {
        let m = &self.m;
        let h = m.slice_height;
        let spp = m.slices_per_part();
        debug_assert!(h <= MAX_H);
        debug_assert_eq!(yp_chunk.len() % m.vec_size, 0);
        let nparts = yp_chunk.len() / m.vec_size;
        let mut row = 0usize;
        for p in p0..p0 + nparts {
            // Explicit cache: this slice of xp stays hot in L1/L2 for
            // the whole partition (GPU: shared memory).
            let cached = &xp[p * m.vec_size..(p + 1) * m.vec_size];
            for ls in 0..spp {
                let s = p * spp + ls;
                let base = m.slice_ptr[s] as usize;
                let w = m.slice_width[s] as usize;
                let mut lane = 0usize;
                while lane + W <= h {
                    let mut acc = Pack::<S, W>::ZERO;
                    for k in 0..w {
                        let off = base + k * h + lane;
                        let vals = Pack::load(&m.ell_vals[off..off + W]);
                        // SAFETY: EhybMatrix::validate bounds every ELL
                        // column below vec_size == cached.len(); padding
                        // is col 0 / val 0 (branch-free).
                        let xg = unsafe {
                            Pack::gather_u16_unchecked(cached, &m.ell_cols[off..off + W])
                        };
                        acc = vals.mul_add(xg, acc);
                    }
                    acc.store(&mut yp_chunk[row + lane..row + lane + W]);
                    lane += W;
                }
                while lane < h {
                    let mut acc = S::ZERO;
                    for k in 0..w {
                        let idx = base + k * h + lane;
                        acc = unsafe {
                            m.ell_vals.get_unchecked(idx).mul_add(
                                *cached.get_unchecked(*m.ell_cols.get_unchecked(idx) as usize),
                                acc,
                            )
                        };
                    }
                    yp_chunk[row + lane] = acc;
                    lane += 1;
                }
                row += h;
            }
        }
    }

    /// Blocked ELL kernel over the partition range starting at `p0`:
    /// NB input vectors, NB disjoint output row-chunks. The val/col
    /// load per (k, lane) slot is shared by NB fused multiply-adds —
    /// the batch-width multiplier on arithmetic intensity.
    fn spmm_parts_scalar<const NB: usize>(&self, xps: &[&[S]], yout: &mut [&mut [S]], p0: usize) {
        let m = &self.m;
        let h = m.slice_height;
        let spp = m.slices_per_part();
        debug_assert!(h <= MAX_H);
        debug_assert_eq!(xps.len(), NB);
        debug_assert_eq!(yout.len(), NB);
        debug_assert_eq!(yout[0].len() % m.vec_size, 0);
        let nparts = yout[0].len() / m.vec_size;
        let mut acc = [[S::ZERO; MAX_H]; NB];
        let mut row = 0usize;
        for p in p0..p0 + nparts {
            let lo = p * m.vec_size;
            let cached: [&[S]; NB] = std::array::from_fn(|b| &xps[b][lo..lo + m.vec_size]);
            for ls in 0..spp {
                let s = p * spp + ls;
                let base = m.slice_ptr[s] as usize;
                let w = m.slice_width[s] as usize;
                for a in acc.iter_mut() {
                    a[..h].fill(S::ZERO);
                }
                for k in 0..w {
                    let off = base + k * h;
                    let vals = &m.ell_vals[off..off + h];
                    let cols = &m.ell_cols[off..off + h];
                    for lane in 0..h {
                        let (v, c) = unsafe {
                            (*vals.get_unchecked(lane), *cols.get_unchecked(lane) as usize)
                        };
                        for b in 0..NB {
                            acc[b][lane] =
                                unsafe { v.mul_add(*cached[b].get_unchecked(c), acc[b][lane]) };
                        }
                    }
                }
                for (b, a) in acc.iter().enumerate() {
                    yout[b][row..row + h].copy_from_slice(&a[..h]);
                }
                row += h;
            }
        }
    }

    /// Lane-packed blocked SpMM: NB × W pack accumulators; one val/col
    /// pack load is shared by NB lane-wise fmas. Per-(vector, row)
    /// chains stay k-ordered — bit-identical to
    /// [`Self::spmm_parts_scalar`].
    fn spmm_parts_simd<const NB: usize, const W: usize>(
        &self,
        xps: &[&[S]],
        yout: &mut [&mut [S]],
        p0: usize,
    ) {
        let m = &self.m;
        let h = m.slice_height;
        let spp = m.slices_per_part();
        debug_assert!(h <= MAX_H);
        debug_assert_eq!(xps.len(), NB);
        debug_assert_eq!(yout.len(), NB);
        debug_assert_eq!(yout[0].len() % m.vec_size, 0);
        let nparts = yout[0].len() / m.vec_size;
        let mut row = 0usize;
        for p in p0..p0 + nparts {
            let lo = p * m.vec_size;
            let cached: [&[S]; NB] = std::array::from_fn(|b| &xps[b][lo..lo + m.vec_size]);
            for ls in 0..spp {
                let s = p * spp + ls;
                let base = m.slice_ptr[s] as usize;
                let w = m.slice_width[s] as usize;
                let mut lane = 0usize;
                while lane + W <= h {
                    let mut acc = [Pack::<S, W>::ZERO; NB];
                    for k in 0..w {
                        let off = base + k * h + lane;
                        let vals = Pack::load(&m.ell_vals[off..off + W]);
                        let cols = &m.ell_cols[off..off + W];
                        for b in 0..NB {
                            // SAFETY: same ELL column bound as
                            // ell_pass_simd (validate: col < vec_size).
                            let xg = unsafe { Pack::gather_u16_unchecked(cached[b], cols) };
                            acc[b] = vals.mul_add(xg, acc[b]);
                        }
                    }
                    for (b, a) in acc.iter().enumerate() {
                        a.store(&mut yout[b][row + lane..row + lane + W]);
                    }
                    lane += W;
                }
                while lane < h {
                    let mut acc = [S::ZERO; NB];
                    for k in 0..w {
                        let idx = base + k * h + lane;
                        let (v, c) = unsafe {
                            (*m.ell_vals.get_unchecked(idx), *m.ell_cols.get_unchecked(idx) as usize)
                        };
                        for b in 0..NB {
                            acc[b] = unsafe { v.mul_add(*cached[b].get_unchecked(c), acc[b]) };
                        }
                    }
                    for b in 0..NB {
                        yout[b][row + lane] = acc[b];
                    }
                    lane += 1;
                }
                row += h;
            }
        }
    }

    /// ER pass over the slice range `[s0, s1)`: uncached gathers over
    /// the full xp, scatter-add through the raw `yp` pointer. Extracted
    /// so the serial tail and the parallel scatter share one kernel
    /// body (a raw pointer rather than `&mut [S]` so concurrent workers
    /// never hold aliasing mutable slices).
    ///
    /// # Safety
    /// `yp` must point to at least `yp_len` initialized elements, every
    /// `y_idx_er` target must be `< yp_len` (checked by
    /// `EhybMatrix::validate`), and no other thread may concurrently
    /// access the `yp` elements this range scatters into.
    unsafe fn er_pass_range(
        &self,
        xp: &[S],
        yp: *mut S,
        yp_len: usize,
        s0: usize,
        s1: usize,
        simd: bool,
    ) {
        if simd {
            match lane_width(S::BYTES) {
                16 => self.er_pass_range_simd::<16>(xp, yp, yp_len, s0, s1),
                8 => self.er_pass_range_simd::<8>(xp, yp, yp_len, s0, s1),
                4 => self.er_pass_range_simd::<4>(xp, yp, yp_len, s0, s1),
                _ => self.er_pass_range_simd::<2>(xp, yp, yp_len, s0, s1),
            }
        } else {
            self.er_pass_range_scalar(xp, yp, yp_len, s0, s1);
        }
    }

    /// Scalar ER range walk (see [`Self::er_pass_range`] for the safety
    /// contract).
    ///
    /// # Safety
    /// Same contract as [`Self::er_pass_range`].
    unsafe fn er_pass_range_scalar(
        &self,
        xp: &[S],
        yp: *mut S,
        yp_len: usize,
        s0: usize,
        s1: usize,
    ) {
        let m = &self.m;
        let h = m.slice_height;
        debug_assert!(h <= MAX_H);
        let mut acc = [S::ZERO; MAX_H];
        for s in s0..s1 {
            let base = m.er_slice_ptr[s] as usize;
            let w = m.er_slice_width[s] as usize;
            let jmax = (m.er_rows - s * h).min(h);
            acc[..jmax].fill(S::ZERO);
            for k in 0..w {
                let off = base + k * h;
                for lane in 0..jmax {
                    let idx = off + lane;
                    acc[lane] = unsafe {
                        m.er_vals.get_unchecked(idx).mul_add(
                            *xp.get_unchecked(*m.er_cols.get_unchecked(idx) as usize),
                            acc[lane],
                        )
                    };
                }
            }
            for lane in 0..jmax {
                let out = m.y_idx_er[s * h + lane] as usize;
                // Always-on: a malformed target must panic (as the old
                // safe indexing did), never write out of bounds. One
                // predictable branch per ER row — noise next to the
                // k-loop above.
                assert!(out < yp_len, "yIdxER target {out} out of bounds {yp_len}");
                unsafe { *yp.add(out) += acc[lane] };
            }
        }
    }

    /// Lane-packed ER range walk: `W` ER rows accumulate per pack with
    /// k-ordered fused chains (bit-identical to
    /// [`Self::er_pass_range_scalar`]); the injective scatter-add stays
    /// scalar.
    ///
    /// # Safety
    /// Same contract as [`Self::er_pass_range`].
    unsafe fn er_pass_range_simd<const W: usize>(
        &self,
        xp: &[S],
        yp: *mut S,
        yp_len: usize,
        s0: usize,
        s1: usize,
    ) {
        let m = &self.m;
        let h = m.slice_height;
        debug_assert!(h <= MAX_H);
        let mut acc = [S::ZERO; MAX_H];
        for s in s0..s1 {
            let base = m.er_slice_ptr[s] as usize;
            let w = m.er_slice_width[s] as usize;
            let jmax = (m.er_rows - s * h).min(h);
            let mut lane = 0usize;
            while lane + W <= jmax {
                let mut a = Pack::<S, W>::ZERO;
                for k in 0..w {
                    let off = base + k * h + lane;
                    let vals = Pack::load(&m.er_vals[off..off + W]);
                    // SAFETY: validate() bounds every er_cols entry
                    // below padded_rows == xp.len().
                    let xg = unsafe { Pack::gather_u32_unchecked(xp, &m.er_cols[off..off + W]) };
                    a = vals.mul_add(xg, a);
                }
                a.store(&mut acc[lane..lane + W]);
                lane += W;
            }
            while lane < jmax {
                let mut a = S::ZERO;
                for k in 0..w {
                    let idx = base + k * h + lane;
                    a = unsafe {
                        m.er_vals.get_unchecked(idx).mul_add(
                            *xp.get_unchecked(*m.er_cols.get_unchecked(idx) as usize),
                            a,
                        )
                    };
                }
                acc[lane] = a;
                lane += 1;
            }
            for lane in 0..jmax {
                let out = m.y_idx_er[s * h + lane] as usize;
                // Always-on, as in the scalar walk: malformed targets
                // panic, never write out of bounds.
                assert!(out < yp_len, "yIdxER target {out} out of bounds {yp_len}");
                unsafe { *yp.add(out) += acc[lane] };
            }
        }
    }

    /// Serial ER tail over every slice.
    fn er_pass(&self, xp: &[S], yp: &mut [S], simd: bool) {
        // SAFETY: exclusive &mut access to all of yp; validate() bounds
        // every y_idx_er target below padded_rows == yp.len().
        unsafe {
            self.er_pass_range(xp, yp.as_mut_ptr(), yp.len(), 0, self.m.er_slice_width.len(), simd)
        }
    }

    /// Parallel ER scatter: ER slice ranges split across worker
    /// threads. Each logical ER slot `j = s*h + lane` targets output
    /// row `y_idx_er[j]`, and `y_idx_er` is **injective** over logical
    /// slots (one slot per distinct ER row — guaranteed by the
    /// assembler, asserted by `EhybMatrix::validate`, and re-checked at
    /// engine construction into `er_scatter_disjoint`, which gates this
    /// fan-out), so different slice ranges scatter into
    /// pairwise-disjoint `yp` entries. Each row still gets exactly one
    /// k-ordered accumulate plus one add, so the result is bit-identical
    /// to the serial [`Self::er_pass`].
    fn er_pass_parallel(&self, xp: &[S], yp: &mut [S], simd: bool) {
        let nslices = self.m.er_slice_width.len();
        let threads = par::num_threads().min(nslices).max(1);
        if threads <= 1 || !self.er_scatter_disjoint {
            return self.er_pass(xp, yp, simd);
        }
        let len = yp.len();
        let base = SendPtr(yp.as_mut_ptr());
        let chunk = nslices.div_ceil(threads);
        let ranges: Vec<(usize, usize)> = (0..threads)
            .map(|t| (t * chunk, ((t + 1) * chunk).min(nslices)))
            .filter(|r| r.0 < r.1)
            .collect();
        par::par_for_each(ranges, |_, (s0, s1)| {
            // SAFETY: we hold the only &mut to yp for the duration of
            // the scoped fan-out; each worker writes only its range's
            // y_idx_er targets, disjoint from every other worker's by
            // injectivity, through the raw pointer (no aliasing &mut
            // slices are formed). xp and the matrix are only read.
            unsafe { self.er_pass_range(xp, base.0, len, s0, s1, simd) };
        });
    }

    /// The GPU-order walk (lane-outer, stride-h array access) — kept as
    /// the §Perf baseline. Identical arithmetic per row, so results are
    /// bit-equal to [`Self::spmv_new_order`].
    pub fn spmv_new_order_lane_major(&self, xp: &[S], yp: &mut [S]) {
        let m = &self.m;
        let h = m.slice_height;
        let spp = m.slices_per_part();
        for p in 0..m.num_parts {
            let cached = &xp[p * m.vec_size..(p + 1) * m.vec_size];
            let mut row = p * m.vec_size;
            for ls in 0..spp {
                let s = p * spp + ls;
                let base = m.slice_ptr[s] as usize;
                let w = m.slice_width[s] as usize;
                for lane in 0..h {
                    let mut acc = S::ZERO;
                    let mut idx = base + lane;
                    for _ in 0..w {
                        acc = unsafe {
                            let xc = *cached.get_unchecked(*m.ell_cols.get_unchecked(idx) as usize);
                            m.ell_vals.get_unchecked(idx).mul_add(xc, acc)
                        };
                        idx += h;
                    }
                    yp[row + lane] = acc;
                }
                row += h;
            }
        }
        for s in 0..m.er_slice_width.len() {
            let base = m.er_slice_ptr[s] as usize;
            let w = m.er_slice_width[s] as usize;
            let jmax = (m.er_rows - s * h).min(h);
            for lane in 0..jmax {
                let mut acc = S::ZERO;
                let mut idx = base + lane;
                for _ in 0..w {
                    acc = unsafe {
                        m.er_vals
                            .get_unchecked(idx)
                            .mul_add(*xp.get_unchecked(*m.er_cols.get_unchecked(idx) as usize), acc)
                    };
                    idx += h;
                }
                let out = m.y_idx_er[s * h + lane] as usize;
                yp[out] += acc;
            }
        }
    }

    /// Permute `x` (old order) into `xp` (padded new order).
    fn permute_in(&self, x: &[S], xp: &mut [S]) {
        let m = &self.m;
        for new in 0..m.padded_rows() {
            let old = m.iperm[new] as usize;
            xp[new] = if old < m.n { x[old] } else { S::ZERO };
        }
    }

    /// Scatter `yp` (padded new order) back into `y` (old order).
    fn permute_out(&self, yp: &[S], y: &mut [S]) {
        let m = &self.m;
        for new in 0..m.padded_rows() {
            let old = m.iperm[new] as usize;
            if old < m.n {
                y[old] = yp[new];
            }
        }
    }

    fn want_parallel(&self) -> bool {
        self.m.num_parts > 1 && self.m.nnz() >= PAR_MIN_NNZ && par::num_threads() > 1
    }
}

impl<S: Scalar> PermutedSpmv<S> for EhybCpu<S> {
    fn padded_len(&self) -> usize {
        self.m.padded_rows()
    }

    fn inner_perm(&self) -> &[u32] {
        &self.m.perm
    }

    fn inner_iperm(&self) -> &[u32] {
        &self.m.iperm
    }

    fn spmv_permuted(&self, xq: &[S], yq: &mut [S]) {
        assert_eq!(xq.len(), self.m.padded_rows());
        assert_eq!(yq.len(), self.m.padded_rows());
        let t = crate::profile::timer();
        if self.want_parallel() {
            self.spmv_new_order_parallel(xq, yq);
        } else {
            self.spmv_new_order(xq, yq);
        }
        self.profile.record(1, crate::profile::elapsed(t), || {
            crate::profile::CallCost::of_ehyb(&self.m)
        });
    }

    fn spmv_batch_permuted(&self, xqs: &[&[S]], yqs: &mut [&mut [S]]) {
        let t = crate::profile::timer();
        self.spmm_new_order(xqs, yqs);
        self.profile.record(xqs.len(), crate::profile::elapsed(t), || {
            crate::profile::CallCost::of_ehyb(&self.m)
        });
    }
}

impl<S: Scalar> SpmvEngine<S> for EhybCpu<S> {
    fn name(&self) -> &'static str {
        "ehyb"
    }

    fn spmv(&self, x: &[S], y: &mut [S]) {
        let m = &self.m;
        assert_eq!(x.len(), m.n);
        assert_eq!(y.len(), m.n);
        let t = crate::profile::timer();
        let mut scr = self.pool.take(1, m.padded_rows());
        self.permute_in(x, &mut scr.xp);
        if self.want_parallel() {
            self.spmv_new_order_parallel(&scr.xp, &mut scr.yp);
        } else {
            self.spmv_new_order(&scr.xp, &mut scr.yp);
        }
        self.permute_out(&scr.yp, y);
        self.pool.put(scr);
        self.profile.record(1, crate::profile::elapsed(t), || {
            crate::profile::CallCost::of_ehyb(&self.m)
        });
    }

    fn spmv_batch(&self, xs: VecBatch<'_, S>, ys: &mut VecBatchMut<'_, S>) {
        assert_eq!(xs.width(), ys.width(), "batch inputs/outputs disagree");
        let bw = xs.width();
        if bw == 0 {
            return;
        }
        let m = &self.m;
        assert_eq!(xs.n(), m.n);
        assert_eq!(ys.n(), m.n);
        let t = crate::profile::timer();
        let padded = m.padded_rows();
        let mut scr = self.pool.take(bw, padded);
        for (b, chunk) in scr.xp.chunks_mut(padded).enumerate() {
            self.permute_in(xs.col(b), chunk);
        }
        {
            let xcols: Vec<&[S]> = scr.xp.chunks(padded).collect();
            let mut ycols: Vec<&mut [S]> = scr.yp.chunks_mut(padded).collect();
            self.spmm_new_order(&xcols, &mut ycols);
        }
        for (b, chunk) in scr.yp.chunks(padded).enumerate() {
            self.permute_out(chunk, ys.col_mut(b));
        }
        self.pool.put(scr);
        self.profile.record(bw, crate::profile::elapsed(t), || {
            crate::profile::CallCost::of_ehyb(&self.m)
        });
    }

    fn nrows(&self) -> usize {
        self.m.n
    }
    fn nnz(&self) -> usize {
        self.m.nnz()
    }
    fn format_bytes(&self) -> usize {
        self.m.bytes()
    }
    fn permuted_kernel(&self) -> Option<&dyn PermutedSpmv<S>> {
        Some(self)
    }
    fn kernel_profile(&self) -> Option<crate::profile::KernelProfile> {
        self.profile.snapshot("ehyb")
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::batch::BatchBuf;
    use crate::preprocess::{EhybPlan, PreprocessConfig};
    use crate::spmv::testutil::validate_engine;
    use crate::sparse::gen::{circuit, poisson2d, poisson3d, unstructured_mesh};

    fn cfg(v: usize) -> PreprocessConfig {
        PreprocessConfig { vec_size_override: Some(v), ..Default::default() }
    }

    #[test]
    fn validates_poisson2d() {
        let m = poisson2d::<f64>(20, 20);
        let plan = EhybPlan::build(&m, &cfg(64)).unwrap();
        validate_engine(&EhybCpu::new(&plan), &m);
    }

    #[test]
    fn validates_poisson3d_f32() {
        let m = poisson3d::<f32>(9, 8, 7);
        let plan = EhybPlan::build(&m, &cfg(96)).unwrap();
        validate_engine(&EhybCpu::new(&plan), &m);
    }

    #[test]
    fn validates_unstructured() {
        let m = unstructured_mesh::<f64>(24, 24, 0.7, 8);
        let plan = EhybPlan::build(&m, &cfg(128)).unwrap();
        validate_engine(&EhybCpu::new(&plan), &m);
    }

    #[test]
    fn validates_circuit() {
        let m = circuit::<f64>(900, 4, 0.04, 15);
        let plan = EhybPlan::build(&m, &cfg(64)).unwrap();
        validate_engine(&EhybCpu::new(&plan), &m);
    }

    #[test]
    fn matches_reference_semantics() {
        // Engine must agree with the EhybMatrix reference spmv exactly
        // (same arithmetic order).
        let m = unstructured_mesh::<f64>(16, 16, 0.5, 6);
        let plan = EhybPlan::build(&m, &cfg(64)).unwrap();
        let engine = EhybCpu::new(&plan);
        let x: Vec<f64> = (0..m.nrows()).map(|i| (i as f64 * 0.37).sin()).collect();
        let mut y1 = vec![0.0; m.nrows()];
        let mut y2 = vec![0.0; m.nrows()];
        engine.spmv(&x, &mut y1);
        plan.matrix.spmv(&x, &mut y2);
        assert_eq!(y1, y2);
    }

    #[test]
    fn loop_orders_agree_exactly() {
        // k-outer (CPU-optimized) and lane-outer (GPU-order baseline)
        // accumulate per-row in the same k order => bit-identical.
        let m = unstructured_mesh::<f64>(20, 20, 0.6, 9);
        let plan = EhybPlan::build(&m, &cfg(64)).unwrap();
        let engine = EhybCpu::new(&plan);
        let xp = plan.matrix.permute_x(
            &(0..m.nrows()).map(|i| (i as f64 * 0.11).cos()).collect::<Vec<_>>(),
        );
        let mut y1 = vec![0.0; plan.matrix.padded_rows()];
        let mut y2 = vec![0.0; plan.matrix.padded_rows()];
        engine.spmv_new_order(&xp, &mut y1);
        engine.spmv_new_order_lane_major(&xp, &mut y2);
        assert_eq!(y1, y2);
    }

    #[test]
    fn new_order_path_consistent() {
        let m = poisson2d::<f64>(16, 16);
        let plan = EhybPlan::build(&m, &cfg(64)).unwrap();
        let engine = EhybCpu::new(&plan);
        let x: Vec<f64> = (0..256).map(|i| i as f64 * 0.01).collect();
        let xp = plan.matrix.permute_x(&x);
        let mut yp = vec![0.0; plan.matrix.padded_rows()];
        engine.spmv_new_order(&xp, &mut yp);
        let y = plan.matrix.unpermute_y(&yp);
        let mut y_ref = vec![0.0; 256];
        m.spmv(&x, &mut y_ref);
        for i in 0..256 {
            assert!((y[i] - y_ref[i]).abs() < 1e-12);
        }
    }

    fn parallel_matches_serial_on<SC: Scalar>(m: &crate::sparse::csr::Csr<SC>, vec_size: usize) {
        let plan = EhybPlan::build(m, &cfg(vec_size)).unwrap();
        let engine = EhybCpu::new(&plan);
        let xp = plan.matrix.permute_x(
            &(0..m.nrows())
                .map(|i| SC::from_f64((((i * 13 + 7) % 29) as f64) * 0.125 - 1.0))
                .collect::<Vec<_>>(),
        );
        let mut y_ser = vec![SC::ZERO; plan.matrix.padded_rows()];
        let mut y_par = vec![SC::ZERO; plan.matrix.padded_rows()];
        engine.spmv_new_order(&xp, &mut y_ser);
        engine.spmv_new_order_parallel(&xp, &mut y_par);
        assert_eq!(
            y_ser,
            y_par,
            "parallel walk diverged ({}, er_nnz={})",
            SC::NAME,
            plan.matrix.er_nnz
        );
    }

    #[test]
    fn parallel_bit_identical_to_serial_f64() {
        // Big enough for several partitions so the fan-out is real.
        parallel_matches_serial_on(&poisson2d::<f64>(48, 48), 64);
    }

    #[test]
    fn parallel_bit_identical_to_serial_f32() {
        parallel_matches_serial_on(&poisson2d::<f32>(48, 48), 96);
    }

    #[test]
    fn parallel_bit_identical_on_er_heavy_matrix() {
        // A hub-heavy circuit graph at tiny vec_size scatters a large
        // fraction of nnz into the ER part — this exercises the parallel
        // ER scatter across many slices, not just the ELL fan-out.
        let m = circuit::<f64>(2_000, 5, 0.05, 23);
        let plan = EhybPlan::build(&m, &cfg(64)).unwrap();
        assert!(
            plan.matrix.er_fraction() > 0.2,
            "matrix not ER-heavy enough: {}",
            plan.matrix.er_fraction()
        );
        assert!(plan.matrix.er_slice_width.len() >= 4, "need several ER slices");
        parallel_matches_serial_on(&m, 64);
    }

    #[test]
    fn non_injective_er_targets_fall_back_to_serial() {
        // EhybMatrix has public fields, so a hand-assembled matrix can
        // carry duplicate y_idx_er targets without ever being
        // validated. The engine must detect that at construction and
        // keep the ER tail serial (same result as the serial kernel on
        // the same data) instead of fanning out a racy scatter.
        let m = circuit::<f64>(600, 4, 0.05, 3);
        let plan = EhybPlan::build(&m, &cfg(32)).unwrap();
        let mut bad = plan.matrix.clone();
        assert!(bad.er_rows >= 2, "need at least two ER rows");
        bad.y_idx_er[1] = bad.y_idx_er[0]; // duplicate scatter target
        let engine = EhybCpu::from_matrix(bad.clone());
        assert!(!engine.er_scatter_disjoint, "duplicate target not detected");
        let xp: Vec<f64> =
            (0..bad.padded_rows()).map(|i| ((i * 11 + 3) % 13) as f64 * 0.5 - 3.0).collect();
        let mut y_ser = vec![0.0; bad.padded_rows()];
        let mut y_par = vec![0.0; bad.padded_rows()];
        engine.spmv_new_order(&xp, &mut y_ser);
        engine.spmv_new_order_parallel(&xp, &mut y_par);
        assert_eq!(y_ser, y_par);
    }

    #[test]
    fn simd_walk_bit_identical_to_scalar() {
        // The lane-packed ELL walk and ER tail preserve each row's
        // k-ordered fused chain, so simd == scalar bit-for-bit — on an
        // ER-heavy matrix too, and for both scalar types.
        for &(nodes, hubs) in &[(900usize, 15usize), (2_000, 23)] {
            let m = circuit::<f64>(nodes, 4, 0.05, hubs);
            let plan = EhybPlan::build(&m, &cfg(64)).unwrap();
            let engine = EhybCpu::new(&plan);
            let xp = plan.matrix.permute_x(
                &(0..m.nrows()).map(|i| ((i * 13 + 7) % 31) as f64 * 0.125 - 1.5).collect::<Vec<_>>(),
            );
            let mut y_sc = vec![0.0; plan.matrix.padded_rows()];
            let mut y_simd = vec![0.0; plan.matrix.padded_rows()];
            engine.spmv_new_order_scalar(&xp, &mut y_sc);
            engine.spmv_new_order_simd(&xp, &mut y_simd);
            assert_eq!(y_sc, y_simd, "nodes={nodes}");
        }
        let m = poisson2d::<f32>(40, 40);
        let plan = EhybPlan::build(&m, &cfg(96)).unwrap();
        let engine = EhybCpu::new(&plan);
        let xp = plan.matrix.permute_x(
            &(0..m.nrows()).map(|i| ((i * 7 + 3) % 17) as f32 * 0.25 - 2.0).collect::<Vec<_>>(),
        );
        let mut y_sc = vec![0.0f32; plan.matrix.padded_rows()];
        let mut y_simd = vec![0.0f32; plan.matrix.padded_rows()];
        engine.spmv_new_order_scalar(&xp, &mut y_sc);
        engine.spmv_new_order_simd(&xp, &mut y_simd);
        assert_eq!(y_sc, y_simd, "f32");
    }

    #[test]
    fn spmm_simd_bit_identical_to_scalar() {
        let m = unstructured_mesh::<f64>(28, 28, 0.6, 11);
        let plan = EhybPlan::build(&m, &cfg(64)).unwrap();
        let engine = EhybCpu::new(&plan);
        let padded = plan.matrix.padded_rows();
        // Width 7 exercises the 4/2/1 block dispatch in both variants.
        let xps: Vec<Vec<f64>> = (0..7)
            .map(|t| {
                plan.matrix.permute_x(
                    &(0..m.nrows())
                        .map(|i| ((i * 5 + t * 13) % 19) as f64 * 0.5 - 2.0)
                        .collect::<Vec<_>>(),
                )
            })
            .collect();
        let xrefs: Vec<&[f64]> = xps.iter().map(|v| v.as_slice()).collect();
        let mut y_sc = vec![vec![0.0f64; padded]; 7];
        let mut y_simd = vec![vec![0.0f64; padded]; 7];
        {
            let mut yr: Vec<&mut [f64]> = y_sc.iter_mut().map(|v| v.as_mut_slice()).collect();
            engine.spmm_new_order_with(&xrefs, &mut yr, false);
        }
        {
            let mut yr: Vec<&mut [f64]> = y_simd.iter_mut().map(|v| v.as_mut_slice()).collect();
            engine.spmm_new_order_with(&xrefs, &mut yr, true);
        }
        assert_eq!(y_sc, y_simd);
    }

    #[test]
    fn spmm_bit_identical_to_repeated_spmv() {
        let m = unstructured_mesh::<f64>(28, 28, 0.6, 11);
        let plan = EhybPlan::build(&m, &cfg(64)).unwrap();
        let engine = EhybCpu::new(&plan);
        let padded = plan.matrix.padded_rows();
        // Odd batch width exercises the 4/2/1 block dispatch.
        let xps: Vec<Vec<f64>> = (0..7)
            .map(|t| {
                plan.matrix.permute_x(
                    &(0..m.nrows())
                        .map(|i| ((i * 3 + t * 17) % 23) as f64 * 0.25 - 2.5)
                        .collect::<Vec<_>>(),
                )
            })
            .collect();
        let xrefs: Vec<&[f64]> = xps.iter().map(|v| v.as_slice()).collect();
        let mut ydata = vec![vec![0.0f64; padded]; xrefs.len()];
        {
            let mut yrefs: Vec<&mut [f64]> = ydata.iter_mut().map(|v| v.as_mut_slice()).collect();
            engine.spmm_new_order(&xrefs, &mut yrefs);
        }
        for (xp, yb) in xrefs.iter().zip(&ydata) {
            let mut y1 = vec![0.0; padded];
            engine.spmv_new_order(xp, &mut y1);
            assert_eq!(&y1, yb);
        }
    }

    #[test]
    fn batch_engine_entry_matches_single() {
        let m = poisson3d::<f64>(10, 9, 8);
        let plan = EhybPlan::build(&m, &cfg(128)).unwrap();
        let engine = EhybCpu::new(&plan);
        let n = m.nrows();
        let mut xs = BatchBuf::<f64>::zeros(n, 5);
        for t in 0..5 {
            for i in 0..n {
                xs.col_mut(t)[i] = ((i + t * 41) as f64 * 0.01).sin();
            }
        }
        let mut ys = BatchBuf::<f64>::zeros(n, 5);
        {
            let mut ysv = ys.view_mut();
            engine.spmv_batch(xs.view(), &mut ysv);
        }
        for t in 0..5 {
            let mut y1 = vec![0.0; n];
            engine.spmv(xs.col(t), &mut y1);
            assert_eq!(&y1[..], ys.col(t));
        }
    }

    #[test]
    fn concurrent_spmv_uses_distinct_scratch() {
        // Hammer one engine from several threads; every result must
        // match the serial answer (the pool hands out disjoint buffers).
        let m = poisson2d::<f64>(32, 32);
        let plan = EhybPlan::build(&m, &cfg(64)).unwrap();
        let engine = std::sync::Arc::new(EhybCpu::new(&plan));
        let n = m.nrows();
        let mut handles = Vec::new();
        for t in 0..6 {
            let engine = engine.clone();
            let m = m.clone();
            handles.push(std::thread::spawn(move || {
                let x: Vec<f64> =
                    (0..n).map(|i| ((i * 7 + t * 13) % 19) as f64 * 0.5 - 4.0).collect();
                let mut y = vec![0.0; n];
                for _ in 0..8 {
                    engine.spmv(&x, &mut y);
                }
                let mut want = vec![0.0; n];
                m.spmv(&x, &mut want);
                for i in 0..n {
                    assert!((y[i] - want[i]).abs() < 1e-10);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
    }
}
