//! Report emission: markdown tables (for EXPERIMENTS.md) and CSV (for
//! external plotting) from the harness aggregates, the service
//! observability surface (batch-width / bytes-moved / shard metrics),
//! and the machine-readable bench report (`BENCH_ci.json` in CI).

use super::ablation::{AblationRow, DriftAblationRow, ReorderRow, TrafficRow};
use super::runner::ValidationRow;
use super::tables::{Fig6Row, FigureSeries, SpeedupRow};
use crate::runtime::json::{self, Json};
use crate::telemetry::{ServiceMetrics, TelemetrySnapshot};
use crate::shard::ShardedEngine;
use crate::sparse::scalar::Scalar;
use crate::spmv::SpmvEngine;
use std::fmt::Write as _;

/// Tables 1/2 as markdown (the paper's exact columns).
pub fn speedup_markdown(title: &str, rows: &[SpeedupRow]) -> String {
    let mut s = String::new();
    let _ = writeln!(s, "### {title}\n");
    let _ = writeln!(
        s,
        "| SpMV framework | EHYB faster in % | max speedup | min speedup | average speedup | geomean |"
    );
    let _ = writeln!(s, "|---|---|---|---|---|---|");
    for r in rows {
        let _ = writeln!(
            s,
            "| {} | {:.1}% | {:.2} | {:.2} | {:.3} | {:.3} |",
            r.framework, r.win_pct, r.max, r.min, r.avg, r.geomean
        );
    }
    s
}

/// Figure 2-5 series as CSV: matrix,nnz,<framework...>.
pub fn figure_csv(f: &FigureSeries) -> String {
    let mut s = String::new();
    let _ = write!(s, "matrix,nnz");
    for fw in &f.frameworks {
        let _ = write!(s, ",{fw}");
    }
    let _ = writeln!(s);
    for (i, m) in f.matrices.iter().enumerate() {
        let _ = write!(s, "{m},{}", f.nnz[i]);
        for series in &f.gflops {
            let _ = write!(s, ",{:.3}", series[i]);
        }
        let _ = writeln!(s);
    }
    s
}

/// Compact figure summary for the terminal: per-framework GFLOPS
/// geomean + EHYB win count (the "shape" of the plot).
pub fn figure_summary(f: &FigureSeries) -> String {
    let mut s = String::new();
    let _ = writeln!(s, "{} matrices; per-framework geomean GFLOPS:", f.matrices.len());
    for (fi, fw) in f.frameworks.iter().enumerate() {
        let logs: f64 = f.gflops[fi].iter().map(|g| g.max(1e-9).ln()).sum();
        let geo = (logs / f.matrices.len().max(1) as f64).exp();
        let _ = writeln!(s, "  {fw:>15}: {geo:8.2}");
    }
    s
}

/// Figure 6 as markdown.
pub fn fig6_markdown(rows: &[Fig6Row]) -> String {
    let mut s = String::new();
    let _ = writeln!(s, "| matrix | partition (xSpMV) | reorder (xSpMV) | total (xSpMV) |");
    let _ = writeln!(s, "|---|---|---|---|");
    for r in rows {
        let _ = writeln!(
            s,
            "| {} | {:.0} | {:.0} | {:.0} |",
            r.matrix, r.partition_x, r.reorder_x, r.total_x
        );
    }
    s
}

/// Service metrics as markdown — makes the request-fusion win
/// observable: fused-batch widths, estimated bytes streamed, and the
/// latency profile.
pub fn service_markdown(title: &str, m: &ServiceMetrics) -> String {
    use std::sync::atomic::Ordering;
    let mut s = String::new();
    let _ = writeln!(s, "### {title}\n");
    let _ = writeln!(
        s,
        "| requests | fused batches | mean width | max width | bytes moved | mean latency (ms) | p50 (ms) | p99 (ms) | shed | faults | respawns | deadline misses | batch limit |"
    );
    let _ = writeln!(s, "|---|---|---|---|---|---|---|---|---|---|---|---|---|");
    let limit = m.adaptive_max_batch.load(Ordering::Relaxed);
    let _ = writeln!(
        s,
        "| {} | {} | {:.2} | {} | {} | {:.3} | {:.3} | {:.3} | {} | {} | {} | {} | {} |",
        m.requests.load(Ordering::Relaxed),
        m.batches.load(Ordering::Relaxed),
        m.batch_width.mean(),
        m.batch_width.max(),
        m.bytes_moved.load(Ordering::Relaxed),
        1e3 * m.spmv_latency.mean_secs(),
        1e3 * m.spmv_latency.quantile_secs(0.5),
        1e3 * m.spmv_latency.quantile_secs(0.99),
        m.shed.load(Ordering::Relaxed),
        m.faults.load(Ordering::Relaxed),
        m.respawns.load(Ordering::Relaxed),
        m.deadline_misses.load(Ordering::Relaxed),
        // 0 = fixed-limit service; adaptive services publish the live
        // shed-rate-driven limit here.
        if limit == 0 { "fixed".to_string() } else { limit.to_string() },
    );
    let _ = write!(s, "\nbatch widths:");
    for i in 0..m.batch_width.num_buckets() {
        let c = m.batch_width.bucket(i);
        if c > 0 {
            let _ = write!(s, " {}+:{}", 1u64 << i, c);
        }
    }
    let _ = writeln!(s);
    s
}

/// A frozen [`TelemetrySnapshot`] as markdown: the metric tables
/// (counters, gauges, histograms with p50/p99), the span tree, and the
/// trace-tagged health events — the operator-facing rendering of
/// `ctx.telemetry_snapshot()` (also what the `stats` CLI subcommand
/// prints).
pub fn telemetry_markdown(title: &str, snap: &TelemetrySnapshot) -> String {
    let mut s = String::new();
    let _ = writeln!(s, "### {title}\n");
    if !snap.counters.is_empty() {
        let _ = writeln!(s, "| counter | value |");
        let _ = writeln!(s, "|---|---|");
        for (k, v) in &snap.counters {
            let _ = writeln!(s, "| {k} | {v} |");
        }
        let _ = writeln!(s);
    }
    if !snap.gauges.is_empty() {
        let _ = writeln!(s, "| gauge | value |");
        let _ = writeln!(s, "|---|---|");
        for (k, v) in &snap.gauges {
            let _ = writeln!(s, "| {k} | {v:.6} |");
        }
        let _ = writeln!(s);
    }
    if !snap.histograms.is_empty() {
        let _ =
            writeln!(s, "| histogram | count | mean (ms) | p50 (ms) | p99 (ms) | max (ms) |");
        let _ = writeln!(s, "|---|---|---|---|---|---|");
        for (k, h) in &snap.histograms {
            let _ = writeln!(
                s,
                "| {k} | {} | {:.3} | {:.3} | {:.3} | {:.3} |",
                h.count,
                1e3 * h.mean_secs,
                1e3 * h.p50_secs,
                1e3 * h.p99_secs,
                1e3 * h.max_secs
            );
        }
        let _ = writeln!(s);
    }
    let _ = writeln!(
        s,
        "{} spans ({} dropped), {} events ({} dropped), {} traces\n",
        snap.spans.len(),
        snap.spans_dropped,
        snap.events.len(),
        snap.events_dropped,
        snap.known_traces().len()
    );
    if !snap.spans.is_empty() {
        let _ = writeln!(s, "```\n{}```", snap.span_tree());
    }
    if !snap.health_events.is_empty() {
        let _ = writeln!(s, "\nhealth events:");
        for ev in &snap.health_events {
            if ev.trace == 0 {
                let _ = writeln!(s, "- {}", ev.detail);
            } else {
                let _ = writeln!(s, "- [trace {}] {}", ev.trace, ev.detail);
            }
        }
    }
    s
}

/// A context's degradation ledger as markdown: the counters from
/// [`crate::resilience::HealthReport`] plus the (capped) event log —
/// the operator-facing view of `ctx.health()`.
pub fn health_markdown(title: &str, h: &crate::resilience::HealthReport) -> String {
    let mut s = String::new();
    let _ = writeln!(s, "### {title}\n");
    let _ = writeln!(
        s,
        "| status | engine fallbacks | solver restarts | non-finite outputs | rejected inputs | model drifts |"
    );
    let _ = writeln!(s, "|---|---|---|---|---|---|");
    let status = if h.healthy() {
        "healthy"
    } else if h.degraded() {
        "degraded"
    } else {
        "recovering"
    };
    let _ = writeln!(
        s,
        "| {} | {} | {} | {} | {} | {} |",
        status,
        h.engine_fallbacks,
        h.solver_restarts,
        h.nonfinite_outputs,
        h.rejected_inputs,
        h.model_drifts
    );
    if !h.events.is_empty() {
        let _ = writeln!(s);
        for ev in &h.events {
            let _ = writeln!(s, "- {ev}");
        }
    }
    s
}

/// An observed [`crate::profile::KernelProfile`] as markdown: the
/// aggregate call/lane/throughput row plus the per-component byte
/// attribution and the structural figures — the operator-facing view
/// of `ctx.profile()` (also what the `profile` CLI subcommand prints).
pub fn profile_markdown(title: &str, p: &crate::profile::KernelProfile) -> String {
    let mut s = String::new();
    let _ = writeln!(s, "### {title}\n");
    let _ = writeln!(
        s,
        "| engine | calls | lanes | tile reuse | total bytes | bytes/lane | GFLOPS | GB/s |"
    );
    let _ = writeln!(s, "|---|---|---|---|---|---|---|---|");
    let _ = writeln!(
        s,
        "| {} | {} | {} | {:.2} | {} | {:.1} | {:.2} | {:.2} |",
        p.engine,
        p.calls,
        p.lanes,
        p.tile_reuse(),
        p.total_bytes(),
        p.bytes_per_lane(),
        p.gflops(),
        p.bandwidth_gbs()
    );
    let _ = writeln!(s, "\n| component | bytes |");
    let _ = writeln!(s, "|---|---|");
    for (name, b) in [
        ("ell-stream", p.ell_bytes),
        ("er-tail", p.er_bytes),
        ("meta", p.meta_bytes),
        ("x-fill", p.x_fill_bytes),
        ("x-gather", p.x_gather_bytes),
        ("halo", p.halo_bytes),
        ("write", p.write_bytes),
    ] {
        let _ = writeln!(s, "| {name} | {b} |");
    }
    let _ = writeln!(
        s,
        "\nx footprint: {} lines; padding: {} slots ({} bytes/lane); ER scatter rows: {}",
        p.x_lines, p.pad_slots, p.pad_bytes, p.er_scatter_rows
    );
    s
}

/// A [`crate::profile::DriftReport`] as markdown: one row per traffic
/// component (observed per-lane vs the simulator's prediction, with
/// the symmetric relative gap), then the total-bytes / DRAM-model /
/// seconds summary and the verdict against the threshold.
pub fn drift_markdown(title: &str, d: &crate::profile::DriftReport) -> String {
    let mut s = String::new();
    let _ = writeln!(s, "### {title}\n");
    let _ = writeln!(s, "| component | observed bytes/lane | predicted bytes | rel drift |");
    let _ = writeln!(s, "|---|---|---|---|");
    for c in &d.components {
        let _ = writeln!(
            s,
            "| {} | {:.0} | {:.0} | {:.1}% |",
            c.component,
            c.observed_bytes,
            c.predicted_bytes,
            100.0 * c.rel()
        );
    }
    let _ = writeln!(
        s,
        "| total | {:.0} | {:.0} | {:.1}% |",
        d.observed_bytes,
        d.predicted_bytes,
        100.0 * d.bytes_drift()
    );
    let _ = writeln!(
        s,
        "\nvs DRAM model ({} bytes): {:.1}%; secs {:.3e} observed vs {:.3e} predicted ({})",
        d.predicted_dram_bytes,
        100.0 * d.dram_drift(),
        d.observed_secs,
        d.predicted_secs,
        if d.calibrated { "calibrated" } else { "uncalibrated" }
    );
    let verdict = if d.exceeded() {
        let worst = d
            .worst_component()
            .filter(|c| c.rel() >= d.stamp())
            .map_or("calibrated-secs", |c| c.component);
        format!("DRIFTED: {} off by {:.1}%", worst, 100.0 * d.stamp())
    } else {
        format!("within bounds ({:.1}% <= {:.0}%)", 100.0 * d.stamp(), 100.0 * d.threshold)
    };
    let _ = writeln!(s, "{} — engine {}, {} lanes", verdict, d.engine, d.lanes);
    s
}

/// Solve outcomes as markdown — one row per labelled
/// [`crate::coordinator::SolveReport`], with the typed
/// [`crate::coordinator::SolveStatus`] spelled out (converged is no
/// longer a bare boolean: breakdown and divergence are distinct,
/// actionable outcomes).
pub fn solve_markdown(title: &str, rows: &[super::tables::SolveRow]) -> String {
    let mut s = String::new();
    let _ = writeln!(s, "### {title}\n");
    let _ = writeln!(s, "| case | solver | status | iters | rel residual | spmv calls |");
    let _ = writeln!(s, "|---|---|---|---|---|---|");
    for r in rows {
        let _ = writeln!(
            s,
            "| {} | {} | {} | {} | {:.3e} | {} |",
            r.label, r.solver, r.status, r.iters, r.rel_residual, r.spmv_count
        );
    }
    s
}

/// Per-shard execution metrics of a [`ShardedEngine`] as markdown —
/// the sharded-service observability surface: row/nnz ownership per
/// shard plus how many single-vector and fused-batch kernels each
/// shard ran (one fused batch per shard per service drain).
pub fn shard_markdown<S: Scalar>(title: &str, e: &ShardedEngine<S>) -> String {
    use std::sync::atomic::Ordering;
    let mut s = String::new();
    let _ = writeln!(s, "### {title}\n");
    let _ = writeln!(s, "| shard | rows | nnz | nnz % | spmv calls | fused batches | lanes |");
    let _ = writeln!(s, "|---|---|---|---|---|---|---|");
    let total_nnz = e.nnz().max(1);
    for (i, (st, rg)) in e.stats().iter().zip(e.ranges()).enumerate() {
        let _ = writeln!(
            s,
            "| {} | {}..{} | {} | {:.1}% | {} | {} | {} |",
            i,
            rg.start,
            rg.end,
            st.nnz,
            100.0 * st.nnz as f64 / total_nnz as f64,
            st.spmv_calls.load(Ordering::Relaxed),
            st.batch_calls.load(Ordering::Relaxed),
            st.lanes.load(Ordering::Relaxed),
        );
    }
    s
}

/// One matrix's engine sweep in the machine-readable bench report.
#[derive(Clone, Debug)]
pub struct BenchCase {
    pub matrix: String,
    pub n: usize,
    pub nnz: usize,
    /// `(engine name, GFLOPS)` rows, e.g. from
    /// [`crate::harness::runner::bench_cpu_engines`].
    pub engines: Vec<(String, f64)>,
}

/// The CI bench artifact (`BENCH_ci.json`): deterministic JSON via
/// [`crate::runtime::json`] so the perf trajectory gets stable,
/// diffable data points per commit.
pub fn bench_json(label: &str, cases: &[BenchCase]) -> Json {
    let cases = cases
        .iter()
        .map(|c| {
            let engines = Json::Obj(
                c.engines.iter().map(|(name, g)| (name.clone(), Json::Num(*g))).collect(),
            );
            json::obj([
                ("matrix", Json::Str(c.matrix.clone())),
                ("n", Json::Num(c.n as f64)),
                ("nnz", Json::Num(c.nnz as f64)),
                ("gflops", engines),
            ])
        })
        .collect();
    json::obj([
        ("schema", Json::Str("ehyb-bench-v1".into())),
        ("label", Json::Str(label.into())),
        ("cases", Json::Arr(cases)),
    ])
}

/// The reorder ablation as markdown: per-spec locality metrics
/// (bandwidth / profile / windowed distinct-column footprint /
/// simulated x DRAM bytes), the cache-aware cross-shard cut, and
/// simulated EHYB throughput.
pub fn reorder_markdown(title: &str, rows: &[ReorderRow]) -> String {
    let mut s = String::new();
    let _ = writeln!(s, "### {title}\n");
    let _ = writeln!(
        s,
        "| ordering | bandwidth | profile | window footprint | x DRAM bytes | cut nnz | GFLOPS | ER fraction |"
    );
    let _ = writeln!(s, "|---|---|---|---|---|---|---|---|");
    for r in rows {
        let _ = writeln!(
            s,
            "| {} | {} | {} | {:.1} | {} | {} | {:.2} | {:.4} |",
            r.spec,
            r.bandwidth,
            r.profile,
            r.footprint,
            r.x_dram_bytes,
            r.cut_nnz,
            r.gflops,
            r.er_fraction
        );
    }
    s
}

/// The traffic ablation as markdown: one row per engine with the
/// simulated per-level byte counters, L2 hit rate, x reuse factor, the
/// replay's predicted SpMV time, and the measured CPU throughput it is
/// validated against.
pub fn traffic_markdown(title: &str, rows: &[TrafficRow]) -> String {
    let mut s = String::new();
    let _ = writeln!(s, "### {title}\n");
    let _ = writeln!(
        s,
        "| engine | DRAM bytes | L2 bytes | shm bytes | L2 hit rate | x reuse | predicted us | measured GFLOPS |"
    );
    let _ = writeln!(s, "|---|---|---|---|---|---|---|---|");
    for r in rows {
        let _ = writeln!(
            s,
            "| {} | {} | {} | {} | {:.3} | {:.2} | {:.2} | {:.2} |",
            r.engine,
            r.dram_bytes,
            r.l2_bytes,
            r.shm_bytes,
            r.l2_hit_rate,
            r.x_reuse,
            1e6 * r.predicted_secs,
            r.measured_gflops
        );
    }
    s
}

/// The oracle-validation sweep as markdown: per matrix, the engine the
/// traffic-scored search picked vs the measured-probe winner, the
/// measured throughput of each, and the agreement verdict — plus a
/// trailing majority line.
pub fn traffic_validation_markdown(title: &str, rows: &[ValidationRow]) -> String {
    let mut s = String::new();
    let _ = writeln!(s, "### {title}\n");
    let _ = writeln!(
        s,
        "| matrix | simulated pick | measured pick | sim-pick GFLOPS | measured-pick GFLOPS | agree |"
    );
    let _ = writeln!(s, "|---|---|---|---|---|---|");
    for r in rows {
        let _ = writeln!(
            s,
            "| {} | {} | {} | {:.2} | {:.2} | {} |",
            r.matrix,
            r.simulated_pick,
            r.measured_pick,
            r.sim_pick_gflops,
            r.measured_pick_gflops,
            if r.agree { "yes" } else { "no" }
        );
    }
    let agreed = rows.iter().filter(|r| r.agree).count();
    let _ = writeln!(s, "\nagreement: {agreed}/{} cases", rows.len());
    s
}

/// The drift (calibration) ablation as markdown: the Heuristic pick
/// with and without the fitted per-host calibration, the oracle score
/// each won on, and the measured throughput of each pick.
pub fn drift_ablation_markdown(title: &str, rows: &[DriftAblationRow]) -> String {
    let mut s = String::new();
    let _ = writeln!(s, "### {title}\n");
    let _ = writeln!(
        s,
        "| variant | pick | oracle us | measured GFLOPS | fit residual | samples |"
    );
    let _ = writeln!(s, "|---|---|---|---|---|---|");
    for r in rows {
        let _ = writeln!(
            s,
            "| {} | {} | {:.2} | {:.2} | {:.3} | {} |",
            r.variant,
            r.pick,
            1e6 * r.score_secs,
            r.measured_gflops,
            r.fit_residual,
            r.samples
        );
    }
    s
}

pub fn ablation_markdown(title: &str, rows: &[AblationRow]) -> String {
    let mut s = String::new();
    let _ = writeln!(s, "### {title}\n");
    let _ = writeln!(s, "| variant | GFLOPS | ER fraction | ELL fill |");
    let _ = writeln!(s, "|---|---|---|---|");
    for r in rows {
        let _ = writeln!(
            s,
            "| {} | {:.2} | {:.4} | {:.3} |",
            r.variant, r.gflops, r.er_fraction, r.ell_fill
        );
    }
    s
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::harness::tables::SpeedupRow;

    #[test]
    fn speedup_markdown_contains_rows() {
        let rows = vec![SpeedupRow {
            framework: "csr5",
            win_pct: 100.0,
            max: 1.9,
            min: 1.3,
            avg: 1.5,
            geomean: 1.49,
        }];
        let md = speedup_markdown("Table 1", &rows);
        assert!(md.contains("csr5"));
        assert!(md.contains("100.0%"));
    }

    #[test]
    fn figure_csv_shape() {
        let f = FigureSeries {
            matrices: vec!["a".into(), "b".into()],
            nnz: vec![10, 20],
            frameworks: vec!["ehyb", "csr5"],
            gflops: vec![vec![100.0, 90.0], vec![80.0, 70.0]],
        };
        let csv = figure_csv(&f);
        let lines: Vec<_> = csv.lines().collect();
        assert_eq!(lines.len(), 3);
        assert!(lines[0].starts_with("matrix,nnz,ehyb,csr5"));
        assert!(lines[1].starts_with("a,10,100.000,80.000"));
    }

    #[test]
    fn service_markdown_shows_fusion_metrics() {
        use std::sync::atomic::Ordering;
        let m = ServiceMetrics::new();
        m.requests.fetch_add(12, Ordering::Relaxed);
        m.batches.fetch_add(3, Ordering::Relaxed);
        m.batch_width.record(4);
        m.batch_width.record(4);
        m.batch_width.record(4);
        m.bytes_moved.fetch_add(1024, Ordering::Relaxed);
        m.shed.fetch_add(2, Ordering::Relaxed);
        m.spmv_latency.record(0.002);
        let md = service_markdown("Service", &m);
        assert!(md.contains("| 12 | 3 | 4.00 | 4 | 1024 |"), "{md}");
        assert!(md.contains("| 2 | 0 | 0 | 0 | fixed |\n"), "shed/fault/limit columns: {md}");
        assert!(md.contains("batch widths: 4+:3"), "{md}");
        // Satellite (ISSUE 8): the latency profile has explicit p50 and
        // p99 columns; with one 2ms sample both quantiles are exact.
        assert!(md.contains("| p50 (ms) | p99 (ms) |"), "{md}");
        assert!(md.contains("| 2.000 | 2.000 | 2.000 |"), "{md}");
        // An adaptive service publishes its live limit instead.
        m.adaptive_max_batch.store(4, Ordering::Relaxed);
        assert!(service_markdown("S", &m).contains("| 2 | 0 | 0 | 0 | 4 |\n"));
        // Resilience counters land in their own columns.
        m.faults.fetch_add(1, Ordering::Relaxed);
        m.respawns.fetch_add(1, Ordering::Relaxed);
        m.deadline_misses.fetch_add(5, Ordering::Relaxed);
        assert!(service_markdown("S", &m).contains("| 2 | 1 | 1 | 5 | 4 |\n"));
    }

    #[test]
    fn telemetry_markdown_renders_metrics_spans_and_health() {
        use crate::telemetry::Telemetry;
        let tel = Telemetry::with_fake_clock();
        tel.registry().incr("requests.total");
        tel.registry().set_gauge("shard.scratch_misses", 3.0);
        tel.histogram("queue.wait_secs").record(0.004);
        let outer = tel.span("build");
        drop(tel.span("reorder"));
        drop(outer);
        let mut snap = tel.snapshot();
        snap.health_events.push(crate::telemetry::TraceHealthEvent {
            trace: 7,
            detail: "solver restart: cg breakdown".into(),
        });
        snap.health_events
            .push(crate::telemetry::TraceHealthEvent { trace: 0, detail: "untraced".into() });
        let md = telemetry_markdown("Telemetry", &snap);
        assert!(md.contains("| requests.total | 1 |"), "{md}");
        assert!(md.contains("| shard.scratch_misses | 3.000000 |"), "{md}");
        assert!(md.contains("| queue.wait_secs | 1 | 4.000 | 4.000 | 4.000 | 4.000 |"), "{md}");
        assert!(md.contains("2 spans (0 dropped)"), "{md}");
        // The tree is fenced and indented: reorder nests under build.
        assert!(md.contains("```\nbuild"), "{md}");
        assert!(md.contains("\n  reorder"), "{md}");
        assert!(md.contains("- [trace 7] solver restart: cg breakdown"), "{md}");
        assert!(md.contains("- untraced\n"), "{md}");
    }

    #[test]
    fn health_markdown_shows_status_and_events() {
        use crate::resilience::Health;
        let h = Health::default();
        let md = health_markdown("Health", &h.report());
        assert!(md.contains("| healthy | 0 | 0 | 0 | 0 | 0 |"), "{md}");
        h.record_engine_fallback("ehyb plan failed; csr-vector serving");
        h.record_rejected_input("x[3] is NaN");
        let md = health_markdown("Health", &h.report());
        assert!(md.contains("| degraded | 1 | 0 | 0 | 1 | 0 |"), "{md}");
        assert!(md.contains("- engine fallback: ehyb plan failed"), "{md}");
        // Guarded-but-not-downgraded contexts are "recovering".
        let h2 = Health::default();
        h2.record_solver_restart("cg breakdown at iter 2");
        assert!(health_markdown("H", &h2.report()).contains("| recovering | 0 | 1 | 0 | 0 | 0 |"));
        // A model-drift event is observability, not degradation: the
        // context keeps serving its (re-searchable) plan.
        let h3 = Health::default();
        h3.record_model_drift("ehyb: x-gather off by 40% (bound 15%)");
        let md = health_markdown("H", &h3.report());
        assert!(md.contains("| recovering | 0 | 0 | 0 | 0 | 1 |"), "{md}");
        assert!(md.contains("- model drift: ehyb: x-gather"), "{md}");
    }

    #[test]
    fn profile_markdown_attributes_components() {
        let p = crate::profile::KernelProfile {
            engine: "ehyb".into(),
            calls: 2,
            lanes: 8,
            spmm_blocks: 4,
            ell_bytes: 4000,
            er_bytes: 800,
            meta_bytes: 200,
            x_fill_bytes: 1000,
            x_gather_bytes: 160,
            write_bytes: 640,
            halo_bytes: 0,
            x_lines: 12,
            pad_slots: 30,
            pad_bytes: 300,
            er_scatter_rows: 5,
            flops: 16_000,
            secs: 2e-3,
        };
        let md = profile_markdown("Profile", &p);
        assert!(md.contains("| ehyb | 2 | 8 | 2.00 | 6800 | 850.0 |"), "{md}");
        assert!(md.contains("| ell-stream | 4000 |"), "{md}");
        assert!(md.contains("| halo | 0 |"), "{md}");
        assert!(md.contains("x footprint: 12 lines; padding: 30 slots (300 bytes/lane)"), "{md}");
    }

    #[test]
    fn drift_markdown_renders_verdicts() {
        use crate::profile::{DriftReport, KernelProfile, DEFAULT_DRIFT_THRESHOLD};
        use crate::traffic::ehyb_traffic;
        let m = crate::sparse::gen::poisson2d::<f64>(16, 16);
        let e = crate::preprocess::EhybPlan::build(&m, &Default::default()).unwrap().matrix;
        let r = ehyb_traffic(&e, &crate::gpu::device::GpuDevice::v100());
        let c = &r.components;
        let agree = KernelProfile {
            engine: "ehyb".into(),
            calls: 1,
            lanes: 1,
            spmm_blocks: 1,
            ell_bytes: c.ell,
            er_bytes: c.er,
            meta_bytes: c.meta,
            x_fill_bytes: c.x_fill,
            x_gather_bytes: c.x_gather,
            write_bytes: c.write,
            secs: 1e-4,
            ..KernelProfile::default()
        };
        let d = DriftReport::new(&agree, &r, None, DEFAULT_DRIFT_THRESHOLD);
        let md = drift_markdown("Drift", &d);
        assert!(md.contains("| ell-stream |"), "{md}");
        assert!(md.contains("| total |"), "{md}");
        assert!(md.contains("within bounds (0.0% <= 15%)"), "{md}");
        assert!(md.contains("uncalibrated"), "{md}");
        // Inflate one component past the bound: the verdict names it.
        let mut off = agree;
        off.x_gather_bytes = off.x_gather_bytes * 3 + 64;
        let d = DriftReport::new(&off, &r, None, DEFAULT_DRIFT_THRESHOLD);
        let md = drift_markdown("Drift", &d);
        assert!(d.exceeded());
        assert!(md.contains("DRIFTED: x-gather off by"), "{md}");
    }

    #[test]
    fn solve_markdown_spells_out_status() {
        use crate::harness::tables::SolveRow;
        let rows = vec![
            SolveRow {
                label: "poisson2d-64 + ehyb".into(),
                solver: "cg",
                status: "converged",
                iters: 41,
                rel_residual: 3.2e-9,
                spmv_count: 42,
            },
            SolveRow {
                label: "zero-diag".into(),
                solver: "bicgstab",
                status: "breakdown",
                iters: 1,
                rel_residual: 1.0,
                spmv_count: 2,
            },
        ];
        let md = solve_markdown("Solves", &rows);
        assert!(md.contains("| poisson2d-64 + ehyb | cg | converged | 41 | 3.200e-9 | 42 |"), "{md}");
        assert!(md.contains("| zero-diag | bicgstab | breakdown | 1 |"), "{md}");
    }

    #[test]
    fn shard_markdown_has_one_row_per_shard() {
        use crate::shard::{ShardPlan, ShardStrategy, ShardedEngine};
        let m = crate::sparse::gen::poisson2d::<f64>(12, 12);
        let plan = ShardPlan::new(&m, 3, ShardStrategy::CacheAware);
        let cfg = crate::preprocess::PreprocessConfig {
            vec_size_override: Some(32),
            ..Default::default()
        };
        let e = ShardedEngine::build(&m, crate::api::EngineKind::CsrScalar, &cfg, &plan, None)
            .unwrap();
        let x = vec![1.0; m.ncols()];
        let mut y = vec![0.0; m.nrows()];
        e.spmv(&x, &mut y);
        let md = shard_markdown("Shards", &e);
        assert_eq!(md.lines().filter(|l| l.starts_with("| ")).count(), 1 + 3, "{md}");
        assert!(md.contains("| 0 | 0.."), "{md}");
        // Every shard executed exactly one spmv call (lines 0..4 are
        // title, blank, header, separator).
        for line in md.lines().skip(4) {
            assert!(line.contains("| 1 | 0 | 0 |"), "{md}");
        }
    }

    #[test]
    fn reorder_markdown_has_one_row_per_spec() {
        let rows = vec![
            ReorderRow {
                spec: "none".into(),
                bandwidth: 900,
                profile: 120_000,
                footprint: 812.5,
                x_dram_bytes: 65_536,
                cut_nnz: 4200,
                gflops: 55.0,
                er_fraction: 0.04,
            },
            ReorderRow {
                spec: "rcm".into(),
                bandwidth: 41,
                profile: 9_100,
                footprint: 310.0,
                x_dram_bytes: 32_768,
                cut_nnz: 240,
                gflops: 61.2,
                er_fraction: 0.03,
            },
        ];
        let md = reorder_markdown("Reorder", &rows);
        assert!(
            md.contains("| none | 900 | 120000 | 812.5 | 65536 | 4200 | 55.00 | 0.0400 |"),
            "{md}"
        );
        assert!(md.contains("| rcm | 41 |"), "{md}");
    }

    #[test]
    fn traffic_markdown_rows_and_units() {
        let rows = vec![TrafficRow {
            engine: "ehyb".into(),
            dram_bytes: 150_000,
            l2_bytes: 220_000,
            shm_bytes: 96_000,
            l2_hit_rate: 0.8125,
            x_reuse: 3.5,
            predicted_secs: 12.5e-6,
            measured_gflops: 9.75,
        }];
        let md = traffic_markdown("Traffic", &rows);
        assert!(
            md.contains("| ehyb | 150000 | 220000 | 96000 | 0.813 | 3.50 | 12.50 | 9.75 |"),
            "{md}"
        );
        assert!(md.contains("predicted us"), "{md}");
    }

    #[test]
    fn traffic_validation_markdown_counts_agreement() {
        let rows = vec![
            ValidationRow {
                matrix: "fem-a".into(),
                simulated_pick: "ehyb".into(),
                measured_pick: "ehyb".into(),
                sim_pick_gflops: 10.0,
                measured_pick_gflops: 10.0,
                agree: true,
            },
            ValidationRow {
                matrix: "fem-b".into(),
                simulated_pick: "sellp".into(),
                measured_pick: "csr-vector".into(),
                sim_pick_gflops: 6.0,
                measured_pick_gflops: 9.0,
                agree: false,
            },
        ];
        let md = traffic_validation_markdown("Validation", &rows);
        assert!(md.contains("| fem-a | ehyb | ehyb | 10.00 | 10.00 | yes |"), "{md}");
        assert!(md.contains("| fem-b | sellp | csr-vector | 6.00 | 9.00 | no |"), "{md}");
        assert!(md.contains("agreement: 1/2 cases"), "{md}");
    }

    #[test]
    fn drift_ablation_markdown_rows() {
        let rows = vec![DriftAblationRow {
            variant: "calibrated".into(),
            pick: "ehyb".into(),
            score_secs: 12.5e-6,
            measured_gflops: 9.5,
            fit_residual: 0.125,
            samples: 4,
        }];
        let md = drift_ablation_markdown("Drift ablation", &rows);
        assert!(md.contains("| calibrated | ehyb | 12.50 | 9.50 | 0.125 | 4 |"), "{md}");
    }

    #[test]
    fn bench_json_round_trips() {
        let cases = vec![BenchCase {
            matrix: "poisson2d-16".into(),
            n: 256,
            nnz: 1216,
            engines: vec![("ehyb".into(), 12.5), ("csr-scalar".into(), 8.25)],
        }];
        let j = bench_json("ci-smoke", &cases);
        let text = j.dump();
        let back = Json::parse(&text).unwrap();
        assert_eq!(back, j);
        assert_eq!(back.get("schema").and_then(Json::as_str), Some("ehyb-bench-v1"));
        let case = &back.get("cases").and_then(Json::as_arr).unwrap()[0];
        assert_eq!(case.get("nnz").and_then(Json::as_usize), Some(1216));
        assert_eq!(
            case.get("gflops").and_then(|g| g.get("ehyb")).and_then(Json::as_f64),
            Some(12.5)
        );
    }

    #[test]
    fn fig6_markdown_rows() {
        let rows = vec![Fig6Row {
            matrix: "m".into(),
            partition_x: 700.0,
            reorder_x: 100.0,
            total_x: 800.0,
        }];
        let md = fig6_markdown(&rows);
        assert!(md.contains("| m | 700 | 100 | 800 |"));
    }
}
