//! The benchmark corpus: synthetic stand-ins for the paper's 94
//! SuiteSparse FEM matrices (Table 3) and its 16 "commonly tested"
//! matrices (Figures 3/5/6).
//!
//! Each spec reproduces its category's structural signature (nnz/row
//! distribution, locality, degree skew). Linear dimensions scale with
//! [`Scale`] so tests run in milliseconds, the default bench in
//! minutes, and `Scale::Full` approaches paper-size matrices.

use crate::sparse::csr::Csr;
use crate::sparse::gen;

/// Linear-dimension multiplier for the whole corpus.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Scale {
    /// Unit tests: n ≈ 1–5 k.
    Tiny,
    /// Default bench sweeps: n ≈ 10–100 k.
    Small,
    /// Paper-approaching: n ≈ 100 k – 1 M+ (slow).
    Full,
}

impl Scale {
    pub fn from_env() -> Scale {
        match std::env::var("EHYB_SUITE_SCALE").as_deref() {
            Ok("tiny") => Scale::Tiny,
            Ok("full") => Scale::Full,
            _ => Scale::Small,
        }
    }

    fn dim(&self, tiny: usize, small: usize, full: usize) -> usize {
        match self {
            Scale::Tiny => tiny,
            Scale::Small => small,
            Scale::Full => full,
        }
    }
}

/// Generator recipe (all parameters scale-resolved at build time).
#[derive(Clone, Debug)]
pub enum Recipe {
    Poisson3d { d: (usize, usize, usize) },
    Stencil27 { d: (usize, usize, usize), seed: u64 },
    Elasticity { d: (usize, usize, usize), ndof: usize, seed: u64 },
    Unstructured { d: (usize, usize), extra: f64, seed: u64 },
    Circuit { n: usize, deg: usize, hubs: f64, seed: u64 },
    Kkt { nh: usize, seed: u64 },
    Banded { n: usize, bw: usize, fill: f64, seed: u64 },
}

/// One corpus entry.
#[derive(Clone, Debug)]
pub struct MatrixSpec {
    pub name: String,
    pub category: &'static str,
    pub recipe: Recipe,
}

impl MatrixSpec {
    pub fn build(&self) -> Csr<f64> {
        match &self.recipe {
            Recipe::Poisson3d { d } => gen::poisson3d(d.0, d.1, d.2),
            Recipe::Stencil27 { d, seed } => gen::stencil27(d.0, d.1, d.2, *seed),
            Recipe::Elasticity { d, ndof, seed } => gen::elasticity3d(d.0, d.1, d.2, *ndof, *seed),
            Recipe::Unstructured { d, extra, seed } => {
                gen::unstructured_mesh(d.0, d.1, *extra, *seed)
            }
            Recipe::Circuit { n, deg, hubs, seed } => gen::circuit(*n, *deg, *hubs, *seed),
            Recipe::Kkt { nh, seed } => gen::kkt(*nh, *seed),
            Recipe::Banded { n, bw, fill, seed } => gen::banded(*n, *bw, *fill, *seed),
        }
    }
}

/// The 16 "commonly tested" analogues (Fig. 3/5/6). Names reference the
/// paper's matrices; shapes reproduce their category + relative size.
pub fn suite16(s: Scale) -> Vec<MatrixSpec> {
    let d3 = |t, sm, f| {
        let d = s.dim(t, sm, f);
        (d, d, d)
    };
    let d2 = |t, sm, f| {
        let d = s.dim(t, sm, f);
        (d, d)
    };
    let mk = |name: &str, category, recipe| MatrixSpec { name: name.to_string(), category, recipe };
    vec![
        mk("poisson3D-like", "CFD", Recipe::Poisson3d { d: d3(10, 44, 95) }),
        mk("cant-like", "3D problem", Recipe::Stencil27 { d: d3(8, 29, 63), seed: 101 }),
        mk("consph-like", "3D problem", Recipe::Stencil27 { d: d3(9, 32, 69), seed: 102 }),
        mk("pwtk-like", "Structural", Recipe::Elasticity { d: d3(6, 20, 42), ndof: 3, seed: 103 }),
        mk("shipsec5-like", "Structural", Recipe::Elasticity {
            d: d3(6, 19, 39),
            ndof: 3,
            seed: 104,
        }),
        mk("bmwcra_1-like", "Structural", Recipe::Elasticity {
            d: d3(6, 18, 37),
            ndof: 3,
            seed: 105,
        }),
        mk("crankseg_2-like", "Structural", Recipe::Elasticity {
            d: d3(5, 14, 28),
            ndof: 3,
            seed: 106,
        }),
        mk("ldoor-like", "Structural", Recipe::Elasticity { d: d3(7, 22, 68), ndof: 3, seed: 107 }),
        mk("audikw_1-like", "Structural", Recipe::Elasticity {
            d: d3(7, 21, 68),
            ndof: 3,
            seed: 108,
        }),
        mk("boneS10-like", "Bio Engineering", Recipe::Elasticity {
            d: d3(7, 21, 67),
            ndof: 3,
            seed: 109,
        }),
        mk("atmosmodj-like", "CFD", Recipe::Poisson3d { d: d3(11, 48, 108) }),
        mk("G3_circuit-like", "Circuit Simulation", Recipe::Circuit {
            n: s.dim(2_000, 60_000, 1_500_000),
            deg: 3,
            hubs: 0.001,
            seed: 110,
        }),
        mk("memchip-like", "Circuit Simulation", Recipe::Circuit {
            n: s.dim(2_500, 80_000, 2_500_000),
            deg: 4,
            hubs: 0.002,
            seed: 111,
        }),
        mk("nlpkkt80-like", "Optimization", Recipe::Kkt { nh: s.dim(7, 26, 56), seed: 112 }),
        mk("F1-like", "Structural", Recipe::Unstructured {
            d: d2(40, 190, 585),
            extra: 0.8,
            seed: 113,
        }),
        mk("offshore-like", "Electromagnetics", Recipe::Unstructured {
            d: d2(35, 165, 510),
            extra: 0.5,
            seed: 114,
        }),
    ]
}

/// The 94-matrix corpus: every category of the paper's Table 3, several
/// size decades per category, deterministic seeds.
pub fn suite94(s: Scale) -> Vec<MatrixSpec> {
    let mut specs = Vec::with_capacity(94);
    let mut n = 0usize;
    let mut push = |name: String, category: &'static str, recipe: Recipe| {
        specs.push(MatrixSpec { name, category, recipe });
        n += 1;
        let _ = n;
    };

    // Structural / elasticity (the largest category in the paper): 24.
    for i in 0..24 {
        let base = 5 + i % 8; // vary size
        let d = s.dim(base, base * 4 + i % 5, base * 8);
        push(
            format!("struct_{i:02}"),
            "Structural",
            Recipe::Elasticity { d: (d, d, d), ndof: 3, seed: 200 + i as u64 },
        );
    }
    // CFD 7-pt stencils: 16.
    for i in 0..16 {
        let base = 8 + (i % 6) * 2;
        let d = s.dim(base, base * 6, base * 10 + i);
        push(format!("cfd_{i:02}"), "CFD", Recipe::Poisson3d { d: (d, d + i % 3, d) });
    }
    // 3D problems, 27-pt: 12.
    for i in 0..12 {
        let base = 6 + i % 5;
        let d = s.dim(base, base * 6, base * 9);
        push(
            format!("fem3d_{i:02}"),
            "3D Problem",
            Recipe::Stencil27 { d: (d, d, d), seed: 300 + i as u64 },
        );
    }
    // Electromagnetics / unstructured: 12.
    for i in 0..12 {
        let base = 24 + (i % 6) * 6;
        let d = s.dim(base, base * 8, base * 14);
        push(
            format!("em_{i:02}"),
            "Electromagnetics",
            Recipe::Unstructured {
                d: (d, d),
                extra: 0.4 + 0.1 * (i % 3) as f64,
                seed: 400 + i as u64,
            },
        );
    }
    // Biomedical (elasticity-like with higher variance): 8.
    for i in 0..8 {
        let base = 5 + i % 4;
        let d = s.dim(base, base * 4, base * 9);
        push(
            format!("bio_{i:02}"),
            "Bio Engineering",
            Recipe::Elasticity { d: (d, d, d), ndof: 3, seed: 500 + i as u64 },
        );
    }
    // Circuit / power: 10.
    for i in 0..10 {
        let nn =
            s.dim(1_500 + 500 * (i % 4), 150_000 + 50_000 * (i % 4), 1_000_000 + 400_000 * (i % 4));
        push(
            format!("circuit_{i:02}"),
            "Circuit Simulation",
            Recipe::Circuit {
                n: nn,
                deg: 3 + i % 3,
                hubs: 0.001 * (1 + i % 4) as f64,
                seed: 600 + i as u64,
            },
        );
    }
    // Optimization (KKT): 6.
    for i in 0..6 {
        let nh = s.dim(6 + i % 3, 30 + 4 * (i % 3), 50 + 6 * (i % 3));
        push(format!("opt_{i:02}"), "Optimization", Recipe::Kkt { nh, seed: 700 + i as u64 });
    }
    // Model reduction / semiconductor (banded): 6.
    for i in 0..6 {
        let nn = s.dim(2_000, 200_000 + 40_000 * (i % 3), 900_000);
        push(
            format!("semi_{i:02}"),
            "Semiconductor",
            Recipe::Banded { n: nn, bw: 12 + 4 * (i % 3), fill: 0.35, seed: 800 + i as u64 },
        );
    }
    assert_eq!(specs.len(), 94, "corpus must have exactly 94 matrices");
    specs
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::stats::MatrixStats;

    #[test]
    fn corpus_has_94() {
        assert_eq!(suite94(Scale::Tiny).len(), 94);
    }

    #[test]
    fn suite16_has_16_unique_names() {
        let s = suite16(Scale::Tiny);
        assert_eq!(s.len(), 16);
        let mut names: Vec<_> = s.iter().map(|m| m.name.clone()).collect();
        names.sort();
        names.dedup();
        assert_eq!(names.len(), 16);
    }

    #[test]
    fn tiny_specs_build_and_are_square() {
        for spec in suite16(Scale::Tiny) {
            let m = spec.build();
            assert_eq!(m.nrows(), m.ncols(), "{}", spec.name);
            assert!(m.nnz() > 0, "{}", spec.name);
        }
    }

    #[test]
    fn categories_have_distinct_signatures() {
        // Structural (ndof=3, 27-pt) must have much higher nnz/row than
        // circuit matrices — the paper's corpus diversity, reproduced.
        let s16 = suite16(Scale::Tiny);
        let stat = |name: &str| {
            let spec = s16.iter().find(|m| m.name == name).unwrap();
            MatrixStats::of(&spec.build())
        };
        let structural = stat("pwtk-like");
        let circuit = stat("G3_circuit-like");
        assert!(structural.row_nnz.mean > 3.0 * circuit.row_nnz.mean);
    }

    #[test]
    fn scales_are_ordered() {
        let spec_t = &suite16(Scale::Tiny)[0];
        let spec_s = &suite16(Scale::Small)[0];
        assert!(spec_s.build().nrows() > spec_t.build().nrows());
    }

    #[test]
    fn deterministic_rebuild() {
        let a = suite16(Scale::Tiny)[1].build();
        let b = suite16(Scale::Tiny)[1].build();
        assert_eq!(a.col_idx, b.col_idx);
    }
}
