//! Aggregation into the paper's tables and figure series.

use super::runner::{frameworks, MatrixRun};
use crate::sparse::scalar::Scalar;
use crate::util::stats::{win_rate, Summary};

/// One row of Table 1 / Table 2.
#[derive(Clone, Debug)]
pub struct SpeedupRow {
    pub framework: &'static str,
    pub win_pct: f64,
    pub max: f64,
    pub min: f64,
    pub avg: f64,
    pub geomean: f64,
}

/// Tables 1 (f32) / 2 (f64): EHYB speedup statistics vs each framework.
pub fn speedup_table<S: Scalar>(runs: &[MatrixRun]) -> Vec<SpeedupRow> {
    frameworks::<S>()
        .into_iter()
        .map(|f| {
            let speedups: Vec<f64> = runs.iter().filter_map(|r| r.speedup_vs(f)).collect();
            let s = Summary::of(&speedups).unwrap_or(Summary {
                n: 0,
                min: 0.0,
                max: 0.0,
                mean: 0.0,
                geomean: 0.0,
                median: 0.0,
                stddev: 0.0,
            });
            SpeedupRow {
                framework: f,
                win_pct: 100.0 * win_rate(&speedups),
                max: s.max,
                min: s.min,
                avg: s.mean,
                geomean: s.geomean,
            }
        })
        .collect()
}

/// Figure 2/3 (f32) and 4/5 (f64) series: GFLOPS per (matrix, framework),
/// matrices ordered by nnz as in the paper's plots.
#[derive(Clone, Debug)]
pub struct FigureSeries {
    pub matrices: Vec<String>,
    pub nnz: Vec<usize>,
    pub frameworks: Vec<&'static str>,
    /// `gflops[f][m]` for framework f, matrix m.
    pub gflops: Vec<Vec<f64>>,
}

pub fn figure_series<S: Scalar>(runs: &[MatrixRun]) -> FigureSeries {
    let mut order: Vec<usize> = (0..runs.len()).collect();
    order.sort_by_key(|&i| runs[i].nnz);
    let mut fw = vec!["ehyb"];
    fw.extend(frameworks::<S>());
    let gflops = fw
        .iter()
        .map(|f| order.iter().map(|&i| runs[i].gflops_of(f).unwrap_or(0.0)).collect())
        .collect();
    FigureSeries {
        matrices: order.iter().map(|&i| runs[i].name.clone()).collect(),
        nnz: order.iter().map(|&i| runs[i].nnz).collect(),
        frameworks: fw,
        gflops,
    }
}

/// One row of the solve-outcome table: a labelled
/// [`crate::coordinator::SolveReport`] with its typed status spelled
/// out (rendered by `harness::report::solve_markdown`).
#[derive(Clone, Debug)]
pub struct SolveRow {
    /// Case label, e.g. "poisson2d-64 + ehyb".
    pub label: String,
    pub solver: &'static str,
    /// [`crate::coordinator::SolveStatus::name`] of the outcome.
    pub status: &'static str,
    pub iters: usize,
    pub rel_residual: f64,
    pub spmv_count: usize,
}

/// Flatten labelled reports into table rows.
pub fn solve_rows(items: &[(&str, &crate::coordinator::SolveReport)]) -> Vec<SolveRow> {
    items
        .iter()
        .map(|(label, rep)| SolveRow {
            label: (*label).to_string(),
            solver: rep.solver,
            status: rep.status.name(),
            iters: rep.iters,
            rel_residual: rep.final_rel_residual,
            spmv_count: rep.spmv_count,
        })
        .collect()
}

/// Figure 6 data point: preprocessing phases in units of one SpMV.
#[derive(Clone, Debug)]
pub struct Fig6Row {
    pub matrix: String,
    pub partition_x: f64,
    pub reorder_x: f64,
    pub total_x: f64,
}

pub fn fig6_rows(runs: &[MatrixRun]) -> Vec<Fig6Row> {
    runs.iter()
        .map(|r| {
            let u = r.prep.in_spmv_units(r.ehyb_spmv_secs);
            Fig6Row {
                matrix: r.name.clone(),
                partition_x: u.partition,
                reorder_x: u.reorder,
                total_x: u.total,
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::gpu::GpuDevice;
    use crate::harness::runner::run_matrix;
    use crate::preprocess::PreprocessConfig;
    use crate::sparse::gen::{poisson3d, stencil27};

    fn runs_f64() -> Vec<MatrixRun> {
        let cfg = PreprocessConfig { vec_size_override: Some(128), ..Default::default() };
        let dev = GpuDevice::v100();
        vec![
            run_matrix("a", "CFD", &poisson3d::<f64>(8, 8, 8), &cfg, &dev).unwrap(),
            run_matrix("b", "3D", &stencil27::<f64>(7, 7, 7, 1), &cfg, &dev).unwrap(),
        ]
    }

    #[test]
    fn solve_rows_carry_status_names() {
        use crate::coordinator::{cg, Jacobi, SolverConfig};
        let a = crate::sparse::gen::poisson2d::<f64>(12, 12);
        let n = a.nrows();
        let b: Vec<f64> = (0..n).map(|i| ((i * 7 + 3) % 11) as f64 - 5.0).collect();
        let pre = Jacobi::new(&a);
        let (_, good) = cg(|v, y| a.spmv(v, y), &b, &vec![0.0; n], &pre, &SolverConfig::default());
        let cfg = SolverConfig { max_iters: 1, ..Default::default() };
        let (_, capped) = cg(|v, y| a.spmv(v, y), &b, &vec![0.0; n], &pre, &cfg);
        let rows = solve_rows(&[("poisson + jacobi", &good), ("capped", &capped)]);
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].status, "converged");
        assert_eq!(rows[0].solver, "cg");
        assert_eq!(rows[1].status, "max-iters");
        assert_eq!(rows[1].iters, 1);
        assert!(rows[0].rel_residual < 1e-8);
    }

    #[test]
    fn speedup_table_has_all_frameworks() {
        let t = speedup_table::<f64>(&runs_f64());
        assert_eq!(t.len(), 5);
        for row in &t {
            assert!(row.max >= row.min);
            assert!(row.avg > 0.0);
            assert!((0.0..=100.0).contains(&row.win_pct));
        }
    }

    #[test]
    fn figure_series_sorted_by_nnz() {
        let f = figure_series::<f64>(&runs_f64());
        assert!(f.nnz.windows(2).all(|w| w[0] <= w[1]));
        assert_eq!(f.frameworks[0], "ehyb");
        assert_eq!(f.gflops.len(), f.frameworks.len());
        assert_eq!(f.gflops[0].len(), f.matrices.len());
    }

    #[test]
    fn fig6_rows_consistent() {
        let rows = fig6_rows(&runs_f64());
        for r in rows {
            assert!((r.partition_x + r.reorder_x - r.total_x).abs() < 1e-9);
            assert!(r.total_x > 0.0);
        }
    }
}
