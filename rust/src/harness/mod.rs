//! Benchmark harness: regenerates every table and figure in the paper's
//! evaluation section (DESIGN.md §6 maps each to its experiment id).
//!
//! * [`suite`] — the synthetic 94-matrix corpus + the 16 "commonly
//!   tested" analogues (SuiteSparse substitutes, DESIGN.md §4).
//! * [`runner`] — runs every framework's simulated kernel (plus EHYB
//!   preprocessing) over a matrix and collects [`SimReport`]s.
//! * [`tables`] — Table 1/2 speedup statistics, Figure 2–5 series,
//!   Figure 6 preprocessing decomposition.
//! * [`ablation`] — DESIGN.md §7: explicit-cache on/off, u16/u32
//!   columns, partitioner quality, descending-sort on/off, VecSize (K)
//!   sweep, the autotuning ablation (default vs heuristic vs measured
//!   plan — ISSUE 3), and the simulated-traffic ablation (per-engine
//!   per-level bytes next to measured throughput — ISSUE 7).
//! * [`report`] — markdown / CSV emission.
//!
//! The [`runner::traffic_validation`] mode (ISSUE 7) checks the
//! [`crate::traffic`] oracle's engine ranking against the
//! measured-probe winner per matrix.

pub mod suite;
pub mod runner;
pub mod tables;
pub mod ablation;
pub mod report;

pub use runner::{run_matrix, traffic_validation, FrameworkRow, MatrixRun, ValidationRow};
pub use suite::{suite16, suite94, MatrixSpec, Scale};
