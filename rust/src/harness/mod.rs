//! Benchmark harness: regenerates every table and figure in the paper's
//! evaluation section (DESIGN.md §6 maps each to its experiment id).
//!
//! * [`suite`] — the synthetic 94-matrix corpus + the 16 "commonly
//!   tested" analogues (SuiteSparse substitutes, DESIGN.md §4).
//! * [`runner`] — runs every framework's simulated kernel (plus EHYB
//!   preprocessing) over a matrix and collects [`SimReport`]s.
//! * [`tables`] — Table 1/2 speedup statistics, Figure 2–5 series,
//!   Figure 6 preprocessing decomposition.
//! * [`ablation`] — DESIGN.md §7: explicit-cache on/off, u16/u32
//!   columns, partitioner quality, descending-sort on/off, VecSize (K)
//!   sweep, plus the autotuning ablation (default vs heuristic vs
//!   measured plan — ISSUE 3).
//! * [`report`] — markdown / CSV emission.

pub mod suite;
pub mod runner;
pub mod tables;
pub mod ablation;
pub mod report;

pub use runner::{run_matrix, FrameworkRow, MatrixRun};
pub use suite::{suite16, suite94, MatrixSpec, Scale};
