//! Ablations (DESIGN.md §7): isolate each design choice the paper
//! motivates and measure its contribution on the simulator — plus the
//! ISSUE 3 tuning ablation (default vs. heuristic vs. measured plan).

use super::runner::ehyb_context;
use crate::api::{EngineKind, SpmvContext};
use crate::autotune::TuneLevel;
use crate::gpu::{kernels, simulate, GpuDevice};
use crate::partition::{PartitionConfig, PartitionMethod};
use crate::preprocess::PreprocessConfig;
use crate::reorder::{ReorderSpec, Reordering};
use crate::shard::{ShardPlan, ShardStrategy};
use crate::sparse::csr::Csr;
use crate::sparse::scalar::Scalar;

#[derive(Clone, Debug)]
pub struct AblationRow {
    pub variant: String,
    pub gflops: f64,
    pub er_fraction: f64,
    pub ell_fill: f64,
}

/// §7.1 + §7.2: explicit cache on/off × u16/u32 columns.
pub fn cache_and_cols<S: Scalar>(
    m: &Csr<S>,
    cfg: &PreprocessConfig,
    dev: &GpuDevice,
) -> crate::Result<Vec<AblationRow>> {
    let ctx = ehyb_context(m, cfg)?;
    let e = &ctx.plan().expect("EHYB context carries a plan").matrix;
    let mut rows = Vec::new();
    for (cache, u16c) in [(true, true), (true, false), (false, true), (false, false)] {
        let r = simulate(&kernels::ehyb(e, dev, cache, u16c), dev);
        rows.push(AblationRow {
            variant: format!(
                "cache={} cols={}",
                if cache { "shm" } else { "l2" },
                if u16c { "u16" } else { "u32" }
            ),
            gflops: r.gflops,
            er_fraction: e.er_fraction(),
            ell_fill: e.ell_fill_ratio(),
        });
    }
    Ok(rows)
}

/// §7.3: partitioner quality (multilevel vs bfs vs index vs random).
pub fn partitioner_quality<S: Scalar>(
    m: &Csr<S>,
    base: &PreprocessConfig,
    dev: &GpuDevice,
) -> crate::Result<Vec<AblationRow>> {
    let mut rows = Vec::new();
    for method in [
        PartitionMethod::Multilevel,
        PartitionMethod::BfsBand,
        PartitionMethod::IndexBlock,
        PartitionMethod::Random,
    ] {
        let cfg = PreprocessConfig {
            partition: PartitionConfig { method, ..base.partition.clone() },
            ..base.clone()
        };
        let ctx = ehyb_context(m, &cfg)?;
        let plan = ctx.plan().expect("EHYB context carries a plan");
        let r = simulate(&kernels::ehyb(&plan.matrix, dev, true, true), dev);
        rows.push(AblationRow {
            variant: format!("{method:?}"),
            gflops: r.gflops,
            er_fraction: plan.matrix.er_fraction(),
            ell_fill: plan.matrix.ell_fill_ratio(),
        });
    }
    Ok(rows)
}

/// §7.4: descending-nnz reorder on/off.
pub fn sort_ablation<S: Scalar>(
    m: &Csr<S>,
    base: &PreprocessConfig,
    dev: &GpuDevice,
) -> crate::Result<Vec<AblationRow>> {
    let mut rows = Vec::new();
    for sort in [true, false] {
        let cfg = PreprocessConfig { sort_descending: sort, ..base.clone() };
        let ctx = ehyb_context(m, &cfg)?;
        let plan = ctx.plan().expect("EHYB context carries a plan");
        let r = simulate(&kernels::ehyb(&plan.matrix, dev, true, true), dev);
        rows.push(AblationRow {
            variant: format!("sort_desc={sort}"),
            gflops: r.gflops,
            er_fraction: plan.matrix.er_fraction(),
            ell_fill: plan.matrix.ell_fill_ratio(),
        });
    }
    Ok(rows)
}

/// §7.5: VecSize (cache size / K) sweep — paper equations (1)-(2) trade
/// partition count against ER size.
pub fn vecsize_sweep<S: Scalar>(
    m: &Csr<S>,
    base: &PreprocessConfig,
    dev: &GpuDevice,
    sizes: &[usize],
) -> crate::Result<Vec<AblationRow>> {
    let mut rows = Vec::new();
    for &v in sizes {
        if v >= m.nrows() {
            continue;
        }
        let cfg = PreprocessConfig { vec_size_override: Some(v), ..base.clone() };
        let ctx = ehyb_context(m, &cfg)?;
        let plan = ctx.plan().expect("EHYB context carries a plan");
        let r = simulate(&kernels::ehyb(&plan.matrix, dev, true, true), dev);
        rows.push(AblationRow {
            variant: format!("vec_size={v}"),
            gflops: r.gflops,
            er_fraction: plan.matrix.er_fraction(),
            ell_fill: plan.matrix.ell_fill_ratio(),
        });
    }
    Ok(rows)
}

/// ISSUE 3: the tuning ablation — the EHYB plan as configured
/// (default), autotuned by the roofline model (heuristic), and
/// autotuned by measured probes — each simulated on the same device.
/// The variant label records the knobs the tuner landed on, so the
/// report shows *what* changed, not just by how much.
pub fn tuning_ablation<S: Scalar>(
    m: &Csr<S>,
    base: &PreprocessConfig,
    dev: &GpuDevice,
) -> crate::Result<Vec<AblationRow>> {
    let variants: [(&str, Option<TuneLevel>); 3] = [
        ("default", None),
        ("tuned-heuristic", Some(TuneLevel::Heuristic)),
        ("tuned-measured", Some(TuneLevel::measured())),
    ];
    let mut rows = Vec::new();
    for (name, level) in variants {
        // Fresh search per variant: an ablation must not read cached
        // plans (a measured entry would silently serve the heuristic
        // row) nor write into the user's EHYB_TUNE_DIR cache.
        let mut b = SpmvContext::builder(m.clone())
            .engine(EngineKind::Ehyb)
            .config(base.clone())
            .no_plan_cache();
        if let Some(level) = level {
            b = b.tune(level);
        }
        let ctx = b.build()?;
        let plan = ctx.plan().expect("EHYB context carries a plan");
        let r = simulate(&kernels::ehyb(&plan.matrix, dev, true, true), dev);
        rows.push(AblationRow {
            variant: format!(
                "{name} (vec_size={}, h={}, cutoff={:?})",
                plan.matrix.vec_size,
                plan.matrix.slice_height,
                ctx.config().ell_width_cutoff
            ),
            gflops: r.gflops,
            er_fraction: plan.matrix.er_fraction(),
            ell_fill: plan.matrix.ell_fill_ratio(),
        });
    }
    Ok(rows)
}

/// One [`ReorderSpec`]'s outcome in the reorder ablation: the locality
/// metrics of the chosen ordering, the cache-aware cross-shard cut it
/// leaves behind, and the simulated EHYB throughput on the reordered
/// structure.
#[derive(Clone, Debug)]
pub struct ReorderRow {
    /// Resolved ordering tag (`Auto` rows read "auto->rcm" etc.).
    pub spec: String,
    pub bandwidth: usize,
    pub profile: u64,
    pub footprint: f64,
    /// Simulated x DRAM bytes of a CSR walk under the ordering — the
    /// [`crate::traffic`] score `Auto` ranks by since 0.7.
    pub x_dram_bytes: u64,
    /// `ShardStrategy::CacheAware` cross-shard entries at the sweep's
    /// shard count, measured on the reordered matrix.
    pub cut_nnz: usize,
    pub gflops: f64,
    pub er_fraction: f64,
}

/// ISSUE 5: the reorder ablation — every [`ReorderSpec`] on one matrix:
/// bandwidth / profile / windowed footprint of the ordering, the
/// CacheAware `cut_nnz` at `shards_k` shards, and the simulated EHYB
/// GFLOPS of the pipeline run on the reordered structure.
pub fn reorder_ablation<S: Scalar>(
    m: &Csr<S>,
    base: &PreprocessConfig,
    dev: &GpuDevice,
    shards_k: usize,
) -> crate::Result<Vec<ReorderRow>> {
    let specs = [
        ReorderSpec::None,
        ReorderSpec::DegreeSort,
        ReorderSpec::Rcm,
        ReorderSpec::PartitionRank { k: 0 },
        ReorderSpec::Auto,
    ];
    let mut rows = Vec::new();
    for spec in specs {
        let r = Reordering::compute(m, spec)?;
        let pm;
        let exec: &Csr<S> = if r.is_identity() {
            m
        } else {
            pm = r.apply(m);
            &pm
        };
        let cut = ShardPlan::new(exec, shards_k, ShardStrategy::CacheAware).cut_nnz(exec);
        let ctx = ehyb_context(exec, base)?;
        let plan = ctx.plan().expect("EHYB context carries a plan");
        let sim = simulate(&kernels::ehyb(&plan.matrix, dev, true, true), dev);
        let tag = if spec == ReorderSpec::Auto {
            format!("auto->{}", r.resolved)
        } else if r.is_identity() && spec != ReorderSpec::None {
            // Resolved tags normalize to "none" on identity outcomes;
            // keep the requested spec visible in the table.
            format!("{} (=none)", spec.tag())
        } else {
            r.resolved.clone()
        };
        rows.push(ReorderRow {
            spec: tag,
            bandwidth: r.after.bandwidth,
            profile: r.after.profile,
            footprint: r.after.window_footprint,
            x_dram_bytes: r.after.x_dram_bytes,
            cut_nnz: cut,
            gflops: sim.gflops,
            er_fraction: plan.matrix.er_fraction(),
        });
    }
    Ok(rows)
}

/// One engine's simulated storage traffic next to its measured CPU
/// throughput — the ISSUE 7 traffic ablation row.
#[derive(Clone, Debug)]
pub struct TrafficRow {
    pub engine: String,
    /// Simulated DRAM bytes (reads + writes) per SpMV.
    pub dram_bytes: u64,
    /// Simulated L2 bytes (reads + writes) per SpMV.
    pub l2_bytes: u64,
    /// Simulated shared-memory bytes served per SpMV (0 for engines
    /// with no explicit cache).
    pub shm_bytes: u64,
    /// Simulated L2 sector hit rate.
    pub l2_hit_rate: f64,
    /// Average times each touched x sector was requested (≥ 1).
    pub x_reuse: f64,
    /// Hit-aware predicted SpMV seconds from the replay.
    pub predicted_secs: f64,
    /// Wall-clock CPU GFLOPS of the real engine on this host — the
    /// measured column the predicted ranking is validated against.
    pub measured_gflops: f64,
}

/// ISSUE 7: the traffic ablation — replay every concrete engine's
/// storage traffic through the [`crate::traffic`] simulator and set the
/// per-level byte counters, hit rates, and x-reuse next to the measured
/// CPU throughput of the same engine. Plain dense-width ELL is skipped
/// on padding-hostile matrices (same rule as the engine sweeps).
pub fn traffic_ablation<S: Scalar>(
    m: &Csr<S>,
    base: &PreprocessConfig,
    dev: &GpuDevice,
) -> crate::Result<Vec<TrafficRow>> {
    let x = vec![S::ONE; m.nrows()];
    let mut rows = Vec::new();
    for kind in EngineKind::ALL {
        if kind == EngineKind::Ell && crate::api::ell_padding_excessive(m) {
            continue;
        }
        let ctx = SpmvContext::builder(m.clone()).engine(kind).config(base.clone()).build()?;
        let report = match ctx.plan() {
            Some(plan) => crate::traffic::ehyb_traffic(&plan.matrix, dev),
            None => crate::traffic::baseline_traffic(kind, m, dev),
        };
        let e = ctx.engine();
        let mut y = vec![S::ZERO; e.nrows()];
        let secs = crate::util::timer::bench_secs(
            || e.spmv(&x, &mut y),
            3,
            std::time::Duration::from_millis(30),
        );
        rows.push(TrafficRow {
            engine: kind.name().to_string(),
            dram_bytes: report.dram.total_bytes(),
            l2_bytes: report.l2.total_bytes(),
            shm_bytes: report.shm.total_bytes(),
            l2_hit_rate: report.l2.hit_rate(),
            x_reuse: report.x.reuse_factor(),
            predicted_secs: report.predicted_secs,
            measured_gflops: crate::spmv::gflops(e.nnz(), secs),
        });
    }
    Ok(rows)
}

/// One variant of the calibration ablation: the Heuristic pick made
/// with or without the fitted per-host [`Calibration`], the oracle
/// score it won on, and the measured throughput of the picked engine.
///
/// [`Calibration`]: crate::profile::Calibration
#[derive(Clone, Debug)]
pub struct DriftAblationRow {
    /// "uncalibrated" | "calibrated".
    pub variant: String,
    /// Engine the Heuristic search chose.
    pub pick: String,
    /// The winner's oracle score (seconds per SpMV under the variant's
    /// cost model — raw V100 replay vs calibrated-to-host).
    pub score_secs: f64,
    /// Wall-clock throughput of the picked engine on this host.
    pub measured_gflops: f64,
    /// RMS relative residual of the calibration fit (0 when raw).
    pub fit_residual: f64,
    /// Probes the fit consumed (0 when raw).
    pub samples: usize,
}

/// ISSUE 10: the drift ablation — fit a [`crate::profile::Calibration`]
/// from measured probes of a few concrete engines on this host, then
/// run the same Heuristic search twice, uncalibrated and calibrated,
/// and measure what each picked. The acceptance bar (asserted in the
/// tests and rendered by `ablation --which drift`) is that the
/// calibrated pick is never measurably worse than the uncalibrated one.
pub fn drift_ablation<S: Scalar>(
    m: &Csr<S>,
    base: &PreprocessConfig,
    dev: &GpuDevice,
) -> crate::Result<Vec<DriftAblationRow>> {
    use crate::profile::{CalSample, Calibration};
    let x = vec![S::ONE; m.nrows()];
    // Probe engines with distinct DRAM/L2/shm mixes so the fit's
    // features stay distinguishable (the explicitly-cached EHYB walk
    // vs two uncached CSR walks vs the padded SELL-P stream).
    let mut samples = Vec::new();
    for kind in
        [EngineKind::Ehyb, EngineKind::CsrVector, EngineKind::CsrScalar, EngineKind::SellP]
    {
        let ctx =
            SpmvContext::builder(m.clone()).engine(kind).config(base.clone()).no_plan_cache().build()?;
        let report = match ctx.plan() {
            Some(plan) => crate::traffic::ehyb_traffic(&plan.matrix, dev),
            None => crate::traffic::baseline_traffic(kind, m, dev),
        };
        let e = ctx.engine();
        let mut y = vec![S::ZERO; e.nrows()];
        let secs = crate::util::timer::bench_secs(
            || e.spmv(&x, &mut y),
            3,
            std::time::Duration::from_millis(20),
        );
        samples.push(CalSample::of(&report, secs));
    }
    let cal = Calibration::fit(&samples).unwrap_or_else(|| Calibration::uncalibrated(dev));
    let mut rows = Vec::new();
    for (variant, cal) in [("uncalibrated", None), ("calibrated", Some(cal))] {
        let mut b = SpmvContext::builder(m.clone())
            .config(base.clone())
            .tune(TuneLevel::Heuristic)
            .no_plan_cache();
        if let Some(c) = &cal {
            b = b.calibration(c.clone());
        }
        let ctx = b.build()?;
        let tuned = ctx.tuned().expect("tuner-routed build records a TunedPlan");
        let e = ctx.engine();
        let mut y = vec![S::ZERO; e.nrows()];
        let secs = crate::util::timer::bench_secs(
            || e.spmv(&x, &mut y),
            3,
            std::time::Duration::from_millis(30),
        );
        rows.push(DriftAblationRow {
            variant: variant.to_string(),
            pick: tuned.engine.name().to_string(),
            score_secs: tuned.score_secs,
            measured_gflops: crate::spmv::gflops(e.nnz(), secs),
            fit_residual: cal.as_ref().map_or(0.0, |c| c.residual),
            samples: cal.as_ref().map_or(0, |c| c.samples),
        });
    }
    Ok(rows)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::gen::unstructured_mesh;

    fn setup() -> (Csr<f64>, PreprocessConfig, GpuDevice) {
        (
            unstructured_mesh::<f64>(48, 48, 0.4, 5),
            PreprocessConfig { vec_size_override: Some(256), ..Default::default() },
            GpuDevice::v100(),
        )
    }

    #[test]
    fn cache_ablation_shows_benefit() {
        let (m, cfg, dev) = setup();
        let rows = cache_and_cols(&m, &cfg, &dev).unwrap();
        assert_eq!(rows.len(), 4);
        let g = |v: &str| rows.iter().find(|r| r.variant.starts_with(v)).unwrap().gflops;
        // Full EHYB ≥ no-cache variant.
        assert!(g("cache=shm cols=u16") >= g("cache=l2 cols=u16"));
        // u16 ≥ u32 at same cache setting.
        assert!(g("cache=shm cols=u16") >= g("cache=shm cols=u32"));
    }

    #[test]
    fn partitioner_ablation_ordering() {
        let (m, cfg, dev) = setup();
        let rows = partitioner_quality(&m, &cfg, &dev).unwrap();
        let er = |v: &str| rows.iter().find(|r| r.variant == v).unwrap().er_fraction;
        assert!(er("Multilevel") < er("Random"));
    }

    #[test]
    fn sort_ablation_fill() {
        let (m, cfg, dev) = setup();
        let rows = sort_ablation(&m, &cfg, &dev).unwrap();
        let fill_on = rows.iter().find(|r| r.variant == "sort_desc=true").unwrap().ell_fill;
        let fill_off = rows.iter().find(|r| r.variant == "sort_desc=false").unwrap().ell_fill;
        assert!(fill_on <= fill_off);
    }

    #[test]
    fn vecsize_sweep_runs() {
        let (m, cfg, dev) = setup();
        let rows = vecsize_sweep(&m, &cfg, &dev, &[64, 128, 256, 512]).unwrap();
        assert!(rows.len() >= 3);
        assert!(rows.iter().all(|r| r.gflops > 0.0));
    }

    #[test]
    fn reorder_ablation_reports_every_spec_and_improves_locality() {
        let (m, cfg, dev) = setup();
        let rows = reorder_ablation(&m, &cfg, &dev, 8).unwrap();
        assert_eq!(rows.len(), 5);
        let get = |tag: &str| {
            rows.iter()
                .find(|r| r.spec == tag || r.spec.starts_with(tag))
                .unwrap_or_else(|| panic!("missing row {tag}"))
        };
        let none = get("none");
        // The mesh generator hides locality behind random labels: both
        // locality-aware orderings must beat the natural order on
        // bandwidth AND on the cache-aware cross-shard cut (the ISSUE 5
        // acceptance criterion, reported here and asserted again in
        // rust/tests/reorder.rs).
        for tag in ["rcm", "partrank"] {
            let row = get(tag);
            assert!(
                row.bandwidth < none.bandwidth,
                "{tag} bandwidth {} !< none {}",
                row.bandwidth,
                none.bandwidth
            );
            assert!(
                row.cut_nnz < none.cut_nnz,
                "{tag} cut {} !< none {}",
                row.cut_nnz,
                none.cut_nnz
            );
        }
        assert!(get("auto->").footprint <= none.footprint);
        assert!(rows.iter().all(|r| r.gflops > 0.0));
    }

    #[test]
    fn traffic_ablation_covers_every_engine() {
        let (m, cfg, dev) = setup();
        let rows = traffic_ablation(&m, &cfg, &dev).unwrap();
        assert_eq!(rows.len(), EngineKind::ALL.len());
        let get = |name: &str| {
            rows.iter().find(|r| r.engine == name).unwrap_or_else(|| panic!("missing {name}"))
        };
        // Only the explicitly-cached engine serves bytes out of shm.
        assert!(get("ehyb").shm_bytes > 0);
        assert_eq!(get("csr-vector").shm_bytes, 0);
        for r in &rows {
            assert!(r.predicted_secs > 0.0, "{}: no predicted time", r.engine);
            assert!(r.measured_gflops > 0.0, "{}: no measured rate", r.engine);
            assert!(r.dram_bytes > 0 && r.l2_bytes > 0, "{}: empty traffic", r.engine);
            assert!(r.x_reuse >= 1.0, "{}: reuse factor below 1", r.engine);
            assert!((0.0..=1.0).contains(&r.l2_hit_rate), "{}", r.engine);
        }
    }

    #[test]
    fn drift_ablation_calibrated_pick_not_measurably_worse() {
        let (m, cfg, dev) = setup();
        let rows = drift_ablation(&m, &cfg, &dev).unwrap();
        assert_eq!(rows.len(), 2);
        assert_eq!(rows[0].variant, "uncalibrated");
        assert_eq!(rows[1].variant, "calibrated");
        assert_eq!(rows[1].samples, 4, "fit consumed every probe");
        assert!(rows[1].fit_residual.is_finite());
        assert!(rows.iter().all(|r| r.score_secs > 0.0 && r.measured_gflops > 0.0), "{rows:?}");
        // The acceptance bar: calibrating the oracle must not make the
        // Heuristic pick measurably worse. Generous slack absorbs CI
        // timer noise when both variants pick the same engine.
        assert!(
            rows[1].measured_gflops >= 0.5 * rows[0].measured_gflops,
            "calibrated pick regressed: {rows:?}"
        );
    }

    #[test]
    fn tuning_ablation_has_three_variants() {
        let (m, cfg, dev) = setup();
        let rows = tuning_ablation(&m, &cfg, &dev).unwrap();
        assert_eq!(rows.len(), 3);
        assert!(rows[0].variant.starts_with("default"));
        assert!(rows[1].variant.starts_with("tuned-heuristic"));
        assert!(rows[2].variant.starts_with("tuned-measured"));
        assert!(rows.iter().all(|r| r.gflops > 0.0));
        // Every variant records the knobs it ran with.
        assert!(rows.iter().all(|r| r.variant.contains("vec_size=")));
    }
}
