//! Per-matrix measurement: preprocess into EHYB, walk every framework's
//! simulated kernel, and return one row per framework — the unit of
//! work behind every figure and table.

use crate::api::{EngineKind, SpmvContext};
use crate::gpu::{kernels, simulate, GpuDevice, SimReport};
use crate::preprocess::{PreprocessConfig, PreprocessTimings};
use crate::sparse::csr::Csr;
use crate::sparse::scalar::Scalar;
use crate::spmv::SpmvEngine;
use crate::util::Timer;

/// Build an EHYB [`SpmvContext`] for a harness measurement — the one
/// place the harness runs preprocessing (everything downstream,
/// including [`super::ablation`], reads the plan back off the context;
/// the engine itself is built lazily, so plan-only measurements never
/// pay for it).
pub(crate) fn ehyb_context<S: Scalar>(
    m: &Csr<S>,
    cfg: &PreprocessConfig,
) -> crate::Result<SpmvContext<S>> {
    SpmvContext::builder(m.clone()).engine(EngineKind::Ehyb).config(cfg.clone()).build()
}

/// One framework's result on one matrix.
#[derive(Clone, Debug)]
pub struct FrameworkRow {
    pub framework: &'static str,
    pub gflops: f64,
    pub time_secs: f64,
    pub bound: &'static str,
}

/// Everything measured for one matrix at one precision.
#[derive(Clone, Debug)]
pub struct MatrixRun {
    pub name: String,
    pub category: &'static str,
    pub n: usize,
    pub nnz: usize,
    pub dtype: &'static str,
    pub er_fraction: f64,
    pub ell_fill: f64,
    pub cut_fraction: f64,
    pub rows: Vec<FrameworkRow>,
    /// Host preprocessing wall-clock (partition + reorder).
    pub prep: PreprocessTimings,
    /// Simulated single-SpMV time of the EHYB kernel (Fig. 6's unit).
    pub ehyb_spmv_secs: f64,
}

impl MatrixRun {
    pub fn gflops_of(&self, framework: &str) -> Option<f64> {
        self.rows.iter().find(|r| r.framework == framework).map(|r| r.gflops)
    }

    /// EHYB speedup vs `framework` (>1 = EHYB faster).
    pub fn speedup_vs(&self, framework: &str) -> Option<f64> {
        let e = self.gflops_of("ehyb")?;
        let f = self.gflops_of(framework)?;
        Some(e / f)
    }
}

/// Frameworks compared in the paper's figures, in plot order.
/// (f64 drops yaspmv — the paper notes it has no double support.)
pub fn frameworks<S: Scalar>() -> Vec<&'static str> {
    let mut v = vec!["holaspmv", "csr5", "merge", "cusparse-alg1", "cusparse-alg2"];
    if S::BYTES == 4 {
        v.insert(0, "yaspmv");
    }
    v
}

/// Run the full framework comparison on one matrix.
pub fn run_matrix<S: Scalar>(
    name: &str,
    category: &'static str,
    m: &Csr<S>,
    cfg: &PreprocessConfig,
    dev: &GpuDevice,
) -> crate::Result<MatrixRun> {
    let ctx = ehyb_context(m, cfg)?;
    let plan = ctx.plan().expect("EHYB context carries a plan");
    let mut rows = Vec::new();

    let push = |rows: &mut Vec<FrameworkRow>, r: SimReport| {
        rows.push(FrameworkRow {
            framework: r.name,
            gflops: r.gflops,
            time_secs: r.time_secs,
            bound: r.bound,
        });
    };

    // EHYB itself.
    let ehyb_report = simulate(&kernels::ehyb(&plan.matrix, dev, true, true), dev);
    let ehyb_spmv_secs = ehyb_report.time_secs;
    push(&mut rows, ehyb_report);

    // Baselines.
    if S::BYTES == 4 {
        push(&mut rows, simulate(&kernels::bcoo_yaspmv(m, dev), dev));
    }
    push(&mut rows, simulate(&kernels::hola(m, dev), dev));
    push(&mut rows, simulate(&kernels::csr5(m, dev), dev));
    push(&mut rows, simulate(&kernels::merge_based(m, dev), dev));
    push(&mut rows, simulate(&kernels::csr_vector_alg1(m, dev), dev));
    push(&mut rows, simulate(&kernels::csr_adaptive_alg2(m, dev), dev));

    Ok(MatrixRun {
        name: name.to_string(),
        category,
        n: m.nrows(),
        nnz: m.nnz(),
        dtype: S::NAME,
        er_fraction: plan.matrix.er_fraction(),
        ell_fill: plan.matrix.ell_fill_ratio(),
        cut_fraction: plan.partition.cut_fraction,
        rows,
        prep: plan.timings,
        ehyb_spmv_secs,
    })
}

/// Measure host preprocessing against the *CPU* EHYB SpMV wall-clock —
/// the apples-to-apples decomposition when no GPU exists (used as a
/// cross-check next to the simulated ratio in Fig. 6).
pub fn measure_prep_ratio_cpu<S: Scalar>(
    m: &Csr<S>,
    cfg: &PreprocessConfig,
) -> crate::Result<(PreprocessTimings, f64)> {
    let ctx = ehyb_context(m, cfg)?;
    let timings = ctx.plan().expect("EHYB context carries a plan").timings;
    let engine = ctx.engine();
    let x = vec![S::ONE; m.nrows()];
    let mut y = vec![S::ZERO; m.nrows()];
    let secs = crate::util::timer::bench_secs(
        || engine.spmv(&x, &mut y),
        3,
        std::time::Duration::from_millis(30),
    );
    Ok((timings, secs))
}

/// One matrix's simulated-vs-measured engine ranking (ISSUE 7): the
/// traffic oracle's pick against the [`TuneLevel::Measured`] winner.
#[derive(Clone, Debug)]
pub struct ValidationRow {
    pub matrix: String,
    /// Engine the traffic-scored heuristic search picked.
    pub simulated_pick: String,
    /// Engine the measured (wall-clock probe) search picked.
    pub measured_pick: String,
    /// Measured CPU GFLOPS of the simulated pick.
    pub sim_pick_gflops: f64,
    /// Measured CPU GFLOPS of the measured pick.
    pub measured_pick_gflops: f64,
    /// Same engine, or the simulated pick measures within 10% of the
    /// measured winner — "the simulation ranked usefully".
    pub agree: bool,
}

/// Validate the traffic oracle's ranking on one matrix: run the same
/// `Auto` search twice — once scored by the replayed
/// [`crate::traffic`] simulation ([`TuneLevel::Heuristic`]), once by
/// wall-clock probes ([`TuneLevel::Measured`]) — then measure both
/// picks with the real engines and report whether the simulated
/// ranking agreed with the measured one. Both searches run
/// cache-isolated so no persisted plan can stand in for either.
pub fn traffic_validation<S: Scalar>(
    name: &str,
    m: &Csr<S>,
    cfg: &PreprocessConfig,
) -> crate::Result<ValidationRow> {
    use crate::autotune::TuneLevel;
    let pick = |level: TuneLevel| -> crate::Result<EngineKind> {
        Ok(SpmvContext::builder(m.clone())
            .engine(EngineKind::Auto)
            .config(cfg.clone())
            .no_plan_cache()
            .tune(level)
            .build()?
            .kind())
    };
    let simulated = pick(TuneLevel::Heuristic)?;
    let measured = pick(TuneLevel::measured())?;
    let bench = |kind: EngineKind| -> crate::Result<f64> {
        let ctx = SpmvContext::builder(m.clone()).engine(kind).config(cfg.clone()).build()?;
        let e = ctx.engine();
        let x = vec![S::ONE; m.nrows()];
        let mut y = vec![S::ZERO; e.nrows()];
        let secs = crate::util::timer::bench_secs(
            || e.spmv(&x, &mut y),
            3,
            std::time::Duration::from_millis(30),
        );
        Ok(crate::spmv::gflops(e.nnz(), secs))
    };
    let sim_pick_gflops = bench(simulated)?;
    let measured_pick_gflops =
        if simulated == measured { sim_pick_gflops } else { bench(measured)? };
    // Wall-clock probes are noisy at these sizes: "agreement" is the
    // simulated pick landing within 10% of the measured winner, not
    // exact-name equality.
    let agree = simulated == measured || sim_pick_gflops >= 0.9 * measured_pick_gflops;
    Ok(ValidationRow {
        matrix: name.to_string(),
        simulated_pick: simulated.name().to_string(),
        measured_pick: measured.name().to_string(),
        sim_pick_gflops,
        measured_pick_gflops,
        agree,
    })
}

/// Wall-clock benchmark of the CPU engines (used by the hotpath bench
/// and the §Perf iteration log). The sweep builds one [`SpmvContext`]
/// per [`EngineKind`] — the crate's single engine-construction path
/// (the old `spmv::registry` is retired).
pub fn bench_cpu_engines<S: Scalar>(
    m: &Csr<S>,
    cfg: &PreprocessConfig,
) -> crate::Result<Vec<(String, f64)>> {
    let x = vec![S::ONE; m.nrows()];
    let mut out = Vec::new();
    // One context at a time (each owns a matrix clone + the engine's
    // format copy): building the whole `api::all_contexts` vector up
    // front would hold |ALL| clones of a possibly-large CSR alive at
    // once for no benefit here.
    for kind in EngineKind::ALL {
        // Plain dense-width ELL allocates nrows×max_row_nnz slots — on
        // power-law matrices that dwarfs the matrix itself (the old
        // registry sweep omitted plain ELL entirely). Skip it rather
        // than abort the whole sweep.
        if kind == EngineKind::Ell && crate::api::ell_padding_excessive(m) {
            continue;
        }
        let ctx = SpmvContext::builder(m.clone()).engine(kind).config(cfg.clone()).build()?;
        let e = ctx.engine();
        let mut y = vec![S::ZERO; e.nrows()];
        let secs = crate::util::timer::bench_secs(
            || e.spmv(&x, &mut y),
            3,
            std::time::Duration::from_millis(30),
        );
        out.push((e.name().to_string(), crate::spmv::gflops(e.nnz(), secs)));
    }
    let _ = Timer::start();
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::gen::{poisson3d, unstructured_mesh};

    fn cfg(v: usize) -> PreprocessConfig {
        PreprocessConfig { vec_size_override: Some(v), ..Default::default() }
    }

    #[test]
    fn run_matrix_produces_all_frameworks_f64() {
        let m = poisson3d::<f64>(10, 10, 10);
        let run = run_matrix("p3d", "CFD", &m, &cfg(128), &GpuDevice::v100()).unwrap();
        let names: Vec<_> = run.rows.iter().map(|r| r.framework).collect();
        assert!(names.contains(&"ehyb"));
        for f in frameworks::<f64>() {
            assert!(names.contains(&f), "missing {f}");
        }
        assert!(!names.contains(&"yaspmv"), "yaspmv has no f64 (paper §5.2)");
        assert!(run.rows.iter().all(|r| r.gflops > 0.0));
    }

    #[test]
    fn run_matrix_f32_includes_yaspmv() {
        let m = poisson3d::<f32>(8, 8, 8);
        let run = run_matrix("p3d", "CFD", &m, &cfg(128), &GpuDevice::v100()).unwrap();
        assert!(run.gflops_of("yaspmv").is_some());
        assert!(run.speedup_vs("cusparse-alg1").unwrap() > 0.0);
    }

    #[test]
    fn traffic_validation_reports_both_picks() {
        let m = poisson3d::<f64>(8, 8, 8);
        let row = traffic_validation("p3d-8", &m, &cfg(64)).unwrap();
        assert_eq!(row.matrix, "p3d-8");
        assert!(EngineKind::from_name(&row.simulated_pick).is_some(), "{}", row.simulated_pick);
        assert!(EngineKind::from_name(&row.measured_pick).is_some(), "{}", row.measured_pick);
        assert!(row.sim_pick_gflops > 0.0 && row.measured_pick_gflops > 0.0);
        // agree is a derived field, recomputable from the row itself.
        assert_eq!(
            row.agree,
            row.simulated_pick == row.measured_pick
                || row.sim_pick_gflops >= 0.9 * row.measured_pick_gflops
        );
    }

    #[test]
    fn prep_ratio_positive() {
        let m = unstructured_mesh::<f64>(24, 24, 0.4, 3);
        let (prep, spmv) = measure_prep_ratio_cpu(&m, &cfg(64)).unwrap();
        assert!(spmv > 0.0);
        assert!(prep.total_secs() > 0.0);
    }

    #[test]
    fn cpu_engines_benchable() {
        let m = poisson3d::<f64>(6, 6, 6);
        let rows = bench_cpu_engines(&m, &cfg(64)).unwrap();
        // One row per concrete EngineKind (EHYB + seven baselines).
        assert_eq!(rows.len(), EngineKind::ALL.len());
        assert!(rows.iter().all(|(_, g)| *g > 0.0));
    }

    #[test]
    fn cpu_engines_skip_plain_ell_on_power_law_rows() {
        use crate::sparse::coo::Coo;
        // One near-dense row: dense-width ELL would allocate ~4.5M
        // slots for 4.5k nonzeros; the sweep must skip it, not abort.
        let n = 3000;
        let mut coo = Coo::<f64>::new(n, n);
        for i in 0..n {
            coo.push(i, i, 2.0);
        }
        for j in 1..1500 {
            coo.push(0, j, 0.5);
        }
        let rows = bench_cpu_engines(&coo.to_csr(), &cfg(96)).unwrap();
        assert_eq!(rows.len(), EngineKind::ALL.len() - 1);
        assert!(rows.iter().all(|(name, _)| name != "ell"));
        assert!(rows.iter().any(|(name, _)| name == "sellp"), "sliced formats stay in");
    }
}
