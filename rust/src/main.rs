//! `ehyb` — CLI for the EHYB SpMV framework reproduction.
//!
//! Subcommands:
//!   info        matrix structure statistics
//!   preprocess  run Algorithms 1-2, report partition/ER/fill/timings
//!   spmv        one SpMV: CPU wallclock + simulated V100 + optional PJRT
//!   solve       preconditioned CG/BiCGSTAB over the chosen engine
//!   tune        OSKI-style plan search (+ optional persistent cache)
//!   bench       regenerate paper tables/figures (see DESIGN.md §6)
//!   ablation    DESIGN.md §7 ablations + the tuning ablation
//!   chaos       seeded fault-injection drills over the resilience layer
//!   stats       seeded fake-clock workload -> full telemetry snapshot
//!   trace       replay one request's story from its trace ID
//!   profile     seeded workload -> observed kernel profile + model drift
//!
//! Matrix selection: `--gen poisson3d:24` style specs or `--mtx file.mtx`.

use ehyb::coordinator::{Jacobi, Spai0, SolverConfig};
use ehyb::gpu::GpuDevice;
use ehyb::harness::{report, runner, suite, tables};
use ehyb::harness::suite::Scale;
use ehyb::preprocess::PreprocessConfig;
use ehyb::sparse::csr::Csr;
use ehyb::spmv::SpmvEngine;
use ehyb::{EngineKind, ReorderSpec, ShardSpec, SpmvContext};
use ehyb::sparse::gen;
use ehyb::sparse::mmio::read_matrix_market;
use ehyb::sparse::stats::MatrixStats;
use std::collections::HashMap;

fn main() {
    let args: Vec<String> = std::env::args().skip(1).collect();
    if args.is_empty() {
        usage();
        std::process::exit(2);
    }
    let cmd = args[0].clone();
    let opts = parse_opts(&args[1..]);
    let r = match cmd.as_str() {
        "info" => cmd_info(&opts),
        "preprocess" => cmd_preprocess(&opts),
        "spmv" => cmd_spmv(&opts),
        "solve" => cmd_solve(&opts),
        "tune" => cmd_tune(&opts),
        "bench" => cmd_bench(&opts),
        "ablation" => cmd_ablation(&opts),
        "chaos" => cmd_chaos(&opts),
        "stats" => cmd_stats(&opts),
        "trace" => cmd_trace(&opts),
        "profile" => cmd_profile(&opts),
        "--help" | "-h" | "help" => {
            usage();
            Ok(())
        }
        other => {
            eprintln!("unknown command {other}");
            usage();
            std::process::exit(2);
        }
    };
    if let Err(e) = r {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn usage() {
    eprintln!(
        "usage: ehyb <cmd> [--gen SPEC | --mtx FILE] [options]\n\
         cmds: info | preprocess | spmv | solve | tune | bench | ablation | chaos\n\
         \x20     | stats | trace | profile\n\
         gen specs: poisson2d:NX[:NY] poisson3d:N[:NY:NZ] stencil27:N\n\
                    elasticity:N unstructured:N circuit:N kkt:N banded:N\n\
         options: --vec-size V  --shards K|auto  --reorder none|degree|rcm|partrank[:K]|auto\n\
                  --dtype f32|f64  --pjrt  --artifacts DIR\n\
                  --precond none|jacobi|spai0  --solver cg|bicgstab\n\
                  --table 1|2  --fig 2|3|4|5|6  --scale tiny|small|full\n\
                  --validate (bench: simulated-vs-measured engine ranking)\n\
                  --out DIR  --which cache|partitioner|sort|vecsize|tuning|reorder|traffic|drift\n\
                  --level heuristic|measured  --oracle traffic|roofline  --budget-ms N\n\
                  --engine auto|ehyb|...\n\
                  --cache DIR (tune; default $EHYB_TUNE_DIR)  --seed N (chaos/stats/trace/profile)\n\
                  --format md|json|prom (stats)  --trace N (trace; default: retried request)\n\
                  --json (profile: machine-readable profile + drift report)"
    );
}

fn parse_opts(args: &[String]) -> HashMap<String, String> {
    let mut m = HashMap::new();
    let mut i = 0;
    while i < args.len() {
        let a = &args[i];
        if let Some(key) = a.strip_prefix("--") {
            if i + 1 < args.len() && !args[i + 1].starts_with("--") {
                m.insert(key.to_string(), args[i + 1].clone());
                i += 2;
            } else {
                m.insert(key.to_string(), "true".to_string());
                i += 1;
            }
        } else {
            eprintln!("ignoring stray argument {a}");
            i += 1;
        }
    }
    m
}

fn build_matrix(opts: &HashMap<String, String>) -> anyhow::Result<Csr<f64>> {
    if let Some(path) = opts.get("mtx") {
        return Ok(read_matrix_market::<f64, _>(path)?.to_csr());
    }
    let spec = opts.get("gen").cloned().unwrap_or_else(|| "poisson3d:20".to_string());
    let parts: Vec<&str> = spec.split(':').collect();
    let d = |i: usize, def: usize| parts.get(i).and_then(|s| s.parse().ok()).unwrap_or(def);
    Ok(match parts[0] {
        "poisson2d" => gen::poisson2d(d(1, 32), d(2, d(1, 32))),
        "poisson3d" => gen::poisson3d(d(1, 20), d(2, d(1, 20)), d(3, d(1, 20))),
        "stencil27" => gen::stencil27(d(1, 16), d(1, 16), d(1, 16), 1),
        "elasticity" => gen::elasticity3d(d(1, 10), d(1, 10), d(1, 10), 3, 1),
        "unstructured" => gen::unstructured_mesh(d(1, 64), d(1, 64), 0.5, 1),
        "circuit" => gen::circuit(d(1, 10_000), 3, 0.01, 1),
        "kkt" => gen::kkt(d(1, 16), 1),
        "banded" => gen::banded(d(1, 10_000), 16, 0.4, 1),
        other => anyhow::bail!("unknown generator {other}"),
    })
}

fn preprocess_cfg(opts: &HashMap<String, String>) -> PreprocessConfig {
    let mut cfg = PreprocessConfig::default();
    if let Some(v) = opts.get("vec-size").and_then(|v| v.parse().ok()) {
        cfg.vec_size_override = Some(v);
    }
    cfg
}

/// `--shards K` / `--shards auto` → row-sharded execution spec.
fn shard_spec(opts: &HashMap<String, String>) -> anyhow::Result<Option<ShardSpec>> {
    match opts.get("shards").map(String::as_str) {
        None => Ok(None),
        Some("auto") | Some("true") => Ok(Some(ShardSpec::Auto)),
        Some(v) => {
            let k: usize = v.parse().map_err(|_| anyhow::anyhow!("bad --shards value {v}"))?;
            Ok(Some(ShardSpec::Count(k)))
        }
    }
}

/// Apply `--shards` to a context builder.
fn with_shards<S: ehyb::sparse::scalar::Scalar>(
    b: ehyb::api::SpmvContextBuilder<S>,
    opts: &HashMap<String, String>,
) -> anyhow::Result<ehyb::api::SpmvContextBuilder<S>> {
    Ok(match shard_spec(opts)? {
        Some(spec) => b.shards(spec),
        None => b,
    })
}

/// `--reorder none|degree|rcm|partrank[:K]|auto` → global ordering spec.
fn reorder_spec(opts: &HashMap<String, String>) -> anyhow::Result<Option<ReorderSpec>> {
    match opts.get("reorder").map(String::as_str) {
        None => Ok(None),
        Some(v) => Ok(Some(
            ReorderSpec::from_name(v)
                .ok_or_else(|| anyhow::anyhow!("bad --reorder value {v}"))?,
        )),
    }
}

/// Apply `--reorder` to a context builder.
fn with_reorder<S: ehyb::sparse::scalar::Scalar>(
    b: ehyb::api::SpmvContextBuilder<S>,
    opts: &HashMap<String, String>,
) -> anyhow::Result<ehyb::api::SpmvContextBuilder<S>> {
    Ok(match reorder_spec(opts)? {
        Some(spec) => b.reorder(spec),
        None => b,
    })
}

/// One-line before→after summary of a context's reordering.
fn print_reorder_summary<S: ehyb::sparse::scalar::Scalar>(ctx: &SpmvContext<S>) {
    if let Some(r) = ctx.reordering() {
        println!(
            "reorder     : {} (bandwidth {} -> {}, profile {} -> {}, window footprint \
             {:.1} -> {:.1})",
            r.resolved,
            r.before.bandwidth,
            r.after.bandwidth,
            r.before.profile,
            r.after.profile,
            r.before.window_footprint,
            r.after.window_footprint
        );
        if let Some((before, after)) = ctx.reorder_cut_nnz() {
            println!("shard cut   : {before} -> {after} cross-shard entries");
        }
    }
}

fn cmd_info(opts: &HashMap<String, String>) -> anyhow::Result<()> {
    let m = build_matrix(opts)?;
    let s = MatrixStats::of(&m);
    println!("{}", s.oneline());
    println!(
        "row nnz: mean={:.2} median={:.1} sd={:.2} min={:.0} max={:.0}; empty rows={}",
        s.row_nnz.mean,
        s.row_nnz.median,
        s.row_nnz.stddev,
        s.row_nnz.min,
        s.row_nnz.max,
        s.empty_rows
    );
    println!(
        "bandwidth={} mean|col-row|={:.1} structural symmetry={:.3}",
        s.bandwidth, s.mean_band, s.structural_symmetry
    );
    Ok(())
}

fn cmd_preprocess(opts: &HashMap<String, String>) -> anyhow::Result<()> {
    let m = build_matrix(opts)?;
    let cfg = preprocess_cfg(opts);
    let ctx = SpmvContext::builder(m).engine(EngineKind::Ehyb).config(cfg).build()?;
    let plan = ctx.plan().expect("EHYB context carries a plan");
    let e = &plan.matrix;
    println!("partitions      : {} x vec_size {}", e.num_parts, e.vec_size);
    println!("K (eq.1)        : {}", plan.cache.k);
    println!(
        "edge cut        : {} ({:.2}% of edges)",
        plan.partition.edgecut,
        100.0 * plan.partition.cut_fraction
    );
    println!("ELL nnz         : {} (fill ratio {:.3})", e.ell_nnz, e.ell_fill_ratio());
    println!(
        "ER nnz          : {} ({:.2}% of nnz, {} rows)",
        e.er_nnz,
        100.0 * e.er_fraction(),
        e.er_rows
    );
    println!("bytes           : {} (u32 cols would be {})", e.bytes(), e.bytes_u32_cols());
    println!("partition time  : {:.4}s", plan.timings.partition_secs);
    println!("reorder time    : {:.4}s", plan.timings.reorder_secs);
    Ok(())
}

fn cmd_spmv(opts: &HashMap<String, String>) -> anyhow::Result<()> {
    let m = build_matrix(opts)?;
    let cfg = preprocess_cfg(opts);
    let dev = GpuDevice::v100();
    println!("matrix: n={} nnz={}", m.nrows(), m.nnz());

    println!("\nCPU wall-clock (this host):");
    for (name, gflops) in runner::bench_cpu_engines(&m, &cfg)? {
        println!("  {name:>15}: {gflops:7.3} GFLOPS");
    }

    if shard_spec(opts)?.is_some() || reorder_spec(opts)?.is_some() {
        let b = SpmvContext::builder(m.clone()).engine(EngineKind::Ehyb).config(cfg.clone());
        let ctx = with_reorder(with_shards(b, opts)?, opts)?.build()?;
        print_reorder_summary(&ctx);
        let x = vec![1.0f64; m.ncols()];
        let mut y = vec![0.0f64; m.nrows()];
        let e = ctx.engine();
        let secs = ehyb::util::timer::bench_secs(
            || e.spmv(&x, &mut y),
            3,
            std::time::Duration::from_millis(100),
        );
        println!(
            "\nehyb ({} row shards, reorder {}): {:.3} GFLOPS",
            ctx.shards(),
            ctx.reordering().map_or("none", |r| r.resolved.as_str()),
            ehyb::spmv::gflops(m.nnz(), secs)
        );
        if let Some(sharded) = ctx.sharded() {
            println!("{}", report::shard_markdown("Per-shard execution", sharded));
        }
    }

    println!("\nsimulated V100 (GPU cost model):");
    let run = runner::run_matrix("cli", "cli", &m, &cfg, &dev)?;
    for row in &run.rows {
        println!("  {:>15}: {:7.2} GFLOPS ({}-bound)", row.framework, row.gflops, row.bound);
    }
    println!("  er_fraction={:.4} ell_fill={:.3}", run.er_fraction, run.ell_fill);

    if opts.contains_key("pjrt") {
        let dir = opts.get("artifacts").cloned().unwrap_or_else(|| "artifacts".into());
        let rt = ehyb::runtime::PjrtRuntime::new(dir)?;
        let ctx = SpmvContext::builder(m.clone()).engine(EngineKind::Ehyb).config(cfg).build()?;
        let engine = rt.spmv_engine(&ctx.plan().expect("EHYB context carries a plan").matrix)?;
        let x = vec![1.0f64; m.nrows()];
        let mut y = vec![0.0; m.nrows()];
        let t = ehyb::util::Timer::start();
        engine.spmv(&x, &mut y)?;
        let secs = t.elapsed_secs();
        let oracle = m.spmv_f64_oracle(&x);
        ehyb::util::check::assert_allclose(&y, &oracle, 1e-9, 1e-9)
            .map_err(|e| anyhow::anyhow!("PJRT mismatch: {e}"))?;
        println!("\nPJRT ({}): {:.3} ms/SpMV — results match oracle", rt.platform(), secs * 1e3);
    }
    Ok(())
}

fn cmd_solve(opts: &HashMap<String, String>) -> anyhow::Result<()> {
    let m = build_matrix(opts)?;
    let cfg = preprocess_cfg(opts);
    let n = m.nrows();
    let b: Vec<f64> = (0..n).map(|i| ((i % 13) as f64) / 13.0 - 0.5).collect();
    let solver = opts.get("solver").map(String::as_str).unwrap_or("cg");
    let scfg = SolverConfig {
        max_iters: opts.get("max-iters").and_then(|v| v.parse().ok()).unwrap_or(2000),
        rtol: opts.get("rtol").and_then(|v| v.parse().ok()).unwrap_or(1e-8),
        divergence_window: opts.get("divergence-window").and_then(|v| v.parse().ok()).unwrap_or(0),
        ..Default::default()
    };
    let bld = with_shards(SpmvContext::builder(m).engine(EngineKind::Ehyb).config(cfg), opts)?;
    let ctx = with_reorder(bld, opts)?.build()?;
    print_reorder_summary(&ctx);
    let m = ctx.matrix();
    let h = ctx.solver();

    let pre_name = opts.get("precond").map(String::as_str).unwrap_or("jacobi");
    let report = match (solver, pre_name) {
        ("cg", "jacobi") => h.cg(&b, None, &Jacobi::new(m), &scfg)?.1,
        ("cg", "spai0") => h.cg(&b, None, &Spai0::new(m), &scfg)?.1,
        ("cg", _) => h.cg(&b, None, &ehyb::coordinator::precond::Identity, &scfg)?.1,
        ("bicgstab", "jacobi") => h.bicgstab(&b, None, &Jacobi::new(m), &scfg)?.1,
        ("bicgstab", "spai0") => h.bicgstab(&b, None, &Spai0::new(m), &scfg)?.1,
        ("bicgstab", _) => {
            h.bicgstab(&b, None, &ehyb::coordinator::precond::Identity, &scfg)?.1
        }
        (s, _) => anyhow::bail!("unknown solver {s}"),
    };
    println!(
        "{} + {}: {} iters, status={}, final rel residual {:.3e}, {} SpMVs, {:.3}s",
        report.solver,
        pre_name,
        report.iters,
        report.status.name(),
        report.final_rel_residual,
        report.spmv_count,
        report.wall_secs
    );
    // A K >= 2 sharded EHYB build skips the never-executed whole-matrix
    // plan; its preprocessing cost is the sum of the K block pipelines.
    let prep = match ctx.plan() {
        Some(p) => p.timings.total_secs(),
        None => ctx.sharded().map_or(0.0, |e| {
            e.stats().iter().filter_map(|s| s.block_prep.map(|t| t.total_secs())).sum()
        }),
    };
    let per_spmv = report.wall_secs / report.spmv_count.max(1) as f64;
    println!(
        "preprocessing {:.3}s = {:.0}x one SpMV; amortized over {} SpMVs: {:.1}% overhead",
        prep,
        prep / per_spmv.max(1e-12),
        report.spmv_count,
        100.0 * prep / (report.wall_secs + prep)
    );
    Ok(())
}

fn cmd_tune(opts: &HashMap<String, String>) -> anyhow::Result<()> {
    use ehyb::autotune::{
        config_key, device_key, tune_scored, Fingerprint, PlanStore, ScoreOracle, TuneLevel,
    };
    let m = build_matrix(opts)?;
    let cfg = preprocess_cfg(opts);
    let level = match opts.get("level").map(String::as_str) {
        Some("measured") => {
            let ms = opts.get("budget-ms").and_then(|v| v.parse().ok()).unwrap_or(250u64);
            TuneLevel::Measured { budget: std::time::Duration::from_millis(ms) }
        }
        Some("heuristic") | None => TuneLevel::Heuristic,
        Some(other) => anyhow::bail!("unknown tune level {other}"),
    };
    let oracle = match opts.get("oracle").map(String::as_str) {
        None => ScoreOracle::default(),
        Some(name) => ScoreOracle::from_name(name)
            .ok_or_else(|| anyhow::anyhow!("unknown score oracle {name}"))?,
    };
    let requested = match opts.get("engine") {
        Some(name) => {
            EngineKind::from_name(name).ok_or_else(|| anyhow::anyhow!("unknown engine {name}"))?
        }
        None => EngineKind::Auto,
    };

    // --reorder: tune the permuted structure (exactly what the facade
    // executes), stamping the resolved tag into the plan's provenance.
    let (m, reorder_tag) = match reorder_spec(opts)? {
        Some(spec) if spec != ReorderSpec::None => {
            let r = ehyb::Reordering::compute(&m, spec)?;
            println!(
                "reorder         : {} (bandwidth {} -> {}, window footprint {:.1} -> {:.1})",
                r.resolved,
                r.before.bandwidth,
                r.after.bandwidth,
                r.before.window_footprint,
                r.after.window_footprint
            );
            let tag = r.resolved.clone();
            let pm = if r.is_identity() { m } else { r.apply(&m) };
            (pm, tag)
        }
        _ => (m, "none".to_string()),
    };

    let fp = Fingerprint::of(&m);
    println!("fingerprint     : {}", fp.key());
    println!(
        "rows            : mean={:.2} max={:.0} sd={:.2}; diag-dominant {:.0}%",
        fp.row_mean,
        fp.row_max,
        fp.row_stddev,
        100.0 * fp.diag_dominant_fraction
    );

    // Mirror the facade's cache policy: an existing usable entry is
    // reported, not clobbered (a default heuristic run must never
    // overwrite a persisted measured winner for the same key).
    let store = opts.get("cache").map(PlanStore::new).or_else(PlanStore::from_env);
    if let Some(store) = &store {
        if let Ok(Some(existing)) =
            store.load(&fp.key(), &device_key(&cfg.device), "f64", requested.name())
        {
            if existing.usable_for(requested, level, oracle, &config_key(&cfg))
                && existing.reorder == reorder_tag
            {
                println!(
                    "cache hit       : engine={} slice_height={} vec_size={:?} cutoff={:?} \
                     ({} level; delete {} to re-tune)",
                    existing.engine.name(),
                    existing.slice_height,
                    existing.vec_size,
                    existing.ell_width_cutoff,
                    existing.level,
                    store
                        .path_for(
                            &existing.fingerprint,
                            &existing.device,
                            &existing.dtype,
                            &existing.scope
                        )
                        .display()
                );
                return Ok(());
            }
        }
    }

    let mut out = tune_scored(&m, &cfg, requested, level, oracle, Some(fp))?;
    out.plan.reorder = reorder_tag;
    let p = &out.plan;
    println!(
        "tuned plan      : engine={} slice_height={} vec_size={:?} cutoff={:?}",
        p.engine.name(),
        p.slice_height,
        p.vec_size,
        p.ell_width_cutoff
    );
    println!(
        "score ({})  : {:.3e}s vs default {:.3e}s ({:.1}% better)",
        p.level,
        p.score_secs,
        p.default_score_secs,
        100.0 * (1.0 - p.score_secs / p.default_score_secs.max(1e-300))
    );
    if p.level == "measured" {
        println!("probe width     : best at batch width {}", p.probe_width);
    } else {
        println!("oracle          : {} (heuristic scoring)", p.oracle);
    }
    println!(
        "search          : {} tried, {} skipped, {:.3}s",
        out.candidates_tried, out.candidates_skipped, out.search_secs
    );

    if let Some(store) = store {
        if out.searched() {
            let path = store.save(p)?;
            println!("persisted       : {}", path.display());
            let back = store
                .load(&p.fingerprint, &p.device, &p.dtype, &p.scope)?
                .ok_or_else(|| anyhow::anyhow!("saved plan did not load back"))?;
            anyhow::ensure!(back == *p, "plan-store round-trip mismatch");
            println!("reload          : verified (round-trip identical)");
        } else {
            println!(
                "not persisted   : budget too small to compare any candidate ({} shed on budget)",
                out.budget_skipped
            );
        }
    }
    Ok(())
}

fn bench_runs<S: ehyb::runtime::XlaScalar>(
    specs: &[suite::MatrixSpec],
    dev: &GpuDevice,
) -> Vec<runner::MatrixRun> {
    let mut runs = Vec::new();
    for (i, spec) in specs.iter().enumerate() {
        let m64 = spec.build();
        let m: Csr<S> = m64.cast();
        let cfg = PreprocessConfig::default();
        match runner::run_matrix(&spec.name, spec.category, &m, &cfg, dev) {
            Ok(run) => {
                eprintln!(
                    "[{}/{}] {}: n={} nnz={} ehyb={:.1} GF er={:.3}",
                    i + 1,
                    specs.len(),
                    spec.name,
                    run.n,
                    run.nnz,
                    run.gflops_of("ehyb").unwrap_or(0.0),
                    run.er_fraction
                );
                runs.push(run);
            }
            Err(e) => eprintln!("[{}/{}] {} FAILED: {e:#}", i + 1, specs.len(), spec.name),
        }
    }
    runs
}

fn cmd_bench(opts: &HashMap<String, String>) -> anyhow::Result<()> {
    let scale = match opts.get("scale").map(String::as_str) {
        Some("tiny") => Scale::Tiny,
        Some("full") => Scale::Full,
        Some("small") | None => Scale::from_env(),
        Some(other) => anyhow::bail!("unknown scale {other}"),
    };
    let dev = GpuDevice::v100();
    let out_dir = opts.get("out").cloned();
    let emit = |name: &str, content: &str| -> anyhow::Result<()> {
        if let Some(dir) = &out_dir {
            std::fs::create_dir_all(dir)?;
            let path = format!("{dir}/{name}");
            std::fs::write(&path, content)?;
            println!("wrote {path}");
        } else {
            println!("{content}");
        }
        Ok(())
    };

    // ISSUE 7 validation mode: does the traffic oracle's engine
    // ranking agree with wall-clock measured winners, per matrix?
    if opts.contains_key("validate") {
        let specs = suite::suite16(scale);
        let mut rows = Vec::new();
        for (i, spec) in specs.iter().enumerate() {
            let m = spec.build();
            match runner::traffic_validation(&spec.name, &m, &PreprocessConfig::default()) {
                Ok(row) => {
                    eprintln!(
                        "[{}/{}] {}: sim={} measured={} agree={}",
                        i + 1,
                        specs.len(),
                        spec.name,
                        row.simulated_pick,
                        row.measured_pick,
                        row.agree
                    );
                    rows.push(row);
                }
                Err(e) => eprintln!("[{}/{}] {} FAILED: {e:#}", i + 1, specs.len(), spec.name),
            }
        }
        emit(
            "traffic_validation.md",
            &report::traffic_validation_markdown(
                "Traffic oracle vs measured winner (16-matrix suite)",
                &rows,
            ),
        )?;
        return Ok(());
    }

    if let Some(t) = opts.get("table") {
        let specs = suite::suite94(scale);
        match t.as_str() {
            "1" => {
                let runs = bench_runs::<f32>(&specs, &dev);
                let tab = tables::speedup_table::<f32>(&runs);
                emit(
                    "table1_f32.md",
                    &report::speedup_markdown(
                        "Table 1 — EHYB speedups, single precision, 94 matrices",
                        &tab,
                    ),
                )?;
            }
            "2" => {
                let runs = bench_runs::<f64>(&specs, &dev);
                let tab = tables::speedup_table::<f64>(&runs);
                emit(
                    "table2_f64.md",
                    &report::speedup_markdown(
                        "Table 2 — EHYB speedups, double precision, 94 matrices",
                        &tab,
                    ),
                )?;
            }
            other => anyhow::bail!("unknown table {other}"),
        }
        return Ok(());
    }

    let fig = opts.get("fig").map(String::as_str).unwrap_or("2");
    match fig {
        "2" | "4" => {
            let specs = suite::suite94(scale);
            if fig == "2" {
                let runs = bench_runs::<f32>(&specs, &dev);
                let f = tables::figure_series::<f32>(&runs);
                emit("fig2_f32_94.csv", &report::figure_csv(&f))?;
                println!("{}", report::figure_summary(&f));
            } else {
                let runs = bench_runs::<f64>(&specs, &dev);
                let f = tables::figure_series::<f64>(&runs);
                emit("fig4_f64_94.csv", &report::figure_csv(&f))?;
                println!("{}", report::figure_summary(&f));
            }
        }
        "3" | "5" => {
            let specs = suite::suite16(scale);
            if fig == "3" {
                let runs = bench_runs::<f32>(&specs, &dev);
                let f = tables::figure_series::<f32>(&runs);
                emit("fig3_f32_16.csv", &report::figure_csv(&f))?;
                println!("{}", report::figure_summary(&f));
            } else {
                let runs = bench_runs::<f64>(&specs, &dev);
                let f = tables::figure_series::<f64>(&runs);
                emit("fig5_f64_16.csv", &report::figure_csv(&f))?;
                println!("{}", report::figure_summary(&f));
            }
        }
        "6" => {
            let specs = suite::suite16(scale);
            let runs = bench_runs::<f64>(&specs, &dev);
            let rows = tables::fig6_rows(&runs);
            emit("fig6_preprocessing.md", &report::fig6_markdown(&rows))?;
        }
        other => anyhow::bail!("unknown figure {other}"),
    }
    Ok(())
}

fn cmd_ablation(opts: &HashMap<String, String>) -> anyhow::Result<()> {
    use ehyb::harness::ablation;
    let m = build_matrix(opts)?;
    let cfg = preprocess_cfg(opts);
    let dev = GpuDevice::v100();
    let which = opts.get("which").map(String::as_str).unwrap_or("all");
    if which == "cache" || which == "all" {
        let rows = ablation::cache_and_cols(&m, &cfg, &dev)?;
        println!("{}", report::ablation_markdown("Explicit cache × column width", &rows));
    }
    if which == "partitioner" || which == "all" {
        let rows = ablation::partitioner_quality(&m, &cfg, &dev)?;
        println!("{}", report::ablation_markdown("Partitioner quality", &rows));
    }
    if which == "sort" || which == "all" {
        let rows = ablation::sort_ablation(&m, &cfg, &dev)?;
        println!("{}", report::ablation_markdown("Descending-nnz reorder", &rows));
    }
    if which == "vecsize" || which == "all" {
        let rows = ablation::vecsize_sweep(&m, &cfg, &dev, &[64, 128, 256, 512, 1024, 2048])?;
        println!("{}", report::ablation_markdown("VecSize (cache size) sweep", &rows));
    }
    if which == "tuning" || which == "all" {
        let rows = ablation::tuning_ablation(&m, &cfg, &dev)?;
        println!(
            "{}",
            report::ablation_markdown("Autotuning (default vs heuristic vs measured)", &rows)
        );
    }
    if which == "traffic" || which == "all" {
        let rows = ablation::traffic_ablation(&m, &cfg, &dev)?;
        println!(
            "{}",
            report::traffic_markdown("Simulated storage traffic (per engine)", &rows)
        );
    }
    if which == "reorder" || which == "all" {
        let k = opts.get("shards").and_then(|v| v.parse().ok()).unwrap_or(8);
        let rows = ablation::reorder_ablation(&m, &cfg, &dev, k)?;
        println!(
            "{}",
            report::reorder_markdown(
                &format!("Global reordering (cut at K={k} cache-aware shards)"),
                &rows
            )
        );
    }
    if which == "drift" || which == "all" {
        let rows = ablation::drift_ablation(&m, &cfg, &dev)?;
        println!(
            "{}",
            report::drift_ablation_markdown(
                "Oracle calibration (uncalibrated vs calibrated Heuristic pick)",
                &rows
            )
        );
        if let (Some(raw), Some(cal)) = (
            rows.iter().find(|r| r.variant == "uncalibrated"),
            rows.iter().find(|r| r.variant == "calibrated"),
        ) {
            anyhow::ensure!(
                cal.measured_gflops >= 0.5 * raw.measured_gflops,
                "calibrated pick measurably worse: {:.2} vs {:.2} GFLOPS",
                cal.measured_gflops,
                raw.measured_gflops
            );
        }
    }
    Ok(())
}

/// `profile --seed N [--gen SPEC] [--json]`: run a seeded SpMV workload
/// over the EHYB and csr-vector engines and print, per engine, the
/// observed kernel profile and its drift against the traffic replay of
/// the same prepared plan. With the `profile` feature compiled out
/// (`--no-default-features`) there is nothing to observe; the command
/// says so and exits cleanly.
fn cmd_profile(opts: &HashMap<String, String>) -> anyhow::Result<()> {
    use ehyb::runtime::json::{self, Json};
    if !ehyb::profile::enabled() {
        println!("profile feature is off (--no-default-features); nothing to observe");
        return Ok(());
    }
    let seed = opts.get("seed").and_then(|v| v.parse().ok()).unwrap_or(7u64);
    let m = build_matrix(opts)?;
    let cfg = preprocess_cfg(opts);
    let n = m.nrows();
    let mut docs = Vec::new();
    for kind in [EngineKind::Ehyb, EngineKind::CsrVector] {
        let mut ctx =
            SpmvContext::builder(m.clone()).engine(kind).config(cfg.clone()).build()?;
        let x: Vec<f64> = (0..n)
            .map(|i| ((i as u64).wrapping_mul(seed.max(1)) % 17) as f64 * 0.25 - 2.0)
            .collect();
        let mut y = vec![0.0f64; n];
        for _ in 0..3 {
            ctx.engine().spmv(&x, &mut y);
        }
        let p = ctx
            .profile()
            .ok_or_else(|| anyhow::anyhow!("{} recorded no profile", kind.name()))?;
        let d = ctx
            .observe_drift()
            .ok_or_else(|| anyhow::anyhow!("{} produced no drift report", kind.name()))?;
        if opts.contains_key("json") {
            docs.push(json::obj([
                ("engine", Json::Str(kind.name().to_string())),
                ("profile", p.to_json()),
                ("drift", d.to_json()),
            ]));
        } else {
            println!(
                "{}",
                report::profile_markdown(
                    &format!("Observed kernel profile — {} (seed {seed})", kind.name()),
                    &p
                )
            );
            println!(
                "{}",
                report::drift_markdown(
                    &format!("Model drift — {} vs traffic replay", kind.name()),
                    &d
                )
            );
        }
        let h = ctx.health();
        if h.model_drifts > 0 {
            println!("{}", report::health_markdown("Model-drift health", &h));
        }
    }
    if opts.contains_key("json") {
        let doc = json::obj([
            ("schema", Json::Str("ehyb-profile-v1".to_string())),
            ("seed", Json::Num(seed as f64)),
            ("engines", Json::Arr(docs)),
        ]);
        println!("{}", doc.dump());
    }
    Ok(())
}

/// `chaos --seed N`: run the deterministic fault-injection drills end
/// to end and exit nonzero if any resilience contract is violated. The
/// same seed drives `rust/tests/resilience.rs`, so a failure here
/// reproduces there bit-for-bit.
fn cmd_chaos(opts: &HashMap<String, String>) -> anyhow::Result<()> {
    use ehyb::autotune::{tune_with_fingerprint, PlanStore, TuneLevel};
    use ehyb::coordinator::service::{BatchKernel, SpmvService};
    use ehyb::resilience::{FaultInjector, FaultPlan, RetryPolicy};
    use ehyb::runtime::json::Json;
    use ehyb::sparse::coo::Coo;
    use ehyb::util::check::assert_allclose;
    use ehyb::{EhybError, GuardLevel};
    use std::sync::atomic::Ordering;
    use std::time::{Duration, Instant};

    let seed = opts.get("seed").and_then(|v| v.parse().ok()).unwrap_or(7u64);
    let plan = FaultPlan::from_seed(seed);
    println!("fault plan (seed {seed}): {}", plan.to_json().dump());
    let back = FaultPlan::from_json(&Json::parse(&plan.to_json().dump())?)?;
    anyhow::ensure!(back == plan, "fault plan JSON round-trip drifted");

    let m = build_matrix(opts)?;
    let cfg = preprocess_cfg(opts);
    let n = m.nrows();
    anyhow::ensure!(n == m.ncols(), "chaos drills need a square matrix");
    let ctx =
        SpmvContext::builder(m.clone()).engine(EngineKind::Ehyb).config(cfg.clone()).build()?;
    let x: Vec<f64> = (0..n).map(|i| ((i % 13) as f64) * 0.25 - 1.5).collect();
    let want = m.spmv_f64_oracle(&x);
    let allclose =
        |y: &[f64]| assert_allclose(y, &want, 1e-9, 1e-9).map_err(|e| anyhow::anyhow!(e));

    // Drill 1: panic isolation. The injector panics inside the kernel
    // on the plan's scheduled call; exactly that request gets the typed
    // fault, the engine respawns, and the next request is correct.
    let inj = FaultInjector::new(plan.clone());
    let panic_on = plan.panic_on_call.unwrap_or(1);
    let engine = ctx.engine_arc();
    let inj_kernel = inj.clone();
    let svc: SpmvService<f64> = SpmvService::spawn(
        move || {
            let engine = engine.clone();
            let fb = engine.format_bytes();
            let kernel: BatchKernel<f64> = Box::new(move |xs, ys| engine.spmv_batch(xs, ys));
            Ok((inj_kernel.wrap_kernel(kernel), fb))
        },
        n,
        8,
    )?;
    let client = svc.client();
    for _ in 1..panic_on {
        allclose(&client.spmv(x.clone())?)?;
    }
    match client.spmv(x.clone()) {
        Err(EhybError::EngineFault(msg)) => {
            println!("drill 1: kernel call {panic_on} -> typed EngineFault ({msg})");
        }
        other => anyhow::bail!("drill 1: expected EngineFault, got {other:?}"),
    }
    allclose(&client.spmv(x.clone())?)?;
    anyhow::ensure!(svc.metrics.faults.load(Ordering::Relaxed) == 1, "drill 1: fault not counted");
    anyhow::ensure!(svc.metrics.respawns.load(Ordering::Relaxed) == 1, "drill 1: no respawn");
    println!("drill 1: engine respawned; post-fault SpMV matches the oracle");

    // Drill 2: an already-expired deadline is triaged out with a typed
    // error at drain time, without occupying kernel width.
    match client.spmv_deadline(x.clone(), Instant::now() - Duration::from_millis(5)) {
        Err(EhybError::DeadlineExceeded) => {
            println!("drill 2: expired deadline -> typed DeadlineExceeded");
        }
        other => anyhow::bail!("drill 2: expected DeadlineExceeded, got {other:?}"),
    }
    anyhow::ensure!(
        svc.metrics.deadline_misses.load(Ordering::Relaxed) == 1,
        "drill 2: miss not counted"
    );

    // Drill 3: bounded retry/backoff recovers an injected fault on the
    // first kernel call — the caller never observes it.
    let inj_retry = FaultInjector::new(FaultPlan { panic_on_call: Some(1), ..plan.clone() });
    let engine = ctx.engine_arc();
    let svc2: SpmvService<f64> = SpmvService::spawn(
        move || {
            let engine = engine.clone();
            let fb = engine.format_bytes();
            let kernel: BatchKernel<f64> = Box::new(move |xs, ys| engine.spmv_batch(xs, ys));
            Ok((inj_retry.wrap_kernel(kernel), fb))
        },
        n,
        8,
    )?;
    let policy = RetryPolicy {
        max_attempts: 3,
        base_delay: Duration::from_micros(200),
        max_delay: Duration::from_millis(2),
        seed,
    };
    allclose(&svc2.client().spmv_with_retry(x.clone(), &policy)?)?;
    anyhow::ensure!(
        svc2.metrics.faults.load(Ordering::Relaxed) == 1
            && svc2.metrics.respawns.load(Ordering::Relaxed) == 1,
        "drill 3: retry path did not record exactly one fault + respawn"
    );
    println!("drill 3: retry recovered the injected fault (1 fault, 1 respawn, 0 caller errors)");

    // Drill 4: queue saturation. A gate holds the kernel open on a
    // depth-1 queue; the plan's whole flood sheds with typed
    // backpressure, and the accepted requests still complete correctly.
    let engine = ctx.engine_arc();
    let (started_tx, started_rx) = std::sync::mpsc::channel::<()>();
    let (gate_tx, gate_rx) = std::sync::mpsc::channel::<()>();
    let mut rig = Some((started_tx, gate_rx));
    let svc3: SpmvService<f64> = SpmvService::spawn_bounded(
        move || {
            let engine = engine.clone();
            let fb = engine.format_bytes();
            let (stx, grx) = rig.take().expect("gated rig builds one engine");
            let kernel: BatchKernel<f64> = Box::new(move |xs, ys| {
                stx.send(()).ok();
                grx.recv().ok();
                engine.spmv_batch(xs, ys)
            });
            Ok((kernel, fb))
        },
        n,
        8,
        1,
    )?;
    let c3 = svc3.client();
    let rx1 = c3.submit(x.clone())?;
    started_rx.recv()?;
    let rx2 = c3.submit(x.clone())?;
    let mut shed = 0u64;
    for _ in 0..plan.saturate_requests {
        if let Err((EhybError::Overloaded { .. }, _)) = c3.try_submit(x.clone()) {
            shed += 1;
        }
    }
    anyhow::ensure!(
        shed == plan.saturate_requests,
        "drill 4: only {shed}/{} flood requests shed",
        plan.saturate_requests
    );
    gate_tx.send(()).ok();
    gate_tx.send(()).ok();
    allclose(&rx1.recv()??)?;
    allclose(&rx2.recv()??)?;
    drop(gate_tx);
    println!("drill 4: {shed} flood requests shed with typed Overloaded; accepted ones correct");

    // Drill 5: NaN poisoning. Reject guard returns a typed error naming
    // the poisoned index; Monitor records the non-finite output.
    let nan_call = plan.nan_on_call.unwrap_or(1);
    let inj_nan = FaultInjector::new(FaultPlan { nan_on_call: Some(nan_call), ..plan.clone() });
    let mut xp = x.clone();
    let idx = inj_nan.poison(nan_call, &mut xp).expect("poison fires on its scheduled call");
    let rctx = SpmvContext::builder(m.clone())
        .engine(EngineKind::Ehyb)
        .config(cfg.clone())
        .guard(GuardLevel::Reject)
        .build()?;
    match rctx.spmv_alloc(&xp) {
        Err(EhybError::NonFinite { what: "x", index }) if index == idx => {
            println!("drill 5: NaN planted at x[{idx}] -> typed NonFinite (Reject guard)");
        }
        other => anyhow::bail!("drill 5: expected NonFinite at {idx}, got {other:?}"),
    }
    anyhow::ensure!(rctx.health().rejected_inputs == 1, "drill 5: rejection not recorded");
    let mctx = SpmvContext::builder(m.clone())
        .engine(EngineKind::CsrVector)
        .config(cfg.clone())
        .guard(GuardLevel::Monitor)
        .build()?;
    let y = mctx.spmv_alloc(&xp)?;
    anyhow::ensure!(y.iter().any(|v| v.is_nan()), "drill 5: NaN should propagate under Monitor");
    anyhow::ensure!(mctx.health().nonfinite_outputs >= 1, "drill 5: output NaN not recorded");
    println!("drill 5: Monitor guard recorded the non-finite output without failing the call");

    // Drill 6: a torn plan-cache entry is quarantined to `.bad` and a
    // fresh tune re-occupies the key.
    let dir = std::env::temp_dir().join(format!("ehyb-chaos-{seed}-{}", std::process::id()));
    std::fs::remove_dir_all(&dir).ok();
    let store = PlanStore::new(&dir);
    let out = tune_with_fingerprint(&m, &cfg, EngineKind::Ehyb, TuneLevel::Heuristic, None)?;
    let p = out.plan;
    let path = store.save(&p)?;
    anyhow::ensure!(inj.tear_file(&path)?, "drill 6: plan schedules no tear");
    anyhow::ensure!(
        store.load(&p.fingerprint, &p.device, &p.dtype, &p.scope).is_err(),
        "drill 6: torn entry must fail to load"
    );
    anyhow::ensure!(store.quarantines() == 1, "drill 6: tear not quarantined");
    anyhow::ensure!(
        store.load(&p.fingerprint, &p.device, &p.dtype, &p.scope)?.is_none(),
        "drill 6: quarantined key must read as a cold miss"
    );
    store.save(&p)?;
    anyhow::ensure!(
        store.load(&p.fingerprint, &p.device, &p.dtype, &p.scope)?.is_some(),
        "drill 6: fresh save must re-occupy the key"
    );
    std::fs::remove_dir_all(&dir).ok();
    println!("drill 6: torn plan-cache entry quarantined to .bad; fresh save re-occupied the key");

    // Drill 7: degraded-mode fallback. EHYB cannot build on a
    // non-square matrix; with fallback on, csr-vector serves instead
    // (recorded), and the degraded engine still computes correctly.
    let mut coo = Coo::<f64>::new(3, 4);
    coo.push(0, 0, 1.0);
    coo.push(0, 3, 2.0);
    coo.push(1, 1, 2.0);
    coo.push(2, 2, 2.0);
    let fctx =
        SpmvContext::builder(coo.to_csr()).engine(EngineKind::Ehyb).fallback(true).build()?;
    anyhow::ensure!(
        fctx.kind() == EngineKind::CsrVector && fctx.health().degraded(),
        "drill 7: fallback did not downgrade to csr-vector"
    );
    anyhow::ensure!(
        fctx.spmv_alloc(&[1.0; 4])? == vec![3.0, 2.0, 2.0],
        "drill 7: degraded engine computed a wrong answer"
    );
    println!("drill 7: failed EHYB build degraded to csr-vector (recorded in health)");

    // Drill 8: solver restart. CG diverges on a Jordan block; the
    // fallback restarts once as Jacobi-preconditioned BiCGSTAB, which
    // converges exactly to x = (-2, 1).
    let mut coo = Coo::<f64>::new(2, 2);
    coo.push(0, 0, 1.0);
    coo.push(0, 1, 2.0);
    coo.push(1, 1, 1.0);
    let sctx =
        SpmvContext::builder(coo.to_csr()).engine(EngineKind::CsrVector).fallback(true).build()?;
    let scfg = SolverConfig { divergence_window: 1, ..Default::default() };
    let (sol, rep) =
        sctx.solver().cg(&[0.0, 1.0], None, &ehyb::coordinator::precond::Identity, &scfg)?;
    anyhow::ensure!(
        rep.converged() && rep.solver == "bicgstab",
        "drill 8: restart did not converge: {rep:?}"
    );
    assert_allclose(&sol, &[-2.0, 1.0], 1e-10, 1e-10).map_err(|e| anyhow::anyhow!(e))?;
    anyhow::ensure!(sctx.health().solver_restarts == 1, "drill 8: restart not recorded");
    println!("drill 8: diverging CG restarted once as jacobi-bicgstab and converged");

    println!();
    println!("{}", report::service_markdown("Chaos service (drills 1-2)", &svc.metrics));
    println!("{}", report::health_markdown("Degraded context health (drill 7)", &fctx.health()));
    println!("chaos: all drills passed (seed {seed})");
    Ok(())
}

/// The seeded, fake-clock workload behind `stats` and `trace`: one
/// sharded EHYB build, a few served round-trips (plus one expired
/// deadline and one injected-fault request recovered by retry), and a
/// CG solve — every layer records into one [`ehyb::Telemetry`] handle.
/// The fake clock ticks once per observation and every round-trip is
/// serial, so two runs with the same seed produce identical snapshots.
fn telemetry_workload(seed: u64) -> anyhow::Result<ehyb::TelemetrySnapshot> {
    use ehyb::coordinator::service::{BatchKernel, SpmvService};
    use ehyb::resilience::{FaultInjector, FaultPlan, RetryPolicy};
    use ehyb::telemetry::Telemetry;
    use std::time::{Duration, Instant};

    let m = gen::poisson2d::<f64>(16, 16);
    let n = m.nrows();
    let ctx = SpmvContext::builder(m)
        .engine(EngineKind::Ehyb)
        .config(PreprocessConfig { vec_size_override: Some(64), ..Default::default() })
        .shards(ShardSpec::Count(2))
        .telemetry(Telemetry::with_fake_clock())
        .build()?;

    // A handful of serial round-trips (each one drains as a width-1
    // fused batch), plus one already-expired deadline triaged at drain.
    {
        let svc = ctx.serve(8)?;
        let client = svc.client();
        for r in 0..3u64 {
            let x: Vec<f64> = (0..n)
                .map(|i| ((i as u64).wrapping_mul(seed.wrapping_add(r)) % 17) as f64 * 0.25 - 2.0)
                .collect();
            let y = client.spmv(x)?;
            anyhow::ensure!(y.len() == n, "served reply has wrong length");
        }
        let expired = Instant::now() - Duration::from_millis(5);
        match client.spmv_deadline(vec![1.0; n], expired) {
            Err(ehyb::EhybError::DeadlineExceeded) => {}
            other => anyhow::bail!("expected DeadlineExceeded, got {other:?}"),
        }
    }

    // An injected engine panic on the first kernel call: attempt 1 ends
    // in a fault terminal event, the engine respawns, and the retry's
    // fresh trace links back via its `retry` event.
    {
        let inj = FaultInjector::new(FaultPlan {
            panic_on_call: Some(1),
            nan_on_call: None,
            ..FaultPlan::from_seed(seed)
        });
        let engine = ctx.engine_arc();
        let svc: SpmvService<f64> = SpmvService::spawn_with_telemetry(
            move || {
                let engine = engine.clone();
                let fb = engine.format_bytes();
                let kernel: BatchKernel<f64> = Box::new(move |xs, ys| engine.spmv_batch(xs, ys));
                Ok((inj.wrap_kernel(kernel), fb))
            },
            n,
            8,
            64,
            false,
            ctx.telemetry().clone(),
        )?;
        let policy = RetryPolicy {
            max_attempts: 3,
            base_delay: Duration::from_micros(50),
            max_delay: Duration::from_micros(400),
            seed,
        };
        let x: Vec<f64> = (0..n).map(|i| ((i % 13) as f64) * 0.25 - 1.5).collect();
        let y = svc.client().spmv_with_retry(x, &policy)?;
        anyhow::ensure!(y.len() == n, "retried reply has wrong length");
    }

    // One solve: a traced `solve.cg` span with per-iteration residual
    // events.
    let b: Vec<f64> = (0..n).map(|i| ((i as u64 % (seed % 5 + 3)) as f64) * 0.5 + 0.25).collect();
    let (_, rep) = ctx.solver().cg(&b, None, &Jacobi::new(ctx.matrix()), &SolverConfig::default())?;
    anyhow::ensure!(rep.converged(), "seeded solve should converge: {rep:?}");

    Ok(ctx.telemetry_snapshot())
}

/// `stats --seed N [--format md|json|prom]`: run the seeded workload
/// and print the full telemetry snapshot — markdown tables + span tree
/// by default, or either deterministic export format.
fn cmd_stats(opts: &HashMap<String, String>) -> anyhow::Result<()> {
    let seed = opts.get("seed").and_then(|v| v.parse().ok()).unwrap_or(7u64);
    let snap = telemetry_workload(seed)?;
    match opts.get("format").map(String::as_str).unwrap_or("md") {
        "md" => println!(
            "{}",
            report::telemetry_markdown(&format!("Telemetry (seed {seed})"), &snap)
        ),
        "json" => println!("{}", snap.to_json().dump()),
        "prom" => print!("{}", snap.to_prometheus()),
        other => anyhow::bail!("unknown --format {other} (md|json|prom)"),
    }
    Ok(())
}

/// `trace --seed N [--trace ID]`: run the seeded workload and replay
/// one request's whole story — submit, queue wait, the fused batch it
/// rode in (width + per-shard kernel spans), retry links, and its
/// terminal event — from a single snapshot. Defaults to the retried
/// request (the most eventful trace in the workload).
fn cmd_trace(opts: &HashMap<String, String>) -> anyhow::Result<()> {
    let seed = opts.get("seed").and_then(|v| v.parse().ok()).unwrap_or(7u64);
    let snap = telemetry_workload(seed)?;
    let known = snap.known_traces();
    anyhow::ensure!(!known.is_empty(), "workload recorded no traces");
    let trace = match opts.get("trace") {
        Some(v) => v.parse().map_err(|_| anyhow::anyhow!("bad --trace value {v}"))?,
        // The retry's fresh trace tells the richest story: its `retry`
        // event links back to the faulted first attempt.
        None => snap
            .events
            .iter()
            .find(|e| e.kind == "retry")
            .map(|e| e.trace)
            .unwrap_or(known[0]),
    };
    anyhow::ensure!(
        known.contains(&trace),
        "trace {trace} not in this snapshot (known: {known:?})"
    );
    println!("known traces: {known:?}\n");
    print!("{}", snap.describe_trace(trace));
    Ok(())
}
