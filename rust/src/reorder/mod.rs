//! Global matrix reordering (ISSUE 5 tentpole): locality-aware
//! symmetric row/column permutations applied **ahead of** the whole
//! pipeline, so everything downstream — the cache-aware shard
//! boundaries, the EHYB partitioner, the autotuner's fingerprint — sees
//! a matrix whose hot entries already sit near the diagonal.
//!
//! Akbudak, Kayaaslan & Aykanat ("Hypergraph-Partitioning-Based Models
//! and Methods for Exploiting Cache Locality in SpMV") show that a
//! locality-aware symmetric ordering shrinks the cache footprint of
//! exactly the SpMV access pattern EHYB explicitly caches; the OSKI
//! line of work puts reordering *inside* the tuning search rather than
//! hard-coding it. Both ideas land here:
//!
//! * [`ReorderSpec`] — the orderings: `None` (natural), `DegreeSort`
//!   (descending nnz/row), `Rcm` (reverse Cuthill–McKee over the
//!   symmetrized structure, component-safe), `PartitionRank` (rows
//!   ranked by a k-way [`crate::partition`] assignment whose parts are
//!   themselves Cuthill–McKee-ordered on the quotient graph, so
//!   strongly-coupled parts get adjacent ranks), and `Auto` (pick by
//!   scored footprint reduction).
//! * [`Reordering`] — a computed permutation (`perm[old] = new` + its
//!   inverse) with quality metrics **before and after**
//!   ([`ReorderQuality`]): bandwidth (max `|i − j|` over entries),
//!   profile (summed per-row index span), the average distinct-column
//!   footprint per [`FOOTPRINT_WINDOW`]-row window, and — since 0.7 —
//!   the **simulated x DRAM bytes** of a CSR walk under the ordering
//!   ([`crate::traffic::x_traffic_under`] on the reference
//!   [`GpuDevice::v100`] model), which is what `Auto` now ranks by:
//!   unlike the windowed proxy it sees sector granularity, L2
//!   capacity, and the eviction pressure of the matrix streams.
//! * [`ReorderedEngine`](engine::ReorderedEngine) — the
//!   [`crate::spmv::SpmvEngine`] adapter the facade wraps around the
//!   built engine: user-facing vectors stay in original index space,
//!   the permutation happens through pooled scratch at the boundary.
//!
//! The permuted matrix is produced by
//! [`Csr::permute_symmetric_stable`], which preserves each row's entry
//! order — so every row-local engine computes **bit-identical** per-row
//! FMA chains with reordering on (proptested in
//! `rust/tests/reorder.rs`); the global-layout engines (`ehyb`,
//! `merge`) re-derive their layouts and agree to roundoff.
//!
//! Callers normally reach this through the facade:
//! `SpmvContext::builder(m).reorder(ReorderSpec::Rcm).build()?` — see
//! [`crate::api::SpmvContextBuilder::reorder`].

pub mod engine;

pub use engine::ReorderedEngine;

use crate::gpu::device::GpuDevice;
use crate::partition::{partition_graph, Graph, PartitionConfig};
use crate::sparse::csr::Csr;
use crate::sparse::scalar::Scalar;
use std::collections::{BTreeMap, VecDeque};

/// Which global row/column ordering to apply ahead of the pipeline.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ReorderSpec {
    /// Keep the natural order (identity permutation).
    None,
    /// Rows by descending nnz (ties by index) — groups heavy rows, a
    /// cheap baseline for the ELL-family formats.
    DegreeSort,
    /// Reverse Cuthill–McKee over the symmetrized structure graph:
    /// BFS from a pseudo-peripheral start per component, neighbours by
    /// ascending degree, whole order reversed. The classic
    /// bandwidth/profile minimizer.
    Rcm,
    /// Rank rows by a k-way graph partition ([`crate::partition`]),
    /// with the parts Cuthill–McKee-ordered on the partition quotient
    /// graph so strongly-coupled parts receive adjacent ranks.
    /// `k = 0` picks a size-derived default.
    PartitionRank { k: usize },
    /// Compute every candidate ordering and keep the one with the
    /// lowest **simulated x DRAM traffic**
    /// ([`ReorderQuality::x_dram_bytes`], replayed through
    /// [`crate::traffic`]; ties by windowed footprint, then profile);
    /// falls back to the identity when nothing improves on it.
    Auto,
}

impl ReorderSpec {
    /// Stable lowercase tag ("none", "degree", "rcm", "partrank{k}",
    /// "auto") — used by CLI flags, reports, and (in resolved form) the
    /// plan-store provenance. Inverse of [`ReorderSpec::from_name`]
    /// modulo `PartitionRank`'s embedded k.
    pub fn tag(&self) -> String {
        match self {
            ReorderSpec::None => "none".into(),
            ReorderSpec::DegreeSort => "degree".into(),
            ReorderSpec::Rcm => "rcm".into(),
            ReorderSpec::PartitionRank { k } => format!("partrank{k}"),
            ReorderSpec::Auto => "auto".into(),
        }
    }

    /// Parse a CLI/report tag: `none | degree | rcm | auto |
    /// partrank[:K]` (`partrank` alone = size-derived k).
    pub fn from_name(name: &str) -> Option<ReorderSpec> {
        Some(match name {
            "none" => ReorderSpec::None,
            "degree" => ReorderSpec::DegreeSort,
            "rcm" => ReorderSpec::Rcm,
            "auto" => ReorderSpec::Auto,
            other => {
                let rest = other.strip_prefix("partrank")?;
                let k = match rest.strip_prefix(':').unwrap_or(rest) {
                    "" => 0,
                    digits => digits.parse().ok()?,
                };
                ReorderSpec::PartitionRank { k }
            }
        })
    }
}

/// Rows per window of the distinct-column footprint metric: roughly the
/// scale of one explicitly-cached x-slice, so the metric tracks how
/// many distinct x entries a cached partition's worth of rows touches.
pub const FOOTPRINT_WINDOW: usize = 256;

/// Locality metrics of one ordering of one matrix — lower is better on
/// every axis.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ReorderQuality {
    /// `max |i − j|` over stored entries (in the measured order).
    pub bandwidth: usize,
    /// Σ over rows of (max index − min index) touched by the row
    /// (row's own index included) — the envelope/profile measure.
    pub profile: u64,
    /// Average number of distinct columns referenced per
    /// [`FOOTPRINT_WINDOW`]-row window — the static cache-footprint
    /// proxy (what pre-0.7 `Auto` minimized; kept for reporting and
    /// tie-breaking).
    pub window_footprint: f64,
    /// Simulated x-vector DRAM bytes of one CSR SpMV walk under this
    /// ordering, replayed through the [`crate::traffic`] storage model
    /// on the reference [`GpuDevice::v100`] — the score
    /// [`ReorderSpec::Auto`] minimizes since 0.7.
    pub x_dram_bytes: u64,
}

impl ReorderQuality {
    /// Metrics of the natural (identity) order.
    pub fn of<S: Scalar>(m: &Csr<S>) -> ReorderQuality {
        let identity: Vec<u32> = (0..m.nrows() as u32).collect();
        quality_under(m, &identity)
    }
}

/// Metrics of `m` under `perm` (`perm[old] = new`) without
/// materializing the permuted matrix.
fn quality_under<S: Scalar>(m: &Csr<S>, perm: &[u32]) -> ReorderQuality {
    let n = m.nrows();
    debug_assert_eq!(perm.len(), n);
    let mut bandwidth = 0usize;
    let mut profile = 0u64;
    for i in 0..n {
        let (cols, _) = m.row(i);
        let ni = perm[i] as usize;
        let (mut lo, mut hi) = (ni, ni);
        for &c in cols {
            let nc = perm[c as usize] as usize;
            lo = lo.min(nc);
            hi = hi.max(nc);
            bandwidth = bandwidth.max(ni.abs_diff(nc));
        }
        profile += (hi - lo) as u64;
    }
    // Distinct columns per window of consecutive *new* rows: walk the
    // new order via the inverse permutation, stamping each column with
    // the window id that last touched it.
    let mut iperm = vec![0u32; n];
    for (old, &new) in perm.iter().enumerate() {
        iperm[new as usize] = old as u32;
    }
    let mut last_seen = vec![u64::MAX; n];
    let mut windows = 0u64;
    let mut distinct_total = 0u64;
    for w0 in (0..n).step_by(FOOTPRINT_WINDOW) {
        let wid = windows;
        windows += 1;
        for r in w0..(w0 + FOOTPRINT_WINDOW).min(n) {
            let (cols, _) = m.row(iperm[r] as usize);
            for &c in cols {
                let nc = perm[c as usize] as usize;
                if last_seen[nc] != wid {
                    last_seen[nc] = wid;
                    distinct_total += 1;
                }
            }
        }
    }
    // Replay one CSR SpMV under this ordering through the storage
    // simulator and keep the x-stream DRAM bytes — `iperm` is exactly
    // the new → old order `x_traffic_under` wants. Scored on the
    // canonical V100 model so the metric (like the others) is a
    // property of the ordering alone, not of the build's device config.
    let order: Vec<usize> = iperm.iter().map(|&v| v as usize).collect();
    let x_dram_bytes = crate::traffic::x_traffic_under(m, &order, &GpuDevice::v100());
    ReorderQuality {
        bandwidth,
        profile,
        window_footprint: distinct_total as f64 / windows.max(1) as f64,
        x_dram_bytes,
    }
}

/// A computed global ordering: the permutation pair plus before/after
/// quality metrics. Produced by [`Reordering::compute`], applied with
/// [`Reordering::apply`] (order-preserving symmetric permute), and
/// carried by the facade for reporting
/// ([`crate::api::SpmvContext::reordering`]).
#[derive(Clone, Debug)]
pub struct Reordering {
    /// The spec this reordering was requested as (may be `Auto`).
    pub spec: ReorderSpec,
    /// The concrete ordering that was chosen, as a stable tag
    /// ("none", "degree", "rcm", "partrank8"). For `Auto` this is the
    /// footprint-score winner; recorded in persisted tuned plans so
    /// cache entries key on what actually ran. **Normalized to
    /// "none" whenever the computed permutation is the identity** —
    /// the executed structure (and its fingerprint) is the natural
    /// one, so the provenance tag must say so, or identity-resolving
    /// reordered builds and plain builds would share one plan-store
    /// file while rejecting each other's entries.
    pub resolved: String,
    /// `perm[old] = new` — a bijection over the rows.
    pub perm: Vec<u32>,
    /// `iperm[new] = old`.
    pub iperm: Vec<u32>,
    /// Metrics of the natural order.
    pub before: ReorderQuality,
    /// Metrics under [`Self::perm`].
    pub after: ReorderQuality,
}

impl Reordering {
    /// Compute the ordering `spec` requests for the square matrix `m`.
    /// `Auto` scores every candidate by simulated x DRAM traffic (ties
    /// by windowed footprint, then profile) and keeps the winner — the
    /// identity included, so it never adopts an ordering that
    /// simulates worse than natural.
    pub fn compute<S: Scalar>(m: &Csr<S>, spec: ReorderSpec) -> crate::Result<Reordering> {
        crate::ensure!(
            m.nrows() == m.ncols() && m.nrows() > 0,
            "reordering requires a non-empty square matrix, got {}x{}",
            m.nrows(),
            m.ncols()
        );
        // One natural-order metrics pass, shared by every candidate an
        // `Auto` search scores (it is a full O(nnz + n) walk).
        let before = ReorderQuality::of(m);
        Self::compute_inner(m, spec, before)
    }

    fn compute_inner<S: Scalar>(
        m: &Csr<S>,
        spec: ReorderSpec,
        before: ReorderQuality,
    ) -> crate::Result<Reordering> {
        let n = m.nrows();
        if spec == ReorderSpec::Auto {
            let mut best = Self::compute_inner(m, ReorderSpec::None, before)?;
            for cand in
                [ReorderSpec::DegreeSort, ReorderSpec::Rcm, ReorderSpec::PartitionRank { k: 0 }]
            {
                let r = Self::compute_inner(m, cand, before)?;
                let better = (
                    r.after.x_dram_bytes,
                    r.after.window_footprint,
                    r.after.profile,
                ) < (
                    best.after.x_dram_bytes,
                    best.after.window_footprint,
                    best.after.profile,
                );
                if better {
                    best = r;
                }
            }
            return Ok(Reordering { spec, ..best });
        }
        let (order, resolved): (Vec<u32>, String) = match spec {
            ReorderSpec::None => ((0..n as u32).collect(), spec.tag()),
            ReorderSpec::DegreeSort => {
                let mut rows: Vec<u32> = (0..n as u32).collect();
                rows.sort_by_key(|&r| (std::cmp::Reverse(m.row_nnz(r as usize)), r));
                (rows, spec.tag())
            }
            ReorderSpec::Rcm => (rcm_order(&Graph::from_matrix_structure(m)), spec.tag()),
            ReorderSpec::PartitionRank { k } => {
                let (order, k) = partition_rank_order(m, k);
                (order, format!("partrank{k}"))
            }
            ReorderSpec::Auto => unreachable!("handled above"),
        };
        debug_assert_eq!(order.len(), n);
        let mut perm = vec![0u32; n];
        for (new, &old) in order.iter().enumerate() {
            perm[old as usize] = new as u32;
        }
        let identity = perm.iter().enumerate().all(|(old, &new)| old == new as usize);
        // See the `resolved` field doc: an identity outcome IS the
        // natural order, whatever spec produced it.
        let resolved = if identity { ReorderSpec::None.tag() } else { resolved };
        let after = if identity { before } else { quality_under(m, &perm) };
        Ok(Reordering { spec, resolved, perm, iperm: order, before, after })
    }

    /// Whether this is the identity permutation (nothing to apply).
    pub fn is_identity(&self) -> bool {
        self.perm.iter().enumerate().all(|(old, &new)| old == new as usize)
    }

    /// The permuted matrix `P A Pᵀ`, with each row's entry order
    /// preserved ([`Csr::permute_symmetric_stable`]) so row-local
    /// engines stay bit-identical.
    pub fn apply<S: Scalar>(&self, m: &Csr<S>) -> Csr<S> {
        m.permute_symmetric_stable(&self.perm)
    }

    /// Rows this reordering covers.
    pub fn len(&self) -> usize {
        self.perm.len()
    }

    pub fn is_empty(&self) -> bool {
        self.perm.is_empty()
    }
}

/// Cuthill–McKee order (new → old) with the reverse applied, over the
/// symmetrized structure graph. Component-safe: each connected
/// component (isolated vertices included) is swept from its own
/// pseudo-peripheral start; component starts are scanned from one
/// degree-sorted list so n isolated vertices cost O(n log n), not
/// O(n²).
fn rcm_order(g: &Graph) -> Vec<u32> {
    let n = g.nvtx();
    let mut order: Vec<u32> = Vec::with_capacity(n);
    let mut visited = vec![false; n];
    let mut seen = vec![0u64; n];
    let mut epoch = 0u64;
    let mut by_degree: Vec<u32> = (0..n as u32).collect();
    by_degree.sort_by_key(|&v| (g.degree(v as usize), v));
    let mut cursor = 0usize;
    let mut q: VecDeque<usize> = VecDeque::new();
    let mut nbrs: Vec<usize> = Vec::new();
    while order.len() < n {
        while visited[by_degree[cursor] as usize] {
            cursor += 1;
        }
        // Pseudo-peripheral start: two farthest-vertex sweeps from the
        // component's min-degree vertex (George–Liu style).
        let mut start = by_degree[cursor] as usize;
        for _ in 0..2 {
            epoch += 1;
            start = farthest_min_degree(g, start, &visited, &mut seen, epoch);
        }
        visited[start] = true;
        q.push_back(start);
        while let Some(v) = q.pop_front() {
            order.push(v as u32);
            nbrs.clear();
            nbrs.extend(g.neighbors(v).map(|(u, _)| u).filter(|&u| !visited[u]));
            nbrs.sort_by_key(|&u| (g.degree(u), u));
            for &u in &nbrs {
                visited[u] = true;
                q.push_back(u);
            }
        }
    }
    order.reverse(); // the R in RCM
    order
}

/// Min-degree vertex of the farthest BFS level from `start`, restricted
/// to unvisited vertices (the current component). `seen`/`epoch` are a
/// stamp array so repeated sweeps share one allocation.
fn farthest_min_degree(
    g: &Graph,
    start: usize,
    visited: &[bool],
    seen: &mut [u64],
    epoch: u64,
) -> usize {
    seen[start] = epoch;
    let mut level = vec![start];
    let mut best = start;
    while !level.is_empty() {
        best = *level.iter().min_by_key(|&&v| (g.degree(v), v)).expect("non-empty level");
        let mut next = Vec::new();
        for &v in &level {
            for (u, _) in g.neighbors(v) {
                if !visited[u] && seen[u] != epoch {
                    seen[u] = epoch;
                    next.push(u);
                }
            }
        }
        level = next;
    }
    best
}

/// Partition-rank order (new → old): rows grouped by a k-way partition
/// of the structure graph, parts ranked by Cuthill–McKee on the
/// quotient graph (so parts that exchange many entries sit at adjacent
/// ranks and their cross entries stay near the diagonal), rows stable
/// by original index within each part. Returns the order and the
/// resolved k.
fn partition_rank_order<S: Scalar>(m: &Csr<S>, k: usize) -> (Vec<u32>, usize) {
    let n = m.nrows();
    let k = if k == 0 { (n / 256).clamp(2, 1024).min(n.max(1)) } else { k.clamp(1, n.max(1)) };
    if k <= 1 {
        return ((0..n as u32).collect(), 1);
    }
    let g = Graph::from_matrix_structure(m);
    // Loose capacity: reordering wants locality, not tight balance.
    let cap = (n.div_ceil(k) + n.div_ceil(4 * k) + 1) as u64;
    let part = partition_graph(&g, k, cap, &PartitionConfig::default());
    // Quotient adjacency (BTreeMap for deterministic iteration).
    let mut adj: Vec<BTreeMap<u32, u64>> = vec![BTreeMap::new(); k];
    for i in 0..n {
        let (cols, _) = m.row(i);
        let a = part.assignment[i];
        for &c in cols {
            let b = part.assignment[c as usize];
            if a != b {
                *adj[a as usize].entry(b).or_insert(0) += 1;
                *adj[b as usize].entry(a).or_insert(0) += 1;
            }
        }
    }
    let rank = quotient_cm(&adj);
    let mut rows: Vec<u32> = (0..n as u32).collect();
    rows.sort_by_key(|&r| (rank[part.assignment[r as usize] as usize], r));
    (rows, k)
}

/// Weighted Cuthill–McKee over the quotient graph: part → rank. FIFO
/// BFS per component from the min-degree part; a part's unvisited
/// neighbours are enqueued by **descending coupling weight** (cross
/// entries shared with it, ties by ascending degree then id), so the
/// parts that exchange the most entries receive the closest ranks —
/// the property the row ordering then inherits.
fn quotient_cm(adj: &[BTreeMap<u32, u64>]) -> Vec<u32> {
    let k = adj.len();
    let mut rank = vec![u32::MAX; k];
    let mut next = 0u32;
    let mut by_deg: Vec<u32> = (0..k as u32).collect();
    by_deg.sort_by_key(|&p| (adj[p as usize].len(), p));
    let mut cursor = 0usize;
    let mut q: VecDeque<usize> = VecDeque::new();
    while (next as usize) < k {
        while rank[by_deg[cursor] as usize] != u32::MAX {
            cursor += 1;
        }
        let s = by_deg[cursor] as usize;
        rank[s] = next;
        next += 1;
        q.push_back(s);
        while let Some(p) = q.pop_front() {
            let mut nb: Vec<(u64, u32)> = adj[p]
                .iter()
                .filter(|&(&b, _)| rank[b as usize] == u32::MAX)
                .map(|(&b, &w)| (w, b))
                .collect();
            nb.sort_by_key(|&(w, b)| (std::cmp::Reverse(w), adj[b as usize].len(), b));
            for (_, b) in nb {
                rank[b as usize] = next;
                next += 1;
                q.push_back(b as usize);
            }
        }
    }
    rank
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::coo::Coo;
    use crate::sparse::gen::{banded, poisson2d, unstructured_mesh};
    use crate::util::Xoshiro256;

    /// A banded matrix hidden behind a random symmetric relabeling —
    /// the "locality exists but the natural order lost it" case every
    /// locality-aware ordering must recover.
    fn scrambled_banded(n: usize, bw: usize, seed: u64) -> Csr<f64> {
        let m = banded::<f64>(n, bw, 0.7, seed);
        let mut shuffle: Vec<u32> = (0..n as u32).collect();
        Xoshiro256::new(seed ^ 0xD1CE).shuffle(&mut shuffle);
        m.permute_symmetric_stable(&shuffle)
    }

    fn assert_bijection(perm: &[u32]) {
        let mut seen = vec![false; perm.len()];
        for &p in perm {
            assert!((p as usize) < perm.len(), "perm target {p} out of range");
            assert!(!seen[p as usize], "perm target {p} duplicated");
            seen[p as usize] = true;
        }
    }

    #[test]
    fn every_spec_yields_a_bijection() {
        let m = unstructured_mesh::<f64>(20, 20, 0.5, 7);
        for spec in [
            ReorderSpec::None,
            ReorderSpec::DegreeSort,
            ReorderSpec::Rcm,
            ReorderSpec::PartitionRank { k: 0 },
            ReorderSpec::PartitionRank { k: 7 },
            ReorderSpec::Auto,
        ] {
            let r = Reordering::compute(&m, spec).unwrap();
            assert_bijection(&r.perm);
            for (new, &old) in r.iperm.iter().enumerate() {
                assert_eq!(r.perm[old as usize] as usize, new, "{spec:?}: iperm mismatch");
            }
            assert_eq!(r.spec, spec);
        }
    }

    #[test]
    fn rcm_recovers_band_from_scrambled_matrix() {
        let m = scrambled_banded(1200, 6, 3);
        let r = Reordering::compute(&m, ReorderSpec::Rcm).unwrap();
        assert!(
            r.after.bandwidth * 4 < r.before.bandwidth,
            "rcm bandwidth {} vs natural {}",
            r.after.bandwidth,
            r.before.bandwidth
        );
        assert!(r.after.profile < r.before.profile);
        assert!(r.after.window_footprint < r.before.window_footprint);
    }

    #[test]
    fn partition_rank_improves_locality_on_hidden_mesh() {
        // The unstructured generator hides spatial locality behind
        // random labels; partition-rank must pull it back together.
        let m = unstructured_mesh::<f64>(40, 40, 0.3, 11);
        let r = Reordering::compute(&m, ReorderSpec::PartitionRank { k: 0 }).unwrap();
        assert!(r.resolved.starts_with("partrank"));
        assert!(
            r.after.bandwidth < r.before.bandwidth,
            "partrank bandwidth {} vs natural {}",
            r.after.bandwidth,
            r.before.bandwidth
        );
        assert!(r.after.window_footprint < r.before.window_footprint);
    }

    #[test]
    fn rcm_handles_disconnected_graphs_and_isolated_rows() {
        // Two blocks plus isolated diagonal-only rows: still a
        // bijection, every component swept.
        let mut coo = Coo::<f64>::new(20, 20);
        for i in 0..20 {
            coo.push(i, i, 2.0);
        }
        for i in 0..5usize {
            // chain 0-1-2-3-4
            if i + 1 < 5 {
                coo.push(i, i + 1, -1.0);
                coo.push(i + 1, i, -1.0);
            }
        }
        for i in 8..12usize {
            // chain 8..12
            if i + 1 < 12 {
                coo.push(i, i + 1, -1.0);
                coo.push(i + 1, i, -1.0);
            }
        }
        let m = coo.to_csr();
        let r = Reordering::compute(&m, ReorderSpec::Rcm).unwrap();
        assert_bijection(&r.perm);
        assert_eq!(r.len(), 20);
    }

    #[test]
    fn auto_never_scores_worse_than_natural() {
        for m in [poisson2d::<f64>(24, 24), scrambled_banded(800, 5, 9)] {
            let r = Reordering::compute(&m, ReorderSpec::Auto).unwrap();
            // Primary score: simulated x DRAM traffic; the tie-breaks
            // mean the windowed proxy can never regress either.
            assert!(r.after.x_dram_bytes <= r.before.x_dram_bytes);
            assert!(
                r.after.x_dram_bytes < r.before.x_dram_bytes
                    || r.after.window_footprint <= r.before.window_footprint
            );
            assert_eq!(r.spec, ReorderSpec::Auto);
            assert_ne!(r.resolved, "auto", "Auto must record the resolved ordering");
        }
        // On a scrambled banded matrix something locality-aware must win.
        let r = Reordering::compute(&scrambled_banded(800, 5, 9), ReorderSpec::Auto).unwrap();
        assert!(r.resolved == "rcm" || r.resolved.starts_with("partrank"), "{}", r.resolved);
    }

    #[test]
    fn identity_outcomes_normalize_their_resolved_tag_to_none() {
        // Rows already in descending-nnz order: DegreeSort computes the
        // identity. The resolved tag must say "none" — the executed
        // structure (and its tuning fingerprint) IS the natural one, so
        // a reordered and a plain build of this matrix must share plan
        // provenance instead of clobbering one store file forever.
        let mut coo = Coo::<f64>::new(4, 4);
        for i in 0..4usize {
            for j in 0..(4 - i) {
                coo.push(i, j, 1.0 + i as f64);
            }
        }
        let m = coo.to_csr();
        assert!((0..3).all(|i| m.row_nnz(i) >= m.row_nnz(i + 1)), "rows must start sorted");
        let r = Reordering::compute(&m, ReorderSpec::DegreeSort).unwrap();
        assert!(r.is_identity());
        assert_eq!(r.resolved, "none");
        assert_eq!(r.spec, ReorderSpec::DegreeSort);
        assert_eq!(r.before, r.after);
    }

    #[test]
    fn none_is_identity_with_equal_metrics() {
        let m = poisson2d::<f64>(10, 10);
        let r = Reordering::compute(&m, ReorderSpec::None).unwrap();
        assert!(r.is_identity());
        assert_eq!(r.before, r.after);
        assert_eq!(r.before, ReorderQuality::of(&m));
    }

    #[test]
    fn quality_matches_materialized_permutation() {
        // quality_under(m, perm) must equal ReorderQuality::of(P A Pt).
        let m = unstructured_mesh::<f64>(16, 16, 0.5, 5);
        let r = Reordering::compute(&m, ReorderSpec::Rcm).unwrap();
        let pm = r.apply(&m);
        let direct = ReorderQuality::of(&pm);
        assert_eq!(r.after.bandwidth, direct.bandwidth);
        assert_eq!(r.after.profile, direct.profile);
        assert!((r.after.window_footprint - direct.window_footprint).abs() < 1e-12);
        // The replayed permutation walk and the materialized permuted
        // matrix issue the same address stream (stable permute
        // preserves per-row entry order), so the simulated x traffic
        // matches exactly.
        assert_eq!(r.after.x_dram_bytes, direct.x_dram_bytes);
    }

    #[test]
    fn spec_tags_roundtrip() {
        for (spec, tag) in [
            (ReorderSpec::None, "none"),
            (ReorderSpec::DegreeSort, "degree"),
            (ReorderSpec::Rcm, "rcm"),
            (ReorderSpec::Auto, "auto"),
            (ReorderSpec::PartitionRank { k: 8 }, "partrank8"),
        ] {
            assert_eq!(spec.tag(), tag);
            assert_eq!(ReorderSpec::from_name(tag), Some(spec));
        }
        assert_eq!(
            ReorderSpec::from_name("partrank:16"),
            Some(ReorderSpec::PartitionRank { k: 16 })
        );
        assert_eq!(
            ReorderSpec::from_name("partrank"),
            Some(ReorderSpec::PartitionRank { k: 0 })
        );
        assert_eq!(ReorderSpec::from_name("zorder"), None);
    }

    #[test]
    fn rejects_non_square() {
        let m = Coo::<f64>::new(3, 4).to_csr();
        assert!(Reordering::compute(&m, ReorderSpec::Rcm).is_err());
    }
}
