//! The reorder boundary adapter: an engine built on the **permuted**
//! matrix presented in **original** index space. User-facing vectors
//! never see the permutation — `x` is permuted in and `y` permuted out
//! through pooled scratch ([`crate::util::pool::VecPool`]), so `cg` /
//! `cg_many` / the request-fusing service run unchanged on top.
//!
//! Per output row the inner engine computes exactly the permuted
//! matrix's row chain, and [`Csr::permute_symmetric_stable`] preserved
//! each row's entry order — so for row-local engine kinds the adapter's
//! output is bit-identical to the unreordered engine's (proptested in
//! `rust/tests/reorder.rs`).
//!
//! [`Csr::permute_symmetric_stable`]: crate::sparse::csr::Csr::permute_symmetric_stable

use super::Reordering;
use crate::api::batch::{VecBatch, VecBatchMut};
use crate::sparse::scalar::Scalar;
use crate::spmv::SpmvEngine;
use crate::util::pool::VecPool;
use std::sync::Arc;

/// [`SpmvEngine`] adapter around an engine prepared on the permuted
/// matrix: `spmv`/`spmv_batch` accept and produce vectors in original
/// index space. Built by the facade when
/// [`crate::api::SpmvContextBuilder::reorder`] resolved to a
/// non-identity ordering.
pub struct ReorderedEngine<S: Scalar> {
    inner: Arc<dyn SpmvEngine<S>>,
    r: Arc<Reordering>,
    /// Permuted-vector scratch (x side and y side share the pool).
    pool: VecPool<S>,
}

impl<S: Scalar> ReorderedEngine<S> {
    /// Wrap `inner` (prepared on `r.apply(matrix)`) so callers keep
    /// original index space. `inner` must be square with `r.len()`
    /// rows.
    pub fn new(inner: Arc<dyn SpmvEngine<S>>, r: Arc<Reordering>) -> ReorderedEngine<S> {
        assert_eq!(inner.nrows(), r.len(), "inner engine does not match the reordering");
        assert_eq!(inner.ncols(), r.len(), "reordered engines are square");
        // 2 buffers per in-flight spmv, 2 per batch; 8 tolerates a few
        // concurrent callers before reuse starts missing.
        ReorderedEngine { inner, r, pool: VecPool::new(8) }
    }

    /// The wrapped engine (runs in permuted index space).
    pub fn inner(&self) -> &Arc<dyn SpmvEngine<S>> {
        &self.inner
    }

    /// The ordering this adapter translates through.
    pub fn reordering(&self) -> &Reordering {
        &self.r
    }

    /// Scratch-pool misses (allocations/growth) — flat across repeated
    /// same-shape calls.
    pub fn scratch_misses(&self) -> u64 {
        self.pool.misses()
    }
}

impl<S: Scalar> SpmvEngine<S> for ReorderedEngine<S> {
    fn name(&self) -> &'static str {
        "reordered"
    }

    fn spmv(&self, x: &[S], y: &mut [S]) {
        let n = self.r.len();
        assert_eq!(x.len(), n);
        assert_eq!(y.len(), n);
        let perm = &self.r.perm;
        let mut xp = self.pool.take(n, S::ZERO);
        let mut yp = self.pool.take(n, S::ZERO);
        for (old, &v) in x.iter().enumerate() {
            xp[perm[old] as usize] = v;
        }
        self.inner.spmv(&xp, &mut yp);
        for (old, out) in y.iter_mut().enumerate() {
            *out = yp[perm[old] as usize];
        }
        self.pool.put(xp);
        self.pool.put(yp);
    }

    fn spmv_batch(&self, xs: VecBatch<'_, S>, ys: &mut VecBatchMut<'_, S>) {
        assert_eq!(xs.width(), ys.width(), "batch inputs/outputs disagree");
        let n = self.r.len();
        assert_eq!(xs.n(), n);
        assert_eq!(ys.n(), n);
        let width = xs.width();
        if width == 0 {
            return;
        }
        let perm = &self.r.perm;
        let mut xp = self.pool.take(n * width, S::ZERO);
        let mut yp = self.pool.take(n * width, S::ZERO);
        for b in 0..width {
            let (src, dst) = (xs.col(b), &mut xp[b * n..(b + 1) * n]);
            for (old, &v) in src.iter().enumerate() {
                dst[perm[old] as usize] = v;
            }
        }
        {
            let xv = VecBatch::new(&xp, n).expect("contiguous reorder scratch");
            let mut yv = VecBatchMut::new(&mut yp, n).expect("contiguous reorder scratch");
            self.inner.spmv_batch(xv, &mut yv);
        }
        for b in 0..width {
            let (src, dst) = (&yp[b * n..(b + 1) * n], ys.col_mut(b));
            for (old, out) in dst.iter_mut().enumerate() {
                *out = src[perm[old] as usize];
            }
        }
        self.pool.put(xp);
        self.pool.put(yp);
    }

    fn nrows(&self) -> usize {
        self.inner.nrows()
    }
    fn ncols(&self) -> usize {
        self.inner.ncols()
    }
    fn nnz(&self) -> usize {
        self.inner.nnz()
    }
    fn format_bytes(&self) -> usize {
        // The permutation pair rides along with the format.
        self.inner.format_bytes() + 2 * 4 * self.r.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::{build_engine, BatchBuf, EngineKind};
    use crate::reorder::ReorderSpec;
    use crate::sparse::gen::unstructured_mesh;

    #[test]
    fn adapter_is_bitwise_for_a_row_local_engine() {
        let m = unstructured_mesh::<f64>(20, 20, 0.5, 13);
        let r = Arc::new(Reordering::compute(&m, ReorderSpec::Rcm).unwrap());
        let pm = r.apply(&m);
        let plain = build_engine::<f64>(EngineKind::CsrScalar, &m, None);
        let wrapped =
            ReorderedEngine::new(build_engine::<f64>(EngineKind::CsrScalar, &pm, None), r);
        let n = m.nrows();
        let x: Vec<f64> = (0..n).map(|i| ((i * 7 + 3) % 13) as f64 * 0.5 - 3.0).collect();
        let mut y0 = vec![0.0; n];
        let mut y1 = vec![0.0; n];
        plain.spmv(&x, &mut y0);
        wrapped.spmv(&x, &mut y1);
        assert_eq!(y0, y1, "stable permute + adapter must be bitwise for row-local engines");
        // Batch path matches repeated single calls bitwise.
        let mut xs = BatchBuf::<f64>::zeros(n, 3);
        for b in 0..3 {
            for i in 0..n {
                xs.col_mut(b)[i] = ((i * 5 + b * 11 + 1) % 17) as f64 * 0.25 - 2.0;
            }
        }
        let mut ys = BatchBuf::<f64>::zeros(n, 3);
        {
            let mut yv = ys.view_mut();
            wrapped.spmv_batch(xs.view(), &mut yv);
        }
        for b in 0..3 {
            let mut y1 = vec![0.0; n];
            wrapped.spmv(xs.col(b), &mut y1);
            assert_eq!(ys.col(b), &y1[..], "lane {b}");
        }
    }

    #[test]
    fn scratch_pool_reaches_steady_state() {
        let m = unstructured_mesh::<f64>(16, 16, 0.4, 3);
        let r = Arc::new(Reordering::compute(&m, ReorderSpec::Rcm).unwrap());
        let pm = r.apply(&m);
        let e = ReorderedEngine::new(build_engine::<f64>(EngineKind::CsrScalar, &pm, None), r);
        let n = m.nrows();
        let x = vec![1.0; n];
        let mut y = vec![0.0; n];
        e.spmv(&x, &mut y);
        let after_first = e.scratch_misses();
        for _ in 0..16 {
            e.spmv(&x, &mut y);
        }
        assert_eq!(e.scratch_misses(), after_first, "steady-state spmv must not allocate");
    }
}
