//! The reorder boundary adapter: an engine built on the **permuted**
//! matrix presented in **original** index space. User-facing vectors
//! never see the permutation — `x` is permuted in and `y` permuted out
//! through pooled scratch ([`crate::util::pool::VecPool`]), so `cg` /
//! `cg_many` / the request-fusing service run unchanged on top.
//!
//! Per output row the inner engine computes exactly the permuted
//! matrix's row chain, and [`Csr::permute_symmetric_stable`] preserved
//! each row's entry order — so for row-local engine kinds the adapter's
//! output is bit-identical to the unreordered engine's (proptested in
//! `rust/tests/reorder.rs`).
//!
//! **Gather fusion** (0.9): when the inner engine exposes its own
//! internal permutation through [`PermutedSpmv`] (EHYB permutes every
//! vector into its partitioned new order), the adapter composes both
//! permutations into precomputed index maps at construction, so a call
//! performs **one** gather into kernel order and **one** gather out —
//! instead of the 0.8 two-pass route (adapter permute + engine-internal
//! permute, an intermediate n-vector per side). The kernel input values
//! and the kernel itself are unchanged, so fusion is bit-identical to
//! the two-pass path ([`ReorderedEngine::with_fusion`] keeps the 0.8
//! route callable; `rust/tests/reorder.rs` pins the equivalence).
//!
//! [`Csr::permute_symmetric_stable`]: crate::sparse::csr::Csr::permute_symmetric_stable

use super::Reordering;
use crate::api::batch::{VecBatch, VecBatchMut};
use crate::sparse::scalar::Scalar;
use crate::spmv::{PermutedSpmv, SpmvEngine};
use crate::util::pool::VecPool;
use std::sync::Arc;

/// Padding marker in [`FusedMaps::in_map`]: kernel slots that feed from
/// no original x entry (EHYB's padded rows) load zero.
const FUSE_PAD: u32 = u32::MAX;

/// Composed permutation maps for the fused path. With `r` the outer
/// reordering (`perm[old] = mid`) and `k` the engine's internal
/// permutation (`perm[mid] = q`, padded):
/// `in_map[q] = r.iperm[k.iperm[q]]` and `out_map[old] = k.perm[r.perm[old]]`.
struct FusedMaps {
    /// Original x index feeding kernel slot `q` (or [`FUSE_PAD`]).
    in_map: Vec<u32>,
    /// Kernel slot holding the result for original row `old`.
    out_map: Vec<u32>,
    /// Kernel-order vector length (`inner.permuted_kernel().padded_len()`).
    padded: usize,
}

/// [`SpmvEngine`] adapter around an engine prepared on the permuted
/// matrix: `spmv`/`spmv_batch` accept and produce vectors in original
/// index space. Built by the facade when
/// [`crate::api::SpmvContextBuilder::reorder`] resolved to a
/// non-identity ordering.
pub struct ReorderedEngine<S: Scalar> {
    inner: Arc<dyn SpmvEngine<S>>,
    r: Arc<Reordering>,
    /// Permuted-vector scratch (x side and y side share the pool).
    pool: VecPool<S>,
    /// Composed gather maps — `Some` iff fusion was requested and the
    /// inner engine exposes a [`PermutedSpmv`] kernel.
    fused: Option<FusedMaps>,
}

impl<S: Scalar> ReorderedEngine<S> {
    /// Wrap `inner` (prepared on `r.apply(matrix)`) so callers keep
    /// original index space. `inner` must be square with `r.len()`
    /// rows. Permute fusion engages automatically when the inner
    /// engine exposes its internal permutation.
    pub fn new(inner: Arc<dyn SpmvEngine<S>>, r: Arc<Reordering>) -> ReorderedEngine<S> {
        Self::with_fusion(inner, r, true)
    }

    /// [`Self::new`] with an explicit fusion switch. `fuse = false`
    /// forces the 0.8 two-pass route (adapter gather + engine-internal
    /// permute) — kept callable so the fused path can be tested and
    /// benched against its bitwise-equal baseline.
    pub fn with_fusion(
        inner: Arc<dyn SpmvEngine<S>>,
        r: Arc<Reordering>,
        fuse: bool,
    ) -> ReorderedEngine<S> {
        assert_eq!(inner.nrows(), r.len(), "inner engine does not match the reordering");
        assert_eq!(inner.ncols(), r.len(), "reordered engines are square");
        let fused = if fuse { Self::compose_maps(inner.as_ref(), &r) } else { None };
        // 2 buffers per in-flight spmv, 2 per batch; 8 tolerates a few
        // concurrent callers before reuse starts missing.
        ReorderedEngine { inner, r, pool: VecPool::new(8), fused }
    }

    /// Compose the outer reordering with the engine's internal
    /// permutation into one gather map per side. Returns `None` (two-
    /// pass fallback) when the engine has no permuted kernel or its
    /// permutation shape is inconsistent.
    fn compose_maps(inner: &dyn SpmvEngine<S>, r: &Reordering) -> Option<FusedMaps> {
        let k = inner.permuted_kernel()?;
        let n = r.len();
        let padded = k.padded_len();
        let (kperm, kiperm) = (k.inner_perm(), k.inner_iperm());
        if kperm.len() != n || kiperm.len() != padded || padded < n {
            return None;
        }
        let mut in_map = vec![FUSE_PAD; padded];
        for (q, &mid) in kiperm.iter().enumerate() {
            if (mid as usize) < n {
                in_map[q] = r.iperm[mid as usize];
            }
        }
        let out_map: Vec<u32> = (0..n).map(|old| kperm[r.perm[old] as usize]).collect();
        // The maps are total over their domains by construction; the
        // gathers below index with them unchecked-free (plain indexing
        // panics on a malformed engine permutation, as permute_in did).
        Some(FusedMaps { in_map, out_map, padded })
    }

    /// The wrapped engine (runs in permuted index space).
    pub fn inner(&self) -> &Arc<dyn SpmvEngine<S>> {
        &self.inner
    }

    /// The ordering this adapter translates through.
    pub fn reordering(&self) -> &Reordering {
        &self.r
    }

    /// True when calls run the fused single-gather path.
    pub fn is_fused(&self) -> bool {
        self.fused.is_some()
    }

    /// Scratch-pool misses (allocations/growth) — flat across repeated
    /// same-shape calls.
    pub fn scratch_misses(&self) -> u64 {
        self.pool.misses()
    }
}

impl<S: Scalar> SpmvEngine<S> for ReorderedEngine<S> {
    fn name(&self) -> &'static str {
        "reordered"
    }

    fn spmv(&self, x: &[S], y: &mut [S]) {
        let n = self.r.len();
        assert_eq!(x.len(), n);
        assert_eq!(y.len(), n);
        if let Some(f) = &self.fused {
            // One gather per side straight between original index
            // space and the kernel's padded order — no intermediate
            // mid-order vector.
            let k = self.inner.permuted_kernel().expect("fused maps imply a permuted kernel");
            let mut xq = self.pool.take(f.padded, S::ZERO);
            let mut yq = self.pool.take(f.padded, S::ZERO);
            for (slot, &src) in xq.iter_mut().zip(&f.in_map) {
                *slot = if src == FUSE_PAD { S::ZERO } else { x[src as usize] };
            }
            k.spmv_permuted(&xq, &mut yq);
            for (out, &q) in y.iter_mut().zip(&f.out_map) {
                *out = yq[q as usize];
            }
            self.pool.put(xq);
            self.pool.put(yq);
            return;
        }
        let perm = &self.r.perm;
        let mut xp = self.pool.take(n, S::ZERO);
        let mut yp = self.pool.take(n, S::ZERO);
        for (old, &v) in x.iter().enumerate() {
            xp[perm[old] as usize] = v;
        }
        self.inner.spmv(&xp, &mut yp);
        for (old, out) in y.iter_mut().enumerate() {
            *out = yp[perm[old] as usize];
        }
        self.pool.put(xp);
        self.pool.put(yp);
    }

    fn spmv_batch(&self, xs: VecBatch<'_, S>, ys: &mut VecBatchMut<'_, S>) {
        assert_eq!(xs.width(), ys.width(), "batch inputs/outputs disagree");
        let n = self.r.len();
        assert_eq!(xs.n(), n);
        assert_eq!(ys.n(), n);
        let width = xs.width();
        if width == 0 {
            return;
        }
        if let Some(f) = &self.fused {
            let k = self.inner.permuted_kernel().expect("fused maps imply a permuted kernel");
            let padded = f.padded;
            let mut xq = self.pool.take(padded * width, S::ZERO);
            let mut yq = self.pool.take(padded * width, S::ZERO);
            for b in 0..width {
                let src = xs.col(b);
                let dst = &mut xq[b * padded..(b + 1) * padded];
                for (slot, &m) in dst.iter_mut().zip(&f.in_map) {
                    *slot = if m == FUSE_PAD { S::ZERO } else { src[m as usize] };
                }
            }
            {
                let xcols: Vec<&[S]> = xq.chunks(padded).collect();
                let mut ycols: Vec<&mut [S]> = yq.chunks_mut(padded).collect();
                k.spmv_batch_permuted(&xcols, &mut ycols);
            }
            for b in 0..width {
                let src = &yq[b * padded..(b + 1) * padded];
                for (out, &q) in ys.col_mut(b).iter_mut().zip(&f.out_map) {
                    *out = src[q as usize];
                }
            }
            self.pool.put(xq);
            self.pool.put(yq);
            return;
        }
        let perm = &self.r.perm;
        let mut xp = self.pool.take(n * width, S::ZERO);
        let mut yp = self.pool.take(n * width, S::ZERO);
        for b in 0..width {
            let (src, dst) = (xs.col(b), &mut xp[b * n..(b + 1) * n]);
            for (old, &v) in src.iter().enumerate() {
                dst[perm[old] as usize] = v;
            }
        }
        {
            let xv = VecBatch::new(&xp, n).expect("contiguous reorder scratch");
            let mut yv = VecBatchMut::new(&mut yp, n).expect("contiguous reorder scratch");
            self.inner.spmv_batch(xv, &mut yv);
        }
        for b in 0..width {
            let (src, dst) = (&yp[b * n..(b + 1) * n], ys.col_mut(b));
            for (old, out) in dst.iter_mut().enumerate() {
                *out = src[perm[old] as usize];
            }
        }
        self.pool.put(xp);
        self.pool.put(yp);
    }

    fn nrows(&self) -> usize {
        self.inner.nrows()
    }
    fn ncols(&self) -> usize {
        self.inner.ncols()
    }
    fn nnz(&self) -> usize {
        self.inner.nnz()
    }
    fn format_bytes(&self) -> usize {
        // The permutation pair rides along with the format.
        self.inner.format_bytes() + 2 * 4 * self.r.len()
    }
    fn kernel_profile(&self) -> Option<crate::profile::KernelProfile> {
        // Both routes land in the inner engine's counters: the fused
        // path drives its permuted kernel (which records), the two-pass
        // path calls `inner.spmv` directly.
        self.inner.kernel_profile()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::{build_engine, BatchBuf, EngineKind};
    use crate::reorder::ReorderSpec;
    use crate::sparse::gen::unstructured_mesh;

    #[test]
    fn adapter_is_bitwise_for_a_row_local_engine() {
        let m = unstructured_mesh::<f64>(20, 20, 0.5, 13);
        let r = Arc::new(Reordering::compute(&m, ReorderSpec::Rcm).unwrap());
        let pm = r.apply(&m);
        let plain = build_engine::<f64>(EngineKind::CsrScalar, &m, None);
        let wrapped =
            ReorderedEngine::new(build_engine::<f64>(EngineKind::CsrScalar, &pm, None), r);
        let n = m.nrows();
        let x: Vec<f64> = (0..n).map(|i| ((i * 7 + 3) % 13) as f64 * 0.5 - 3.0).collect();
        let mut y0 = vec![0.0; n];
        let mut y1 = vec![0.0; n];
        plain.spmv(&x, &mut y0);
        wrapped.spmv(&x, &mut y1);
        assert_eq!(y0, y1, "stable permute + adapter must be bitwise for row-local engines");
        // Batch path matches repeated single calls bitwise.
        let mut xs = BatchBuf::<f64>::zeros(n, 3);
        for b in 0..3 {
            for i in 0..n {
                xs.col_mut(b)[i] = ((i * 5 + b * 11 + 1) % 17) as f64 * 0.25 - 2.0;
            }
        }
        let mut ys = BatchBuf::<f64>::zeros(n, 3);
        {
            let mut yv = ys.view_mut();
            wrapped.spmv_batch(xs.view(), &mut yv);
        }
        for b in 0..3 {
            let mut y1 = vec![0.0; n];
            wrapped.spmv(xs.col(b), &mut y1);
            assert_eq!(ys.col(b), &y1[..], "lane {b}");
        }
    }

    #[test]
    fn fusion_engages_only_for_permuted_kernels() {
        let m = unstructured_mesh::<f64>(20, 20, 0.5, 13);
        let r = Arc::new(Reordering::compute(&m, ReorderSpec::Rcm).unwrap());
        let pm = r.apply(&m);
        let plain = ReorderedEngine::new(build_engine::<f64>(EngineKind::CsrScalar, &pm, None), r.clone());
        assert!(!plain.is_fused(), "csr-scalar has no internal permutation to fuse");
        let plan = crate::preprocess::EhybPlan::build(&pm, &Default::default()).unwrap();
        let ehyb: Arc<dyn crate::spmv::SpmvEngine<f64>> =
            Arc::new(crate::spmv::ehyb_cpu::EhybCpu::new(&plan));
        let fused = ReorderedEngine::new(ehyb.clone(), r.clone());
        assert!(fused.is_fused(), "EHYB inner must engage gather fusion");
        assert!(!ReorderedEngine::with_fusion(ehyb, r, false).is_fused());
    }

    #[test]
    fn fused_path_bitwise_equals_two_pass() {
        // The composed-gather route must reproduce the 0.8 two-pass
        // adapter bit-for-bit: identical kernel inputs, identical
        // kernel, pure copies on the way out.
        let m = unstructured_mesh::<f64>(24, 24, 0.6, 7);
        let n = m.nrows();
        for spec in [ReorderSpec::Rcm, ReorderSpec::PartitionRank { k: 0 }] {
            let r = Arc::new(Reordering::compute(&m, spec).unwrap());
            let pm = r.apply(&m);
            let plan = crate::preprocess::EhybPlan::build(&pm, &Default::default()).unwrap();
            let inner: Arc<dyn crate::spmv::SpmvEngine<f64>> =
                Arc::new(crate::spmv::ehyb_cpu::EhybCpu::new(&plan));
            let fused = ReorderedEngine::new(inner.clone(), r.clone());
            let twopass = ReorderedEngine::with_fusion(inner, r, false);
            assert!(fused.is_fused() && !twopass.is_fused());
            let x: Vec<f64> = (0..n).map(|i| ((i * 11 + 5) % 23) as f64 * 0.25 - 2.5).collect();
            let mut y_fused = vec![0.0; n];
            let mut y_two = vec![0.0; n];
            fused.spmv(&x, &mut y_fused);
            twopass.spmv(&x, &mut y_two);
            assert_eq!(y_fused, y_two, "spmv diverged under {spec:?}");
            // Batch path too (drives spmv_batch_permuted / blocked SpMM).
            let mut xs = BatchBuf::<f64>::zeros(n, 3);
            for b in 0..3 {
                for i in 0..n {
                    xs.col_mut(b)[i] = ((i * 3 + b * 17 + 1) % 19) as f64 * 0.5 - 4.0;
                }
            }
            let mut ys_f = BatchBuf::<f64>::zeros(n, 3);
            let mut ys_t = BatchBuf::<f64>::zeros(n, 3);
            {
                let mut yv = ys_f.view_mut();
                fused.spmv_batch(xs.view(), &mut yv);
            }
            {
                let mut yv = ys_t.view_mut();
                twopass.spmv_batch(xs.view(), &mut yv);
            }
            for b in 0..3 {
                assert_eq!(ys_f.col(b), ys_t.col(b), "batch lane {b} under {spec:?}");
            }
        }
    }

    #[test]
    fn fused_scratch_pool_reaches_steady_state() {
        let m = unstructured_mesh::<f64>(16, 16, 0.4, 3);
        let r = Arc::new(Reordering::compute(&m, ReorderSpec::Rcm).unwrap());
        let pm = r.apply(&m);
        let plan = crate::preprocess::EhybPlan::build(&pm, &Default::default()).unwrap();
        let e = ReorderedEngine::new(Arc::new(crate::spmv::ehyb_cpu::EhybCpu::new(&plan)), r);
        assert!(e.is_fused());
        let n = m.nrows();
        let x = vec![1.0; n];
        let mut y = vec![0.0; n];
        e.spmv(&x, &mut y);
        let after_first = e.scratch_misses();
        for _ in 0..16 {
            e.spmv(&x, &mut y);
        }
        assert_eq!(e.scratch_misses(), after_first, "steady-state fused spmv must not allocate");
    }

    #[test]
    fn scratch_pool_reaches_steady_state() {
        let m = unstructured_mesh::<f64>(16, 16, 0.4, 3);
        let r = Arc::new(Reordering::compute(&m, ReorderSpec::Rcm).unwrap());
        let pm = r.apply(&m);
        let e = ReorderedEngine::new(build_engine::<f64>(EngineKind::CsrScalar, &pm, None), r);
        let n = m.nrows();
        let x = vec![1.0; n];
        let mut y = vec![0.0; n];
        e.spmv(&x, &mut y);
        let after_first = e.scratch_misses();
        for _ in 0..16 {
            e.spmv(&x, &mut y);
        }
        assert_eq!(e.scratch_misses(), after_first, "steady-state spmv must not allocate");
    }
}
