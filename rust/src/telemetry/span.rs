//! Structured spans and point events, recorded into bounded rings.
//!
//! A span is one timed region with a parent link (0 = root) and an
//! optional trace tag; the tree is reconstructed from the flat records
//! at snapshot time. Completed spans land in insertion (= completion)
//! order; snapshots re-sort by `(start_nanos, id)` so parents precede
//! their children in the exported list.

use super::{lock, Telemetry, TraceId};
use std::collections::VecDeque;
use std::sync::Mutex;

/// One completed span.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct SpanRecord {
    /// Sequential id (from 1) on the owning [`Telemetry`] handle.
    pub id: u64,
    /// Parent span id; 0 = root.
    pub parent: u64,
    /// Trace tag ([`TraceId`]); 0 = untraced.
    pub trace: u64,
    pub name: String,
    pub start_nanos: u64,
    pub end_nanos: u64,
}

impl SpanRecord {
    pub fn duration_nanos(&self) -> u64 {
        self.end_nanos - self.start_nanos
    }
}

/// One point event (submit / reply / shed / deadline / fault / retry /
/// respawn / solver-iter / health / …).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct EventRecord {
    pub nanos: u64,
    /// Trace tag; 0 = untraced.
    pub trace: u64,
    pub kind: String,
    pub detail: String,
}

/// Bounded FIFO ring: pushing past capacity evicts the oldest record
/// and counts it, so a long-running service keeps the newest window.
struct Ring<T> {
    cap: usize,
    inner: Mutex<(VecDeque<T>, u64)>,
}

impl<T: Clone> Ring<T> {
    fn new(cap: usize) -> Self {
        Ring { cap, inner: Mutex::new((VecDeque::new(), 0)) }
    }

    fn push(&self, item: T) {
        let mut g = lock(&self.inner);
        if g.0.len() == self.cap {
            g.0.pop_front();
            g.1 += 1;
        }
        g.0.push_back(item);
    }

    fn snapshot(&self) -> (Vec<T>, u64) {
        let g = lock(&self.inner);
        (g.0.iter().cloned().collect(), g.1)
    }
}

pub(crate) struct SpanRing(Ring<SpanRecord>);

impl SpanRing {
    pub(crate) fn new(cap: usize) -> Self {
        SpanRing(Ring::new(cap))
    }

    pub(crate) fn push(&self, rec: SpanRecord) {
        self.0.push(rec);
    }

    /// `(records sorted by (start, id), evicted count)`.
    pub(crate) fn snapshot(&self) -> (Vec<SpanRecord>, u64) {
        let (mut v, dropped) = self.0.snapshot();
        v.sort_by_key(|s| (s.start_nanos, s.id));
        (v, dropped)
    }
}

pub(crate) struct EventRing(Ring<EventRecord>);

impl EventRing {
    pub(crate) fn new(cap: usize) -> Self {
        EventRing(Ring::new(cap))
    }

    pub(crate) fn push(&self, rec: EventRecord) {
        self.0.push(rec);
    }

    /// `(records in recording order, evicted count)`.
    pub(crate) fn snapshot(&self) -> (Vec<EventRecord>, u64) {
        self.0.snapshot()
    }
}

/// RAII guard for an open span: created by [`Telemetry::span`] /
/// [`Telemetry::span_traced`], records on drop (or explicit
/// [`SpanGuard::finish`]) and restores the handle's implicit parent.
/// Guards are expected to close LIFO (natural scoping); an out-of-order
/// close only skews later parent inference, never loses a record.
pub struct SpanGuard {
    tel: Telemetry,
    id: u64,
    parent: u64,
    trace: TraceId,
    name: String,
    start: u64,
    finished: bool,
}

impl SpanGuard {
    pub(crate) fn new(
        tel: Telemetry,
        id: u64,
        parent: u64,
        trace: TraceId,
        name: String,
        start: u64,
    ) -> Self {
        SpanGuard { tel, id, parent, trace, name, start, finished: false }
    }

    pub fn id(&self) -> u64 {
        self.id
    }

    pub fn trace(&self) -> TraceId {
        self.trace
    }

    /// Open a child span inheriting this guard's trace tag. (Any span
    /// opened while this guard is innermost is parented here anyway;
    /// `child` just also propagates the trace.)
    pub fn child(&self, name: impl Into<String>) -> SpanGuard {
        self.tel.span_traced(name, self.trace)
    }

    /// Close now instead of at end of scope.
    pub fn finish(mut self) {
        self.close();
    }

    fn close(&mut self) {
        if self.finished {
            return;
        }
        self.finished = true;
        let end = self.tel.now_nanos();
        self.tel.close_span(
            SpanRecord {
                id: self.id,
                parent: self.parent,
                trace: self.trace.0,
                name: std::mem::take(&mut self.name),
                start_nanos: self.start,
                end_nanos: end.max(self.start),
            },
            self.parent,
        );
    }
}

impl Drop for SpanGuard {
    fn drop(&mut self) {
        self.close();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ring_evicts_oldest_and_counts() {
        let r = SpanRing::new(2);
        for i in 1..=3u64 {
            r.push(SpanRecord {
                id: i,
                parent: 0,
                trace: 0,
                name: format!("s{i}"),
                start_nanos: i,
                end_nanos: i + 1,
            });
        }
        let (v, dropped) = r.snapshot();
        assert_eq!(dropped, 1);
        assert_eq!(v.iter().map(|s| s.id).collect::<Vec<_>>(), vec![2, 3]);
    }

    #[test]
    fn snapshot_sorts_by_start_then_id() {
        let r = SpanRing::new(8);
        // Completion order: child first (guards close inside-out), but
        // the parent started earlier and must sort first.
        r.push(SpanRecord {
            id: 2,
            parent: 1,
            trace: 0,
            name: "child".into(),
            start_nanos: 5,
            end_nanos: 6,
        });
        r.push(SpanRecord {
            id: 1,
            parent: 0,
            trace: 0,
            name: "parent".into(),
            start_nanos: 1,
            end_nanos: 9,
        });
        let (v, _) = r.snapshot();
        assert_eq!(v[0].name, "parent");
        assert_eq!(v[1].name, "child");
    }

    #[test]
    fn finish_records_once() {
        let t = Telemetry::with_fake_clock();
        let g = t.span("once");
        g.finish();
        assert_eq!(t.snapshot().spans.len(), 1);
    }
}
