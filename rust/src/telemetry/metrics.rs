//! The shared metric types and the lock-cheap registry.
//!
//! [`LatencyHistogram`] / [`WidthHistogram`] / [`ServiceMetrics`] moved
//! here from `coordinator::metrics` in 0.8 (the deprecated re-exports
//! were removed in 0.10) so the service, the sharded engine, the tuner, and the
//! harness all publish into one namespace. Registration takes a short
//! mutex once and hands back an `Arc`; the hot path afterwards is pure
//! relaxed atomics.

use super::lock;
use super::snapshot::HistogramSnapshot;
use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex};

/// Monotonic named counter.
#[derive(Debug, Default)]
pub struct Counter(AtomicU64);

impl Counter {
    pub fn new() -> Self {
        Counter(AtomicU64::new(0))
    }

    #[inline]
    pub fn incr(&self) {
        self.add(1);
    }

    #[inline]
    pub fn add(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Last-writer-wins named gauge (an `f64` stored as bits — timings,
/// limits, ratios).
#[derive(Debug, Default)]
pub struct Gauge(AtomicU64);

impl Gauge {
    pub fn new() -> Self {
        Gauge(AtomicU64::new(0))
    }

    pub fn set(&self, v: f64) {
        self.0.store(v.to_bits(), Ordering::Relaxed);
    }

    pub fn get(&self) -> f64 {
        f64::from_bits(self.0.load(Ordering::Relaxed))
    }
}

/// Compose a metric name with sorted `key="value"` labels —
/// `name{k1="a",k2="b"}`. The exporters split on `{` and pass the
/// label block through verbatim, so sorting here is what makes the
/// Prometheus exposition's label order stable.
pub fn labeled(name: &str, labels: &[(&str, &str)]) -> String {
    if labels.is_empty() {
        return name.to_string();
    }
    let mut ls: Vec<(&str, &str)> = labels.to_vec();
    ls.sort_by(|a, b| a.0.cmp(b.0));
    let body =
        ls.iter().map(|(k, v)| format!("{k}=\"{v}\"")).collect::<Vec<_>>().join(",");
    format!("{name}{{{body}}}")
}

/// Named counters, gauges, and latency histograms. `BTreeMap` keying
/// gives every snapshot (and thus both exporters) a deterministic
/// iteration order for free.
#[derive(Default)]
pub struct MetricRegistry {
    counters: Mutex<BTreeMap<String, Arc<Counter>>>,
    gauges: Mutex<BTreeMap<String, Arc<Gauge>>>,
    histograms: Mutex<BTreeMap<String, Arc<LatencyHistogram>>>,
}

impl MetricRegistry {
    pub fn new() -> Self {
        Self::default()
    }

    /// Get-or-register: the first caller creates the metric, later
    /// callers share it.
    pub fn counter(&self, name: &str) -> Arc<Counter> {
        lock(&self.counters).entry(name.to_string()).or_insert_with(Arc::default).clone()
    }

    pub fn gauge(&self, name: &str) -> Arc<Gauge> {
        lock(&self.gauges).entry(name.to_string()).or_insert_with(Arc::default).clone()
    }

    pub fn histogram(&self, name: &str) -> Arc<LatencyHistogram> {
        lock(&self.histograms)
            .entry(name.to_string())
            .or_insert_with(|| Arc::new(LatencyHistogram::new()))
            .clone()
    }

    /// One-shot increment for call sites that don't keep the handle.
    pub fn incr(&self, name: &str) {
        self.counter(name).incr();
    }

    pub fn add(&self, name: &str, n: u64) {
        self.counter(name).add(n);
    }

    pub fn set_gauge(&self, name: &str, v: f64) {
        self.gauge(name).set(v);
    }

    /// Value maps for a snapshot (deterministically ordered).
    #[allow(clippy::type_complexity)]
    pub fn snapshot_maps(
        &self,
    ) -> (BTreeMap<String, u64>, BTreeMap<String, f64>, BTreeMap<String, HistogramSnapshot>) {
        let counters =
            lock(&self.counters).iter().map(|(k, v)| (k.clone(), v.get())).collect();
        let gauges = lock(&self.gauges).iter().map(|(k, v)| (k.clone(), v.get())).collect();
        let histograms =
            lock(&self.histograms).iter().map(|(k, v)| (k.clone(), v.snapshot())).collect();
        (counters, gauges, histograms)
    }
}

/// Log-spaced latency histogram from 1 µs to ~1 s (30 buckets, ×2
/// each), with per-bucket observed min/max so quantiles interpolate
/// within the recorded range instead of reporting the upper bucket
/// edge (which overstated p50/p99 by up to 2× at log-spaced widths).
pub struct LatencyHistogram {
    buckets: Vec<AtomicU64>,
    /// Smallest recorded nanos per bucket (`u64::MAX` = empty).
    bucket_min: Vec<AtomicU64>,
    /// Largest recorded nanos per bucket (0 = empty).
    bucket_max: Vec<AtomicU64>,
    count: AtomicU64,
    sum_nanos: AtomicU64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHistogram {
    pub fn new() -> Self {
        Self {
            buckets: (0..30).map(|_| AtomicU64::new(0)).collect(),
            bucket_min: (0..30).map(|_| AtomicU64::new(u64::MAX)).collect(),
            bucket_max: (0..30).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum_nanos: AtomicU64::new(0),
        }
    }

    #[inline]
    pub fn record(&self, secs: f64) {
        let nanos = (secs * 1e9) as u64;
        let us = nanos / 1000;
        let idx = if us == 0 { 0 } else { (63 - us.leading_zeros() as usize).min(29) };
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
        self.bucket_min[idx].fetch_min(nanos, Ordering::Relaxed);
        self.bucket_max[idx].fetch_max(nanos, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_nanos.fetch_add(nanos, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn mean_secs(&self) -> f64 {
        let c = self.count();
        if c == 0 {
            return 0.0;
        }
        self.sum_nanos.load(Ordering::Relaxed) as f64 / c as f64 / 1e9
    }

    /// Total recorded seconds (the Prometheus `_sum`).
    pub fn sum_secs(&self) -> f64 {
        self.sum_nanos.load(Ordering::Relaxed) as f64 / 1e9
    }

    /// Smallest recorded value in seconds (0 when empty).
    pub fn min_secs(&self) -> f64 {
        for m in &self.bucket_min {
            let v = m.load(Ordering::Relaxed);
            if v != u64::MAX {
                return v as f64 * 1e-9;
            }
        }
        0.0
    }

    /// Largest recorded value in seconds (0 when empty).
    pub fn max_secs(&self) -> f64 {
        for m in self.bucket_max.iter().rev() {
            let v = m.load(Ordering::Relaxed);
            if v != 0 {
                return v as f64 * 1e-9;
            }
        }
        0.0
    }

    /// Histogram quantile, interpolated by rank between the target
    /// bucket's observed min and max — the reported value is always
    /// clamped to the recorded range (a histogram of identical samples
    /// reports exactly that sample at every q). Monotone in `q`:
    /// bucket ranges are disjoint and ordered, and the within-bucket
    /// interpolation is monotone in rank.
    pub fn quantile_secs(&self, q: f64) -> f64 {
        let total = self.count();
        if total == 0 {
            return 0.0;
        }
        let target = ((q.clamp(0.0, 1.0) * total as f64).ceil() as u64).clamp(1, total);
        let mut acc = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            let c = b.load(Ordering::Relaxed);
            if c == 0 {
                continue;
            }
            if acc + c >= target {
                let lo = self.bucket_min[i].load(Ordering::Relaxed);
                let hi = self.bucket_max[i].load(Ordering::Relaxed).max(lo);
                let pos = if c <= 1 {
                    0.0
                } else {
                    (target - acc - 1) as f64 / (c - 1) as f64
                };
                return (lo as f64 + pos * (hi - lo) as f64) * 1e-9;
            }
            acc += c;
        }
        // Counters are updated relaxed; a racing record can leave the
        // per-bucket sum momentarily behind `count`. Report the
        // observed max rather than inventing a value.
        self.max_secs()
    }

    /// Point-in-time summary for the exporters.
    pub fn snapshot(&self) -> HistogramSnapshot {
        HistogramSnapshot {
            count: self.count(),
            sum_secs: self.sum_secs(),
            mean_secs: self.mean_secs(),
            p50_secs: self.quantile_secs(0.5),
            p99_secs: self.quantile_secs(0.99),
            min_secs: self.min_secs(),
            max_secs: self.max_secs(),
        }
    }
}

/// Power-of-two histogram of fused-batch widths: bucket `i` counts
/// widths in `[2^i, 2^(i+1))`, the last bucket absorbs the overflow.
/// Makes the request-fusion win (mean width > 1) observable.
pub struct WidthHistogram {
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum: AtomicU64,
    max: AtomicU64,
}

impl Default for WidthHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl WidthHistogram {
    pub fn new() -> Self {
        Self {
            buckets: (0..16).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            max: AtomicU64::new(0),
        }
    }

    #[inline]
    pub fn record(&self, width: usize) {
        let w = width.max(1) as u64;
        let idx = (63 - w.leading_zeros() as usize).min(self.buckets.len() - 1);
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(w, Ordering::Relaxed);
        self.max.fetch_max(w, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Mean recorded width (0 when empty).
    pub fn mean(&self) -> f64 {
        let c = self.count();
        if c == 0 {
            return 0.0;
        }
        self.sum.load(Ordering::Relaxed) as f64 / c as f64
    }

    pub fn max(&self) -> u64 {
        self.max.load(Ordering::Relaxed)
    }

    pub fn num_buckets(&self) -> usize {
        self.buckets.len()
    }

    /// Count in bucket `i` (widths in `[2^i, 2^(i+1))`).
    pub fn bucket(&self, i: usize) -> u64 {
        self.buckets[i].load(Ordering::Relaxed)
    }
}

/// Service-level counters.
pub struct ServiceMetrics {
    pub requests: AtomicU64,
    pub batches: AtomicU64,
    /// Kernel latency each request observed (the fused call's wall time).
    pub spmv_latency: LatencyHistogram,
    /// Width of every fused kernel call. Invariant: only batches that
    /// actually **executed** are recorded here — a shed request's width
    /// never enters this histogram (sheds are counted in
    /// [`Self::shed`] at submit time, before any width accounting), so
    /// `batch_width.count() == batches` always holds. Pinned by
    /// `service::tests::shed_requests_never_recorded_in_width_histogram`.
    pub batch_width: WidthHistogram,
    /// Estimated bytes streamed by the engine: the matrix format once
    /// per fused call plus `2 · nrows · sizeof(S)` per request (x in,
    /// y out) — the quantity request fusion amortizes.
    pub bytes_moved: AtomicU64,
    /// Requests shed because the bounded queue was full
    /// (`EhybError::Overloaded`) — recorded client-side at submit.
    pub shed: AtomicU64,
    /// Current fused-batch limit of an **adaptive** service
    /// (`spawn_adaptive` / `serve_adaptive`): shrinks when submissions
    /// shed, grows back while the queue drains idle. 0 = fixed-limit
    /// service (the default `spawn`/`serve` paths never touch it).
    pub adaptive_max_batch: AtomicU64,
    /// Fused batches quarantined because the engine panicked mid-call
    /// (every request in the batch got `EhybError::EngineFault`). One
    /// increment per poisoned *batch*, not per request.
    pub faults: AtomicU64,
    /// Engines respawned via the service's factory after a fault.
    /// Steady state: `respawns == faults`; a lag means the factory
    /// failed and the service exited.
    pub respawns: AtomicU64,
    /// Requests dropped at drain time because their deadline had
    /// already expired (`EhybError::DeadlineExceeded`) — they never
    /// occupied kernel width.
    pub deadline_misses: AtomicU64,
}

impl Default for ServiceMetrics {
    fn default() -> Self {
        Self::new()
    }
}

impl ServiceMetrics {
    pub fn new() -> Self {
        Self {
            requests: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            spmv_latency: LatencyHistogram::new(),
            batch_width: WidthHistogram::new(),
            bytes_moved: AtomicU64::new(0),
            shed: AtomicU64::new(0),
            adaptive_max_batch: AtomicU64::new(0),
            faults: AtomicU64::new(0),
            respawns: AtomicU64::new(0),
            deadline_misses: AtomicU64::new(0),
        }
    }

    pub fn mean_batch_size(&self) -> f64 {
        let b = self.batches.load(Ordering::Relaxed);
        if b == 0 {
            return 0.0;
        }
        self.requests.load(Ordering::Relaxed) as f64 / b as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_records_and_means() {
        let h = LatencyHistogram::new();
        h.record(0.001);
        h.record(0.003);
        assert_eq!(h.count(), 2);
        assert!((h.mean_secs() - 0.002).abs() < 1e-6);
    }

    #[test]
    fn quantiles_ordered() {
        let h = LatencyHistogram::new();
        for i in 1..=100 {
            h.record(i as f64 * 1e-5);
        }
        assert!(h.quantile_secs(0.5) <= h.quantile_secs(0.99));
        assert!(h.quantile_secs(0.99) > 1e-4);
    }

    #[test]
    fn quantile_clamps_to_observed_range() {
        // The pre-0.8 histogram reported the upper bucket edge: 100
        // identical 3 µs samples gave p50 = p99 = 4 µs. Interpolating
        // between the bucket's observed min/max must report exactly
        // the recorded value instead.
        let h = LatencyHistogram::new();
        for _ in 0..100 {
            h.record(3e-6);
        }
        assert!((h.quantile_secs(0.5) - 3e-6).abs() < 1e-12);
        assert!((h.quantile_secs(0.99) - 3e-6).abs() < 1e-12);
        assert!((h.max_secs() - 3e-6).abs() < 1e-12);
        assert!((h.min_secs() - 3e-6).abs() < 1e-12);
    }

    #[test]
    fn quantile_interpolates_within_bucket() {
        // 2 µs and 3.9 µs share one log bucket ([2, 4) µs): p0 must
        // report the low end, p100 the high end, and everything stays
        // inside the observed range.
        let h = LatencyHistogram::new();
        h.record(2e-6);
        h.record(3.9e-6);
        let lo = h.quantile_secs(0.0);
        let hi = h.quantile_secs(1.0);
        assert!((lo - 2e-6).abs() < 1e-12, "{lo}");
        assert!((hi - 3.9e-6).abs() < 1e-12, "{hi}");
        let mid = h.quantile_secs(0.6);
        assert!(mid >= lo && mid <= hi);
    }

    #[test]
    fn quantile_never_exceeds_observed_max() {
        let h = LatencyHistogram::new();
        for v in [1e-6, 5e-6, 17e-6, 130e-6] {
            h.record(v);
        }
        for q in [0.0, 0.1, 0.5, 0.9, 0.99, 1.0] {
            let v = h.quantile_secs(q);
            assert!(v >= 1e-6 - 1e-12 && v <= 130e-6 + 1e-12, "q={q} v={v}");
        }
    }

    #[test]
    fn batch_size_accounting() {
        let m = ServiceMetrics::new();
        m.requests.fetch_add(10, Ordering::Relaxed);
        m.batches.fetch_add(4, Ordering::Relaxed);
        assert!((m.mean_batch_size() - 2.5).abs() < 1e-12);
    }

    #[test]
    fn empty_histogram_safe() {
        let h = LatencyHistogram::new();
        assert_eq!(h.mean_secs(), 0.0);
        assert_eq!(h.quantile_secs(0.9), 0.0);
        assert_eq!(h.min_secs(), 0.0);
        assert_eq!(h.max_secs(), 0.0);
    }

    #[test]
    fn adaptive_gauge_defaults_to_fixed() {
        // 0 marks a fixed-limit service; adaptive services overwrite it
        // with their live limit.
        let m = ServiceMetrics::new();
        assert_eq!(m.adaptive_max_batch.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn fault_counters_start_at_zero() {
        let m = ServiceMetrics::new();
        assert_eq!(m.faults.load(Ordering::Relaxed), 0);
        assert_eq!(m.respawns.load(Ordering::Relaxed), 0);
        assert_eq!(m.deadline_misses.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn width_histogram_buckets_and_stats() {
        let h = WidthHistogram::new();
        for w in [1usize, 1, 2, 3, 8, 16] {
            h.record(w);
        }
        assert_eq!(h.count(), 6);
        assert_eq!(h.max(), 16);
        assert!((h.mean() - 31.0 / 6.0).abs() < 1e-12);
        assert_eq!(h.bucket(0), 2); // widths 1
        assert_eq!(h.bucket(1), 2); // widths 2..3
        assert_eq!(h.bucket(3), 1); // width 8
        assert_eq!(h.bucket(4), 1); // width 16
    }

    #[test]
    fn width_histogram_empty_and_overflow() {
        let h = WidthHistogram::new();
        assert_eq!(h.mean(), 0.0);
        assert_eq!(h.max(), 0);
        h.record(1 << 20); // overflow clamps into the last bucket
        assert_eq!(h.bucket(h.num_buckets() - 1), 1);
    }

    #[test]
    fn registry_shares_and_orders_metrics() {
        let r = MetricRegistry::new();
        r.counter("b.count").add(2);
        r.counter("a.count").incr();
        r.counter("b.count").incr(); // same metric as the first handle
        r.set_gauge("g.v", 1.5);
        r.histogram("h.lat").record(1e-4);
        let (c, g, h) = r.snapshot_maps();
        assert_eq!(c.keys().cloned().collect::<Vec<_>>(), vec!["a.count", "b.count"]);
        assert_eq!(c["b.count"], 3);
        assert_eq!(c["a.count"], 1);
        assert!((g["g.v"] - 1.5).abs() < 1e-12);
        assert_eq!(h["h.lat"].count, 1);
    }

    #[test]
    fn labeled_names_sort_keys() {
        assert_eq!(labeled("m", &[]), "m");
        assert_eq!(
            labeled("shard.kernel", &[("shard", "3"), ("engine", "ehyb")]),
            "shard.kernel{engine=\"ehyb\",shard=\"3\"}"
        );
    }

    #[test]
    fn gauge_round_trips_f64() {
        let g = Gauge::new();
        assert_eq!(g.get(), 0.0);
        g.set(-2.75);
        assert_eq!(g.get(), -2.75);
    }
}
