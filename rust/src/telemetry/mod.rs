//! Crate-wide instrumentation: one [`Telemetry`] handle per
//! [`crate::api::SpmvContext`] carrying a lock-cheap [`MetricRegistry`]
//! (named counters / gauges / the log-spaced histograms every subsystem
//! shares), structured [`span::SpanRecord`] trees with monotonic
//! timing over the whole pipeline (build: reorder → tune per-candidate
//! → EHYB partition/assemble → shard build → engine build; serve:
//! submit → queue wait → drain → fused kernel per shard → reply), and
//! per-request **trace IDs** minted at submit and carried through
//! deadline triage, retries, shed/fault/respawn events, and solver
//! iterations — so one ID reconstructs a request's whole story.
//!
//! Everything lands in bounded ring buffers and is exported off one
//! [`snapshot::TelemetrySnapshot`]: deterministic JSON (via
//! [`crate::runtime::json::Json::dump`]) and Prometheus text
//! exposition, both byte-identical across two snapshots of a frozen
//! registry.
//!
//! Determinism in CI: [`Telemetry::with_fake_clock`] swaps the wall
//! clock for a logical tick counter that advances by one nanosecond
//! per observation, so a seeded single-threaded run produces the same
//! span tree bit-for-bit every time (the same convention the
//! `FaultPlan` chaos drills use for reproducibility).

pub mod metrics;
pub mod snapshot;
pub mod span;

pub use metrics::{
    labeled, Counter, Gauge, LatencyHistogram, MetricRegistry, ServiceMetrics, WidthHistogram,
};
pub use snapshot::{HistogramSnapshot, TelemetrySnapshot, TraceHealthEvent};
pub use span::{EventRecord, SpanGuard, SpanRecord};

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, MutexGuard};
use std::time::Instant;

/// Poison-tolerant lock: a panic on the serving path (already isolated
/// by `catch_unwind`) must never make telemetry unrecordable.
pub(crate) fn lock<T>(m: &Mutex<T>) -> MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

/// Per-request trace identifier. `0` is reserved for "no trace"
/// ([`TraceId::NONE`]); real IDs are minted sequentially from 1 by
/// [`Telemetry::mint_trace`], so within one context a trace ID is
/// deterministic under a seeded workload.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord, Hash)]
pub struct TraceId(pub u64);

impl TraceId {
    /// "No trace in scope" — untraced spans and events carry this.
    pub const NONE: TraceId = TraceId(0);

    pub fn is_none(self) -> bool {
        self.0 == 0
    }
}

impl std::fmt::Display for TraceId {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{}", self.0)
    }
}

/// Monotonic nanosecond clock behind every span/event timestamp.
///
/// * **Wall** mode reads `Instant::elapsed` since the handle was
///   created.
/// * **Fake** mode (tests, goldens, the `trace` CLI) is a logical
///   counter: every observation ticks it forward by exactly 1 ns, so
///   timestamps are distinct, strictly increasing in call order, and
///   bit-for-bit reproducible for a deterministic call sequence.
pub struct Clock {
    start: Instant,
    fake: Option<AtomicU64>,
}

impl Clock {
    pub fn wall() -> Self {
        Clock { start: Instant::now(), fake: None }
    }

    pub fn fake() -> Self {
        Clock { start: Instant::now(), fake: Some(AtomicU64::new(0)) }
    }

    pub fn is_fake(&self) -> bool {
        self.fake.is_some()
    }

    /// Current time in nanoseconds. Fake mode ticks by 1 per call.
    pub fn now_nanos(&self) -> u64 {
        match &self.fake {
            Some(t) => t.fetch_add(1, Ordering::Relaxed) + 1,
            None => self.start.elapsed().as_nanos() as u64,
        }
    }

    /// Advance a fake clock by `n` extra nanoseconds (no-op in wall
    /// mode — wall time advances itself).
    pub fn advance_nanos(&self, n: u64) {
        if let Some(t) = &self.fake {
            t.fetch_add(n, Ordering::Relaxed);
        }
    }
}

struct Inner {
    clock: Clock,
    registry: MetricRegistry,
    spans: span::SpanRing,
    events: span::EventRing,
    /// Next span id (spans are numbered from 1; 0 = "root / no parent").
    next_span: AtomicU64,
    /// Next trace id (from 1; 0 = [`TraceId::NONE`]).
    next_trace: AtomicU64,
    /// Innermost open [`SpanGuard`]'s id — the implicit parent for new
    /// guards and for engine-internal spans (per-shard kernels) that
    /// cannot see the guard that encloses their call. Last-writer-wins
    /// across threads; the deterministic goldens run single-threaded.
    current: AtomicU64,
    /// Service metric blocks attached by [`Telemetry::attach_service`];
    /// snapshots fold them into the registry namespace as
    /// `service.*{svc="i"}`.
    services: Mutex<Vec<Arc<ServiceMetrics>>>,
}

/// The per-context instrumentation handle. Cheap to clone (one `Arc`);
/// every recording path is either a plain atomic (counters, gauges,
/// histograms) or a short bounded-ring mutex push (spans, events).
#[derive(Clone)]
pub struct Telemetry {
    inner: Arc<Inner>,
}

impl Default for Telemetry {
    fn default() -> Self {
        Self::new()
    }
}

/// Default bounded-ring capacities: spans and events are evidence, not
/// an unbounded log — a long-running service keeps the most recent
/// window and counts what it evicted.
const SPAN_CAP: usize = 4096;
const EVENT_CAP: usize = 8192;

impl Telemetry {
    /// Wall-clock telemetry with the default ring capacities.
    pub fn new() -> Self {
        Self::with_clock(Clock::wall())
    }

    /// Deterministic tick-clock telemetry (tests / goldens / seeded
    /// CLI dumps).
    pub fn with_fake_clock() -> Self {
        Self::with_clock(Clock::fake())
    }

    pub fn with_clock(clock: Clock) -> Self {
        Self::with_clock_and_capacity(clock, SPAN_CAP, EVENT_CAP)
    }

    pub fn with_clock_and_capacity(clock: Clock, span_cap: usize, event_cap: usize) -> Self {
        Telemetry {
            inner: Arc::new(Inner {
                clock,
                registry: MetricRegistry::new(),
                spans: span::SpanRing::new(span_cap.max(1)),
                events: span::EventRing::new(event_cap.max(1)),
                next_span: AtomicU64::new(1),
                next_trace: AtomicU64::new(1),
                current: AtomicU64::new(0),
                services: Mutex::new(Vec::new()),
            }),
        }
    }

    pub fn registry(&self) -> &MetricRegistry {
        &self.inner.registry
    }

    pub fn clock(&self) -> &Clock {
        &self.inner.clock
    }

    /// Shorthand for [`Clock::now_nanos`].
    pub fn now_nanos(&self) -> u64 {
        self.inner.clock.now_nanos()
    }

    /// Get-or-register a named counter (see [`MetricRegistry::counter`]).
    pub fn counter(&self, name: &str) -> Arc<Counter> {
        self.inner.registry.counter(name)
    }

    /// Get-or-register a named gauge.
    pub fn gauge(&self, name: &str) -> Arc<Gauge> {
        self.inner.registry.gauge(name)
    }

    /// Get-or-register a named latency histogram.
    pub fn histogram(&self, name: &str) -> Arc<LatencyHistogram> {
        self.inner.registry.histogram(name)
    }

    /// Mint the next sequential trace ID (1, 2, 3, … per handle).
    pub fn mint_trace(&self) -> TraceId {
        TraceId(self.inner.next_trace.fetch_add(1, Ordering::Relaxed))
    }

    /// Innermost open guard's span id (0 when none) — the parent an
    /// engine-internal span should attach to.
    pub fn current_parent(&self) -> u64 {
        self.inner.current.load(Ordering::Relaxed)
    }

    /// Open an untraced span parented under the innermost open guard.
    pub fn span(&self, name: impl Into<String>) -> SpanGuard {
        self.span_traced(name, TraceId::NONE)
    }

    /// Open a span carrying `trace`, parented under the innermost open
    /// guard on this handle.
    pub fn span_traced(&self, name: impl Into<String>, trace: TraceId) -> SpanGuard {
        let id = self.inner.next_span.fetch_add(1, Ordering::Relaxed);
        let start = self.now_nanos();
        let parent = self.inner.current.swap(id, Ordering::Relaxed);
        SpanGuard::new(self.clone(), id, parent, trace, name.into(), start)
    }

    pub(crate) fn close_span(&self, rec: SpanRecord, restore_parent: u64) {
        self.inner.current.store(restore_parent, Ordering::Relaxed);
        self.inner.spans.push(rec);
    }

    /// Record an already-timed span with explicit parent/timestamps
    /// (queue-wait spans start at submit time on another thread).
    pub fn record_span(
        &self,
        name: impl Into<String>,
        parent: u64,
        trace: TraceId,
        start_nanos: u64,
        end_nanos: u64,
    ) {
        let id = self.inner.next_span.fetch_add(1, Ordering::Relaxed);
        self.inner.spans.push(SpanRecord {
            id,
            parent,
            trace: trace.0,
            name: name.into(),
            start_nanos,
            end_nanos: end_nanos.max(start_nanos),
        });
    }

    /// Record a span whose duration was measured by a wall timer in a
    /// layer that is not telemetry-aware (e.g. the preprocessing
    /// phase decomposition in [`crate::preprocess::PreprocessTimings`]):
    /// in wall mode the span ends now and extends `wall_secs` back; in
    /// fake mode it is a 1-tick span at the current logical time, so
    /// goldens stay bit-for-bit reproducible.
    pub fn derived_span(&self, name: impl Into<String>, trace: TraceId, wall_secs: f64) {
        let parent = self.current_parent();
        if self.inner.clock.is_fake() {
            let start = self.now_nanos();
            let end = self.now_nanos();
            self.record_span(name, parent, trace, start, end);
        } else {
            let end = self.now_nanos();
            let dur = (wall_secs.max(0.0) * 1e9) as u64;
            self.record_span(name, parent, trace, end.saturating_sub(dur), end);
        }
    }

    /// Record a point event (`kind` ∈ submit / reply / shed / deadline
    /// / fault / respawn / retry / solver-iter / …) optionally tagged
    /// with the trace it belongs to.
    pub fn event(&self, kind: &str, trace: TraceId, detail: impl Into<String>) {
        let nanos = self.now_nanos();
        self.inner.events.push(EventRecord {
            nanos,
            trace: trace.0,
            kind: kind.to_string(),
            detail: detail.into(),
        });
    }

    /// Fold a service's metric block into this handle's snapshots as
    /// `service.*{svc="<index>"}`. Returns the instance index.
    pub fn attach_service(&self, metrics: Arc<ServiceMetrics>) -> usize {
        let mut svcs = lock(&self.inner.services);
        svcs.push(metrics);
        svcs.len() - 1
    }

    /// Consistent point-in-time snapshot of everything this handle has
    /// recorded. Snapshotting never observes the clock, so two
    /// snapshots of a frozen registry are byte-identical through both
    /// exporters.
    pub fn snapshot(&self) -> TelemetrySnapshot {
        let (mut counters, mut gauges, mut histograms) = self.inner.registry.snapshot_maps();
        for (i, svc) in lock(&self.inner.services).iter().enumerate() {
            snapshot::fold_service(&mut counters, &mut gauges, &mut histograms, svc, i);
        }
        let (spans, spans_dropped) = self.inner.spans.snapshot();
        let (events, events_dropped) = self.inner.events.snapshot();
        TelemetrySnapshot {
            counters,
            gauges,
            histograms,
            spans,
            spans_dropped,
            events,
            events_dropped,
            health_events: Vec::new(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fake_clock_ticks_monotonically() {
        let c = Clock::fake();
        assert!(c.is_fake());
        assert_eq!(c.now_nanos(), 1);
        assert_eq!(c.now_nanos(), 2);
        c.advance_nanos(10);
        assert_eq!(c.now_nanos(), 13);
    }

    #[test]
    fn wall_clock_is_monotone() {
        let c = Clock::wall();
        let a = c.now_nanos();
        let b = c.now_nanos();
        assert!(b >= a);
        c.advance_nanos(5); // no-op, must not panic
    }

    #[test]
    fn trace_ids_are_sequential_from_one() {
        let t = Telemetry::with_fake_clock();
        assert_eq!(t.mint_trace(), TraceId(1));
        assert_eq!(t.mint_trace(), TraceId(2));
        assert!(TraceId::NONE.is_none());
        assert!(!TraceId(1).is_none());
    }

    #[test]
    fn guards_nest_and_restore_parent() {
        let t = Telemetry::with_fake_clock();
        {
            let root = t.span("root");
            assert_eq!(t.current_parent(), root.id());
            {
                let child = t.span("child");
                assert_eq!(t.current_parent(), child.id());
            }
            assert_eq!(t.current_parent(), root.id());
        }
        assert_eq!(t.current_parent(), 0);
        let snap = t.snapshot();
        assert_eq!(snap.spans.len(), 2);
        let root = snap.spans.iter().find(|s| s.name == "root").unwrap();
        let child = snap.spans.iter().find(|s| s.name == "child").unwrap();
        assert_eq!(root.parent, 0);
        assert_eq!(child.parent, root.id);
        // Strict containment under the tick clock.
        assert!(root.start_nanos < child.start_nanos);
        assert!(child.end_nanos < root.end_nanos);
    }

    #[test]
    fn derived_span_is_one_tick_under_fake_clock() {
        let t = Telemetry::with_fake_clock();
        t.derived_span("ehyb.partition", TraceId::NONE, 123.456);
        let snap = t.snapshot();
        assert_eq!(snap.spans.len(), 1);
        assert_eq!(snap.spans[0].end_nanos - snap.spans[0].start_nanos, 1);
    }

    #[test]
    fn events_carry_traces() {
        let t = Telemetry::with_fake_clock();
        let tr = t.mint_trace();
        t.event("reply", tr, "ok");
        t.event("note", TraceId::NONE, "untraced");
        let snap = t.snapshot();
        assert_eq!(snap.events.len(), 2);
        assert_eq!(snap.events[0].trace, tr.0);
        assert_eq!(snap.events[1].trace, 0);
    }
}
