//! One snapshot type, two exporters.
//!
//! [`TelemetrySnapshot`] is the frozen view everything renders from:
//! deterministic JSON (via [`crate::runtime::json::Json::dump`] —
//! `BTreeMap`-ordered keys, shortest-round-trip numbers, diffable in
//! CI) and Prometheus text exposition (`# HELP`/`# TYPE`, stable
//! label order). Two snapshots of the same frozen registry export
//! byte-identically through both — snapshotting never reads the clock
//! and never stamps a "generated at".

use super::metrics::ServiceMetrics;
use super::span::{EventRecord, SpanRecord};
use crate::runtime::json::{self, Json};
use std::collections::{BTreeMap, BTreeSet};
use std::sync::atomic::Ordering;

/// Point-in-time summary of one [`super::LatencyHistogram`].
#[derive(Clone, Debug, PartialEq)]
pub struct HistogramSnapshot {
    pub count: u64,
    pub sum_secs: f64,
    pub mean_secs: f64,
    pub p50_secs: f64,
    pub p99_secs: f64,
    pub min_secs: f64,
    pub max_secs: f64,
}

/// A [`crate::resilience::Health`] event surfaced through the
/// telemetry snapshot, tagged with the trace that caused it (0 when no
/// trace was in scope).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct TraceHealthEvent {
    pub trace: u64,
    pub detail: String,
}

/// Everything a [`super::Telemetry`] handle recorded, frozen.
/// `health_events` is filled by the context
/// ([`crate::api::SpmvContext::telemetry_snapshot`]) — the handle
/// itself does not know about [`crate::resilience::Health`].
#[derive(Clone, Debug)]
pub struct TelemetrySnapshot {
    pub counters: BTreeMap<String, u64>,
    pub gauges: BTreeMap<String, f64>,
    pub histograms: BTreeMap<String, HistogramSnapshot>,
    /// Sorted by `(start_nanos, id)` — parents precede children.
    pub spans: Vec<SpanRecord>,
    pub spans_dropped: u64,
    /// In recording order.
    pub events: Vec<EventRecord>,
    pub events_dropped: u64,
    pub health_events: Vec<TraceHealthEvent>,
}

/// Event kinds that end a request's story — a submitted trace reaches
/// exactly one of these.
pub const TERMINAL_KINDS: [&str; 4] = ["reply", "shed", "deadline", "fault"];

/// Fold one attached service's metric block into the snapshot maps as
/// `service.*{svc="<idx>"}`.
pub(crate) fn fold_service(
    counters: &mut BTreeMap<String, u64>,
    gauges: &mut BTreeMap<String, f64>,
    histograms: &mut BTreeMap<String, HistogramSnapshot>,
    svc: &ServiceMetrics,
    idx: usize,
) {
    let i = idx.to_string();
    let name = |base: &str| super::metrics::labeled(base, &[("svc", &i)]);
    let load = |a: &std::sync::atomic::AtomicU64| a.load(Ordering::Relaxed);
    counters.insert(name("service.requests"), load(&svc.requests));
    counters.insert(name("service.batches"), load(&svc.batches));
    counters.insert(name("service.shed"), load(&svc.shed));
    counters.insert(name("service.faults"), load(&svc.faults));
    counters.insert(name("service.respawns"), load(&svc.respawns));
    counters.insert(name("service.deadline_misses"), load(&svc.deadline_misses));
    counters.insert(name("service.bytes_moved"), load(&svc.bytes_moved));
    gauges.insert(name("service.batch_width_mean"), svc.batch_width.mean());
    gauges.insert(name("service.batch_width_max"), svc.batch_width.max() as f64);
    gauges.insert(name("service.mean_batch_size"), svc.mean_batch_size());
    gauges.insert(name("service.adaptive_max_batch"), load(&svc.adaptive_max_batch) as f64);
    histograms.insert(name("service.spmv_latency"), svc.spmv_latency.snapshot());
}

impl TelemetrySnapshot {
    /// Deterministic JSON document (`schema: "ehyb-telemetry-v1"`).
    pub fn to_json(&self) -> Json {
        let counters = Json::Obj(
            self.counters.iter().map(|(k, v)| (k.clone(), Json::Num(*v as f64))).collect(),
        );
        let gauges =
            Json::Obj(self.gauges.iter().map(|(k, v)| (k.clone(), Json::Num(*v))).collect());
        let histograms = Json::Obj(
            self.histograms
                .iter()
                .map(|(k, h)| {
                    (
                        k.clone(),
                        json::obj([
                            ("count", Json::Num(h.count as f64)),
                            ("sum_secs", Json::Num(h.sum_secs)),
                            ("mean_secs", Json::Num(h.mean_secs)),
                            ("p50_secs", Json::Num(h.p50_secs)),
                            ("p99_secs", Json::Num(h.p99_secs)),
                            ("min_secs", Json::Num(h.min_secs)),
                            ("max_secs", Json::Num(h.max_secs)),
                        ]),
                    )
                })
                .collect(),
        );
        let spans = Json::Arr(
            self.spans
                .iter()
                .map(|s| {
                    json::obj([
                        ("id", Json::Num(s.id as f64)),
                        ("parent", Json::Num(s.parent as f64)),
                        ("trace", Json::Num(s.trace as f64)),
                        ("name", Json::Str(s.name.clone())),
                        ("start_nanos", Json::Num(s.start_nanos as f64)),
                        ("end_nanos", Json::Num(s.end_nanos as f64)),
                    ])
                })
                .collect(),
        );
        let events = Json::Arr(
            self.events
                .iter()
                .map(|e| {
                    json::obj([
                        ("nanos", Json::Num(e.nanos as f64)),
                        ("trace", Json::Num(e.trace as f64)),
                        ("kind", Json::Str(e.kind.clone())),
                        ("detail", Json::Str(e.detail.clone())),
                    ])
                })
                .collect(),
        );
        let health = Json::Arr(
            self.health_events
                .iter()
                .map(|h| {
                    json::obj([
                        ("trace", Json::Num(h.trace as f64)),
                        ("detail", Json::Str(h.detail.clone())),
                    ])
                })
                .collect(),
        );
        json::obj([
            ("schema", Json::Str("ehyb-telemetry-v1".into())),
            ("counters", counters),
            ("gauges", gauges),
            ("histograms", histograms),
            ("spans", spans),
            ("spans_dropped", Json::Num(self.spans_dropped as f64)),
            ("events", events),
            ("events_dropped", Json::Num(self.events_dropped as f64)),
            ("health", health),
        ])
    }

    /// Prometheus text exposition. Metric names are `ehyb_`-prefixed
    /// and sanitized (`.`/`-` → `_`); label blocks pass through in the
    /// sorted order [`super::metrics::labeled`] composed them in;
    /// `# HELP`/`# TYPE` are emitted once per metric name; histograms
    /// export as summaries (`{quantile=…}` + `_sum` + `_count`).
    pub fn to_prometheus(&self) -> String {
        let mut out = String::new();
        let mut seen_type: BTreeSet<String> = BTreeSet::new();
        for (full, v) in &self.counters {
            let (base, labels) = split_labels(full);
            let name = sanitize(base);
            header(&mut out, &mut seen_type, &name, "counter", base);
            out.push_str(&format!("{name}{labels} {v}\n"));
        }
        for (full, v) in &self.gauges {
            let (base, labels) = split_labels(full);
            let name = sanitize(base);
            header(&mut out, &mut seen_type, &name, "gauge", base);
            out.push_str(&format!("{name}{labels} {v}\n"));
        }
        for (full, h) in &self.histograms {
            let (base, labels) = split_labels(full);
            let name = sanitize(base);
            header(&mut out, &mut seen_type, &name, "summary", base);
            for (q, v) in [("0.5", h.p50_secs), ("0.99", h.p99_secs)] {
                let ql = merge_label(labels, &format!("quantile=\"{q}\""));
                out.push_str(&format!("{name}{ql} {v}\n"));
            }
            out.push_str(&format!("{name}_sum{labels} {}\n", h.sum_secs));
            out.push_str(&format!("{name}_count{labels} {}\n", h.count));
        }
        out
    }

    /// How many terminal events (reply / shed / deadline / fault) this
    /// trace reached — the proptested invariant is exactly one per
    /// submitted request.
    pub fn terminal_event_count(&self, trace: u64) -> usize {
        self.events
            .iter()
            .filter(|e| e.trace == trace && TERMINAL_KINDS.contains(&e.kind.as_str()))
            .count()
    }

    /// Render the whole span forest with indentation (children under
    /// parents, ordered by start time).
    pub fn span_tree(&self) -> String {
        let mut children: BTreeMap<u64, Vec<&SpanRecord>> = BTreeMap::new();
        let ids: BTreeSet<u64> = self.spans.iter().map(|s| s.id).collect();
        let mut roots: Vec<&SpanRecord> = Vec::new();
        for s in &self.spans {
            if s.parent != 0 && ids.contains(&s.parent) {
                children.entry(s.parent).or_default().push(s);
            } else {
                roots.push(s);
            }
        }
        let mut out = String::new();
        for r in roots {
            render(&mut out, r, &children, 0);
        }
        out
    }

    /// Reconstruct one request's whole story from this snapshot: its
    /// events in time order, retry links to other attempts, the spans
    /// that carry its trace plus the enclosing batch subtree (queue
    /// wait, batch width, per-shard kernel spans), and the
    /// [`crate::resilience::Health`] events it triggered.
    pub fn describe_trace(&self, trace: u64) -> String {
        let mut out = format!("# trace {trace}\n");
        let mut evs: Vec<&EventRecord> =
            self.events.iter().filter(|e| e.trace == trace).collect();
        evs.sort_by_key(|e| e.nanos);
        out.push_str("\n## events\n");
        if evs.is_empty() {
            out.push_str("(no events recorded for this trace)\n");
        }
        for e in &evs {
            out.push_str(&format!("- t={}ns {}: {}\n", e.nanos, e.kind, e.detail));
        }
        // Retry links in both directions: this attempt retried as a
        // later trace, or this trace is itself a retry of an earlier
        // one (the `retry` event is tagged with the *new* trace and
        // names its predecessor in the detail).
        let prev_tag = format!("prev={trace}");
        for e in self.events.iter().filter(|e| e.kind == "retry") {
            if e.detail.contains(&prev_tag) {
                out.push_str(&format!("- retried as trace {} ({})\n", e.trace, e.detail));
            }
        }
        let spans = self.trace_spans(trace);
        out.push_str("\n## spans\n");
        if spans.is_empty() {
            out.push_str("(no spans recorded for this trace)\n");
        }
        for s in &spans {
            let tag = if s.trace == trace { " <-- this trace" } else { "" };
            out.push_str(&format!(
                "- [{}..{}ns] {} (id={} parent={}){}\n",
                s.start_nanos, s.end_nanos, s.name, s.id, s.parent, tag
            ));
        }
        let health: Vec<&TraceHealthEvent> =
            self.health_events.iter().filter(|h| h.trace == trace).collect();
        if !health.is_empty() {
            out.push_str("\n## health events\n");
            for h in health {
                out.push_str(&format!("- {}\n", h.detail));
            }
        }
        out
    }

    /// Spans carrying `trace` plus the full subtree of every enclosing
    /// batch span (so the per-shard kernel spans of the fused call the
    /// request rode in are part of its story).
    fn trace_spans(&self, trace: u64) -> Vec<&SpanRecord> {
        let mut include: BTreeSet<u64> = BTreeSet::new();
        let mut frontier: Vec<u64> = Vec::new();
        for s in &self.spans {
            if s.trace == trace {
                include.insert(s.id);
                if s.parent != 0 {
                    frontier.push(s.parent);
                }
            }
        }
        let mut children: BTreeMap<u64, Vec<u64>> = BTreeMap::new();
        for s in &self.spans {
            children.entry(s.parent).or_default().push(s.id);
        }
        // Enclosing spans and all their descendants.
        while let Some(id) = frontier.pop() {
            if !include.insert(id) {
                continue;
            }
            if let Some(kids) = children.get(&id) {
                frontier.extend(kids.iter().copied());
            }
        }
        self.spans.iter().filter(|s| include.contains(&s.id)).collect()
    }

    /// Traces that appear anywhere in this snapshot (events or spans),
    /// ascending.
    pub fn known_traces(&self) -> Vec<u64> {
        let mut set: BTreeSet<u64> = BTreeSet::new();
        for e in &self.events {
            if e.trace != 0 {
                set.insert(e.trace);
            }
        }
        for s in &self.spans {
            if s.trace != 0 {
                set.insert(s.trace);
            }
        }
        set.into_iter().collect()
    }
}

fn render(
    out: &mut String,
    s: &SpanRecord,
    children: &BTreeMap<u64, Vec<&SpanRecord>>,
    depth: usize,
) {
    let indent = "  ".repeat(depth);
    let trace = if s.trace != 0 { format!(" trace={}", s.trace) } else { String::new() };
    out.push_str(&format!(
        "{indent}{} [{}..{}ns]{}\n",
        s.name, s.start_nanos, s.end_nanos, trace
    ));
    if let Some(kids) = children.get(&s.id) {
        for k in kids {
            render(out, k, children, depth + 1);
        }
    }
}

/// `name{a="1"}` → `("name", "{a=\"1\"}")`; plain names get `""`.
fn split_labels(full: &str) -> (&str, &str) {
    match full.find('{') {
        Some(i) => (&full[..i], &full[i..]),
        None => (full, ""),
    }
}

/// Splice one more label into an existing (possibly empty) label block,
/// keeping it last so ordering stays stable.
fn merge_label(labels: &str, extra: &str) -> String {
    if labels.is_empty() {
        format!("{{{extra}}}")
    } else {
        format!("{},{extra}}}", &labels[..labels.len() - 1])
    }
}

/// Prometheus metric name: `ehyb_` prefix, non-`[a-zA-Z0-9_:]` → `_`.
fn sanitize(base: &str) -> String {
    let mut s = String::with_capacity(base.len() + 5);
    s.push_str("ehyb_");
    for c in base.chars() {
        if c.is_ascii_alphanumeric() || c == '_' || c == ':' {
            s.push(c);
        } else {
            s.push('_');
        }
    }
    s
}

fn header(out: &mut String, seen: &mut BTreeSet<String>, name: &str, ty: &str, base: &str) {
    if seen.insert(name.to_string()) {
        out.push_str(&format!("# HELP {name} ehyb {ty} \"{base}\".\n"));
        out.push_str(&format!("# TYPE {name} {ty}\n"));
    }
}

#[cfg(test)]
mod tests {
    use super::super::{Telemetry, TraceId};
    use super::*;

    fn sample() -> TelemetrySnapshot {
        let t = Telemetry::with_fake_clock();
        t.counter("build.engines").add(2);
        t.counter(&super::super::metrics::labeled("shard.kernel_calls", &[("shard", "0")]))
            .incr();
        t.gauge("build.partition_secs").set(0.25);
        t.histogram("serve.latency").record(2e-6);
        let tr = t.mint_trace();
        {
            let batch = t.span("serve.batch(w=1)");
            t.record_span("queue.wait", batch.id(), tr, 1, 3);
            let _k = batch.child("kernel");
        }
        t.event("reply", tr, "ok");
        t.snapshot()
    }

    #[test]
    fn exporters_are_frozen_registry_stable() {
        let snap = sample();
        assert_eq!(snap.to_json().dump(), snap.to_json().dump());
        assert_eq!(snap.to_prometheus(), snap.to_prometheus());
        // And a second snapshot of the same (now idle) registry
        // renders the same bytes — snapshotting mutates nothing.
        let snap2 = sample();
        assert_eq!(snap.counters, snap2.counters);
    }

    #[test]
    fn prometheus_shape() {
        let p = sample().to_prometheus();
        assert!(p.contains("# TYPE ehyb_build_engines counter\n"));
        assert!(p.contains("ehyb_build_engines 2\n"));
        assert!(p.contains("ehyb_shard_kernel_calls{shard=\"0\"} 1\n"));
        assert!(p.contains("# TYPE ehyb_build_partition_secs gauge\n"));
        assert!(p.contains("# TYPE ehyb_serve_latency summary\n"));
        assert!(p.contains("ehyb_serve_latency{quantile=\"0.5\"}"));
        assert!(p.contains("ehyb_serve_latency_count 1\n"));
        // One TYPE line per metric name.
        let types: Vec<&str> =
            p.lines().filter(|l| l.starts_with("# TYPE ehyb_serve_latency ")).collect();
        assert_eq!(types.len(), 1);
    }

    #[test]
    fn json_is_deterministic_and_typed() {
        let j = sample().to_json();
        let dump = j.dump();
        assert!(dump.contains("\"schema\":\"ehyb-telemetry-v1\""));
        assert!(dump.contains("\"counters\""));
        let reparsed = crate::runtime::json::Json::parse(&dump).expect("round trip");
        assert_eq!(reparsed.dump(), dump);
    }

    #[test]
    fn trace_story_includes_batch_subtree_and_terminal() {
        let snap = sample();
        assert_eq!(snap.known_traces(), vec![1]);
        assert_eq!(snap.terminal_event_count(1), 1);
        let story = snap.describe_trace(1);
        assert!(story.contains("reply"), "{story}");
        assert!(story.contains("queue.wait"), "{story}");
        // Sibling kernel span of the enclosing batch is pulled in.
        assert!(story.contains("kernel"), "{story}");
        assert!(story.contains("serve.batch(w=1)"), "{story}");
    }

    #[test]
    fn span_tree_indents_children() {
        let tree = sample().span_tree();
        let batch_line = tree.lines().position(|l| l.starts_with("serve.batch")).unwrap();
        let kernel_line = tree.lines().position(|l| l.contains("kernel")).unwrap();
        assert!(kernel_line > batch_line);
        assert!(tree.lines().nth(kernel_line).unwrap().starts_with("  "));
    }

    #[test]
    fn merge_label_splices_last() {
        assert_eq!(merge_label("", "quantile=\"0.5\""), "{quantile=\"0.5\"}");
        assert_eq!(
            merge_label("{svc=\"0\"}", "quantile=\"0.5\""),
            "{svc=\"0\",quantile=\"0.5\"}"
        );
    }
}
