//! API-compatible stand-in for [`client`](super) when the `pjrt`
//! feature is off (the `xla` bindings are outside the offline
//! dependency closure). [`PjrtRuntime::new`] always fails, so the
//! engine types are uninhabited (`Infallible` field) and their methods
//! are statically unreachable — callers keep their artifact-missing
//! fallback paths and the whole crate builds without XLA.

use super::bucketize::BucketizedEhyb;
use super::manifest::Manifest;
use super::XlaScalar;
use crate::sparse::ehyb::EhybMatrix;
use std::convert::Infallible;

/// Stub runtime: construction always errors (feature `pjrt` is off).
pub struct PjrtRuntime {
    never: Infallible,
    pub manifest: Manifest,
}

impl PjrtRuntime {
    /// Always fails: the PJRT client needs the `xla` bindings.
    pub fn new(artifact_dir: impl AsRef<std::path::Path>) -> crate::Result<Self> {
        let _ = artifact_dir.as_ref();
        Err(crate::EhybError::Runtime(
            "PJRT runtime unavailable: built without the `pjrt` feature \
             (enable it with the xla bindings and run `make artifacts`)"
                .into(),
        ))
    }

    pub fn platform(&self) -> String {
        match self.never {}
    }

    pub fn spmv_engine<S: XlaScalar>(&self, _m: &EhybMatrix<S>) -> crate::Result<EhybPjrt<S>> {
        match self.never {}
    }

    pub fn cg_engine<S: XlaScalar>(
        &self,
        _m: &EhybMatrix<S>,
        _diag: &[S],
    ) -> crate::Result<CgPjrt<S>> {
        match self.never {}
    }
}

/// Stub PJRT SpMV engine (uninhabited — see [`PjrtRuntime::new`]).
pub struct EhybPjrt<S: XlaScalar> {
    never: Infallible,
    pub bucket: BucketizedEhyb<S>,
}

impl<S: XlaScalar> EhybPjrt<S> {
    pub fn name(&self) -> &'static str {
        match self.never {}
    }

    pub fn nrows(&self) -> usize {
        match self.never {}
    }

    pub fn nnz(&self) -> usize {
        match self.never {}
    }

    pub fn spmv(&self, _x: &[S], _y: &mut [S]) -> crate::Result<()> {
        match self.never {}
    }

    pub fn spmv_new_order(&self, _xp: &[S]) -> crate::Result<Vec<S>> {
        match self.never {}
    }
}

/// Stub fused CG-step engine (uninhabited).
pub struct CgPjrt<S: XlaScalar> {
    never: Infallible,
    pub bucket: BucketizedEhyb<S>,
}

/// One CG iteration's host-visible state (bucket order) — shape shared
/// with the real client so downstream signatures match.
pub struct CgState<S> {
    pub x: Vec<S>,
    pub r: Vec<S>,
    pub p: Vec<S>,
    pub rz: S,
    /// <p, Ap> from the last step (breakdown monitor).
    pub alpha_den: S,
}

impl<S: XlaScalar> CgPjrt<S> {
    pub fn init(&self, _b_rhs: &[S]) -> CgState<S> {
        match self.never {}
    }

    pub fn step(&self, _st: &mut CgState<S>) -> crate::Result<()> {
        match self.never {}
    }

    pub fn rel_residual(&self, _st: &CgState<S>, _bnorm: f64) -> f64 {
        match self.never {}
    }

    pub fn solve(
        &self,
        _b_rhs: &[S],
        _rtol: f64,
        _max_iters: usize,
    ) -> crate::Result<(Vec<S>, usize, bool)> {
        match self.never {}
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn runtime_errors_without_pjrt_feature() {
        let err = PjrtRuntime::new("/nonexistent-artifacts-dir");
        assert!(err.is_err());
        let msg = format!("{:#}", err.err().unwrap());
        assert!(msg.contains("pjrt"), "{msg}");
    }
}
