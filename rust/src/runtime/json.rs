//! Minimal JSON reader/writer — just enough for `artifacts/manifest.json`
//! and the autotune plan store (objects, arrays, strings, numbers,
//! bools, null; UTF-8 passthrough; no escapes beyond \" \\ \/ \n \t \r).
//! No serde in the offline dependency closure. [`Json::dump`] emits
//! compact, deterministic output (object keys are `BTreeMap`-ordered)
//! that [`Json::parse`] round-trips.

use std::collections::BTreeMap;

#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> crate::Result<Json> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        crate::ensure!(p.i == p.b.len(), "trailing bytes at {}", p.i);
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    /// Serialize compactly. Deterministic (object keys in `BTreeMap`
    /// order) and parseable back by [`Json::parse`]: numbers use Rust's
    /// shortest round-trip `Display`, strings escape exactly the set the
    /// parser understands. Non-finite numbers serialize as `null` (JSON
    /// has no NaN/inf).
    pub fn dump(&self) -> String {
        let mut out = String::new();
        self.write_into(&mut out);
        out
    }

    fn write_into(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if !n.is_finite() {
                    out.push_str("null");
                } else if n.fract() == 0.0 && n.abs() < 9.0e15 {
                    // Integral values print without an exponent/fraction
                    // so `as_usize` consumers read them back exactly.
                    out.push_str(&format!("{}", *n as i64));
                } else {
                    out.push_str(&format!("{n}"));
                }
            }
            Json::Str(s) => {
                out.push('"');
                for c in s.chars() {
                    match c {
                        '"' => out.push_str("\\\""),
                        '\\' => out.push_str("\\\\"),
                        '\n' => out.push_str("\\n"),
                        '\t' => out.push_str("\\t"),
                        '\r' => out.push_str("\\r"),
                        c => out.push(c),
                    }
                }
                out.push('"');
            }
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write_into(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    Json::Str(k.clone()).write_into(out);
                    out.push(':');
                    v.write_into(out);
                }
                out.push('}');
            }
        }
    }
}

/// Build a [`Json::Obj`] from `(key, value)` pairs — the writer-side
/// convenience the plan store uses.
pub fn obj(pairs: impl IntoIterator<Item = (&'static str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn value(&mut self) -> crate::Result<Json> {
        crate::ensure!(self.i < self.b.len(), "unexpected EOF");
        match self.b[self.i] {
            b'{' => self.obj(),
            b'[' => self.arr(),
            b'"' => Ok(Json::Str(self.string()?)),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'n' => self.lit("null", Json::Null),
            _ => self.num(),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> crate::Result<Json> {
        crate::ensure!(self.b[self.i..].starts_with(word.as_bytes()), "bad literal at {}", self.i);
        self.i += word.len();
        Ok(v)
    }

    fn num(&mut self) -> crate::Result<Json> {
        let start = self.i;
        while self.i < self.b.len()
            && matches!(self.b[self.i], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        let n = s
            .parse::<f64>()
            .map_err(|e| crate::EhybError::Parse(format!("bad number {s:?}: {e}")))?;
        Ok(Json::Num(n))
    }

    fn string(&mut self) -> crate::Result<String> {
        crate::ensure!(
            self.i < self.b.len() && self.b[self.i] == b'"',
            "expected string at {}",
            self.i
        );
        self.i += 1;
        let mut out = Vec::new();
        while self.i < self.b.len() {
            match self.b[self.i] {
                b'"' => {
                    self.i += 1;
                    return Ok(String::from_utf8(out)?);
                }
                b'\\' => {
                    self.i += 1;
                    crate::ensure!(self.i < self.b.len(), "EOF in escape");
                    out.push(match self.b[self.i] {
                        b'n' => b'\n',
                        b't' => b'\t',
                        b'r' => b'\r',
                        c @ (b'"' | b'\\' | b'/') => c,
                        c => crate::bail!("unsupported escape \\{}", c as char),
                    });
                    self.i += 1;
                }
                c => {
                    out.push(c);
                    self.i += 1;
                }
            }
        }
        crate::bail!("unterminated string")
    }

    fn obj(&mut self) -> crate::Result<Json> {
        self.i += 1; // '{'
        let mut m = BTreeMap::new();
        self.ws();
        if self.i < self.b.len() && self.b[self.i] == b'}' {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            crate::ensure!(self.b.get(self.i) == Some(&b':'), "expected ':' at {}", self.i);
            self.i += 1;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.b.get(self.i) {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => crate::bail!("expected ',' or '}}' at {}", self.i),
            }
        }
    }

    fn arr(&mut self) -> crate::Result<Json> {
        self.i += 1; // '['
        let mut a = Vec::new();
        self.ws();
        if self.i < self.b.len() && self.b[self.i] == b']' {
            self.i += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            self.ws();
            a.push(self.value()?);
            self.ws();
            match self.b.get(self.i) {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(a));
                }
                _ => crate::bail!("expected ',' or ']' at {}", self.i),
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_shape() {
        let j = Json::parse(r#"{"buckets": [{"name": "tiny", "p": 4, "file": "a.txt"}]}"#).unwrap();
        let b = &j.get("buckets").unwrap().as_arr().unwrap()[0];
        assert_eq!(b.get("name").unwrap().as_str(), Some("tiny"));
        assert_eq!(b.get("p").unwrap().as_usize(), Some(4));
    }

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("3.5").unwrap().as_f64(), Some(3.5));
        assert_eq!(Json::parse("-42").unwrap().as_f64(), Some(-42.0));
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse(r#""hi\n""#).unwrap().as_str(), Some("hi\n"));
    }

    #[test]
    fn parses_nested() {
        let j = Json::parse(r#"{"a": [1, [2, {"b": "c"}], 3]}"#).unwrap();
        let a = j.get("a").unwrap().as_arr().unwrap();
        assert_eq!(a.len(), 3);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse(r#"{"a": }"#).is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("\"unterminated").is_err());
    }

    #[test]
    fn empty_containers() {
        assert_eq!(Json::parse("{}").unwrap(), Json::Obj(Default::default()));
        assert_eq!(Json::parse("[]").unwrap(), Json::Arr(vec![]));
    }

    #[test]
    fn dump_parse_roundtrip() {
        let v = obj([
            ("name", Json::Str("a \"b\"\n\\c".into())),
            ("n", Json::Num(1024.0)),
            ("score", Json::Num(3.25e-7)),
            ("flag", Json::Bool(true)),
            ("none", Json::Null),
            ("arr", Json::Arr(vec![Json::Num(-1.0), Json::Num(0.5)])),
        ]);
        let text = v.dump();
        assert_eq!(Json::parse(&text).unwrap(), v);
        // Integral numbers stay integral in the text form.
        assert!(text.contains("\"n\":1024"), "{text}");
    }

    #[test]
    fn dump_is_deterministic() {
        let v = obj([("b", Json::Num(2.0)), ("a", Json::Num(1.0))]);
        assert_eq!(v.dump(), "{\"a\":1,\"b\":2}");
        assert_eq!(v.dump(), v.dump());
    }

    #[test]
    fn dump_float_roundtrips_bits() {
        for x in [0.1f64, 1.0 / 3.0, 2.5e-9, 123456.789] {
            let text = Json::Num(x).dump();
            let back = Json::parse(&text).unwrap().as_f64().unwrap();
            assert_eq!(back.to_bits(), x.to_bits(), "{text}");
        }
    }

    #[test]
    fn dump_nonfinite_becomes_null() {
        assert_eq!(Json::Num(f64::NAN).dump(), "null");
        assert_eq!(Json::Num(f64::INFINITY).dump(), "null");
    }
}
