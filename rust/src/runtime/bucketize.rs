//! Marshal a preprocessed [`EhybMatrix`] into the dense bucket-shaped
//! arrays an AOT artifact expects (see `python/compile/model.py`
//! for the argument contract):
//!
//! * `ell_cols`/`ell_vals`: `(P, W, R)`, partition-major, width-major,
//!   row-within-partition last; partition-local i32 columns.
//! * `er_cols`/`er_vals`: `(E, WE)` with **bucket-global** columns.
//! * `er_yidx`: `(E,)` bucket-global output rows.
//!
//! Bucket-global index of (partition p, local q) is `p * R + q` — note
//! R is the *bucket's* row stride, not the matrix's `vec_size`, so all
//! new-order indices are remapped here.

use super::manifest::BucketSpec;
use crate::sparse::ehyb::EhybMatrix;
use crate::sparse::scalar::Scalar;

/// Bucket-shaped arrays plus the old-order ↔ bucket-order permutation.
#[derive(Clone, Debug)]
pub struct BucketizedEhyb<S: Scalar> {
    pub spec: BucketSpec,
    /// Original (unpadded) dimension.
    pub n: usize,
    pub ell_cols: Vec<i32>,
    pub ell_vals: Vec<S>,
    pub er_cols: Vec<i32>,
    pub er_vals: Vec<S>,
    pub er_yidx: Vec<i32>,
    /// `perm[old_row] = bucket index`.
    pub perm: Vec<u32>,
}

impl<S: Scalar> BucketizedEhyb<S> {
    /// Lay `m` out in `spec`'s shapes. Fails if the matrix does not fit.
    pub fn build(m: &EhybMatrix<S>, spec: &BucketSpec) -> crate::Result<Self> {
        let max_w = m.slice_width.iter().copied().max().unwrap_or(0) as usize;
        let max_er_w = m.er_slice_width.iter().copied().max().unwrap_or(0) as usize;
        crate::ensure!(
            spec.fits(m.num_parts, m.vec_size, max_w, m.er_rows, max_er_w),
            "matrix (parts={} vec={} w={} er={}x{}) does not fit bucket {} (p={} r={} w={} e={} we={})",
            m.num_parts,
            m.vec_size,
            max_w,
            m.er_rows,
            max_er_w,
            spec.name,
            spec.p,
            spec.r,
            spec.w,
            spec.e,
            spec.we,
        );
        let (pb, wb, rb) = (spec.p, spec.w, spec.r);
        let h = m.slice_height;
        let spp = m.slices_per_part();

        // ELL: (P, W, R) with padding col=0/val=0.
        let mut ell_cols = vec![0i32; pb * wb * rb];
        let mut ell_vals = vec![S::ZERO; pb * wb * rb];
        for p in 0..m.num_parts {
            for ls in 0..spp {
                let s = p * spp + ls;
                let base = m.slice_ptr[s] as usize;
                let w = m.slice_width[s] as usize;
                for lane in 0..h {
                    let q = ls * h + lane; // row within partition
                    for k in 0..w {
                        let idx = base + k * h + lane;
                        let dst = (p * wb + k) * rb + q;
                        ell_cols[dst] = m.ell_cols[idx] as i32;
                        ell_vals[dst] = m.ell_vals[idx];
                    }
                }
            }
        }

        // Remap a matrix new-order index (p*vec_size + q) to bucket order
        // (p*R + q).
        let remap = |new: u32| -> i32 {
            let p = new as usize / m.vec_size;
            let q = new as usize % m.vec_size;
            (p * rb + q) as i32
        };

        // ER: (E, WE); padding rows keep yidx=0 with all-zero values.
        let mut er_cols = vec![0i32; spec.e * spec.we];
        let mut er_vals = vec![S::ZERO; spec.e * spec.we];
        let mut er_yidx = vec![0i32; spec.e];
        for j in 0..m.er_rows {
            let s = j / h;
            let lane = j % h;
            let base = m.er_slice_ptr[s] as usize;
            let w = m.er_slice_width[s] as usize;
            er_yidx[j] = remap(m.y_idx_er[j]);
            for k in 0..w {
                let idx = base + k * h + lane;
                // Skip stored padding (val 0) to keep gathers tight.
                er_cols[j * spec.we + k] = remap(m.er_cols[idx]);
                er_vals[j * spec.we + k] = m.er_vals[idx];
            }
        }

        let perm: Vec<u32> = (0..m.n).map(|old| remap(m.perm[old]) as u32).collect();
        Ok(Self { spec: spec.clone(), n: m.n, ell_cols, ell_vals, er_cols, er_vals, er_yidx, perm })
    }

    /// Old-order x → bucket-order padded xp.
    pub fn permute_x(&self, x: &[S]) -> Vec<S> {
        assert_eq!(x.len(), self.n);
        let mut xp = vec![S::ZERO; self.spec.n()];
        for old in 0..self.n {
            xp[self.perm[old] as usize] = x[old];
        }
        xp
    }

    /// Bucket-order yp → old-order y.
    pub fn unpermute_y(&self, yp: &[S], y: &mut [S]) {
        assert_eq!(y.len(), self.n);
        for old in 0..self.n {
            y[old] = yp[self.perm[old] as usize];
        }
    }

    /// Reference execution of the bucket arrays (the exact math the HLO
    /// performs) — lets tests validate marshalling without PJRT.
    pub fn spmv_reference(&self, xp: &[S]) -> Vec<S> {
        let (pb, wb, rb) = (self.spec.p, self.spec.w, self.spec.r);
        assert_eq!(xp.len(), pb * rb);
        let mut yp = vec![S::ZERO; pb * rb];
        for p in 0..pb {
            for q in 0..rb {
                let mut acc = S::ZERO;
                for k in 0..wb {
                    let idx = (p * wb + k) * rb + q;
                    let c = p * rb + self.ell_cols[idx] as usize;
                    acc = self.ell_vals[idx].mul_add(xp[c], acc);
                }
                yp[p * rb + q] = acc;
            }
        }
        for j in 0..self.spec.e {
            let mut acc = S::ZERO;
            for k in 0..self.spec.we {
                let idx = j * self.spec.we + k;
                acc = self.er_vals[idx].mul_add(xp[self.er_cols[idx] as usize], acc);
            }
            let out = self.er_yidx[j] as usize;
            yp[out] += acc;
        }
        yp
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::preprocess::{EhybPlan, PreprocessConfig};
    use crate::sparse::gen::{poisson2d, unstructured_mesh};
    use crate::util::check::assert_allclose;

    fn spec(p: usize, w: usize, r: usize, e: usize, we: usize) -> BucketSpec {
        BucketSpec {
            kind: "spmv".into(),
            dtype: "f64".into(),
            name: "test".into(),
            p,
            w,
            r,
            e,
            we,
            file: "unused".into(),
        }
    }

    fn check_roundtrip(m: &crate::sparse::csr::Csr<f64>, vec_size: usize, s: BucketSpec) {
        let plan = EhybPlan::build(
            m,
            &PreprocessConfig { vec_size_override: Some(vec_size), ..Default::default() },
        )
        .unwrap();
        let b = BucketizedEhyb::build(&plan.matrix, &s).unwrap();
        let x: Vec<f64> = (0..m.nrows()).map(|i| ((i * 7 + 3) % 13) as f64 * 0.5 - 3.0).collect();
        let xp = b.permute_x(&x);
        let yp = b.spmv_reference(&xp);
        let mut y = vec![0.0; m.nrows()];
        b.unpermute_y(&yp, &mut y);
        let mut y_ref = vec![0.0; m.nrows()];
        m.spmv(&x, &mut y_ref);
        assert_allclose(&y, &y_ref, 1e-10, 1e-10).unwrap();
    }

    #[test]
    fn exact_fit_bucket() {
        let m = poisson2d::<f64>(16, 16);
        check_roundtrip(&m, 64, spec(4, 8, 64, 256, 8));
    }

    #[test]
    fn padded_bucket_larger_r_and_p() {
        // Bucket much larger than the matrix: R and P padding paths.
        let m = poisson2d::<f64>(12, 11);
        check_roundtrip(&m, 32, spec(8, 8, 128, 256, 8));
    }

    #[test]
    fn irregular_matrix() {
        let m = unstructured_mesh::<f64>(20, 20, 0.5, 3);
        check_roundtrip(&m, 96, spec(8, 16, 128, 1024, 8));
    }

    #[test]
    fn rejects_too_small_bucket() {
        let m = poisson2d::<f64>(16, 16);
        let plan = EhybPlan::build(
            &m,
            &PreprocessConfig { vec_size_override: Some(64), ..Default::default() },
        )
        .unwrap();
        assert!(BucketizedEhyb::build(&plan.matrix, &spec(2, 8, 64, 128, 8)).is_err());
    }
}
