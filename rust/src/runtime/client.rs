//! PJRT client wrapper + the EHYB PJRT execution engine.
//!
//! The xla crate's handles wrap raw pointers (`!Send`), so the runtime
//! lives on one thread — the coordinator's service loop owns it and
//! serves SpMV requests over channels (the "leader owns the device"
//! topology; see [`crate::coordinator`]).

use super::bucketize::BucketizedEhyb;
use super::manifest::{BucketSpec, Manifest};
use super::XlaScalar;
use crate::sparse::ehyb::EhybMatrix;
use std::cell::RefCell;
use std::collections::HashMap;
use std::path::Path;
use std::rc::Rc;

/// PJRT CPU client + executable cache keyed by artifact file name.
pub struct PjrtRuntime {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    cache: RefCell<HashMap<String, Rc<xla::PjRtLoadedExecutable>>>,
}

impl PjrtRuntime {
    /// Create a CPU PJRT client and load the artifact manifest.
    pub fn new(artifact_dir: impl AsRef<Path>) -> crate::Result<Self> {
        let client = xla::PjRtClient::cpu()?;
        let manifest = Manifest::load(artifact_dir)?;
        Ok(Self { client, manifest, cache: RefCell::new(HashMap::new()) })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile (or fetch from cache) the artifact for `spec`.
    pub fn load(&self, spec: &BucketSpec) -> crate::Result<Rc<xla::PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.borrow().get(&spec.file) {
            return Ok(exe.clone());
        }
        let path = self.manifest.artifact_path(spec);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| crate::EhybError::Runtime("non-utf8 path".into()))?,
        )?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = Rc::new(self.client.compile(&comp)?);
        self.cache.borrow_mut().insert(spec.file.clone(), exe.clone());
        Ok(exe)
    }

    fn pick_bucket<S: XlaScalar>(
        &self,
        kind: &str,
        m: &EhybMatrix<S>,
    ) -> crate::Result<BucketSpec> {
        let max_w = m.slice_width.iter().copied().max().unwrap_or(0) as usize;
        let max_er_w = m.er_slice_width.iter().copied().max().unwrap_or(0) as usize;
        Ok(self
            .manifest
            .pick(kind, S::DTYPE_TAG, m.num_parts, m.vec_size, max_w, m.er_rows, max_er_w)
            .ok_or_else(|| {
                crate::EhybError::Runtime(format!(
                    "no {kind}/{} bucket fits parts={} vec={} w={} er={}x{}",
                    S::DTYPE_TAG,
                    m.num_parts,
                    m.vec_size,
                    max_w,
                    m.er_rows,
                    max_er_w
                ))
            })?
            .clone())
    }

    /// Build the PJRT SpMV engine for a preprocessed matrix: pick the
    /// smallest fitting `spmv` bucket, marshal, compile.
    pub fn spmv_engine<S: XlaScalar>(&self, m: &EhybMatrix<S>) -> crate::Result<EhybPjrt<S>> {
        let spec = self.pick_bucket("spmv", m)?;
        let exe = self.load(&spec)?;
        let b = BucketizedEhyb::build(m, &spec)?;
        EhybPjrt::new(exe, b, m.nnz())
    }

    /// Build the fused CG-step engine (the `cg` artifact kind): one PJRT
    /// execution per iteration — SpMV, both dot products, the axpys and
    /// the Jacobi preconditioner application all inside one executable.
    /// `diag` is the matrix diagonal in the *original* index space.
    pub fn cg_engine<S: XlaScalar>(
        &self,
        m: &EhybMatrix<S>,
        diag: &[S],
    ) -> crate::Result<CgPjrt<S>> {
        let spec = self.pick_bucket("cg", m)?;
        let exe = self.load(&spec)?;
        let b = BucketizedEhyb::build(m, &spec)?;
        CgPjrt::new(exe, b, diag)
    }
}

/// The EHYB SpMV engine running over PJRT: matrix literals are uploaded
/// once at construction; each `spmv` call marshals only the x vector.
pub struct EhybPjrt<S: XlaScalar> {
    exe: Rc<xla::PjRtLoadedExecutable>,
    pub bucket: BucketizedEhyb<S>,
    nnz: usize,
    // Cached matrix-argument literals (arg order of model.ehyb_spmv).
    ell_cols: xla::Literal,
    ell_vals: xla::Literal,
    er_cols: xla::Literal,
    er_vals: xla::Literal,
    er_yidx: xla::Literal,
}

impl<S: XlaScalar> EhybPjrt<S> {
    fn new(
        exe: Rc<xla::PjRtLoadedExecutable>,
        b: BucketizedEhyb<S>,
        nnz: usize,
    ) -> crate::Result<Self> {
        let s = &b.spec;
        let (p, w, r) = (s.p as i64, s.w as i64, s.r as i64);
        let (e, we) = (s.e as i64, s.we as i64);
        let ell_cols = xla::Literal::vec1(&b.ell_cols).reshape(&[p, w, r])?;
        let ell_vals = xla::Literal::vec1(&b.ell_vals).reshape(&[p, w, r])?;
        let er_cols = xla::Literal::vec1(&b.er_cols).reshape(&[e, we])?;
        let er_vals = xla::Literal::vec1(&b.er_vals).reshape(&[e, we])?;
        let er_yidx = xla::Literal::vec1(&b.er_yidx);
        Ok(Self { exe, bucket: b, nnz, ell_cols, ell_vals, er_cols, er_vals, er_yidx })
    }

    pub fn name(&self) -> &'static str {
        "ehyb-pjrt"
    }

    pub fn nrows(&self) -> usize {
        self.bucket.n
    }

    pub fn nnz(&self) -> usize {
        self.nnz
    }

    /// `y = A x` in the original index space.
    pub fn spmv(&self, x: &[S], y: &mut [S]) -> crate::Result<()> {
        let xp = self.bucket.permute_x(x);
        let yp = self.spmv_new_order(&xp)?;
        self.bucket.unpermute_y(&yp, y);
        Ok(())
    }

    /// `yp = A xp` in bucket order — the hot call the solver loop uses
    /// (keeps vectors permanently permuted, like the CUDA version).
    pub fn spmv_new_order(&self, xp: &[S]) -> crate::Result<Vec<S>> {
        crate::ensure!(xp.len() == self.bucket.spec.n(), "xp length");
        let x_lit = xla::Literal::vec1(xp);
        // Borrowed literals: the matrix-argument uploads are reused
        // across calls (deep-cloning Literals would copy the arrays).
        let result = self.exe.execute::<&xla::Literal>(&[
            &x_lit,
            &self.ell_cols,
            &self.ell_vals,
            &self.er_cols,
            &self.er_vals,
            &self.er_yidx,
        ])?;
        let out = result[0][0].to_literal_sync()?.to_tuple1()?;
        Ok(out.to_vec::<S>()?)
    }
}

/// Fused CG-step engine over the `cg` artifact
/// (`python/compile/model.py::cg_step`): Jacobi-preconditioned CG with
/// the whole iteration body in one XLA executable. Vectors live in
/// bucket order between iterations (permutation only at solve
/// boundaries, like the CUDA implementation).
pub struct CgPjrt<S: XlaScalar> {
    exe: Rc<xla::PjRtLoadedExecutable>,
    pub bucket: BucketizedEhyb<S>,
    ell_cols: xla::Literal,
    ell_vals: xla::Literal,
    er_cols: xla::Literal,
    er_vals: xla::Literal,
    er_yidx: xla::Literal,
    diag_inv: xla::Literal,
}

/// One CG iteration's host-visible state (bucket order).
pub struct CgState<S> {
    pub x: Vec<S>,
    pub r: Vec<S>,
    pub p: Vec<S>,
    pub rz: S,
    /// <p, Ap> from the last step (breakdown monitor).
    pub alpha_den: S,
}

impl<S: XlaScalar> CgPjrt<S> {
    fn new(
        exe: Rc<xla::PjRtLoadedExecutable>,
        b: BucketizedEhyb<S>,
        diag: &[S],
    ) -> crate::Result<Self> {
        let s = &b.spec;
        let (p, w, r) = (s.p as i64, s.w as i64, s.r as i64);
        let (e, we) = (s.e as i64, s.we as i64);
        // 1/diag in bucket order; padded slots get 0 (their residual
        // stays 0, so they never enter the Krylov space).
        let mut dinv = vec![<S as crate::sparse::scalar::Scalar>::ZERO; s.n()];
        for old in 0..b.n {
            let d = diag[old];
            dinv[b.perm[old] as usize] =
                if d.to_f64().abs() < 1e-300 { S::ONE } else { S::ONE / d };
        }
        Ok(Self {
            exe,
            ell_cols: xla::Literal::vec1(&b.ell_cols).reshape(&[p, w, r])?,
            ell_vals: xla::Literal::vec1(&b.ell_vals).reshape(&[p, w, r])?,
            er_cols: xla::Literal::vec1(&b.er_cols).reshape(&[e, we])?,
            er_vals: xla::Literal::vec1(&b.er_vals).reshape(&[e, we])?,
            er_yidx: xla::Literal::vec1(&b.er_yidx),
            diag_inv: xla::Literal::vec1(&dinv),
            bucket: b,
        })
    }

    /// Initial state for right-hand side `b_rhs` (original order), x0=0:
    /// r0 = b, z0 = M⁻¹ r0, p0 = z0, rz = <r0, z0>.
    pub fn init(&self, b_rhs: &[S]) -> CgState<S> {
        let r = self.bucket.permute_x(b_rhs);
        let dinv = self.diag_inv.to_vec::<S>().expect("diag_inv literal readback");
        let z: Vec<S> = dinv.iter().zip(&r).map(|(&d, &ri)| d * ri).collect();
        let rz = crate::sparse::scalar::dot(&r, &z);
        CgState {
            x: vec![<S as crate::sparse::scalar::Scalar>::ZERO; r.len()],
            r,
            p: z,
            rz,
            alpha_den: <S as crate::sparse::scalar::Scalar>::ZERO,
        }
    }

    /// Run one fused iteration on the device state.
    pub fn step(&self, st: &mut CgState<S>) -> crate::Result<()> {
        let xk = xla::Literal::vec1(&st.x);
        let rk = xla::Literal::vec1(&st.r);
        let pk = xla::Literal::vec1(&st.p);
        let rz = xla::Literal::from(st.rz);
        let result = self.exe.execute::<&xla::Literal>(&[
            &xk,
            &rk,
            &pk,
            &rz,
            &self.ell_cols,
            &self.ell_vals,
            &self.er_cols,
            &self.er_vals,
            &self.er_yidx,
            &self.diag_inv,
        ])?;
        let outs = result[0][0].to_literal_sync()?.to_tuple()?;
        crate::ensure!(outs.len() == 5, "cg artifact returned {} outputs", outs.len());
        st.x = outs[0].to_vec::<S>()?;
        st.r = outs[1].to_vec::<S>()?;
        st.p = outs[2].to_vec::<S>()?;
        st.rz = outs[3].get_first_element::<S>()?;
        st.alpha_den = outs[4].get_first_element::<S>()?;
        Ok(())
    }

    /// Relative residual ‖r‖/‖b‖ of the current state.
    pub fn rel_residual(&self, st: &CgState<S>, bnorm: f64) -> f64 {
        crate::sparse::scalar::norm2(&st.r).to_f64() / bnorm.max(1e-300)
    }

    /// Full solve: returns (x in original order, iterations, converged).
    pub fn solve(
        &self,
        b_rhs: &[S],
        rtol: f64,
        max_iters: usize,
    ) -> crate::Result<(Vec<S>, usize, bool)> {
        let bnorm = crate::sparse::scalar::norm2(b_rhs).to_f64();
        let mut st = self.init(b_rhs);
        let mut converged = false;
        let mut iters = 0;
        for k in 0..max_iters {
            self.step(&mut st)?;
            iters = k + 1;
            if self.rel_residual(&st, bnorm) < rtol {
                converged = true;
                break;
            }
        }
        let mut x = vec![<S as crate::sparse::scalar::Scalar>::ZERO; self.bucket.n];
        self.bucket.unpermute_y(&st.x, &mut x);
        Ok((x, iters, converged))
    }
}

#[cfg(test)]
mod tests {
    // PJRT round-trip tests live in rust/tests/runtime_pjrt.rs (they
    // need built artifacts); unit tests here cover pure logic only.
    use super::*;

    #[test]
    fn runtime_errors_without_artifacts() {
        let err = PjrtRuntime::new("/nonexistent-artifacts-dir");
        assert!(err.is_err());
        let msg = format!("{:#}", err.err().unwrap());
        assert!(msg.contains("make artifacts"), "{msg}");
    }
}
