//! Artifact manifest: which HLO files exist at which bucket shapes.

use super::json::Json;
use std::path::{Path, PathBuf};

/// One compiled artifact's shape contract (mirrors aot.py's BUCKETS).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct BucketSpec {
    pub kind: String,
    pub dtype: String,
    pub name: String,
    pub p: usize,
    pub w: usize,
    pub r: usize,
    pub e: usize,
    pub we: usize,
    pub file: String,
}

impl BucketSpec {
    /// Padded dimension the artifact computes over.
    pub fn n(&self) -> usize {
        self.p * self.r
    }

    /// Can a matrix with these EHYB stats run in this bucket?
    pub fn fits(
        &self,
        num_parts: usize,
        vec_size: usize,
        max_width: usize,
        er_rows: usize,
        er_width: usize,
    ) -> bool {
        num_parts <= self.p
            && vec_size <= self.r
            && max_width <= self.w
            && er_rows <= self.e
            && er_width <= self.we
    }
}

#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub buckets: Vec<BucketSpec>,
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> crate::Result<Manifest> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path).map_err(|e| {
            crate::EhybError::Io(format!("read {path:?}: {e} (run `make artifacts` first)"))
        })?;
        Self::parse(&text, dir)
    }

    pub fn parse(text: &str, dir: PathBuf) -> crate::Result<Manifest> {
        let j = Json::parse(text)?;
        let arr = j
            .get("buckets")
            .and_then(|b| b.as_arr())
            .ok_or_else(|| crate::EhybError::Parse("manifest missing buckets".into()))?;
        let mut buckets = Vec::with_capacity(arr.len());
        for b in arr {
            let s = |k: &str| -> crate::Result<String> {
                Ok(b.get(k)
                    .and_then(|v| v.as_str())
                    .ok_or_else(|| crate::EhybError::Parse(format!("bucket missing {k}")))?
                    .to_string())
            };
            let u = |k: &str| -> crate::Result<usize> {
                b.get(k)
                    .and_then(|v| v.as_usize())
                    .ok_or_else(|| crate::EhybError::Parse(format!("bucket missing {k}")))
            };
            buckets.push(BucketSpec {
                kind: s("kind")?,
                dtype: s("dtype")?,
                name: s("name")?,
                p: u("p")?,
                w: u("w")?,
                r: u("r")?,
                e: u("e")?,
                we: u("we")?,
                file: s("file")?,
            });
        }
        Ok(Manifest { dir, buckets })
    }

    /// The smallest bucket (by padded n, then slot count) of the given
    /// kind/dtype that fits the matrix.
    pub fn pick(
        &self,
        kind: &str,
        dtype: &str,
        num_parts: usize,
        vec_size: usize,
        max_width: usize,
        er_rows: usize,
        er_width: usize,
    ) -> Option<&BucketSpec> {
        self.buckets
            .iter()
            .filter(|b| b.kind == kind && b.dtype == dtype)
            .filter(|b| b.fits(num_parts, vec_size, max_width, er_rows, er_width))
            .min_by_key(|b| (b.n(), b.p * b.w * b.r))
    }

    pub fn artifact_path(&self, b: &BucketSpec) -> PathBuf {
        self.dir.join(&b.file)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{"buckets": [
        {"kind":"spmv","dtype":"f64","name":"tiny","p":4,"w":8,"r":64,"e":64,"we":4,"n":256,"file":"spmv_f64_tiny.hlo.txt","sha256":"x"},
        {"kind":"spmv","dtype":"f64","name":"small","p":16,"w":16,"r":128,"e":512,"we":8,"n":2048,"file":"spmv_f64_small.hlo.txt","sha256":"y"}
    ]}"#;

    #[test]
    fn parse_sample() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/tmp")).unwrap();
        assert_eq!(m.buckets.len(), 2);
        assert_eq!(m.buckets[0].n(), 256);
    }

    #[test]
    fn pick_smallest_fitting() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/tmp")).unwrap();
        let b = m.pick("spmv", "f64", 4, 64, 5, 10, 2).unwrap();
        assert_eq!(b.name, "tiny");
        let b = m.pick("spmv", "f64", 4, 64, 12, 10, 2).unwrap();
        assert_eq!(b.name, "small"); // width 12 > tiny's 8
        assert!(m.pick("spmv", "f64", 100, 64, 5, 10, 2).is_none());
        assert!(m.pick("spmv", "f32", 4, 64, 5, 10, 2).is_none());
    }

    #[test]
    fn real_manifest_if_built() {
        // When `make artifacts` has run, the real manifest must load.
        let dir = concat!(env!("CARGO_MANIFEST_DIR"), "/artifacts");
        if std::path::Path::new(dir).join("manifest.json").exists() {
            let m = Manifest::load(dir).unwrap();
            assert!(m.pick("spmv", "f64", 4, 64, 8, 64, 4).is_some());
            assert!(m.pick("cg", "f32", 4, 64, 8, 64, 4).is_some());
        }
    }
}
