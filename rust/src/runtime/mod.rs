//! PJRT runtime: load the AOT-compiled HLO artifacts (built once by
//! `python/compile/aot.py`) and execute them from the Rust hot path.
//! Python is never on the request path — after `make artifacts` the
//! binary is self-contained.
//!
//! * [`manifest`] — parses `artifacts/manifest.json` (a dependency-free
//!   JSON reader lives in [`json`]).
//! * [`bucketize`] — XLA executables are shape-static, so a preprocessed
//!   [`EhybMatrix`](crate::sparse::ehyb::EhybMatrix) is padded into the
//!   smallest compiled bucket that fits (padding is col=0/val=0 and
//!   zero x entries — numerically inert).
//! * [`client`] — `PjRtClient::cpu()` → `HloModuleProto::from_text_file`
//!   → `compile` → `execute`, with an executable cache keyed by
//!   artifact file; the [`client::EhybPjrt`] engine implements
//!   [`SpmvEngine`](crate::spmv::SpmvEngine) so the whole harness can
//!   run over PJRT.
//!
//! Interchange is HLO **text**, not serialized protos: jax ≥ 0.5 emits
//! 64-bit instruction ids that xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see /opt/xla-example/README.md).
//!
//! The real client needs the `xla` bindings, which are not in the
//! offline dependency closure — it is gated behind the **`pjrt`**
//! cargo feature. Without the feature an API-compatible stub keeps
//! every caller compiling: `PjrtRuntime::new` returns an error, so the
//! artifact-missing fallback paths (CPU engines) run instead.

pub mod json;
pub mod manifest;
pub mod bucketize;
#[cfg(feature = "pjrt")]
pub mod client;
#[cfg(not(feature = "pjrt"))]
#[path = "client_stub.rs"]
pub mod client;

pub use bucketize::BucketizedEhyb;
pub use client::{EhybPjrt, PjrtRuntime};
pub use manifest::{BucketSpec, Manifest};

use crate::sparse::scalar::Scalar;

/// Scalars that can cross the PJRT literal boundary.
#[cfg(feature = "pjrt")]
pub trait XlaScalar: Scalar + xla::NativeType + xla::ArrayElement {
    /// dtype tag used in artifact names ("f32"/"f64").
    const DTYPE_TAG: &'static str;
}

/// Scalars that can cross the PJRT literal boundary. Without the
/// `pjrt` feature the bound degenerates to [`Scalar`] so generic call
/// sites (harness, CLI) compile unchanged.
#[cfg(not(feature = "pjrt"))]
pub trait XlaScalar: Scalar {
    /// dtype tag used in artifact names ("f32"/"f64").
    const DTYPE_TAG: &'static str;
}

impl XlaScalar for f32 {
    const DTYPE_TAG: &'static str = "f32";
}
impl XlaScalar for f64 {
    const DTYPE_TAG: &'static str = "f64";
}
