//! Preconditioners. §6's argument: on GPUs, SPAI-family preconditioners
//! (refs [10][13][21]) keep SpMV the dominant cost — applying M⁻¹ *is*
//! an SpMV — so EHYB accelerates the whole solve. Implemented here:
//!
//! * [`Jacobi`] — diagonal scaling, the baseline.
//! * [`Spai0`] — SPAI(0): M has the sparsity of I (diagonal) chosen to
//!   minimize ‖AM − I‖_F columnwise, i.e. m_jj = a_jj / ‖A e_j‖².
//!   (The classic static-pattern SPAI with unit pattern; cheap, robust,
//!   and exactly what `spai` codes fall back to on FEM matrices.)

use crate::sparse::csr::Csr;
use crate::sparse::scalar::Scalar;

pub trait Preconditioner<S: Scalar>: Send + Sync {
    /// z = M⁻¹ r (approximately A⁻¹ r).
    fn apply(&self, r: &[S], z: &mut [S]);
    fn name(&self) -> &'static str;
}

/// Identity (no preconditioning).
pub struct Identity;

impl<S: Scalar> Preconditioner<S> for Identity {
    fn apply(&self, r: &[S], z: &mut [S]) {
        z.copy_from_slice(r);
    }
    fn name(&self) -> &'static str {
        "none"
    }
}

/// Jacobi: z = D⁻¹ r.
pub struct Jacobi<S: Scalar> {
    inv_diag: Vec<S>,
}

impl<S: Scalar> Jacobi<S> {
    pub fn new(a: &Csr<S>) -> Self {
        let inv_diag = a
            .diagonal()
            .into_iter()
            .map(|d| if d.to_f64().abs() < 1e-300 { S::ONE } else { S::ONE / d })
            .collect();
        Self { inv_diag }
    }

    pub fn inv_diag(&self) -> &[S] {
        &self.inv_diag
    }
}

impl<S: Scalar> Preconditioner<S> for Jacobi<S> {
    fn apply(&self, r: &[S], z: &mut [S]) {
        for i in 0..r.len() {
            z[i] = self.inv_diag[i] * r[i];
        }
    }
    fn name(&self) -> &'static str {
        "jacobi"
    }
}

/// SPAI(0): diagonal M minimizing ‖AM − I‖_F ⇒ m_jj = a_jj / Σ_i a_ij².
pub struct Spai0<S: Scalar> {
    m_diag: Vec<S>,
}

impl<S: Scalar> Spai0<S> {
    pub fn new(a: &Csr<S>) -> Self {
        let n = a.nrows();
        // Column sums of squares and the diagonal.
        let mut colsq = vec![0.0f64; n];
        for i in 0..n {
            let (cols, vals) = a.row(i);
            for (&c, &v) in cols.iter().zip(vals) {
                colsq[c as usize] += v.to_f64() * v.to_f64();
            }
        }
        let diag = a.diagonal();
        let m_diag = (0..n)
            .map(|j| {
                let d = diag[j].to_f64();
                if colsq[j] < 1e-300 {
                    S::ONE
                } else {
                    S::from_f64(d / colsq[j])
                }
            })
            .collect();
        Self { m_diag }
    }
}

impl<S: Scalar> Preconditioner<S> for Spai0<S> {
    fn apply(&self, r: &[S], z: &mut [S]) {
        for i in 0..r.len() {
            z[i] = self.m_diag[i] * r[i];
        }
    }
    fn name(&self) -> &'static str {
        "spai0"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sparse::gen::{diag_dominant, poisson2d, unstructured_mesh};

    #[test]
    fn jacobi_inverts_diagonal() {
        let a = poisson2d::<f64>(4, 4);
        let j = Jacobi::new(&a);
        let r = vec![4.0; 16];
        let mut z = vec![0.0; 16];
        j.apply(&r, &mut z);
        assert!(z.iter().all(|&v| (v - 1.0).abs() < 1e-12));
    }

    #[test]
    fn spai0_reduces_residual_contraction() {
        // For diagonally dominant A, one step x += M r should contract
        // the residual of Ax=b.
        let a = diag_dominant(&unstructured_mesh::<f64>(12, 12, 0.4, 5));
        let n = a.nrows();
        let s = Spai0::new(&a);
        let b: Vec<f64> = (0..n).map(|i| ((i % 5) as f64) - 2.0).collect();
        // r0 = b (x=0); x1 = M b; r1 = b - A x1.
        let mut x1 = vec![0.0; n];
        s.apply(&b, &mut x1);
        let mut ax = vec![0.0; n];
        a.spmv(&x1, &mut ax);
        let r1: f64 = b.iter().zip(&ax).map(|(bi, ai)| (bi - ai) * (bi - ai)).sum::<f64>().sqrt();
        let r0: f64 = b.iter().map(|bi| bi * bi).sum::<f64>().sqrt();
        assert!(r1 < r0, "no contraction: {r1} >= {r0}");
    }

    #[test]
    fn identity_is_identity() {
        let id = Identity;
        let r = vec![1.0f32, -2.0, 3.0];
        let mut z = vec![0.0f32; 3];
        Preconditioner::<f32>::apply(&id, &r, &mut z);
        assert_eq!(z, r);
    }

    #[test]
    fn zero_diagonal_guarded() {
        use crate::sparse::coo::Coo;
        let a = Coo::<f64>::from_triplets(2, 2, vec![(0, 1, 1.0), (1, 0, 1.0)]).unwrap().to_csr();
        let j = Jacobi::new(&a);
        let mut z = vec![0.0; 2];
        j.apply(&[1.0, 1.0], &mut z);
        assert!(z.iter().all(|v| v.is_finite()));
    }
}
