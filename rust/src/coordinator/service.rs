//! SpMV service: a dedicated thread owns the execution engine (the PJRT
//! handles are `!Send`, so the device lives where it was created — the
//! leader/worker topology of GPU serving systems) and serves requests
//! from any number of worker threads over an MPSC channel, draining
//! pending requests in batches to amortize wakeups.

use super::metrics::ServiceMetrics;
use crate::sparse::scalar::Scalar;
use crate::util::Timer;
use std::sync::mpsc;
use std::sync::Arc;

enum Msg<S> {
    Spmv { x: Vec<S>, reply: mpsc::Sender<Vec<S>> },
    Shutdown,
}

/// Handle to a running SpMV service. Clone-able; each clone can submit.
pub struct SpmvClient<S> {
    tx: mpsc::Sender<Msg<S>>,
    nrows: usize,
}

impl<S> Clone for SpmvClient<S> {
    fn clone(&self) -> Self {
        Self { tx: self.tx.clone(), nrows: self.nrows }
    }
}

impl<S: Scalar> SpmvClient<S> {
    /// Synchronous SpMV round-trip through the service.
    pub fn spmv(&self, x: &[S]) -> crate::Result<Vec<S>> {
        let (reply_tx, reply_rx) = mpsc::channel();
        self.tx
            .send(Msg::Spmv { x: x.to_vec(), reply: reply_tx })
            .map_err(|_| anyhow::anyhow!("service stopped"))?;
        Ok(reply_rx.recv().map_err(|_| anyhow::anyhow!("service dropped reply"))?)
    }

    /// Fire-and-forget submit; returns the receiver for the result.
    pub fn submit(&self, x: Vec<S>) -> crate::Result<mpsc::Receiver<Vec<S>>> {
        let (reply_tx, reply_rx) = mpsc::channel();
        self.tx
            .send(Msg::Spmv { x, reply: reply_tx })
            .map_err(|_| anyhow::anyhow!("service stopped"))?;
        Ok(reply_rx)
    }

    pub fn nrows(&self) -> usize {
        self.nrows
    }
}

/// A running service; dropping shuts it down.
pub struct SpmvService<S> {
    client: SpmvClient<S>,
    pub metrics: Arc<ServiceMetrics>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl<S: Scalar> SpmvService<S> {
    /// Spawn the service thread. `make_engine` runs *inside* the thread
    /// (so it may construct `!Send` PJRT state) and returns the SpMV
    /// closure plus the row count. `max_batch` bounds how many pending
    /// requests one drain processes.
    pub fn spawn<F, G>(make_engine: F, nrows: usize, max_batch: usize) -> crate::Result<Self>
    where
        F: FnOnce() -> crate::Result<G> + Send + 'static,
        G: FnMut(&[S], &mut [S]),
        S: 'static,
    {
        let (tx, rx) = mpsc::channel::<Msg<S>>();
        let metrics = Arc::new(ServiceMetrics::new());
        let metrics_thread = metrics.clone();
        let (ready_tx, ready_rx) = mpsc::channel::<crate::Result<()>>();
        let handle = std::thread::Builder::new().name("spmv-service".into()).spawn(move || {
            let mut engine = match make_engine() {
                Ok(e) => {
                    let _ = ready_tx.send(Ok(()));
                    e
                }
                Err(e) => {
                    let _ = ready_tx.send(Err(e));
                    return;
                }
            };
            let mut y = vec![S::ZERO; nrows];
            let mut batch: Vec<(Vec<S>, mpsc::Sender<Vec<S>>)> = Vec::new();
            'outer: loop {
                // Block for the first request, then drain what's queued.
                match rx.recv() {
                    Ok(Msg::Spmv { x, reply }) => batch.push((x, reply)),
                    Ok(Msg::Shutdown) | Err(_) => break 'outer,
                }
                while batch.len() < max_batch {
                    match rx.try_recv() {
                        Ok(Msg::Spmv { x, reply }) => batch.push((x, reply)),
                        Ok(Msg::Shutdown) => {
                            // Serve what we have, then stop.
                            for (x, reply) in batch.drain(..) {
                                let t = Timer::start();
                                engine(&x, &mut y);
                                metrics_thread.spmv_latency.record(t.elapsed_secs());
                                let _ = reply.send(y.clone());
                            }
                            break 'outer;
                        }
                        Err(_) => break,
                    }
                }
                metrics_thread
                    .requests
                    .fetch_add(batch.len() as u64, std::sync::atomic::Ordering::Relaxed);
                metrics_thread.batches.fetch_add(1, std::sync::atomic::Ordering::Relaxed);
                for (x, reply) in batch.drain(..) {
                    let t = Timer::start();
                    engine(&x, &mut y);
                    metrics_thread.spmv_latency.record(t.elapsed_secs());
                    let _ = reply.send(y.clone());
                }
            }
        })?;
        ready_rx.recv().map_err(|_| anyhow::anyhow!("service died during init"))??;
        Ok(Self { client: SpmvClient { tx, nrows }, metrics, handle: Some(handle) })
    }

    pub fn client(&self) -> SpmvClient<S> {
        self.client.clone()
    }
}

impl<S> Drop for SpmvService<S> {
    fn drop(&mut self) {
        let _ = self.client.tx.send(Msg::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::preprocess::{EhybPlan, PreprocessConfig};
    use crate::sparse::gen::poisson2d;
    use crate::spmv::ehyb_cpu::EhybCpu;
    use crate::spmv::SpmvEngine;

    fn service() -> (SpmvService<f64>, crate::sparse::csr::Csr<f64>) {
        let a = poisson2d::<f64>(16, 16);
        let a2 = a.clone();
        let svc = SpmvService::spawn(
            move || {
                let plan = EhybPlan::build(
                    &a2,
                    &PreprocessConfig { vec_size_override: Some(64), ..Default::default() },
                )?;
                let engine = EhybCpu::new(&plan);
                Ok(move |x: &[f64], y: &mut [f64]| engine.spmv(x, y))
            },
            256,
            8,
        )
        .unwrap();
        (svc, a)
    }

    #[test]
    fn serves_correct_results() {
        let (svc, a) = service();
        let client = svc.client();
        let x: Vec<f64> = (0..256).map(|i| (i as f64 * 0.01).sin()).collect();
        let y = client.spmv(&x).unwrap();
        let mut want = vec![0.0; 256];
        a.spmv(&x, &mut want);
        for i in 0..256 {
            assert!((y[i] - want[i]).abs() < 1e-12);
        }
        assert_eq!(svc.metrics.spmv_latency.count(), 1);
    }

    #[test]
    fn concurrent_clients() {
        let (svc, a) = service();
        let mut handles = Vec::new();
        for t in 0..8 {
            let client = svc.client();
            let a = a.clone();
            handles.push(std::thread::spawn(move || {
                let x: Vec<f64> = (0..256).map(|i| ((i + t * 31) as f64 * 0.02).cos()).collect();
                let y = client.spmv(&x).unwrap();
                let mut want = vec![0.0; 256];
                a.spmv(&x, &mut want);
                for i in 0..256 {
                    assert!((y[i] - want[i]).abs() < 1e-12);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(svc.metrics.requests.load(std::sync::atomic::Ordering::Relaxed), 8);
        assert!(svc.metrics.mean_batch_size() >= 1.0);
    }

    #[test]
    fn async_submit() {
        let (svc, _) = service();
        let client = svc.client();
        let rx1 = client.submit(vec![1.0; 256]).unwrap();
        let rx2 = client.submit(vec![2.0; 256]).unwrap();
        let y1 = rx1.recv().unwrap();
        let y2 = rx2.recv().unwrap();
        for i in 0..256 {
            assert!((y2[i] - 2.0 * y1[i]).abs() < 1e-9); // linearity
        }
    }

    #[test]
    fn init_failure_propagates() {
        let r: crate::Result<SpmvService<f64>> = SpmvService::spawn(
            || -> crate::Result<fn(&[f64], &mut [f64])> { anyhow::bail!("boom") },
            4,
            1,
        );
        assert!(r.is_err());
    }
}
