//! SpMV service: a dedicated thread owns the execution engine (the PJRT
//! handles are `!Send`, so the device lives where it was created — the
//! leader/worker topology of GPU serving systems) and serves requests
//! from any number of worker threads over an MPSC channel.
//!
//! Pending requests are drained in batches and executed as **one fused
//! batched kernel call** over borrowed [`VecBatch`]/[`VecBatchMut`]
//! views of two persistent contiguous buffers: the matrix streams once
//! per drain instead of once per request, which is the whole game for a
//! memory-bound kernel. Requests hand their `x` allocation over
//! ([`SpmvClient::spmv`] takes `Vec<S>` — no hidden copy on the client
//! side), replies reuse that same allocation for the output, and the
//! two batch buffers persist across drains — steady state does zero
//! per-request allocation.
//!
//! The request queue is **bounded** (a `sync_channel` of depth
//! `queue_bound`, default [`DEFAULT_QUEUE_BOUND`]): when producers
//! outrun the engine, submissions beyond the bound are **shed** with a
//! typed [`EhybError::Overloaded`] instead of growing an unbounded
//! backlog — latency stays bounded and callers get an explicit signal
//! to back off (counted in [`ServiceMetrics::shed`]).
//!
//! An **adaptive** service ([`SpmvService::spawn_adaptive`] /
//! `SpmvContext::serve_adaptive`) additionally floats the fused-batch
//! limit on the observed shed rate: sheds halve it (shorter kernel
//! calls return replies — and queue slots — sooner under overload),
//! idle drains double it back toward the cap (full fusion for
//! well-behaved load). The live limit is published in
//! [`ServiceMetrics::adaptive_max_batch`].
//!
//! # Resilience contract
//!
//! The drain loop is **panic-isolated**: each fused kernel call runs
//! under `catch_unwind`, so an engine panic maps to a typed
//! [`EhybError::EngineFault`] reply for exactly the requests in the
//! poisoned batch — it is the *engine* that is quarantined (dropped
//! and respawned via the `make_engine` factory), never the service.
//! Requests may carry an optional **deadline** checked at drain time:
//! an expired request replies [`EhybError::DeadlineExceeded`] without
//! occupying kernel width. [`SpmvClient::spmv_with_retry`] layers
//! bounded exponential backoff (deterministic
//! [`crate::util::prng`]-seeded jitter) over transient faults —
//! `Overloaded` and `EngineFault` — and never retries permanent
//! errors. Faults, respawns, and deadline misses are counted in
//! [`ServiceMetrics`].

use crate::api::batch::{VecBatch, VecBatchMut};
use crate::api::error::EhybError;
use crate::resilience::RetryPolicy;
use crate::sparse::scalar::Scalar;
use crate::telemetry::{ServiceMetrics, Telemetry, TraceId};
use crate::util::prng::Xoshiro256;
use crate::util::Timer;
use std::sync::mpsc;
use std::sync::Arc;
use std::time::Instant;

/// Request-queue depth used by the convenience entry points
/// ([`SpmvService::spawn`], `SpmvContext::serve`). Large enough that
/// well-behaved workloads never shed, small enough to bound queueing
/// latency; pick explicitly via `spawn_bounded` / `serve_bounded`.
pub const DEFAULT_QUEUE_BOUND: usize = 1024;

/// The batched kernel a service thread runs per drain:
/// `ys.col(b) = A xs.col(b)`. Built inside the service thread (so it
/// may close over `!Send` PJRT state).
pub type BatchKernel<S> = Box<dyn FnMut(VecBatch<'_, S>, &mut VecBatchMut<'_, S>)>;

/// Receiver side of one in-flight request. The service replies with
/// the result vector or a typed serving error
/// ([`EhybError::EngineFault`], [`EhybError::DeadlineExceeded`]).
pub type ReplyReceiver<S> = mpsc::Receiver<crate::Result<Vec<S>>>;

enum Msg<S> {
    Spmv {
        x: Vec<S>,
        deadline: Option<Instant>,
        reply: mpsc::Sender<crate::Result<Vec<S>>>,
        trace: u64,
        enq_nanos: u64,
    },
    Shutdown,
}

/// One drained request awaiting execution.
struct Request<S> {
    x: Vec<S>,
    deadline: Option<Instant>,
    reply: mpsc::Sender<crate::Result<Vec<S>>>,
    trace: u64,
    enq_nanos: u64,
}

/// Handle to a running SpMV service. Clone-able; each clone can submit.
pub struct SpmvClient<S> {
    tx: mpsc::SyncSender<Msg<S>>,
    nrows: usize,
    queue_bound: usize,
    metrics: Arc<ServiceMetrics>,
    tel: Telemetry,
}

impl<S> Clone for SpmvClient<S> {
    fn clone(&self) -> Self {
        Self {
            tx: self.tx.clone(),
            nrows: self.nrows,
            queue_bound: self.queue_bound,
            metrics: self.metrics.clone(),
            tel: self.tel.clone(),
        }
    }
}

impl<S: Scalar> SpmvClient<S> {
    /// Synchronous SpMV round-trip through the service. Takes `x` by
    /// value — the allocation travels to the service and comes back as
    /// the reply buffer, so the round-trip copies nothing. Sheds with
    /// [`EhybError::Overloaded`] when the bounded queue is full; a
    /// quarantined batch surfaces as [`EhybError::EngineFault`].
    pub fn spmv(&self, x: Vec<S>) -> crate::Result<Vec<S>> {
        let rx = self.submit(x)?;
        rx.recv().unwrap_or(Err(EhybError::ServiceStopped))
    }

    /// [`Self::spmv`] with a drain-time deadline: if the service has
    /// not *started* serving the request by `deadline`, it is dropped
    /// with [`EhybError::DeadlineExceeded`] instead of occupying
    /// kernel width (counted in [`ServiceMetrics::deadline_misses`]).
    pub fn spmv_deadline(&self, x: Vec<S>, deadline: Instant) -> crate::Result<Vec<S>> {
        let rx = self.submit_with_deadline(x, Some(deadline))?;
        rx.recv().unwrap_or(Err(EhybError::ServiceStopped))
    }

    /// [`Self::spmv`] with bounded retry/backoff: transient failures
    /// ([`EhybError::Overloaded`] backpressure and
    /// [`EhybError::EngineFault`] quarantines) sleep a deterministic
    /// jittered exponential backoff and retry, up to
    /// `policy.max_attempts`; permanent errors (dimension mismatch,
    /// parse/validation, [`EhybError::ServiceStopped`]) return
    /// immediately. Costs one defensive clone of `x` per attempt that
    /// still has retries left: an accepted request consumes its
    /// allocation and a quarantined batch cannot hand it back (a shed
    /// does — the clone is dropped and the returned buffer reused).
    pub fn spmv_with_retry(&self, x: Vec<S>, policy: &RetryPolicy) -> crate::Result<Vec<S>> {
        let attempts = policy.max_attempts.max(1);
        let mut rng = Xoshiro256::new(policy.seed);
        let mut x = x;
        // Each attempt is its own trace (so every trace keeps exactly
        // one terminal event); a `retry` event on the new trace links
        // back to the attempt it replaces via `prev=<trace>`.
        let mut prev_trace = TraceId::NONE;
        let link = |trace: TraceId, attempt: usize, prev: TraceId| {
            if attempt > 0 && !trace.is_none() {
                self.tel.event("retry", trace, format!("attempt={} prev={}", attempt + 1, prev.0));
            }
        };
        for attempt in 0..attempts {
            let last = attempt + 1 == attempts;
            let backup = if last { None } else { Some(x.clone()) };
            let err = match self.try_submit_traced(x, None) {
                Ok((rx, trace)) => {
                    link(trace, attempt, prev_trace);
                    prev_trace = trace;
                    match rx.recv().unwrap_or(Err(EhybError::ServiceStopped)) {
                        Ok(y) => return Ok(y),
                        Err(e) => e,
                    }
                }
                Err((e, buffer_back, trace)) => {
                    link(trace, attempt, prev_trace);
                    prev_trace = trace;
                    if !last && policy.retries(&e) {
                        // The request was never accepted, so the shed
                        // handed our buffer back: retry with it.
                        x = buffer_back;
                        std::thread::sleep(policy.delay(attempt, &mut rng));
                        continue;
                    }
                    return Err(e);
                }
            };
            if last || !policy.retries(&err) {
                return Err(err);
            }
            x = backup.expect("retries remain");
            std::thread::sleep(policy.delay(attempt, &mut rng));
        }
        unreachable!("the final attempt returns")
    }

    /// Fire-and-forget submit; returns the receiver for the result.
    /// Non-blocking: a full request queue sheds the request with
    /// [`EhybError::Overloaded`] (recorded in
    /// [`ServiceMetrics::shed`]) — back off and retry, or route the
    /// request to another replica. Use [`Self::try_submit`] to get the
    /// input buffer back on shed (no reallocation per retry), or
    /// [`Self::submit_blocking`] to wait for queue space instead.
    pub fn submit(&self, x: Vec<S>) -> crate::Result<ReplyReceiver<S>> {
        self.try_submit_inner(x, None).map_err(|(e, _)| e)
    }

    /// [`Self::submit`] with an optional drain-time deadline (see
    /// [`Self::spmv_deadline`]).
    pub fn submit_with_deadline(
        &self,
        x: Vec<S>,
        deadline: Option<Instant>,
    ) -> crate::Result<ReplyReceiver<S>> {
        self.try_submit_inner(x, deadline).map_err(|(e, _)| e)
    }

    /// [`Self::submit`] that hands the input allocation back alongside
    /// the error when the request is not accepted, so an overloaded
    /// caller can retry without reallocating (the zero-copy story
    /// holds across sheds).
    pub fn try_submit(
        &self,
        x: Vec<S>,
    ) -> std::result::Result<ReplyReceiver<S>, (EhybError, Vec<S>)> {
        self.try_submit_inner(x, None)
    }

    fn try_submit_inner(
        &self,
        x: Vec<S>,
        deadline: Option<Instant>,
    ) -> std::result::Result<ReplyReceiver<S>, (EhybError, Vec<S>)> {
        self.try_submit_traced(x, deadline).map(|(rx, _)| rx).map_err(|(e, x, _)| (e, x))
    }

    /// The traced submit every entry point funnels through: mints the
    /// request's [`TraceId`], records the `submit` event, and — when
    /// the request is *not* accepted — records its terminal event
    /// (`shed` on backpressure, `fault` on a stopped service) so every
    /// minted trace reaches exactly one terminal.
    fn try_submit_traced(
        &self,
        x: Vec<S>,
        deadline: Option<Instant>,
    ) -> std::result::Result<(ReplyReceiver<S>, TraceId), (EhybError, Vec<S>, TraceId)> {
        if x.len() != self.nrows {
            let e = EhybError::DimensionMismatch {
                what: "service request x",
                expected: self.nrows,
                got: x.len(),
            };
            // Rejected before a trace exists: a validation error is the
            // caller's bug, not a request in flight.
            return Err((e, x, TraceId::NONE));
        }
        let trace = self.tel.mint_trace();
        let enq_nanos = self.tel.now_nanos();
        self.tel.event(
            "submit",
            trace,
            if deadline.is_some() { "queued (deadline)" } else { "queued" },
        );
        let (reply_tx, reply_rx) = mpsc::channel();
        let msg =
            Msg::Spmv { x, deadline, reply: reply_tx, trace: trace.0, enq_nanos };
        match self.tx.try_send(msg) {
            Ok(()) => Ok((reply_rx, trace)),
            Err(mpsc::TrySendError::Full(Msg::Spmv { x, .. })) => {
                use std::sync::atomic::Ordering;
                self.metrics.shed.fetch_add(1, Ordering::Relaxed);
                self.tel.event("shed", trace, format!("queue full (depth={})", self.queue_bound));
                Err((EhybError::Overloaded { queue_depth: self.queue_bound }, x, trace))
            }
            Err(mpsc::TrySendError::Disconnected(Msg::Spmv { x, .. })) => {
                self.tel.event("fault", trace, "service stopped");
                Err((EhybError::ServiceStopped, x, trace))
            }
            // try_send returns back exactly the message we passed in.
            Err(_) => unreachable!("submitted a Spmv message"),
        }
    }

    /// Submit that *waits* for queue space instead of shedding — the
    /// right entry point for client-side batching ([`Self::spmv_many`])
    /// where the caller intends every request to run: backpressure
    /// becomes blocking, not an error. Still fails with
    /// [`EhybError::ServiceStopped`] if the service is gone.
    pub fn submit_blocking(&self, x: Vec<S>) -> crate::Result<ReplyReceiver<S>> {
        if x.len() != self.nrows {
            return Err(EhybError::DimensionMismatch {
                what: "service request x",
                expected: self.nrows,
                got: x.len(),
            });
        }
        let trace = self.tel.mint_trace();
        let enq_nanos = self.tel.now_nanos();
        self.tel.event("submit", trace, "queued (blocking)");
        let (reply_tx, reply_rx) = mpsc::channel();
        self.tx
            .send(Msg::Spmv { x, deadline: None, reply: reply_tx, trace: trace.0, enq_nanos })
            .map_err(|_| {
                self.tel.event("fault", trace, "service stopped");
                EhybError::ServiceStopped
            })?;
        Ok(reply_rx)
    }

    /// The configured request-queue bound (requests beyond it shed).
    pub fn queue_bound(&self) -> usize {
        self.queue_bound
    }

    /// Multi-RHS round-trip: submit every vector first, then collect —
    /// the submissions queue together, so the service fuses them into
    /// (at most a few) batched kernel calls. Uses
    /// [`Self::submit_blocking`]: a batch wider than the queue bound
    /// waits for the service to drain rather than shedding its own
    /// tail mid-flight.
    pub fn spmv_many(&self, xs: Vec<Vec<S>>) -> crate::Result<Vec<Vec<S>>> {
        let rxs: Vec<_> =
            xs.into_iter().map(|x| self.submit_blocking(x)).collect::<crate::Result<Vec<_>>>()?;
        rxs.into_iter().map(|rx| rx.recv().unwrap_or(Err(EhybError::ServiceStopped))).collect()
    }

    pub fn nrows(&self) -> usize {
        self.nrows
    }

    /// The [`Telemetry`] handle this client records submit / shed /
    /// retry events and trace IDs into.
    pub fn telemetry(&self) -> &Telemetry {
        &self.tel
    }
}

/// A running service; dropping shuts it down.
pub struct SpmvService<S> {
    client: SpmvClient<S>,
    pub metrics: Arc<ServiceMetrics>,
    handle: Option<std::thread::JoinHandle<()>>,
}

impl<S: Scalar> SpmvService<S> {
    /// Spawn the service thread. `make_engine` runs *inside* the thread
    /// (so it may construct `!Send` PJRT state) and returns the batched
    /// SpMV kernel plus the format's device-memory bytes (for the
    /// bytes-moved metric). It must be re-callable (`FnMut`): after an
    /// engine panic the service quarantines the broken kernel and calls
    /// the factory again to respawn a fresh one. `max_batch` bounds how
    /// many pending requests one drain fuses. Requests carry
    /// square-system vectors of length `nrows`. The request queue is
    /// bounded at [`DEFAULT_QUEUE_BOUND`]; see [`Self::spawn_bounded`].
    pub fn spawn<F>(make_engine: F, nrows: usize, max_batch: usize) -> crate::Result<Self>
    where
        F: FnMut() -> crate::Result<(BatchKernel<S>, usize)> + Send + 'static,
    {
        Self::spawn_bounded(make_engine, nrows, max_batch, DEFAULT_QUEUE_BOUND)
    }

    /// [`Self::spawn`] with an explicit request-queue bound (clamped to
    /// ≥ 1): at most `queue_bound` requests wait between drains;
    /// further submissions shed with [`EhybError::Overloaded`].
    pub fn spawn_bounded<F>(
        make_engine: F,
        nrows: usize,
        max_batch: usize,
        queue_bound: usize,
    ) -> crate::Result<Self>
    where
        F: FnMut() -> crate::Result<(BatchKernel<S>, usize)> + Send + 'static,
    {
        Self::spawn_inner(make_engine, nrows, max_batch, queue_bound, false, Telemetry::new())
    }

    /// [`Self::spawn_bounded`] with a **shed-rate-adaptive** fused-batch
    /// limit: `max_batch` becomes the cap. When submissions shed
    /// ([`EhybError::Overloaded`] observed since the last drain) the
    /// limit halves — smaller fused batches return replies sooner, so a
    /// saturated queue drains steadily instead of stalling behind one
    /// wide kernel call; while the queue drains idle (a drain pulls
    /// fewer requests than the limit) it doubles back toward the cap,
    /// recovering full fusion for well-behaved load. The live limit is
    /// visible in [`ServiceMetrics::adaptive_max_batch`].
    pub fn spawn_adaptive<F>(
        make_engine: F,
        nrows: usize,
        max_batch: usize,
        queue_bound: usize,
    ) -> crate::Result<Self>
    where
        F: FnMut() -> crate::Result<(BatchKernel<S>, usize)> + Send + 'static,
    {
        Self::spawn_inner(make_engine, nrows, max_batch, queue_bound, true, Telemetry::new())
    }

    /// [`Self::spawn_bounded`] / [`Self::spawn_adaptive`] recording
    /// into a caller-supplied [`Telemetry`] handle instead of a fresh
    /// one — the entry point `SpmvContext::serve*` uses so the whole
    /// pipeline (build spans, service traces, engine-internal kernel
    /// spans) lands in one snapshot. The service's
    /// [`ServiceMetrics`] block is attached to the handle at spawn
    /// (folded into snapshots as `service.*{svc="<idx>"}`).
    pub fn spawn_with_telemetry<F>(
        make_engine: F,
        nrows: usize,
        max_batch: usize,
        queue_bound: usize,
        adaptive: bool,
        telemetry: Telemetry,
    ) -> crate::Result<Self>
    where
        F: FnMut() -> crate::Result<(BatchKernel<S>, usize)> + Send + 'static,
    {
        Self::spawn_inner(make_engine, nrows, max_batch, queue_bound, adaptive, telemetry)
    }

    fn spawn_inner<F>(
        mut make_engine: F,
        nrows: usize,
        max_batch: usize,
        queue_bound: usize,
        adaptive: bool,
        tel: Telemetry,
    ) -> crate::Result<Self>
    where
        F: FnMut() -> crate::Result<(BatchKernel<S>, usize)> + Send + 'static,
    {
        let queue_bound = queue_bound.max(1);
        let (tx, rx) = mpsc::sync_channel::<Msg<S>>(queue_bound);
        let metrics = Arc::new(ServiceMetrics::new());
        tel.attach_service(metrics.clone());
        if adaptive {
            // Publish the starting limit before the caller can observe
            // the service (the thread only updates it per drain).
            metrics
                .adaptive_max_batch
                .store(max_batch.max(1) as u64, std::sync::atomic::Ordering::Relaxed);
        }
        let metrics_thread = metrics.clone();
        let tel_thread = tel.clone();
        let (ready_tx, ready_rx) = mpsc::channel::<crate::Result<()>>();
        let handle = std::thread::Builder::new().name("spmv-service".into()).spawn(move || {
            use std::sync::atomic::Ordering;
            let (mut engine, mut format_bytes) = match make_engine() {
                Ok(e) => {
                    let _ = ready_tx.send(Ok(()));
                    e
                }
                Err(e) => {
                    let _ = ready_tx.send(Err(e));
                    return;
                }
            };
            // Persistent contiguous batch storage for the fused calls —
            // grows to the high-water batch width once, then is reused
            // by every drain.
            let mut xbuf: Vec<S> = Vec::new();
            let mut ybuf: Vec<S> = Vec::new();
            let mut batch: Vec<Request<S>> = Vec::new();
            // Adaptive mode: `limit` floats in [1, max_batch], halving
            // when sheds were observed since the last drain and doubling
            // back while the queue drains idle. Fixed mode never moves.
            let mut limit = max_batch.max(1);
            let mut last_shed = 0u64;
            loop {
                // Block for the first request, then drain what's queued.
                let mut shutdown = false;
                match rx.recv() {
                    Ok(Msg::Spmv { x, deadline, reply, trace, enq_nanos }) => {
                        batch.push(Request { x, deadline, reply, trace, enq_nanos })
                    }
                    Ok(Msg::Shutdown) | Err(_) => break,
                }
                while batch.len() < limit {
                    match rx.try_recv() {
                        Ok(Msg::Spmv { x, deadline, reply, trace, enq_nanos }) => {
                            batch.push(Request { x, deadline, reply, trace, enq_nanos })
                        }
                        Ok(Msg::Shutdown) => {
                            shutdown = true;
                            break;
                        }
                        Err(_) => break,
                    }
                }
                if adaptive {
                    let shed_now = metrics_thread.shed.load(Ordering::Relaxed);
                    if shed_now > last_shed {
                        // Producers are being shed: shorter fused calls
                        // return replies (and free queue slots) sooner.
                        limit = (limit / 2).max(1);
                    } else if batch.len() < limit {
                        // Queue drained dry below the limit: recover
                        // fusion width for the next burst.
                        limit = (limit * 2).min(max_batch.max(1));
                    }
                    last_shed = shed_now;
                    metrics_thread.adaptive_max_batch.store(limit as u64, Ordering::Relaxed);
                }
                // Deadline triage: expired requests reply with a typed
                // error *before* staging, so they never occupy kernel
                // width (their batch slots go to live requests).
                let now = Instant::now();
                batch.retain(|req| {
                    if req.deadline.is_some_and(|d| d <= now) {
                        metrics_thread.deadline_misses.fetch_add(1, Ordering::Relaxed);
                        tel_thread.event(
                            "deadline",
                            TraceId(req.trace),
                            "expired before drain, dropped from batch",
                        );
                        let _ = req.reply.send(Err(EhybError::DeadlineExceeded));
                        false
                    } else {
                        true
                    }
                });
                let ok = serve_fused(
                    &mut engine,
                    &mut batch,
                    &mut xbuf,
                    &mut ybuf,
                    nrows,
                    &metrics_thread,
                    format_bytes,
                    &tel_thread,
                );
                if !ok {
                    // The engine panicked: the poisoned batch was
                    // answered with EngineFault. Quarantine the engine
                    // (drop it) and respawn a fresh one via the
                    // factory. If the factory itself fails, the service
                    // exits — in-flight and future requests observe
                    // ServiceStopped (dropped reply senders / a
                    // disconnected queue), never a hang.
                    match make_engine() {
                        Ok((e, fb)) => {
                            engine = e;
                            format_bytes = fb;
                            metrics_thread.respawns.fetch_add(1, Ordering::Relaxed);
                            tel_thread.event(
                                "respawn",
                                TraceId::NONE,
                                "engine quarantined after fault, fresh engine spawned",
                            );
                        }
                        Err(_) => break,
                    }
                }
                if shutdown {
                    break;
                }
            }
        })?;
        ready_rx.recv().map_err(|_| EhybError::ServiceStopped)??;
        Ok(Self {
            client: SpmvClient { tx, nrows, queue_bound, metrics: metrics.clone(), tel },
            metrics,
            handle: Some(handle),
        })
    }

    pub fn client(&self) -> SpmvClient<S> {
        self.client.clone()
    }
}

/// Extract a human-readable message from a caught panic payload.
fn panic_detail(payload: Box<dyn std::any::Any + Send>) -> String {
    if let Some(s) = payload.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = payload.downcast_ref::<String>() {
        s.clone()
    } else {
        "engine panicked (non-string payload)".into()
    }
}

/// Execute one drained batch as a single fused kernel call over the
/// persistent contiguous buffers and reply. Returns `false` when the
/// kernel panicked (the batch was answered with
/// [`EhybError::EngineFault`] and the caller must respawn the engine).
///
/// Telemetry: the drain is one `serve.batch(w=N)` span; every fused
/// request contributes a `queue.wait` child stretching from its submit
/// timestamp to the drain, the fused call itself is a `kernel` child
/// (engine-internal per-shard spans attach under it via the implicit
/// current-parent), and each request's terminal event (`reply` or
/// `fault`) is recorded as it is answered.
#[allow(clippy::too_many_arguments)]
fn serve_fused<S: Scalar>(
    engine: &mut BatchKernel<S>,
    batch: &mut Vec<Request<S>>,
    xbuf: &mut Vec<S>,
    ybuf: &mut Vec<S>,
    nrows: usize,
    metrics: &ServiceMetrics,
    format_bytes: usize,
    tel: &Telemetry,
) -> bool {
    use std::sync::atomic::Ordering;
    if batch.is_empty() {
        return true;
    }
    let bw = batch.len();
    let batch_span = tel.span(format!("serve.batch(w={bw})"));
    let drained_nanos = tel.now_nanos();
    for req in batch.iter() {
        tel.record_span(
            "queue.wait",
            batch_span.id(),
            TraceId(req.trace),
            req.enq_nanos,
            drained_nanos,
        );
    }
    if xbuf.len() < bw * nrows {
        xbuf.resize(bw * nrows, S::ZERO);
        ybuf.resize(bw * nrows, S::ZERO);
    }
    // Stage the requests into ONE contiguous input batch (lengths were
    // validated at submit time).
    for (b, req) in batch.iter().enumerate() {
        xbuf[b * nrows..(b + 1) * nrows].copy_from_slice(&req.x);
    }
    let t = Timer::start();
    let caught = {
        let xs = VecBatch::new(&xbuf[..bw * nrows], nrows).expect("contiguous request batch");
        let mut ys =
            VecBatchMut::new(&mut ybuf[..bw * nrows], nrows).expect("contiguous reply batch");
        // AssertUnwindSafe is justified here, not assumed: the kernel
        // computes row-local outputs over immutable `&[S]` column
        // views, so the only state it can leave inconsistent on unwind
        // is (a) the kernel's own captures — discarded below, the
        // engine is respawned and never reused after a panic — and
        // (b) `ybuf`, which every SpMV engine fully rewrites for the
        // columns of the *next* drain before any byte of it is read
        // (replies only copy columns the current call produced).
        let kernel_span = tel.span("kernel");
        let caught =
            std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| engine(xs, &mut ys))).err();
        drop(kernel_span);
        caught
    };
    if let Some(payload) = caught {
        let detail = panic_detail(payload);
        metrics.faults.fetch_add(1, Ordering::Relaxed);
        // Exactly the requests fused into this batch are poisoned:
        // each gets the typed fault (no latency/width accounting — the
        // batch never executed).
        for req in batch.drain(..) {
            tel.event("fault", TraceId(req.trace), format!("engine panic: {detail}"));
            let _ = req.reply.send(Err(EhybError::EngineFault(detail.clone())));
        }
        return false;
    }
    let secs = t.elapsed_secs();
    metrics.requests.fetch_add(bw as u64, Ordering::Relaxed);
    metrics.batches.fetch_add(1, Ordering::Relaxed);
    metrics.batch_width.record(bw);
    metrics
        .bytes_moved
        .fetch_add((format_bytes + bw * 2 * nrows * S::BYTES) as u64, Ordering::Relaxed);
    for (i, req) in batch.drain(..).enumerate() {
        metrics.spmv_latency.record(secs);
        tel.event("reply", TraceId(req.trace), format!("served in batch width={bw}"));
        // Reply reuses the request's own x allocation (buffer
        // recycling — zero per-request allocation in steady state).
        let mut out = req.x;
        out.copy_from_slice(&ybuf[i * nrows..(i + 1) * nrows]);
        let _ = req.reply.send(Ok(out));
    }
    true
}

impl<S> Drop for SpmvService<S> {
    fn drop(&mut self) {
        let _ = self.client.tx.send(Msg::Shutdown);
        if let Some(h) = self.handle.take() {
            let _ = h.join();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::{EngineKind, SpmvContext};
    use crate::preprocess::PreprocessConfig;
    use crate::sparse::gen::poisson2d;
    use std::sync::atomic::{AtomicUsize, Ordering};
    use std::time::Duration;

    fn context() -> (SpmvContext<f64>, crate::sparse::csr::Csr<f64>) {
        let a = poisson2d::<f64>(16, 16);
        let ctx = SpmvContext::builder(a.clone())
            .engine(EngineKind::Ehyb)
            .config(PreprocessConfig { vec_size_override: Some(64), ..Default::default() })
            .build()
            .unwrap();
        (ctx, a)
    }

    fn service() -> (SpmvService<f64>, crate::sparse::csr::Csr<f64>) {
        let (ctx, a) = context();
        (ctx.serve(8).unwrap(), a)
    }

    /// Gate-driven service used by the deterministic scheduling tests:
    /// the kernel signals entry and then blocks on a gate, so the test
    /// controls exactly when each drain completes. Builds one engine
    /// (the gate receiver is not cloneable, so a respawn would panic
    /// the factory — none of these tests inject faults).
    fn gated_service(
        max_batch: usize,
        queue_bound: usize,
        adaptive: bool,
    ) -> (SpmvService<f64>, mpsc::Receiver<()>, mpsc::Sender<()>) {
        let (ctx, _) = context();
        let engine = ctx.engine_arc();
        let (started_tx, started_rx) = mpsc::channel::<()>();
        let (gate_tx, gate_rx) = mpsc::channel::<()>();
        let mut rig = Some((started_tx, gate_rx));
        let make = move || {
            let engine = engine.clone();
            let fb = engine.format_bytes();
            let (stx, grx) = rig.take().expect("gated rig builds one engine");
            let kernel: BatchKernel<f64> = Box::new(move |xs, ys| {
                stx.send(()).unwrap();
                grx.recv().unwrap();
                engine.spmv_batch(xs, ys)
            });
            Ok((kernel, fb))
        };
        let svc = if adaptive {
            SpmvService::spawn_adaptive(make, 256, max_batch, queue_bound).unwrap()
        } else {
            SpmvService::spawn_bounded(make, 256, max_batch, queue_bound).unwrap()
        };
        (svc, started_rx, gate_tx)
    }

    #[test]
    fn serves_correct_results() {
        let (svc, a) = service();
        let client = svc.client();
        let x: Vec<f64> = (0..256).map(|i| (i as f64 * 0.01).sin()).collect();
        let y = client.spmv(x.clone()).unwrap();
        let mut want = vec![0.0; 256];
        a.spmv(&x, &mut want);
        for i in 0..256 {
            assert!((y[i] - want[i]).abs() < 1e-12);
        }
        assert_eq!(svc.metrics.spmv_latency.count(), 1);
        assert!(svc.metrics.bytes_moved.load(Ordering::Relaxed) > 0);
    }

    #[test]
    fn concurrent_clients() {
        let (svc, a) = service();
        let mut handles = Vec::new();
        for t in 0..8 {
            let client = svc.client();
            let a = a.clone();
            handles.push(std::thread::spawn(move || {
                let x: Vec<f64> = (0..256).map(|i| ((i + t * 31) as f64 * 0.02).cos()).collect();
                let y = client.spmv(x.clone()).unwrap();
                let mut want = vec![0.0; 256];
                a.spmv(&x, &mut want);
                for i in 0..256 {
                    assert!((y[i] - want[i]).abs() < 1e-12);
                }
            }));
        }
        for h in handles {
            h.join().unwrap();
        }
        assert_eq!(svc.metrics.requests.load(Ordering::Relaxed), 8);
        assert!(svc.metrics.mean_batch_size() >= 1.0);
        assert_eq!(svc.metrics.batch_width.count(), svc.metrics.batches.load(Ordering::Relaxed));
    }

    #[test]
    fn async_submit() {
        let (svc, _) = service();
        let client = svc.client();
        let rx1 = client.submit(vec![1.0; 256]).unwrap();
        let rx2 = client.submit(vec![2.0; 256]).unwrap();
        let y1 = rx1.recv().unwrap().unwrap();
        let y2 = rx2.recv().unwrap().unwrap();
        for i in 0..256 {
            assert!((y2[i] - 2.0 * y1[i]).abs() < 1e-9); // linearity
        }
    }

    #[test]
    fn queued_requests_fused_into_fewer_kernel_calls() {
        // N queued requests must be served by < N kernel invocations:
        // the engine sleeps so later submissions pile up behind the
        // first drain and fuse into one batched call.
        let (ctx, _) = context();
        let calls = Arc::new(AtomicUsize::new(0));
        let calls_engine = calls.clone();
        let engine = ctx.engine_arc();
        let svc: SpmvService<f64> = SpmvService::spawn(
            move || {
                let engine = engine.clone();
                let calls_engine = calls_engine.clone();
                let fb = engine.format_bytes();
                let kernel: BatchKernel<f64> = Box::new(move |xs, ys| {
                    calls_engine.fetch_add(1, Ordering::Relaxed);
                    std::thread::sleep(Duration::from_millis(25));
                    engine.spmv_batch(xs, ys)
                });
                Ok((kernel, fb))
            },
            256,
            16,
        )
        .unwrap();
        let client = svc.client();
        let n_req = 8;
        let rxs: Vec<_> =
            (0..n_req).map(|t| client.submit(vec![1.0 + t as f64; 256]).unwrap()).collect();
        for rx in rxs {
            let y = rx.recv().unwrap().unwrap();
            assert_eq!(y.len(), 256);
            assert!(y.iter().all(|v| v.is_finite()));
        }
        let k = calls.load(Ordering::Relaxed);
        assert!(k < n_req, "expected fused execution, got {k} kernel calls for {n_req} requests");
        assert_eq!(svc.metrics.requests.load(Ordering::Relaxed), n_req as u64);
        assert!(svc.metrics.batch_width.mean() > 1.0);
    }

    #[test]
    fn spmv_many_round_trip() {
        let (svc, a) = service();
        let client = svc.client();
        let xs: Vec<Vec<f64>> = (0..5)
            .map(|t| (0..256).map(|i| ((i * 3 + t * 7) % 11) as f64 * 0.5 - 2.0).collect())
            .collect();
        let ys = client.spmv_many(xs.clone()).unwrap();
        for (x, y) in xs.iter().zip(&ys) {
            let mut want = vec![0.0; 256];
            a.spmv(x, &mut want);
            for i in 0..256 {
                assert!((y[i] - want[i]).abs() < 1e-12);
            }
        }
    }

    #[test]
    fn wrong_length_request_is_typed_error() {
        let (svc, _) = service();
        let client = svc.client();
        match client.spmv(vec![1.0; 17]) {
            Err(EhybError::DimensionMismatch { expected: 256, got: 17, .. }) => {}
            other => panic!("expected DimensionMismatch, got {other:?}"),
        }
    }

    #[test]
    fn stopped_service_returns_service_stopped() {
        let (svc, _) = service();
        let client = svc.client();
        drop(svc); // joins the service thread; the channel receiver dies
        match client.spmv(vec![0.0; 256]) {
            Err(EhybError::ServiceStopped) => {}
            other => panic!("expected ServiceStopped, got {other:?}"),
        }
        assert!(matches!(client.submit(vec![0.0; 256]), Err(EhybError::ServiceStopped)));
    }

    #[test]
    fn full_queue_sheds_with_overloaded() {
        // Deterministic overload: the kernel signals entry and then
        // blocks on a gate, so the test controls exactly when the
        // single queue slot frees up.
        let (svc, started_rx, gate_tx) = gated_service(16, 1, false);
        let client = svc.client();
        assert_eq!(client.queue_bound(), 1);
        // r1 is popped by the service thread and blocks inside the
        // kernel (wait for the signal so this is not racy)...
        let rx1 = client.submit(vec![1.0; 256]).unwrap();
        started_rx.recv().unwrap();
        // ...r2 occupies the single queue slot...
        let rx2 = client.submit(vec![2.0; 256]).unwrap();
        // ...and r3 must shed with the typed error, handing the input
        // allocation back for a reallocation-free retry.
        match client.try_submit(vec![3.0; 256]) {
            Err((EhybError::Overloaded { queue_depth: 1 }, x3)) => {
                assert_eq!(x3.len(), 256);
                assert!(x3.iter().all(|&v| v == 3.0), "shed must return the caller's buffer");
            }
            other => panic!("expected Overloaded, got {:?}", other.map(|_| ())),
        }
        match client.submit(vec![3.0; 256]) {
            Err(EhybError::Overloaded { queue_depth: 1 }) => {}
            other => panic!("expected Overloaded, got {other:?}"),
        }
        assert_eq!(svc.metrics.shed.load(Ordering::Relaxed), 2);
        // Release the gate (once per drain: r1's batch, then r2's) and
        // the accepted requests complete normally.
        gate_tx.send(()).unwrap();
        gate_tx.send(()).unwrap();
        assert_eq!(rx1.recv().unwrap().unwrap().len(), 256);
        assert_eq!(rx2.recv().unwrap().unwrap().len(), 256);
        drop(gate_tx); // further drains (shutdown path) must not block
    }

    #[test]
    fn shed_requests_never_recorded_in_width_histogram() {
        // ISSUE 4 satellite: shed accounting and the batch-width
        // histogram must stay disjoint — a shed request's width is
        // never recorded (widths are recorded only when a drained
        // batch executes), so count(widths) == batches exactly.
        let (svc, started_rx, gate_tx) = gated_service(16, 1, false);
        let client = svc.client();
        let rx1 = client.submit(vec![1.0; 256]).unwrap();
        started_rx.recv().unwrap(); // r1 is inside the kernel
        let rx2 = client.submit(vec![2.0; 256]).unwrap(); // occupies the slot
        for _ in 0..3 {
            assert!(matches!(client.submit(vec![3.0; 256]), Err(EhybError::Overloaded { .. })));
        }
        gate_tx.send(()).unwrap();
        gate_tx.send(()).unwrap();
        rx1.recv().unwrap().unwrap();
        rx2.recv().unwrap().unwrap();
        // Pinned counts: exactly 2 executed batches of width 1, 3 sheds.
        assert_eq!(svc.metrics.shed.load(Ordering::Relaxed), 3);
        assert_eq!(svc.metrics.batches.load(Ordering::Relaxed), 2);
        assert_eq!(svc.metrics.batch_width.count(), 2, "width histogram counted a shed");
        assert_eq!(svc.metrics.batch_width.max(), 1);
        assert_eq!(svc.metrics.requests.load(Ordering::Relaxed), 2);
    }

    #[test]
    fn adaptive_limit_shrinks_on_shed_and_grows_when_idle() {
        // Deterministic gate-driven schedule (same rig as
        // full_queue_sheds): force a shed, watch the limit halve before
        // the next drain, then watch idle drains double it back.
        let (svc, started_rx, gate_tx) = gated_service(8, 1, true);
        let client = svc.client();
        assert_eq!(svc.metrics.adaptive_max_batch.load(Ordering::Relaxed), 8);
        // r1 enters the kernel and blocks; r2 fills the queue slot; r3
        // sheds.
        let rx1 = client.submit(vec![1.0; 256]).unwrap();
        started_rx.recv().unwrap();
        let rx2 = client.submit(vec![2.0; 256]).unwrap();
        assert!(matches!(client.submit(vec![3.0; 256]), Err(EhybError::Overloaded { .. })));
        // Release r1; the service drains r2 and, having observed the
        // shed, halves the limit before executing.
        gate_tx.send(()).unwrap();
        started_rx.recv().unwrap(); // r2's drain is past the adjustment
        assert_eq!(svc.metrics.adaptive_max_batch.load(Ordering::Relaxed), 4);
        gate_tx.send(()).unwrap();
        rx1.recv().unwrap().unwrap();
        rx2.recv().unwrap().unwrap();
        // Idle traffic: each drain pulls one request (< limit) with no
        // new sheds, so the limit doubles back to the cap.
        let rx4 = client.submit(vec![4.0; 256]).unwrap();
        started_rx.recv().unwrap();
        assert_eq!(svc.metrics.adaptive_max_batch.load(Ordering::Relaxed), 8);
        gate_tx.send(()).unwrap();
        rx4.recv().unwrap().unwrap();
        drop(gate_tx);
    }

    #[test]
    fn fixed_service_never_touches_adaptive_gauge() {
        let (svc, _) = service();
        let client = svc.client();
        client.spmv(vec![1.0; 256]).unwrap();
        assert_eq!(svc.metrics.adaptive_max_batch.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn adaptive_service_serves_correctly_under_load() {
        let (ctx, a) = context();
        let engine = ctx.engine_arc();
        let svc: SpmvService<f64> = SpmvService::spawn_adaptive(
            move || {
                let engine = engine.clone();
                let fb = engine.format_bytes();
                let kernel: BatchKernel<f64> = Box::new(move |xs, ys| engine.spmv_batch(xs, ys));
                Ok((kernel, fb))
            },
            256,
            4,
            2,
        )
        .unwrap();
        let client = svc.client();
        let xs: Vec<Vec<f64>> = (0..12)
            .map(|t| (0..256).map(|i| ((i * 5 + t * 3) % 13) as f64 * 0.5 - 3.0).collect())
            .collect();
        let ys = client.spmv_many(xs.clone()).unwrap();
        for (x, y) in xs.iter().zip(&ys) {
            let mut want = vec![0.0; 256];
            a.spmv(x, &mut want);
            for i in 0..256 {
                assert!((y[i] - want[i]).abs() < 1e-12);
            }
        }
        let limit = svc.metrics.adaptive_max_batch.load(Ordering::Relaxed);
        assert!((1..=4).contains(&limit), "live limit {limit} outside [1, cap]");
    }

    #[test]
    fn spmv_many_wider_than_queue_bound_succeeds() {
        // Client-side batching blocks on backpressure instead of
        // shedding its own tail: 16 RHS through a queue bounded at 2
        // must all complete correctly.
        let (ctx, a) = context();
        let engine = ctx.engine_arc();
        let svc: SpmvService<f64> = SpmvService::spawn_bounded(
            move || {
                let engine = engine.clone();
                let fb = engine.format_bytes();
                let kernel: BatchKernel<f64> = Box::new(move |xs, ys| engine.spmv_batch(xs, ys));
                Ok((kernel, fb))
            },
            256,
            4,
            2,
        )
        .unwrap();
        let client = svc.client();
        let xs: Vec<Vec<f64>> = (0..16)
            .map(|t| (0..256).map(|i| ((i * 3 + t * 7) % 13) as f64 * 0.5 - 3.0).collect())
            .collect();
        let ys = client.spmv_many(xs.clone()).unwrap();
        for (x, y) in xs.iter().zip(&ys) {
            let mut want = vec![0.0; 256];
            a.spmv(x, &mut want);
            for i in 0..256 {
                assert!((y[i] - want[i]).abs() < 1e-12);
            }
        }
        assert_eq!(svc.metrics.shed.load(Ordering::Relaxed), 0, "blocking path must not shed");
    }

    #[test]
    fn default_bound_large_enough_for_serial_use() {
        let (svc, a) = service();
        let client = svc.client();
        assert_eq!(client.queue_bound(), DEFAULT_QUEUE_BOUND);
        let x: Vec<f64> = (0..256).map(|i| (i % 7) as f64).collect();
        let y = client.spmv(x.clone()).unwrap();
        let mut want = vec![0.0; 256];
        a.spmv(&x, &mut want);
        assert_eq!(y, want);
        assert_eq!(svc.metrics.shed.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn init_failure_propagates() {
        let r: crate::Result<SpmvService<f64>> = SpmvService::spawn(
            || -> crate::Result<(BatchKernel<f64>, usize)> {
                Err(EhybError::Runtime("boom".into()))
            },
            4,
            1,
        );
        assert!(r.is_err());
    }

    /// Service whose kernel panics on exactly the `panic_on`-th kernel
    /// call (counted across respawns — the counter is shared), serving
    /// the 256-row Poisson context.
    fn faulting_service(panic_on: usize) -> (SpmvService<f64>, crate::sparse::csr::Csr<f64>) {
        let (ctx, a) = context();
        let engine = ctx.engine_arc();
        let calls = Arc::new(AtomicUsize::new(0));
        let svc = SpmvService::spawn(
            move || {
                let engine = engine.clone();
                let calls = calls.clone();
                let fb = engine.format_bytes();
                let kernel: BatchKernel<f64> = Box::new(move |xs, ys| {
                    let call = calls.fetch_add(1, Ordering::Relaxed) + 1;
                    if call == panic_on {
                        panic!("injected engine fault on kernel call {call}");
                    }
                    engine.spmv_batch(xs, ys)
                });
                Ok((kernel, fb))
            },
            256,
            8,
        )
        .unwrap();
        (svc, a)
    }

    #[test]
    fn engine_panic_is_typed_fault_and_service_keeps_serving() {
        // The ISSUE 6 satellite contract: a worker panic loses only the
        // poisoned batch — a request submitted after the fault
        // round-trips successfully and respawns == 1.
        let (svc, a) = faulting_service(2);
        let client = svc.client();
        let x: Vec<f64> = (0..256).map(|i| ((i % 13) as f64) * 0.25 - 1.0).collect();
        // Call 1 executes normally.
        assert!(client.spmv(x.clone()).is_ok());
        // Call 2 panics inside the kernel: the request gets the typed
        // fault (the panic never escapes the service).
        match client.spmv(x.clone()) {
            Err(EhybError::EngineFault(msg)) => {
                assert!(msg.contains("injected engine fault"), "{msg}");
            }
            other => panic!("expected EngineFault, got {other:?}"),
        }
        // Call 3 runs on the respawned engine and is correct.
        let y = client.spmv(x.clone()).unwrap();
        let mut want = vec![0.0; 256];
        a.spmv(&x, &mut want);
        for i in 0..256 {
            assert!((y[i] - want[i]).abs() < 1e-12);
        }
        assert_eq!(svc.metrics.faults.load(Ordering::Relaxed), 1);
        assert_eq!(svc.metrics.respawns.load(Ordering::Relaxed), 1);
        // The poisoned batch never entered the execution accounting.
        assert_eq!(svc.metrics.requests.load(Ordering::Relaxed), 2);
        assert_eq!(svc.metrics.batches.load(Ordering::Relaxed), 2);
        assert_eq!(svc.metrics.batch_width.count(), 2);
    }

    #[test]
    fn expired_deadline_is_shed_without_kernel_width() {
        let (svc, started_rx, gate_tx) = gated_service(8, 4, false);
        let client = svc.client();
        // r1 blocks inside the kernel; r2 (already expired) queues
        // behind it.
        let rx1 = client.submit(vec![1.0; 256]).unwrap();
        started_rx.recv().unwrap();
        let rx2 = client
            .submit_with_deadline(vec![2.0; 256], Some(Instant::now() - Duration::from_millis(1)))
            .unwrap();
        gate_tx.send(()).unwrap(); // r1 completes
        assert_eq!(rx1.recv().unwrap().unwrap().len(), 256);
        // r2's drain triages it out before staging: typed error, no
        // kernel call (the gate is NOT released again), no width.
        match rx2.recv().unwrap() {
            Err(EhybError::DeadlineExceeded) => {}
            other => panic!("expected DeadlineExceeded, got {other:?}"),
        }
        assert_eq!(svc.metrics.deadline_misses.load(Ordering::Relaxed), 1);
        assert_eq!(svc.metrics.batches.load(Ordering::Relaxed), 1);
        assert_eq!(svc.metrics.batch_width.count(), 1);
        // A fresh request with a generous deadline still round-trips.
        let rx3 = client
            .submit_with_deadline(vec![3.0; 256], Some(Instant::now() + Duration::from_secs(60)))
            .unwrap();
        started_rx.recv().unwrap();
        gate_tx.send(()).unwrap();
        assert_eq!(rx3.recv().unwrap().unwrap().len(), 256);
        assert_eq!(svc.metrics.deadline_misses.load(Ordering::Relaxed), 1);
        drop(gate_tx);
    }

    #[test]
    fn retry_recovers_from_engine_fault() {
        // First kernel call panics; the retry lands on the respawned
        // engine and succeeds — recovery inside the policy budget with
        // no caller-visible fault.
        let (svc, a) = faulting_service(1);
        let client = svc.client();
        let policy = RetryPolicy {
            max_attempts: 3,
            base_delay: Duration::ZERO,
            max_delay: Duration::ZERO,
            seed: 7,
        };
        let x: Vec<f64> = (0..256).map(|i| ((i % 7) as f64) - 3.0).collect();
        let y = client.spmv_with_retry(x.clone(), &policy).unwrap();
        let mut want = vec![0.0; 256];
        a.spmv(&x, &mut want);
        for i in 0..256 {
            assert!((y[i] - want[i]).abs() < 1e-12);
        }
        assert_eq!(svc.metrics.faults.load(Ordering::Relaxed), 1);
        assert_eq!(svc.metrics.respawns.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn retry_budget_exhausts_with_typed_fault() {
        // Every kernel call panics: the policy's budget runs out and
        // the last typed fault surfaces (no infinite retry, no hang).
        let (ctx, _) = context();
        let engine = ctx.engine_arc();
        let svc: SpmvService<f64> = SpmvService::spawn(
            move || {
                let fb = engine.format_bytes();
                let kernel: BatchKernel<f64> =
                    Box::new(move |_xs, _ys| panic!("injected: always faulting"));
                Ok((kernel, fb))
            },
            256,
            8,
        )
        .unwrap();
        let client = svc.client();
        let policy = RetryPolicy {
            max_attempts: 2,
            base_delay: Duration::ZERO,
            max_delay: Duration::ZERO,
            seed: 7,
        };
        match client.spmv_with_retry(vec![1.0; 256], &policy) {
            Err(EhybError::EngineFault(_)) => {}
            other => panic!("expected EngineFault, got {other:?}"),
        }
        assert_eq!(svc.metrics.faults.load(Ordering::Relaxed), 2);
        assert_eq!(svc.metrics.respawns.load(Ordering::Relaxed), 2);
    }

    #[test]
    fn every_request_trace_reaches_exactly_one_terminal_event() {
        use crate::telemetry::{snapshot::TERMINAL_KINDS, Telemetry};
        let (ctx, _) = context();
        let engine = ctx.engine_arc();
        let tel = Telemetry::with_fake_clock();
        let svc: SpmvService<f64> = SpmvService::spawn_with_telemetry(
            move || {
                let engine = engine.clone();
                let fb = engine.format_bytes();
                let kernel: BatchKernel<f64> = Box::new(move |xs, ys| engine.spmv_batch(xs, ys));
                Ok((kernel, fb))
            },
            256,
            8,
            4,
            false,
            tel.clone(),
        )
        .unwrap();
        let client = svc.client();
        // Served requests terminate with `reply`...
        for t in 0..3 {
            client.spmv(vec![1.0 + t as f64; 256]).unwrap();
        }
        // ...an expired deadline terminates with `deadline`...
        let rx = client
            .submit_with_deadline(vec![5.0; 256], Some(Instant::now() - Duration::from_millis(1)))
            .unwrap();
        let _ = rx.recv().unwrap();
        drop(svc); // join the service thread so every event is recorded
        let snap = tel.snapshot();
        let traces = snap.known_traces();
        assert_eq!(traces.len(), 4);
        for tr in traces {
            assert_eq!(snap.terminal_event_count(tr), 1, "trace {tr}");
        }
        // Terminal kinds observed: 3 replies + 1 deadline.
        let count = |k: &str| snap.events.iter().filter(|e| e.kind == k).count();
        assert_eq!(count("reply"), 3);
        assert_eq!(count("deadline"), 1);
        assert!(TERMINAL_KINDS.contains(&"deadline"));
        // The batch subtree is reconstructible from any served trace.
        let story = snap.describe_trace(1);
        assert!(story.contains("queue.wait"), "{story}");
        assert!(story.contains("serve.batch"), "{story}");
        assert!(story.contains("kernel"), "{story}");
    }

    #[test]
    fn retried_attempts_are_linked_traces() {
        use crate::telemetry::Telemetry;
        let (ctx, _) = context();
        let engine = ctx.engine_arc();
        let tel = Telemetry::with_fake_clock();
        let calls = Arc::new(AtomicUsize::new(0));
        let calls_k = calls.clone();
        let svc: SpmvService<f64> = SpmvService::spawn_with_telemetry(
            move || {
                let engine = engine.clone();
                let calls_k = calls_k.clone();
                let fb = engine.format_bytes();
                let kernel: BatchKernel<f64> = Box::new(move |xs, ys| {
                    if calls_k.fetch_add(1, Ordering::Relaxed) == 0 {
                        panic!("injected first-call fault");
                    }
                    engine.spmv_batch(xs, ys)
                });
                Ok((kernel, fb))
            },
            256,
            8,
            4,
            false,
            tel.clone(),
        )
        .unwrap();
        let client = svc.client();
        let policy = RetryPolicy {
            max_attempts: 3,
            base_delay: Duration::ZERO,
            max_delay: Duration::ZERO,
            seed: 7,
        };
        client.spmv_with_retry(vec![1.0; 256], &policy).unwrap();
        drop(svc);
        let snap = tel.snapshot();
        // Attempt 1 (trace 1) faulted; attempt 2 (trace 2) replied and
        // carries the linking `retry` event naming its predecessor.
        assert_eq!(snap.terminal_event_count(1), 1);
        assert_eq!(snap.terminal_event_count(2), 1);
        let retry = snap.events.iter().find(|e| e.kind == "retry").expect("retry event");
        assert_eq!(retry.trace, 2);
        assert!(retry.detail.contains("attempt=2"), "{}", retry.detail);
        assert!(retry.detail.contains("prev=1"), "{}", retry.detail);
        // The faulted attempt's story names its successor.
        let story = snap.describe_trace(1);
        assert!(story.contains("retried as trace 2"), "{story}");
        // Respawn left its mark as an untraced event.
        assert!(snap.events.iter().any(|e| e.kind == "respawn"));
    }

    #[test]
    fn retry_never_retries_permanent_errors() {
        let (svc, _) = service();
        let client = svc.client();
        // A dimension error with a pathological backoff: if the policy
        // retried it, this test would sleep ~20 s. It must return
        // immediately instead.
        let policy = RetryPolicy {
            max_attempts: 5,
            base_delay: Duration::from_secs(5),
            max_delay: Duration::from_secs(5),
            seed: 1,
        };
        let t0 = Instant::now();
        match client.spmv_with_retry(vec![1.0; 3], &policy) {
            Err(EhybError::DimensionMismatch { expected: 256, got: 3, .. }) => {}
            other => panic!("expected DimensionMismatch, got {other:?}"),
        }
        assert!(t0.elapsed() < Duration::from_secs(1), "permanent error must not back off");
    }
}
