//! L3 coordinator: the host-side system around the SpMV kernel.
//!
//! The paper's contribution is the kernel + preprocessing; the
//! coordinator is the thin-but-real layer a downstream user deploys:
//!
//! * [`solver`] — preconditioned CG / BiCGSTAB whose hot path is the
//!   EHYB SpMV (the §6 use case: SPAI-preconditioned iterative solvers
//!   amortizing preprocessing over thousands of iterations).
//! * [`precond`] — Jacobi and SPAI(0) preconditioners built from
//!   scratch (paper refs [10][13]).
//! * [`service`] — a single-threaded SpMV service owning the (!Send)
//!   PJRT runtime, serving requests over channels with batching;
//!   worker threads submit and await.
//! * [`metrics`] — counters/latency histograms for the service.

pub mod solver;
pub mod precond;
pub mod service;
pub mod metrics;

pub use precond::{Jacobi, Preconditioner, Spai0};
pub use solver::{bicgstab, cg, SolveReport, SolverConfig};
