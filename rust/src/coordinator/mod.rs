//! L3 coordinator: the host-side system around the SpMV kernel.
//!
//! The paper's contribution is the kernel + preprocessing; the
//! coordinator is the thin-but-real layer a downstream user deploys:
//!
//! * [`solver`] — preconditioned CG / BiCGSTAB whose hot path is the
//!   EHYB SpMV (the §6 use case: SPAI-preconditioned iterative solvers
//!   amortizing preprocessing over thousands of iterations), plus the
//!   multi-RHS [`solver::cg_many`] that fuses every iteration's SpMVs
//!   into one batched kernel call.
//! * [`precond`] — Jacobi and SPAI(0) preconditioners built from
//!   scratch (paper refs [10][13]).
//! * [`service`] — a single-threaded SpMV service owning the (!Send)
//!   PJRT runtime, serving requests over channels; a drained request
//!   batch executes as one fused `spmv_batch` call with recycled
//!   output buffers.
//!
//! The service metric types live in [`crate::telemetry`] since 0.8;
//! the deprecated `coordinator::metrics` aliases were removed in 0.10
//! (MIGRATION.md 0.9 → 0.10).

pub mod solver;
pub mod precond;
pub mod service;

pub use precond::{Jacobi, Preconditioner, Spai0};
pub use solver::{
    bicgstab, cg, cg_many, DivergenceMonitor, SolveReport, SolveStatus, SolverConfig,
};
