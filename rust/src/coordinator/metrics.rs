//! Service metrics: request counters and a fixed-bucket latency
//! histogram (log-spaced), lock-free on the hot path.

use std::sync::atomic::{AtomicU64, Ordering};

/// Log-spaced latency histogram from 1 µs to ~1 s (30 buckets, ×2 each).
pub struct LatencyHistogram {
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum_nanos: AtomicU64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHistogram {
    pub fn new() -> Self {
        Self {
            buckets: (0..30).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum_nanos: AtomicU64::new(0),
        }
    }

    #[inline]
    pub fn record(&self, secs: f64) {
        let nanos = (secs * 1e9) as u64;
        let us = nanos / 1000;
        let idx = if us == 0 { 0 } else { (63 - us.leading_zeros() as usize).min(29) };
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_nanos.fetch_add(nanos, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn mean_secs(&self) -> f64 {
        let c = self.count();
        if c == 0 {
            return 0.0;
        }
        self.sum_nanos.load(Ordering::Relaxed) as f64 / c as f64 / 1e9
    }

    /// Approximate quantile from the histogram (upper bucket edge).
    pub fn quantile_secs(&self, q: f64) -> f64 {
        let total = self.count();
        if total == 0 {
            return 0.0;
        }
        let target = (q.clamp(0.0, 1.0) * total as f64).ceil() as u64;
        let mut acc = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            acc += b.load(Ordering::Relaxed);
            if acc >= target {
                return (1u64 << (i + 1)) as f64 * 1e-6; // bucket upper edge in µs
            }
        }
        (1u64 << 30) as f64 * 1e-6
    }
}

/// Service-level counters.
#[derive(Default)]
pub struct ServiceMetrics {
    pub requests: AtomicU64,
    pub batches: AtomicU64,
    pub spmv_latency: LatencyHistogram,
}

impl ServiceMetrics {
    pub fn new() -> Self {
        Self { requests: AtomicU64::new(0), batches: AtomicU64::new(0), spmv_latency: LatencyHistogram::new() }
    }

    pub fn mean_batch_size(&self) -> f64 {
        let b = self.batches.load(Ordering::Relaxed);
        if b == 0 {
            return 0.0;
        }
        self.requests.load(Ordering::Relaxed) as f64 / b as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_records_and_means() {
        let h = LatencyHistogram::new();
        h.record(0.001);
        h.record(0.003);
        assert_eq!(h.count(), 2);
        assert!((h.mean_secs() - 0.002).abs() < 1e-6);
    }

    #[test]
    fn quantiles_ordered() {
        let h = LatencyHistogram::new();
        for i in 1..=100 {
            h.record(i as f64 * 1e-5);
        }
        assert!(h.quantile_secs(0.5) <= h.quantile_secs(0.99));
        assert!(h.quantile_secs(0.99) > 1e-4);
    }

    #[test]
    fn batch_size_accounting() {
        let m = ServiceMetrics::new();
        m.requests.fetch_add(10, Ordering::Relaxed);
        m.batches.fetch_add(4, Ordering::Relaxed);
        assert!((m.mean_batch_size() - 2.5).abs() < 1e-12);
    }

    #[test]
    fn empty_histogram_safe() {
        let h = LatencyHistogram::new();
        assert_eq!(h.mean_secs(), 0.0);
        assert_eq!(h.quantile_secs(0.9), 0.0);
    }
}
