//! Service metrics: request counters, a fixed-bucket latency histogram
//! (log-spaced), a fused-batch-width histogram, and a bytes-moved
//! counter — all lock-free on the hot path. Rendered by
//! [`crate::harness::report::service_markdown`].

use std::sync::atomic::{AtomicU64, Ordering};

/// Log-spaced latency histogram from 1 µs to ~1 s (30 buckets, ×2 each).
pub struct LatencyHistogram {
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum_nanos: AtomicU64,
}

impl Default for LatencyHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl LatencyHistogram {
    pub fn new() -> Self {
        Self {
            buckets: (0..30).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum_nanos: AtomicU64::new(0),
        }
    }

    #[inline]
    pub fn record(&self, secs: f64) {
        let nanos = (secs * 1e9) as u64;
        let us = nanos / 1000;
        let idx = if us == 0 { 0 } else { (63 - us.leading_zeros() as usize).min(29) };
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_nanos.fetch_add(nanos, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn mean_secs(&self) -> f64 {
        let c = self.count();
        if c == 0 {
            return 0.0;
        }
        self.sum_nanos.load(Ordering::Relaxed) as f64 / c as f64 / 1e9
    }

    /// Approximate quantile from the histogram (upper bucket edge).
    pub fn quantile_secs(&self, q: f64) -> f64 {
        let total = self.count();
        if total == 0 {
            return 0.0;
        }
        let target = (q.clamp(0.0, 1.0) * total as f64).ceil() as u64;
        let mut acc = 0u64;
        for (i, b) in self.buckets.iter().enumerate() {
            acc += b.load(Ordering::Relaxed);
            if acc >= target {
                return (1u64 << (i + 1)) as f64 * 1e-6; // bucket upper edge in µs
            }
        }
        (1u64 << 30) as f64 * 1e-6
    }
}

/// Power-of-two histogram of fused-batch widths: bucket `i` counts
/// widths in `[2^i, 2^(i+1))`, the last bucket absorbs the overflow.
/// Makes the request-fusion win (mean width > 1) observable.
pub struct WidthHistogram {
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum: AtomicU64,
    max: AtomicU64,
}

impl Default for WidthHistogram {
    fn default() -> Self {
        Self::new()
    }
}

impl WidthHistogram {
    pub fn new() -> Self {
        Self {
            buckets: (0..16).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum: AtomicU64::new(0),
            max: AtomicU64::new(0),
        }
    }

    #[inline]
    pub fn record(&self, width: usize) {
        let w = width.max(1) as u64;
        let idx = (63 - w.leading_zeros() as usize).min(self.buckets.len() - 1);
        self.buckets[idx].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(w, Ordering::Relaxed);
        self.max.fetch_max(w, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    /// Mean recorded width (0 when empty).
    pub fn mean(&self) -> f64 {
        let c = self.count();
        if c == 0 {
            return 0.0;
        }
        self.sum.load(Ordering::Relaxed) as f64 / c as f64
    }

    pub fn max(&self) -> u64 {
        self.max.load(Ordering::Relaxed)
    }

    pub fn num_buckets(&self) -> usize {
        self.buckets.len()
    }

    /// Count in bucket `i` (widths in `[2^i, 2^(i+1))`).
    pub fn bucket(&self, i: usize) -> u64 {
        self.buckets[i].load(Ordering::Relaxed)
    }
}

/// Service-level counters.
pub struct ServiceMetrics {
    pub requests: AtomicU64,
    pub batches: AtomicU64,
    /// Kernel latency each request observed (the fused call's wall time).
    pub spmv_latency: LatencyHistogram,
    /// Width of every fused kernel call. Invariant: only batches that
    /// actually **executed** are recorded here — a shed request's width
    /// never enters this histogram (sheds are counted in
    /// [`Self::shed`] at submit time, before any width accounting), so
    /// `batch_width.count() == batches` always holds. Pinned by
    /// `service::tests::shed_requests_never_recorded_in_width_histogram`.
    pub batch_width: WidthHistogram,
    /// Estimated bytes streamed by the engine: the matrix format once
    /// per fused call plus `2 · nrows · sizeof(S)` per request (x in,
    /// y out) — the quantity request fusion amortizes.
    pub bytes_moved: AtomicU64,
    /// Requests shed because the bounded queue was full
    /// (`EhybError::Overloaded`) — recorded client-side at submit.
    pub shed: AtomicU64,
    /// Current fused-batch limit of an **adaptive** service
    /// (`spawn_adaptive` / `serve_adaptive`): shrinks when submissions
    /// shed, grows back while the queue drains idle. 0 = fixed-limit
    /// service (the default `spawn`/`serve` paths never touch it).
    pub adaptive_max_batch: AtomicU64,
    /// Fused batches quarantined because the engine panicked mid-call
    /// (every request in the batch got `EhybError::EngineFault`). One
    /// increment per poisoned *batch*, not per request.
    pub faults: AtomicU64,
    /// Engines respawned via the service's factory after a fault.
    /// Steady state: `respawns == faults`; a lag means the factory
    /// failed and the service exited.
    pub respawns: AtomicU64,
    /// Requests dropped at drain time because their deadline had
    /// already expired (`EhybError::DeadlineExceeded`) — they never
    /// occupied kernel width.
    pub deadline_misses: AtomicU64,
}

impl Default for ServiceMetrics {
    fn default() -> Self {
        Self::new()
    }
}

impl ServiceMetrics {
    pub fn new() -> Self {
        Self {
            requests: AtomicU64::new(0),
            batches: AtomicU64::new(0),
            spmv_latency: LatencyHistogram::new(),
            batch_width: WidthHistogram::new(),
            bytes_moved: AtomicU64::new(0),
            shed: AtomicU64::new(0),
            adaptive_max_batch: AtomicU64::new(0),
            faults: AtomicU64::new(0),
            respawns: AtomicU64::new(0),
            deadline_misses: AtomicU64::new(0),
        }
    }

    pub fn mean_batch_size(&self) -> f64 {
        let b = self.batches.load(Ordering::Relaxed);
        if b == 0 {
            return 0.0;
        }
        self.requests.load(Ordering::Relaxed) as f64 / b as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_records_and_means() {
        let h = LatencyHistogram::new();
        h.record(0.001);
        h.record(0.003);
        assert_eq!(h.count(), 2);
        assert!((h.mean_secs() - 0.002).abs() < 1e-6);
    }

    #[test]
    fn quantiles_ordered() {
        let h = LatencyHistogram::new();
        for i in 1..=100 {
            h.record(i as f64 * 1e-5);
        }
        assert!(h.quantile_secs(0.5) <= h.quantile_secs(0.99));
        assert!(h.quantile_secs(0.99) > 1e-4);
    }

    #[test]
    fn batch_size_accounting() {
        let m = ServiceMetrics::new();
        m.requests.fetch_add(10, Ordering::Relaxed);
        m.batches.fetch_add(4, Ordering::Relaxed);
        assert!((m.mean_batch_size() - 2.5).abs() < 1e-12);
    }

    #[test]
    fn empty_histogram_safe() {
        let h = LatencyHistogram::new();
        assert_eq!(h.mean_secs(), 0.0);
        assert_eq!(h.quantile_secs(0.9), 0.0);
    }

    #[test]
    fn adaptive_gauge_defaults_to_fixed() {
        // 0 marks a fixed-limit service; adaptive services overwrite it
        // with their live limit.
        let m = ServiceMetrics::new();
        assert_eq!(m.adaptive_max_batch.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn fault_counters_start_at_zero() {
        let m = ServiceMetrics::new();
        assert_eq!(m.faults.load(Ordering::Relaxed), 0);
        assert_eq!(m.respawns.load(Ordering::Relaxed), 0);
        assert_eq!(m.deadline_misses.load(Ordering::Relaxed), 0);
    }

    #[test]
    fn width_histogram_buckets_and_stats() {
        let h = WidthHistogram::new();
        for w in [1usize, 1, 2, 3, 8, 16] {
            h.record(w);
        }
        assert_eq!(h.count(), 6);
        assert_eq!(h.max(), 16);
        assert!((h.mean() - 31.0 / 6.0).abs() < 1e-12);
        assert_eq!(h.bucket(0), 2); // widths 1
        assert_eq!(h.bucket(1), 2); // widths 2..3
        assert_eq!(h.bucket(3), 1); // width 8
        assert_eq!(h.bucket(4), 1); // width 16
    }

    #[test]
    fn width_histogram_empty_and_overflow() {
        let h = WidthHistogram::new();
        assert_eq!(h.mean(), 0.0);
        assert_eq!(h.max(), 0);
        h.record(1 << 20); // overflow clamps into the last bucket
        assert_eq!(h.bucket(h.num_buckets() - 1), 1);
    }
}
