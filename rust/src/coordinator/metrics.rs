//! Deprecated location of the service metric types.
//!
//! 0.8 promoted [`LatencyHistogram`], [`WidthHistogram`], and
//! [`ServiceMetrics`] into [`crate::telemetry`] so every subsystem —
//! not just the service — publishes into one registry namespace. These
//! aliases keep 0.7 call sites compiling; migrate imports to
//! `ehyb::telemetry::*` (see MIGRATION.md 0.7 → 0.8).

#[deprecated(since = "0.8.0", note = "moved to `ehyb::telemetry::LatencyHistogram`")]
pub type LatencyHistogram = crate::telemetry::LatencyHistogram;

#[deprecated(since = "0.8.0", note = "moved to `ehyb::telemetry::WidthHistogram`")]
pub type WidthHistogram = crate::telemetry::WidthHistogram;

#[deprecated(since = "0.8.0", note = "moved to `ehyb::telemetry::ServiceMetrics`")]
pub type ServiceMetrics = crate::telemetry::ServiceMetrics;

#[cfg(test)]
mod tests {
    // The deprecated aliases must keep resolving to the moved types
    // (same layout, same inherent methods) for 0.7 call sites.
    #![allow(deprecated)]

    #[test]
    fn aliases_resolve_to_telemetry_types() {
        let h = super::LatencyHistogram::new();
        h.record(1e-3);
        assert_eq!(h.count(), 1);
        let m = super::ServiceMetrics::new();
        assert_eq!(m.mean_batch_size(), 0.0);
        let w: super::WidthHistogram = crate::telemetry::WidthHistogram::new();
        w.record(4);
        assert_eq!(w.max(), 4);
    }
}
