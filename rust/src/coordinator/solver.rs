//! Preconditioned Krylov solvers whose hot path is the SpMV under test.
//! Generic over the SpMV implementation (CPU engines, the GPU-simulated
//! kernel, or the PJRT engine) via a closure, so the same solver drives
//! every layer of the stack.

use super::precond::Preconditioner;
use crate::api::batch::{VecBatch, VecBatchMut};
use crate::sparse::scalar::{axpy, dot, norm2, Scalar};
use crate::util::Timer;

#[derive(Clone, Debug)]
pub struct SolverConfig {
    pub max_iters: usize,
    /// Relative residual tolerance ‖r‖/‖b‖.
    pub rtol: f64,
    /// Record ‖r‖ every iteration (the fem_solver example logs this).
    pub track_history: bool,
    /// Declare [`SolveStatus::Diverged`] after this many *consecutive*
    /// iterations with a growing relative residual. 0 (the default)
    /// disables the check — existing trajectories are untouched; the
    /// monitor only ever stops iterations that were already failing.
    pub divergence_window: usize,
}

impl Default for SolverConfig {
    fn default() -> Self {
        Self { max_iters: 1000, rtol: 1e-8, track_history: true, divergence_window: 0 }
    }
}

/// How a solve ended. Replaces the old bare `converged: bool`: a
/// breakdown (a Krylov denominator collapsed — the method cannot
/// continue) and a divergence (the residual grew
/// [`SolverConfig::divergence_window`] iterations in a row) are
/// distinct, actionable failures, and `MaxIters` means "ran out of
/// budget while still making progress".
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SolveStatus {
    Converged,
    MaxIters,
    Breakdown,
    Diverged,
}

impl SolveStatus {
    /// Stable lowercase label for tables and CLI output.
    pub fn name(self) -> &'static str {
        match self {
            SolveStatus::Converged => "converged",
            SolveStatus::MaxIters => "max-iters",
            SolveStatus::Breakdown => "breakdown",
            SolveStatus::Diverged => "diverged",
        }
    }
}

#[derive(Clone, Debug)]
pub struct SolveReport {
    pub solver: &'static str,
    pub iters: usize,
    pub status: SolveStatus,
    pub final_rel_residual: f64,
    pub spmv_count: usize,
    pub wall_secs: f64,
    pub history: Vec<f64>,
}

impl SolveReport {
    /// Derived accessor over [`Self::status`] (the pre-0.6 boolean).
    pub fn converged(&self) -> bool {
        matches!(self.status, SolveStatus::Converged)
    }
}

/// Tracks consecutive residual growth; fires when the run reaches the
/// configured window. `window == 0` disables it (never fires), so the
/// default config observes nothing and changes no trajectory.
pub struct DivergenceMonitor {
    window: usize,
    prev: f64,
    run: usize,
}

impl DivergenceMonitor {
    pub fn new(window: usize) -> Self {
        Self { window, prev: f64::INFINITY, run: 0 }
    }

    /// Feed one relative residual; true when it has grown `window`
    /// consecutive iterations (NaN counts as growth — a poisoned
    /// iterate never compares greater, but it is certainly not
    /// progress).
    pub fn observe(&mut self, rel_residual: f64) -> bool {
        if self.window == 0 {
            return false;
        }
        if rel_residual > self.prev || rel_residual.is_nan() {
            self.run += 1;
        } else {
            self.run = 0;
        }
        self.prev = rel_residual;
        self.run >= self.window
    }
}

/// Outcome of one PCG update.
enum StepOutcome {
    Continue,
    Converged,
    Breakdown,
    Diverged,
}

/// One preconditioned-CG update given `ap = A p` — the shared iteration
/// body of [`cg`] and [`cg_many`], extracted so the two can never drift
/// (multi-RHS trajectories are documented as bit-identical to [`cg`]).
#[allow(clippy::too_many_arguments)]
fn cg_step<S: Scalar>(
    x: &mut [S],
    r: &mut [S],
    z: &mut [S],
    p: &mut [S],
    rz: &mut S,
    ap: &[S],
    precond: &dyn Preconditioner<S>,
    bnorm: f64,
    rtol: f64,
    track_history: bool,
    history: &mut Vec<f64>,
    monitor: &mut DivergenceMonitor,
) -> StepOutcome {
    let n = x.len();
    let den = dot(p, ap).to_f64();
    if den.abs() < 1e-300 {
        return StepOutcome::Breakdown;
    }
    let alpha = S::from_f64(rz.to_f64() / den);
    axpy(alpha, p, x);
    axpy(-alpha, ap, r);
    let rn = norm2(r).to_f64() / bnorm;
    if track_history {
        history.push(rn);
    }
    if rn < rtol {
        return StepOutcome::Converged;
    }
    if monitor.observe(rn) {
        return StepOutcome::Diverged;
    }
    precond.apply(r, z);
    let rz_new = dot(r, z);
    // Sign-preserving clamp: only guard against |rz| underflow. (A plain
    // `max(1e-300).copysign(..)` would collapse any negative rz — a
    // non-SPD preconditioner — to -1e-300 and explode beta.)
    let rz_old = rz.to_f64();
    let denom = if rz_old.abs() < 1e-300 { 1e-300f64.copysign(rz_old) } else { rz_old };
    let beta = S::from_f64(rz_new.to_f64() / denom);
    *rz = rz_new;
    for i in 0..n {
        p[i] = z[i] + beta * p[i];
    }
    StepOutcome::Continue
}

/// Preconditioned conjugate gradients (SPD systems).
pub fn cg<S: Scalar>(
    mut spmv: impl FnMut(&[S], &mut [S]),
    b: &[S],
    x0: &[S],
    precond: &dyn Preconditioner<S>,
    cfg: &SolverConfig,
) -> (Vec<S>, SolveReport) {
    let n = b.len();
    let timer = Timer::start();
    let mut x = x0.to_vec();
    let mut r = vec![S::ZERO; n];
    let mut ax = vec![S::ZERO; n];
    spmv(&x, &mut ax);
    for i in 0..n {
        r[i] = b[i] - ax[i];
    }
    let bnorm = norm2(b).to_f64().max(1e-300);
    let mut z = vec![S::ZERO; n];
    precond.apply(&r, &mut z);
    let mut p = z.clone();
    let mut rz = dot(&r, &z);
    let mut spmv_count = 1usize;
    let mut history = Vec::new();
    let mut status = SolveStatus::MaxIters;
    let mut iters = 0usize;
    let mut monitor = DivergenceMonitor::new(cfg.divergence_window);

    for k in 0..cfg.max_iters {
        iters = k + 1;
        let mut ap = vec![S::ZERO; n];
        spmv(&p, &mut ap);
        spmv_count += 1;
        match cg_step(
            &mut x,
            &mut r,
            &mut z,
            &mut p,
            &mut rz,
            &ap,
            precond,
            bnorm,
            cfg.rtol,
            cfg.track_history,
            &mut history,
            &mut monitor,
        ) {
            StepOutcome::Continue => {}
            StepOutcome::Converged => {
                status = SolveStatus::Converged;
                break;
            }
            StepOutcome::Breakdown => {
                status = SolveStatus::Breakdown;
                break;
            }
            StepOutcome::Diverged => {
                status = SolveStatus::Diverged;
                break;
            }
        }
    }
    let final_rel_residual = norm2(&r).to_f64() / bnorm;
    (
        x,
        SolveReport {
            solver: "cg",
            iters,
            status,
            final_rel_residual,
            spmv_count,
            wall_secs: timer.elapsed_secs(),
            history,
        },
    )
}

/// Multi-RHS preconditioned CG: solve `A xᵢ = bᵢ` for several
/// right-hand sides sharing one matrix (multiple load cases /
/// preconditioned systems over one FEM stiffness matrix). Every
/// iteration's SpMVs are fused into **one** batched call over borrowed
/// [`VecBatch`]/[`VecBatchMut`] views of two persistent contiguous
/// buffers, so the matrix streams once per iteration instead of once
/// per system and the batch occupies one allocation per side — the
/// solver-layer consumer of [`crate::spmv::SpmvEngine::spmv_batch`].
///
/// The per-system arithmetic is identical to [`cg`], so when
/// `spmv_batch` is element-wise equal to repeated `spmv` (every engine
/// guarantees this) each system's trajectory is bit-identical to a
/// standalone [`cg`] solve. Converged (or broken-down) systems drop
/// out of the batch; the loop ends when none remain active.
pub fn cg_many<S: Scalar>(
    mut spmv_batch: impl FnMut(VecBatch<'_, S>, &mut VecBatchMut<'_, S>),
    bs: &[Vec<S>],
    x0s: &[Vec<S>],
    precond: &dyn Preconditioner<S>,
    cfg: &SolverConfig,
) -> Vec<(Vec<S>, SolveReport)> {
    assert_eq!(bs.len(), x0s.len(), "rhs/x0 count mismatch");
    let nsys = bs.len();
    if nsys == 0 {
        return Vec::new();
    }
    let n = bs[0].len();
    for (b, x0) in bs.iter().zip(x0s) {
        assert_eq!(b.len(), n, "rhs lengths disagree");
        assert_eq!(x0.len(), n, "x0 lengths disagree");
    }
    let timer = Timer::start();

    struct Sys<S> {
        x: Vec<S>,
        r: Vec<S>,
        z: Vec<S>,
        p: Vec<S>,
        rz: S,
        bnorm: f64,
        active: bool,
        status: SolveStatus,
        iters: usize,
        spmv_count: usize,
        history: Vec<f64>,
        monitor: DivergenceMonitor,
    }

    // Persistent contiguous batch storage for the fused calls: inputs
    // (x₀ now, then the active p's) and outputs (Ax₀ / Ap), one
    // allocation per side for the whole solve.
    let mut xdata = vec![S::ZERO; nsys * n];
    let mut ydata = vec![S::ZERO; nsys * n];
    for (i, x0) in x0s.iter().enumerate() {
        xdata[i * n..(i + 1) * n].copy_from_slice(x0);
    }
    {
        let xs = VecBatch::new(&xdata, n).expect("contiguous solver batch");
        let mut ys = VecBatchMut::new(&mut ydata, n).expect("contiguous solver batch");
        spmv_batch(xs, &mut ys);
    }
    let mut sys: Vec<Sys<S>> = (0..nsys)
        .map(|i| {
            let ax0 = &ydata[i * n..(i + 1) * n];
            let mut r = vec![S::ZERO; n];
            for j in 0..n {
                r[j] = bs[i][j] - ax0[j];
            }
            let mut z = vec![S::ZERO; n];
            precond.apply(&r, &mut z);
            let rz = dot(&r, &z);
            Sys {
                x: x0s[i].clone(),
                p: z.clone(),
                r,
                z,
                rz,
                bnorm: norm2(&bs[i]).to_f64().max(1e-300),
                active: true,
                status: SolveStatus::MaxIters,
                iters: 0,
                spmv_count: 1,
                history: Vec::new(),
                monitor: DivergenceMonitor::new(cfg.divergence_window),
            }
        })
        .collect();

    for _k in 0..cfg.max_iters {
        let act: Vec<usize> =
            sys.iter().enumerate().filter(|(_, s)| s.active).map(|(i, _)| i).collect();
        if act.is_empty() {
            break;
        }
        {
            // Stage the active search directions into the contiguous
            // input batch (the copy is O(act·n), dwarfed by the SpMV).
            for (j, &i) in act.iter().enumerate() {
                xdata[j * n..(j + 1) * n].copy_from_slice(&sys[i].p);
            }
            let xs =
                VecBatch::new(&xdata[..act.len() * n], n).expect("contiguous solver batch");
            let mut ys =
                VecBatchMut::new(&mut ydata[..act.len() * n], n).expect("contiguous solver batch");
            spmv_batch(xs, &mut ys);
        }
        for (j, &i) in act.iter().enumerate() {
            let s = &mut sys[i];
            let ap: &[S] = &ydata[j * n..(j + 1) * n];
            s.iters += 1;
            s.spmv_count += 1;
            match cg_step(
                &mut s.x,
                &mut s.r,
                &mut s.z,
                &mut s.p,
                &mut s.rz,
                ap,
                precond,
                s.bnorm,
                cfg.rtol,
                cfg.track_history,
                &mut s.history,
                &mut s.monitor,
            ) {
                StepOutcome::Continue => {}
                StepOutcome::Converged => {
                    s.status = SolveStatus::Converged;
                    s.active = false;
                }
                StepOutcome::Breakdown => {
                    s.status = SolveStatus::Breakdown;
                    s.active = false;
                }
                StepOutcome::Diverged => {
                    s.status = SolveStatus::Diverged;
                    s.active = false;
                }
            }
        }
    }

    sys.into_iter()
        .map(|s| {
            let final_rel_residual = norm2(&s.r).to_f64() / s.bnorm;
            (
                s.x,
                SolveReport {
                    solver: "cg-many",
                    iters: s.iters,
                    status: s.status,
                    final_rel_residual,
                    spmv_count: s.spmv_count,
                    wall_secs: timer.elapsed_secs(),
                    history: s.history,
                },
            )
        })
        .collect()
}

/// BiCGSTAB (general nonsymmetric systems).
pub fn bicgstab<S: Scalar>(
    mut spmv: impl FnMut(&[S], &mut [S]),
    b: &[S],
    x0: &[S],
    precond: &dyn Preconditioner<S>,
    cfg: &SolverConfig,
) -> (Vec<S>, SolveReport) {
    let n = b.len();
    let timer = Timer::start();
    let mut x = x0.to_vec();
    let mut r = vec![S::ZERO; n];
    let mut tmp = vec![S::ZERO; n];
    spmv(&x, &mut tmp);
    for i in 0..n {
        r[i] = b[i] - tmp[i];
    }
    let r0 = r.clone(); // shadow residual
    let bnorm = norm2(b).to_f64().max(1e-300);
    let mut rho = S::ONE;
    let mut alpha = S::ONE;
    let mut omega = S::ONE;
    let mut v = vec![S::ZERO; n];
    let mut p = vec![S::ZERO; n];
    let mut spmv_count = 1usize;
    let mut history = Vec::new();
    let mut status = SolveStatus::MaxIters;
    let mut iters = 0usize;
    let mut monitor = DivergenceMonitor::new(cfg.divergence_window);
    let mut phat = vec![S::ZERO; n];
    let mut shat = vec![S::ZERO; n];
    let mut s = vec![S::ZERO; n];
    let mut t = vec![S::ZERO; n];

    for k in 0..cfg.max_iters {
        iters = k + 1;
        let rho_new = dot(&r0, &r);
        if rho_new.to_f64().abs() < 1e-300 {
            status = SolveStatus::Breakdown;
            break;
        }
        if k == 0 {
            p.copy_from_slice(&r);
        } else {
            let beta = S::from_f64(
                (rho_new.to_f64() / rho.to_f64()) * (alpha.to_f64() / omega.to_f64()),
            );
            for i in 0..n {
                p[i] = r[i] + beta * (p[i] - omega * v[i]);
            }
        }
        rho = rho_new;
        precond.apply(&p, &mut phat);
        spmv(&phat, &mut v);
        spmv_count += 1;
        let den = dot(&r0, &v).to_f64();
        if den.abs() < 1e-300 {
            status = SolveStatus::Breakdown;
            break;
        }
        alpha = S::from_f64(rho.to_f64() / den);
        for i in 0..n {
            s[i] = r[i] - alpha * v[i];
        }
        let snorm = norm2(&s).to_f64() / bnorm;
        if snorm < cfg.rtol {
            axpy(alpha, &phat, &mut x);
            if cfg.track_history {
                history.push(snorm);
            }
            status = SolveStatus::Converged;
            r.copy_from_slice(&s);
            break;
        }
        precond.apply(&s, &mut shat);
        spmv(&shat, &mut t);
        spmv_count += 1;
        let tt = dot(&t, &t).to_f64();
        if tt < 1e-300 {
            status = SolveStatus::Breakdown;
            break;
        }
        omega = S::from_f64(dot(&t, &s).to_f64() / tt);
        for i in 0..n {
            x[i] += alpha * phat[i] + omega * shat[i];
            r[i] = s[i] - omega * t[i];
        }
        let rn = norm2(&r).to_f64() / bnorm;
        if cfg.track_history {
            history.push(rn);
        }
        if rn < cfg.rtol {
            status = SolveStatus::Converged;
            break;
        }
        if monitor.observe(rn) {
            status = SolveStatus::Diverged;
            break;
        }
        if omega.to_f64().abs() < 1e-300 {
            status = SolveStatus::Breakdown;
            break;
        }
    }
    let final_rel_residual = norm2(&r).to_f64() / bnorm;
    (
        x,
        SolveReport {
            solver: "bicgstab",
            iters,
            status,
            final_rel_residual,
            spmv_count,
            wall_secs: timer.elapsed_secs(),
            history,
        },
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::precond::{Identity, Jacobi, Spai0};
    use crate::sparse::csr::Csr;
    use crate::sparse::gen::{diag_dominant, poisson2d, poisson3d, unstructured_mesh};

    fn residual(a: &Csr<f64>, x: &[f64], b: &[f64]) -> f64 {
        let mut ax = vec![0.0; b.len()];
        a.spmv(x, &mut ax);
        let num: f64 = ax.iter().zip(b).map(|(ai, bi)| (ai - bi) * (ai - bi)).sum::<f64>().sqrt();
        let den: f64 = b.iter().map(|v| v * v).sum::<f64>().sqrt();
        num / den
    }

    fn rhs(n: usize) -> Vec<f64> {
        (0..n).map(|i| ((i * 37 + 11) % 23) as f64 / 23.0 - 0.5).collect()
    }

    #[test]
    fn cg_solves_poisson2d() {
        let a = poisson2d::<f64>(20, 20);
        let b = rhs(400);
        let pre = Jacobi::new(&a);
        let (x, rep) = cg(|v, y| a.spmv(v, y), &b, &vec![0.0; 400], &pre, &SolverConfig::default());
        assert!(rep.converged(), "{rep:?}");
        assert_eq!(rep.status, SolveStatus::Converged);
        assert!(residual(&a, &x, &b) < 1e-7);
        assert!(rep.history.len() == rep.iters);
    }

    #[test]
    fn cg_jacobi_faster_than_identity_on_scaled_system() {
        // Badly scaled SPD system: Jacobi should cut iterations.
        use crate::sparse::coo::Coo;
        let base = poisson2d::<f64>(16, 16);
        let n = base.nrows();
        let mut coo = Coo::<f64>::new(n, n);
        for i in 0..n {
            let (cols, vals) = base.row(i);
            let si = 1.0 + (i % 7) as f64 * 10.0;
            for (&c, &v) in cols.iter().zip(vals) {
                let sj = 1.0 + (c as usize % 7) as f64 * 10.0;
                coo.push(i, c as usize, v * si * sj);
            }
        }
        let a = coo.to_csr();
        let b = rhs(n);
        let cfg = SolverConfig { max_iters: 2000, ..Default::default() };
        let (_, rep_id) = cg(|v, y| a.spmv(v, y), &b, &vec![0.0; n], &Identity, &cfg);
        let pre = Jacobi::new(&a);
        let (_, rep_j) = cg(|v, y| a.spmv(v, y), &b, &vec![0.0; n], &pre, &cfg);
        assert!(rep_j.iters < rep_id.iters, "jacobi {} >= identity {}", rep_j.iters, rep_id.iters);
    }

    #[test]
    fn bicgstab_solves_nonsymmetric() {
        let a = diag_dominant(&unstructured_mesh::<f64>(14, 14, 0.4, 7));
        let n = a.nrows();
        let b = rhs(n);
        let pre = Spai0::new(&a);
        let (x, rep) =
            bicgstab(|v, y| a.spmv(v, y), &b, &vec![0.0; n], &pre, &SolverConfig::default());
        assert!(rep.converged(), "{rep:?}");
        assert!(residual(&a, &x, &b) < 1e-7);
    }

    #[test]
    fn cg_through_ehyb_engine_matches_csr_path() {
        use crate::preprocess::{EhybPlan, PreprocessConfig};
        use crate::spmv::ehyb_cpu::EhybCpu;
        use crate::spmv::SpmvEngine;
        let a = poisson3d::<f64>(8, 8, 8);
        let n = a.nrows();
        let plan = EhybPlan::build(
            &a,
            &PreprocessConfig { vec_size_override: Some(128), ..Default::default() },
        )
        .unwrap();
        let engine = EhybCpu::new(&plan);
        let b = rhs(n);
        let pre = Jacobi::new(&a);
        let cfg = SolverConfig::default();
        let (x1, r1) = cg(|v, y| a.spmv(v, y), &b, &vec![0.0; n], &pre, &cfg);
        let (x2, r2) = cg(|v, y| engine.spmv(v, y), &b, &vec![0.0; n], &pre, &cfg);
        assert!(r1.converged() && r2.converged());
        // Same Krylov trajectory up to rounding: same iteration count ±1.
        assert!((r1.iters as i64 - r2.iters as i64).abs() <= 1, "{} vs {}", r1.iters, r2.iters);
        let diff: f64 =
            x1.iter().zip(&x2).map(|(a, b)| (a - b) * (a - b)).sum::<f64>().sqrt();
        assert!(diff < 1e-6, "solutions diverged: {diff}");
    }

    #[test]
    fn residual_history_monotone_ish_for_cg() {
        // CG residuals are not strictly monotone, but the trend must be
        // strongly downward: final < 1e-6 * initial.
        let a = poisson2d::<f64>(24, 24);
        let n = a.nrows();
        let b = rhs(n);
        let pre = Jacobi::new(&a);
        let (_, rep) = cg(|v, y| a.spmv(v, y), &b, &vec![0.0; n], &pre, &SolverConfig::default());
        let first = rep.history.first().copied().unwrap_or(1.0);
        let last = *rep.history.last().unwrap();
        assert!(last < first * 1e-4);
    }

    #[test]
    fn cg_many_matches_sequential_cg_bitwise() {
        // The fused multi-RHS solve must reproduce each standalone CG
        // trajectory exactly: spmv_batch is element-wise identical to
        // repeated spmv and the scalar update order is shared.
        use crate::preprocess::{EhybPlan, PreprocessConfig};
        use crate::spmv::ehyb_cpu::EhybCpu;
        use crate::spmv::SpmvEngine;
        let a = poisson2d::<f64>(18, 18);
        let n = a.nrows();
        let plan = EhybPlan::build(
            &a,
            &PreprocessConfig { vec_size_override: Some(64), ..Default::default() },
        )
        .unwrap();
        let engine = EhybCpu::new(&plan);
        let bs: Vec<Vec<f64>> = (0..3)
            .map(|t| (0..n).map(|i| ((i * 5 + t * 13 + 1) % 17) as f64 / 17.0 - 0.5).collect())
            .collect();
        let x0s = vec![vec![0.0; n]; 3];
        let pre = Jacobi::new(&a);
        let cfg = SolverConfig::default();
        let many = cg_many(|xs, ys| engine.spmv_batch(xs, ys), &bs, &x0s, &pre, &cfg);
        assert_eq!(many.len(), 3);
        for (i, (x, rep)) in many.iter().enumerate() {
            let (x1, rep1) = cg(|v, y: &mut [f64]| engine.spmv(v, y), &bs[i], &x0s[i], &pre, &cfg);
            assert!(rep.converged() && rep1.converged(), "system {i}: {rep:?} vs {rep1:?}");
            assert_eq!(rep.iters, rep1.iters, "system {i} diverged from standalone CG");
            assert_eq!(x, &x1, "system {i} solution differs");
            assert_eq!(rep.history, rep1.history, "system {i} residual history differs");
        }
    }

    #[test]
    fn cg_many_handles_mixed_convergence_speeds() {
        // Systems converge at different iteration counts; slower ones
        // must keep iterating after faster ones drop out of the batch.
        let a = poisson2d::<f64>(16, 16);
        let n = a.nrows();
        let bs: Vec<Vec<f64>> = vec![
            rhs(n),
            (0..n).map(|i| if i == 0 { 1.0 } else { 0.0 }).collect(), // point source
        ];
        let x0s = vec![vec![0.0; n]; 2];
        let pre = Jacobi::new(&a);
        let res = cg_many(
            |xs, ys| {
                for b in 0..xs.width() {
                    a.spmv(xs.col(b), ys.col_mut(b));
                }
            },
            &bs,
            &x0s,
            &pre,
            &SolverConfig::default(),
        );
        for (i, (x, rep)) in res.iter().enumerate() {
            assert!(rep.converged(), "system {i}: {rep:?}");
            assert!(residual(&a, x, &bs[i]) < 1e-7, "system {i}");
        }
    }

    #[test]
    fn cg_many_empty_input() {
        let a = poisson2d::<f64>(4, 4);
        let pre = Jacobi::new(&a);
        let res = cg_many(|_xs, _ys| {}, &[], &[], &pre, &SolverConfig::default());
        assert!(res.is_empty());
    }

    #[test]
    fn zero_rhs_converges_immediately() {
        let a = poisson2d::<f64>(8, 8);
        let b = vec![0.0; 64];
        let pre = Jacobi::new(&a);
        let (x, rep) = cg(|v, y| a.spmv(v, y), &b, &vec![0.0; 64], &pre, &SolverConfig::default());
        assert!(rep.final_rel_residual < 1e-8);
        assert!(x.iter().all(|&v| v.abs() < 1e-12));
    }

    #[test]
    fn status_names_are_stable() {
        assert_eq!(SolveStatus::Converged.name(), "converged");
        assert_eq!(SolveStatus::MaxIters.name(), "max-iters");
        assert_eq!(SolveStatus::Breakdown.name(), "breakdown");
        assert_eq!(SolveStatus::Diverged.name(), "diverged");
    }

    #[test]
    fn out_of_budget_reports_max_iters() {
        let a = poisson2d::<f64>(20, 20);
        let b = rhs(400);
        let pre = Identity;
        let cfg = SolverConfig { max_iters: 2, ..Default::default() };
        let (_, rep) = cg(|v, y| a.spmv(v, y), &b, &vec![0.0; 400], &pre, &cfg);
        assert_eq!(rep.status, SolveStatus::MaxIters);
        assert!(!rep.converged());
        assert_eq!(rep.iters, 2);
    }

    #[test]
    fn zero_operator_reports_breakdown() {
        // A ≡ 0 collapses the first CG denominator: p·Ap = 0.
        let b = vec![1.0f64; 8];
        let (_, rep) =
            cg(|_v, y: &mut [f64]| y.fill(0.0), &b, &vec![0.0; 8], &Identity, &SolverConfig::default());
        assert_eq!(rep.status, SolveStatus::Breakdown);
        assert!(!rep.converged());
        assert_eq!(rep.iters, 1);
        // BiCGSTAB breaks down on the same operator (r0·v = 0).
        let (_, rep) = bicgstab(
            |_v, y: &mut [f64]| y.fill(0.0),
            &b,
            &vec![0.0; 8],
            &Identity,
            &SolverConfig::default(),
        );
        assert_eq!(rep.status, SolveStatus::Breakdown);
    }

    #[test]
    fn growing_residual_reports_diverged_within_window() {
        // Nonsymmetric circulant operator A = I + P (P = cyclic down
        // shift): CG's assumptions are violated and the residual grows
        // every iteration (hand trace: ‖r‖ = 1 after iter 1, √3 after
        // iter 2), so window = 1 must fire at iteration 2.
        let n = 8;
        let spmv = |x: &[f64], y: &mut [f64]| {
            for i in 0..n {
                y[i] = x[i] + x[(i + n - 1) % n];
            }
        };
        let mut b = vec![0.0f64; n];
        b[0] = 1.0;
        let cfg = SolverConfig { divergence_window: 1, ..Default::default() };
        let (_, rep) = cg(spmv, &b, &vec![0.0; n], &Identity, &cfg);
        assert_eq!(rep.status, SolveStatus::Diverged, "{rep:?}");
        assert_eq!(rep.iters, 2);
        assert!(!rep.converged());
        // With the monitor disabled (the default window = 0), the same
        // solve never reports divergence and keeps iterating past the
        // point where the window would have fired — trajectories
        // without an opt-in window are untouched.
        let cfg0 = SolverConfig { max_iters: 50, ..Default::default() };
        let (_, rep0) = cg(spmv, &b, &vec![0.0; n], &Identity, &cfg0);
        assert_ne!(rep0.status, SolveStatus::Diverged);
        assert!(rep0.iters > 2);
    }

    #[test]
    fn divergence_monitor_requires_consecutive_growth() {
        let mut m = DivergenceMonitor::new(2);
        assert!(!m.observe(1.0)); // first sample never fires
        assert!(!m.observe(2.0)); // run = 1
        assert!(!m.observe(1.5)); // shrank: run resets
        assert!(!m.observe(2.0)); // run = 1
        assert!(m.observe(3.0)); // run = 2 → fire
        // NaN counts as growth.
        let mut m = DivergenceMonitor::new(1);
        assert!(!m.observe(1.0));
        assert!(m.observe(f64::NAN));
        // Window 0 never fires.
        let mut m = DivergenceMonitor::new(0);
        assert!(!m.observe(1.0));
        assert!(!m.observe(f64::INFINITY));
    }

    #[test]
    fn cg_many_reports_per_system_status() {
        // One well-posed system converges while its batch-mate hits the
        // iteration budget: statuses are tracked per system.
        let a = poisson2d::<f64>(16, 16);
        let n = a.nrows();
        let bs = vec![rhs(n), rhs(n)];
        let x0s = vec![vec![0.0; n]; 2];
        let pre = Jacobi::new(&a);
        let cfg = SolverConfig { max_iters: 3, ..Default::default() };
        let res = cg_many(
            |xs, ys| {
                for bcol in 0..xs.width() {
                    a.spmv(xs.col(bcol), ys.col_mut(bcol));
                }
            },
            &bs,
            &x0s,
            &pre,
            &cfg,
        );
        for (_, rep) in &res {
            assert_eq!(rep.status, SolveStatus::MaxIters, "{rep:?}");
        }
        let res = cg_many(
            |xs, ys| {
                for bcol in 0..xs.width() {
                    a.spmv(xs.col(bcol), ys.col_mut(bcol));
                }
            },
            &bs,
            &x0s,
            &pre,
            &SolverConfig::default(),
        );
        for (_, rep) in &res {
            assert_eq!(rep.status, SolveStatus::Converged, "{rep:?}");
        }
    }
}
