//! Storage-traffic simulation (ISSUE 7 tentpole): replay a *prepared*
//! plan — EHYB partitions with their explicit x-slice cache, the
//! CSR/ELL/SELL-P baseline walks, and [`ShardPlan`] halo traffic —
//! through a modeled memory hierarchy (per-partition shared memory,
//! sectored L2 built on [`L2Sim`], DRAM) and count what actually moves.
//!
//! The paper's whole argument is that SpMV is data-movement-bound and
//! EHYB wins by *not* re-fetching x (§3.1); the static roofline bounds
//! in [`crate::perfmodel`] cannot see that — they charge compulsory
//! bytes only. This module is the executable oracle the ROADMAP's
//! "tune off gpu::l2, not the roofline" item asks for (spada-sim's
//! storage-traffic model, SNIPPETS.md 1): per-level read/write byte
//! counters, x-reuse statistics, and a [`TrafficReport::predicted_secs`]
//! that credits L2/shared-memory hits.
//!
//! Everything here is deterministic: no RNG, no clocks, fixed iteration
//! order — replaying the same plan twice yields bit-identical counters
//! (gated by `tests/traffic.rs`).

use crate::gpu::device::GpuDevice;
use crate::gpu::l2::L2Sim;
use crate::shard::ShardPlan;
use crate::sparse::csr::Csr;
use crate::sparse::ehyb::EhybMatrix;
use crate::sparse::scalar::Scalar;
use std::collections::HashSet;

// Disjoint synthetic base addresses per array (16 GiB regions), the
// same map `gpu::kernels` uses, so matrix streams and x gathers
// conflict in the simulated L2 like they do on hardware.
const X_BASE: u64 = 0;
const VAL_BASE: u64 = 1 << 34;
const COL_BASE: u64 = 2 << 34;
const PTR_BASE: u64 = 3 << 34;
const AUX_BASE: u64 = 5 << 34;

/// Rows a static CSR block covers (mirrors `gpu::kernels`' warp-per-row
/// model: 4 warps × 32 rows of warp-width work per block).
const ROWS_PER_BLOCK: usize = 128;

/// Traffic observed at one level of the hierarchy. `accesses` is
/// counted per probe, `hits`/`misses` per outcome, so the conservation
/// invariant `hits + misses == accesses` is a real check on the replay,
/// not true by construction.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct LevelTraffic {
    /// Bytes requested from this level (sector-granular for L2/DRAM).
    pub read_bytes: u64,
    /// Bytes written through this level.
    pub write_bytes: u64,
    /// Probes issued to this level.
    pub accesses: u64,
    /// Probes served here.
    pub hits: u64,
    /// Probes passed down to the next level.
    pub misses: u64,
}

impl LevelTraffic {
    pub fn total_bytes(&self) -> u64 {
        self.read_bytes + self.write_bytes
    }

    pub fn hit_rate(&self) -> f64 {
        if self.accesses == 0 {
            return 0.0;
        }
        self.hits as f64 / self.accesses as f64
    }
}

/// Reuse statistics for the input vector — the quantity EHYB's explicit
/// cache exists to exploit.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct XReuse {
    /// Element requests into x (gather lanes + explicit-cache fills).
    pub gathers: u64,
    /// Sector probes after warp coalescing.
    pub sector_probes: u64,
    /// Distinct x sectors ever touched (compulsory working set).
    pub distinct_sectors: u64,
    /// x bytes that actually came from DRAM (L2 misses × sector).
    pub dram_bytes: u64,
}

impl XReuse {
    /// Average times each touched x sector was requested; 1.0 means no
    /// reuse to exploit, large values mean caching pays.
    pub fn reuse_factor(&self) -> f64 {
        if self.distinct_sectors == 0 {
            return 1.0;
        }
        self.sector_probes as f64 / self.distinct_sectors as f64
    }
}

/// Logical (pre-coalescing) bytes each kernel component requested — the
/// attribution layer [`crate::profile::DriftReport`] diffs against the
/// engines' observed counters. Unlike the per-level counters these are
/// not sector-granular: they count exactly the bytes the replayed
/// kernel asked for, which is what the structural counters in
/// [`crate::profile`] can reproduce and a drifting prediction can be
/// blamed on by name.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct ComponentBytes {
    /// Primary format stream: ELL slice values + u16 columns for EHYB,
    /// the whole CSR/ELL/SELL-P stream for the baseline walks.
    pub ell: u64,
    /// ER-tail stream (u32 columns + values); 0 for baselines.
    pub er: u64,
    /// Descriptors: slice/row pointers, widths, `y_idx_er`.
    pub meta: u64,
    /// Explicit shared-memory x-cache fills (EHYB only).
    pub x_fill: u64,
    /// Uncached x gather lanes (ER tail, CSR gathers), logical bytes.
    pub x_gather: u64,
    /// Halo (out-of-shard) share split out of `x_gather` in shard
    /// replays; 0 for whole-matrix kernels.
    pub halo: u64,
    /// Output-vector writes.
    pub write: u64,
}

impl ComponentBytes {
    pub fn total(&self) -> u64 {
        self.ell + self.er + self.meta + self.x_fill + self.x_gather + self.halo + self.write
    }
}

/// Per-level traffic for one simulated kernel over one matrix.
#[derive(Clone, Debug, PartialEq)]
pub struct TrafficReport {
    /// Engine/kernel tag ("ehyb", "csr-vector", ...).
    pub name: String,
    pub nnz: usize,
    pub nrows: usize,
    /// Explicit shared-memory cache (EHYB only; never misses by
    /// construction — residency is guaranteed by the format).
    pub shm: LevelTraffic,
    pub l2: LevelTraffic,
    /// DRAM is the backstop: every probe hits.
    pub dram: LevelTraffic,
    pub x: XReuse,
    /// Logical per-component attribution of the requested bytes.
    pub components: ComponentBytes,
    /// Time at the binding level — max of DRAM, L2, and shared-memory
    /// service times — plus launch overhead. Unlike the roofline bound
    /// this credits hits: traffic served by L2/shm doesn't pay HBM.
    pub predicted_secs: f64,
}

impl TrafficReport {
    pub fn dram_total_bytes(&self) -> u64 {
        self.dram.total_bytes()
    }

    pub fn gflops(&self) -> f64 {
        if self.predicted_secs <= 0.0 {
            return 0.0;
        }
        2.0 * self.nnz as f64 / self.predicted_secs / 1e9
    }
}

/// The replay context: one sectored L2 in front of DRAM, plus the
/// per-level counters and x-reuse tracking.
struct MemSim<'d> {
    l2sim: L2Sim,
    dev: &'d GpuDevice,
    shm: LevelTraffic,
    l2: LevelTraffic,
    dram: LevelTraffic,
    x: XReuse,
    comp: ComponentBytes,
    x_sectors: HashSet<u64>,
}

impl<'d> MemSim<'d> {
    fn new(dev: &'d GpuDevice) -> Self {
        Self {
            l2sim: L2Sim::new(dev.l2_bytes, dev.sector_bytes),
            dev,
            shm: LevelTraffic::default(),
            l2: LevelTraffic::default(),
            dram: LevelTraffic::default(),
            x: XReuse::default(),
            comp: ComponentBytes::default(),
            x_sectors: HashSet::new(),
        }
    }

    /// Coalesced stream read of `len` bytes at `addr`: one L2 probe per
    /// covered sector; misses become sector-sized DRAM reads.
    fn stream_read(&mut self, addr: u64, len: u64) -> (u64, u64) {
        if len == 0 {
            return (0, 0);
        }
        let sb = self.dev.sector_bytes as u64;
        let (h, m) = self.l2sim.access_range(addr, len, sb);
        self.l2.accesses += h + m;
        self.l2.read_bytes += (h + m) * sb;
        self.l2.hits += h;
        self.l2.misses += m;
        self.dram.accesses += m;
        self.dram.hits += m; // DRAM always serves
        self.dram.read_bytes += m * sb;
        (h, m)
    }

    /// Stream read that targets the x vector (explicit-cache fills):
    /// same L2/DRAM accounting, plus x-reuse tracking.
    fn stream_read_x(&mut self, addr: u64, len: u64, tau: u64) {
        if len == 0 {
            return;
        }
        let sb = self.dev.sector_bytes as u64;
        for sec in (addr / sb)..=((addr + len - 1) / sb) {
            self.x_sectors.insert(sec);
        }
        self.x.gathers += len / tau;
        self.comp.x_fill += len;
        let (h, m) = self.stream_read(addr, len);
        self.x.sector_probes += h + m;
        self.x.dram_bytes += m * sb;
    }

    /// One warp of x gathers: coalescing merges lanes that land in the
    /// same sector (≤ warp distinct sectors per warp). Returns the
    /// missed bytes so callers can attribute them (halo accounting).
    fn warp_gather_x(&mut self, cols: &mut dyn Iterator<Item = usize>, tau: u64) -> u64 {
        let sb = self.dev.sector_bytes as u64;
        let mut sectors = [u64::MAX; 64];
        let mut ns = 0usize;
        for c in cols {
            self.x.gathers += 1;
            self.comp.x_gather += tau;
            let sec = (X_BASE + c as u64 * tau) / sb;
            if ns < sectors.len() && !sectors[..ns].contains(&sec) {
                sectors[ns] = sec;
                ns += 1;
            }
        }
        let mut missed = 0u64;
        for &sec in &sectors[..ns] {
            self.x_sectors.insert(sec);
            self.x.sector_probes += 1;
            self.l2.accesses += 1;
            self.l2.read_bytes += sb;
            if self.l2sim.access(sec) {
                self.l2.hits += 1;
            } else {
                self.l2.misses += 1;
                self.dram.accesses += 1;
                self.dram.hits += 1;
                self.dram.read_bytes += sb;
                self.x.dram_bytes += sb;
                missed += sb;
            }
        }
        missed
    }

    /// `elems` reads served by the explicit shared-memory cache. The
    /// format guarantees residency, so shm never misses.
    fn shm_serve(&mut self, elems: u64, tau: u64) {
        self.shm.accesses += elems;
        self.shm.hits += elems;
        self.shm.read_bytes += elems * tau;
    }

    /// Coalesced output write (write-allocate skipped, like hardware's
    /// streaming stores): bytes pass through L2 to DRAM.
    fn stream_write(&mut self, len: u64) {
        self.l2.write_bytes += len;
        self.dram.write_bytes += len;
        self.comp.write += len;
    }

    fn finish(mut self, name: &str, nnz: usize, nrows: usize) -> TrafficReport {
        self.x.distinct_sectors = self.x_sectors.len() as u64;
        let d = self.dev;
        let t_dram = self.dram.total_bytes() as f64 / d.hbm_bw;
        let t_l2 = self.l2.total_bytes() as f64 / d.l2_bw;
        let shm_bw = d.shm_bytes_per_cycle * d.sms as f64 * d.total_cycles_per_sec();
        let t_shm = self.shm.read_bytes as f64 / shm_bw;
        let predicted_secs = t_dram.max(t_l2).max(t_shm) + d.launch_overhead;
        TrafficReport {
            name: name.to_string(),
            nnz,
            nrows,
            shm: self.shm,
            l2: self.l2,
            dram: self.dram,
            x: self.x,
            components: self.comp,
            predicted_secs,
        }
    }
}

/// Replay a CSR warp-per-row walk, optionally under a symmetric
/// permutation (`perm[p]` = old row at new position `p`; columns map
/// through the inverse). Matrix streams use running offsets, i.e. the
/// layout the permuted matrix would be materialized in.
fn replay_csr<S: Scalar>(
    ms: &mut MemSim<'_>,
    m: &Csr<S>,
    perm: Option<&[usize]>,
    iperm: Option<&[usize]>,
) {
    let tau = S::BYTES as u64;
    let warp = ms.dev.warp_size;
    let n = m.nrows();
    let mut k_off = 0u64; // running nnz offset in the (permuted) layout
    let mut row = 0usize;
    while row < n {
        let row_end = (row + ROWS_PER_BLOCK).min(n);
        for p in row..row_end {
            let r = perm.map_or(p, |pm| pm[p]);
            let (cols, _) = m.row(r);
            let rn = cols.len() as u64;
            ms.stream_read(PTR_BASE + p as u64 * 4, 8);
            ms.comp.meta += 8;
            ms.stream_read(COL_BASE + k_off * 4, rn * 4);
            ms.stream_read(VAL_BASE + k_off * tau, rn * tau);
            ms.comp.ell += rn * (4 + tau);
            k_off += rn;
            let mut k = 0usize;
            while k < cols.len() {
                let kend = (k + warp).min(cols.len());
                ms.warp_gather_x(
                    &mut cols[k..kend].iter().map(|&c| {
                        let c = c as usize;
                        match iperm {
                            Some(ip) if c < ip.len() => ip[c],
                            _ => c,
                        }
                    }),
                    tau,
                );
                k = kend;
            }
        }
        ms.stream_write((row_end - row) as u64 * tau);
        row = row_end;
    }
}

/// Replay a column-major ELL walk of uniform width (the dense max-width
/// layout): a warp reads 32 rows' k-th entries contiguously — padding
/// slots still stream bytes, but only real entries gather x.
fn replay_ell_like<S: Scalar>(ms: &mut MemSim<'_>, m: &Csr<S>, slice_height: usize, sellp: bool) {
    let tau = S::BYTES as u64;
    let n = m.nrows();
    let h = slice_height.max(1);
    let mut base = 0u64; // running slot offset across slices
    let nslices = n.div_ceil(h);
    // SELL-P streams its per-slice pointer/width pairs; plain ELL has a
    // single global width and no per-slice metadata.
    if sellp {
        ms.stream_read(PTR_BASE, (nslices as u64 + 1) * 8);
        ms.comp.meta += (nslices as u64 + 1) * 8;
    }
    let global_w = (0..n).map(|r| m.row_nnz(r)).max().unwrap_or(0);
    let warp = ms.dev.warp_size;
    for s in 0..nslices {
        let r0 = s * h;
        let r1 = ((s + 1) * h).min(n);
        let w = if sellp {
            (r0..r1).map(|r| m.row_nnz(r)).max().unwrap_or(0)
        } else {
            global_w
        };
        // One thread per row; warps are consecutive row chunks walking
        // the slice's k columns in lockstep.
        let mut wr0 = r0;
        while wr0 < r1 {
            let wr1 = (wr0 + warp).min(r1);
            for k in 0..w {
                let slot0 = base + k as u64 * (r1 - r0) as u64 + (wr0 - r0) as u64;
                ms.stream_read(COL_BASE + slot0 * 4, (wr1 - wr0) as u64 * 4);
                ms.stream_read(VAL_BASE + slot0 * tau, (wr1 - wr0) as u64 * tau);
                ms.comp.ell += (wr1 - wr0) as u64 * (4 + tau);
                ms.warp_gather_x(
                    &mut (wr0..wr1).filter(|&r| k < m.row_nnz(r)).map(|r| {
                        let (cols, _) = m.row(r);
                        cols[k] as usize
                    }),
                    tau,
                );
            }
            wr0 = wr1;
        }
        base += (w * (r1 - r0)) as u64;
        ms.stream_write((r1 - r0) as u64 * tau);
    }
}

/// Greedy 4/2/1 register blocking the fused SpMM kernel uses
/// (`EhybCpu`'s register-blocked `spmv_batch`): a batch of `b`
/// right-hand sides is walked as blocks of 4, then 2, then 1 lanes,
/// with the matrix streamed once per block. [`crate::profile`] charges
/// its observed batch counters with the same blocking so the fused
/// path cross-checks exactly.
pub fn spmm_register_blocks(b: usize) -> Vec<usize> {
    let mut out = Vec::new();
    let mut rem = b;
    while rem >= 4 {
        out.push(4);
        rem -= 4;
    }
    if rem >= 2 {
        out.push(2);
        rem -= 2;
    }
    if rem == 1 {
        out.push(1);
    }
    out
}

/// Replay the EHYB kernel (paper Algorithm 3) over a prepared matrix:
/// per partition a coalesced explicit-cache fill of the x-slice, then
/// u16-column ELL slices whose gathers are served entirely by shared
/// memory, then the ER tail with u32 global columns gathering x through
/// L2 and atomically scattering into y.
pub fn ehyb_traffic<S: Scalar>(e: &EhybMatrix<S>, dev: &GpuDevice) -> TrafficReport {
    ehyb_batch_traffic(e, dev, 1)
}

/// Replay the *fused* `spmv_batch` walk over `b` right-hand sides
/// (ROADMAP "extend the replay to `spmv_batch`"): matrix streams are
/// charged once per [`spmm_register_blocks`] register block — the
/// fused path's reuse — while explicit-cache fills, shm serves, ER
/// tails, and y writes are paid per lane. Each lane's x copy lives in
/// its own address region, so cross-lane L2 reuse is matrix-stream
/// reuse only, like the real kernel. `b = 1` is exactly the single
/// [`ehyb_traffic`] replay.
pub fn ehyb_batch_traffic<S: Scalar>(e: &EhybMatrix<S>, dev: &GpuDevice, b: usize) -> TrafficReport {
    let b = b.max(1);
    let tau = S::BYTES as u64;
    let h = e.slice_height;
    let mut ms = MemSim::new(dev);
    let spp = e.slices_per_part();
    let x_stride = e.padded_rows() as u64;
    let mut lane0 = 0u64;
    for blk in spmm_register_blocks(b) {
        let blk = blk as u64;
        for p in 0..e.num_parts {
            // Algorithm 3 line 4: fill the shared-memory x-slice cache,
            // once per lane in the register block.
            for lane in 0..blk {
                let off = (lane0 + lane) * x_stride + (p * e.vec_size) as u64;
                ms.stream_read_x(X_BASE + off * tau, e.vec_size as u64 * tau, tau);
            }
            for ls in 0..spp {
                let s = p * spp + ls;
                let base = e.slice_ptr[s] as u64;
                let w = e.slice_width[s] as u64;
                // Slice descriptor (ptr + width), once per block.
                ms.stream_read(PTR_BASE + s as u64 * 8, 8);
                ms.comp.meta += 8;
                // Compact u16 columns + values, coalesced, streamed
                // once per register block.
                ms.stream_read(COL_BASE + base * 2, w * h as u64 * 2);
                ms.stream_read(VAL_BASE + base * tau, w * h as u64 * tau);
                ms.comp.ell += w * h as u64 * (2 + tau);
                // Every ELL gather is served by the explicit cache, one
                // read per lane.
                ms.shm_serve(w * h as u64 * blk, tau);
            }
            ms.stream_write(e.vec_size as u64 * tau * blk);
        }
        // ER tail: u32 global columns, x through L2, atomic y scatter.
        // The register-blocked kernel runs the tail per lane.
        let er_ptr_base = PTR_BASE + (e.slice_ptr.len() as u64) * 8;
        let er_col_base = COL_BASE + e.ell_cols.len() as u64 * 2;
        let er_val_base = VAL_BASE + e.ell_vals.len() as u64 * tau;
        for lane in 0..blk {
            let xoff = ((lane0 + lane) * x_stride) as usize;
            for s in 0..e.er_slice_width.len() {
                let base = e.er_slice_ptr[s] as u64;
                let w = e.er_slice_width[s] as u64;
                ms.stream_read(er_ptr_base + s as u64 * 8, 8);
                ms.comp.meta += 8;
                ms.stream_read(er_col_base + base * 4, w * h as u64 * 4);
                ms.stream_read(er_val_base + base * tau, w * h as u64 * tau);
                ms.comp.er += w * h as u64 * (4 + tau);
                for k in 0..w {
                    let idx0 = base as usize + k as usize * h;
                    ms.warp_gather_x(
                        &mut (0..h).map(|l| xoff + e.er_cols[idx0 + l] as usize),
                        tau,
                    );
                }
                // yIdxER read + atomic scatter-add.
                ms.stream_read(AUX_BASE + (s * h) as u64 * 4, h as u64 * 4);
                ms.comp.meta += h as u64 * 4;
                ms.stream_write(h as u64 * tau);
            }
        }
        lane0 += blk;
    }
    ms.finish("ehyb", e.nnz() * b, e.n)
}

/// Replay a baseline engine's walk. The CSR-family engines (csr-scalar,
/// csr-vector, merge, csr5, hyb) share the CSR stream/gather shape —
/// the same lumping [`crate::perfmodel::csr_bound`] applies — while ELL
/// and SELL-P replay their padded column-major layouts.
pub fn baseline_traffic<S: Scalar>(
    kind: crate::api::EngineKind,
    m: &Csr<S>,
    dev: &GpuDevice,
) -> TrafficReport {
    use crate::api::EngineKind as K;
    let mut ms = MemSim::new(dev);
    match kind {
        K::Ell => replay_ell_like(&mut ms, m, m.nrows().max(1), false),
        K::SellP => replay_ell_like(&mut ms, m, 32, true),
        _ => replay_csr(&mut ms, m, None, None),
    }
    ms.finish(kind.name(), m.nnz(), m.nrows())
}

/// Simulated x-vector DRAM bytes for a CSR walk of `m` under symmetric
/// permutation `perm` (`perm[p]` = old row at new position `p`; pass
/// the identity for the natural order). This is the locality score
/// [`crate::reorder`]'s `Auto` ranks orderings by: unlike the windowed
/// footprint proxy it sees sector granularity, L2 capacity, and the
/// eviction pressure of the matrix streams.
pub fn x_traffic_under<S: Scalar>(m: &Csr<S>, perm: &[usize], dev: &GpuDevice) -> u64 {
    debug_assert_eq!(perm.len(), m.nrows());
    let mut iperm = vec![0usize; perm.len()];
    for (p, &r) in perm.iter().enumerate() {
        iperm[r] = p;
    }
    let mut ms = MemSim::new(dev);
    replay_csr(&mut ms, m, Some(perm), Some(&iperm));
    ms.finish("x-traffic", m.nnz(), m.nrows()).x.dram_bytes
}

/// Per-shard traffic for a row sharding: each shard replays its rows as
/// its own kernel (fresh L2 working set), with gathers split into
/// diagonal-block columns and halo columns so the cross-shard x traffic
/// the cache-aware boundaries minimize becomes a measured byte count.
#[derive(Clone, Debug)]
pub struct ShardTraffic {
    pub shards: Vec<TrafficReport>,
    /// x DRAM bytes attributable to out-of-shard (halo) columns.
    pub halo_dram_bytes: u64,
    /// Out-of-shard entries per shard ([`ShardPlan::halo_nnz`]).
    pub halo_nnz: Vec<usize>,
}

impl ShardTraffic {
    pub fn total_dram_bytes(&self) -> u64 {
        self.shards.iter().map(|s| s.dram.total_bytes()).sum()
    }

    /// Slowest shard binds the fan-out.
    pub fn predicted_secs(&self) -> f64 {
        self.shards.iter().map(|s| s.predicted_secs).fold(0.0, f64::max)
    }
}

/// Replay every shard of `plan` over `m`.
pub fn shard_traffic<S: Scalar>(m: &Csr<S>, plan: &ShardPlan, dev: &GpuDevice) -> ShardTraffic {
    let tau = S::BYTES as u64;
    let warp = dev.warp_size;
    let mut shards = Vec::with_capacity(plan.num_shards());
    let mut halo_dram_bytes = 0u64;
    for rg in plan.ranges() {
        let mut ms = MemSim::new(dev);
        let mut k_off = 0u64;
        let mut nnz = 0usize;
        let mut row = rg.start;
        while row < rg.end {
            let row_end = (row + ROWS_PER_BLOCK).min(rg.end);
            for r in row..row_end {
                let (cols, _) = m.row(r);
                let rn = cols.len() as u64;
                nnz += cols.len();
                ms.stream_read(PTR_BASE + (r - rg.start) as u64 * 4, 8);
                ms.comp.meta += 8;
                ms.stream_read(COL_BASE + k_off * 4, rn * 4);
                ms.stream_read(VAL_BASE + k_off * tau, rn * tau);
                ms.comp.ell += rn * (4 + tau);
                k_off += rn;
                // Diagonal-block lanes and halo lanes gather separately
                // so halo misses are attributable.
                let local: Vec<usize> = cols
                    .iter()
                    .map(|&c| c as usize)
                    .filter(|&c| c >= rg.start && c < rg.end)
                    .collect();
                let halo: Vec<usize> = cols
                    .iter()
                    .map(|&c| c as usize)
                    .filter(|&c| c < rg.start || c >= rg.end)
                    .collect();
                for chunk in local.chunks(warp) {
                    ms.warp_gather_x(&mut chunk.iter().copied(), tau);
                }
                for chunk in halo.chunks(warp) {
                    halo_dram_bytes += ms.warp_gather_x(&mut chunk.iter().copied(), tau);
                    // Attribute halo lanes separately from in-shard
                    // gathers so the cross-shard share is named.
                    let bytes = chunk.len() as u64 * tau;
                    ms.comp.x_gather -= bytes;
                    ms.comp.halo += bytes;
                }
            }
            ms.stream_write((row_end - row) as u64 * tau);
            row = row_end;
        }
        shards.push(ms.finish("shard-csr", nnz, rg.len()));
    }
    ShardTraffic { shards, halo_dram_bytes, halo_nnz: plan.halo_nnz(m) }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::api::EngineKind;
    use crate::preprocess::{EhybPlan, PreprocessConfig};
    use crate::shard::{ShardPlan, ShardStrategy};
    use crate::sparse::gen::{poisson2d, unstructured_mesh};

    fn dev() -> GpuDevice {
        GpuDevice::v100()
    }

    fn conserve(r: &TrafficReport) {
        for (tag, l) in [("shm", &r.shm), ("l2", &r.l2), ("dram", &r.dram)] {
            assert_eq!(l.hits + l.misses, l.accesses, "{}: {tag}", r.name);
        }
        assert_eq!(r.shm.misses, 0, "explicit cache never misses");
        assert_eq!(r.dram.misses, 0, "DRAM is the backstop");
        assert!(r.predicted_secs > 0.0);
    }

    #[test]
    fn csr_walk_conserves_and_moves_bytes() {
        let m = poisson2d::<f64>(24, 24);
        let r = baseline_traffic(EngineKind::CsrVector, &m, &dev());
        conserve(&r);
        // Streams must at least move col+val+ptr compulsory bytes.
        let min = m.nnz() as u64 * 12 + (m.nrows() as u64 + 1) * 4;
        assert!(r.l2.read_bytes >= min, "{} < {min}", r.l2.read_bytes);
        assert!(r.dram.write_bytes >= m.nrows() as u64 * 8);
    }

    #[test]
    fn ehyb_explicit_cache_cuts_x_dram_traffic() {
        let m = poisson2d::<f64>(48, 48);
        let cfg = PreprocessConfig { vec_size_override: Some(256), ..Default::default() };
        let plan = EhybPlan::build(&m, &cfg).unwrap();
        let e = ehyb_traffic(&plan.matrix, &dev());
        let c = baseline_traffic(EngineKind::CsrVector, &m, &dev());
        conserve(&e);
        conserve(&c);
        assert!(e.shm.read_bytes > 0, "ELL gathers must be shm-served");
        // The explicit cache fetches each x slice once; the CSR walk
        // re-gathers per row. Per-gather DRAM cost must not be worse.
        assert!(
            e.x.dram_bytes <= c.x.dram_bytes,
            "ehyb x dram {} > csr x dram {}",
            e.x.dram_bytes,
            c.x.dram_bytes
        );
    }

    #[test]
    fn replay_is_deterministic() {
        let m = unstructured_mesh::<f64>(60, 60, 0.5, 9);
        let a = baseline_traffic(EngineKind::CsrVector, &m, &dev());
        let b = baseline_traffic(EngineKind::CsrVector, &m, &dev());
        assert_eq!(a, b);
        let cfg = PreprocessConfig::default();
        let plan = EhybPlan::build(&m, &cfg).unwrap();
        let e1 = ehyb_traffic(&plan.matrix, &dev());
        let e2 = ehyb_traffic(&plan.matrix, &dev());
        assert_eq!(e1, e2);
    }

    #[test]
    fn identity_permutation_matches_natural_walk() {
        let m = poisson2d::<f64>(20, 20);
        let id: Vec<usize> = (0..m.nrows()).collect();
        let natural = baseline_traffic(EngineKind::CsrVector, &m, &dev());
        assert_eq!(x_traffic_under(&m, &id, &dev()), natural.x.dram_bytes);
    }

    #[test]
    fn shard_traffic_attributes_halo() {
        let m = poisson2d::<f64>(32, 32);
        let plan = ShardPlan::new(&m, 4, ShardStrategy::NnzBalanced);
        let st = shard_traffic(&m, &plan, &dev());
        assert_eq!(st.shards.len(), 4);
        for s in &st.shards {
            conserve(s);
        }
        // A 5-point stencil always has boundary-crossing entries.
        assert!(st.halo_nnz.iter().sum::<usize>() > 0);
        assert!(st.halo_dram_bytes > 0);
        assert_eq!(st.halo_nnz.len(), 4);
    }

    #[test]
    fn register_blocks_cover_every_width() {
        for b in 1..=9usize {
            let blocks = spmm_register_blocks(b);
            assert_eq!(blocks.iter().sum::<usize>(), b, "b={b}");
            assert!(blocks.iter().all(|&w| matches!(w, 1 | 2 | 4)), "b={b}");
        }
        assert_eq!(spmm_register_blocks(7), vec![4, 2, 1]);
        assert!(spmm_register_blocks(0).is_empty());
    }

    #[test]
    fn batch_replay_reuses_matrix_streams() {
        let m = poisson2d::<f64>(32, 32);
        let plan = EhybPlan::build(&m, &PreprocessConfig::default()).unwrap();
        let b1 = ehyb_traffic(&plan.matrix, &dev());
        assert_eq!(b1, ehyb_batch_traffic(&plan.matrix, &dev(), 1), "b=1 is the single replay");
        for b in [4usize, 8] {
            let bb = ehyb_batch_traffic(&plan.matrix, &dev(), b);
            conserve(&bb);
            // The fused path streams the ELL part once per register
            // block, not once per lane.
            let blocks = spmm_register_blocks(b).len() as u64;
            assert_eq!(bb.components.ell, b1.components.ell * blocks, "b={b}");
            // Per-lane costs scale with the batch.
            assert_eq!(bb.components.x_fill, b1.components.x_fill * b as u64, "b={b}");
            assert_eq!(bb.components.er, b1.components.er * b as u64, "b={b}");
            assert_eq!(bb.components.write, b1.components.write * b as u64, "b={b}");
            assert_eq!(bb.nnz, m.nnz() * b);
        }
    }

    #[test]
    fn components_attribute_every_requested_byte() {
        let m = unstructured_mesh::<f64>(48, 48, 0.5, 11);
        // EHYB: logical components must tie out against the structural
        // closed forms of the prepared matrix.
        let plan = EhybPlan::build(&m, &PreprocessConfig::default()).unwrap();
        let e = &plan.matrix;
        let r = ehyb_traffic(e, &dev());
        let tau = 8u64;
        let h = e.slice_height as u64;
        let er_slices = e.er_slice_width.len() as u64;
        let c = &r.components;
        assert_eq!(c.ell, e.ell_vals.len() as u64 * (2 + tau));
        assert_eq!(c.er, e.er_vals.len() as u64 * (4 + tau));
        assert_eq!(c.meta, 8 * e.num_slices() as u64 + er_slices * (8 + 4 * h));
        assert_eq!(c.x_fill, e.padded_rows() as u64 * tau);
        assert_eq!(c.x_gather, e.er_vals.len() as u64 * tau);
        assert_eq!(c.write, e.padded_rows() as u64 * tau + er_slices * h * tau);
        assert_eq!(c.halo, 0);
        // CSR walk: stream + meta + gathers + writes.
        let cr = baseline_traffic(EngineKind::CsrVector, &m, &dev());
        let cc = &cr.components;
        assert_eq!(cc.ell, m.nnz() as u64 * 12);
        assert_eq!(cc.meta, 8 * m.nrows() as u64);
        assert_eq!(cc.x_gather, m.nnz() as u64 * 8);
        assert_eq!(cc.write, m.nrows() as u64 * 8);
        assert_eq!(cc.er + cc.x_fill + cc.halo, 0);
    }

    #[test]
    fn shard_components_split_halo_from_local_gathers() {
        let m = poisson2d::<f64>(32, 32);
        let plan = ShardPlan::new(&m, 4, ShardStrategy::NnzBalanced);
        let st = shard_traffic(&m, &plan, &dev());
        let halo: u64 = st.shards.iter().map(|s| s.components.halo).sum();
        let local: u64 = st.shards.iter().map(|s| s.components.x_gather).sum();
        assert!(halo > 0, "stencil shards always cross boundaries");
        // Every gather lane is attributed exactly once.
        assert_eq!(halo + local, m.nnz() as u64 * 8);
    }

    #[test]
    fn ell_padding_streams_but_never_gathers() {
        let m = unstructured_mesh::<f64>(40, 40, 0.5, 3);
        let ell = baseline_traffic(EngineKind::Ell, &m, &dev());
        let sellp = baseline_traffic(EngineKind::SellP, &m, &dev());
        conserve(&ell);
        conserve(&sellp);
        // Gathers touch only real entries...
        assert_eq!(ell.x.gathers, m.nnz() as u64);
        assert_eq!(sellp.x.gathers, m.nnz() as u64);
        // ...but dense-width ELL streams strictly more padding bytes on
        // a skewed matrix than per-slice SELL-P widths.
        assert!(ell.l2.read_bytes > sellp.l2.read_bytes);
    }
}
