//! Lightweight property-test harness (proptest is not in the offline
//! dependency closure). A property is a closure over a seeded PRNG; the
//! runner executes many random cases and reports the failing seed so a
//! failure reproduces deterministically.

use super::prng::Xoshiro256;

/// Number of cases per property; override with `EHYB_PROPTEST_CASES`.
pub fn default_cases() -> u64 {
    std::env::var("EHYB_PROPTEST_CASES")
        .ok()
        .and_then(|v| v.parse().ok())
        .unwrap_or(64)
}

/// Run `prop` for `cases` random seeds derived from `base_seed`.
/// The closure returns `Err(msg)` to signal a violated property.
pub fn check_prop<F>(name: &str, base_seed: u64, cases: u64, prop: F)
where
    F: Fn(&mut Xoshiro256) -> Result<(), String>,
{
    for case in 0..cases {
        let seed = base_seed.wrapping_mul(0x9E3779B97F4A7C15).wrapping_add(case);
        let mut rng = Xoshiro256::new(seed);
        if let Err(msg) = prop(&mut rng) {
            panic!("property `{name}` failed at case {case} (seed={seed:#x}): {msg}");
        }
    }
}

/// Assert two float slices match to a relative-or-absolute tolerance.
/// SpMV accumulation order differs between engines, so exact equality is
/// wrong; this mirrors `numpy.testing.assert_allclose` semantics.
pub fn assert_allclose(
    actual: &[f64],
    expected: &[f64],
    rtol: f64,
    atol: f64,
) -> Result<(), String> {
    if actual.len() != expected.len() {
        return Err(format!("length mismatch: {} vs {}", actual.len(), expected.len()));
    }
    for (i, (&a, &e)) in actual.iter().zip(expected).enumerate() {
        let tol = atol + rtol * e.abs();
        if (a - e).abs() > tol {
            return Err(format!(
                "index {i}: actual={a} expected={e} (|diff|={} > tol={tol})",
                (a - e).abs()
            ));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs() {
        check_prop("trivial", 1, 16, |rng| {
            let n = rng.next_below(100);
            if n < 100 { Ok(()) } else { Err("impossible".into()) }
        });
    }

    #[test]
    #[should_panic(expected = "property `always-fails` failed")]
    fn failing_property_panics() {
        check_prop("always-fails", 1, 4, |_| Err("nope".into()));
    }

    #[test]
    fn allclose_accepts_close() {
        assert!(assert_allclose(&[1.0, 2.0], &[1.0 + 1e-12, 2.0], 1e-9, 1e-9).is_ok());
    }

    #[test]
    fn allclose_rejects_far() {
        assert!(assert_allclose(&[1.0], &[1.1], 1e-9, 1e-9).is_err());
    }

    #[test]
    fn allclose_rejects_len_mismatch() {
        assert!(assert_allclose(&[1.0], &[1.0, 2.0], 1e-9, 1e-9).is_err());
    }
}
