//! Portable SIMD lane abstraction for the hot kernels (the `simd`
//! cargo feature's implementation layer).
//!
//! `std::simd` is still nightly-only and the crate's MSRV is 1.75, so
//! this module is the stable stand-in: a fixed-width value pack
//! ([`Pack`]) whose per-element operations are written so LLVM's
//! auto-vectorizer lowers them to vector instructions on every tier-1
//! target (the arrays are register-sized, the loops are
//! `W`-trip-count-known, and every method is `#[inline(always)]`).
//! Kernels are generic over `const W: usize` and dispatched once per
//! call through [`lane_width`], so the lane count is a compile-time
//! constant inside every loop body.
//!
//! Two properties the kernel rewrites rely on:
//!
//! * **Per-lane fma chains are preserved.** [`Pack::mul_add`] applies
//!   [`Scalar::mul_add`] lane-wise, so a kernel that assigns each
//!   output row to a fixed lane keeps that row's k-ordered fused chain
//!   bit-identical to the scalar walk — this is what makes the
//!   simd-vs-scalar proptests *bitwise* for the lane-parallel engines
//!   (EHYB ELL/ER, SELL-P, ELL, the csr-vector warp model, blocked
//!   SpMM).
//! * **Padding is a bitwise no-op for finite data.** Formats that pad
//!   with `val = +0.0` can gather pad slots from `x[0]` instead of
//!   branching: `fma(+0.0, x, acc)` returns `acc` bit-for-bit whenever
//!   `x` is finite, because `+0.0 * x` is `±0.0` and `acc + ±0.0 == acc`
//!   for every `acc` that is not `-0.0` — and an accumulator chain
//!   seeded with `+0.0` over finite fmas can never produce `-0.0`
//!   (IEEE 754 round-to-nearest only yields `-0.0` from a sum when
//!   both addends are `-0.0`). Non-finite x entries at *pad* slots
//!   would break this (`0 * inf = NaN`), which is why the per-kind
//!   test docs state "bitwise for finite inputs".

use crate::sparse::scalar::Scalar;

/// Vector register width in bytes for the compile target: 64 when
/// AVX-512 is enabled, 32 for AVX/AVX2, 16 otherwise (SSE2 baseline on
/// x86-64, NEON on aarch64). `cfg!` resolves at compile time, so this
/// is a true constant.
pub const fn simd_bytes() -> usize {
    if cfg!(target_feature = "avx512f") {
        64
    } else if cfg!(any(target_feature = "avx2", target_feature = "avx")) {
        32
    } else {
        16
    }
}

/// Lanes per [`Pack`] for a scalar of `scalar_bytes` bytes: the widest
/// native vector divided by the element size, clamped to the
/// `{2, 4, 8, 16}` widths the kernels instantiate (f64: 2–8,
/// f32: 4–16).
pub const fn lane_width(scalar_bytes: usize) -> usize {
    let w = simd_bytes() / scalar_bytes;
    if w < 2 {
        2
    } else if w > 16 {
        16
    } else {
        w
    }
}

/// A register-sized pack of `W` scalars. All operations are
/// element-wise over the fixed-size array, which LLVM unrolls and
/// vectorizes at the instantiated width.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct Pack<S, const W: usize>(pub [S; W]);

impl<S: Scalar, const W: usize> Pack<S, W> {
    /// All-zero pack (`+0.0` in every lane — the identity-fma seed).
    pub const ZERO: Self = Pack([S::ZERO; W]);

    /// Broadcast one value to every lane.
    #[inline(always)]
    pub fn splat(v: S) -> Self {
        Pack([v; W])
    }

    /// Load `W` consecutive elements from the front of `src`.
    #[inline(always)]
    pub fn load(src: &[S]) -> Self {
        let arr: &[S; W] = src[..W].try_into().expect("Pack::load needs W elements");
        Pack(*arr)
    }

    /// Store the pack to the front of `dst`.
    #[inline(always)]
    pub fn store(self, dst: &mut [S]) {
        dst[..W].copy_from_slice(&self.0);
    }

    /// Lane-wise fused multiply-add: `self[l] * x[l] + acc[l]`. Uses
    /// [`Scalar::mul_add`] per lane, preserving each lane's fused
    /// rounding chain exactly as the scalar kernels compute it.
    #[inline(always)]
    pub fn mul_add(self, x: Self, acc: Self) -> Self {
        let mut out = acc.0;
        let mut l = 0;
        while l < W {
            out[l] = self.0[l].mul_add(x.0[l], out[l]);
            l += 1;
        }
        Pack(out)
    }

    /// Lane-wise product `self[l] * rhs[l]` (unfused — used by the
    /// CSR5 leg's two-phase product/segmented-sum split, which is why
    /// that engine's simd-vs-scalar contract is allclose, not bitwise).
    #[inline(always)]
    pub fn mul(self, rhs: Self) -> Self {
        let mut out = self.0;
        let mut l = 0;
        while l < W {
            out[l] = out[l] * rhs.0[l];
            l += 1;
        }
        Pack(out)
    }

    /// Gather `src[idx[l]]` for the first `W` u16 indices.
    ///
    /// # Safety
    /// `idx` must hold at least `W` elements and every `idx[l] as usize`
    /// must be `< src.len()` (the EHYB column invariant established by
    /// `EhybMatrix::validate`: partition-local columns are `< vec_size`).
    #[inline(always)]
    pub unsafe fn gather_u16_unchecked(src: &[S], idx: &[u16]) -> Self {
        debug_assert!(idx.len() >= W);
        let mut out = [S::ZERO; W];
        let mut l = 0;
        while l < W {
            out[l] = *src.get_unchecked(*idx.get_unchecked(l) as usize);
            l += 1;
        }
        Pack(out)
    }

    /// Gather `src[idx[l]]` for the first `W` u32 indices.
    ///
    /// # Safety
    /// `idx` must hold at least `W` elements and every `idx[l] as usize`
    /// must be `< src.len()`.
    #[inline(always)]
    pub unsafe fn gather_u32_unchecked(src: &[S], idx: &[u32]) -> Self {
        debug_assert!(idx.len() >= W);
        let mut out = [S::ZERO; W];
        let mut l = 0;
        while l < W {
            out[l] = *src.get_unchecked(*idx.get_unchecked(l) as usize);
            l += 1;
        }
        Pack(out)
    }

    /// Gather with a pad sentinel: lanes whose index equals `pad` read
    /// `src[0]` instead (safe because the matching value lane is
    /// `+0.0`, making the fma a bitwise no-op for finite `src` — see
    /// the module docs). Indices are checked: a corrupt non-pad column
    /// panics exactly like the scalar path's `x[c as usize]` would.
    #[inline(always)]
    pub fn gather_u32_pad0(src: &[S], idx: &[u32], pad: u32) -> Self {
        let idx: &[u32; W] = idx[..W].try_into().expect("gather needs W indices");
        let mut out = [S::ZERO; W];
        let mut l = 0;
        while l < W {
            let c = if idx[l] == pad { 0 } else { idx[l] as usize };
            out[l] = src[c];
            l += 1;
        }
        Pack(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn widths_are_sane() {
        let wf64 = lane_width(8);
        let wf32 = lane_width(4);
        assert!(wf64 >= 2 && wf64 <= 8, "f64 width {wf64}");
        assert!(wf32 >= 4 && wf32 <= 16, "f32 width {wf32}");
        assert_eq!(wf32, 2 * wf64, "f32 packs twice the lanes of f64");
        assert!(simd_bytes().is_power_of_two());
    }

    #[test]
    fn mul_add_matches_scalar_chain() {
        let v = Pack::<f64, 4>([1.5, -2.0, 0.25, 3.0]);
        let x = Pack::<f64, 4>([2.0, 0.5, -4.0, 1.0 / 3.0]);
        let mut acc = Pack::<f64, 4>::splat(0.125);
        acc = v.mul_add(x, acc);
        for l in 0..4 {
            assert_eq!(acc.0[l], v.0[l].mul_add(x.0[l], 0.125), "lane {l}");
        }
    }

    #[test]
    fn pad_gather_is_fma_identity_for_finite_inputs() {
        // The invariant the SELL-P/ELL simd legs rely on: a pad slot
        // (val = +0.0, col -> 0) leaves any reachable accumulator
        // bit-unchanged, including negative x[0] (whose product is
        // -0.0) and acc == +0.0.
        for &x0 in &[3.5f64, -3.5, 0.0] {
            for &acc in &[0.0f64, 1.25, -1.25, 1e-300, -1e-300] {
                let r = 0.0f64.mul_add(x0, acc);
                assert_eq!(r.to_bits(), acc.to_bits(), "x0={x0} acc={acc}");
            }
        }
    }

    #[test]
    fn gathers_pick_indexed_lanes() {
        let src = [10.0f64, 11.0, 12.0, 13.0, 14.0];
        let p = unsafe { Pack::<f64, 4>::gather_u16_unchecked(&src, &[4u16, 0, 2, 2]) };
        assert_eq!(p.0, [14.0, 10.0, 12.0, 12.0]);
        let q = unsafe { Pack::<f64, 4>::gather_u32_unchecked(&src, &[1u32, 1, 3, 0]) };
        assert_eq!(q.0, [11.0, 11.0, 13.0, 10.0]);
        let r = Pack::<f64, 4>::gather_u32_pad0(&src, &[2u32, u32::MAX, 0, u32::MAX], u32::MAX);
        assert_eq!(r.0, [12.0, 10.0, 10.0, 10.0]);
    }

    #[test]
    fn load_store_round_trip() {
        let src = [1.0f32, 2.0, 3.0, 4.0, 5.0];
        let p = Pack::<f32, 4>::load(&src);
        let mut dst = [0.0f32; 6];
        p.store(&mut dst[1..5]);
        assert_eq!(dst, [0.0, 1.0, 2.0, 3.0, 4.0, 0.0]);
    }
}
