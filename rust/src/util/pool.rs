//! Pop/push buffer pool for hot-path scratch vectors — the allocation
//! discipline `spmv::ehyb_cpu` established (allocation in the hot loop
//! costs ~10 % on paper-scale matrices), factored out so the sharded
//! fan-out and the reorder adapter reuse it instead of allocating per
//! call.
//!
//! Contract: [`VecPool::take`] hands back a buffer of exactly the
//! requested length with **unspecified contents** (a reused buffer of
//! the same length is returned as-is); callers must fully overwrite
//! before reading. [`VecPool::put`] returns a buffer for reuse, keeping
//! at most `bound` buffers alive so bursty concurrency cannot pin
//! unbounded memory.
//!
//! [`VecPool::misses`] counts every `take` that had to allocate or grow
//! a buffer — the observable the zero-steady-state-allocation tests
//! pin: after warm-up, repeated calls with non-growing sizes must not
//! move the counter.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// A bounded pop/push pool of `Vec<T>` scratch buffers.
pub struct VecPool<T> {
    free: Mutex<Vec<Vec<T>>>,
    /// Maximum buffers retained by [`Self::put`].
    bound: usize,
    /// `take` calls that allocated or grew (capacity miss).
    misses: AtomicU64,
}

impl<T: Copy> VecPool<T> {
    /// An empty pool retaining at most `bound` buffers.
    pub fn new(bound: usize) -> Self {
        Self { free: Mutex::new(Vec::new()), bound: bound.max(1), misses: AtomicU64::new(0) }
    }

    /// Pop (or allocate) a buffer of exactly `len` elements. Contents
    /// are unspecified unless the buffer had to grow, in which case the
    /// whole buffer is `fill`-initialized; callers must overwrite
    /// whatever they read either way.
    pub fn take(&self, len: usize, fill: T) -> Vec<T> {
        let mut v = self.free.lock().unwrap().pop().unwrap_or_default();
        if v.capacity() < len {
            self.misses.fetch_add(1, Ordering::Relaxed);
        }
        if v.len() != len {
            v.clear();
            v.resize(len, fill);
        }
        v
    }

    /// Return a buffer for reuse (dropped if the pool is full).
    pub fn put(&self, v: Vec<T>) {
        let mut free = self.free.lock().unwrap();
        if free.len() < self.bound {
            free.push(v);
        }
    }

    /// Number of `take` calls that had to allocate or grow a buffer.
    /// Flat across repeated same-shape calls = zero steady-state
    /// allocation growth (single caller; concurrent callers beyond
    /// `bound` in-flight buffers can still miss).
    pub fn misses(&self) -> u64 {
        self.misses.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn steady_state_take_put_never_misses_again() {
        let pool: VecPool<f64> = VecPool::new(4);
        let v = pool.take(128, 0.0);
        assert_eq!(v.len(), 128);
        assert_eq!(pool.misses(), 1);
        pool.put(v);
        for _ in 0..10 {
            let v = pool.take(128, 0.0);
            pool.put(v);
        }
        assert_eq!(pool.misses(), 1, "same-size reuse must not allocate");
        // Shrinking reuses capacity; growing past it is a miss.
        let v = pool.take(64, 0.0);
        pool.put(v);
        assert_eq!(pool.misses(), 1);
        let v = pool.take(256, 0.0);
        pool.put(v);
        assert_eq!(pool.misses(), 2);
        // And the grown buffer then serves both sizes.
        for len in [256usize, 128, 256] {
            let v = pool.take(len, 0.0);
            assert_eq!(v.len(), len);
            pool.put(v);
        }
        assert_eq!(pool.misses(), 2);
    }

    #[test]
    fn bound_caps_retained_buffers() {
        let pool: VecPool<f64> = VecPool::new(2);
        let bufs: Vec<_> = (0..4).map(|_| pool.take(8, 0.0)).collect();
        assert_eq!(pool.misses(), 4);
        for b in bufs {
            pool.put(b);
        }
        assert_eq!(pool.free.lock().unwrap().len(), 2, "bound must cap retention");
    }
}
