//! Minimal data-parallel helpers over `std::thread::scope` (no rayon in
//! the offline dependency closure). Used by the multilevel partitioner —
//! the paper runs METIS with 16 host threads — by the suite harness to
//! overlap independent matrix measurements, and by the partition-parallel
//! EHYB SpMV/SpMM hot paths in [`crate::spmv::ehyb_cpu`].

use std::sync::atomic::{AtomicUsize, Ordering};

/// Cached worker-thread count; 0 = not yet resolved. `num_threads()` now
/// sits on the SpMV hot path, so the `EHYB_THREADS` env lookup must run
/// once, not per call. An atomic (rather than a `OnceLock`) lets
/// [`set_num_threads`] re-point it for bench sweeps.
static THREADS: AtomicUsize = AtomicUsize::new(0);

/// Number of worker threads to use: honours `EHYB_THREADS`, defaults to
/// `min(available_parallelism, 16)` to mirror the paper's "at most 16 CPU
/// cores for preprocessing". Resolved once and cached; override at
/// runtime with [`set_num_threads`].
pub fn num_threads() -> usize {
    match THREADS.load(Ordering::Relaxed) {
        0 => {
            let t = threads_from_env();
            // Install the env-derived value only if still unresolved, so
            // a racing `set_num_threads` override is never clobbered.
            match THREADS.compare_exchange(0, t, Ordering::Relaxed, Ordering::Relaxed) {
                Ok(_) => t,
                Err(current) => current,
            }
        }
        t => t,
    }
}

/// Override the worker-thread count (takes precedence over the cached
/// `EHYB_THREADS` value) — the knob behind the hotpath bench's threads
/// sweep and embedders that manage their own thread budget.
pub fn set_num_threads(n: usize) {
    THREADS.store(n.max(1), Ordering::Relaxed);
}

fn threads_from_env() -> usize {
    if let Ok(v) = std::env::var("EHYB_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1).min(16)
}

/// Parallel map over an index range with static chunking. `f` must be
/// `Sync`; results are returned in index order.
pub fn par_map<T: Send, F: Fn(usize) -> T + Sync>(n: usize, f: F) -> Vec<T> {
    let threads = num_threads().min(n.max(1));
    if threads <= 1 || n < 64 {
        return (0..n).map(f).collect();
    }
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    let chunk = n.div_ceil(threads);
    std::thread::scope(|s| {
        for (t, slice) in out.chunks_mut(chunk).enumerate() {
            let f = &f;
            s.spawn(move || {
                let base = t * chunk;
                for (i, slot) in slice.iter_mut().enumerate() {
                    *slot = Some(f(base + i));
                }
            });
        }
    });
    out.into_iter().map(|o| o.unwrap()).collect()
}

/// Parallel for-each over mutable chunks of a slice: each worker owns a
/// contiguous chunk. `f(chunk_start_index, chunk)`.
pub fn par_chunks_mut<T: Send, F: Fn(usize, &mut [T]) + Sync>(xs: &mut [T], chunk: usize, f: F) {
    let chunk = chunk.max(1);
    if xs.len() <= chunk {
        f(0, xs);
        return;
    }
    std::thread::scope(|s| {
        for (t, slice) in xs.chunks_mut(chunk).enumerate() {
            let f = &f;
            s.spawn(move || f(t * chunk, slice));
        }
    });
}

/// Run `f(index, item)` once per item, each on its own scoped thread —
/// the fan-out for work units that already carry their mutable state
/// (e.g. one disjoint row-chunk per output vector in the batched SpMM).
/// With 0 or 1 items no thread is spawned.
pub fn par_for_each<T: Send, F: Fn(usize, T) + Sync>(items: Vec<T>, f: F) {
    if items.len() <= 1 {
        for (i, it) in items.into_iter().enumerate() {
            f(i, it);
        }
        return;
    }
    std::thread::scope(|s| {
        for (i, it) in items.into_iter().enumerate() {
            let f = &f;
            s.spawn(move || f(i, it));
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn par_map_matches_serial() {
        let out = par_map(1000, |i| i * i);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i * i);
        }
    }

    #[test]
    fn par_map_small_input() {
        assert_eq!(par_map(3, |i| i + 1), vec![1, 2, 3]);
        assert_eq!(par_map(0, |i| i), Vec::<usize>::new());
    }

    #[test]
    fn par_chunks_mut_covers_all() {
        let mut xs = vec![0usize; 10_000];
        par_chunks_mut(&mut xs, 1024, |base, chunk| {
            for (i, x) in chunk.iter_mut().enumerate() {
                *x = base + i;
            }
        });
        for (i, x) in xs.iter().enumerate() {
            assert_eq!(*x, i);
        }
    }

    #[test]
    fn par_for_each_runs_every_item() {
        use std::sync::atomic::AtomicU64;
        let hits: Vec<AtomicU64> = (0..8).map(|_| AtomicU64::new(0)).collect();
        let items: Vec<usize> = (0..8).collect();
        par_for_each(items, |i, item| {
            assert_eq!(i, item);
            hits[i].fetch_add(1, Ordering::Relaxed);
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn num_threads_at_least_one() {
        assert!(num_threads() >= 1);
    }

    #[test]
    fn set_num_threads_overrides_and_restores() {
        let before = num_threads();
        set_num_threads(3);
        assert_eq!(num_threads(), 3);
        set_num_threads(before);
        assert_eq!(num_threads(), before);
    }
}
