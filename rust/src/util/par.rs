//! Minimal data-parallel helpers over `std::thread::scope` (no rayon in
//! the offline dependency closure). Used by the multilevel partitioner —
//! the paper runs METIS with 16 host threads — and by the suite harness
//! to overlap independent matrix measurements.

/// Number of worker threads to use: honours `EHYB_THREADS`, defaults to
/// `min(available_parallelism, 16)` to mirror the paper's "at most 16 CPU
/// cores for preprocessing".
pub fn num_threads() -> usize {
    if let Ok(v) = std::env::var("EHYB_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1).min(16)
}

/// Parallel map over an index range with static chunking. `f` must be
/// `Sync`; results are returned in index order.
pub fn par_map<T: Send, F: Fn(usize) -> T + Sync>(n: usize, f: F) -> Vec<T> {
    let threads = num_threads().min(n.max(1));
    if threads <= 1 || n < 64 {
        return (0..n).map(f).collect();
    }
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    let chunk = n.div_ceil(threads);
    std::thread::scope(|s| {
        for (t, slice) in out.chunks_mut(chunk).enumerate() {
            let f = &f;
            s.spawn(move || {
                let base = t * chunk;
                for (i, slot) in slice.iter_mut().enumerate() {
                    *slot = Some(f(base + i));
                }
            });
        }
    });
    out.into_iter().map(|o| o.unwrap()).collect()
}

/// Parallel for-each over mutable chunks of a slice: each worker owns a
/// contiguous chunk. `f(chunk_start_index, chunk)`.
pub fn par_chunks_mut<T: Send, F: Fn(usize, &mut [T]) + Sync>(xs: &mut [T], chunk: usize, f: F) {
    let chunk = chunk.max(1);
    if xs.len() <= chunk {
        f(0, xs);
        return;
    }
    std::thread::scope(|s| {
        for (t, slice) in xs.chunks_mut(chunk).enumerate() {
            let f = &f;
            s.spawn(move || f(t * chunk, slice));
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn par_map_matches_serial() {
        let out = par_map(1000, |i| i * i);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i * i);
        }
    }

    #[test]
    fn par_map_small_input() {
        assert_eq!(par_map(3, |i| i + 1), vec![1, 2, 3]);
        assert_eq!(par_map(0, |i| i), Vec::<usize>::new());
    }

    #[test]
    fn par_chunks_mut_covers_all() {
        let mut xs = vec![0usize; 10_000];
        par_chunks_mut(&mut xs, 1024, |base, chunk| {
            for (i, x) in chunk.iter_mut().enumerate() {
                *x = base + i;
            }
        });
        for (i, x) in xs.iter().enumerate() {
            assert_eq!(*x, i);
        }
    }

    #[test]
    fn num_threads_at_least_one() {
        assert!(num_threads() >= 1);
    }
}
