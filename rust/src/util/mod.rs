//! Small shared utilities: deterministic PRNG, timers, stats, and a
//! scoped-thread parallel map (the crate has no external deps beyond
//! `xla`/`anyhow`, so rand/rayon equivalents live here).

pub mod prng;
pub mod timer;
pub mod stats;
pub mod par;
pub mod check;
pub mod pool;
pub mod lanes;

pub use prng::Xoshiro256;
pub use timer::Timer;
