//! Wall-clock timing helpers used by the preprocessing decomposition
//! (paper Fig. 6) and the benchmark harness.

use std::time::{Duration, Instant};

/// Simple stopwatch.
pub struct Timer {
    start: Instant,
}

impl Timer {
    pub fn start() -> Self {
        Self { start: Instant::now() }
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }

    /// Restart and return the lap time in seconds.
    pub fn lap(&mut self) -> f64 {
        let t = self.elapsed_secs();
        self.start = Instant::now();
        t
    }
}

/// Run `f` repeatedly until `min_time` has elapsed (at least `min_iters`
/// times) and return the mean seconds per iteration. The benchmark
/// equivalent of criterion's core loop, sized for SpMV-scale kernels.
pub fn bench_secs<F: FnMut()>(mut f: F, min_iters: u32, min_time: Duration) -> f64 {
    // Warmup.
    f();
    let start = Instant::now();
    let mut iters = 0u32;
    loop {
        f();
        iters += 1;
        if iters >= min_iters && start.elapsed() >= min_time {
            break;
        }
        // Hard cap so pathological cases cannot hang a suite run.
        if iters >= 1_000_000 {
            break;
        }
    }
    start.elapsed().as_secs_f64() / iters as f64
}

/// Median-of-runs measurement: more robust than the mean for the short
/// kernels in the Fig. 6 preprocessing-ratio experiment.
pub fn bench_median<F: FnMut()>(mut f: F, runs: usize) -> f64 {
    let mut times: Vec<f64> = (0..runs.max(1))
        .map(|_| {
            let t = Instant::now();
            f();
            t.elapsed().as_secs_f64()
        })
        .collect();
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    times[times.len() / 2]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn timer_advances() {
        let t = Timer::start();
        std::thread::sleep(Duration::from_millis(2));
        assert!(t.elapsed_secs() >= 0.001);
    }

    #[test]
    fn lap_resets() {
        let mut t = Timer::start();
        std::thread::sleep(Duration::from_millis(2));
        let l1 = t.lap();
        let l2 = t.elapsed_secs();
        assert!(l1 >= 0.001);
        assert!(l2 < l1);
    }

    #[test]
    fn bench_secs_positive() {
        let mut x = 0u64;
        let s = bench_secs(
            || {
                x = x.wrapping_add(1);
                std::hint::black_box(x);
            },
            10,
            Duration::from_millis(1),
        );
        assert!(s > 0.0);
    }

    #[test]
    fn bench_median_ordering() {
        let s = bench_median(|| std::thread::sleep(Duration::from_micros(100)), 5);
        assert!(s >= 50e-6);
    }
}
