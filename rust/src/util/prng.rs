//! Deterministic PRNG (xoshiro256**), used by matrix generators, the
//! partitioner's tie-breaking, and the property-test harness. No
//! dependency on `rand` — reproducibility across runs is a requirement
//! for the benchmark suite (the 94-matrix corpus is generated, so it must
//! be bit-identical between the baseline and EHYB measurement passes).

/// xoshiro256** 1.0 by Blackman & Vigna (public domain reference impl).
#[derive(Clone, Debug)]
pub struct Xoshiro256 {
    s: [u64; 4],
}

impl Xoshiro256 {
    /// Seed via splitmix64 so that any u64 seed (including 0) gives a
    /// well-mixed state.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Self { s: [next(), next(), next(), next()] }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let result = self.s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    /// Uniform in `[0, n)`. Lemire's multiply-shift rejection method.
    #[inline]
    pub fn next_below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        let n = n as u64;
        loop {
            let x = self.next_u64();
            let m = (x as u128).wrapping_mul(n as u128);
            let lo = m as u64;
            if lo >= n.wrapping_neg() % n {
                return (m >> 64) as usize;
            }
        }
    }

    /// Uniform f64 in `[0, 1)`.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in `[lo, hi)`.
    #[inline]
    pub fn range_f64(&mut self, lo: f64, hi: f64) -> f64 {
        lo + self.next_f64() * (hi - lo)
    }

    /// Standard normal via Box–Muller (one value per call; simple and
    /// adequate for matrix-value generation).
    pub fn next_gaussian(&mut self) -> f64 {
        let u1 = self.next_f64().max(1e-300);
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_below(i + 1);
            xs.swap(i, j);
        }
    }

    /// `k` distinct indices from `[0, n)` (partial Fisher–Yates on an
    /// index map; O(k) memory when k << n via a hash-free swap table).
    pub fn sample_distinct(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        // For small n just shuffle; for large n use Floyd's algorithm.
        if n <= 2 * k {
            let mut idx: Vec<usize> = (0..n).collect();
            self.shuffle(&mut idx);
            idx.truncate(k);
            idx
        } else {
            let mut chosen = std::collections::HashSet::with_capacity(k);
            let mut out = Vec::with_capacity(k);
            for j in (n - k)..n {
                let t = self.next_below(j + 1);
                if chosen.insert(t) {
                    out.push(t);
                } else {
                    chosen.insert(j);
                    out.push(j);
                }
            }
            out
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Xoshiro256::new(42);
        let mut b = Xoshiro256::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Xoshiro256::new(1);
        let mut b = Xoshiro256::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn next_below_in_range() {
        let mut r = Xoshiro256::new(7);
        for _ in 0..10_000 {
            let n = 1 + r.next_below(1000);
            let v = r.next_below(n);
            assert!(v < n);
        }
    }

    #[test]
    fn next_below_covers_all_residues() {
        let mut r = Xoshiro256::new(3);
        let mut seen = [false; 8];
        for _ in 0..1000 {
            seen[r.next_below(8)] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn f64_unit_interval() {
        let mut r = Xoshiro256::new(9);
        for _ in 0..10_000 {
            let v = r.next_f64();
            assert!((0.0..1.0).contains(&v));
        }
    }

    #[test]
    fn gaussian_moments() {
        let mut r = Xoshiro256::new(11);
        let n = 50_000;
        let (mut sum, mut sq) = (0.0, 0.0);
        for _ in 0..n {
            let g = r.next_gaussian();
            sum += g;
            sq += g * g;
        }
        let mean = sum / n as f64;
        let var = sq / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.05, "var={var}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Xoshiro256::new(5);
        let mut xs: Vec<usize> = (0..100).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn sample_distinct_properties() {
        let mut r = Xoshiro256::new(13);
        for &(n, k) in &[(10usize, 10usize), (1000, 5), (50, 25), (3, 0)] {
            let s = r.sample_distinct(n, k);
            assert_eq!(s.len(), k);
            let set: std::collections::HashSet<_> = s.iter().collect();
            assert_eq!(set.len(), k, "distinct");
            assert!(s.iter().all(|&i| i < n));
        }
    }
}
