//! Descriptive statistics used by the harness's speedup tables
//! (paper Tables 1–2) and matrix structure reports.

/// Summary of a sample: used for the "max / min / average speedup" rows.
#[derive(Clone, Debug, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub min: f64,
    pub max: f64,
    pub mean: f64,
    pub geomean: f64,
    pub median: f64,
    pub stddev: f64,
}

impl Summary {
    pub fn of(xs: &[f64]) -> Option<Summary> {
        if xs.is_empty() {
            return None;
        }
        let n = xs.len();
        let mut sorted = xs.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let sum: f64 = xs.iter().sum();
        let mean = sum / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        let logsum: f64 = xs.iter().map(|x| x.max(1e-300).ln()).sum();
        let median = if n % 2 == 1 {
            sorted[n / 2]
        } else {
            0.5 * (sorted[n / 2 - 1] + sorted[n / 2])
        };
        Some(Summary {
            n,
            min: sorted[0],
            max: sorted[n - 1],
            mean,
            geomean: (logsum / n as f64).exp(),
            median,
            stddev: var.sqrt(),
        })
    }
}

/// Fraction of entries strictly greater than 1.0 — the paper's
/// "EHYB is faster in % of matrices" column.
pub fn win_rate(speedups: &[f64]) -> f64 {
    if speedups.is_empty() {
        return 0.0;
    }
    speedups.iter().filter(|&&s| s > 1.0).count() as f64 / speedups.len() as f64
}

/// Histogram with fixed bin width starting at `lo`; used for nnz/row
/// distribution reports in `sparse::stats`.
pub fn histogram(xs: &[f64], lo: f64, width: f64, bins: usize) -> Vec<usize> {
    let mut h = vec![0usize; bins];
    for &x in xs {
        let b = ((x - lo) / width).floor();
        if b >= 0.0 && (b as usize) < bins {
            h[b as usize] += 1;
        }
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0]).unwrap();
        assert_eq!(s.n, 4);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 4.0);
        assert!((s.mean - 2.5).abs() < 1e-12);
        assert!((s.median - 2.5).abs() < 1e-12);
        assert!((s.geomean - 24f64.powf(0.25)).abs() < 1e-12);
    }

    #[test]
    fn summary_single() {
        let s = Summary::of(&[7.0]).unwrap();
        assert_eq!(s.min, 7.0);
        assert_eq!(s.max, 7.0);
        assert_eq!(s.median, 7.0);
        assert_eq!(s.stddev, 0.0);
    }

    #[test]
    fn summary_empty_none() {
        assert!(Summary::of(&[]).is_none());
    }

    #[test]
    fn win_rate_counts_strict_wins() {
        assert_eq!(win_rate(&[1.5, 0.9, 1.0, 2.0]), 0.5);
        assert_eq!(win_rate(&[]), 0.0);
    }

    #[test]
    fn histogram_bins() {
        let h = histogram(&[0.5, 1.5, 1.7, 9.9, -1.0, 100.0], 0.0, 1.0, 10);
        assert_eq!(h[0], 1);
        assert_eq!(h[1], 2);
        assert_eq!(h[9], 1);
        assert_eq!(h.iter().sum::<usize>(), 4); // outliers dropped
    }
}
