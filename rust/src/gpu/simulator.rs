//! Execution model: combine a [`KernelTrace`]'s traffic counts and block
//! cycle loads into a predicted kernel time.
//!
//! `time = max(T_bw, T_l2, T_shm, T_compute) + launch_overhead`
//!
//! * `T_bw`      — HBM bytes / HBM bandwidth (the memory-bound bound).
//! * `T_l2`      — all load bytes / L2 bandwidth (hits are not free).
//! * `T_shm`     — shared-memory bytes / aggregate shm bandwidth.
//! * `T_compute` — per-SM issue cycles under the kernel's scheduling
//!   model: static round-robin block assignment (max SM load) or
//!   dynamic work-stealing (sum/SMs + tail block).

use super::device::GpuDevice;
use super::kernels::KernelTrace;

/// Simulation result for one kernel launch.
#[derive(Clone, Debug)]
pub struct SimReport {
    pub name: &'static str,
    pub time_secs: f64,
    pub gflops: f64,
    /// Which bound dominated: "hbm", "l2", "shm", "compute".
    pub bound: &'static str,
    pub t_bw: f64,
    pub t_l2: f64,
    pub t_shm: f64,
    pub t_compute: f64,
    pub hbm_read_bytes: u64,
    pub hbm_write_bytes: u64,
    pub l2_hit_bytes: u64,
    pub shm_read_bytes: u64,
    /// max SM load / mean SM load (1.0 = perfectly balanced).
    pub imbalance: f64,
    /// Useful lane ops / issued lane slots.
    pub lane_efficiency: f64,
}

/// Assign block cycle loads to SMs and return (max_sm_cycles, imbalance).
fn schedule(block_cycles: &[f64], sms: usize, dynamic: bool) -> (f64, f64) {
    if block_cycles.is_empty() {
        return (0.0, 1.0);
    }
    let total: f64 = block_cycles.iter().sum();
    let mean = total / sms as f64;
    if dynamic {
        // Work-stealing makespan: the ideal share or the single largest
        // block, whichever dominates. Always ≤ the static bound.
        let max_block = block_cycles.iter().cloned().fold(0.0, f64::max);
        let t = mean.max(max_block);
        (t, t / mean.max(1e-30))
    } else {
        // Static round-robin in launch order (the hardware block
        // scheduler is close to this for uniform resource usage).
        let mut loads = vec![0.0f64; sms];
        for (i, &c) in block_cycles.iter().enumerate() {
            loads[i % sms] += c;
        }
        let max = loads.iter().cloned().fold(0.0, f64::max);
        (max, max / mean.max(1e-30))
    }
}

/// Predict the kernel time for `trace` on `dev`.
pub fn simulate(trace: &KernelTrace, dev: &GpuDevice) -> SimReport {
    let hbm_bytes = (trace.hbm_read_bytes + trace.hbm_write_bytes) as f64;
    let t_bw = hbm_bytes / dev.hbm_bw;
    // Every load traverses the L2 crossbar (hits and misses alike).
    let l2_bytes = (trace.hbm_read_bytes + trace.l2_hit_bytes) as f64;
    let t_l2 = l2_bytes / dev.l2_bw;
    let shm_agg_bw = dev.shm_bytes_per_cycle * dev.sms as f64 * dev.total_cycles_per_sec();
    let t_shm = trace.shm_read_bytes as f64 / shm_agg_bw;
    let (max_sm_cycles, imbalance) = schedule(&trace.block_cycles, dev.sms, trace.dynamic_balance);
    // Issue throughput: `issue_per_cycle` warps dual-issue; cycles above
    // are per-warp-scheduler, so divide by the scheduler count.
    let t_compute = max_sm_cycles / dev.issue_per_cycle / dev.total_cycles_per_sec();

    let t = t_bw.max(t_l2).max(t_shm).max(t_compute);
    let bound = if t == t_bw {
        "hbm"
    } else if t == t_l2 {
        "l2"
    } else if t == t_shm {
        "shm"
    } else {
        "compute"
    };
    let time_secs = t + dev.launch_overhead;
    SimReport {
        name: trace.name,
        time_secs,
        gflops: 2.0 * trace.nnz as f64 / time_secs / 1e9,
        bound,
        t_bw,
        t_l2,
        t_shm,
        t_compute,
        hbm_read_bytes: trace.hbm_read_bytes,
        hbm_write_bytes: trace.hbm_write_bytes,
        l2_hit_bytes: trace.l2_hit_bytes,
        shm_read_bytes: trace.shm_read_bytes,
        imbalance,
        lane_efficiency: trace.lane_efficiency(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use super::super::kernels;
    use crate::preprocess::{EhybPlan, PreprocessConfig};
    use crate::sparse::gen::{poisson2d, poisson3d, unstructured_mesh};

    fn dev() -> GpuDevice {
        GpuDevice::v100()
    }

    #[test]
    fn schedule_static_vs_dynamic() {
        // One huge block among many small: static RR puts it on one SM.
        let mut blocks = vec![10.0; 160];
        blocks[0] = 1000.0;
        let (stat, _) = schedule(&blocks, 80, false);
        let (dynm, _) = schedule(&blocks, 80, true);
        assert!(dynm <= stat + 1e-9);
    }

    #[test]
    fn spmv_is_memory_bound_at_scale() {
        let m = poisson3d::<f64>(24, 24, 24);
        let t = kernels::csr_vector_alg1(&m, &dev());
        let r = simulate(&t, &dev());
        assert!(r.bound == "hbm" || r.bound == "l2", "bound={} report={r:?}", r.bound);
        // Sanity: V100 f64 SpMV lands in the 10-200 GFLOPS decade.
        assert!(r.gflops > 1.0 && r.gflops < 500.0, "gflops={}", r.gflops);
    }

    #[test]
    fn ehyb_beats_csr_on_partitionable_mesh() {
        // The paper's headline: explicit caching wins on FEM-type
        // matrices. Use a mesh large enough that x misses hurt baselines.
        let m = unstructured_mesh::<f64>(96, 96, 0.5, 5);
        let plan = EhybPlan::build(
            &m,
            &PreprocessConfig { vec_size_override: Some(1024), ..Default::default() },
        )
        .unwrap();
        let te = kernels::ehyb(&plan.matrix, &dev(), true, true);
        let tc = kernels::csr_vector_alg1(&m, &dev());
        let re = simulate(&te, &dev());
        let rc = simulate(&tc, &dev());
        assert!(
            re.gflops > rc.gflops,
            "ehyb {} <= alg1 {} (er_frac={})",
            re.gflops,
            rc.gflops,
            plan.matrix.er_fraction()
        );
    }

    #[test]
    fn explicit_cache_ablation_helps() {
        let m = unstructured_mesh::<f64>(64, 64, 0.5, 9);
        let plan = EhybPlan::build(
            &m,
            &PreprocessConfig { vec_size_override: Some(512), ..Default::default() },
        )
        .unwrap();
        let on = simulate(&kernels::ehyb(&plan.matrix, &dev(), true, true), &dev());
        let off = simulate(&kernels::ehyb(&plan.matrix, &dev(), false, true), &dev());
        assert!(on.time_secs <= off.time_secs, "cache on {} > off {}", on.time_secs, off.time_secs);
    }

    #[test]
    fn report_components_consistent() {
        let m = poisson2d::<f64>(32, 32);
        let r = simulate(&kernels::merge_based(&m, &dev()), &dev());
        assert!(r.time_secs >= r.t_bw);
        assert!(r.time_secs >= r.t_compute);
        assert!(r.imbalance >= 1.0 - 1e9 * f64::EPSILON);
        assert!(r.lane_efficiency > 0.0 && r.lane_efficiency <= 1.0);
    }
}
