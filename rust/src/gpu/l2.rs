//! Set-associative L2 cache simulator at sector (32 B) granularity.
//! Every global-memory access — matrix streams *and* x-vector gathers —
//! probes it, so streaming data evicts x lines exactly as on hardware
//! (the effect that motivates EHYB's explicit cache, paper §3.1).

/// 16-way set-associative, LRU-by-counter within the set.
pub struct L2Sim {
    ways: usize,
    sets: usize,
    /// tags[set * ways + way] = sector id (u64::MAX = invalid).
    tags: Vec<u64>,
    /// last-use stamps parallel to `tags`.
    stamp: Vec<u64>,
    clock: u64,
    pub hits: u64,
    pub misses: u64,
}

impl L2Sim {
    pub fn new(capacity_bytes: usize, sector_bytes: usize) -> Self {
        let ways = 16usize;
        let sectors = (capacity_bytes / sector_bytes).max(ways);
        let sets = (sectors / ways).next_power_of_two();
        Self {
            ways,
            sets,
            tags: vec![u64::MAX; sets * ways],
            stamp: vec![0; sets * ways],
            clock: 0,
            hits: 0,
            misses: 0,
        }
    }

    /// Probe sector `sec`; returns true on hit. Misses fill with LRU
    /// eviction.
    #[inline]
    pub fn access(&mut self, sec: u64) -> bool {
        self.clock += 1;
        let set = (sec as usize ^ (sec >> 17) as usize) & (self.sets - 1);
        let base = set * self.ways;
        let mut lru_way = 0usize;
        let mut lru_stamp = u64::MAX;
        for w in 0..self.ways {
            let i = base + w;
            if self.tags[i] == sec {
                self.stamp[i] = self.clock;
                self.hits += 1;
                return true;
            }
            if self.stamp[i] < lru_stamp {
                lru_stamp = self.stamp[i];
                lru_way = w;
            }
        }
        let i = base + lru_way;
        self.tags[i] = sec;
        self.stamp[i] = self.clock;
        self.misses += 1;
        false
    }

    /// Probe every sector covering `[addr, addr+len)`; returns
    /// (hits, misses).
    pub fn access_range(&mut self, addr: u64, len: u64, sector_bytes: u64) -> (u64, u64) {
        let first = addr / sector_bytes;
        let last = (addr + len.max(1) - 1) / sector_bytes;
        let (mut h, mut m) = (0, 0);
        for s in first..=last {
            if self.access(s) {
                h += 1;
            } else {
                m += 1;
            }
        }
        (h, m)
    }

    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            return 0.0;
        }
        self.hits as f64 / total as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn repeat_access_hits() {
        let mut l2 = L2Sim::new(1 << 20, 32);
        assert!(!l2.access(42));
        assert!(l2.access(42));
        assert_eq!(l2.hits, 1);
        assert_eq!(l2.misses, 1);
    }

    #[test]
    fn capacity_eviction() {
        let mut l2 = L2Sim::new(1 << 14, 32); // 512 sectors
        // Stream 10x capacity, then re-touch the first sector: must miss.
        for s in 0..5120u64 {
            l2.access(s);
        }
        assert!(!l2.access(0), "sector 0 should have been evicted");
    }

    #[test]
    fn working_set_within_capacity_stays() {
        let mut l2 = L2Sim::new(1 << 20, 32); // 32768 sectors
        for _ in 0..4 {
            for s in 0..1000u64 {
                l2.access(s);
            }
        }
        // 3 of 4 rounds hit.
        assert!(l2.hit_rate() > 0.70, "hit_rate={}", l2.hit_rate());
    }

    #[test]
    fn access_range_counts_sectors() {
        let mut l2 = L2Sim::new(1 << 20, 32);
        let (h, m) = l2.access_range(0, 64, 32); // sectors 0,1
        assert_eq!((h, m), (0, 2));
        let (h, m) = l2.access_range(16, 32, 32); // sectors 0,1 again
        assert_eq!((h, m), (2, 0));
    }

    #[test]
    fn hit_rate_zero_when_untouched() {
        let l2 = L2Sim::new(1 << 20, 32);
        assert_eq!(l2.hit_rate(), 0.0);
    }
}
