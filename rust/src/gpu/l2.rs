//! Set-associative L2 cache simulator at sector (32 B) granularity.
//! Every global-memory access — matrix streams *and* x-vector gathers —
//! probes it, so streaming data evicts x lines exactly as on hardware
//! (the effect that motivates EHYB's explicit cache, paper §3.1).

/// Set-associative, LRU-by-counter within the set. `new` gives the
/// V100-like 16-way default; `with_ways` picks any associativity (the
/// traffic simulator sweeps it when modeling other devices).
pub struct L2Sim {
    ways: usize,
    sets: usize,
    /// tags[set * ways + way] = sector id (u64::MAX = invalid).
    tags: Vec<u64>,
    /// last-use stamps parallel to `tags`.
    stamp: Vec<u64>,
    clock: u64,
    pub hits: u64,
    pub misses: u64,
}

impl L2Sim {
    pub fn new(capacity_bytes: usize, sector_bytes: usize) -> Self {
        Self::with_ways(capacity_bytes, sector_bytes, 16)
    }

    /// Build a cache of `capacity_bytes` with configurable associativity.
    /// `ways == 1` is direct-mapped; `ways >= sectors` degenerates to
    /// fully associative. Set count rounds up to a power of two so the
    /// set hash stays a mask.
    pub fn with_ways(capacity_bytes: usize, sector_bytes: usize, ways: usize) -> Self {
        let ways = ways.max(1);
        let sectors = (capacity_bytes / sector_bytes.max(1)).max(ways);
        let sets = (sectors / ways).next_power_of_two();
        Self {
            ways,
            sets,
            tags: vec![u64::MAX; sets * ways],
            stamp: vec![0; sets * ways],
            clock: 0,
            hits: 0,
            misses: 0,
        }
    }

    /// Associativity this cache was built with.
    pub fn ways(&self) -> usize {
        self.ways
    }

    /// Total sector probes so far (`hits + misses`).
    pub fn accesses(&self) -> u64 {
        self.hits + self.misses
    }

    /// Probe sector `sec`; returns true on hit. Misses fill with LRU
    /// eviction.
    #[inline]
    pub fn access(&mut self, sec: u64) -> bool {
        self.clock += 1;
        let set = (sec as usize ^ (sec >> 17) as usize) & (self.sets - 1);
        let base = set * self.ways;
        let mut lru_way = 0usize;
        let mut lru_stamp = u64::MAX;
        for w in 0..self.ways {
            let i = base + w;
            if self.tags[i] == sec {
                self.stamp[i] = self.clock;
                self.hits += 1;
                return true;
            }
            if self.stamp[i] < lru_stamp {
                lru_stamp = self.stamp[i];
                lru_way = w;
            }
        }
        let i = base + lru_way;
        self.tags[i] = sec;
        self.stamp[i] = self.clock;
        self.misses += 1;
        false
    }

    /// Probe every sector overlapping `[addr, addr+len)`; returns
    /// (hits, misses). Partial leading/trailing sectors count as full
    /// sector transactions (hardware moves whole sectors), and a
    /// zero-length range touches nothing — it used to probe a phantom
    /// sector at `addr`, skewing counters for empty streams.
    pub fn access_range(&mut self, addr: u64, len: u64, sector_bytes: u64) -> (u64, u64) {
        if len == 0 {
            return (0, 0);
        }
        let first = addr / sector_bytes;
        let last = (addr + len - 1) / sector_bytes;
        let (mut h, mut m) = (0, 0);
        for s in first..=last {
            if self.access(s) {
                h += 1;
            } else {
                m += 1;
            }
        }
        (h, m)
    }

    pub fn hit_rate(&self) -> f64 {
        let total = self.hits + self.misses;
        if total == 0 {
            return 0.0;
        }
        self.hits as f64 / total as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn repeat_access_hits() {
        let mut l2 = L2Sim::new(1 << 20, 32);
        assert!(!l2.access(42));
        assert!(l2.access(42));
        assert_eq!(l2.hits, 1);
        assert_eq!(l2.misses, 1);
        assert_eq!(l2.accesses(), 2);
    }

    #[test]
    fn capacity_eviction() {
        let mut l2 = L2Sim::new(1 << 14, 32); // 512 sectors
        // Stream 10x capacity, then re-touch the first sector: must miss.
        for s in 0..5120u64 {
            l2.access(s);
        }
        assert!(!l2.access(0), "sector 0 should have been evicted");
    }

    #[test]
    fn working_set_within_capacity_stays() {
        let mut l2 = L2Sim::new(1 << 20, 32); // 32768 sectors
        for _ in 0..4 {
            for s in 0..1000u64 {
                l2.access(s);
            }
        }
        // 3 of 4 rounds hit.
        assert!(l2.hit_rate() > 0.70, "hit_rate={}", l2.hit_rate());
    }

    #[test]
    fn default_is_16_way() {
        let l2 = L2Sim::new(1 << 20, 32);
        assert_eq!(l2.ways(), 16);
    }

    #[test]
    fn two_way_eviction_hand_trace() {
        // 2 sets x 2 ways = 4 sectors total (128 B, 32 B sectors).
        // Sector -> set is (sec ^ (sec >> 17)) & 1, i.e. parity for
        // small ids: even sectors land in set 0, odd in set 1.
        let mut l2 = L2Sim::with_ways(128, 32, 2);
        assert_eq!(l2.ways(), 2);
        assert!(!l2.access(0)); // set 0: [0, -]
        assert!(!l2.access(2)); // set 0: [0, 2]
        assert!(l2.access(0)); // hit; 2 is now LRU
        assert!(!l2.access(4)); // evicts 2 -> set 0: [0, 4]
        assert!(!l2.access(2), "2 was the LRU victim and must miss");
        assert!(l2.access(4), "4 is younger than 0 and must survive");
        assert!(!l2.access(0), "0 was evicted by the re-fill of 2");
        // The odd set was never touched by any of the above.
        assert!(!l2.access(1)); // set 1: [1, -]
        assert!(l2.access(1));
        assert_eq!(l2.accesses(), l2.hits + l2.misses);
    }

    #[test]
    fn direct_mapped_conflicts() {
        // ways=1: two sectors hashing to the same set always conflict.
        let mut l2 = L2Sim::with_ways(64, 32, 1);
        // sets = 2; sectors 0 and 2 both land in set 0.
        assert!(!l2.access(0));
        assert!(!l2.access(2)); // evicts 0
        assert!(!l2.access(0)); // evicts 2
        assert!(!l2.access(2));
        assert_eq!(l2.hits, 0);
        assert_eq!(l2.misses, 4);
    }

    #[test]
    fn access_range_counts_sectors() {
        let mut l2 = L2Sim::new(1 << 20, 32);
        let (h, m) = l2.access_range(0, 64, 32); // sectors 0,1
        assert_eq!((h, m), (0, 2));
        let (h, m) = l2.access_range(16, 32, 32); // sectors 0,1 again
        assert_eq!((h, m), (2, 0));
    }

    #[test]
    fn access_range_partial_sectors_hand_trace() {
        let mut l2 = L2Sim::new(1 << 20, 32);
        // [30, 34): 4 bytes straddling the sector 0/1 boundary — both
        // partial sectors count as full transactions.
        let (h, m) = l2.access_range(30, 4, 32);
        assert_eq!((h, m), (0, 2));
        // [95, 96): 1 byte entirely inside sector 2.
        let (h, m) = l2.access_range(95, 1, 32);
        assert_eq!((h, m), (0, 1));
        // [64, 96): exactly sector 2 again — no phantom sector 3.
        let (h, m) = l2.access_range(64, 32, 32);
        assert_eq!((h, m), (1, 0));
    }

    #[test]
    fn access_range_zero_len_touches_nothing() {
        let mut l2 = L2Sim::new(1 << 20, 32);
        let (h, m) = l2.access_range(128, 0, 32);
        assert_eq!((h, m), (0, 0));
        assert_eq!(l2.accesses(), 0);
    }

    #[test]
    fn hit_rate_zero_when_untouched() {
        let l2 = L2Sim::new(1 << 20, 32);
        assert_eq!(l2.hit_rate(), 0.0);
    }
}
