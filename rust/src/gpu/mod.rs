//! Warp-level GPU performance simulator — the V100 substitute
//! (DESIGN.md §4). SpMV is memory-bound, so the simulator's job is to
//! count *exactly* the memory traffic each format generates — sector-
//! level coalescing, L2 hits/misses for input-vector gathers, shared-
//! memory traffic for EHYB's explicit cache — and to combine those
//! counts with an execution model (per-SM cycle loads, divergence,
//! bandwidth bound) into a predicted kernel time.
//!
//! What it models and why it is sufficient for the paper's claims:
//!
//! * **Coalescing** ([`l2`], [`kernels`]): a warp's 32 gathers touch some
//!   number of 32-byte sectors; each sector is one L2 probe and, on
//!   miss, one HBM transaction. The EHYB-vs-baseline difference is
//!   almost entirely *which* of these gathers hit.
//! * **L2 cache** ([`l2::L2Sim`]): 16-way set-associative, 32 B sectors,
//!   6 MiB (V100). Matrix streams run through it and evict x lines —
//!   exactly the effect §3.1 argues makes implicit caching fail.
//! * **Shared memory**: EHYB fills its x-slice once per block
//!   (coalesced HBM reads), then serves all in-partition gathers at
//!   shared-memory cost.
//! * **Balance/divergence** ([`simulator`]): blocks are scheduled round-
//!   robin over SMs; a warp-iteration costs the *maximum* lane trip
//!   count of the slice (the padding the descending-nnz sort removes).
//!
//! Absolute times are estimates; the paper-facing output is the
//! *relative* standing of formats, which is driven by the exact traffic
//! counts.

pub mod device;
pub mod l2;
pub mod kernels;
pub mod simulator;

pub use device::GpuDevice;
pub use kernels::KernelTrace;
pub use simulator::{simulate, SimReport};
