//! Simulated SpMV kernels: walk each format's exact layout, counting
//! sector-level traffic (through the shared [`L2Sim`]) and per-block
//! issue cycles. One function per framework the paper compares
//! (§5: yaSpMV/BCOO, holaSpMV, CSR5, merge, cuSPARSE ALG1/ALG2) plus
//! EHYB itself and its ablation variants.
//!
//! Address map: disjoint synthetic base addresses per array so the L2
//! simulator sees realistic conflict behaviour between matrix streams
//! and x-vector gathers.

use super::device::GpuDevice;
use super::l2::L2Sim;
use crate::sparse::csr::Csr;
use crate::sparse::ehyb::EhybMatrix;
use crate::sparse::scalar::Scalar;

/// Outcome of walking one kernel over one matrix: every quantity the
/// execution model needs.
#[derive(Clone, Debug)]
pub struct KernelTrace {
    pub name: &'static str,
    pub nnz: usize,
    pub nrows: usize,
    /// Bytes actually fetched from HBM (L2 misses × sector + streams).
    pub hbm_read_bytes: u64,
    /// Bytes served by L2 hits.
    pub l2_hit_bytes: u64,
    /// Bytes served by shared memory (EHYB's explicit cache).
    pub shm_read_bytes: u64,
    /// Bytes written to HBM (y, plus atomics).
    pub hbm_write_bytes: u64,
    /// Issue cycles per block (divergence/padding included).
    pub block_cycles: Vec<f64>,
    /// True when the kernel self-balances across SMs (work-stealing /
    /// nnz-splitting); selects the scheduling model in `simulate`.
    pub dynamic_balance: bool,
    /// Useful lane-operations (= nnz) vs issued lane-slots — the
    /// divergence/padding waste diagnostic.
    pub lane_slots: u64,
}

impl KernelTrace {
    fn new(name: &'static str, nnz: usize, nrows: usize, dynamic_balance: bool) -> Self {
        Self {
            name,
            nnz,
            nrows,
            hbm_read_bytes: 0,
            l2_hit_bytes: 0,
            shm_read_bytes: 0,
            hbm_write_bytes: 0,
            block_cycles: Vec::new(),
            dynamic_balance,
            lane_slots: 0,
        }
    }

    /// Fraction of issued lane slots that did useful work.
    pub fn lane_efficiency(&self) -> f64 {
        if self.lane_slots == 0 {
            return 1.0;
        }
        self.nnz as f64 / self.lane_slots as f64
    }

    pub fn total_read_bytes(&self) -> u64 {
        self.hbm_read_bytes + self.l2_hit_bytes + self.shm_read_bytes
    }
}

/// Issue-cost constants (cycles per warp-iteration). One warp-iteration
/// of a gather-FMA loop issues ~5-7 instructions on Volta; exact values
/// only shift absolute GFLOPS, not format ordering.
const C_ITER_CSR: f64 = 7.0; // ld row bounds amortized + ld col + ld val + gather + fma + loop
const C_ITER_ELL: f64 = 5.0; // no row_ptr traffic in the loop
const C_ITER_SHM: f64 = 4.5; // gather from shared memory is a single-cycle op
const C_REDUCE: f64 = 10.0; // warp shfl tree
const C_ATOMIC: f64 = 8.0; // atomicAdd on global y
const C_BLOCK_SETUP: f64 = 60.0;

/// Shared walk context: the L2, the address map, and counters.
struct Ctx<'d> {
    l2: L2Sim,
    dev: &'d GpuDevice,
    trace: KernelTrace,
}

// Array base addresses (disjoint 16 GiB regions).
const X_BASE: u64 = 0;
const VAL_BASE: u64 = 1 << 34;
const COL_BASE: u64 = 2 << 34;
const PTR_BASE: u64 = 3 << 34;
const AUX_BASE: u64 = 5 << 34;

impl<'d> Ctx<'d> {
    fn new(
        name: &'static str,
        nnz: usize,
        nrows: usize,
        dynamic: bool,
        dev: &'d GpuDevice,
    ) -> Self {
        Self {
            l2: L2Sim::new(dev.l2_bytes, dev.sector_bytes),
            dev,
            trace: KernelTrace::new(name, nnz, nrows, dynamic),
        }
    }

    /// Sequential (coalesced) stream read of `len` bytes at `addr`:
    /// probes L2 per sector; misses become HBM reads.
    fn stream_read(&mut self, addr: u64, len: u64) {
        if len == 0 {
            return;
        }
        let sb = self.dev.sector_bytes as u64;
        let (h, m) = self.l2.access_range(addr, len, sb);
        self.trace.l2_hit_bytes += h * sb;
        self.trace.hbm_read_bytes += m * sb;
    }

    /// A warp of gathers into x: `cols` are element indices; coalescing
    /// merges lanes that fall in the same sector.
    fn warp_gather_x(&mut self, cols: &mut dyn Iterator<Item = usize>, tau: u64) {
        let sb = self.dev.sector_bytes as u64;
        // Distinct sectors of this warp's 32 addresses.
        let mut sectors = [u64::MAX; 32];
        let mut ns = 0usize;
        for c in cols {
            let sec = (X_BASE + c as u64 * tau) / sb;
            if !sectors[..ns].contains(&sec) {
                sectors[ns] = sec;
                ns += 1;
            }
        }
        for &sec in &sectors[..ns] {
            if self.l2.access(sec) {
                self.trace.l2_hit_bytes += sb;
            } else {
                self.trace.hbm_read_bytes += sb;
            }
        }
    }

    /// Coalesced write of `len` bytes (y outputs; write-allocate skipped).
    fn stream_write(&mut self, len: u64) {
        self.trace.hbm_write_bytes += len;
    }

    fn finish(self) -> KernelTrace {
        self.trace
    }
}

/// cuSPARSE generic ALG1 analogue: CSR, one warp per row, static block
/// assignment of contiguous row chunks.
pub fn csr_vector_alg1<S: Scalar>(m: &Csr<S>, dev: &GpuDevice) -> KernelTrace {
    csr_warp_per_row(m, dev, "cusparse-alg1", false)
}

/// holaSpMV analogue: globally homogeneous nnz-splitting — same CSR
/// traffic as a warp-per-row kernel but with dynamic, balanced
/// scheduling and per-block setup for its hierarchical offsets.
pub fn hola<S: Scalar>(m: &Csr<S>, dev: &GpuDevice) -> KernelTrace {
    let mut t = csr_warp_per_row(m, dev, "holaspmv", true);
    // hola reads an auxiliary offset structure ~ 8 bytes per 256-nnz tile.
    let tiles = (m.nnz() as u64).div_ceil(256);
    t.hbm_read_bytes += tiles * 8;
    t
}

fn csr_warp_per_row<S: Scalar>(
    m: &Csr<S>,
    dev: &GpuDevice,
    name: &'static str,
    dynamic: bool,
) -> KernelTrace {
    let tau = S::BYTES as u64;
    let warp = dev.warp_size;
    let mut ctx = Ctx::new(name, m.nnz(), m.nrows(), dynamic, dev);
    // Rows are processed warp-per-row; blocks of 4 warps on V100 ALG1.
    let rows_per_block = 4 * 32; // 4 warps x 32 rows each? No: warp-per-row => 4 rows per block pass
    // Model: each block owns a contiguous chunk of rows, 128 warps-worth
    // of work per block => 128 rows per block.
    let rows_per_block = rows_per_block.max(1);
    let nrows = m.nrows();
    let mut row = 0usize;
    while row < nrows {
        let row_end = (row + rows_per_block).min(nrows);
        let mut cycles = C_BLOCK_SETUP;
        for r in row..row_end {
            let lo = m.row_ptr[r] as usize;
            let hi = m.row_ptr[r + 1] as usize;
            // row_ptr: two u32 loads per row, amortized by coalescing.
            ctx.stream_read(PTR_BASE + r as u64 * 4, 8);
            // Matrix streams: the row's col+val segments.
            ctx.stream_read(COL_BASE + lo as u64 * 4, (hi - lo) as u64 * 4);
            ctx.stream_read(VAL_BASE + lo as u64 * tau, (hi - lo) as u64 * tau);
            // Gathers, a warp-width at a time.
            let mut k = lo;
            while k < hi {
                let kend = (k + warp).min(hi);
                ctx.warp_gather_x(&mut m.col_idx[k..kend].iter().map(|&c| c as usize), tau);
                cycles += C_ITER_CSR;
                ctx.trace.lane_slots += warp as u64;
                k = kend;
            }
            cycles += C_REDUCE;
        }
        ctx.stream_write((row_end - row) as u64 * tau);
        ctx.trace.block_cycles.push(cycles);
        row = row_end;
    }
    ctx.finish()
}

/// cuSPARSE generic ALG2 analogue: CSR-adaptive — nnz-balanced blocks
/// (row-blocks built so each block covers ~2048 nnz), same streams.
pub fn csr_adaptive_alg2<S: Scalar>(m: &Csr<S>, dev: &GpuDevice) -> KernelTrace {
    let tau = S::BYTES as u64;
    let warp = dev.warp_size;
    let mut ctx = Ctx::new("cusparse-alg2", m.nnz(), m.nrows(), true, dev);
    let nnz_per_block = 2048usize;
    let nrows = m.nrows();
    let mut row = 0usize;
    while row < nrows {
        // Grow the block to ~nnz_per_block.
        let mut row_end = row;
        let mut blk_nnz = 0usize;
        while row_end < nrows && (blk_nnz == 0 || blk_nnz < nnz_per_block) {
            blk_nnz += m.row_nnz(row_end);
            row_end += 1;
        }
        let mut cycles = C_BLOCK_SETUP;
        // Row-block metadata read.
        ctx.stream_read(AUX_BASE + (row as u64) * 4, 4);
        for r in row..row_end {
            let lo = m.row_ptr[r] as usize;
            let hi = m.row_ptr[r + 1] as usize;
            ctx.stream_read(PTR_BASE + r as u64 * 4, 8);
            ctx.stream_read(COL_BASE + lo as u64 * 4, (hi - lo) as u64 * 4);
            ctx.stream_read(VAL_BASE + lo as u64 * tau, (hi - lo) as u64 * tau);
            let mut k = lo;
            while k < hi {
                let kend = (k + warp).min(hi);
                ctx.warp_gather_x(&mut m.col_idx[k..kend].iter().map(|&c| c as usize), tau);
                cycles += C_ITER_CSR;
                ctx.trace.lane_slots += warp as u64;
                k = kend;
            }
            cycles += C_REDUCE / 2.0; // block-wide reduction amortized
        }
        ctx.stream_write((row_end - row) as u64 * tau);
        ctx.trace.block_cycles.push(cycles);
        row = row_end;
    }
    ctx.finish()
}

/// Merge-based SpMV (Merrill & Garland): perfectly balanced merge-path
/// segments; streams CSR arrays once plus row_ptr again for the path
/// searches; carry fix-up kernel adds a small write pass.
pub fn merge_based<S: Scalar>(m: &Csr<S>, dev: &GpuDevice) -> KernelTrace {
    let tau = S::BYTES as u64;
    let warp = dev.warp_size;
    let mut ctx = Ctx::new("merge", m.nnz(), m.nrows(), true, dev);
    let items_per_block = 4096usize;
    let total = m.nnz() + m.nrows();
    let blocks = total.div_ceil(items_per_block).max(1);
    // Streams: all of col/val/row_ptr once, coalesced.
    ctx.stream_read(COL_BASE, m.nnz() as u64 * 4);
    ctx.stream_read(VAL_BASE, m.nnz() as u64 * tau);
    ctx.stream_read(PTR_BASE, (m.nrows() as u64 + 1) * 4);
    // Path searches re-read scattered row_ptr: 2 binary searches per
    // block ≈ 2*log2(n) sector touches.
    let log_n = (m.nrows() as f64).log2().ceil().max(1.0) as u64;
    for b in 0..blocks {
        ctx.stream_read(PTR_BASE + (b as u64 * 997) % (m.nrows() as u64 + 1) * 4, log_n * 4);
    }
    // Gathers in nnz order.
    let mut k = 0usize;
    let mut block_cycle_acc = C_BLOCK_SETUP;
    let mut items_in_block = 0usize;
    while k < m.nnz() {
        let kend = (k + warp).min(m.nnz());
        ctx.warp_gather_x(&mut m.col_idx[k..kend].iter().map(|&c| c as usize), tau);
        block_cycle_acc += C_ITER_CSR + 1.0; // merge-path bookkeeping
        ctx.trace.lane_slots += warp as u64;
        items_in_block += kend - k;
        if items_in_block >= items_per_block {
            ctx.trace.block_cycles.push(block_cycle_acc);
            block_cycle_acc = C_BLOCK_SETUP;
            items_in_block = 0;
        }
        k = kend;
    }
    if items_in_block > 0 {
        ctx.trace.block_cycles.push(block_cycle_acc);
    }
    ctx.stream_write(m.nrows() as u64 * tau);
    // Carry fix-up pass.
    ctx.stream_write(blocks as u64 * (tau + 4));
    ctx.finish()
}

/// CSR5 analogue: tiled (ω=4, σ=16) column-major layout with per-tile
/// descriptors; balanced over nnz.
pub fn csr5<S: Scalar>(m: &Csr<S>, dev: &GpuDevice) -> KernelTrace {
    let tau = S::BYTES as u64;
    let warp = dev.warp_size;
    let mut ctx = Ctx::new("csr5", m.nnz(), m.nrows(), true, dev);
    let tile = 64usize; // 4 x 16
    let tiles = m.nnz().div_ceil(tile);
    ctx.stream_read(COL_BASE, m.nnz() as u64 * 4);
    ctx.stream_read(VAL_BASE, m.nnz() as u64 * tau);
    // Tile descriptors: ~ tile/8 flag bytes + 8 byte tile_ptr per tile.
    ctx.stream_read(AUX_BASE, tiles as u64 * (tile as u64 / 8 + 8));
    let tiles_per_block = 64usize;
    let mut k = 0usize;
    let mut block_cycles = C_BLOCK_SETUP;
    let mut tiles_in_block = 0usize;
    while k < m.nnz() {
        let kend = (k + tile).min(m.nnz());
        let mut kk = k;
        while kk < kend {
            let kkend = (kk + warp).min(kend);
            ctx.warp_gather_x(&mut m.col_idx[kk..kkend].iter().map(|&c| c as usize), tau);
            block_cycles += C_ITER_ELL + 2.0; // segmented-scan overhead
            ctx.trace.lane_slots += warp as u64;
            kk = kkend;
        }
        tiles_in_block += 1;
        if tiles_in_block == tiles_per_block {
            ctx.trace.block_cycles.push(block_cycles);
            block_cycles = C_BLOCK_SETUP;
            tiles_in_block = 0;
        }
        k = kend;
    }
    if tiles_in_block > 0 {
        ctx.trace.block_cycles.push(block_cycles);
    }
    ctx.stream_write(m.nrows() as u64 * tau);
    ctx.finish()
}

/// yaSpMV BCOO analogue: column-major blocked COO with bit-flag row
/// markers and delta-compressed columns (~2.5 index bytes/nnz instead of
/// 4), segmented scan; balanced. The format the paper says costs
/// ~155,000 SpMVs of preprocessing.
pub fn bcoo_yaspmv<S: Scalar>(m: &Csr<S>, dev: &GpuDevice) -> KernelTrace {
    let tau = S::BYTES as u64;
    let warp = dev.warp_size;
    let mut ctx = Ctx::new("yaspmv", m.nnz(), m.nrows(), true, dev);
    // Compressed index stream: ~2.5 B/nnz amortized (delta + flags).
    ctx.stream_read(COL_BASE, (m.nnz() as u64 * 5) / 2);
    ctx.stream_read(VAL_BASE, m.nnz() as u64 * tau);
    let mut k = 0usize;
    let nnz_per_block = 4096usize;
    let mut block_cycles = C_BLOCK_SETUP;
    let mut in_block = 0usize;
    while k < m.nnz() {
        let kend = (k + warp).min(m.nnz());
        ctx.warp_gather_x(&mut m.col_idx[k..kend].iter().map(|&c| c as usize), tau);
        block_cycles += C_ITER_ELL + 2.5; // decompression + seg-scan
        ctx.trace.lane_slots += warp as u64;
        in_block += kend - k;
        if in_block >= nnz_per_block {
            ctx.trace.block_cycles.push(block_cycles);
            block_cycles = C_BLOCK_SETUP;
            in_block = 0;
        }
        k = kend;
    }
    if in_block > 0 {
        ctx.trace.block_cycles.push(block_cycles);
    }
    ctx.stream_write(m.nrows() as u64 * tau);
    ctx.finish()
}

/// EHYB kernel (paper Algorithm 3) with optional ablations:
/// `explicit_cache=false` fetches x through L2 even for the ELL part
/// (§7.1); `u16_cols=false` streams 4-byte columns (§7.2).
pub fn ehyb<S: Scalar>(
    e: &EhybMatrix<S>,
    dev: &GpuDevice,
    explicit_cache: bool,
    u16_cols: bool,
) -> KernelTrace {
    let tau = S::BYTES as u64;
    let h = e.slice_height;
    let col_bytes: u64 = if u16_cols { 2 } else { 4 };
    let mut ctx = Ctx::new(
        match (explicit_cache, u16_cols) {
            (true, true) => "ehyb",
            (false, true) => "ehyb-nocache",
            (true, false) => "ehyb-u32",
            (false, false) => "ehyb-nocache-u32",
        },
        e.nnz(),
        e.n,
        true, // Algorithm 3's atomic slice counter work-steals
        dev,
    );
    let spp = e.slices_per_part();
    for p in 0..e.num_parts {
        let mut cycles = C_BLOCK_SETUP;
        if explicit_cache {
            // Algorithm 3 line 4: coalesced fill of the x-slice cache.
            ctx.stream_read(X_BASE + (p * e.vec_size) as u64 * tau, e.vec_size as u64 * tau);
            cycles += e.vec_size as f64 * tau as f64 / dev.shm_bytes_per_cycle;
        }
        for ls in 0..spp {
            let s = p * spp + ls;
            let base = e.slice_ptr[s] as usize;
            let w = e.slice_width[s] as usize;
            // Streams: slice's cols (u16!) and vals, coalesced.
            ctx.stream_read(COL_BASE + base as u64 * col_bytes, (w * h) as u64 * col_bytes);
            ctx.stream_read(VAL_BASE + base as u64 * tau, (w * h) as u64 * tau);
            for k in 0..w {
                if explicit_cache {
                    // Served by shared memory: no L2 probe.
                    ctx.trace.shm_read_bytes += (h as u64) * tau;
                    cycles += C_ITER_SHM;
                } else {
                    let row0 = p * e.vec_size;
                    ctx.warp_gather_x(
                        &mut (0..h).map(|lane| {
                            let idx = base + k * h + lane;
                            row0 + e.ell_cols[idx] as usize
                        }),
                        tau,
                    );
                    cycles += C_ITER_ELL;
                }
                ctx.trace.lane_slots += h as u64;
            }
        }
        ctx.stream_write(e.vec_size as u64 * tau);
        ctx.trace.block_cycles.push(cycles);
    }
    // ER pass: its own grid of slices, work-stolen globally.
    let mut er_cycles = 0.0f64;
    for s in 0..e.er_slice_width.len() {
        let base = e.er_slice_ptr[s] as usize;
        let w = e.er_slice_width[s] as usize;
        ctx.stream_read(
            COL_BASE + (e.ell_cols.len() as u64 * col_bytes) + base as u64 * 4,
            (w * h) as u64 * 4,
        );
        ctx.stream_read(
            VAL_BASE + (e.ell_vals.len() as u64 * tau) + base as u64 * tau,
            (w * h) as u64 * tau,
        );
        for k in 0..w {
            ctx.warp_gather_x(
                &mut (0..h).map(|lane| {
                    let idx = base + k * h + lane;
                    e.er_cols[idx] as usize
                }),
                tau,
            );
            er_cycles += C_ITER_ELL;
            ctx.trace.lane_slots += h as u64;
        }
        // yIdxER read + atomic scatter-add.
        ctx.stream_read(AUX_BASE + (s * h) as u64 * 4, h as u64 * 4);
        ctx.stream_write(h as u64 * tau);
        er_cycles += C_ATOMIC;
    }
    if er_cycles > 0.0 {
        // Spread ER work as extra dynamic blocks (~one per 8 slices).
        let er_blocks = e.er_slice_width.len().div_ceil(8).max(1);
        for _ in 0..er_blocks {
            ctx.trace.block_cycles.push(C_BLOCK_SETUP + er_cycles / er_blocks as f64);
        }
    }
    ctx.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::preprocess::{EhybPlan, PreprocessConfig};
    use crate::sparse::gen::{poisson2d, poisson3d, unstructured_mesh};

    fn dev() -> GpuDevice {
        GpuDevice::v100()
    }

    #[test]
    fn traces_have_positive_traffic() {
        let m = poisson2d::<f64>(32, 32);
        for t in [
            csr_vector_alg1(&m, &dev()),
            csr_adaptive_alg2(&m, &dev()),
            merge_based(&m, &dev()),
            csr5(&m, &dev()),
            bcoo_yaspmv(&m, &dev()),
            hola(&m, &dev()),
        ] {
            assert!(t.hbm_read_bytes > 0, "{}", t.name);
            assert!(t.hbm_write_bytes > 0, "{}", t.name);
            assert!(!t.block_cycles.is_empty(), "{}", t.name);
            assert!(t.lane_efficiency() > 0.0 && t.lane_efficiency() <= 1.0, "{}", t.name);
        }
    }

    #[test]
    fn matrix_stream_bytes_lower_bound() {
        // Any CSR kernel must read at least col+val bytes from HBM+L2.
        let m = poisson3d::<f64>(12, 12, 12);
        let t = csr_vector_alg1(&m, &dev());
        let stream_min = m.nnz() as u64 * (4 + 8);
        assert!(
            t.hbm_read_bytes + t.l2_hit_bytes >= stream_min,
            "read {} < stream min {stream_min}",
            t.hbm_read_bytes + t.l2_hit_bytes
        );
    }

    #[test]
    fn ehyb_shm_serves_ell_gathers() {
        let m = poisson2d::<f64>(48, 48);
        let plan = EhybPlan::build(
            &m,
            &PreprocessConfig { vec_size_override: Some(256), ..Default::default() },
        )
        .unwrap();
        let t = ehyb(&plan.matrix, &dev(), true, true);
        assert!(t.shm_read_bytes > 0);
        // Explicit cache must replace most x gathers: shm bytes dominate
        // gather traffic for a well-partitioned stencil.
        let t_nc = ehyb(&plan.matrix, &dev(), false, true);
        assert!(t.hbm_read_bytes < t_nc.hbm_read_bytes + t_nc.l2_hit_bytes);
    }

    #[test]
    fn u16_cols_reduce_traffic() {
        let m = unstructured_mesh::<f64>(40, 40, 0.5, 3);
        let plan = EhybPlan::build(
            &m,
            &PreprocessConfig { vec_size_override: Some(256), ..Default::default() },
        )
        .unwrap();
        let t16 = ehyb(&plan.matrix, &dev(), true, true);
        let t32 = ehyb(&plan.matrix, &dev(), true, false);
        let r16 = t16.hbm_read_bytes + t16.l2_hit_bytes;
        let r32 = t32.hbm_read_bytes + t32.l2_hit_bytes;
        assert!(r16 < r32, "u16 {} >= u32 {}", r16, r32);
    }

    #[test]
    fn ehyb_nnz_matches() {
        let m = poisson2d::<f64>(24, 24);
        let plan = EhybPlan::build(
            &m,
            &PreprocessConfig { vec_size_override: Some(96), ..Default::default() },
        )
        .unwrap();
        let t = ehyb(&plan.matrix, &dev(), true, true);
        assert_eq!(t.nnz, m.nnz());
    }
}
