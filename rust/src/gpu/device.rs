//! GPU device models. Numbers for the V100-SXM2 come from the datasheet
//! and the paper (§2.1: 900 GB/s HBM, 80 SMs, 96 KiB shared per SM).

#[derive(Clone, Debug)]
pub struct GpuDevice {
    pub name: &'static str,
    pub sms: usize,
    pub warp_size: usize,
    /// Shared memory available to one block (bytes).
    pub shared_mem_per_block: usize,
    /// Core clock, GHz.
    pub clock_ghz: f64,
    /// HBM bandwidth, bytes/s.
    pub hbm_bw: f64,
    /// HBM latency (cycles) — the floor uncached gathers pay.
    pub hbm_latency: f64,
    /// L2 capacity in bytes.
    pub l2_bytes: usize,
    /// L2 hit bandwidth, bytes/s (V100 ≈ 2.5x HBM).
    pub l2_bw: f64,
    /// Shared-memory bandwidth per SM, bytes/cycle (V100: 128 B/clk).
    pub shm_bytes_per_cycle: f64,
    /// Warp instruction issue throughput per SM (schedulers).
    pub issue_per_cycle: f64,
    /// Kernel launch overhead, seconds.
    pub launch_overhead: f64,
    /// Memory sector (transaction) size, bytes.
    pub sector_bytes: usize,
}

impl GpuDevice {
    pub fn v100() -> Self {
        Self {
            name: "V100-SXM2",
            sms: 80,
            warp_size: 32,
            shared_mem_per_block: 96 * 1024,
            clock_ghz: 1.53,
            hbm_bw: 900.0e9,
            hbm_latency: 450.0,
            l2_bytes: 6 * 1024 * 1024,
            l2_bw: 2200.0e9,
            shm_bytes_per_cycle: 128.0,
            issue_per_cycle: 4.0,
            launch_overhead: 4.0e-6,
            sector_bytes: 32,
        }
    }

    /// Cycles available per second across the device.
    pub fn total_cycles_per_sec(&self) -> f64 {
        self.clock_ghz * 1e9
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn v100_datasheet_sanity() {
        let d = GpuDevice::v100();
        assert_eq!(d.sms, 80);
        assert_eq!(d.warp_size, 32);
        assert_eq!(d.shared_mem_per_block, 98304);
        assert!(d.hbm_bw > 8.0e11);
        assert!(d.l2_bw > d.hbm_bw);
    }
}
